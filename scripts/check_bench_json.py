#!/usr/bin/env python3
"""Validate a bench JSONL report (one JSON doc per line, each carrying a
`suite` name and a list of labeled metric `rows`).

Usage: check_bench_json.py <report.json> <suite>

One validator for every perf-smoke bench: exits non-zero when the report
is missing rows the suite must produce or a cross-row semantic invariant
fails (e.g. KBatched must reconfigure less than FIFO, batched queries
must cut matrix bytes per answer). Raw throughput numbers are never
gated here -- CI runners are too noisy -- only presence and internal
consistency.
"""

import json
import sys


def load_rows(path, suite):
    """All labeled rows across the file's JSONL docs, plus the row count."""
    rows = {}
    count = 0
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            doc = json.loads(line)
            assert doc["suite"] == suite, f"suite mismatch: {doc}"
            for row in doc["rows"]:
                rows[row["label"]] = row
                count += 1
    return rows, count


def require(rows, labels):
    for label in labels:
        assert label in rows, f"missing row {label}: {sorted(rows)}"


def check_lanczos_fused(rows, count):
    assert count >= 4, f"expected fused+unfused rows for K in {{8, 32}}, got {count}"
    return "fused+unfused K sweep present"


def check_lanczos_block(rows, count):
    require(rows, ("block_b1", "block_b4"))
    b1, b4 = rows["block_b1"], rows["block_b4"]
    # Stream-once accounting: the block path advances `block` columns per
    # matrix pass; the single path streams once per column.
    assert b1["spmv_count"] == b1["matrix_passes"], b1
    assert b4["spmv_count"] == 4 * b4["matrix_passes"], b4
    for row in (b1, b4):
        assert row["converged"] >= 1, row
    # The tentpole: matrix bytes per converged Ritz pair at least halve
    # at block width 4 (the bench itself asserts the same before writing).
    assert b4["bytes_drop_b4"] >= 2.0, b4
    assert b4["bytes_per_pair"] <= b1["bytes_per_pair"] / 2.0, (b1, b4)
    return (f"b=4 matrix bytes/converged-pair drop {b4['bytes_drop_b4']:.1f}x "
            f"({b1['matrix_passes']:.0f} -> {b4['matrix_passes']:.0f} passes)")


def check_service_throughput(rows, count):
    require(rows, ("single_job", "batch", "registry", "mixed_k_fifo",
                   "mixed_k_kbatched", "policy_summary"))
    summary = rows["policy_summary"]
    assert summary["kbatched_reconfigs"] < summary["fifo_reconfigs"], summary
    assert rows["registry"]["prepares"] == 1, rows["registry"]
    return (f"reconfigs fifo={summary['fifo_reconfigs']:.0f} "
            f"kbatched={summary['kbatched_reconfigs']:.0f}")


def check_delta_update(rows, count):
    for frac in ("0.001", "0.01", "0.1"):
        label = f"reprep_dirty_{frac}"
        require(rows, (label,))
        assert rows[label]["exact"] == 1.0, rows[label]
    require(rows, tuple(f"warm_vs_cold_k{k}" for k in (1, 4, 8)))
    # The smallest delta must reuse most CU shards.
    small = rows["reprep_dirty_0.001"]
    assert small["shards_reused"] >= 1, small
    return (f"0.1%-dirty re-prep speedup {small['speedup_incremental']:.2f}x, "
            f"warm k=1 saves {rows['warm_vs_cold_k1']['spmv_saved']:.0f} SpMVs")


def check_query_throughput(rows, count):
    require(rows, ("replica_equivalence", "query_only", "query_batched",
                   "query_early_exit", "ppr_only", "ppr_warm_restart",
                   "mixed_eigen_query"))
    assert rows["ppr_only"]["colsum_builds"] == 1, rows["ppr_only"]
    mixed = rows["mixed_eigen_query"]
    for key in ("query_p50_ms", "query_p99_ms", "jobs_per_s"):
        assert mixed[key] > 0, mixed
    assert mixed["query_p50_ms"] <= mixed["query_p99_ms"], mixed
    # Batched SpMM: matrix bytes per answered query must at least halve at
    # batch 4 and keep dropping at batch 8 (the bench separately gates
    # bitwise equality with the unbatched stream before reporting).
    batched = rows["query_batched"]
    assert batched["bytes_drop_b4"] >= 2.0, batched
    assert (batched["bytes_per_query_b8"] <= batched["bytes_per_query_b4"]
            <= batched["bytes_per_query_b1"]), batched
    early = rows["query_early_exit"]
    assert early["shards_skipped"] > 0, early
    warm = rows["ppr_warm_restart"]
    assert warm["warm_hits"] >= 1, warm
    assert warm["warm_iters"] <= warm["cold_iters"], warm
    return (f"batch=4 matrix bytes/query drop {batched['bytes_drop_b4']:.1f}x; "
            f"early exit skipped {early['shards_skipped']:.0f} shards; "
            f"warm PPR saves {warm['iters_saved']:.0f} sweeps; "
            f"mixed-load query p99 {mixed['query_p99_ms']:.2f} ms")


def check_lanczos_ooc(rows, count):
    names = ("f32", "q131", "q230", "q115")
    require(rows, tuple(f"resident_{n}" for n in names)
            + tuple(f"ooc_{n}" for n in names))
    for n in names:
        ooc = rows[f"ooc_{n}"]
        # The bench aborts before writing rows unless the OOC eigenpairs
        # match the resident solve bit-for-bit; the flag pins that here.
        assert ooc["bitwise_equal"] == 1.0, ooc
        assert ooc["io_bytes_read"] > 0, ooc
        assert ooc["bytes_per_s"] > 0, ooc
        assert rows[f"resident_{n}"]["bytes_per_s"] > 0, rows[f"resident_{n}"]
        # Double buffering must overlap I/O with compute: a sweep that
        # blocks on every chunk stalls as often as it reads.
        assert ooc["prefetch_stalls"] < ooc["chunks_read"], ooc
    f32 = rows["ooc_f32"]
    return (f"bitwise OK at 4 formats; f32 OOC {f32['bytes_per_s'] / 1e6:.0f} MB/s, "
            f"{f32['prefetch_stalls']:.0f} stalls / {f32['chunks_read']:.0f} chunk reads")


CHECKS = {
    "lanczos_fused": check_lanczos_fused,
    "lanczos_ooc": check_lanczos_ooc,
    "lanczos_block": check_lanczos_block,
    "service_throughput": check_service_throughput,
    "delta_update": check_delta_update,
    "query_throughput": check_query_throughput,
}


def main():
    if len(sys.argv) != 3 or sys.argv[2] not in CHECKS:
        sys.exit(f"usage: {sys.argv[0]} <report.json> <suite>; "
                 f"suites: {', '.join(sorted(CHECKS))}")
    path, suite = sys.argv[1], sys.argv[2]
    rows, count = load_rows(path, suite)
    detail = CHECKS[suite](rows, count)
    print(f"{path} valid ({count} rows); {detail}")


if __name__ == "__main__":
    main()

#!/usr/bin/env bash
# Generate an out-of-core R-MAT packet directory without ever holding the
# graph in memory: edges are re-derived per shard and written straight to
# 512-bit-aligned chunk files (see `topk-eigen generate-ooc --help`).
#
# Usage: scripts/gen_ooc_graph.sh <dir> [n] [degree] [precision] [cus]
#
#   dir        output packet directory (created; must not hold other data)
#   n          vertex count, power of two        (default 4194304 = 2^22)
#   degree     target edges per vertex           (default 8)
#   precision  f32 | q1.31 | q2.30 | q1.15       (default f32)
#   cus        shard files / compute units       (default 5)
#
# The resulting directory solves directly:
#   cargo run --release -- solve --ooc <dir> -k 8
set -euo pipefail

dir=${1:?usage: $0 <dir> [n] [degree] [precision] [cus]}
n=${2:-4194304}
degree=${3:-8}
precision=${4:-f32}
cus=${5:-5}

cd "$(dirname "$0")/../rust"
exec cargo run --release -- generate-ooc "$dir" \
    --n "$n" --degree "$degree" --precision "$precision" --cus "$cus"

//! Deterministic pseudo-random number generation (offline substitute for the
//! `rand` crate).
//!
//! Provides [`Pcg64`] (PCG-XSL-RR 128/64, the same generator family as
//! `rand_pcg::Pcg64`) seeded through SplitMix64, plus the handful of
//! distributions the workload generators and solvers need: uniform ints,
//! uniform floats, standard normal (Box-Muller), and Fisher-Yates shuffling.
//!
//! Determinism matters here: every synthetic graph in the Table II catalog is
//! identified by `(generator, scale, seed)`, so benches and tests are
//! reproducible bit-for-bit across runs and machines.

/// SplitMix64: used to expand a single `u64` seed into the 128-bit PCG state.
///
/// Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSL-RR 128/64: a small, fast, statistically strong PRNG.
///
/// 128 bits of state, 64-bit output, period 2^128.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let i0 = splitmix64(&mut sm);
        let i1 = splitmix64(&mut sm);
        let state = ((s0 as u128) << 64) | s1 as u128;
        // The increment must be odd.
        let inc = (((i0 as u128) << 64) | i1 as u128) | 1;
        let mut rng = Self { state, inc };
        // Burn one output so that nearby seeds decorrelate immediately.
        rng.next_u64();
        rng
    }

    /// Derive an independent stream, e.g. one per worker thread or CU shard.
    pub fn split(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform `u64` in `[0, bound)` via Lemire's nearly-divisionless method.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24 bits of precision.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal sample (Box-Muller; one value per call, no caching so
    /// the stream stays splittable/deterministic).
    pub fn normal(&mut self) -> f64 {
        // Avoid u1 == 0 (log(0)).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct values from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = rng.below(10) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            // Each bucket expects 10_000; allow 10% slack.
            assert!((9_000..=11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg64::new(11);
        let mut mean = 0.0;
        for _ in 0..100_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            mean += v;
        }
        mean /= 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle left input ordered");
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut rng = Pcg64::new(13);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn split_streams_decorrelate() {
        let mut root = Pcg64::new(42);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}

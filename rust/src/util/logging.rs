//! Minimal `log` facade backend: timestamped stderr logger with a level
//! filter from `TOPK_LOG` (error|warn|info|debug|trace). Install once at
//! process start; repeated installs are no-ops.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::{Once, OnceLock};
use std::time::Instant;

static INIT: Once = Once::new();
static LOGGER: StderrLogger = StderrLogger;
static START: OnceLock<Instant> = OnceLock::new();

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t0 = START.get().copied().unwrap_or_else(Instant::now);
        let dt = t0.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{dt:10.4}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). Level comes from `TOPK_LOG`, default
/// `info`.
pub fn init() {
    INIT.call_once(|| {
        let _ = START.set(Instant::now());
        let level = match std::env::var("TOPK_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        if log::set_logger(&LOGGER).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}

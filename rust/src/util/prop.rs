//! Miniature property-based testing harness (offline substitute for
//! `proptest`). Deterministic by default, seedable via
//! `TOPK_PROP_SEED`, case count via `TOPK_PROP_CASES`.
//!
//! Usage:
//! ```
//! use topk_eigen::prop_assert;
//! use topk_eigen::util::prop::{forall, Gen};
//! forall("sum is commutative", |g: &mut Gen| {
//!     let a = g.f64_in(-1.0, 1.0);
//!     let b = g.f64_in(-1.0, 1.0);
//!     prop_assert!(g, (a + b - (b + a)).abs() == 0.0, "a={a} b={b}");
//!     true
//! });
//! ```
//!
//! On failure the harness retries the failing case with progressively
//! "smaller" derived seeds (a bounded shrinking pass) and reports the
//! smallest reproduction seed it found.

use crate::util::rng::Pcg64;

/// Value generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// Size hint in `[0, 1]`; early cases are small, later cases large.
    /// Generators should scale collection lengths/magnitudes with this.
    pub size: f64,
    failure: Option<String>,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self { rng: Pcg64::new(seed), size, failure: None }
    }

    /// Record a failure message (used by `prop_assert!`).
    pub fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
    }

    /// Uniform usize in `[lo, hi]` (inclusive), scaled by the size hint so
    /// early cases stay small.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.size).ceil() as usize;
        let hi_eff = lo + scaled.min(span);
        self.rng.range(lo, hi_eff + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Random vector of length `len` with entries in `[lo, hi)`.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Random f32 vector of length `len` with entries in `[lo, hi)`.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + (hi - lo) * self.rng.f32()).collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }

    /// Access the underlying RNG for bespoke generation.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Configuration resolved from the environment.
fn config() -> (u64, usize) {
    let seed = std::env::var("TOPK_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x70_70_70);
    let cases = std::env::var("TOPK_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64);
    (seed, cases)
}

/// Run `prop` for the configured number of cases; panic with the seed of the
/// smallest failing case if any case returns `false` or records a failure.
pub fn forall(name: &str, prop: impl Fn(&mut Gen) -> bool) {
    let (base_seed, cases) = config();
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let size = ((case + 1) as f64 / cases as f64).min(1.0);
        if let Some(msg) = run_case(&prop, seed, size) {
            // Bounded "shrink": try smaller sizes with the same seed to find
            // a smaller reproduction, then report.
            let mut best = (size, msg);
            for step in 1..=8 {
                let smaller = size * (1.0 - step as f64 / 10.0);
                if smaller <= 0.0 {
                    break;
                }
                if let Some(m) = run_case(&prop, seed, smaller) {
                    best = (smaller, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}/{cases})\n  repro: TOPK_PROP_SEED={base_seed} seed={seed} size={:.2}\n  {}",
                best.0, best.1
            );
        }
    }
}

fn run_case(prop: &impl Fn(&mut Gen) -> bool, seed: u64, size: f64) -> Option<String> {
    let mut g = Gen::new(seed, size);
    let ok = prop(&mut g);
    if let Some(msg) = g.failure {
        Some(msg)
    } else if !ok {
        Some("property returned false".to_string())
    } else {
        None
    }
}

/// Assert inside a property, recording a rich message instead of panicking so
/// the harness can shrink.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)*) => {
        if !$cond {
            $g.fail(format!($($fmt)*));
            return false;
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_quietly() {
        forall("reverse twice is identity", |g| {
            let n = g.usize_in(0, 100);
            let v = g.vec_f64(n, -1.0, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            v == w
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_repro() {
        forall("always fails", |_g| false);
    }

    #[test]
    fn sizes_grow_over_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let max_len = AtomicU64::new(0);
        forall("observe sizes", |g| {
            let n = g.usize_in(0, 1000) as u64;
            max_len.fetch_max(n, Ordering::SeqCst);
            true
        });
        assert!(max_len.load(Ordering::SeqCst) > 100, "late cases should be large");
    }

    #[test]
    #[should_panic(expected = "x=")]
    fn prop_assert_reports_bindings() {
        forall("bad bound", |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!(g, x > 2.0, "x={x}");
            true
        });
    }
}

//! Tiny declarative command-line parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, positional
//! arguments, defaults, and auto-generated `--help`. Typed accessors parse
//! on demand and report errors with the offending flag name.

use std::collections::BTreeMap;
use std::fmt;

/// Error produced while parsing the command line.
#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Declaration of a single option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    name: &'static str,
    help: &'static str,
    default: Option<&'static str>,
    is_flag: bool,
}

/// Declaration of a command (or subcommand): options + positionals.
#[derive(Clone, Debug, Default)]
pub struct Command {
    name: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str)>,
    opt_positionals: Vec<(&'static str, &'static str)>,
}

impl Command {
    /// New command with a one-line description.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, ..Default::default() }
    }

    /// Add `--name <value>` with an optional default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    /// Add a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Add a required positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Add an optional trailing positional argument (after all required
    /// ones). When omitted, [`Matches::get`] returns `None` — the command
    /// decides whether another source (e.g. `--ooc <dir>`) stands in.
    pub fn positional_opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opt_positionals.push((name, help));
        self
    }

    /// Render the usage/help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        for (p, _) in &self.opt_positionals {
            s.push_str(&format!(" [<{p}>]"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() || !self.opt_positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
            for (p, h) in &self.opt_positionals {
                s.push_str(&format!("  [<{p}>]  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
                s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
            }
        }
        s
    }

    /// Parse a raw argument list (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos: Vec<String> = Vec::new();
        for o in &self.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}\n\n{}", self.usage())))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(CliError(format!("flag --{key} does not take a value")));
                    }
                    flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError(format!("option --{key} expects a value")))?
                            .clone(),
                    };
                    values.insert(key, v);
                }
            } else {
                pos.push(a.clone());
            }
        }
        if pos.len() < self.positionals.len() {
            return Err(CliError(format!(
                "missing required argument <{}>\n\n{}",
                self.positionals[pos.len()].0,
                self.usage()
            )));
        }
        for (i, (name, _)) in self.positionals.iter().enumerate() {
            values.insert(name.to_string(), pos[i].clone());
        }
        for (i, (name, _)) in self.opt_positionals.iter().enumerate() {
            if let Some(v) = pos.get(self.positionals.len() + i) {
                values.insert(name.to_string(), v.clone());
            }
        }
        let consumed =
            self.positionals.len() + self.opt_positionals.len().min(pos.len() - self.positionals.len());
        Ok(Matches { values, flags, extra_positionals: pos.split_off(consumed) })
    }
}

/// Result of a successful parse.
#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments beyond the declared ones.
    pub extra_positionals: Vec<String>,
}

impl Matches {
    /// Raw string value (from option, positional, or default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required string value.
    pub fn str(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError(format!("missing --{name}")))
    }

    /// Typed value parsed via `FromStr`.
    pub fn parse<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: fmt::Display,
    {
        let raw = self.str(name)?;
        raw.parse::<T>().map_err(|e| CliError(format!("--{name}={raw}: {e}")))
    }

    /// Was the boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated typed list (e.g. `--ks 4,8,16`). Empty items are
    /// rejected; the error names the offending flag.
    pub fn parse_list<T: std::str::FromStr>(&self, name: &str) -> Result<Vec<T>, CliError>
    where
        T::Err: fmt::Display,
    {
        let raw = self.str(name)?;
        raw.split(',')
            .map(|item| {
                item.trim()
                    .parse::<T>()
                    .map_err(|e| CliError(format!("--{name}={raw}: bad item '{item}': {e}")))
            })
            .collect()
    }

    /// Typed value with an inclusive lower bound — for counts that must be
    /// positive (eigenpairs, compute units, worker threads).
    pub fn parse_at_least<T>(&self, name: &str, min: T) -> Result<T, CliError>
    where
        T: std::str::FromStr + PartialOrd + fmt::Display,
        T::Err: fmt::Display,
    {
        let v = self.parse::<T>(name)?;
        if v < min {
            return Err(CliError(format!("--{name}={v}: must be >= {min}")));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("solve", "solve an eigenproblem")
            .positional("input", "matrix file")
            .opt("k", "number of eigenpairs", Some("8"))
            .opt("seed", "rng seed", Some("42"))
            .flag("verbose", "chatty output")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positional_options_and_flags() {
        let m = cmd().parse(&args(&["g.mtx", "--k", "16", "--verbose"])).unwrap();
        assert_eq!(m.str("input").unwrap(), "g.mtx");
        assert_eq!(m.parse::<usize>("k").unwrap(), 16);
        assert_eq!(m.parse::<u64>("seed").unwrap(), 42); // default
        assert!(m.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let m = cmd().parse(&args(&["g.mtx", "--k=24"])).unwrap();
        assert_eq!(m.parse::<usize>("k").unwrap(), 24);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&args(&["g.mtx", "--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_positional_errors() {
        let e = cmd().parse(&args(&[])).unwrap_err();
        assert!(e.0.contains("missing required argument <input>"), "{}", e.0);
    }

    #[test]
    fn bad_typed_value_reports_flag() {
        let m = cmd().parse(&args(&["g.mtx", "--k", "pony"])).unwrap();
        let e = m.parse::<usize>("k").unwrap_err();
        assert!(e.0.contains("--k=pony"), "{}", e.0);
    }

    #[test]
    fn help_is_an_error_carrying_usage() {
        let e = cmd().parse(&args(&["--help"])).unwrap_err();
        assert!(e.0.contains("USAGE"), "{}", e.0);
        assert!(e.0.contains("--k"));
    }

    #[test]
    fn parse_at_least_enforces_bound() {
        let m = cmd().parse(&args(&["g.mtx", "--k", "0"])).unwrap();
        let e = m.parse_at_least::<usize>("k", 1).unwrap_err();
        assert!(e.0.contains("must be >= 1"), "{}", e.0);
        let m = cmd().parse(&args(&["g.mtx", "--k", "3"])).unwrap();
        assert_eq!(m.parse_at_least::<usize>("k", 1).unwrap(), 3);
    }

    #[test]
    fn parse_list_splits_and_reports_bad_items() {
        let cmd = Command::new("serve", "serve").opt("ks", "k list", Some("4,8"));
        let m = cmd.parse(&args(&[])).unwrap();
        assert_eq!(m.parse_list::<usize>("ks").unwrap(), vec![4, 8]);
        let m = cmd.parse(&args(&["--ks", "2, 16 ,32"])).unwrap();
        assert_eq!(m.parse_list::<usize>("ks").unwrap(), vec![2, 16, 32]);
        let m = cmd.parse(&args(&["--ks", "2,pony"])).unwrap();
        let e = m.parse_list::<usize>("ks").unwrap_err();
        assert!(e.0.contains("'pony'"), "{}", e.0);
    }

    #[test]
    fn optional_positional_may_be_omitted() {
        let cmd = Command::new("solve", "solve")
            .positional_opt("input", "matrix file")
            .opt("ooc", "packet directory", None);
        let m = cmd.parse(&args(&["--ooc", "pkts/"])).unwrap();
        assert_eq!(m.get("input"), None);
        assert_eq!(m.str("ooc").unwrap(), "pkts/");
        let m = cmd.parse(&args(&["g.mtx"])).unwrap();
        assert_eq!(m.get("input"), Some("g.mtx"));
        assert_eq!(m.get("ooc"), None);
        let m = cmd.parse(&args(&["g.mtx", "trailing"])).unwrap();
        assert_eq!(m.extra_positionals, vec!["trailing".to_string()]);
        assert!(cmd.usage().contains("[<input>]"), "{}", cmd.usage());
    }

    #[test]
    fn extra_positionals_collected() {
        let m = cmd().parse(&args(&["g.mtx", "other1", "other2"])).unwrap();
        assert_eq!(m.extra_positionals, vec!["other1".to_string(), "other2".to_string()]);
    }
}

//! Thread-local allocation counting — test infrastructure for the
//! zero-steady-state-allocation guarantee of the fused Lanczos datapath.
//!
//! [`CountingAlloc`] wraps the system allocator and counts allocations and
//! allocated bytes **per thread**. It is test-only in the sense that
//! nothing in the library registers it: a test binary opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static A: topk_eigen::util::alloc::CountingAlloc = topk_eigen::util::alloc::CountingAlloc;
//! ```
//!
//! and then brackets the code under test with [`thread_allocations`]
//! snapshots (see `tests/alloc_regression.rs`). Counters are thread-local
//! so concurrent test threads do not interfere; pool-worker allocations are
//! attributed to the worker thread, not the publisher — the regression test
//! therefore measures the *publishing* thread, which is where every
//! steady-state allocation of the Lanczos loop would occur (workers only
//! run borrowed closures).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCATIONS: Cell<u64> = const { Cell::new(0) };
    static ALLOCATED_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Allocation calls made by the current thread since it started.
pub fn thread_allocations() -> u64 {
    ALLOCATIONS.try_with(|c| c.get()).unwrap_or(0)
}

/// Bytes requested by the current thread's allocation calls so far.
pub fn thread_allocated_bytes() -> u64 {
    ALLOCATED_BYTES.try_with(|c| c.get()).unwrap_or(0)
}

/// A [`GlobalAlloc`] that forwards to [`System`] and counts per-thread
/// allocation calls. Register it with `#[global_allocator]` in a test
/// binary; it costs two thread-local increments per allocation.
pub struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn record(size: usize) {
        // try_with: allocation can happen during TLS teardown, where the
        // counters are already destroyed — skip counting, never panic.
        let _ = ALLOCATIONS.try_with(|c| c.set(c.get() + 1));
        let _ = ALLOCATED_BYTES.try_with(|c| c.set(c.get() + size as u64));
    }
}

// SAFETY: forwards verbatim to `System`, which upholds the GlobalAlloc
// contract; the counters do not allocate (const-initialized Cells).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::record(layout.size());
        // SAFETY: the caller upholds GlobalAlloc's contract; forwarded
        // verbatim to `System`.
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: as in `alloc` — the caller's contract, forwarded.
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A realloc that grows is an allocation event for the purpose of
        // the steady-state regression (shrinks stay in place for System).
        if new_size > layout.size() {
            Self::record(new_size - layout.size());
        }
        // SAFETY: as in `alloc` — the caller's contract, forwarded.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::record(layout.size());
        // SAFETY: as in `alloc` — the caller's contract, forwarded.
        unsafe { System.alloc_zeroed(layout) }
    }
}

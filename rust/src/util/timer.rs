//! Timing and summary statistics used by the bench harness and the
//! coordinator's metrics.

use std::time::{Duration, Instant};

/// Stopwatch with lap support.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        let now = Instant::now();
        Self { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap` (or construction), and reset the lap.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Streaming summary statistics (Welford) over a series of samples.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
}

impl Stats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.samples.push(x);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
    /// Minimum sample.
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    /// Maximum sample.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Percentile in `[0, 100]` by nearest-rank on the recorded samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    /// Median (p50).
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Geometric mean of a slice (ignores non-positive entries, matching the
/// paper's geomean-speedup convention).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Format seconds human-readably (ns/us/ms/s).
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Measure the wall time of `f` in seconds, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Sleep-free busy-wait for at least `d` (used by failure-injection tests).
pub fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_and_stddev() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138).abs() < 1e-3);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentiles() {
        let mut s = Stats::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.median() - 50.0).abs() <= 1.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(90.0) - 90.0).abs() <= 1.0);
    }

    #[test]
    fn geomean_matches_hand_value() {
        let g = geomean(&[1.0, 4.0, 16.0]);
        assert!((g - 4.0).abs() < 1e-12, "{g}");
        // Non-positive entries are excluded, like the paper excluding HT.
        let g2 = geomean(&[1.0, 4.0, 16.0, 0.0, -3.0]);
        assert!((g2 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_duration_scales() {
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
        assert!(fmt_duration(2.5e-6).ends_with("us"));
        assert!(fmt_duration(2.5e-3).ends_with("ms"));
        assert!(fmt_duration(2.5).ends_with('s'));
    }

    #[test]
    fn time_it_measures() {
        let (v, dt) = time_it(|| {
            spin_for(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(dt >= 0.004, "dt={dt}");
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.median(), 0.0);
    }
}

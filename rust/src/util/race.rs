//! Opt-in scoped-claim race detector (`--features race-check`).
//!
//! The crate's parallel kernels share output buffers across scoped tasks
//! through [`SendPtr`](crate::util::ptr::SendPtr) and a manual disjointness
//! argument: every task writes only its own index range, and the fork/join
//! completes before the buffer moves or drops. This module machine-checks
//! that argument on demand:
//!
//! * [`ScopeToken::begin`] — opened by
//!   [`ThreadPool::scope_chunks`](crate::util::pool::ThreadPool::scope_chunks)
//!   before the task descriptor is published to workers; dropping it (after
//!   the join, also on unwind) retires the scope together with every claim
//!   registered under it, so a panicking task cannot leak claimed ranges.
//! * [`enter_task`] — binds a worker thread to `(scope, task index)` while
//!   it runs one claimed index; the guard pops the binding even on panic.
//! * [`claim_range`] — called by the checked `SendPtr` accessors *before*
//!   any reference is produced. Registers elements `[start, end)` of a
//!   buffer for the current task and panics if the range overlaps a claim
//!   made by a *different* task on the same buffer, naming both call
//!   sites. A claim arriving after its scope already joined fail-stops the
//!   process: the pointee's stack frame may already be gone, so no
//!   recovery is sound.
//! * [`lease`]/[`release`] — identity tracking for the out-of-core
//!   chunk-buffer pool: a pooled buffer handed out twice, or recycled
//!   twice, panics at the offending call site.
//!
//! Without the feature every hook is an empty `#[inline]` function — the
//! hot paths compile exactly as they did before the detector existed.
//! With it, overlap checking is O(claims²) per scope behind a per-scope
//! mutex: a debug/CI tool, not a production path.

#[cfg(feature = "race-check")]
mod imp {
    use std::cell::RefCell;
    use std::collections::{HashMap, HashSet};
    use std::panic::Location;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

    /// One registered write claim: elements `[start, end)` of the buffer
    /// based at `base`, made by scoped task `task` at `site`.
    struct Claim {
        base: usize,
        start: usize,
        end: usize,
        task: usize,
        site: &'static Location<'static>,
    }

    /// Claim registry of one live `scope_chunks` fork/join.
    struct ScopeState {
        closed: AtomicBool,
        claims: Mutex<Vec<Claim>>,
    }

    /// Detector locks ignore poisoning: the whole point of an overlap
    /// panic is to unwind through these mutexes, and the registry must
    /// stay coherent for the assertions that run after the catch.
    fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn registry() -> &'static Mutex<HashMap<u64, Arc<ScopeState>>> {
        static REGISTRY: OnceLock<Mutex<HashMap<u64, Arc<ScopeState>>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
    }

    fn leases() -> &'static Mutex<HashSet<u64>> {
        static LEASES: OnceLock<Mutex<HashSet<u64>>> = OnceLock::new();
        LEASES.get_or_init(|| Mutex::new(HashSet::new()))
    }

    thread_local! {
        /// Stack of `(scope, task index)` contexts; the top entry is the
        /// scoped task this thread is currently running. A stack, not a
        /// slot, because the publisher of one pool can drain a task that
        /// itself publishes a scope on a *different* pool.
        static CURRENT: RefCell<Vec<(Arc<ScopeState>, usize)>> = const { RefCell::new(Vec::new()) };
    }

    /// Live handle on one fork/join's claim registry. Created by the
    /// publisher before the task descriptor becomes visible to workers;
    /// dropped after the join completes (also on unwind), which erases the
    /// scope's claims and turns any straggler claim into a fail-stop.
    pub struct ScopeToken {
        id: u64,
    }

    impl ScopeToken {
        /// Open a new scope and register its (empty) claim set.
        pub fn begin() -> Self {
            static NEXT: AtomicU64 = AtomicU64::new(1);
            let id = NEXT.fetch_add(1, Ordering::Relaxed);
            let state = Arc::new(ScopeState {
                closed: AtomicBool::new(false),
                claims: Mutex::new(Vec::new()),
            });
            lock(registry()).insert(id, state);
            Self { id }
        }

        /// Identifier workers pass to [`enter_task`].
        pub fn id(&self) -> u64 {
            self.id
        }
    }

    impl Drop for ScopeToken {
        fn drop(&mut self) {
            if let Some(state) = lock(registry()).remove(&self.id) {
                state.closed.store(true, Ordering::Release);
            }
        }
    }

    /// Unbinds the thread's task context on drop (a panicking task still
    /// pops its binding on the way out).
    pub struct TaskGuard {
        /// Keep the guard on the thread that entered the task.
        _not_send: std::marker::PhantomData<*const ()>,
    }

    impl Drop for TaskGuard {
        fn drop(&mut self) {
            CURRENT.with(|c| {
                c.borrow_mut().pop();
            });
        }
    }

    /// Bind the current thread to task `task` of scope `scope` for the
    /// guard's lifetime. Fail-stops if the scope has already joined: a
    /// task starting after its publisher returned would read a freed stack
    /// frame, so no in-process recovery is sound.
    pub fn enter_task(scope: u64, task: usize) -> TaskGuard {
        let state = lock(registry()).get(&scope).cloned();
        let Some(state) = state else {
            eprintln!(
                "race-check: task {task} entered scope {scope} after its join completed; aborting"
            );
            std::process::abort();
        };
        CURRENT.with(|c| c.borrow_mut().push((state, task)));
        TaskGuard { _not_send: std::marker::PhantomData }
    }

    /// Register a claim on elements `[start, end)` of the buffer based at
    /// `base` for the scoped task running on this thread.
    ///
    /// No active scope (serial paths, the `tasks == 1` inline fast path)
    /// means there is nothing to race with: the claim is a no-op. An
    /// overlap with a *different* task's claim on the same buffer panics,
    /// naming both call sites; overlapping re-claims by the same task are
    /// fine (sequential within a task). A claim against a scope that has
    /// already joined fail-stops the process.
    #[track_caller]
    pub fn claim_range(base: usize, start: usize, end: usize) {
        let site = Location::caller();
        CURRENT.with(|cur| {
            let ctx = cur.borrow();
            let Some((state, task)) = ctx.last() else {
                return;
            };
            if state.closed.load(Ordering::Acquire) {
                eprintln!(
                    "race-check: post-join dereference at {site}: claim [{start}, {end}) on \
                     buffer {base:#x} arrived after the scope's join completed; aborting"
                );
                std::process::abort();
            }
            let mut claims = lock(&state.claims);
            for c in claims.iter() {
                if c.base == base && c.task != *task && start < c.end && c.start < end {
                    panic!(
                        "race-check: overlapping claims on buffer {base:#x}: task {task} claims \
                         [{start}, {end}) at {site}, task {} already claimed [{}, {}) at {}",
                        c.task, c.start, c.end, c.site
                    );
                }
            }
            claims.push(Claim { base, start, end, task: *task, site });
        });
    }

    /// Number of scopes currently open (tests assert this returns to zero
    /// after every join, including panicked ones).
    pub fn active_scopes() -> usize {
        lock(registry()).len()
    }

    /// Fresh identity for a pooled buffer (out-of-core lease tracking).
    pub fn new_lease_id() -> u64 {
        static NEXT: AtomicU64 = AtomicU64::new(1);
        NEXT.fetch_add(1, Ordering::Relaxed)
    }

    /// Record pooled buffer `id` as handed out to a consumer. Panics if it
    /// is already out — two owners of one recycled buffer is exactly the
    /// prefetch-pool bug class this guards.
    #[track_caller]
    pub fn lease(id: u64) {
        assert!(
            lock(leases()).insert(id),
            "race-check: pooled buffer {id} handed out while still leased (double handout)"
        );
    }

    /// Record pooled buffer `id` as returned to its pool. Panics if it was
    /// not out (double recycle).
    #[track_caller]
    pub fn release(id: u64) {
        assert!(
            lock(leases()).remove(&id),
            "race-check: pooled buffer {id} recycled while not leased (double recycle)"
        );
    }
}

#[cfg(not(feature = "race-check"))]
mod imp {
    //! Compiled-out stand-ins: every hook is an empty inline function the
    //! optimizer erases, so default builds pay nothing for the detector.

    /// Scope handle (no-op without `race-check`).
    pub struct ScopeToken;

    impl ScopeToken {
        /// Open a detector scope (no-op).
        #[inline(always)]
        pub fn begin() -> Self {
            ScopeToken
        }

        /// Scope id for task binding (always 0).
        #[inline(always)]
        pub fn id(&self) -> u64 {
            0
        }
    }

    /// Task-context guard (no-op without `race-check`).
    pub struct TaskGuard;

    /// Bind the current thread to `(scope, task)` (no-op).
    #[inline(always)]
    pub fn enter_task(_scope: u64, _task: usize) -> TaskGuard {
        TaskGuard
    }

    /// Register a half-open claim `[start, end)` on `base` (no-op).
    #[inline(always)]
    pub fn claim_range(_base: usize, _start: usize, _end: usize) {}

    /// Open detector scopes (always 0 without `race-check`).
    #[inline(always)]
    pub fn active_scopes() -> usize {
        0
    }

    /// Fresh pooled-buffer identity (always 0 without `race-check`).
    #[inline(always)]
    pub fn new_lease_id() -> u64 {
        0
    }

    /// Record a pooled-buffer handout (no-op).
    #[inline(always)]
    pub fn lease(_id: u64) {}

    /// Record a pooled-buffer return (no-op).
    #[inline(always)]
    pub fn release(_id: u64) {}
}

pub use imp::*;

#[cfg(all(test, feature = "race-check"))]
mod tests {
    use super::*;

    #[test]
    fn claims_without_a_scope_are_ignored() {
        // Serial paths (`tasks == 1` inlining, the default Operator's
        // serial `parallel_for`) claim with no active scope: must be free.
        claim_range(0x1000, 0, 10);
        claim_range(0x1000, 5, 15);
    }

    #[test]
    fn same_task_overlap_is_allowed_and_scope_retires() {
        {
            let scope = ScopeToken::begin();
            let _task = enter_task(scope.id(), 0);
            claim_range(0x2000, 0, 10);
            // Same task, overlapping range: sequential within the task.
            claim_range(0x2000, 5, 15);
        }
        // Token dropped: its registry record must be gone.
        // (Other tests may hold scopes concurrently, so only assert this
        // scope no longer pins the count above the others'.)
    }

    #[test]
    fn cross_task_overlap_panics_with_both_sites() {
        let scope = ScopeToken::begin();
        {
            let _t0 = enter_task(scope.id(), 0);
            claim_range(0x3000, 0, 100);
        }
        let _t1 = enter_task(scope.id(), 1);
        let r = std::panic::catch_unwind(|| claim_range(0x3000, 50, 150));
        let payload = r.expect_err("cross-task overlap must panic");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("overlapping claims"), "{msg}");
        assert_eq!(msg.matches("race.rs").count(), 2, "both sites named: {msg}");
    }

    #[test]
    fn distinct_buffers_do_not_conflict() {
        let scope = ScopeToken::begin();
        {
            let _t0 = enter_task(scope.id(), 0);
            claim_range(0x4000, 0, 100);
        }
        let _t1 = enter_task(scope.id(), 1);
        // Same range, different base: different buffer, no conflict.
        claim_range(0x5000, 0, 100);
    }

    #[test]
    fn lease_cycle_balances_and_double_lease_panics() {
        let id = new_lease_id();
        lease(id);
        release(id);
        lease(id);
        let r = std::panic::catch_unwind(|| lease(id));
        assert!(r.is_err(), "double handout must panic");
        release(id);
        let r = std::panic::catch_unwind(|| release(id));
        assert!(r.is_err(), "double recycle must panic");
    }
}

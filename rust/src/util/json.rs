//! Minimal JSON document builder + serializer (offline substitute for
//! `serde_json`). Write-only: the crate emits machine-readable bench and
//! experiment reports; it never needs to parse JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` uses a `BTreeMap` so output ordering is stable,
/// which keeps report diffs clean.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any finite number. NaN/inf serialize as `null` (matching serde_json).
    Num(f64),
    /// String (escaped on write).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a key (builder style); panics if `self` is not an object.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let j = Json::obj()
            .set("name", "wiki-Talk")
            .set("rows", 2_394_385usize)
            .set("ok", true)
            .set("ratio", 0.5f64)
            .set("tags", vec!["graph", "social"]);
        let s = j.to_string();
        assert_eq!(
            s,
            r#"{"name":"wiki-Talk","ok":true,"ratio":0.5,"rows":2394385,"tags":["graph","social"]}"#
        );
    }

    #[test]
    fn escapes_control_chars() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(j.to_string(), "\"a\\\"b\\\\c\\nd\\te\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_has_stable_key_order() {
        let j = Json::obj().set("b", 1u64).set("a", 2u64);
        assert_eq!(j.pretty(), "{\n  \"a\": 2,\n  \"b\": 1\n}");
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}

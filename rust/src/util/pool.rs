//! Scoped thread pool (offline substitute for `rayon`/`tokio` in the
//! coordinator's data-parallel paths).
//!
//! The pool models the paper's hardware parallelism: each worker stands in
//! for one SpMV Compute Unit (CU) fed by its own HBM pseudo-channel. Work is
//! submitted as closures; [`ThreadPool::scope_chunks`] provides structured
//! fork/join over borrowed data (the common case for sharded SpMV over one
//! matrix, and for the fused Lanczos vector sweeps).
//!
//! ## Reduction-friendly, allocation-free scoped dispatch
//!
//! `scope_chunks` sits on the per-iteration hot path of the fused Lanczos
//! datapath (three fork/joins per iteration), so it is written to perform
//! **zero heap allocations per call**: the scoped task descriptor lives on
//! the publishing caller's stack and is shared with the persistent workers
//! through a raw pointer guarded by the pool mutex — no `Box` per job, no
//! `Arc` per scope. The publisher also participates in draining the task
//! cursor, so a pool of `W` workers runs a scope on up to `W + 1` threads
//! and a scope never deadlocks even when every worker is busy.
//! `tests/alloc_regression.rs` pins the zero-allocation property.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A scoped parallel task published to the workers: the borrowed closure is
/// shared via raw pointer (valid while the publishing call blocks), tasks
/// are claimed through an atomic cursor, and completions are counted so the
/// publisher knows when every index has run.
struct ScopeTask {
    fptr: *const (),
    call: unsafe fn(*const (), usize),
    next: AtomicUsize,
    done: AtomicUsize,
    tasks: usize,
    /// Set when any task index panicked; remaining indices are skipped and
    /// the publisher re-raises after the join (so the stack-held closure is
    /// never freed while a worker can still reach it).
    panicked: AtomicBool,
    /// First panic's payload, re-raised verbatim by the publisher so the
    /// original assertion message survives the fork/join.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Race-detector scope id ([`crate::util::race::ScopeToken`]) binding
    /// every task index of this fork/join to one claim registry. Always 0
    /// without `--features race-check` (the detector is a no-op shim).
    race_scope: u64,
}

impl ScopeTask {
    /// Claim-and-run loop shared by workers and the publisher. Never
    /// unwinds: a panicking task marks the scope poisoned (skipping the
    /// indices not yet started), every claimed index still counts toward
    /// `done`, and the publisher re-raises the first panic after the join.
    ///
    /// # Safety
    /// `task` must point to a live `ScopeTask` whose closure outlives the
    /// call — guaranteed by `scope_chunks`, which keeps the descriptor on
    /// its stack and blocks until `done == tasks` and no worker holds the
    /// pointer (`scope_users == 0`).
    unsafe fn drain(task: *const ScopeTask) {
        // SAFETY: the caller's contract (above) — the descriptor outlives
        // this call.
        let t = unsafe { &*task };
        loop {
            let i = t.next.fetch_add(1, Ordering::Relaxed);
            if i >= t.tasks {
                break;
            }
            if !t.panicked.load(Ordering::Relaxed) {
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // Bind this thread to (scope, task index) for the race
                    // detector; the guard pops the binding even on panic,
                    // and the whole call is a no-op without `race-check`.
                    let _task = crate::util::race::enter_task(t.race_scope, i);
                    // SAFETY: see above — the closure is alive for the whole
                    // drain.
                    unsafe { (t.call)(t.fptr, i) }
                }));
                if let Err(payload) = run {
                    t.panicked.store(true, Ordering::Relaxed);
                    let mut slot = t.panic_payload.lock().expect("panic slot poisoned");
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
            }
            t.done.fetch_add(1, Ordering::Release);
        }
    }
}

struct PoolState {
    /// Fire-and-forget jobs from [`ThreadPool::execute`].
    queue: VecDeque<Job>,
    /// Currently-published scoped task (null when idle). Points into the
    /// stack frame of the blocked `scope_chunks` caller.
    scope: *const ScopeTask,
    /// Thread that published the current scope — publishing again from the
    /// same thread (its own scoped task calling back into the pool) would
    /// self-deadlock, so it is detected and rejected.
    scope_publisher: Option<std::thread::ThreadId>,
    /// Bumped per publication so a worker joins each scope at most once.
    scope_gen: u64,
    /// Workers currently holding the scope pointer; the publisher may not
    /// return (and free the descriptor) until this is back to zero.
    scope_users: usize,
    /// `execute` jobs queued or running (for [`ThreadPool::wait_idle`]).
    jobs_pending: usize,
    /// This pool's worker threads (registered at startup) — lets debug
    /// builds catch the deadlock-prone "scope published from inside a
    /// worker" pattern with a panic instead of a hang.
    worker_ids: Vec<std::thread::ThreadId>,
    shutdown: bool,
}

// SAFETY: the raw `scope` pointer is only ever dereferenced while the
// publishing `scope_chunks` call blocks (see ScopeTask::drain), so moving
// the state between threads under the pool mutex is sound.
unsafe impl Send for PoolState {}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for queue jobs, a new scope, or shutdown.
    work_cv: Condvar,
    /// `wait_idle` and scope publishers wait here for completions.
    done_cv: Condvar,
}

enum Work {
    Job(Job),
    Scope(*const ScopeTask),
    Exit,
}

/// Fixed-size thread pool with FIFO job dispatch and allocation-free
/// scoped fork/join.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (`size >= 1`).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "pool needs at least one worker");
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                scope: std::ptr::null(),
                scope_publisher: None,
                scope_gen: 0,
                scope_users: 0,
                jobs_pending: 0,
                worker_ids: Vec::with_capacity(size),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let shared = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cu-worker-{i}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("failed to spawn pool worker"),
            );
        }
        Self { shared, workers, size }
    }

    /// Pool with one worker per available hardware thread.
    pub fn with_default_parallelism() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    fn worker_loop(shared: &Shared) {
        shared
            .state
            .lock()
            .expect("pool state poisoned")
            .worker_ids
            .push(std::thread::current().id());
        // Generation of the last scope this worker joined (never re-join).
        let mut seen_gen = 0u64;
        loop {
            let work = {
                let mut st = shared.state.lock().expect("pool state poisoned");
                loop {
                    if let Some(job) = st.queue.pop_front() {
                        break Work::Job(job);
                    }
                    if !st.scope.is_null() && st.scope_gen != seen_gen {
                        seen_gen = st.scope_gen;
                        st.scope_users += 1;
                        break Work::Scope(st.scope);
                    }
                    if st.shutdown {
                        break Work::Exit;
                    }
                    st = shared.work_cv.wait(st).expect("pool state poisoned");
                }
            };
            match work {
                Work::Job(job) => {
                    // A panicking job must not kill the worker or leak the
                    // jobs_pending count (wait_idle would hang forever).
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    let mut st = shared.state.lock().expect("pool state poisoned");
                    st.jobs_pending -= 1;
                    drop(st);
                    shared.done_cv.notify_all();
                }
                Work::Scope(task) => {
                    // SAFETY: scope_users was incremented under the lock, so
                    // the publisher blocks until we are done with `task`.
                    unsafe { ScopeTask::drain(task) };
                    let mut st = shared.state.lock().expect("pool state poisoned");
                    st.scope_users -= 1;
                    drop(st);
                    shared.done_cv.notify_all();
                }
                Work::Exit => return,
            }
        }
    }

    /// Fire-and-forget execution of an owned closure. A panicking job is
    /// contained: the worker survives and the pending-job count stays
    /// balanced (the panic itself is discarded — jobs that can fail should
    /// report through their own channel).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.shared.state.lock().expect("pool state poisoned");
        assert!(!st.shutdown, "pool is shut down");
        st.jobs_pending += 1;
        st.queue.push_back(Box::new(job));
        drop(st);
        self.shared.work_cv.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().expect("pool state poisoned");
        while st.jobs_pending > 0 {
            st = self.shared.done_cv.wait(st).expect("pool state poisoned");
        }
    }

    /// Structured fork/join over borrowed data: run `f` for each index in
    /// `0..tasks`, partitioned across the pool's **persistent** workers and
    /// the calling thread, and join before returning.
    ///
    /// Allocation-free: the task descriptor lives on this call's stack and
    /// workers claim indices through an atomic cursor (see module docs).
    /// Concurrent publishers serialize (one scope active at a time). Must
    /// not be called from inside a worker of the same pool (asserted — a
    /// nested scope would wait on itself forever).
    ///
    /// Panic safety: a panic in `f` is caught on whichever thread ran it,
    /// the remaining unstarted indices are skipped, the join still
    /// completes (so the borrowed closure is never freed while a worker
    /// can reach it), and the first panic's payload is re-raised here on
    /// the publisher — the pool itself stays fully usable.
    pub fn scope_chunks<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if tasks == 0 {
            return;
        }
        if tasks == 1 {
            f(0);
            return;
        }

        unsafe fn call_impl<F: Fn(usize)>(p: *const (), i: usize) {
            // SAFETY: `p` is the publisher's `&F`, alive until the join
            // completes (the caller's contract).
            unsafe { (*(p as *const F))(i) }
        }

        // Open the race-detector scope before the descriptor becomes
        // visible to workers; declared before `task` so it drops after the
        // join (also on the resume_unwind path), retiring every claim.
        let race_scope = crate::util::race::ScopeToken::begin();
        let task = ScopeTask {
            fptr: &f as *const F as *const (),
            call: call_impl::<F>,
            next: AtomicUsize::new(0),
            tasks,
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            panic_payload: Mutex::new(None),
            race_scope: race_scope.id(),
        };
        {
            let me = std::thread::current().id();
            let mut st = self.shared.state.lock().expect("pool state poisoned");
            // Publishing from inside one of this pool's own scoped tasks
            // would deadlock (the blocked index can never finish while its
            // thread waits for the nested scope): fail fast instead of
            // hanging, whether the task ran on a worker or on the
            // publishing thread itself. Two ThreadId checks per fork/join
            // are negligible next to the join.
            assert!(
                !st.worker_ids.contains(&me),
                "scope_chunks called from inside a worker of the same pool"
            );
            assert!(
                !(!st.scope.is_null() && st.scope_publisher == Some(me)),
                "scope_chunks re-entered from the publishing thread's own scoped task"
            );
            // One scope at a time: wait for any concurrent publisher.
            while !st.scope.is_null() {
                st = self.shared.done_cv.wait(st).expect("pool state poisoned");
            }
            st.scope = &task;
            st.scope_publisher = Some(me);
            st.scope_gen = st.scope_gen.wrapping_add(1);
            drop(st);
            self.shared.work_cv.notify_all();
        }
        // The publisher participates: drain alongside the workers so the
        // scope completes even when every worker is busy elsewhere.
        // SAFETY: `task` is on this stack frame and we block below until
        // every index ran and no worker still holds the pointer; `drain`
        // never unwinds (task panics are latched into `task.panicked`).
        unsafe { ScopeTask::drain(&task) };
        let mut st = self.shared.state.lock().expect("pool state poisoned");
        while task.done.load(Ordering::Acquire) < tasks || st.scope_users > 0 {
            st = self.shared.done_cv.wait(st).expect("pool state poisoned");
        }
        st.scope = std::ptr::null();
        st.scope_publisher = None;
        drop(st);
        // Wake any publisher waiting for the scope slot.
        self.shared.done_cv.notify_all();
        // Re-raise the first task panic with its original payload so the
        // failing assertion's message survives the fork/join.
        let payload = task.panic_payload.lock().expect("panic slot poisoned").take();
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }

    /// Parallel map over indices `0..tasks`, preserving order of results.
    ///
    /// Dispatches through [`ThreadPool::scope_chunks`] — i.e. to the pool's
    /// persistent workers, not to freshly spawned OS threads — so warm-path
    /// callers pay no thread-spawn cost per call. Like `scope_chunks`, it
    /// must not be called from inside a worker of the same pool (asserted —
    /// the alternative is a silent deadlock).
    pub fn map<T, F>(&self, tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Send + Sync,
    {
        let mut out: Vec<Option<T>> = (0..tasks).map(|_| None).collect();
        {
            let slots = Mutex::new(&mut out);
            self.scope_chunks(tasks, |i| {
                let v = f(i);
                // Short critical section: one slot write.
                slots.lock().expect("map slots poisoned")[i] = Some(v);
            });
        }
        out.into_iter().map(|o| o.expect("worker skipped a slot")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("pool state poisoned");
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        // Miri runs the same logic at a fraction of the job count.
        let jobs = if cfg!(miri) { 16 } else { 100 };
        for _ in 0..jobs {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), jobs as u64);
    }

    #[test]
    fn scope_chunks_covers_every_index() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..57).map(|_| AtomicU64::new(0)).collect();
        pool.scope_chunks(57, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn scope_chunks_runs_on_pool_workers_and_caller_only() {
        // Dispatch must hit the persistent cu-workers (or the caller), never
        // a freshly spawned thread.
        let pool = ThreadPool::new(2);
        let caller = std::thread::current().id();
        let ok = AtomicU64::new(0);
        pool.scope_chunks(16, |_| {
            let here = std::thread::current();
            let on_pool = here.name().is_some_and(|n| n.starts_with("cu-worker-"));
            if on_pool || here.id() == caller {
                ok.fetch_add(1, Ordering::SeqCst);
            }
            // Give workers a chance to join in.
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(ok.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn consecutive_scopes_reuse_workers() {
        let pool = ThreadPool::new(3);
        let rounds = if cfg!(miri) { 5 } else { 50 };
        for round in 0..rounds {
            let hits: Vec<AtomicU64> = (0..7).map(|_| AtomicU64::new(0)).collect();
            pool.scope_chunks(7, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "round {round} index {i}");
            }
        }
    }

    #[test]
    fn scope_and_execute_interleave() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        let scoped = AtomicU64::new(0);
        pool.scope_chunks(20, |_| {
            scoped.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
        assert_eq!(scoped.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_borrows_local_state() {
        let pool = ThreadPool::new(2);
        let data: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let out = pool.map(4, |shard| data[shard * 8..(shard + 1) * 8].iter().sum::<f64>());
        assert_eq!(out.iter().sum::<f64>(), (0..32).sum::<usize>() as f64);
    }

    #[test]
    fn zero_tasks_is_noop() {
        let pool = ThreadPool::new(2);
        pool.scope_chunks(0, |_| panic!("must not run"));
        let v: Vec<usize> = pool.map(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn panicking_job_does_not_kill_worker_or_leak_pending() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("job boom"));
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        // The single worker must survive the panic, run the second job,
        // and wait_idle must not hang on a leaked pending count.
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scoped_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_chunks(16, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        // The original payload must survive the re-raise.
        let payload = r.expect_err("panic must propagate to the publisher");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // The pool and its workers must remain fully usable afterwards.
        let sum = AtomicU64::new(0);
        pool.scope_chunks(10, |i| {
            sum.fetch_add(i as u64 + 1, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 55);
        let out = pool.map(5, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn concurrent_publishers_serialize() {
        // Two threads publishing scopes on one pool must not corrupt each
        // other's reductions.
        let pool = Arc::new(ThreadPool::new(3));
        std::thread::scope(|s| {
            for t in 0..2 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    let rounds = if cfg!(miri) { 4 } else { 25 };
                    for _ in 0..rounds {
                        let sum = AtomicU64::new(0);
                        pool.scope_chunks(10, |i| {
                            sum.fetch_add(i as u64 + 1, Ordering::SeqCst);
                        });
                        assert_eq!(sum.load(Ordering::SeqCst), 55, "publisher {t}");
                    }
                });
            }
        });
    }
}

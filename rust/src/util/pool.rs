//! Scoped thread pool (offline substitute for `rayon`/`tokio` in the
//! coordinator's data-parallel paths).
//!
//! The pool models the paper's hardware parallelism: each worker stands in
//! for one SpMV Compute Unit (CU) fed by its own HBM pseudo-channel. Work is
//! submitted as closures; `scope` provides structured fork/join over
//! borrowed data (the common case for sharded SpMV over one matrix).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool with FIFO dispatch.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    in_flight: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// Spawn `size` workers (`size >= 1`).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "pool needs at least one worker");
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx: Arc<Mutex<Receiver<Msg>>> = Arc::clone(&rx);
            let in_flight = Arc::clone(&in_flight);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cu-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                job();
                                let (lock, cvar) = &*in_flight;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                cvar.notify_all();
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("failed to spawn pool worker"),
            );
        }
        Self { tx, workers, size, in_flight }
    }

    /// Pool with one worker per available hardware thread.
    pub fn with_default_parallelism() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget execution of an owned closure.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.in_flight;
            *lock.lock().unwrap() += 1;
        }
        self.tx.send(Msg::Run(Box::new(job))).expect("pool is shut down");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.in_flight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
    }

    /// Structured fork/join over borrowed data: run `f` for each index in
    /// `0..tasks`, partitioned across workers, and join before returning.
    ///
    /// Dispatches to the pool's **persistent** workers (no thread spawn per
    /// call — this sits on the per-iteration SpMV hot path, where a
    /// spawn-per-apply costs more than a small shard's compute; see
    /// EXPERIMENTS.md §Perf). Borrowed state is passed through a raw
    /// pointer that is guaranteed valid because this function blocks until
    /// every worker has finished.
    pub fn scope_chunks<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if tasks == 0 {
            return;
        }
        let workers = self.size.min(tasks);
        if workers <= 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }

        struct Ctx {
            fptr: *const (),
            call: unsafe fn(*const (), usize),
            next: AtomicUsize,
            tasks: usize,
            active: Mutex<usize>,
            done: std::sync::Condvar,
        }
        // SAFETY: the raw pointer is only dereferenced while `scope_chunks`
        // blocks below, so the borrow of `f` cannot dangle.
        unsafe impl Send for Ctx {}
        unsafe impl Sync for Ctx {}

        unsafe fn call_impl<F: Fn(usize)>(p: *const (), i: usize) {
            unsafe { (*(p as *const F))(i) }
        }

        let ctx = Arc::new(Ctx {
            fptr: &f as *const F as *const (),
            call: call_impl::<F>,
            next: AtomicUsize::new(0),
            tasks,
            active: Mutex::new(workers),
            done: std::sync::Condvar::new(),
        });
        for _ in 0..workers {
            let c = Arc::clone(&ctx);
            self.execute(move || {
                loop {
                    let i = c.next.fetch_add(1, Ordering::Relaxed);
                    if i >= c.tasks {
                        break;
                    }
                    // SAFETY: see Ctx — `f` outlives this call.
                    unsafe { (c.call)(c.fptr, i) }
                }
                let mut active = c.active.lock().unwrap();
                *active -= 1;
                if *active == 0 {
                    c.done.notify_all();
                }
            });
        }
        let mut active = ctx.active.lock().unwrap();
        while *active > 0 {
            active = ctx.done.wait(active).unwrap();
        }
    }

    /// Parallel map over indices `0..tasks`, preserving order of results.
    pub fn map<T, F>(&self, tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Send + Sync,
    {
        let mut out: Vec<Option<T>> = (0..tasks).map(|_| None).collect();
        {
            let slots = Mutex::new(&mut out);
            let next = AtomicUsize::new(0);
            let workers = self.size.min(tasks.max(1));
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks {
                            break;
                        }
                        let v = f(i);
                        // Short critical section: one slot write.
                        let mut guard = slots.lock().unwrap();
                        guard[i] = Some(v);
                    });
                }
            });
        }
        out.into_iter().map(|o| o.expect("worker skipped a slot")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_chunks_covers_every_index() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..57).map(|_| AtomicU64::new(0)).collect();
        pool.scope_chunks(57, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_borrows_local_state() {
        let pool = ThreadPool::new(2);
        let data: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let out = pool.map(4, |shard| {
            data[shard * 8..(shard + 1) * 8].iter().sum::<f64>()
        });
        assert_eq!(out.iter().sum::<f64>(), (0..32).sum::<usize>() as f64);
    }

    #[test]
    fn zero_tasks_is_noop() {
        let pool = ThreadPool::new(2);
        pool.scope_chunks(0, |_| panic!("must not run"));
        let v: Vec<usize> = pool.map(0, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}

//! Shared raw-pointer wrapper for disjoint-chunk parallel writes.
//!
//! The sharded SpMV engine and the fused Lanczos vector sweeps both hand
//! every worker a full-length output buffer through a raw pointer and rely
//! on a manual disjointness argument: each task writes only its own index
//! range, and the structured fork/join
//! ([`crate::util::pool::ThreadPool::scope_chunks`]) returns before the
//! pointee can move or drop. [`SendPtr`] is the single place that unsafe
//! `Send`/`Sync` assertion lives, so the aliasing contract has one audit
//! point instead of one copy per call site.

/// Raw mutable pointer asserted to be safe to share across a structured
/// fork/join. The safety obligation is the *caller's*: tasks must write
/// disjoint ranges and the join must complete before the pointee goes
/// away.
pub struct SendPtr<T>(
    /// The shared address.
    pub *mut T,
);

// SAFETY: the wrapper only transports the address; all dereferences happen
// inside scoped tasks whose disjointness and lifetime the publishing call
// site proves (see the SAFETY comments at each use).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> Copy for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> SendPtr<T> {
    /// The wrapped pointer.
    pub fn get(self) -> *mut T {
        self.0
    }
}

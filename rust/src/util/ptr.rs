//! Shared raw-pointer wrapper for disjoint-chunk parallel writes.
//!
//! The sharded SpMV engine and the fused Lanczos vector sweeps both hand
//! every worker a full-length output buffer through a raw pointer and rely
//! on a manual disjointness argument: each task writes only its own index
//! range, and the structured fork/join
//! ([`crate::util::pool::ThreadPool::scope_chunks`]) returns before the
//! pointee can move or drop. [`SendPtr`] is the single place that unsafe
//! `Send`/`Sync` assertion lives, so the aliasing contract has one audit
//! point instead of one copy per call site.
//!
//! The [`slice_mut`](SendPtr::slice_mut) and [`set`](SendPtr::set)
//! accessors are the *checked* way to dereference: under
//! `--features race-check` they register the claimed index range with
//! [`crate::util::race`] before producing a reference, so overlapping
//! claims from different scoped tasks panic with both call sites named.
//! In default builds the claim is a compiled-out no-op.

/// Raw mutable pointer asserted to be safe to share across a structured
/// fork/join. The safety obligation is the *caller's*: tasks must write
/// disjoint ranges and the join must complete before the pointee goes
/// away.
pub struct SendPtr<T>(
    /// The shared address.
    pub *mut T,
);

// SAFETY: the wrapper only transports the address; all dereferences happen
// inside scoped tasks whose disjointness and lifetime the publishing call
// site proves (see the SAFETY comments at each use).
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: as above — `SendPtr` is a plain address; shared references to it
// never dereference, so `Sync` adds no obligations beyond `Send`'s.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> Copy for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> SendPtr<T> {
    /// The wrapped pointer.
    pub fn get(self) -> *mut T {
        self.0
    }

    /// Exclusive view of elements `[start, start + len)` of the pointed-to
    /// buffer, race-claimed for the current scoped task.
    ///
    /// # Safety
    ///
    /// The caller must guarantee the pointer addresses at least
    /// `start + len` initialized, aligned elements that outlive `'a`, and
    /// that no other reference to that element range exists while the
    /// returned slice is live (scoped tasks prove this by tiling disjoint
    /// ranges and joining before the buffer moves).
    #[track_caller]
    pub unsafe fn slice_mut<'a>(self, start: usize, len: usize) -> &'a mut [T] {
        crate::util::race::claim_range(self.0 as usize, start, start + len);
        // SAFETY: the caller's contract above — `start + len` in-bounds
        // elements, no aliasing view, pointee outlives `'a`.
        unsafe { std::slice::from_raw_parts_mut(self.0.add(start), len) }
    }

    /// Overwrite element `index` (dropping the old value), race-claimed
    /// for the current scoped task.
    ///
    /// # Safety
    ///
    /// The caller must guarantee `index` is in bounds of an initialized,
    /// live buffer and that no other access to that element races with
    /// this write.
    #[track_caller]
    pub unsafe fn set(self, index: usize, value: T) {
        crate::util::race::claim_range(self.0 as usize, index, index + 1);
        // SAFETY: the caller's contract above — `index` in bounds,
        // initialized, unaliased. Place assignment (not `ptr::write`) so
        // the previous element value is dropped.
        unsafe { *self.0.add(index) = value }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_views_from_one_base_are_independent() {
        let mut buf = vec![0u32; 8];
        let p = SendPtr(buf.as_mut_ptr());
        // SAFETY: the two views tile [0, 8) disjointly and `buf` outlives
        // both (this test is serial, so no scope is active).
        let lo = unsafe { p.slice_mut(0, 4) };
        // SAFETY: as above — [4, 8) does not overlap [0, 4).
        let hi = unsafe { p.slice_mut(4, 4) };
        lo.fill(1);
        hi.fill(2);
        assert_eq!(buf, [1, 1, 1, 1, 2, 2, 2, 2]);
    }

    #[test]
    fn set_writes_one_element_and_drops_the_old_value() {
        let mut buf = vec![vec![1usize; 3], vec![2; 3]];
        let p = SendPtr(buf.as_mut_ptr());
        // SAFETY: index 1 is in bounds and nothing else touches it.
        unsafe { p.set(1, vec![9; 2]) };
        assert_eq!(buf[0], [1, 1, 1]);
        assert_eq!(buf[1], [9, 9]);
    }
}

//! Small self-contained substrates that replace crates unavailable in the
//! offline vendor set (clap, rand, serde_json, rayon/tokio, proptest).
//!
//! Each submodule is deliberately minimal but production-shaped: documented,
//! tested, and used pervasively by the rest of the crate.

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;

//! Small self-contained substrates that replace crates unavailable in the
//! offline vendor set (clap, rand, serde_json, rayon/tokio, proptest).
//! (`anyhow`, `log`, and the `xla` API stub live as path crates under
//! `rust/vendor/` instead, because their call sites use crate-qualified
//! paths.)
//!
//! Each submodule is deliberately minimal but production-shaped: documented,
//! tested, and used pervasively by the rest of the crate.

pub mod alloc;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod ptr;
pub mod prop;
pub mod race;
pub mod rng;
pub mod timer;

//! Hardware trigonometry (§IV-C1).
//!
//! The diagonal CUs need `theta = 0.5 * atan(2b / (a - d))` and then
//! `cos(theta)`, `sin(theta)`. The paper replaces the CORDIC core with
//! order-3 Taylor expansions, "excellent accuracy (~1e-6 at +-pi/4), using
//! significantly fewer DSPs and BRAMs". Because `theta = atan(x)/2` is
//! always in `[-pi/4, pi/4]`, the expansion point never leaves the
//! well-behaved region — that interval bound is what makes the cheap
//! polynomial viable in hardware.

/// Which trig datapath to model.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum TrigMode {
    /// libm `atan2`/`sin_cos` — the software reference.
    Exact,
    /// Order-3-term Taylor/minimax polynomials — the FPGA datapath.
    Taylor3,
}

/// Rotation coefficients `(c, s) = (cos(theta), sin(theta))` with
/// `theta = 0.5 * atan2(2*beta, alpha - delta)` — the annihilating angle of
/// Figure 4a.
pub fn rotation_coeffs(alpha: f64, beta: f64, delta: f64, mode: TrigMode) -> (f64, f64) {
    match mode {
        TrigMode::Exact => {
            let theta = 0.5 * (2.0 * beta).atan2(alpha - delta);
            (theta.cos(), theta.sin())
        }
        TrigMode::Taylor3 => {
            let theta = 0.5 * atan2_taylor(2.0 * beta, alpha - delta);
            let (c, s) = (cos_taylor(theta), sin_taylor(theta));
            // One Newton rsqrt step renormalizes (c, s) onto the unit
            // circle (~2 DSP multiplies in hardware): keeps every rotation
            // exactly orthogonal so errors cannot accumulate across the
            // O(log K) sweeps — only the *angle* carries Taylor error.
            let r2 = c * c + s * s;
            let inv = 0.5 * (3.0 - r2); // Newton for 1/sqrt around 1
            (c * inv, s * inv)
        }
    }
}

/// atan via an order-3 (3-term) polynomial in the |x| <= 1 region, with the
/// standard range reductions `atan(x) = pi/2 - atan(1/x)` for |x| > 1 and
/// quadrant fixup for the atan2 form. Max error ~1e-5 rad on |x|<=1 wich
/// halves at the theta/2 consumer, matching the paper's ~1e-6 claim.
pub fn atan2_taylor(y: f64, x: f64) -> f64 {
    use std::f64::consts::{FRAC_PI_2, PI};
    if x == 0.0 && y == 0.0 {
        // Hardware convention: zero angle when the block is already diagonal.
        return 0.0;
    }
    let (ax, ay) = (x.abs(), y.abs());
    // Core approximation on t in [0, 1].
    let base = |t: f64| -> f64 {
        // Degree-11 odd polynomial fit at Chebyshev nodes for atan on
        // [0,1]: |err| < 2e-6 rad (matching the paper's ~1e-6-at-pi/4
        // claim once halved at the theta/2 consumer); Horner form
        // synthesizes into 6 DSP multiplies.
        let t2 = t * t;
        t * (0.999_974_491
            + t2 * (-0.332_568_317
                + t2 * (0.193_235_292
                    + t2 * (-0.115_729_441 + t2 * (0.051_950_532 + t2 * -0.011_465_810)))))
    };
    let r = if ay <= ax { base(ay / ax) } else { FRAC_PI_2 - base(ax / ay) };
    let r = if x < 0.0 { PI - r } else { r };
    if y < 0.0 {
        -r
    } else {
        r
    }
}

/// sin via odd Taylor series to x^7 (|x| <= pi/4: error < 1e-8).
pub fn sin_taylor(x: f64) -> f64 {
    let x2 = x * x;
    x * (1.0 - x2 / 6.0 * (1.0 - x2 / 20.0 * (1.0 - x2 / 42.0)))
}

/// cos via even Taylor series to x^8 (|x| <= pi/4: error < 3e-9).
pub fn cos_taylor(x: f64) -> f64 {
    let x2 = x * x;
    1.0 - x2 / 2.0 * (1.0 - x2 / 12.0 * (1.0 - x2 / 30.0 * (1.0 - x2 / 56.0)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::{FRAC_PI_4, PI};

    #[test]
    fn sincos_taylor_accuracy_on_quarter_pi() {
        // The paper claims ~1e-6 at +-pi/4; our series beat that.
        let mut worst = 0.0f64;
        for i in -100..=100 {
            let x = FRAC_PI_4 * i as f64 / 100.0;
            worst = worst.max((sin_taylor(x) - x.sin()).abs());
            worst = worst.max((cos_taylor(x) - x.cos()).abs());
        }
        assert!(worst < 1e-6, "worst sin/cos error {worst}");
    }

    #[test]
    fn atan2_taylor_accuracy() {
        let mut worst = 0.0f64;
        for i in 0..=360 {
            let a = PI * (i as f64 - 180.0) / 180.0;
            let (y, x) = (a.sin() * 3.0, a.cos() * 3.0);
            let err = (atan2_taylor(y, x) - y.atan2(x)).abs();
            worst = worst.max(err);
        }
        assert!(worst < 4e-6, "worst atan2 error {worst}");
    }

    #[test]
    fn rotation_annihilates_offdiagonal() {
        // Rotating [[a, b], [b, d]] by the computed theta must zero the
        // off-diagonal: check |b'| tiny for both datapaths.
        for (a, b, d) in [(0.8, 0.3, -0.2), (0.1, -0.5, 0.4), (-0.9, 0.05, -0.91), (0.5, 0.0, 0.5)] {
            for mode in [TrigMode::Exact, TrigMode::Taylor3] {
                let (c, s) = rotation_coeffs(a, b, d, mode);
                // b' = (d - a) sc + b (c^2 - s^2)
                let b_new = (d - a) * s * c + b * (c * c - s * s);
                let tol = if mode == TrigMode::Exact { 1e-12 } else { 3e-5 };
                assert!(b_new.abs() < tol, "{mode:?} a={a} b={b} d={d}: b'={b_new}");
            }
        }
    }

    #[test]
    fn rotation_is_orthonormal() {
        for mode in [TrigMode::Exact, TrigMode::Taylor3] {
            let (c, s) = rotation_coeffs(0.3, 0.7, -0.4, mode);
            assert!((c * c + s * s - 1.0).abs() < 1e-9, "{mode:?}: c^2+s^2 = {}", c * c + s * s);
        }
    }

    #[test]
    fn degenerate_zero_block() {
        let (c, s) = rotation_coeffs(0.0, 0.0, 0.0, TrigMode::Taylor3);
        assert!((c - 1.0).abs() < 1e-9 && s.abs() < 1e-9);
    }
}

//! Systolic-array Jacobi (§III-B, §IV-C; Algorithm 2) — a cycle-faithful
//! software model of the Brent-Luk processor grid.
//!
//! The hardware maps the `K x K` matrix onto `K^2/4` processing elements,
//! each holding a 2x2 block. One *parallel step* does, simultaneously:
//!
//! 1. every diagonal PE computes its annihilating angle (Taylor trig) and
//!    rotates its block (Fig 4a);
//! 2. every off-diagonal PE applies the row angle from `p_ii` and the
//!    column angle from `p_jj` (Fig 4b);
//! 3. every eigenvector PE applies the column angle (Fig 4c);
//! 4. rows/columns interchange per the Brent-Luk round-robin so new pairs
//!    become adjacent — executed *in reverse order* (§IV-C2), the paper's
//!    resource optimization that avoids K temporary vectors.
//!
//! Because the K/2 rotations of a step touch disjoint index pairs, the
//! parallel hardware step is mathematically a product of commuting Givens
//! rotations; the model applies them sequentially and counts one step.
//! Convergence takes `O(log K)` *sweeps* (each sweep = K-1 steps of
//! constant hardware latency), versus the CPU's `O(K^3)`-per-sweep cost.

use crate::jacobi::cyclic::apply_givens;
use crate::jacobi::trig::{rotation_coeffs, TrigMode};
use crate::linalg::DenseMatrix;

/// Statistics from a systolic run (consumed by the FPGA timing model).
#[derive(Clone, Copy, Debug, Default)]
pub struct SystolicStats {
    /// Parallel steps executed (each = constant cycles in hardware).
    pub steps: usize,
    /// Full sweeps (K-1 steps each).
    pub sweeps: usize,
    /// Total 2x2 rotations performed across all PEs.
    pub rotations: usize,
}

/// Round-robin pairing state (the tournament "circle method").
///
/// Slots: `top[i]` meets `bottom[i]`. Element `top[0]` is pinned; the rest
/// rotate one position per step. After `K-1` steps every unordered pair has
/// met exactly once — this is precisely the Brent-Luk data movement, with
/// the physical shifts realized here as an index permutation (the hardware
/// moves values between neighbour PEs; §IV-C2's "reverse order" trick makes
/// those shifts in-place with FFs only).
#[derive(Clone, Debug)]
pub struct RoundRobin {
    top: Vec<usize>,
    bottom: Vec<usize>,
}

impl RoundRobin {
    /// Initial adjacent pairing (0,1), (2,3), ...
    pub fn new(k: usize) -> Self {
        assert!(k >= 2 && k % 2 == 0, "round robin needs even k >= 2, got {k}");
        let top = (0..k / 2).map(|i| 2 * i).collect();
        let bottom = (0..k / 2).map(|i| 2 * i + 1).collect();
        Self { top, bottom }
    }

    /// Current disjoint pairs.
    pub fn pairs(&self) -> Vec<(usize, usize)> {
        self.top.iter().zip(&self.bottom).map(|(&a, &b)| (a.min(b), a.max(b))).collect()
    }

    /// Advance one step. The shift runs from high indices to low —
    /// "in reverse" — so each slot's source is read before being
    /// overwritten, the in-place schedule of §IV-C2.
    pub fn advance(&mut self) {
        let m = self.top.len();
        if m == 1 {
            return;
        }
        // Keep top[0]; bottom[0] moves into top[1]; top shifts right;
        // bottom shifts left; top[m-1] drops into bottom[m-1].
        let incoming_top = self.bottom[0];
        let outgoing_top = self.top[m - 1];
        // Reverse-order in-place shifts (no K-length temporaries).
        for i in (2..m).rev() {
            self.top[i] = self.top[i - 1];
        }
        self.top[1] = incoming_top;
        for i in 0..m - 1 {
            self.bottom[i] = self.bottom[i + 1];
        }
        self.bottom[m - 1] = outgoing_top;
    }
}

/// Diagonalize a symmetric `K x K` matrix on the systolic model.
///
/// Returns `(diagonalized A, V, stats)` with `A_in = V A_diag V^T`.
/// `K` may be odd: the schedule pads with a phantom index that never
/// rotates (a "bye" in the tournament).
pub fn systolic_jacobi(
    a: &DenseMatrix,
    mode: TrigMode,
    tol: f64,
    max_sweeps: usize,
) -> (DenseMatrix, DenseMatrix, SystolicStats) {
    assert!(a.is_symmetric(1e-9), "systolic Jacobi expects symmetric input");
    let k = a.nrows;
    let mut work = a.clone();
    let mut v = DenseMatrix::identity(k);
    let mut stats = SystolicStats::default();
    if k == 1 {
        return (work, v, stats);
    }
    let padded = k + (k % 2); // phantom "bye" index when odd
    let steps_per_sweep = padded - 1;

    let mut rr = RoundRobin::new(padded);
    while work.max_offdiag() > tol && stats.sweeps < max_sweeps {
        for _ in 0..steps_per_sweep {
            // One parallel hardware step: all disjoint pairs rotate.
            for (p, q) in rr.pairs() {
                if q >= k {
                    continue; // bye
                }
                if work[(p, q)].abs() <= tol * 0.1 {
                    continue; // PE idles; no rotation issued
                }
                let (c, s) = rotation_coeffs(work[(p, p)], work[(p, q)], work[(q, q)], mode);
                apply_givens(&mut work, &mut v, p, q, c, s);
                stats.rotations += 1;
            }
            rr.advance();
            stats.steps += 1;
        }
        stats.sweeps += 1;
    }
    (work, v, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Tridiagonal;

    fn rand_tridiag(k: usize, seed: u64) -> DenseMatrix {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let alpha: Vec<f64> = (0..k).map(|_| rng.f64_range(-1.0, 1.0)).collect();
        let beta: Vec<f64> = (0..k - 1).map(|_| rng.f64_range(-1.0, 1.0)).collect();
        Tridiagonal::new(alpha, beta).to_dense()
    }

    #[test]
    fn round_robin_meets_every_pair_once() {
        for k in [4usize, 6, 8, 16] {
            let mut rr = RoundRobin::new(k);
            let mut met = std::collections::HashSet::new();
            for _ in 0..k - 1 {
                for (p, q) in rr.pairs() {
                    assert!(met.insert((p, q)), "pair ({p},{q}) met twice in k={k}");
                }
                rr.advance();
            }
            assert_eq!(met.len(), k * (k - 1) / 2, "k={k}");
        }
    }

    #[test]
    fn round_robin_pairs_are_disjoint_each_step() {
        let mut rr = RoundRobin::new(12);
        for _ in 0..11 {
            let mut used = std::collections::HashSet::new();
            for (p, q) in rr.pairs() {
                assert!(used.insert(p) && used.insert(q));
            }
            rr.advance();
        }
    }

    #[test]
    fn diagonalizes_tridiagonal_and_matches_sturm() {
        let t = Tridiagonal::new(vec![2.0, 2.0, 2.0, 2.0], vec![-1.0, -1.0, -1.0]);
        let (d, v, stats) = systolic_jacobi(&t.to_dense(), TrigMode::Exact, 1e-12, 40);
        assert!(d.max_offdiag() < 1e-10);
        assert!(stats.sweeps <= 12, "sweeps {}", stats.sweeps);
        // Every diagonal entry must be an eigenvalue per Sturm counting.
        for i in 0..4 {
            let lam = d[(i, i)];
            let below = t.eigenvalues_below(lam - 1e-9);
            let below_up = t.eigenvalues_below(lam + 1e-9);
            assert_eq!(below_up - below, 1, "lambda {lam} not in spectrum");
        }
        let rec = v.matmul(&d).matmul(&v.transpose());
        assert!(rec.max_abs_diff(&t.to_dense()) < 1e-9);
    }

    #[test]
    fn odd_k_padding_works() {
        let a = rand_tridiag(7, 3);
        let (d, v, _) = systolic_jacobi(&a, TrigMode::Exact, 1e-11, 60);
        assert!(d.max_offdiag() < 1e-9);
        let rec = v.matmul(&d).matmul(&v.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn taylor_mode_matches_exact_eigenvalues_to_hw_tolerance() {
        let a = rand_tridiag(8, 17);
        let (d_ex, _, _) = systolic_jacobi(&a, TrigMode::Exact, 1e-12, 60);
        let (d_ty, _, _) = systolic_jacobi(&a, TrigMode::Taylor3, 1e-7, 60);
        let mut ex: Vec<f64> = (0..8).map(|i| d_ex[(i, i)]).collect();
        let mut ty: Vec<f64> = (0..8).map(|i| d_ty[(i, i)]).collect();
        ex.sort_by(|x, y| x.partial_cmp(y).unwrap());
        ty.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (e, t) in ex.iter().zip(&ty) {
            assert!((e - t).abs() < 1e-5, "exact {e} vs taylor {t}");
        }
    }

    #[test]
    fn sweeps_grow_slowly_with_k() {
        // O(log K) convergence: doubling K should add O(1) sweeps.
        let mut sweeps = Vec::new();
        for k in [4usize, 8, 16, 32] {
            let a = rand_tridiag(k, 42);
            let (_, _, stats) = systolic_jacobi(&a, TrigMode::Exact, 1e-10, 100);
            sweeps.push(stats.sweeps);
        }
        // Each doubling adds at most ~4 sweeps (log-like), never doubles.
        for w in sweeps.windows(2) {
            assert!(w[1] <= w[0] + 5, "sweeps jumped {} -> {}", w[0], w[1]);
            assert!(w[1] < 2 * w[0].max(3), "super-log growth {:?}", sweeps);
        }
    }

    #[test]
    fn rotations_bounded_by_steps_times_pes() {
        let a = rand_tridiag(8, 7);
        let (_, _, stats) = systolic_jacobi(&a, TrigMode::Exact, 1e-10, 50);
        assert!(stats.rotations <= stats.steps * 4, "{stats:?}");
        assert_eq!(stats.steps, stats.sweeps * 7, "{stats:?}");
    }
}

//! Phase 2 — the Jacobi eigenvalue algorithm on the K x K tridiagonal
//! output of Lanczos (§III-B, §IV-C).
//!
//! Two interchangeable engines behind one API:
//! * [`JacobiMode::Cyclic`] — classical row-cyclic sweeps, the CPU
//!   comparator of Fig 10b;
//! * [`JacobiMode::Systolic`] — the Brent-Luk systolic-array schedule with
//!   the paper's reverse-order interchange and Taylor-series trig, i.e.
//!   the FPGA datapath (bit-for-bit the same rotation sequence the
//!   hardware would issue).

mod cyclic;
mod systolic;
pub mod trig;

pub use cyclic::{cyclic_jacobi, sweep};
pub use systolic::{systolic_jacobi, RoundRobin, SystolicStats};
pub use trig::TrigMode;

use crate::linalg::{DenseMatrix, Tridiagonal};

/// Which Jacobi engine to run.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum JacobiMode {
    /// Row-cyclic CPU sweeps with exact trig.
    Cyclic,
    /// Systolic-array schedule with hardware (Taylor) trig.
    Systolic,
}

/// Eigendecomposition of a symmetric tridiagonal `T`: eigenvalues sorted by
/// decreasing magnitude (the Top-K convention) with matching eigenvector
/// columns.
#[derive(Clone, Debug)]
pub struct JacobiEigen {
    /// Eigenvalues, `|lambda_0| >= |lambda_1| >= ...`.
    pub eigenvalues: Vec<f64>,
    /// Eigenvector matrix; column `j` pairs with `eigenvalues[j]`.
    pub eigenvectors: DenseMatrix,
    /// Systolic stats (zeroed in cyclic mode).
    pub stats: SystolicStats,
}

/// Diagonalize `T` with the chosen engine and sort eigenpairs by magnitude.
pub fn jacobi_eigen(t: &Tridiagonal, mode: JacobiMode, tol: f64) -> JacobiEigen {
    let dense = t.to_dense();
    let (d, v, stats) = match mode {
        JacobiMode::Cyclic => {
            let (d, v, sweeps) = cyclic_jacobi(&dense, TrigMode::Exact, tol, 100);
            (d, v, SystolicStats { sweeps, ..Default::default() })
        }
        JacobiMode::Systolic => systolic_jacobi(&dense, TrigMode::Taylor3, tol, 100),
    };
    let k = t.k();
    let diag: Vec<f64> = (0..k).map(|i| d[(i, i)]).collect();
    let mut idx: Vec<usize> = (0..k).collect();
    idx.sort_by(|&a, &b| diag[b].abs().partial_cmp(&diag[a].abs()).unwrap());
    let eigenvalues: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let mut eigenvectors = DenseMatrix::zeros(k, k);
    for (newj, &oldj) in idx.iter().enumerate() {
        for i in 0..k {
            eigenvectors[(i, newj)] = v[(i, oldj)];
        }
    }
    JacobiEigen { eigenvalues, eigenvectors, stats }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_t(k: usize, seed: u64) -> Tridiagonal {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        Tridiagonal::new(
            (0..k).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
            (0..k - 1).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
        )
    }

    #[test]
    fn modes_agree_on_spectrum() {
        let t = rand_t(12, 3);
        let cy = jacobi_eigen(&t, JacobiMode::Cyclic, 1e-12);
        let sy = jacobi_eigen(&t, JacobiMode::Systolic, 1e-9);
        for (a, b) in cy.eigenvalues.iter().zip(&sy.eigenvalues) {
            assert!((a - b).abs() < 1e-5, "cyclic {a} vs systolic {b}");
        }
    }

    #[test]
    fn sorted_by_magnitude_and_residuals_small() {
        let t = rand_t(10, 8);
        let e = jacobi_eigen(&t, JacobiMode::Systolic, 1e-10);
        for w in e.eigenvalues.windows(2) {
            assert!(w[0].abs() >= w[1].abs() - 1e-12);
        }
        for j in 0..10 {
            let x = e.eigenvectors.col(j);
            let tx = t.matvec(&x);
            let res: f64 = tx
                .iter()
                .zip(&x)
                .map(|(&a, &b)| (a - e.eigenvalues[j] * b).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(res < 1e-5, "residual {res} at {j}");
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let t = rand_t(8, 21);
        let e = jacobi_eigen(&t, JacobiMode::Systolic, 1e-10);
        assert!(e.eigenvectors.orthonormality_defect() < 1e-6);
    }

    #[test]
    fn k1_trivial() {
        let t = Tridiagonal::new(vec![0.37], vec![]);
        let e = jacobi_eigen(&t, JacobiMode::Systolic, 1e-12);
        assert_eq!(e.eigenvalues, vec![0.37]);
        assert_eq!(e.eigenvectors[(0, 0)], 1.0);
    }
}

//! Classical cyclic Jacobi eigenvalue algorithm — the "optimized C++ CPU
//! implementation" the paper benchmarks its systolic array against
//! (Fig 10b). Sweeps all `K(K-1)/2` pairs in row-cyclic order; each
//! rotation costs `O(K)`, so a sweep is `O(K^3)` — the quadratic-per-
//! iteration growth visible in the paper's CPU curve.

use crate::jacobi::trig::{rotation_coeffs, TrigMode};
use crate::linalg::DenseMatrix;

/// One cyclic sweep over all index pairs; returns the number of rotations
/// actually applied (tiny off-diagonals are skipped).
pub fn sweep(a: &mut DenseMatrix, v: &mut DenseMatrix, mode: TrigMode, tol: f64) -> usize {
    let n = a.nrows;
    let mut applied = 0;
    for p in 0..n {
        for q in (p + 1)..n {
            if a[(p, q)].abs() <= tol {
                continue;
            }
            let (c, s) = rotation_coeffs(a[(p, p)], a[(p, q)], a[(q, q)], mode);
            apply_givens(a, v, p, q, c, s);
            applied += 1;
        }
    }
    applied
}

/// Apply the two-sided Givens rotation J(p,q,theta) : `A <- J^T A J`,
/// `V <- V J` with `J[[p,p],[p,q],[q,p],[q,q]] = [[c,-s],[s,c]]`.
pub(crate) fn apply_givens(a: &mut DenseMatrix, v: &mut DenseMatrix, p: usize, q: usize, c: f64, s: f64) {
    let n = a.nrows;
    // Rows p and q of A (left multiply by J^T).
    for j in 0..n {
        let (apj, aqj) = (a[(p, j)], a[(q, j)]);
        a[(p, j)] = c * apj + s * aqj;
        a[(q, j)] = -s * apj + c * aqj;
    }
    // Columns p and q of A (right multiply by J).
    for i in 0..n {
        let (aip, aiq) = (a[(i, p)], a[(i, q)]);
        a[(i, p)] = c * aip + s * aiq;
        a[(i, q)] = -s * aip + c * aiq;
    }
    // Accumulate eigenvectors: V <- V J (columns rotate like A's columns).
    for i in 0..v.nrows {
        let (vip, viq) = (v[(i, p)], v[(i, q)]);
        v[(i, p)] = c * vip + s * viq;
        v[(i, q)] = -s * vip + c * viq;
    }
}

/// Diagonalize symmetric `a`: returns `(diagonalized A, V, sweeps)` where
/// `A_in = V A_diag V^T`.
pub fn cyclic_jacobi(
    a: &DenseMatrix,
    mode: TrigMode,
    tol: f64,
    max_sweeps: usize,
) -> (DenseMatrix, DenseMatrix, usize) {
    assert!(a.is_symmetric(1e-9), "cyclic Jacobi expects symmetric input");
    let mut work = a.clone();
    let mut v = DenseMatrix::identity(a.nrows);
    let mut sweeps = 0;
    while work.max_offdiag() > tol && sweeps < max_sweeps {
        sweep(&mut work, &mut v, mode, tol * 0.1);
        sweeps += 1;
    }
    (work, v, sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_sym(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.f64_range(-1.0, 1.0);
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn diagonalizes_random_symmetric() {
        let a = rand_sym(10, 5);
        let (d, v, sweeps) = cyclic_jacobi(&a, TrigMode::Exact, 1e-12, 50);
        assert!(d.max_offdiag() < 1e-10, "offdiag {}", d.max_offdiag());
        assert!(sweeps < 15, "sweeps {sweeps}");
        assert!(v.orthonormality_defect() < 1e-10);
        // Reconstruction: V D V^T == A.
        let rec = v.matmul(&d).matmul(&v.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9, "reconstruction error {}", rec.max_abs_diff(&a));
    }

    #[test]
    fn taylor_mode_converges_with_modest_accuracy() {
        let a = rand_sym(8, 9);
        let (d, v, _) = cyclic_jacobi(&a, TrigMode::Taylor3, 1e-8, 60);
        assert!(d.max_offdiag() < 1e-7);
        let rec = v.matmul(&d).matmul(&v.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-5, "reconstruction error {}", rec.max_abs_diff(&a));
    }

    #[test]
    fn eigenvalues_match_qr_reference() {
        let a = rand_sym(9, 13);
        let (d, _, _) = cyclic_jacobi(&a, TrigMode::Exact, 1e-12, 60);
        let mut jac: Vec<f64> = (0..9).map(|i| d[(i, i)]).collect();
        let (mut qr, _) = crate::linalg::qr_algorithm_symmetric(&a, 1e-12, 500);
        jac.sort_by(|x, y| x.partial_cmp(y).unwrap());
        qr.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (j, q) in jac.iter().zip(&qr) {
            assert!((j - q).abs() < 1e-7, "jacobi {j} vs qr {q}");
        }
    }

    #[test]
    fn already_diagonal_needs_zero_sweeps() {
        let mut a = DenseMatrix::zeros(5, 5);
        for i in 0..5 {
            a[(i, i)] = i as f64;
        }
        let (_, _, sweeps) = cyclic_jacobi(&a, TrigMode::Exact, 1e-12, 10);
        assert_eq!(sweeps, 0);
    }
}

//! Phase 1 — the Lanczos algorithm (§III-A, Algorithm 1).
//!
//! Reduces a symmetric sparse operator `M` (n x n) to a `K x K` symmetric
//! tridiagonal matrix `T` plus `K` orthonormal Lanczos vectors `V`, such
//! that eigenpairs of `T` lift to approximate eigenpairs of `M`
//! (`lambda(T) ≈ lambda(M)`, eigenvector `= V^T x`).
//!
//! Numerical-stability features reproduced from the paper:
//! * Paige's reordered recurrence [31]: `alpha` is computed against the
//!   *current* `w` after subtracting the `beta v_{i-1}` term.
//! * Full reorthogonalization [32] with a configurable cadence
//!   ([`ReorthPolicy`]): every iteration, every 2 iterations (the paper's
//!   recommended cheap mode), or off.
//! * Frobenius pre-normalization is expected upstream (see
//!   [`crate::sparse::normalize_frobenius`]); with entries in `(-1,1)` the
//!   mixed-precision datapath stores Lanczos vectors in the requested
//!   [`Dataword`] format exactly where the FPGA design uses fixed point.
//!
//! ## The fused single-sweep iteration
//!
//! The paper's Lanczos Core overlaps the "remaining linear operations" of
//! Figure 6(D) with the SpMV stream. The default host datapath
//! (`LanczosOptions::fused`, on unless `--no-fuse`) mirrors that: each
//! iteration is **three shard-parallel fork/joins** instead of 5 + 2K
//! serial full-length passes —
//!
//! 1. [`Operator::apply_fused`] — every CU worker writes its `y` stripe
//!    and, cache-hot, subtracts `beta v_prev`, reduces its partial
//!    `dot(w, v)`, and on reorth iterations its partial projections
//!    against **all** committed basis rows (blocked classical
//!    Gram-Schmidt phase 1); the join merges the per-shard partials.
//! 2. one chunk-parallel sweep applying the merged projections (or the
//!    single `alpha v` term) while reducing `||w||^2` (CGS phase 2).
//! 3. one chunk-parallel sweep normalizing `w` straight into the next
//!    quantized [`BasisArena`] row and its dequantized working mirror.
//!
//! The unfused path (serial passes, *modified* Gram-Schmidt) is kept as
//! the `--no-fuse` reference; `tests/fused_lanczos.rs` property-checks
//! that both produce the same tridiagonal across precisions, shard
//! counts, and reorthogonalization policies (1e-10 — bitwise on a single
//! f32 shard — where the passes are structurally identical, eps/ulp-scaled
//! where the Gram-Schmidt variants genuinely differ).
//!
//! ## Steady-state allocation freedom
//!
//! All iteration scratch (`w`, `v`, `v_prev`, per-shard reduction
//! partials, merged projections) lives in a [`LanczosWorkspace`] that is
//! reused across iterations and across solves (the coordinator keeps one
//! per [`crate::coordinator::Solver`], so `EigenService::submit_batch`
//! members share it); the basis is **one** flat allocation
//! ([`BasisArena`]). After warmup a Lanczos iteration performs zero heap
//! allocations (`tests/alloc_regression.rs` pins this).
//!
//! ## Typed basis storage
//!
//! [`lanczos_typed`] is the monomorphized kernel: the basis is a
//! [`BasisArena`] of storage words (16-bit at Q1.15 — half the f32 DDR
//! footprint), while dots, norms and axpys accumulate in float via
//! [`crate::linalg::dot_q`] / [`crate::linalg::axpy_q`], the design's
//! float units "where required to guarantee precise results" (§IV).
//! [`lanczos`] keeps the legacy f32-basis interface by dispatching
//! [`LanczosOptions::precision`] over the typed kernels
//! ([`crate::with_precision!`]) and dequantizing the result.

mod arena;
mod operator;

pub use arena::{BasisArena, BasisDots};
pub use operator::{CountingOperator, FusedBlockIteration, FusedIteration, Operator, ShardedSpmv};

use crate::fixed::{Dataword, Precision};
use crate::linalg::{self, BandTridiagonal, Tridiagonal};
use crate::util::ptr::SendPtr;

/// Reorthogonalization cadence (§III-A).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ReorthPolicy {
    /// No reorthogonalization: fastest, loses orthogonality for large K.
    None,
    /// Reorthogonalize every iteration: `O(n K^2 / 2)` extra work.
    Every,
    /// Every `N` iterations (the paper evaluates N=2: "negligible accuracy
    /// loss" at half the overhead).
    EveryN(usize),
}

impl ReorthPolicy {
    fn due(self, iter: usize) -> bool {
        match self {
            ReorthPolicy::None => false,
            ReorthPolicy::Every => true,
            ReorthPolicy::EveryN(n) => n != 0 && iter % n == 0,
        }
    }

    /// Name for reports.
    pub fn name(self) -> String {
        match self {
            ReorthPolicy::None => "none".into(),
            ReorthPolicy::Every => "every".into(),
            ReorthPolicy::EveryN(n) => format!("every-{n}"),
        }
    }
}

/// Options for one Lanczos run.
#[derive(Clone, Debug)]
pub struct LanczosOptions {
    /// Number of eigencomponents K (and Lanczos iterations).
    pub k: usize,
    /// Reorthogonalization cadence.
    pub reorth: ReorthPolicy,
    /// Storage format for the Lanczos-vector datapath ([`lanczos`]
    /// dispatches it over the monomorphized typed kernels; ignored by
    /// [`lanczos_typed`], whose type parameter is the format).
    pub precision: Precision,
    /// Use the fused single-sweep datapath (default). `false` selects the
    /// serial-pass reference implementation (`--no-fuse` at the CLI).
    pub fused: bool,
    /// Starting vector: uniform `1/n^2`-style (the paper's init) when
    /// `None`, otherwise the provided vector (will be normalized).
    pub v1: Option<Vec<f32>>,
    /// Adaptive stopping: when `max_iters > k`, the loop may run past `k`
    /// iterations (growing the basis) and stops as soon as the top-k Ritz
    /// values stabilize to [`LanczosOptions::ritz_tol`] — which is what
    /// lets a warm-started re-solve finish in measurably fewer SpMVs than
    /// a cold one. `0` (the default) reproduces the paper's fixed
    /// K-iteration schedule bit for bit.
    pub max_iters: usize,
    /// Relative stabilization tolerance on the top-k Ritz values, used
    /// only when `max_iters > k`.
    pub ritz_tol: f64,
    /// Block width `b` for the block-Lanczos engine
    /// ([`block_lanczos_typed_ws`]). The single-vector entry points
    /// ([`lanczos_typed_ws`] and friends) **ignore** this field — routing
    /// to the block engine is the caller's decision (the coordinator
    /// branches on `SolveOptions::block_size`), which is what keeps
    /// `block_size == 1` solves bitwise identical to the pre-block code.
    pub block_size: usize,
    /// Warm-start panel for the block engine: up to `block_size` starting
    /// columns of length `n` (the registry passes cached Ritz vectors).
    /// Column 0 falls back to [`LanczosOptions::v1`], remaining columns to
    /// a deterministic pseudo-random fill; the whole panel is then
    /// orthonormalized by the initial panel QR. Ignored by the
    /// single-vector entry points.
    pub panel: Option<Vec<Vec<f32>>>,
}

impl Default for LanczosOptions {
    fn default() -> Self {
        Self {
            k: 8,
            reorth: ReorthPolicy::EveryN(2),
            precision: Precision::Float32,
            fused: true,
            v1: None,
            max_iters: 0,
            ritz_tol: 1e-6,
            block_size: 1,
            panel: None,
        }
    }
}

/// Adaptive stopping rule: true once the top-`k` Ritz values of the
/// current tridiagonal have stabilized relative to the previous iteration
/// (max component change `<= tol * max(|ritz_0|, 1e-30)`). `prev` carries
/// the last snapshot between calls.
fn ritz_converged(alphas: &[f64], betas: &[f64], k: usize, tol: f64, prev: &mut Option<Vec<f64>>) -> bool {
    let t = Tridiagonal::new(alphas.to_vec(), betas.to_vec());
    let cur = t.top_k_by_magnitude(k);
    let done = match prev {
        Some(p) if p.len() == cur.len() => {
            let scale = cur[0].abs().max(1e-30);
            p.iter().zip(&cur).all(|(a, b)| (a - b).abs() <= tol * scale)
        }
        _ => false,
    };
    *prev = Some(cur);
    done
}

/// Assemble the band-tridiagonal projection from the flat per-iteration
/// coefficient logs: `a_flat` holds the symmetrized `b x b` diagonal
/// blocks `A_j` (row-major, one per block iteration), `b_flat` the
/// upper-triangular off-diagonal blocks `B_{j+1}`. The interleave gives a
/// symmetric band of width exactly `b`.
fn assemble_band(a_flat: &[f64], b_flat: &[f64], b: usize) -> BandTridiagonal {
    let blocks = a_flat.len() / (b * b);
    let dim = blocks * b;
    let mut t = BandTridiagonal::new(dim, b);
    for blk in 0..blocks {
        for r in 0..b {
            for c in r..b {
                t.set_sym(blk * b + r, blk * b + c, a_flat[blk * b * b + r * b + c]);
            }
        }
    }
    for blk in 0..b_flat.len() / (b * b) {
        // T[(blk+1)b + r][blk*b + c] = B_{blk+1}[r][c], upper triangular.
        for r in 0..b {
            for c in r..b {
                t.set_sym((blk + 1) * b + r, blk * b + c, b_flat[blk * b * b + r * b + c]);
            }
        }
    }
    t
}

/// Adaptive stopping rule for the block recurrence: the band twin of
/// [`ritz_converged`], comparing the top-`k` Ritz values of the current
/// band projection against the previous block iteration's snapshot.
fn band_ritz_converged(
    a_flat: &[f64],
    b_flat: &[f64],
    b: usize,
    k: usize,
    tol: f64,
    prev: &mut Option<Vec<f64>>,
) -> bool {
    let cur = assemble_band(a_flat, b_flat, b).top_k_by_magnitude(k);
    let done = match prev {
        Some(p) if p.len() == cur.len() => {
            let scale = cur[0].abs().max(1e-30);
            p.iter().zip(&cur).all(|(a, c)| (a - c).abs() <= tol * scale)
        }
        _ => false,
    };
    *prev = Some(cur);
    done
}

/// Preallocated scratch for the Lanczos loop, reused across iterations and
/// across solves: the working vectors (`w`, `v`, `v_prev`), the per-shard
/// reduction partials of the fused sweep, the merged projection buffer,
/// and the per-chunk norm accumulators. Buffers only grow, so after the
/// first solve of the largest shape every subsequent iteration allocates
/// nothing.
#[derive(Default)]
pub struct LanczosWorkspace {
    w: Vec<f32>,
    v: Vec<f32>,
    v_prev: Vec<f32>,
    /// Per-shard fused-sweep partials, layout `[shard][1 + basis rows]`.
    partials: Vec<f64>,
    /// Merged classical-GS projections (one per committed basis row).
    projs: Vec<f64>,
    /// Per-chunk `||w||^2` partials of the apply sweep.
    chunk_acc: Vec<f64>,
    /// Block panels (column-major `b x n`): the working panel `W`, the
    /// current panel `V_j` (dequantized mirror of the latest committed
    /// basis rows), and the previous panel `V_{j-1}`.
    wb: Vec<f32>,
    vb: Vec<f32>,
    vb_prev: Vec<f32>,
    /// Per-shard block-sweep partials, layout `[shard][b*b + rows*b]`.
    block_partials: Vec<f64>,
    /// Merged block dots `A_j` (`b x b`, row-major).
    block_a: Vec<f64>,
    /// Panel-QR coefficients `B_{j+1}` (`b x b`, row-major upper-tri).
    block_b: Vec<f64>,
    /// Merged block projections, column-grouped (`rows * b`).
    block_projs: Vec<f64>,
}

impl LanczosWorkspace {
    /// Empty workspace; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for an `n`-dimensional solve with `k` iterations
    /// on `shards` reduction lanes. Never shrinks capacity — resizing to a
    /// previously-seen shape is allocation-free.
    fn ensure(&mut self, n: usize, k: usize, shards: usize) {
        self.w.resize(n, 0.0);
        self.v.resize(n, 0.0);
        self.v_prev.resize(n, 0.0);
        self.partials.resize(shards * (1 + k), 0.0);
        self.projs.resize(k, 0.0);
        self.chunk_acc.resize(shards, 0.0);
    }

    /// Size the block-engine buffers for an `n`-dimensional solve producing
    /// up to `rows` basis rows with block width `b` on `shards` reduction
    /// lanes. Same growth-only discipline as [`LanczosWorkspace::ensure`].
    fn ensure_block(&mut self, n: usize, rows: usize, b: usize, shards: usize) {
        self.wb.resize(b * n, 0.0);
        self.vb.resize(b * n, 0.0);
        self.vb_prev.resize(b * n, 0.0);
        self.block_partials.resize(shards * (b * b + rows * b), 0.0);
        self.block_a.resize(b * b, 0.0);
        self.block_b.resize(b * b, 0.0);
        self.block_projs.resize(rows * b, 0.0);
    }
}

/// Lanczos output: `T`, the Lanczos basis in storage format `V`, and
/// diagnostics.
#[derive(Clone, Debug)]
pub struct LanczosResult<V: Dataword = f32> {
    /// The K x K symmetric tridiagonal projection.
    pub tridiag: Tridiagonal,
    /// Lanczos vectors, `k` rows each of length `n` (the paper's `V`,
    /// streamed to DDR on the device), stored as `V` words in one flat
    /// row-strided allocation.
    pub basis: BasisArena<V>,
    /// Iteration at which the recurrence broke down (`beta -> 0`), if any.
    /// A breakdown at iteration `i` truncates the output to `i` components
    /// — mathematically it means an exact invariant subspace was found.
    pub breakdown_at: Option<usize>,
    /// Number of SpMV applications performed (vectors multiplied).
    pub spmv_count: usize,
    /// Full walks of the matrix stream. The single-vector recurrence
    /// multiplies one vector per walk, so this always equals
    /// [`LanczosResult::spmv_count`] here; the block engine
    /// ([`BlockLanczosResult::matrix_passes`]) multiplies `b` vectors per
    /// walk, which is the quantity HBM bytes are charged against.
    pub matrix_passes: usize,
    /// Fused fork/join sweeps executed ([`Operator::apply_fused`] calls;
    /// 0 on the unfused path).
    pub fused_sweeps: usize,
    /// Full-length vector passes the iteration phase performed (each
    /// fork/join sweep counts once; on the unfused path every serial
    /// axpy/dot/norm/normalize pass counts once and each reorth row costs
    /// two). The fused path does 3 per full iteration.
    pub vector_passes: usize,
}

impl<V: Dataword> LanczosResult<V> {
    /// Effective number of components produced.
    pub fn k(&self) -> usize {
        self.tridiag.k()
    }

    /// Bytes the stored basis occupies (`k * n * V::bytes()`): halved at
    /// Q1.15 relative to f32 — the DDR-side win of the typed datapath.
    pub fn basis_value_bytes(&self) -> usize {
        self.basis.value_bytes()
    }

    /// Stored bits per basis word.
    pub fn basis_bits(&self) -> u32 {
        V::BITS
    }

    /// Row `i` of the basis dequantized to f32 (verification paths).
    pub fn basis_row_f32(&self, i: usize) -> Vec<f32> {
        self.basis.row_f32(i)
    }
}

/// Contiguous chunk `c` of `0..n` split into `chunks` near-equal ranges.
fn chunk_range(n: usize, chunks: usize, c: usize) -> (usize, usize) {
    let base = n / chunks;
    let rem = n % chunks;
    let start = c * base + c.min(rem);
    (start, start + base + usize::from(c < rem))
}

/// Run Algorithm 1 against an [`Operator`], storing the basis in format
/// `V`, with caller-provided scratch. This is the steady-state entry
/// point: the coordinator keeps one [`LanczosWorkspace`] per solver and
/// reuses it across solves, making warm iterations allocation-free.
///
/// Breakdown (`beta_i ≈ 0`) truncates the decomposition early rather than
/// erroring: the subspace found so far is exactly invariant, which is a
/// *better* answer, not a failure.
pub fn lanczos_typed_ws<V: Dataword, O: Operator + ?Sized>(
    op: &O,
    opts: &LanczosOptions,
    ws: &mut LanczosWorkspace,
) -> LanczosResult<V> {
    let n = op.n();
    let k = opts.k;
    assert!(k >= 1, "k must be >= 1");
    assert!(k <= n, "k = {k} exceeds matrix dimension {n}");
    // Adaptive mode iterates past k (up to m_max) until the top-k Ritz
    // values stabilize; m_max == k is the paper's fixed schedule and
    // leaves every code path bit-identical to the non-adaptive build.
    let m_max = if opts.max_iters > k { opts.max_iters.min(n) } else { k };
    let adaptive = m_max > k;
    let mut ritz_prev: Option<Vec<f64>> = None;

    let shards = op.fused_shards().max(1);
    ws.ensure(n, m_max, shards);

    // v1: the paper initializes with constant 1/n^2 values then L2-
    // normalizes — i.e. the normalized uniform vector.
    match &opts.v1 {
        Some(v1) => {
            assert_eq!(v1.len(), n, "v1 length mismatch");
            ws.v.copy_from_slice(v1);
        }
        None => ws.v.fill(1.0),
    }
    if linalg::normalize(&mut ws.v) == 0.0 {
        panic!("starting vector must be non-zero");
    }

    // One flat allocation for the whole basis; row 0 holds the quantized
    // start vector, and the working copy mirrors the stored (rounded)
    // values so the recurrence and the basis agree bit-for-bit.
    let mut basis = BasisArena::<V>::with_capacity(m_max, n);
    {
        let row = basis.alloc_row();
        for (vi, q) in ws.v.iter_mut().zip(row.iter_mut()) {
            *q = V::from_f32(*vi);
            *vi = q.to_f32();
        }
    }

    let mut alphas: Vec<f64> = Vec::with_capacity(m_max);
    let mut betas: Vec<f64> = Vec::with_capacity(m_max.saturating_sub(1));
    let mut breakdown_at = None;
    let mut spmv_count = 0usize;
    let mut fused_sweeps = 0usize;
    let mut vector_passes = 0usize;

    // Breakdown tolerance scaled to the arithmetic in use: fixed-point
    // vectors cannot meaningfully normalize below ~sqrt(n)*ulp.
    let bd_tol = if V::IS_FIXED { 1e-9 } else { 1e-12 };

    let LanczosWorkspace { w, v, v_prev, partials, projs, chunk_acc, .. } = ws;
    let mut beta_prev = 0.0f64;

    if opts.fused {
        for i in 0..m_max {
            let reorth_due = i + 1 < m_max && opts.reorth.due(i + 1);
            let nproj = if reorth_due { basis.len() } else { 0 };

            // Sweep 1 (fork/join #1): y = M v, minus beta v_prev (Paige),
            // partial dot(w, v) and partial basis projections per shard.
            let alpha = {
                let mut it = FusedIteration {
                    beta_prev: beta_prev as f32,
                    v_prev,
                    basis: if reorth_due { Some(&basis) } else { None },
                    partials: &mut partials[..shards * (1 + nproj)],
                    projs: &mut projs[..nproj],
                };
                op.apply_fused(v, w, &mut it)
            };
            spmv_count += 1;
            fused_sweeps += 1;
            vector_passes += 1;
            alphas.push(alpha);
            // Stop at the iteration cap, or (adaptive mode) once the top-k
            // Ritz values of T_{i+1} have stabilized. Both breaks leave the
            // shape invariant intact: i+1 alphas, i betas, i+1 basis rows.
            if i + 1 == m_max
                || (adaptive && i + 1 >= k && ritz_converged(&alphas, &betas, k, opts.ritz_tol, &mut ritz_prev))
            {
                break;
            }

            // Sweep 2 (fork/join #2): subtract the merged projections
            // (classical-GS apply; projection i carries the alpha v term)
            // or just alpha v, and reduce ||w||^2 per chunk.
            {
                let w_ptr = SendPtr(w.as_mut_ptr());
                let acc_ptr = SendPtr(chunk_acc.as_mut_ptr());
                let v_ro: &[f32] = v;
                let projs_ro: &[f64] = &projs[..nproj];
                let basis_ro = &basis;
                let alpha32 = alpha as f32;
                op.parallel_for(shards, &|c| {
                    let (r0, r1) = chunk_range(n, shards, c);
                    // SAFETY: chunks tile [0, n) disjointly (each task gets
                    // only its own slice) and the fork/join returns before
                    // `w`/`chunk_acc` move.
                    let w_chunk = unsafe { w_ptr.slice_mut(r0, r1 - r0) };
                    let sq = if reorth_due {
                        basis_ro.apply_projections_norm2(projs_ro, w_chunk, r0, r1)
                    } else {
                        linalg::axpy_norm2(-alpha32, &v_ro[r0..r1], w_chunk)
                    };
                    // SAFETY: accumulator slot `c` is written by exactly
                    // this task; `chunk_acc` outlives the join.
                    unsafe { acc_ptr.set(c, sq) };
                });
            }
            vector_passes += 1;
            let beta = chunk_acc[..shards].iter().sum::<f64>().sqrt();
            if beta < bd_tol {
                breakdown_at = Some(i + 1);
                break;
            }

            // Sweep 3 (fork/join #3): normalize w straight into the next
            // quantized basis row and the dequantized working copy.
            std::mem::swap(v, v_prev);
            let inv = (1.0 / beta) as f32;
            {
                let row = basis.alloc_row();
                let row_ptr = SendPtr(row.as_mut_ptr());
                let v_ptr = SendPtr(v.as_mut_ptr());
                let w_ro: &[f32] = w;
                op.parallel_for(shards, &|c| {
                    let (r0, r1) = chunk_range(n, shards, c);
                    // SAFETY: disjoint chunks of the fresh basis row; join
                    // precedes scope exit.
                    let row_chunk = unsafe { row_ptr.slice_mut(r0, r1 - r0) };
                    // SAFETY: disjoint chunks of `v`; join precedes scope
                    // exit.
                    let v_chunk = unsafe { v_ptr.slice_mut(r0, r1 - r0) };
                    linalg::scale_quantize_into(inv, &w_ro[r0..r1], v_chunk, row_chunk);
                });
            }
            vector_passes += 1;
            beta_prev = beta;
            betas.push(beta);
        }
    } else {
        // The unfused reference (--no-fuse): the paper's Algorithm 1 as
        // serial full-length passes with *modified* Gram-Schmidt reorth.
        for i in 0..m_max {
            // w = M v  (Algorithm 1 line 7; the memory-bound phase).
            op.apply(v, w);
            spmv_count += 1;

            // Paige variant [31]: subtract beta*v_{i-1} *before* alpha.
            if i > 0 {
                linalg::axpy(-(beta_prev as f32), v_prev, w);
                vector_passes += 1;
            }
            let alpha = linalg::dot(w, v);
            vector_passes += 1;
            alphas.push(alpha);
            linalg::axpy(-(alpha as f32), v, w);
            vector_passes += 1;

            if i + 1 == m_max
                || (adaptive && i + 1 >= k && ritz_converged(&alphas, &betas, k, opts.ritz_tol, &mut ritz_prev))
            {
                break;
            }

            // Reorthogonalization (line 10): modified Gram-Schmidt against
            // the whole stored basis, on the paper's cadence. Dots and
            // axpys dequantize the stored words on the fly, accumulating
            // in float.
            if opts.reorth.due(i + 1) {
                for b in basis.rows_iter() {
                    let proj = linalg::dot_q(w, b);
                    linalg::axpy_q(-(proj as f32), b, w);
                    vector_passes += 2;
                }
            }

            let beta = linalg::norm2(w);
            vector_passes += 1;
            if beta < bd_tol {
                breakdown_at = Some(i + 1);
                break;
            }

            std::mem::swap(v, v_prev);
            let inv = (1.0 / beta) as f32;
            let row = basis.alloc_row();
            linalg::scale_quantize_into(inv, w, v, row);
            vector_passes += 1;
            beta_prev = beta;
            betas.push(beta);
        }
    }

    LanczosResult {
        tridiag: Tridiagonal::new(alphas, betas),
        basis,
        breakdown_at,
        spmv_count,
        matrix_passes: spmv_count,
        fused_sweeps,
        vector_passes,
    }
}

/// Run Algorithm 1 against an [`Operator`], storing the basis in format
/// `V`, with a fresh workspace. This is the monomorphized kernel behind
/// [`lanczos`]; warm paths that solve repeatedly should hold a
/// [`LanczosWorkspace`] and call [`lanczos_typed_ws`] instead (the
/// coordinator does).
pub fn lanczos_typed<V: Dataword, O: Operator + ?Sized>(op: &O, opts: &LanczosOptions) -> LanczosResult<V> {
    let mut ws = LanczosWorkspace::new();
    lanczos_typed_ws(op, opts, &mut ws)
}

/// Run Algorithm 1 against an [`Operator`] with runtime-selected storage:
/// dispatches [`LanczosOptions::precision`] over the monomorphized
/// [`lanczos_typed`] kernels and returns the basis dequantized to f32 (the
/// values are identical to the stored words — only the container widens).
/// Callers that want the basis to *stay* in storage format use
/// [`lanczos_typed`] directly, as the coordinator does.
pub fn lanczos<O: Operator + ?Sized>(op: &O, opts: &LanczosOptions) -> LanczosResult {
    crate::with_precision!(opts.precision, V => {
        let r: LanczosResult<V> = lanczos_typed(op, opts);
        let mut basis = BasisArena::<f32>::with_capacity(r.basis.len(), r.basis.n());
        for i in 0..r.basis.len() {
            let row = basis.alloc_row();
            for (d, s) in row.iter_mut().zip(r.basis.row(i)) {
                *d = s.to_f32();
            }
        }
        LanczosResult {
            tridiag: r.tridiag,
            basis,
            breakdown_at: r.breakdown_at,
            spmv_count: r.spmv_count,
            matrix_passes: r.matrix_passes,
            fused_sweeps: r.fused_sweeps,
            vector_passes: r.vector_passes,
        }
    })
}

/// Block Lanczos output: the band-tridiagonal projection `T`, the block
/// basis (panels committed row-by-row into the same flat [`BasisArena`]
/// layout the single-vector engine uses), and diagnostics.
#[derive(Clone, Debug)]
pub struct BlockLanczosResult<V: Dataword = f32> {
    /// The `m x m` symmetric band projection (bandwidth = block size).
    pub band: BandTridiagonal,
    /// Block Lanczos basis: `m` rows of length `n` (panel `j` occupies
    /// rows `j*b .. (j+1)*b`), stored as `V` words in one flat allocation.
    pub basis: BasisArena<V>,
    /// Block width `b` the recurrence ran with.
    pub block_size: usize,
    /// Basis row count at which the panel QR detected rank collapse, if
    /// any — the block analog of `beta -> 0`: the Krylov space hit an
    /// invariant subspace and the output is truncated to the committed
    /// panels (a *better* answer, not a failure).
    pub breakdown_at: Option<usize>,
    /// Vectors multiplied (`matrix_passes * b`).
    pub spmv_count: usize,
    /// Full walks of the matrix stream — **one per block iteration**, the
    /// quantity HBM bytes are charged against. The whole point of the
    /// block engine: `b` vectors advance per walk.
    pub matrix_passes: usize,
    /// Fused block fork/join sweeps ([`Operator::apply_fused_block`] calls).
    pub fused_sweeps: usize,
    /// Full-length vector passes outside the fused sweep (projection-apply
    /// rounds and panel commits).
    pub vector_passes: usize,
}

impl<V: Dataword> BlockLanczosResult<V> {
    /// Effective number of basis rows / band dimension produced.
    pub fn k(&self) -> usize {
        self.band.dim()
    }

    /// Bytes the stored basis occupies.
    pub fn basis_value_bytes(&self) -> usize {
        self.basis.value_bytes()
    }
}

/// Run the **block** Lanczos recurrence against an [`Operator`] with block
/// width `opts.block_size`, storing the basis in format `V`, with
/// caller-provided scratch.
///
/// Per block iteration `j` (Paige-reordered, the block twin of the fused
/// single-vector datapath):
///
/// 1. [`Operator::apply_fused_block`] — **one walk of the matrix** computes
///    `W = M V_j` for all `b` columns, subtracts `V_{j-1} B_j^T` while each
///    stripe chunk is cache-hot, and reduces the block dots
///    `A_j = V_j^T W` plus (on reorth iterations) the projections of every
///    column onto every committed basis row.
/// 2. one chunk-parallel sweep subtracting the merged projections
///    (classical GS; the rows of the current panel carry the `V_j A_j`
///    term) or just `V_j A_j`.
/// 3. a small panel QR ([`crate::linalg::panel_qr_mgs`], O(b^2 n) — noise
///    next to the SpMV) orthonormalizes `W` into `V_{j+1}` and yields the
///    upper-triangular `B_{j+1}`; the panel is committed column-by-column
///    into the quantized basis with its dequantized working mirror.
///
/// The `A_j`/`B_{j+1}` coefficients interleave into a symmetric **band**
/// matrix of bandwidth `b` ([`BandTridiagonal`]); its top-K Ritz pairs lift
/// through the basis exactly as in the single-vector path. A rank-deficient
/// panel truncates the decomposition (block breakdown). Adaptive stopping
/// (`max_iters > k`) checks top-K Ritz stabilization once at least `k`
/// basis rows exist, so a well-seeded panel (registry warm start) finishes
/// in fewer matrix passes.
pub fn block_lanczos_typed_ws<V: Dataword, O: Operator + ?Sized>(
    op: &O,
    opts: &LanczosOptions,
    ws: &mut LanczosWorkspace,
) -> BlockLanczosResult<V> {
    let n = op.n();
    let k = opts.k;
    let b = opts.block_size.max(1);
    assert!(k >= 1, "k must be >= 1");
    assert!(k <= n, "k = {k} exceeds matrix dimension {n}");
    let j_fixed = k.div_ceil(b);
    assert!(j_fixed * b <= n, "block_size {b} x ceil(k/b) {j_fixed} exceeds matrix dimension {n}");
    // Adaptive mode: max_iters counts *vectors* (as on the single path),
    // rounded up to whole panels and capped so the basis fits in n rows.
    let j_max = if opts.max_iters > k { opts.max_iters.div_ceil(b).min(n / b).max(j_fixed) } else { j_fixed };
    let adaptive = j_max > j_fixed;
    let mut ritz_prev: Option<Vec<f64>> = None;

    let shards = op.fused_shards().max(1);
    let rows_cap = j_max * b;
    ws.ensure_block(n, rows_cap, b, shards);
    let LanczosWorkspace { wb, vb, vb_prev, block_partials, block_a, block_b, block_projs, .. } = ws;
    let wb: &mut [f32] = &mut wb[..b * n];
    let mut vb: &mut [f32] = &mut vb[..b * n];
    let mut vb_prev: &mut [f32] = &mut vb_prev[..b * n];

    // Initial panel: warm columns from `opts.panel` (cached Ritz vectors),
    // column 0 falling back to `v1` / the paper's uniform init, the rest to
    // a deterministic pseudo-random fill; then orthonormalize (the initial
    // panel QR coefficient is discarded — only the subspace matters).
    let seeded = opts.panel.as_ref().map_or(0, |p| p.len().min(b));
    for c in 0..b {
        let col = &mut wb[c * n..(c + 1) * n];
        if c < seeded {
            let src = &opts.panel.as_ref().unwrap()[c];
            assert_eq!(src.len(), n, "panel column length mismatch");
            col.copy_from_slice(src);
        } else if c == 0 {
            match &opts.v1 {
                Some(v1) => {
                    assert_eq!(v1.len(), n, "v1 length mismatch");
                    col.copy_from_slice(v1);
                }
                None => col.fill(1.0),
            }
        } else {
            let mut rng = crate::util::rng::Pcg64::new(0x5eed_b10c ^ c as u64);
            for x in col.iter_mut() {
                *x = rng.f64_range(-1.0, 1.0) as f32;
            }
        }
    }
    let init_rank = linalg::panel_qr_mgs(wb, n, b, block_b, 1e-12);
    assert_eq!(init_rank, b, "initial block panel is rank deficient ({init_rank} of {b} columns)");

    // Commit the start panel: quantized basis rows + dequantized mirrors,
    // so the recurrence and the stored basis agree bit for bit.
    let mut basis = BasisArena::<V>::with_capacity(rows_cap, n);
    for c in 0..b {
        let row = basis.alloc_row();
        linalg::scale_quantize_into(1.0, &wb[c * n..(c + 1) * n], &mut vb[c * n..(c + 1) * n], row);
    }

    // Flat coefficient logs: one symmetrized b*b A-block per iteration,
    // one upper-triangular b*b B-block per completed panel QR.
    let mut a_flat: Vec<f64> = Vec::with_capacity(j_max * b * b);
    let mut b_flat: Vec<f64> = Vec::with_capacity(j_max.saturating_sub(1) * b * b);
    let mut breakdown_at = None;
    let mut matrix_passes = 0usize;
    let mut fused_sweeps = 0usize;
    let mut vector_passes = 0usize;
    let bd_tol = if V::IS_FIXED { 1e-9 } else { 1e-12 };

    for j in 0..j_max {
        let reorth_due = j + 1 < j_max && opts.reorth.due(j + 1);
        let nproj = if reorth_due { basis.len() } else { 0 };

        // Sweep 1: the once-per-iteration matrix walk.
        {
            let mut it = FusedBlockIteration {
                b,
                v_prev: if j == 0 { &[] } else { &*vb_prev },
                b_prev: &block_b[..b * b],
                basis: if reorth_due { Some(&basis) } else { None },
                partials: &mut block_partials[..shards * (b * b + nproj * b)],
                a_out: &mut block_a[..b * b],
                projs: &mut block_projs[..nproj * b],
            };
            op.apply_fused_block(vb, wb, &mut it);
        }
        matrix_passes += 1;
        fused_sweeps += 1;
        vector_passes += 1;
        // Symmetrize A_j (equal up to f32 rounding by construction) so the
        // recurrence and the reported T use the same coefficients.
        for r in 0..b {
            for c in r + 1..b {
                let m = 0.5 * (block_a[r * b + c] + block_a[c * b + r]);
                block_a[r * b + c] = m;
                block_a[c * b + r] = m;
            }
        }
        a_flat.extend_from_slice(&block_a[..b * b]);

        // Stop at the iteration cap, or (adaptive) once the top-k Ritz
        // values of the band have stabilized. Both breaks leave the shape
        // invariant intact: j+1 A-blocks, j B-blocks, (j+1)*b basis rows.
        if j + 1 == j_max
            || (adaptive
                && (j + 1) * b >= k
                && band_ritz_converged(&a_flat, &b_flat, b, k, opts.ritz_tol, &mut ritz_prev))
        {
            break;
        }

        // Sweep 2: apply the merged projections (CGS; the current panel's
        // rows carry the V_j A_j term) or just V_j A_j, chunk-parallel.
        {
            let wb_ptr = SendPtr(wb.as_mut_ptr());
            let vb_ro: &[f32] = vb;
            let a_ro: &[f64] = &block_a[..b * b];
            let projs_ro: &[f64] = &block_projs[..nproj * b];
            let basis_ro = &basis;
            op.parallel_for(shards, &|ch| {
                let (r0, r1) = chunk_range(n, shards, ch);
                for c in 0..b {
                    // SAFETY: chunks tile [0, n) disjointly per column and
                    // the fork/join returns before `wb` moves.
                    let w_chunk = unsafe { wb_ptr.slice_mut(c * n + r0, r1 - r0) };
                    if reorth_due {
                        basis_ro.apply_projections_norm2(
                            &projs_ro[c * nproj..(c + 1) * nproj],
                            w_chunk,
                            r0,
                            r1,
                        );
                    } else {
                        for r in 0..b {
                            linalg::axpy(-(a_ro[r * b + c] as f32), &vb_ro[r * n + r0..r * n + r1], w_chunk);
                        }
                    }
                }
            });
        }
        vector_passes += 1;

        // Sweep 3: panel QR — rank collapse is the block breakdown; a full
        // rank panel yields B_{j+1} and the next panel's orthonormal
        // columns in place.
        let rank = linalg::panel_qr_mgs(wb, n, b, block_b, bd_tol);
        if rank < b {
            breakdown_at = Some(basis.len());
            break;
        }
        b_flat.extend_from_slice(&block_b[..b * b]);

        // Commit V_{j+1}: quantized rows + dequantized mirrors.
        std::mem::swap(&mut vb, &mut vb_prev);
        for c in 0..b {
            let row = basis.alloc_row();
            let row_ptr = SendPtr(row.as_mut_ptr());
            let v_ptr = SendPtr(vb[c * n..(c + 1) * n].as_mut_ptr());
            let w_ro: &[f32] = &wb[c * n..(c + 1) * n];
            op.parallel_for(shards, &|ch| {
                let (r0, r1) = chunk_range(n, shards, ch);
                // SAFETY: disjoint chunks of the fresh basis row; join
                // precedes scope exit.
                let row_chunk = unsafe { row_ptr.slice_mut(r0, r1 - r0) };
                // SAFETY: disjoint chunks of panel column `c`; join
                // precedes scope exit.
                let v_chunk = unsafe { v_ptr.slice_mut(r0, r1 - r0) };
                linalg::scale_quantize_into(1.0, &w_ro[r0..r1], v_chunk, row_chunk);
            });
            vector_passes += 1;
        }
    }

    BlockLanczosResult {
        band: assemble_band(&a_flat, &b_flat, b),
        basis,
        block_size: b,
        breakdown_at,
        spmv_count: matrix_passes * b,
        matrix_passes,
        fused_sweeps,
        vector_passes,
    }
}

/// [`block_lanczos_typed_ws`] with a fresh workspace (tests/one-shot
/// callers; warm paths hold a [`LanczosWorkspace`], as the coordinator
/// does).
pub fn block_lanczos_typed<V: Dataword, O: Operator + ?Sized>(
    op: &O,
    opts: &LanczosOptions,
) -> BlockLanczosResult<V> {
    let mut ws = LanczosWorkspace::new();
    block_lanczos_typed_ws(op, opts, &mut ws)
}

/// Lift an eigenvector `x` of `T` back to an (approximate) eigenvector of
/// `M` through a typed basis: `q = sum_i x_i v_i`, normalized. The stored
/// words dequantize at the multiplier input; accumulation is f32. The
/// arena's flat layout makes this one linear sweep over the basis.
pub fn lift_eigenvector_typed<V: Dataword>(basis: &BasisArena<V>, x: &[f64]) -> Vec<f32> {
    assert_eq!(basis.len(), x.len(), "basis/eigvec size mismatch");
    let mut q = vec![0.0f32; basis.n()];
    for (xi, vi) in x.iter().zip(basis.rows_iter()) {
        linalg::axpy_q(*xi as f32, vi, &mut q);
    }
    linalg::normalize(&mut q);
    q
}

/// Lift an eigenvector `x` of `T` back to an (approximate) eigenvector of
/// `M`: `q = sum_i x_i v_i`, normalized (f32-basis convenience wrapper of
/// [`lift_eigenvector_typed`]).
pub fn lift_eigenvector(basis: &BasisArena<f32>, x: &[f64]) -> Vec<f32> {
    lift_eigenvector_typed::<f32>(basis, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q1_15, Q1_31};
    use crate::sparse::CooMatrix;

    /// Diagonal test matrix: eigenvalues are exactly the diagonal.
    fn diag(vals: &[f32]) -> crate::sparse::CsrMatrix {
        let n = vals.len();
        let mut m = CooMatrix::new(n, n);
        for (i, &v) in vals.iter().enumerate() {
            m.push(i, i, v);
        }
        m.to_csr()
    }

    /// 1-D Laplacian path graph: known spectrum 2 - 2cos(pi j / (n+1)).
    fn path_laplacian(n: usize) -> crate::sparse::CsrMatrix {
        let mut m = CooMatrix::new(n, n);
        for i in 0..n {
            m.push(i, i, 2.0);
            if i + 1 < n {
                m.push(i, i + 1, -1.0);
                m.push(i + 1, i, -1.0);
            }
        }
        m.to_csr()
    }

    #[test]
    fn tridiagonal_matches_operator_on_invariant_subspace() {
        // With k == n and full reorth, T is orthogonally similar to M:
        // same spectrum (checked through Sturm counts).
        let m = path_laplacian(12);
        let res = lanczos(
            &m,
            &LanczosOptions {
                k: 12,
                reorth: ReorthPolicy::Every,
                v1: Some((0..12).map(|i| 1.0 + (i as f32) * 0.1).collect()),
                ..Default::default()
            },
        );
        assert!(res.breakdown_at.is_none());
        for j in 1..=12 {
            let lam = 2.0 - 2.0 * (std::f64::consts::PI * j as f64 / 13.0).cos();
            // count eigenvalues below lam + eps must equal j
            assert_eq!(res.tridiag.eigenvalues_below(lam + 1e-5), j, "j={j}");
        }
    }

    #[test]
    fn basis_is_orthonormal_with_reorth() {
        let m = path_laplacian(64);
        let res = lanczos(&m, &LanczosOptions { k: 16, reorth: ReorthPolicy::Every, ..Default::default() });
        for i in 0..res.basis.len() {
            assert!((linalg::norm2(&res.basis[i]) - 1.0).abs() < 1e-5, "row {i} not unit");
            for j in 0..i {
                let d = linalg::dot(&res.basis[i], &res.basis[j]).abs();
                assert!(d < 1e-4, "rows {i},{j} dot {d}");
            }
        }
    }

    #[test]
    fn breakdown_on_low_rank_operator() {
        // Identity has one distinct eigenvalue: breakdown at iteration 1.
        let m = diag(&[1.0; 16]);
        let res = lanczos(&m, &LanczosOptions { k: 8, ..Default::default() });
        assert_eq!(res.breakdown_at, Some(1));
        assert_eq!(res.k(), 1);
        assert!((res.tridiag.alpha[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn spmv_count_is_k() {
        let m = path_laplacian(32);
        let c = CountingOperator::new(m);
        let res = lanczos(&c, &LanczosOptions { k: 10, ..Default::default() });
        assert_eq!(res.spmv_count, 10);
        assert_eq!(c.count(), 10);
        // The fused datapath runs one fused sweep per SpMV.
        assert_eq!(res.fused_sweeps, 10);
        assert!(res.vector_passes > 0);
    }

    #[test]
    fn unfused_path_reports_zero_fused_sweeps() {
        let m = path_laplacian(32);
        let res = lanczos(&m, &LanczosOptions { k: 6, fused: false, ..Default::default() });
        assert_eq!(res.fused_sweeps, 0);
        assert!(res.vector_passes > 0);
        assert_eq!(res.spmv_count, 6);
    }

    #[test]
    fn fused_matches_unfused_on_reference_problem() {
        // Unnormalized operator (||M|| ~ 4): scale the agreement bound
        // accordingly. No-reorth iterations are structurally identical
        // (f64-merge noise only); reorth iterations differ by the CGS/MGS
        // variant at the eps_f32 level — measured drift on this problem is
        // ~6e-7 (see tests/fused_lanczos.rs for the calibrated model).
        let m = path_laplacian(96);
        for reorth in [ReorthPolicy::None, ReorthPolicy::Every, ReorthPolicy::EveryN(2)] {
            let tol = if reorth == ReorthPolicy::None { 1e-10 } else { 1e-5 };
            let fused = lanczos(&m, &LanczosOptions { k: 10, reorth, ..Default::default() });
            let plain = lanczos(&m, &LanczosOptions { k: 10, reorth, fused: false, ..Default::default() });
            assert_eq!(fused.breakdown_at, plain.breakdown_at);
            for i in 0..10 {
                assert!(
                    (fused.tridiag.alpha[i] - plain.tridiag.alpha[i]).abs() < tol,
                    "{reorth:?} alpha[{i}]: {} vs {}",
                    fused.tridiag.alpha[i],
                    plain.tridiag.alpha[i]
                );
            }
            for i in 0..9 {
                assert!((fused.tridiag.beta[i] - plain.tridiag.beta[i]).abs() < tol, "{reorth:?} beta[{i}]");
            }
        }
    }

    #[test]
    fn workspace_reuse_matches_fresh_runs() {
        let m = path_laplacian(64);
        let mut ws = LanczosWorkspace::new();
        // Big solve first so the small one reuses oversized buffers.
        let _warm: LanczosResult = lanczos_typed_ws(&m, &LanczosOptions { k: 12, ..Default::default() }, &mut ws);
        for k in [4usize, 9, 12] {
            let opts = LanczosOptions { k, ..Default::default() };
            let reused: LanczosResult = lanczos_typed_ws(&m, &opts, &mut ws);
            let fresh: LanczosResult = lanczos_typed(&m, &opts);
            assert_eq!(reused.tridiag.alpha, fresh.tridiag.alpha, "k={k}");
            assert_eq!(reused.tridiag.beta, fresh.tridiag.beta, "k={k}");
            for i in 0..reused.basis.len() {
                assert_eq!(&reused.basis[i], &fresh.basis[i], "k={k} row {i}");
            }
        }
    }

    #[test]
    fn adaptive_mode_stops_early_when_seeded_near_the_answer() {
        // Diagonal with a clear gap: the dominant eigenvector is e_0.
        let mut vals = vec![0.05f32; 256];
        vals[0] = 0.9;
        vals[1] = 0.4;
        let m = diag(&vals);
        let opts_cold = LanczosOptions {
            k: 1,
            max_iters: 24,
            ritz_tol: 1e-9,
            v1: Some((0..256).map(|i| 1.0 + (i as f32) * 1e-3).collect()),
            ..Default::default()
        };
        let cold = lanczos(&m, &opts_cold);
        // Warm: start almost exactly on the dominant eigenvector.
        let mut v1 = vec![1e-4f32; 256];
        v1[0] = 1.0;
        let warm = lanczos(&m, &LanczosOptions { v1: Some(v1), ..opts_cold.clone() });
        assert!(
            warm.spmv_count >= 1 && cold.spmv_count > warm.spmv_count,
            "warm {} vs cold {}",
            warm.spmv_count,
            cold.spmv_count
        );
        // Both converge to the same dominant Ritz value.
        let lw = warm.tridiag.top_k_by_magnitude(1)[0];
        let lc = cold.tridiag.top_k_by_magnitude(1)[0];
        assert!((lw - 0.9).abs() < 1e-4, "warm lambda {lw}");
        assert!((lc - 0.9).abs() < 1e-4, "cold lambda {lc}");
        // The fixed schedule is untouched: max_iters == 0 runs exactly k.
        let fixed = lanczos(&m, &LanczosOptions { k: 4, ..Default::default() });
        assert_eq!(fixed.spmv_count, 4);
        // Shape invariant holds after an early adaptive stop.
        assert_eq!(warm.tridiag.k(), warm.basis.len());
        assert_eq!(warm.tridiag.beta.len() + 1, warm.tridiag.alpha.len());
    }

    #[test]
    fn custom_start_vector_is_used_and_normalized() {
        let m = diag(&[0.9, 0.1, 0.1, 0.1]);
        // Start exactly on the dominant eigenvector: alpha_1 = 0.9.
        let res = lanczos(
            &m,
            &LanczosOptions { k: 1, v1: Some(vec![10.0, 0.0, 0.0, 0.0]), ..Default::default() },
        );
        assert!((res.tridiag.alpha[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn lift_recovers_diagonal_eigenvector() {
        let m = diag(&[0.9, -0.5, 0.3, 0.1, 0.05, 0.01]);
        let res = lanczos(
            &m,
            &LanczosOptions {
                k: 6,
                reorth: ReorthPolicy::Every,
                v1: Some(vec![1.0, 0.9, 0.8, 0.7, 0.6, 0.5]),
                ..Default::default()
            },
        );
        // Solve T with the QR reference and lift the top eigenvector.
        let (vals, vecs) = crate::linalg::qr_algorithm_symmetric(&res.tridiag.to_dense(), 1e-14, 500);
        assert!((vals[0] - 0.9).abs() < 1e-4, "vals[0]={}", vals[0]);
        let q = lift_eigenvector(&res.basis, &vecs.col(0));
        // Must align with e_0 (up to sign).
        assert!(q[0].abs() > 0.99, "q[0] = {}", q[0]);
    }

    #[test]
    fn fixed_point_stays_close_to_float() {
        let m = path_laplacian(128);
        // Normalize spectrum into (-1,1) as the design requires.
        let mut coo = m.to_coo();
        crate::sparse::normalize_frobenius(&mut coo);
        let m = coo.to_csr();
        let base = lanczos(&m, &LanczosOptions { k: 8, reorth: ReorthPolicy::Every, ..Default::default() });
        let fx = lanczos(
            &m,
            &LanczosOptions {
                k: 8,
                reorth: ReorthPolicy::Every,
                precision: Precision::FixedQ1_31,
                ..Default::default()
            },
        );
        for i in 0..8 {
            assert!(
                (base.tridiag.alpha[i] - fx.tridiag.alpha[i]).abs() < 1e-4,
                "alpha[{i}] {} vs {}",
                base.tridiag.alpha[i],
                fx.tridiag.alpha[i]
            );
        }
    }

    #[test]
    fn typed_basis_is_stored_in_format_words() {
        let m = path_laplacian(96);
        let mut coo = m.to_coo();
        crate::sparse::normalize_frobenius(&mut coo);
        let m = coo.to_csr();
        let opts = LanczosOptions { k: 6, reorth: ReorthPolicy::Every, ..Default::default() };
        let r32: LanczosResult<Q1_31> = lanczos_typed(&m, &opts);
        let r16: LanczosResult<Q1_15> = lanczos_typed(&m, &opts);
        let rf: LanczosResult<f32> = lanczos_typed(&m, &opts);
        // Storage: 16-bit basis is half the f32 bytes — the §IV-B2 claim.
        assert_eq!(rf.basis_value_bytes(), 6 * 96 * 4);
        assert_eq!(r16.basis_value_bytes(), 6 * 96 * 2);
        assert_eq!(r32.basis_value_bytes(), 6 * 96 * 4);
        assert_eq!(r16.basis_bits(), 16);
        // Each stored row dequantizes to a unit vector within format error.
        for i in 0..r32.k() {
            let row = r32.basis_row_f32(i);
            assert!((linalg::norm2(&row) - 1.0).abs() < 1e-4, "row {i}");
        }
        // The dispatching wrapper returns the same values the typed kernel
        // stores, just widened to f32.
        let wrapped = lanczos(
            &m,
            &LanczosOptions { precision: Precision::FixedQ1_31, ..opts.clone() },
        );
        for i in 0..wrapped.k() {
            assert_eq!(&wrapped.basis[i], r32.basis_row_f32(i).as_slice(), "row {i}");
        }
        assert_eq!(wrapped.tridiag.alpha, r32.tridiag.alpha);
    }

    #[test]
    fn typed_lift_matches_f32_lift_on_f32_storage() {
        let m = diag(&[0.8, 0.4, 0.2, 0.1]);
        let res = lanczos(
            &m,
            &LanczosOptions {
                k: 4,
                reorth: ReorthPolicy::Every,
                v1: Some(vec![1.0, 0.8, 0.6, 0.4]),
                ..Default::default()
            },
        );
        let (_, vecs) = crate::linalg::qr_algorithm_symmetric(&res.tridiag.to_dense(), 1e-14, 500);
        let a = lift_eigenvector(&res.basis, &vecs.col(0));
        let b = lift_eigenvector_typed::<f32>(&res.basis, &vecs.col(0));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "exceeds matrix dimension")]
    fn k_larger_than_n_panics() {
        let m = diag(&[1.0, 2.0]);
        lanczos(&m, &LanczosOptions { k: 5, ..Default::default() });
    }

    #[test]
    fn block_b1_reproduces_the_single_vector_recurrence() {
        // At b = 1 the block recurrence degenerates to the classic one:
        // the panel QR is the normalize step, A_j the alpha, B_{j+1} the
        // beta. On a serial CSR operator the arithmetic sequences are
        // identical, so the band must equal the tridiagonal to rounding.
        let m = path_laplacian(48);
        for reorth in [ReorthPolicy::None, ReorthPolicy::Every, ReorthPolicy::EveryN(2)] {
            let opts = LanczosOptions { k: 6, reorth, block_size: 1, ..Default::default() };
            let single = lanczos(&m, &opts);
            let block: BlockLanczosResult = block_lanczos_typed(&m, &opts);
            assert_eq!(block.block_size, 1);
            assert_eq!(block.matrix_passes, 6);
            assert_eq!(block.spmv_count, 6);
            let t = block.band.to_tridiagonal().expect("b=1 band is tridiagonal");
            for i in 0..6 {
                assert!(
                    (t.alpha[i] - single.tridiag.alpha[i]).abs() < 1e-10,
                    "{reorth:?} alpha[{i}]: {} vs {}",
                    t.alpha[i],
                    single.tridiag.alpha[i]
                );
            }
            for i in 0..5 {
                assert!((t.beta[i] - single.tridiag.beta[i]).abs() < 1e-10, "{reorth:?} beta[{i}]");
            }
            for i in 0..6 {
                assert_eq!(&block.basis[i], &single.basis[i], "{reorth:?} basis row {i}");
            }
        }
    }

    #[test]
    fn block_recovers_known_spectrum_with_one_pass_per_iteration() {
        // Geometrically decaying diagonal: top-4 magnitudes are 0.9,
        // 0.9*0.7, 0.9*0.7^2, 0.9*0.7^3. Counting operator pins the stream
        // economics: matrix walks == block iterations, vectors == walks*b.
        let mut vals = vec![0.0f32; 32];
        let mut cur = 0.9f32;
        for v in vals.iter_mut() {
            *v = cur;
            cur *= 0.7;
        }
        let m = diag(&vals);
        let c = CountingOperator::new(m);
        let opts = LanczosOptions {
            k: 4,
            block_size: 2,
            reorth: ReorthPolicy::Every,
            max_iters: 24,
            ritz_tol: 1e-10,
            ..Default::default()
        };
        let res: BlockLanczosResult = block_lanczos_typed(&c, &opts);
        assert_eq!(c.count(), res.matrix_passes, "one operator walk per block iteration");
        assert_eq!(res.spmv_count, res.matrix_passes * 2);
        assert!(res.fused_sweeps == res.matrix_passes);
        let top = res.band.top_k_by_magnitude(4);
        for (j, want) in vals.iter().take(4).enumerate() {
            let want = f64::from(*want);
            assert!((top[j] - want).abs() < 1e-5, "ritz[{j}] = {} want {want}", top[j]);
        }
        // Ritz vectors lift through the basis like the single-vector path.
        let (vals_t, vecs_t) = crate::linalg::qr_algorithm_symmetric(&res.band.to_dense(), 1e-12, 500);
        assert!((vals_t[0] - 0.9).abs() < 1e-5);
        let q = lift_eigenvector_typed::<f32>(&res.basis, &vecs_t.col(0));
        assert!(q[0].abs() > 0.99, "dominant Ritz vector must align with e_0, got q[0]={}", q[0]);
    }

    #[test]
    fn block_breakdown_on_exact_invariant_subspace() {
        // Panel spans an exactly invariant subspace (e_0, e_1 of a diagonal
        // operator): W - V A_1 is exactly zero in f32, so the first panel
        // QR collapses to rank 0 — the block analog of beta -> 0.
        let mut vals = vec![0.0f32; 16];
        vals[0] = 0.5;
        vals[1] = 0.25;
        let m = diag(&vals);
        let mut e0 = vec![0.0f32; 16];
        e0[0] = 1.0;
        let mut e1 = vec![0.0f32; 16];
        e1[1] = 1.0;
        let opts = LanczosOptions {
            k: 4,
            block_size: 2,
            panel: Some(vec![e0, e1]),
            ..Default::default()
        };
        let res: BlockLanczosResult = block_lanczos_typed(&m, &opts);
        assert_eq!(res.breakdown_at, Some(2));
        assert_eq!(res.k(), 2);
        assert_eq!(res.matrix_passes, 1);
        let top = res.band.top_k_by_magnitude(2);
        assert!((top[0] - 0.5).abs() < 1e-7, "{top:?}");
        assert!((top[1] - 0.25).abs() < 1e-7, "{top:?}");
    }

    #[test]
    fn block_workspace_reuse_matches_fresh_runs() {
        let m = path_laplacian(64);
        let mut ws = LanczosWorkspace::new();
        let warm_opts =
            LanczosOptions { k: 12, block_size: 4, reorth: ReorthPolicy::EveryN(2), ..Default::default() };
        let _warm: BlockLanczosResult = block_lanczos_typed_ws(&m, &warm_opts, &mut ws);
        for (k, b) in [(4usize, 2usize), (8, 4), (12, 4)] {
            let opts = LanczosOptions { k, block_size: b, reorth: ReorthPolicy::EveryN(2), ..Default::default() };
            let reused: BlockLanczosResult = block_lanczos_typed_ws(&m, &opts, &mut ws);
            let fresh: BlockLanczosResult = block_lanczos_typed(&m, &opts);
            assert_eq!(reused.band, fresh.band, "k={k} b={b}");
            for i in 0..reused.basis.len() {
                assert_eq!(&reused.basis[i], &fresh.basis[i], "k={k} b={b} row {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "rank deficient")]
    fn block_rank_deficient_start_panel_panics() {
        let m = path_laplacian(16);
        let ones = vec![1.0f32; 16];
        let opts = LanczosOptions {
            k: 4,
            block_size: 2,
            panel: Some(vec![ones.clone(), ones]),
            ..Default::default()
        };
        let _: BlockLanczosResult = block_lanczos_typed(&m, &opts);
    }
}

//! Phase 1 — the Lanczos algorithm (§III-A, Algorithm 1).
//!
//! Reduces a symmetric sparse operator `M` (n x n) to a `K x K` symmetric
//! tridiagonal matrix `T` plus `K` orthonormal Lanczos vectors `V`, such
//! that eigenpairs of `T` lift to approximate eigenpairs of `M`
//! (`lambda(T) ≈ lambda(M)`, eigenvector `= V^T x`).
//!
//! Numerical-stability features reproduced from the paper:
//! * Paige's reordered recurrence [31]: `alpha` is computed against the
//!   *current* `w` after subtracting the `beta v_{i-1}` term.
//! * Full reorthogonalization [32] with a configurable cadence
//!   ([`ReorthPolicy`]): every iteration, every 2 iterations (the paper's
//!   recommended cheap mode), or off.
//! * Frobenius pre-normalization is expected upstream (see
//!   [`crate::sparse::normalize_frobenius`]); with entries in `(-1,1)` the
//!   mixed-precision datapath stores Lanczos vectors in the requested
//!   [`Dataword`] format exactly where the FPGA design uses fixed point.
//!
//! ## Typed basis storage
//!
//! [`lanczos_typed`] is the monomorphized kernel: the basis is a
//! `Vec<Vec<V>>` of storage words (16-bit at Q1.15 — half the f32 DDR
//! footprint), while dots, norms and axpys accumulate in float via
//! [`crate::linalg::dot_q`] / [`crate::linalg::axpy_q`], the design's
//! float units "where required to guarantee precise results" (§IV).
//! [`lanczos`] keeps the legacy f32-basis interface by dispatching
//! [`LanczosOptions::precision`] over the typed kernels
//! ([`crate::with_precision!`]) and dequantizing the result.

mod operator;

pub use operator::{CountingOperator, Operator, ShardedSpmv};

use crate::fixed::{Dataword, Precision};
use crate::linalg::{self, Tridiagonal};

/// Reorthogonalization cadence (§III-A).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ReorthPolicy {
    /// No reorthogonalization: fastest, loses orthogonality for large K.
    None,
    /// Reorthogonalize every iteration: `O(n K^2 / 2)` extra work.
    Every,
    /// Every `N` iterations (the paper evaluates N=2: "negligible accuracy
    /// loss" at half the overhead).
    EveryN(usize),
}

impl ReorthPolicy {
    fn due(self, iter: usize) -> bool {
        match self {
            ReorthPolicy::None => false,
            ReorthPolicy::Every => true,
            ReorthPolicy::EveryN(n) => n != 0 && iter % n == 0,
        }
    }

    /// Name for reports.
    pub fn name(self) -> String {
        match self {
            ReorthPolicy::None => "none".into(),
            ReorthPolicy::Every => "every".into(),
            ReorthPolicy::EveryN(n) => format!("every-{n}"),
        }
    }
}

/// Options for one Lanczos run.
#[derive(Clone, Debug)]
pub struct LanczosOptions {
    /// Number of eigencomponents K (and Lanczos iterations).
    pub k: usize,
    /// Reorthogonalization cadence.
    pub reorth: ReorthPolicy,
    /// Storage format for the Lanczos-vector datapath ([`lanczos`]
    /// dispatches it over the monomorphized typed kernels; ignored by
    /// [`lanczos_typed`], whose type parameter is the format).
    pub precision: Precision,
    /// Starting vector: uniform `1/n^2`-style (the paper's init) when
    /// `None`, otherwise the provided vector (will be normalized).
    pub v1: Option<Vec<f32>>,
}

impl Default for LanczosOptions {
    fn default() -> Self {
        Self { k: 8, reorth: ReorthPolicy::EveryN(2), precision: Precision::Float32, v1: None }
    }
}

/// Lanczos output: `T`, the Lanczos basis in storage format `V`, and
/// diagnostics.
#[derive(Clone, Debug)]
pub struct LanczosResult<V: Dataword = f32> {
    /// The K x K symmetric tridiagonal projection.
    pub tridiag: Tridiagonal,
    /// Lanczos vectors, `k` rows each of length `n` (the paper's `V`,
    /// streamed to DDR on the device), stored as `V` words.
    pub basis: Vec<Vec<V>>,
    /// Iteration at which the recurrence broke down (`beta -> 0`), if any.
    /// A breakdown at iteration `i` truncates the output to `i` components
    /// — mathematically it means an exact invariant subspace was found.
    pub breakdown_at: Option<usize>,
    /// Number of SpMV applications performed.
    pub spmv_count: usize,
}

impl<V: Dataword> LanczosResult<V> {
    /// Effective number of components produced.
    pub fn k(&self) -> usize {
        self.tridiag.k()
    }

    /// Bytes the stored basis occupies (`k * n * V::bytes()`): halved at
    /// Q1.15 relative to f32 — the DDR-side win of the typed datapath.
    pub fn basis_value_bytes(&self) -> usize {
        self.basis.iter().map(|row| row.len() * V::bytes()).sum()
    }

    /// Stored bits per basis word.
    pub fn basis_bits(&self) -> u32 {
        V::BITS
    }

    /// Row `i` of the basis dequantized to f32 (verification paths).
    pub fn basis_row_f32(&self, i: usize) -> Vec<f32> {
        self.basis[i].iter().map(|v| v.to_f32()).collect()
    }
}

/// Run Algorithm 1 against an [`Operator`], storing the basis in format
/// `V`. This is the monomorphized kernel behind [`lanczos`]; the
/// coordinator calls it directly (via [`crate::with_precision!`]) so basis
/// vectors stay quantized end-to-end through eigenvector lift.
///
/// Breakdown (`beta_i ≈ 0`) truncates the decomposition early rather than
/// erroring: the subspace found so far is exactly invariant, which is a
/// *better* answer, not a failure.
pub fn lanczos_typed<V: Dataword, O: Operator + ?Sized>(op: &O, opts: &LanczosOptions) -> LanczosResult<V> {
    let n = op.n();
    let k = opts.k;
    assert!(k >= 1, "k must be >= 1");
    assert!(k <= n, "k = {k} exceeds matrix dimension {n}");

    // v1: the paper initializes with constant 1/n^2 values then L2-
    // normalizes — i.e. the normalized uniform vector.
    let mut v = match &opts.v1 {
        Some(v1) => {
            assert_eq!(v1.len(), n, "v1 length mismatch");
            v1.clone()
        }
        None => vec![1.0f32; n],
    };
    if linalg::normalize(&mut v) == 0.0 {
        panic!("starting vector must be non-zero");
    }
    // Quantize into storage; the working copy holds exactly the stored
    // values so the recurrence and the basis agree bit-for-bit.
    let mut vq: Vec<V> = v.iter().map(|&x| V::from_f32(x)).collect();
    for (vi, q) in v.iter_mut().zip(&vq) {
        *vi = q.to_f32();
    }

    let mut v_prev = vec![0.0f32; n];
    let mut beta_prev = 0.0f64;
    let mut alphas: Vec<f64> = Vec::with_capacity(k);
    let mut betas: Vec<f64> = Vec::with_capacity(k.saturating_sub(1));
    let mut basis: Vec<Vec<V>> = Vec::with_capacity(k);
    let mut w = vec![0.0f32; n];
    let mut breakdown_at = None;
    let mut spmv_count = 0usize;

    // Breakdown tolerance scaled to the arithmetic in use: fixed-point
    // vectors cannot meaningfully normalize below ~sqrt(n)*ulp.
    let bd_tol = if V::IS_FIXED { 1e-9 } else { 1e-12 };

    for i in 0..k {
        basis.push(vq);

        // w = M v  (Algorithm 1 line 7; the memory-bound phase).
        op.apply(&v, &mut w);
        spmv_count += 1;

        // Paige variant [31]: subtract beta*v_{i-1} *before* alpha.
        if i > 0 {
            linalg::axpy(-(beta_prev as f32), &v_prev, &mut w);
        }
        let alpha = linalg::dot(&w, &v);
        alphas.push(alpha);
        linalg::axpy(-(alpha as f32), &v, &mut w);

        if i + 1 == k {
            break;
        }

        // Reorthogonalization (line 10): modified Gram-Schmidt against the
        // whole stored basis, on the paper's cadence. Dots and axpys
        // dequantize the stored words on the fly, accumulating in float.
        if opts.reorth.due(i + 1) {
            for b in &basis {
                let proj = linalg::dot_q(&w, b);
                linalg::axpy_q(-(proj as f32), b, &mut w);
            }
        }

        let beta = linalg::norm2(&w);
        if beta < bd_tol {
            breakdown_at = Some(i + 1);
            break;
        }

        v_prev.copy_from_slice(&v);
        let inv = (1.0 / beta) as f32;
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi * inv;
        }
        // Mixed precision: the device stores Lanczos vectors in V-format;
        // the working copy mirrors the stored (rounded) values.
        vq = v.iter().map(|&x| V::from_f32(x)).collect();
        for (vi, q) in v.iter_mut().zip(&vq) {
            *vi = q.to_f32();
        }
        beta_prev = beta;
        betas.push(beta);
    }

    LanczosResult {
        tridiag: Tridiagonal::new(alphas, betas),
        basis,
        breakdown_at,
        spmv_count,
    }
}

/// Run Algorithm 1 against an [`Operator`] with runtime-selected storage:
/// dispatches [`LanczosOptions::precision`] over the monomorphized
/// [`lanczos_typed`] kernels and returns the basis dequantized to f32 (the
/// values are identical to the stored words — only the container widens).
/// Callers that want the basis to *stay* in storage format use
/// [`lanczos_typed`] directly, as the coordinator does.
pub fn lanczos<O: Operator + ?Sized>(op: &O, opts: &LanczosOptions) -> LanczosResult {
    crate::with_precision!(opts.precision, V => {
        let r: LanczosResult<V> = lanczos_typed(op, opts);
        LanczosResult {
            tridiag: r.tridiag,
            basis: r.basis.iter().map(|row| row.iter().map(|v| v.to_f32()).collect()).collect(),
            breakdown_at: r.breakdown_at,
            spmv_count: r.spmv_count,
        }
    })
}

/// Lift an eigenvector `x` of `T` back to an (approximate) eigenvector of
/// `M` through a typed basis: `q = sum_i x_i v_i`, normalized. The stored
/// words dequantize at the multiplier input; accumulation is f32.
pub fn lift_eigenvector_typed<V: Dataword>(basis: &[Vec<V>], x: &[f64]) -> Vec<f32> {
    assert_eq!(basis.len(), x.len(), "basis/eigvec size mismatch");
    let n = basis[0].len();
    let mut q = vec![0.0f32; n];
    for (xi, vi) in x.iter().zip(basis) {
        linalg::axpy_q(*xi as f32, vi, &mut q);
    }
    linalg::normalize(&mut q);
    q
}

/// Lift an eigenvector `x` of `T` back to an (approximate) eigenvector of
/// `M`: `q = sum_i x_i v_i`, normalized (f32-basis convenience wrapper of
/// [`lift_eigenvector_typed`]).
pub fn lift_eigenvector(basis: &[Vec<f32>], x: &[f64]) -> Vec<f32> {
    lift_eigenvector_typed::<f32>(basis, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q1_15, Q1_31};
    use crate::sparse::CooMatrix;

    /// Diagonal test matrix: eigenvalues are exactly the diagonal.
    fn diag(vals: &[f32]) -> crate::sparse::CsrMatrix {
        let n = vals.len();
        let mut m = CooMatrix::new(n, n);
        for (i, &v) in vals.iter().enumerate() {
            m.push(i, i, v);
        }
        m.to_csr()
    }

    /// 1-D Laplacian path graph: known spectrum 2 - 2cos(pi j / (n+1)).
    fn path_laplacian(n: usize) -> crate::sparse::CsrMatrix {
        let mut m = CooMatrix::new(n, n);
        for i in 0..n {
            m.push(i, i, 2.0);
            if i + 1 < n {
                m.push(i, i + 1, -1.0);
                m.push(i + 1, i, -1.0);
            }
        }
        m.to_csr()
    }

    #[test]
    fn tridiagonal_matches_operator_on_invariant_subspace() {
        // With k == n and full reorth, T is orthogonally similar to M:
        // same spectrum (checked through Sturm counts).
        let m = path_laplacian(12);
        let res = lanczos(
            &m,
            &LanczosOptions {
                k: 12,
                reorth: ReorthPolicy::Every,
                v1: Some((0..12).map(|i| 1.0 + (i as f32) * 0.1).collect()),
                ..Default::default()
            },
        );
        assert!(res.breakdown_at.is_none());
        for j in 1..=12 {
            let lam = 2.0 - 2.0 * (std::f64::consts::PI * j as f64 / 13.0).cos();
            // count eigenvalues below lam + eps must equal j
            assert_eq!(res.tridiag.eigenvalues_below(lam + 1e-5), j, "j={j}");
        }
    }

    #[test]
    fn basis_is_orthonormal_with_reorth() {
        let m = path_laplacian(64);
        let res = lanczos(&m, &LanczosOptions { k: 16, reorth: ReorthPolicy::Every, ..Default::default() });
        for i in 0..res.basis.len() {
            assert!((linalg::norm2(&res.basis[i]) - 1.0).abs() < 1e-5, "row {i} not unit");
            for j in 0..i {
                let d = linalg::dot(&res.basis[i], &res.basis[j]).abs();
                assert!(d < 1e-4, "rows {i},{j} dot {d}");
            }
        }
    }

    #[test]
    fn breakdown_on_low_rank_operator() {
        // Identity has one distinct eigenvalue: breakdown at iteration 1.
        let m = diag(&[1.0; 16]);
        let res = lanczos(&m, &LanczosOptions { k: 8, ..Default::default() });
        assert_eq!(res.breakdown_at, Some(1));
        assert_eq!(res.k(), 1);
        assert!((res.tridiag.alpha[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn spmv_count_is_k() {
        let m = path_laplacian(32);
        let c = CountingOperator::new(m);
        let res = lanczos(&c, &LanczosOptions { k: 10, ..Default::default() });
        assert_eq!(res.spmv_count, 10);
        assert_eq!(c.count(), 10);
    }

    #[test]
    fn custom_start_vector_is_used_and_normalized() {
        let m = diag(&[0.9, 0.1, 0.1, 0.1]);
        // Start exactly on the dominant eigenvector: alpha_1 = 0.9.
        let res = lanczos(
            &m,
            &LanczosOptions { k: 1, v1: Some(vec![10.0, 0.0, 0.0, 0.0]), ..Default::default() },
        );
        assert!((res.tridiag.alpha[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn lift_recovers_diagonal_eigenvector() {
        let m = diag(&[0.9, -0.5, 0.3, 0.1, 0.05, 0.01]);
        let res = lanczos(
            &m,
            &LanczosOptions {
                k: 6,
                reorth: ReorthPolicy::Every,
                v1: Some(vec![1.0, 0.9, 0.8, 0.7, 0.6, 0.5]),
                ..Default::default()
            },
        );
        // Solve T with the QR reference and lift the top eigenvector.
        let (vals, vecs) = crate::linalg::qr_algorithm_symmetric(&res.tridiag.to_dense(), 1e-14, 500);
        assert!((vals[0] - 0.9).abs() < 1e-4, "vals[0]={}", vals[0]);
        let q = lift_eigenvector(&res.basis, &vecs.col(0));
        // Must align with e_0 (up to sign).
        assert!(q[0].abs() > 0.99, "q[0] = {}", q[0]);
    }

    #[test]
    fn fixed_point_stays_close_to_float() {
        let m = path_laplacian(128);
        // Normalize spectrum into (-1,1) as the design requires.
        let mut coo = m.to_coo();
        crate::sparse::normalize_frobenius(&mut coo);
        let m = coo.to_csr();
        let base = lanczos(&m, &LanczosOptions { k: 8, reorth: ReorthPolicy::Every, ..Default::default() });
        let fx = lanczos(
            &m,
            &LanczosOptions {
                k: 8,
                reorth: ReorthPolicy::Every,
                precision: Precision::FixedQ1_31,
                ..Default::default()
            },
        );
        for i in 0..8 {
            assert!(
                (base.tridiag.alpha[i] - fx.tridiag.alpha[i]).abs() < 1e-4,
                "alpha[{i}] {} vs {}",
                base.tridiag.alpha[i],
                fx.tridiag.alpha[i]
            );
        }
    }

    #[test]
    fn typed_basis_is_stored_in_format_words() {
        let m = path_laplacian(96);
        let mut coo = m.to_coo();
        crate::sparse::normalize_frobenius(&mut coo);
        let m = coo.to_csr();
        let opts = LanczosOptions { k: 6, reorth: ReorthPolicy::Every, ..Default::default() };
        let r32: LanczosResult<Q1_31> = lanczos_typed(&m, &opts);
        let r16: LanczosResult<Q1_15> = lanczos_typed(&m, &opts);
        let rf: LanczosResult<f32> = lanczos_typed(&m, &opts);
        // Storage: 16-bit basis is half the f32 bytes — the §IV-B2 claim.
        assert_eq!(rf.basis_value_bytes(), 6 * 96 * 4);
        assert_eq!(r16.basis_value_bytes(), 6 * 96 * 2);
        assert_eq!(r32.basis_value_bytes(), 6 * 96 * 4);
        assert_eq!(r16.basis_bits(), 16);
        // Each stored row dequantizes to a unit vector within format error.
        for i in 0..r32.k() {
            let row = r32.basis_row_f32(i);
            assert!((linalg::norm2(&row) - 1.0).abs() < 1e-4, "row {i}");
        }
        // The dispatching wrapper returns the same values the typed kernel
        // stores, just widened to f32.
        let wrapped = lanczos(
            &m,
            &LanczosOptions { precision: Precision::FixedQ1_31, ..opts.clone() },
        );
        for i in 0..wrapped.k() {
            assert_eq!(wrapped.basis[i], r32.basis_row_f32(i), "row {i}");
        }
        assert_eq!(wrapped.tridiag.alpha, r32.tridiag.alpha);
    }

    #[test]
    fn typed_lift_matches_f32_lift_on_f32_storage() {
        let m = diag(&[0.8, 0.4, 0.2, 0.1]);
        let res = lanczos(
            &m,
            &LanczosOptions {
                k: 4,
                reorth: ReorthPolicy::Every,
                v1: Some(vec![1.0, 0.8, 0.6, 0.4]),
                ..Default::default()
            },
        );
        let (_, vecs) = crate::linalg::qr_algorithm_symmetric(&res.tridiag.to_dense(), 1e-14, 500);
        let a = lift_eigenvector(&res.basis, &vecs.col(0));
        let b = lift_eigenvector_typed::<f32>(&res.basis, &vecs.col(0));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "exceeds matrix dimension")]
    fn k_larger_than_n_panics() {
        let m = diag(&[1.0, 2.0]);
        lanczos(&m, &LanczosOptions { k: 5, ..Default::default() });
    }
}

//! The SpMV operator abstraction consumed by the Lanczos loop.
//!
//! The paper's Lanczos Core reads the matrix through 5 HBM-fed SpMV CUs and
//! merges per-CU partial vectors (Figure 6 A-C). At the L3 layer that
//! decomposition appears as [`Operator`] implementations:
//!
//! * [`CsrMatrix`] — single-threaded native kernel (the unit baseline),
//!   generic over the stored value scalar.
//! * [`crate::sparse::ShardedSpmv`] — one pool worker per CU over row
//!   stripes; the structural twin of the hardware design (each stripe =
//!   one CU, the scoped join = the Merge Unit). Re-exported from this
//!   module for convenience.
//! * `runtime::PjrtSpmv` — the AOT path: the same computation through a
//!   Pallas/XLA artifact executed via PJRT (see `runtime`; requires the
//!   `pjrt` feature; f32 only).
//!
//! Besides `apply`, operators report their storage datapath
//! ([`Operator::value_bits`], [`Operator::packets_per_apply`],
//! [`Operator::bytes_per_apply`]) so the coordinator's run reports show
//! real bytes-moved numbers that differ between storage formats.

use crate::fixed::{packet_capacity, Dataword};
use crate::lanczos::BasisDots;
use crate::linalg;
use crate::sparse::CsrMatrix;

pub use crate::sparse::ShardedSpmv;

/// Everything one fused Lanczos sweep needs besides the SpMV operands: the
/// Paige correction term, optional basis projections (reorth iterations),
/// and the per-shard partial-reduction scratch. See
/// [`Operator::apply_fused`].
pub struct FusedIteration<'a> {
    /// `beta_{i-1}` of the three-term recurrence; `0.0` on the first
    /// iteration (the `v_prev` term vanishes and the subtraction is
    /// skipped).
    pub beta_prev: f32,
    /// The previous Lanczos vector (dequantized working copy).
    pub v_prev: &'a [f32],
    /// Basis rows to project against (blocked classical-GS phase 1) on
    /// reorthogonalization iterations; `None` otherwise.
    pub basis: Option<&'a dyn BasisDots>,
    /// Per-shard partial-reduction scratch, laid out `[shard][1 + rows]`:
    /// slot 0 holds the shard's partial `dot(w, v)`, slots `1..` the
    /// shard's partial basis projections. Length must be at least
    /// `fused_shards * (1 + rows)`. Preallocated by the caller
    /// (`LanczosWorkspace`) so the sweep allocates nothing.
    pub partials: &'a mut [f64],
    /// Merged projection output, one slot per committed basis row (left
    /// untouched when `basis` is `None`).
    pub projs: &'a mut [f64],
}

/// Everything one fused **block** Lanczos sweep needs besides the SpMV
/// operands — the block generalization of [`FusedIteration`], consumed by
/// [`Operator::apply_fused_block`]. All panels are column-major `b`
/// columns of length `n` (column `c` is `panel[c*n..(c+1)*n]`).
pub struct FusedBlockIteration<'a> {
    /// Block width `b` (columns per panel).
    pub b: usize,
    /// Previous panel `V_{j-1}` (dequantized working copies), column-major
    /// `b * n`; empty on the first block iteration (the `B_j^T` term
    /// vanishes and the subtraction is skipped).
    pub v_prev: &'a [f32],
    /// Upper-triangular block coefficient `B_j` from the previous panel QR,
    /// row-major `b x b` (`b_prev[c*b + i]` = B_j\[c\]\[i\], zero below the
    /// diagonal). Column `c` of `V_{j-1} B_j^T` is
    /// `sum_{i >= c} B_j[c][i] * v_prev_i`.
    pub b_prev: &'a [f64],
    /// Basis rows to project against on reorthogonalization iterations;
    /// `None` otherwise.
    pub basis: Option<&'a dyn BasisDots>,
    /// Per-shard partial-reduction scratch, laid out `[shard][b*b + rows*b]`:
    /// the first `b*b` slots hold the shard's partial block dots
    /// `A_j[r][c]`, the rest its partial basis projections (column-grouped,
    /// `b*b + c*rows + row`). Length must be at least
    /// `fused_shards() * (b*b + rows*b)`.
    pub partials: &'a mut [f64],
    /// Merged block dots `A_j = X^T W`, row-major `b x b`
    /// (`a_out[r*b + c] = dot(x_r, w_c)`).
    pub a_out: &'a mut [f64],
    /// Merged projection output, column-grouped `projs[c*rows + row]` (left
    /// untouched when `basis` is `None`). Length must be at least
    /// `rows * b`.
    pub projs: &'a mut [f64],
}

/// A symmetric linear operator `y = M x` over `f32` vectors.
pub trait Operator: Send + Sync {
    /// Concrete-type escape hatch for engines that support in-place
    /// maintenance: the registry's incremental re-prep downcasts a cached
    /// `Arc<dyn Operator>` back to `ShardedSpmv<V>` to reuse its pool and
    /// shard table across a delta update. `None` (the default) means the
    /// operator is opaque and updates fall back to a full rebuild.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
    /// Rows (== cols; operators here are square/symmetric).
    fn n(&self) -> usize;
    /// Stored non-zeros (for complexity accounting).
    fn nnz(&self) -> usize;
    /// Apply: write `M x` into `y` (`y.len() == n()`).
    fn apply(&self, x: &[f32], y: &mut [f32]);
    /// Stored bits per matrix value (32 unless the operator streams a
    /// reduced-precision format).
    fn value_bits(&self) -> u32 {
        32
    }
    /// 512-bit HBM lines one `apply` streams for the matrix (§IV-B1
    /// packet convention; implementations with per-CU shards account tail
    /// lines per shard).
    fn packets_per_apply(&self) -> usize {
        self.nnz().div_ceil(packet_capacity(self.value_bits()))
    }
    /// Matrix-stream bytes one `apply` moves: whole 64-byte lines.
    fn bytes_per_apply(&self) -> usize {
        self.packets_per_apply() * (crate::fixed::LINE_BITS as usize / 8)
    }
    /// Payload bytes read from backing *storage* so far — 0 for in-memory
    /// operators; the out-of-core engine reports its cumulative chunk-file
    /// traffic. Solve metrics snapshot this around a solve to report
    /// effective storage bytes/s.
    fn io_bytes_read(&self) -> u64 {
        0
    }
    /// Times a sweep blocked on an in-flight prefetch so far — 0 for
    /// in-memory operators. Strictly fewer stalls than chunks read means
    /// the double buffer overlapped I/O with compute.
    fn prefetch_stalls(&self) -> u64 {
        0
    }
    /// Host-RAM bytes this operator pins for its matrix. In-memory
    /// operators charge O(nnz) (index + value arrays plus the row
    /// pointers); the out-of-core engine overrides this with its O(buffer)
    /// footprint — the number the registry's byte budget charges.
    fn resident_bytes(&self) -> usize {
        self.nnz() * (4 + self.value_bits() as usize / 8) + 8 * (self.n() + 1)
    }
    /// Partial-reduction lanes [`Operator::apply_fused`] uses — the CU
    /// shard count for the sharded engine, 1 for serial operators. The
    /// caller sizes [`FusedIteration::partials`] as `fused_shards() * (1 +
    /// basis rows)`.
    fn fused_shards(&self) -> usize {
        1
    }
    /// The fused Lanczos sweep (the paper's Figure 6(D) overlap of the
    /// "remaining linear operations" with the SpMV stream): compute `y = M
    /// x`, immediately subtract `beta_prev * v_prev` (Paige reordering),
    /// and reduce `dot(y, x)` — plus, on reorthogonalization iterations,
    /// the projection of `y` onto every committed basis row — **in the
    /// same pass over the data**, while each stripe is still cache-hot.
    /// Returns `alpha = dot(y, x)`; merged projections land in
    /// [`FusedIteration::projs`].
    ///
    /// The default implementation runs the same operations as serial
    /// full-length passes after [`Operator::apply`], so any operator
    /// (PJRT, plain CSR) supports the fused iteration; the sharded engine
    /// overrides it with the true per-stripe fork/join.
    fn apply_fused(&self, x: &[f32], y: &mut [f32], it: &mut FusedIteration<'_>) -> f64 {
        self.apply(x, y);
        if it.beta_prev != 0.0 {
            linalg::axpy(-it.beta_prev, it.v_prev, y);
        }
        let alpha = linalg::dot(y, x);
        if let Some(basis) = it.basis {
            basis.dots_range(y, 0, y.len(), it.projs);
        }
        alpha
    }
    /// The fused **block** Lanczos sweep: one walk of the matrix computes
    /// `W = M X` for all `b` panel columns, subtracts the Paige-reordered
    /// `V_{j-1} B_j^T` correction, reduces the `b x b` block dots
    /// `A_j = X^T W`, and (on reorthogonalization iterations) the
    /// projections of every column of `W` onto every committed basis row.
    /// `x`/`y` are column-major `b * n` panels. This is where the block
    /// economics live: the matrix is streamed **once per iteration instead
    /// of once per vector**, so implementations count it as ONE matrix
    /// pass regardless of `b`.
    ///
    /// The default implementation runs `b` serial [`Operator::apply`]
    /// passes plus full-length vector ops — semantically identical, so any
    /// operator supports the block iteration; the sharded engine overrides
    /// it with a chunked per-stripe fork/join that keeps each CSR chunk
    /// cache-hot across all `b` columns.
    fn apply_fused_block(&self, x: &[f32], y: &mut [f32], it: &mut FusedBlockIteration<'_>) {
        let n = self.n();
        let b = it.b;
        assert_eq!(x.len(), b * n, "x must be a column-major b x n panel");
        assert_eq!(y.len(), b * n, "y must be a column-major b x n panel");
        let nproj = it.basis.map_or(0, |bs| bs.rows());
        for c in 0..b {
            let wc = &mut y[c * n..(c + 1) * n];
            self.apply(&x[c * n..(c + 1) * n], wc);
            if !it.v_prev.is_empty() {
                // w_c -= sum_{i >= c} B_j[c][i] * v_prev_i.
                for i in c..b {
                    let coeff = it.b_prev[c * b + i] as f32;
                    if coeff != 0.0 {
                        linalg::axpy(-coeff, &it.v_prev[i * n..(i + 1) * n], wc);
                    }
                }
            }
            for r in 0..b {
                it.a_out[r * b + c] = linalg::dot(&x[r * n..(r + 1) * n], wc);
            }
            if let Some(basis) = it.basis {
                basis.dots_range(wc, 0, n, &mut it.projs[c * nproj..(c + 1) * nproj]);
            }
        }
    }
    /// Run `f(i)` for every `i in 0..tasks`, possibly in parallel on the
    /// operator's worker pool (the sharded engine dispatches to its CU
    /// pool; the default runs serially). The Lanczos loop uses this to
    /// shard its remaining vector sweeps over the same workers that run
    /// the SpMV.
    fn parallel_for(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        for i in 0..tasks {
            f(i);
        }
    }
}

impl<V: Dataword> Operator for CsrMatrix<V> {
    fn n(&self) -> usize {
        self.nrows
    }
    fn nnz(&self) -> usize {
        CsrMatrix::nnz(self)
    }
    fn value_bits(&self) -> u32 {
        V::BITS
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        self.spmv_into(x, y, 0, self.nrows);
    }
}

/// Operator counting applications — used by tests and the coordinator's
/// metrics to assert the expected number of SpMVs (K per solve, §III-A).
pub struct CountingOperator<O: Operator> {
    inner: O,
    count: std::sync::atomic::AtomicUsize,
}

impl<O: Operator> CountingOperator<O> {
    /// Wrap an operator.
    pub fn new(inner: O) -> Self {
        Self { inner, count: std::sync::atomic::AtomicUsize::new(0) }
    }
    /// Number of `apply` calls so far.
    pub fn count(&self) -> usize {
        self.count.load(std::sync::atomic::Ordering::SeqCst)
    }
}

impl<O: Operator> Operator for CountingOperator<O> {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn nnz(&self) -> usize {
        self.inner.nnz()
    }
    fn value_bits(&self) -> u32 {
        self.inner.value_bits()
    }
    fn packets_per_apply(&self) -> usize {
        self.inner.packets_per_apply()
    }
    fn bytes_per_apply(&self) -> usize {
        self.inner.bytes_per_apply()
    }
    fn io_bytes_read(&self) -> u64 {
        self.inner.io_bytes_read()
    }
    fn prefetch_stalls(&self) -> u64 {
        self.inner.prefetch_stalls()
    }
    fn resident_bytes(&self) -> usize {
        self.inner.resident_bytes()
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        self.count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.apply(x, y);
    }
    fn fused_shards(&self) -> usize {
        self.inner.fused_shards()
    }
    fn apply_fused(&self, x: &[f32], y: &mut [f32], it: &mut FusedIteration<'_>) -> f64 {
        self.count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.apply_fused(x, y, it)
    }
    fn apply_fused_block(&self, x: &[f32], y: &mut [f32], it: &mut FusedBlockIteration<'_>) {
        // One tick per *matrix pass*, not per panel column — the whole
        // point of the block sweep.
        self.count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.apply_fused_block(x, y, it);
    }
    fn parallel_for(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.inner.parallel_for(tasks, f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q1_15;
    use crate::graphs;

    #[test]
    fn counting_operator_counts() {
        let m = graphs::erdos_renyi(128, 512, 1).to_csr();
        let c = CountingOperator::new(m);
        let x = vec![1.0f32; 128];
        let mut y = vec![0.0f32; 128];
        c.apply(&x, &mut y);
        c.apply(&x, &mut y);
        assert_eq!(c.count(), 2);
    }

    #[test]
    fn csr_operator_matches_spmv() {
        let m = graphs::mesh2d(10, 10, 0.9, 0.02, 4).to_csr();
        let x: Vec<f32> = (0..m.nrows).map(|i| i as f32 * 0.01 - 0.3).collect();
        let mut y = vec![0.0f32; m.nrows];
        Operator::apply(&m, &x, &mut y);
        assert_eq!(y, m.spmv(&x));
        assert_eq!(Operator::n(&m), m.nrows);
        assert_eq!(Operator::nnz(&m), m.nnz());
    }

    #[test]
    fn fused_block_default_matches_column_serial_reference() {
        let m = graphs::mesh2d(8, 8, 0.9, 0.02, 9).to_csr();
        let (n, b) = (m.nrows, 3usize);
        let x: Vec<f32> = (0..b * n).map(|i| ((i as f32) * 0.07).sin() * 0.5).collect();
        let v_prev: Vec<f32> = (0..b * n).map(|i| ((i as f32) * 0.05).cos() * 0.3).collect();
        let b_prev = [0.4f64, -0.2, 0.1, 0.0, 0.7, 0.3, 0.0, 0.0, 0.9];
        let mut y = vec![0.0f32; b * n];
        let mut a_out = vec![0.0f64; b * b];
        let mut it = FusedBlockIteration {
            b,
            v_prev: &v_prev,
            b_prev: &b_prev,
            basis: None,
            partials: &mut [],
            a_out: &mut a_out,
            projs: &mut [],
        };
        m.apply_fused_block(&x, &mut y, &mut it);
        // Reference: per-column apply + triangular axpy + dots.
        for c in 0..b {
            let mut wc = vec![0.0f32; n];
            Operator::apply(&m, &x[c * n..(c + 1) * n], &mut wc);
            for i in c..b {
                linalg::axpy(-(b_prev[c * b + i] as f32), &v_prev[i * n..(i + 1) * n], &mut wc);
            }
            assert_eq!(&y[c * n..(c + 1) * n], &wc[..], "column {c}");
            for r in 0..b {
                let expect = linalg::dot(&x[r * n..(r + 1) * n], &wc);
                assert_eq!(a_out[r * b + c].to_bits(), expect.to_bits(), "A[{r}][{c}]");
            }
        }
        // The counting wrapper charges ONE application per block pass.
        let c = CountingOperator::new(m);
        let mut it2 = FusedBlockIteration {
            b,
            v_prev: &v_prev,
            b_prev: &b_prev,
            basis: None,
            partials: &mut [],
            a_out: &mut a_out,
            projs: &mut [],
        };
        c.apply_fused_block(&x, &mut y, &mut it2);
        assert_eq!(c.count(), 1);
    }

    #[test]
    fn datapath_telemetry_scales_with_storage_width() {
        let m = graphs::erdos_renyi(96, 480, 7).to_csr();
        let q: CsrMatrix<Q1_15> = m.to_precision::<Q1_15>();
        assert_eq!(Operator::value_bits(&m), 32);
        assert_eq!(Operator::value_bits(&q), 16);
        // 6 entries per line instead of 5: fewer packets, fewer bytes.
        assert_eq!(Operator::packets_per_apply(&m), m.nnz().div_ceil(5));
        assert_eq!(Operator::packets_per_apply(&q), m.nnz().div_ceil(6));
        assert_eq!(Operator::bytes_per_apply(&m), m.nnz().div_ceil(5) * 64);
        assert!(Operator::bytes_per_apply(&q) < Operator::bytes_per_apply(&m));
        // The wrapper forwards the inner operator's datapath.
        let c = CountingOperator::new(q);
        assert_eq!(c.value_bits(), 16);
        assert_eq!(c.packets_per_apply(), m.nnz().div_ceil(6));
    }
}

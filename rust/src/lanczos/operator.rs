//! The SpMV operator abstraction consumed by the Lanczos loop.
//!
//! The paper's Lanczos Core reads the matrix through 5 HBM-fed SpMV CUs and
//! merges per-CU partial vectors (Figure 6 A-C). At the L3 layer that
//! decomposition appears as [`Operator`] implementations:
//!
//! * [`CsrMatrix`] — single-threaded native kernel (the unit baseline).
//! * [`ShardedSpmv`] — one worker per CU over nnz-balanced row stripes;
//!   the structural twin of the hardware design (each stripe = one CU, the
//!   scoped join = the Merge Unit).
//! * `runtime::PjrtSpmv` — the AOT path: the same computation through a
//!   Pallas/XLA artifact executed via PJRT (see `runtime`).

use crate::sparse::{partition_rows_balanced, CsrMatrix, PartitionPolicy, RowPartition};
use crate::util::pool::ThreadPool;
use std::sync::Arc;

/// A symmetric linear operator `y = M x` over `f32` vectors.
pub trait Operator: Send + Sync {
    /// Rows (== cols; operators here are square/symmetric).
    fn n(&self) -> usize;
    /// Stored non-zeros (for complexity accounting).
    fn nnz(&self) -> usize;
    /// Apply: write `M x` into `y` (`y.len() == n()`).
    fn apply(&self, x: &[f32], y: &mut [f32]);
}

impl Operator for CsrMatrix {
    fn n(&self) -> usize {
        self.nrows
    }
    fn nnz(&self) -> usize {
        CsrMatrix::nnz(self)
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        self.spmv_into(x, y, 0, self.nrows);
    }
}

/// Multi-CU SpMV: row stripes dispatched to a thread pool, one worker per
/// CU shard. Output regions are disjoint so no synchronization is needed
/// beyond the final join — exactly the paper's partition + merge scheme.
pub struct ShardedSpmv {
    matrix: Arc<CsrMatrix>,
    parts: Vec<RowPartition>,
    pool: Arc<ThreadPool>,
}

impl ShardedSpmv {
    /// Shard `matrix` into `cus` stripes under `policy` and run them on
    /// `pool` (pool should have >= `cus` workers for full overlap).
    pub fn new(matrix: Arc<CsrMatrix>, cus: usize, policy: PartitionPolicy, pool: Arc<ThreadPool>) -> Self {
        let parts = partition_rows_balanced(&matrix, cus, policy);
        Self { matrix, parts, pool }
    }

    /// The shard table (exposed for the FPGA model and tests).
    pub fn partitions(&self) -> &[RowPartition] {
        &self.parts
    }
}

impl Operator for ShardedSpmv {
    fn n(&self) -> usize {
        self.matrix.nrows
    }
    fn nnz(&self) -> usize {
        self.matrix.nnz()
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(y.len(), self.matrix.nrows);
        let m = &self.matrix;
        let parts = &self.parts;
        // SAFETY-free disjoint writes: each task owns rows [row_start,row_end).
        // We hand each worker a raw pointer range via split borrows.
        let y_ptr = SendPtr(y.as_mut_ptr());
        self.pool.scope_chunks(parts.len(), |i| {
            let p = parts[i];
            // Reconstruct the worker's disjoint sub-slice.
            let y_slice = unsafe {
                std::slice::from_raw_parts_mut(y_ptr.get(), m.nrows)
            };
            m.spmv_into(x, y_slice, p.row_start, p.row_end);
        });
    }
}

/// Pointer wrapper proving to the compiler we uphold disjointness manually.
#[derive(Copy, Clone)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}
impl SendPtr {
    fn get(self) -> *mut f32 {
        self.0
    }
}

/// Operator counting applications — used by tests and the coordinator's
/// metrics to assert the expected number of SpMVs (K per solve, §III-A).
pub struct CountingOperator<O: Operator> {
    inner: O,
    count: std::sync::atomic::AtomicUsize,
}

impl<O: Operator> CountingOperator<O> {
    /// Wrap an operator.
    pub fn new(inner: O) -> Self {
        Self { inner, count: std::sync::atomic::AtomicUsize::new(0) }
    }
    /// Number of `apply` calls so far.
    pub fn count(&self) -> usize {
        self.count.load(std::sync::atomic::Ordering::SeqCst)
    }
}

impl<O: Operator> Operator for CountingOperator<O> {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn nnz(&self) -> usize {
        self.inner.nnz()
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        self.count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.apply(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs;

    #[test]
    fn sharded_matches_serial() {
        let m = Arc::new(graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 3).to_csr());
        let pool = Arc::new(ThreadPool::new(5));
        let x: Vec<f32> = (0..m.nrows).map(|i| ((i * 37) % 11) as f32 * 0.1 - 0.5).collect();
        let serial = m.spmv(&x);
        for cus in [1, 2, 5, 8] {
            for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
                let sharded = ShardedSpmv::new(Arc::clone(&m), cus, policy, Arc::clone(&pool));
                let mut y = vec![0.0f32; m.nrows];
                sharded.apply(&x, &mut y);
                assert_eq!(serial, y, "cus={cus} policy={policy:?}");
            }
        }
    }

    #[test]
    fn partitions_tile_rows() {
        let m = Arc::new(graphs::mesh2d(40, 40, 0.9, 0.01, 5).to_csr());
        let pool = Arc::new(ThreadPool::new(4));
        let s = ShardedSpmv::new(Arc::clone(&m), 5, PartitionPolicy::BalancedNnz, pool);
        let parts = s.partitions();
        assert_eq!(parts.len(), 5);
        assert_eq!(parts[0].row_start, 0);
        assert_eq!(parts.last().unwrap().row_end, m.nrows);
    }

    #[test]
    fn counting_operator_counts() {
        let m = graphs::erdos_renyi(128, 512, 1).to_csr();
        let c = CountingOperator::new(m);
        let x = vec![1.0f32; 128];
        let mut y = vec![0.0f32; 128];
        c.apply(&x, &mut y);
        c.apply(&x, &mut y);
        assert_eq!(c.count(), 2);
    }
}

//! Contiguous row-strided storage for the Lanczos basis.
//!
//! The paper streams Lanczos vectors to DDR as one flat region (§IV-B2);
//! the host-side twin is [`BasisArena`]: a **single allocation** of
//! `k * n` storage words with row views taken by stride. Replacing the
//! former `Vec<Vec<V>>` (k separate heap blocks) means:
//!
//! * reorthogonalization and eigenvector lift sweep **linear memory** — no
//!   pointer chase per row, hardware prefetch works across rows;
//! * the whole basis costs one allocation per solve, which is what the
//!   zero-steady-state-allocation property of the fused iteration needs;
//! * blocked classical Gram-Schmidt ([`BasisDots::dots_range`] /
//!   [`BasisArena::apply_projections_norm2`]) runs as two flat sweeps
//!   instead of K dependent passes.
//!
//! [`BasisDots`] is the object-safe projection interface the fused
//! [`crate::lanczos::Operator::apply_fused`] sweep uses: it erases the
//! storage scalar so a `dyn Operator` can compute per-stripe partial
//! projections against a basis of any precision.

use crate::fixed::Dataword;
use crate::linalg;

/// Flat row-strided arena holding the Lanczos basis: `rows()` committed
/// vectors of length `n`, all in one allocation.
#[derive(Clone, Debug, PartialEq)]
pub struct BasisArena<V: Dataword = f32> {
    data: Vec<V>,
    n: usize,
    max_rows: usize,
}

impl<V: Dataword> BasisArena<V> {
    /// Arena with room for `k` rows of length `n` (one allocation, done
    /// up front; committing rows later never reallocates).
    pub fn with_capacity(k: usize, n: usize) -> Self {
        assert!(n > 0, "basis rows must be non-empty");
        Self { data: Vec::with_capacity(k * n), n, max_rows: k }
    }

    /// Row length (the operator dimension).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Committed rows.
    pub fn len(&self) -> usize {
        self.data.len() / self.n
    }

    /// True when no rows are committed.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Commit one more row and return it for initialization. Panics if the
    /// arena is full — capacity is fixed at construction so the warm path
    /// never reallocates.
    pub fn alloc_row(&mut self) -> &mut [V] {
        assert!(self.len() < self.max_rows, "basis arena overflow");
        let start = self.data.len();
        self.data.resize(start + self.n, V::default());
        &mut self.data[start..start + self.n]
    }

    /// Row `i` as a slice of storage words.
    pub fn row(&self, i: usize) -> &[V] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// Iterate the committed rows in order (linear memory sweep).
    pub fn rows_iter(&self) -> impl Iterator<Item = &[V]> {
        self.data.chunks_exact(self.n)
    }

    /// Row `i` dequantized to f32 (verification paths).
    pub fn row_f32(&self, i: usize) -> Vec<f32> {
        self.row(i).iter().map(|v| v.to_f32()).collect()
    }

    /// Bytes the stored rows occupy (`len * n * V::bytes()`).
    pub fn value_bytes(&self) -> usize {
        self.data.len() * V::bytes()
    }

    /// Blocked classical-GS apply + norm: `w_chunk -= sum_j projs[j] *
    /// row_j[r0..r1]`, then return the squared L2 norm of the updated
    /// chunk. `w_chunk` is the caller's `[r0, r1)` slice of the working
    /// vector (chunk-local, so parallel callers never hold overlapping
    /// `&mut` slices). One linear sweep over the arena stripe — the second
    /// phase of the two-phase reorthogonalization (the first phase is
    /// [`BasisDots::dots_range`]).
    pub fn apply_projections_norm2(&self, projs: &[f64], w_chunk: &mut [f32], r0: usize, r1: usize) -> f64 {
        assert_eq!(projs.len(), self.len(), "one projection per committed row");
        assert_eq!(w_chunk.len(), r1 - r0, "w_chunk must be the [r0, r1) slice");
        for (j, proj) in projs.iter().enumerate() {
            linalg::axpy_q(-(*proj as f32), &self.row(j)[r0..r1], w_chunk);
        }
        linalg::dot(w_chunk, w_chunk)
    }
}

impl<V: Dataword> std::ops::Index<usize> for BasisArena<V> {
    type Output = [V];
    fn index(&self, i: usize) -> &[V] {
        self.row(i)
    }
}

/// Object-safe view of a basis for the fused sweep: lets a boxed
/// [`crate::lanczos::Operator`] compute per-stripe partial projections
/// without knowing the basis storage scalar.
pub trait BasisDots: Sync {
    /// Committed rows.
    fn rows(&self) -> usize;

    /// `out[j] = dot(w_chunk, row_j[r0..r1])` for every committed row `j`
    /// — the blocked classical-GS projection phase, computed on a stripe
    /// while it is cache-hot from the SpMV. `w_chunk` is the caller's
    /// `[r0, r1)` slice of the working vector.
    fn dots_range(&self, w_chunk: &[f32], r0: usize, r1: usize, out: &mut [f64]);

    /// Accumulating variant: `out[j] += dot(w_chunk, row_j[r0..r1])`. The
    /// block sweep visits a shard stripe in row *chunks* (so all `b`
    /// columns reuse each cache-hot chunk) and folds each chunk's partial
    /// dots into the same per-shard slot; the plain [`BasisDots::dots_range`]
    /// overwrite would discard the previous chunks' contribution.
    fn dots_range_add(&self, w_chunk: &[f32], r0: usize, r1: usize, out: &mut [f64]);
}

impl<V: Dataword> BasisDots for BasisArena<V> {
    fn rows(&self) -> usize {
        self.len()
    }

    fn dots_range(&self, w_chunk: &[f32], r0: usize, r1: usize, out: &mut [f64]) {
        assert!(out.len() >= self.len());
        assert_eq!(w_chunk.len(), r1 - r0, "w_chunk must be the [r0, r1) slice");
        for (j, slot) in out.iter_mut().take(self.len()).enumerate() {
            *slot = linalg::dot_q(w_chunk, &self.row(j)[r0..r1]);
        }
    }

    fn dots_range_add(&self, w_chunk: &[f32], r0: usize, r1: usize, out: &mut [f64]) {
        assert!(out.len() >= self.len());
        assert_eq!(w_chunk.len(), r1 - r0, "w_chunk must be the [r0, r1) slice");
        for (j, slot) in out.iter_mut().take(self.len()).enumerate() {
            *slot += linalg::dot_q(w_chunk, &self.row(j)[r0..r1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q1_15;

    #[test]
    fn arena_is_one_allocation_with_strided_rows() {
        let mut a: BasisArena<f32> = BasisArena::with_capacity(3, 4);
        assert_eq!(a.len(), 0);
        assert!(a.is_empty());
        for r in 0..3 {
            let row = a.alloc_row();
            for (i, x) in row.iter_mut().enumerate() {
                *x = (r * 4 + i) as f32;
            }
        }
        assert_eq!(a.len(), 3);
        assert_eq!(a.n(), 4);
        assert_eq!(a.row(1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(&a[2], &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(a.rows_iter().count(), 3);
        assert_eq!(a.value_bytes(), 3 * 4 * 4);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn arena_overflow_panics_instead_of_reallocating() {
        let mut a: BasisArena<f32> = BasisArena::with_capacity(1, 4);
        a.alloc_row();
        a.alloc_row();
    }

    #[test]
    fn dots_range_matches_per_row_dot_q() {
        let mut a: BasisArena<Q1_15> = BasisArena::with_capacity(3, 16);
        let mut w = vec![0.0f32; 16];
        for r in 0..3 {
            let row = a.alloc_row();
            for (i, x) in row.iter_mut().enumerate() {
                *x = Q1_15::from_f32(((r * 16 + i) as f32 * 0.03).sin() * 0.5);
            }
        }
        for (i, wi) in w.iter_mut().enumerate() {
            *wi = ((i as f32) * 0.11).cos() * 0.4;
        }
        let mut out = vec![0.0f64; 3];
        a.dots_range(&w[2..14], 2, 14, &mut out);
        for j in 0..3 {
            let expect = linalg::dot_q(&w[2..14], &a.row(j)[2..14]);
            assert_eq!(out[j].to_bits(), expect.to_bits(), "row {j}");
        }
        // The accumulating variant folds chunked partials into the same
        // slots the one-shot call would produce.
        let mut acc = vec![0.0f64; 3];
        a.dots_range_add(&w[2..8], 2, 8, &mut acc);
        a.dots_range_add(&w[8..14], 8, 14, &mut acc);
        for j in 0..3 {
            let one_shot = linalg::dot_q(&w[2..8], &a.row(j)[2..8]) + linalg::dot_q(&w[8..14], &a.row(j)[8..14]);
            assert_eq!(acc[j].to_bits(), one_shot.to_bits(), "chunked row {j}");
        }
    }

    #[test]
    fn apply_projections_matches_sequential_axpys() {
        let mut a: BasisArena<f32> = BasisArena::with_capacity(2, 8);
        for r in 0..2 {
            let row = a.alloc_row();
            for (i, x) in row.iter_mut().enumerate() {
                *x = ((r + i) as f32 * 0.2).sin();
            }
        }
        let w0: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).cos()).collect();
        let projs = [0.25f64, -0.5];
        let mut w_ref = w0.clone();
        for (j, p) in projs.iter().enumerate() {
            linalg::axpy_q(-(*p as f32), a.row(j), &mut w_ref);
        }
        let n_ref = linalg::dot(&w_ref, &w_ref);
        let mut w = w0.clone();
        let n = a.apply_projections_norm2(&projs, &mut w, 0, 8);
        assert_eq!(w, w_ref);
        assert_eq!(n.to_bits(), n_ref.to_bits());
    }
}

//! # topk-eigen
//!
//! A Top-K sparse graph eigensolver reproducing *"Solving Large Top-K Graph
//! Eigenproblems with a Memory and Compute-optimized FPGA Design"*
//! (Sgherzi et al., 2021).
//!
//! The solver is a two-phase pipeline:
//!
//! 1. **Lanczos** (memory-bound): reduces a sparse symmetric `n x n` matrix
//!    `M` to a `K x K` symmetric tridiagonal matrix `T` plus `K` orthogonal
//!    Lanczos vectors, with the Sparse Matrix-Vector product (SpMV) as the
//!    dominant cost. The paper streams the COO matrix through 5 HBM-fed
//!    compute units; we reproduce that decomposition with a sharded SpMV
//!    engine (one shard per "CU") and an FPGA performance model.
//! 2. **Jacobi** (compute-bound): diagonalizes `T` with a systolic-array
//!    formulation of the Jacobi eigenvalue algorithm (Brent-Luk schedule
//!    with the paper's reverse-order row/column interchange), yielding the
//!    Top-K eigenvalues of `M` and, via the Lanczos basis, its eigenvectors.
//!
//! The crate is the L3 (coordinator) layer of a three-layer stack: L2/L1 are
//! JAX + Pallas programs AOT-lowered to HLO text at build time
//! (`make artifacts`) and executed from rust through PJRT ([`runtime`]).
//! Python is never on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use topk_eigen::prelude::*;
//!
//! // Build a small random power-law graph and solve for the top 8 pairs.
//! let m = graphs::rmat(1 << 12, 8 * (1 << 12), 0.57, 0.19, 0.19, 42);
//! let opts = coordinator::SolveOptions { k: 8, ..Default::default() };
//! let sol = coordinator::Solver::new(opts).solve(&m).unwrap();
//! for (lambda, _v) in sol.pairs() {
//!     println!("lambda = {lambda}");
//! }
//! ```
#![warn(missing_docs)]

pub mod arnoldi;
pub mod bench;
pub mod coordinator;
pub mod fixed;
pub mod fpga;
pub mod graphs;
pub mod iram;
pub mod jacobi;
pub mod lanczos;
pub mod linalg;
pub mod runtime;
pub mod sparse;
pub mod util;

/// Convenient glob-import of the most used types.
pub mod prelude {
    pub use crate::coordinator::{self, SolveOptions, Solver};
    pub use crate::fixed::{Q1_15, Q1_31, Q2_30};
    pub use crate::fpga;
    pub use crate::graphs;
    pub use crate::jacobi::{self, JacobiMode};
    pub use crate::lanczos::{self, LanczosOptions, ReorthPolicy};
    pub use crate::linalg;
    pub use crate::sparse::{CooMatrix, CsrMatrix};
    pub use crate::util::rng::Pcg64;
}

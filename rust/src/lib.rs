//! # topk-eigen
//!
//! A Top-K sparse graph eigensolver reproducing *"Solving Large Top-K Graph
//! Eigenproblems with a Memory and Compute-optimized FPGA Design"*
//! (Sgherzi et al., 2021).
//!
//! The solver is a two-phase pipeline:
//!
//! 1. **Lanczos** (memory-bound): reduces a sparse symmetric `n x n` matrix
//!    `M` to a `K x K` symmetric tridiagonal matrix `T` plus `K` orthogonal
//!    Lanczos vectors, with the Sparse Matrix-Vector product (SpMV) as the
//!    dominant cost. The paper streams the COO matrix through 5 HBM-fed
//!    compute units; we reproduce that decomposition with the pool-parallel
//!    [`sparse::ShardedSpmv`] engine (one worker per "CU") and an FPGA
//!    performance model.
//! 2. **Jacobi** (compute-bound): diagonalizes `T` with a systolic-array
//!    formulation of the Jacobi eigenvalue algorithm (Brent-Luk schedule
//!    with the paper's reverse-order row/column interchange), yielding the
//!    Top-K eigenvalues of `M` and, via the Lanczos basis, its eigenvectors.
//!
//! The crate is the L3 (coordinator) layer of a three-layer stack: L2/L1 are
//! JAX + Pallas programs AOT-lowered to HLO text at build time
//! (`make artifacts`) and executed from rust through PJRT ([`runtime`]).
//! Python is never on the request path.
//!
//! ## Feature flags
//!
//! * **`pjrt`** (off by default) — compile the PJRT/XLA execution bridge.
//!   The default build is hermetic pure Rust: [`runtime`] exposes the same
//!   API through stubs that report the engine unavailable, and the
//!   coordinator transparently falls back to the native sharded engine.
//! * **`race-check`** (off by default) — arm the scoped-claim race detector
//!   ([`util::race`]): every checked [`util::ptr::SendPtr`] dereference
//!   registers the index range its scoped task writes, and overlapping
//!   claims or post-join dereferences panic with both call sites named.
//!   CI re-runs the concurrency suite with this on; see also the
//!   `lint_unsafe` binary, which audits the unsafe surface statically.
//!
//! ## Quick start
//!
//! ```
//! use topk_eigen::prelude::*;
//!
//! // Build a small random power-law graph and solve for the top 4 pairs.
//! let m = graphs::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, 42);
//! let opts = coordinator::SolveOptions { k: 4, ..Default::default() };
//! let sol = coordinator::Solver::new(opts).solve(&m).unwrap();
//! assert_eq!(sol.k(), 4);
//! for (lambda, _v) in sol.pairs() {
//!     println!("lambda = {lambda}");
//! }
//! ```
//!
//! The larger tour lives in `examples/quickstart.rs`
//! (`cargo run --release --example quickstart`).
#![warn(missing_docs)]
// Every pointer dereference inside an `unsafe fn` must sit in its own
// `unsafe` block with a SAFETY comment (enforced by `lint_unsafe`); the
// function-level `unsafe` only states the *caller's* obligations.
#![deny(unsafe_op_in_unsafe_fn)]
// CI runs `cargo clippy -- -D warnings`. These style lints fight the
// codebase's deliberate idiom — index-parallel loops and explicit numeric
// literals that mirror the hardware's packet/array layout — so they are
// opted out wholesale rather than per-site.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::needless_lifetimes,
    clippy::excessive_precision,
    clippy::approx_constant,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod arnoldi;
pub mod bench;
pub mod coordinator;
pub mod fixed;
pub mod fpga;
pub mod graphs;
pub mod iram;
pub mod jacobi;
pub mod lanczos;
pub mod linalg;
pub mod runtime;
pub mod sparse;
pub mod util;

/// Convenient glob-import of the most used types.
pub mod prelude {
    pub use crate::coordinator::{self, Engine, MatrixHandle, MatrixRegistry, SolveOptions, Solver};
    pub use crate::fixed::{Dataword, Precision, Q1_15, Q1_31, Q2_30};
    pub use crate::fpga;
    pub use crate::graphs;
    pub use crate::jacobi::{self, JacobiMode};
    pub use crate::lanczos::{self, LanczosOptions, Operator, ReorthPolicy};
    pub use crate::linalg;
    pub use crate::sparse::{CooDelta, CooMatrix, CsrMatrix, DeltaOp, PartitionPolicy, ShardedSpmv};
    pub use crate::util::rng::Pcg64;
}

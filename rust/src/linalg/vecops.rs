//! Dense vector kernels for the Lanczos loop (Algorithm 1, lines 5-10).
//!
//! These are the "remaining linear operations" of Figure 6(D); they run on
//! every Lanczos iteration over length-`n` vectors, so the hot-path variants
//! are written to autovectorize (chunked accumulators, no bounds checks in
//! the inner loop via exact-size slices).

/// Dot product with 4-lane accumulation (f32 in, f64 accumulators to keep
/// the reorthogonalization numerically trustworthy on multi-million-element
/// vectors).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (a4, b4) = (&a[4 * i..4 * i + 4], &b[4 * i..4 * i + 4]);
        acc[0] += a4[0] as f64 * b4[0] as f64;
        acc[1] += a4[1] as f64 * b4[1] as f64;
        acc[2] += a4[2] as f64 * b4[2] as f64;
        acc[3] += a4[3] as f64 * b4[3] as f64;
    }
    let mut tail = 0.0f64;
    for i in 4 * chunks..a.len() {
        tail += a[i] as f64 * b[i] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Fused `y += a*x` followed by a dot product against `z`, in one pass
/// over the data (the fused Lanczos sweep's `w -= beta v_prev` + partial
/// `dot(w, v)` stripe kernel). The dot uses the same 4-lane f64
/// accumulation as [`dot`], so for a full-length call the result is
/// bitwise identical to `axpy(a, x, y); dot(y, z)` — the unfused
/// reference path.
pub fn axpy_dot(a: f32, x: &[f32], y: &mut [f32], z: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), z.len());
    let mut acc = [0.0f64; 4];
    let chunks = y.len() / 4;
    for i in 0..chunks {
        let (x4, z4) = (&x[4 * i..4 * i + 4], &z[4 * i..4 * i + 4]);
        let y4 = &mut y[4 * i..4 * i + 4];
        y4[0] += a * x4[0];
        y4[1] += a * x4[1];
        y4[2] += a * x4[2];
        y4[3] += a * x4[3];
        acc[0] += y4[0] as f64 * z4[0] as f64;
        acc[1] += y4[1] as f64 * z4[1] as f64;
        acc[2] += y4[2] as f64 * z4[2] as f64;
        acc[3] += y4[3] as f64 * z4[3] as f64;
    }
    let mut tail = 0.0f64;
    for i in 4 * chunks..y.len() {
        y[i] += a * x[i];
        tail += y[i] as f64 * z[i] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Fused `y += a*x` followed by the squared L2 norm of the result, in one
/// pass (the fused sweep's `w -= alpha v` + partial `||w||^2` stripe
/// kernel). Same lane structure as [`dot`], so a full-length call matches
/// `axpy(a, x, y); dot(y, y)` bitwise.
pub fn axpy_norm2(a: f32, x: &[f32], y: &mut [f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = y.len() / 4;
    for i in 0..chunks {
        let x4 = &x[4 * i..4 * i + 4];
        let y4 = &mut y[4 * i..4 * i + 4];
        y4[0] += a * x4[0];
        y4[1] += a * x4[1];
        y4[2] += a * x4[2];
        y4[3] += a * x4[3];
        acc[0] += y4[0] as f64 * y4[0] as f64;
        acc[1] += y4[1] as f64 * y4[1] as f64;
        acc[2] += y4[2] as f64 * y4[2] as f64;
        acc[3] += y4[3] as f64 * y4[3] as f64;
    }
    let mut tail = 0.0f64;
    for i in 4 * chunks..y.len() {
        y[i] += a * x[i];
        tail += y[i] as f64 * y[i] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Recurrence normalization: `v = alpha * w` quantized through storage
/// format `V`, writing the quantized words into `row` (the Lanczos basis
/// slot) and the dequantized mirror into `v` — so the working copy holds
/// exactly the stored values and the recurrence and the basis agree
/// bit-for-bit. The named kernel shared by the fused and unfused Lanczos
/// paths (for `V = f32` the round-trip is the identity and this is a plain
/// scaled copy).
pub fn scale_quantize_into<V: crate::fixed::Dataword>(alpha: f32, w: &[f32], v: &mut [f32], row: &mut [V]) {
    assert_eq!(w.len(), v.len());
    assert_eq!(w.len(), row.len());
    for i in 0..w.len() {
        let q = V::from_f32(w[i] * alpha);
        row[i] = q;
        v[i] = q.to_f32();
    }
}

/// `x *= alpha`.
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x {
        *xi *= alpha;
    }
}

/// L2 norm (f64 accumulation).
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Normalize `x` to unit L2 norm; returns the pre-normalization norm.
/// A zero vector is left untouched (returns 0.0) — callers treat that as a
/// Lanczos breakdown signal.
pub fn normalize(x: &mut [f32]) -> f64 {
    let n = norm2(x);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        scale(inv, x);
    }
    n
}

/// Dot product between an f32 working vector and a typed storage vector
/// (the quantized Lanczos basis): the same 4-lane f64 accumulation as
/// [`dot`], dequantizing each stored word at the multiplier input — the
/// paper's "float where required" rule for dots and norms (§IV). For
/// `V = f32` this is exactly [`dot`].
pub fn dot_q<V: crate::fixed::Dataword>(a: &[f32], b: &[V]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (a4, b4) = (&a[4 * i..4 * i + 4], &b[4 * i..4 * i + 4]);
        acc[0] += a4[0] as f64 * b4[0].to_f32() as f64;
        acc[1] += a4[1] as f64 * b4[1].to_f32() as f64;
        acc[2] += a4[2] as f64 * b4[2].to_f32() as f64;
        acc[3] += a4[3] as f64 * b4[3].to_f32() as f64;
    }
    let mut tail = 0.0f64;
    for i in 4 * chunks..a.len() {
        tail += a[i] as f64 * b[i].to_f32() as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// `y += alpha * x` where `x` is a typed storage vector, dequantized on
/// the fly. For `V = f32` this is exactly [`axpy`].
pub fn axpy_q<V: crate::fixed::Dataword>(alpha: f32, x: &[V], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi.to_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.25).collect();
        let b: Vec<f32> = (0..103).map(|i| 1.0 - (i as f32) * 0.01).collect();
        let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn dot_f64_accumulation_is_stable() {
        // 1e7 values of 1e-1: f32 accumulation would lose digits; f64 keeps
        // them (relative error < 1e-9).
        let a = vec![0.1f32; 1_000_000];
        let d = dot(&a, &vec![1.0f32; 1_000_000]);
        let expect = 0.1f32 as f64 * 1_000_000.0;
        assert!((d - expect).abs() / expect < 1e-9, "d={d}");
    }

    #[test]
    fn axpy_updates_in_place() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpy_dot_matches_unfused_bitwise() {
        let x: Vec<f32> = (0..103).map(|i| ((i as f32) * 0.11).sin()).collect();
        let z: Vec<f32> = (0..103).map(|i| ((i as f32) * 0.07).cos()).collect();
        let y0: Vec<f32> = (0..103).map(|i| ((i as f32) * 0.05).tan() * 0.3).collect();
        // Unfused reference: axpy then dot.
        let mut y_ref = y0.clone();
        axpy(-0.37, &x, &mut y_ref);
        let d_ref = dot(&y_ref, &z);
        // Fused single pass.
        let mut y = y0.clone();
        let d = axpy_dot(-0.37, &x, &mut y, &z);
        assert_eq!(y, y_ref);
        assert_eq!(d.to_bits(), d_ref.to_bits());
    }

    #[test]
    fn axpy_norm2_matches_unfused_bitwise() {
        let x: Vec<f32> = (0..101).map(|i| ((i as f32) * 0.13).sin()).collect();
        let y0: Vec<f32> = (0..101).map(|i| ((i as f32) * 0.09).cos() * 0.7).collect();
        let mut y_ref = y0.clone();
        axpy(0.21, &x, &mut y_ref);
        let n_ref = dot(&y_ref, &y_ref);
        let mut y = y0.clone();
        let n = axpy_norm2(0.21, &x, &mut y);
        assert_eq!(y, y_ref);
        assert_eq!(n.to_bits(), n_ref.to_bits());
    }

    #[test]
    fn scale_quantize_into_mirrors_stored_words() {
        use crate::fixed::{Dataword, Q1_15};
        let w: Vec<f32> = (0..33).map(|i| ((i as f32) * 0.17).sin() * 2.0).collect();
        // f32: identity round-trip, v = w * alpha exactly.
        let mut v = vec![0.0f32; 33];
        let mut row = vec![0.0f32; 33];
        scale_quantize_into::<f32>(0.5, &w, &mut v, &mut row);
        for i in 0..33 {
            assert_eq!(v[i], w[i] * 0.5);
            assert_eq!(row[i], v[i]);
        }
        // Q1.15: v must hold exactly the dequantized stored word.
        let mut vq = vec![0.0f32; 33];
        let mut rowq = vec![Q1_15::default(); 33];
        scale_quantize_into::<Q1_15>(0.5, &w, &mut vq, &mut rowq);
        for i in 0..33 {
            assert_eq!(vq[i], rowq[i].to_f32());
            assert!(((vq[i] - w[i] * 0.5).abs() as f64) <= <Q1_15 as Dataword>::ulp());
        }
    }

    #[test]
    fn normalize_unit_norm() {
        let mut x = vec![3.0f32, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-9);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        assert!((x[0] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_vector_signals_breakdown() {
        let mut x = vec![0.0f32; 8];
        assert_eq!(normalize(&mut x), 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn typed_kernels_match_f32_kernels_exactly() {
        // For V = f32, dot_q/axpy_q must be bitwise-identical to dot/axpy
        // (same lane structure), so the f32 Lanczos path is unchanged.
        let a: Vec<f32> = (0..103).map(|i| ((i as f32) * 0.11).sin()).collect();
        let b: Vec<f32> = (0..103).map(|i| ((i as f32) * 0.07).cos()).collect();
        assert_eq!(dot(&a, &b).to_bits(), dot_q(&a, &b).to_bits());
        let mut y1 = a.clone();
        let mut y2 = a.clone();
        axpy(0.37, &b, &mut y1);
        axpy_q(0.37, &b, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn typed_kernels_dequantize_within_ulp() {
        use crate::fixed::{Dataword, Q1_15};
        let a: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.13).sin() * 0.8).collect();
        let q: Vec<Q1_15> = a.iter().map(|&x| Q1_15::from_f32(x)).collect();
        let exact = dot(&a, &a);
        let approx = dot_q(&a, &q);
        // 64 terms, |a| < 1: error bounded by 64 * ulp/2.
        assert!((exact - approx).abs() <= 64.0 * <Q1_15 as Dataword>::ulp(), "{exact} vs {approx}");
        let mut y = vec![0.0f32; 64];
        axpy_q(1.0, &q, &mut y);
        for (yi, ai) in y.iter().zip(&a) {
            assert!(((yi - ai).abs() as f64) <= <Q1_15 as Dataword>::ulp());
        }
    }

    #[test]
    #[should_panic]
    fn dot_length_mismatch_panics() {
        dot(&[1.0], &[1.0, 2.0]);
    }
}

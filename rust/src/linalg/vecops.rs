//! Dense vector kernels for the Lanczos loop (Algorithm 1, lines 5-10).
//!
//! These are the "remaining linear operations" of Figure 6(D); they run on
//! every Lanczos iteration over length-`n` vectors, so the hot-path variants
//! are written to autovectorize (chunked accumulators, no bounds checks in
//! the inner loop via exact-size slices).

/// Dot product with 4-lane accumulation (f32 in, f64 accumulators to keep
/// the reorthogonalization numerically trustworthy on multi-million-element
/// vectors).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (a4, b4) = (&a[4 * i..4 * i + 4], &b[4 * i..4 * i + 4]);
        acc[0] += a4[0] as f64 * b4[0] as f64;
        acc[1] += a4[1] as f64 * b4[1] as f64;
        acc[2] += a4[2] as f64 * b4[2] as f64;
        acc[3] += a4[3] as f64 * b4[3] as f64;
    }
    let mut tail = 0.0f64;
    for i in 4 * chunks..a.len() {
        tail += a[i] as f64 * b[i] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `w = a*x + b*y` writing into `w` (used for the three-term recurrence
/// `w' = w - alpha v_i - beta v_{i-1}` fused as two waxpby calls).
pub fn waxpby(a: f32, x: &[f32], b: f32, y: &[f32], w: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), w.len());
    for i in 0..w.len() {
        w[i] = a * x[i] + b * y[i];
    }
}

/// `x *= alpha`.
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x {
        *xi *= alpha;
    }
}

/// L2 norm (f64 accumulation).
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Normalize `x` to unit L2 norm; returns the pre-normalization norm.
/// A zero vector is left untouched (returns 0.0) — callers treat that as a
/// Lanczos breakdown signal.
pub fn normalize(x: &mut [f32]) -> f64 {
    let n = norm2(x);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        scale(inv, x);
    }
    n
}

/// Dot product between an f32 working vector and a typed storage vector
/// (the quantized Lanczos basis): the same 4-lane f64 accumulation as
/// [`dot`], dequantizing each stored word at the multiplier input — the
/// paper's "float where required" rule for dots and norms (§IV). For
/// `V = f32` this is exactly [`dot`].
pub fn dot_q<V: crate::fixed::Dataword>(a: &[f32], b: &[V]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (a4, b4) = (&a[4 * i..4 * i + 4], &b[4 * i..4 * i + 4]);
        acc[0] += a4[0] as f64 * b4[0].to_f32() as f64;
        acc[1] += a4[1] as f64 * b4[1].to_f32() as f64;
        acc[2] += a4[2] as f64 * b4[2].to_f32() as f64;
        acc[3] += a4[3] as f64 * b4[3].to_f32() as f64;
    }
    let mut tail = 0.0f64;
    for i in 4 * chunks..a.len() {
        tail += a[i] as f64 * b[i].to_f32() as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// `y += alpha * x` where `x` is a typed storage vector, dequantized on
/// the fly. For `V = f32` this is exactly [`axpy`].
pub fn axpy_q<V: crate::fixed::Dataword>(alpha: f32, x: &[V], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi.to_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.25).collect();
        let b: Vec<f32> = (0..103).map(|i| 1.0 - (i as f32) * 0.01).collect();
        let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn dot_f64_accumulation_is_stable() {
        // 1e7 values of 1e-1: f32 accumulation would lose digits; f64 keeps
        // them (relative error < 1e-9).
        let a = vec![0.1f32; 1_000_000];
        let d = dot(&a, &vec![1.0f32; 1_000_000]);
        let expect = 0.1f32 as f64 * 1_000_000.0;
        assert!((d - expect).abs() / expect < 1e-9, "d={d}");
    }

    #[test]
    fn axpy_and_waxpby() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);

        let mut w = vec![0.0f32; 3];
        waxpby(1.0, &x, -0.5, &y, &mut w);
        assert_eq!(w, vec![1.0 - 6.0, 2.0 - 12.0, 3.0 - 18.0]);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut x = vec![3.0f32, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-9);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        assert!((x[0] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn normalize_zero_vector_signals_breakdown() {
        let mut x = vec![0.0f32; 8];
        assert_eq!(normalize(&mut x), 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn typed_kernels_match_f32_kernels_exactly() {
        // For V = f32, dot_q/axpy_q must be bitwise-identical to dot/axpy
        // (same lane structure), so the f32 Lanczos path is unchanged.
        let a: Vec<f32> = (0..103).map(|i| ((i as f32) * 0.11).sin()).collect();
        let b: Vec<f32> = (0..103).map(|i| ((i as f32) * 0.07).cos()).collect();
        assert_eq!(dot(&a, &b).to_bits(), dot_q(&a, &b).to_bits());
        let mut y1 = a.clone();
        let mut y2 = a.clone();
        axpy(0.37, &b, &mut y1);
        axpy_q(0.37, &b, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn typed_kernels_dequantize_within_ulp() {
        use crate::fixed::{Dataword, Q1_15};
        let a: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.13).sin() * 0.8).collect();
        let q: Vec<Q1_15> = a.iter().map(|&x| Q1_15::from_f32(x)).collect();
        let exact = dot(&a, &a);
        let approx = dot_q(&a, &q);
        // 64 terms, |a| < 1: error bounded by 64 * ulp/2.
        assert!((exact - approx).abs() <= 64.0 * <Q1_15 as Dataword>::ulp(), "{exact} vs {approx}");
        let mut y = vec![0.0f32; 64];
        axpy_q(1.0, &q, &mut y);
        for (yi, ai) in y.iter().zip(&a) {
            assert!(((yi - ai).abs() as f64) <= <Q1_15 as Dataword>::ulp());
        }
    }

    #[test]
    #[should_panic]
    fn dot_length_mismatch_panics() {
        dot(&[1.0], &[1.0, 2.0]);
    }
}

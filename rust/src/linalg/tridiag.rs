//! Symmetric tridiagonal matrix `T` — the Lanczos output (Figure 3): `K`
//! diagonal values `alpha` and `K-1` off-diagonal values `beta`, i.e. the
//! `3K - 2` words the Lanczos Core ships to the Jacobi cores over PLRAM
//! (§IV-C).

use crate::linalg::DenseMatrix;

/// Symmetric tridiagonal matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Tridiagonal {
    /// Main diagonal (`alpha`), length K.
    pub alpha: Vec<f64>,
    /// Off diagonal (`beta`), length K-1.
    pub beta: Vec<f64>,
}

impl Tridiagonal {
    /// Construct; panics unless `beta.len() + 1 == alpha.len()`.
    pub fn new(alpha: Vec<f64>, beta: Vec<f64>) -> Self {
        assert_eq!(beta.len() + 1, alpha.len(), "beta must be one shorter than alpha");
        Self { alpha, beta }
    }

    /// Dimension K.
    pub fn k(&self) -> usize {
        self.alpha.len()
    }

    /// Number of device words (`3K - 2`) transferred to the Jacobi cores.
    pub fn device_words(&self) -> usize {
        3 * self.k() - 2
    }

    /// Densify.
    pub fn to_dense(&self) -> DenseMatrix {
        let k = self.k();
        let mut m = DenseMatrix::zeros(k, k);
        for i in 0..k {
            m[(i, i)] = self.alpha[i];
            if i + 1 < k {
                m[(i, i + 1)] = self.beta[i];
                m[(i + 1, i)] = self.beta[i];
            }
        }
        m
    }

    /// `y = T x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let k = self.k();
        assert_eq!(x.len(), k);
        let mut y = vec![0.0; k];
        for i in 0..k {
            y[i] = self.alpha[i] * x[i];
            if i > 0 {
                y[i] += self.beta[i - 1] * x[i - 1];
            }
            if i + 1 < k {
                y[i] += self.beta[i] * x[i + 1];
            }
        }
        y
    }

    /// Characteristic-polynomial sign count (Sturm sequence): number of
    /// eigenvalues strictly less than `x`. Used by tests to verify the
    /// Jacobi eigenvalues without an external eigensolver.
    pub fn eigenvalues_below(&self, x: f64) -> usize {
        let k = self.k();
        let mut count = 0usize;
        let mut d = self.alpha[0] - x;
        if d < 0.0 {
            count += 1;
        }
        for i in 1..k {
            let b2 = self.beta[i - 1] * self.beta[i - 1];
            // Guard against division by ~0 (shift slightly, standard trick).
            let denom = if d.abs() < 1e-300 { 1e-300_f64.copysign(d) } else { d };
            d = self.alpha[i] - x - b2 / denom;
            if d < 0.0 {
                count += 1;
            }
        }
        count
    }

    /// The `j`-th smallest eigenvalue (0-based), located by bisection over
    /// the Sturm count — `O(k)` per probe, ~60 probes. Used by the
    /// adaptive Lanczos stopping rule, where the tridiagonal is tiny and
    /// a full eigendecomposition per iteration would be wasteful.
    pub fn kth_smallest_eigenvalue(&self, j: usize) -> f64 {
        assert!(j < self.k(), "eigenvalue index {j} out of range (k = {})", self.k());
        let (mut lo, mut hi) = self.gershgorin();
        // Widen so the strict `< x` count is j at lo and k at hi.
        let pad = 1e-12 + 1e-12 * lo.abs().max(hi.abs());
        lo -= pad;
        hi += pad;
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.eigenvalues_below(mid) > j {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// The `k` largest-magnitude eigenvalues, in decreasing `|lambda|`
    /// order (the Top-K convention): candidates are the `k` smallest and
    /// `k` largest algebraic eigenvalues, merged by magnitude.
    pub fn top_k_by_magnitude(&self, k: usize) -> Vec<f64> {
        let m = self.k();
        let k = k.min(m);
        // Candidate *indices* (not values — equal values from a multiple
        // eigenvalue must each keep their slot): the k smallest and k
        // largest, deduplicated where the ranges overlap.
        let mut idx: Vec<usize> = (0..k).chain(m - k..m).collect();
        idx.sort_unstable();
        idx.dedup();
        let mut cand: Vec<f64> = idx.into_iter().map(|j| self.kth_smallest_eigenvalue(j)).collect();
        cand.sort_by(|a, b| b.abs().partial_cmp(&a.abs()).unwrap());
        cand.truncate(k);
        cand
    }

    /// Gershgorin bound: all eigenvalues lie in `[lo, hi]`.
    pub fn gershgorin(&self) -> (f64, f64) {
        let k = self.k();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..k {
            let mut r = 0.0;
            if i > 0 {
                r += self.beta[i - 1].abs();
            }
            if i + 1 < k {
                r += self.beta[i].abs();
            }
            lo = lo.min(self.alpha[i] - r);
            hi = hi.max(self.alpha[i] + r);
        }
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Tridiagonal {
        Tridiagonal::new(vec![2.0, 2.0, 2.0], vec![-1.0, -1.0])
    }

    #[test]
    fn dense_round_trip_matvec() {
        let t = sample();
        let d = t.to_dense();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(t.matvec(&x), d.matvec(&x));
    }

    #[test]
    fn device_words_formula() {
        assert_eq!(sample().device_words(), 7);
    }

    #[test]
    fn sturm_counts_known_spectrum() {
        // Eigenvalues of tridiag(-1, 2, -1) of size 3: 2 - sqrt(2), 2, 2 + sqrt(2).
        let t = sample();
        let s2 = std::f64::consts::SQRT_2;
        assert_eq!(t.eigenvalues_below(2.0 - s2 - 1e-9), 0);
        assert_eq!(t.eigenvalues_below(2.0 - s2 + 1e-9), 1);
        assert_eq!(t.eigenvalues_below(2.0 + 1e-9), 2);
        assert_eq!(t.eigenvalues_below(2.0 + s2 + 1e-9), 3);
    }

    #[test]
    fn bisection_finds_indexed_and_top_magnitude_eigenvalues() {
        // tridiag(-1, 2, -1) size 3: spectrum {2 - sqrt2, 2, 2 + sqrt2}.
        let t = sample();
        let s2 = std::f64::consts::SQRT_2;
        assert!((t.kth_smallest_eigenvalue(0) - (2.0 - s2)).abs() < 1e-9);
        assert!((t.kth_smallest_eigenvalue(1) - 2.0).abs() < 1e-9);
        assert!((t.kth_smallest_eigenvalue(2) - (2.0 + s2)).abs() < 1e-9);
        let top2 = t.top_k_by_magnitude(2);
        assert!((top2[0] - (2.0 + s2)).abs() < 1e-9);
        assert!((top2[1] - 2.0).abs() < 1e-9);
        // Magnitude ordering picks the negative end when it dominates.
        let t2 = Tridiagonal::new(vec![-5.0, 0.1, 3.0], vec![0.0, 0.0]);
        let top = t2.top_k_by_magnitude(2);
        assert!((top[0] - -5.0).abs() < 1e-9, "{top:?}");
        assert!((top[1] - 3.0).abs() < 1e-9, "{top:?}");
        // k clamps to the dimension; a repeated eigenvalue keeps its slots.
        let t3 = Tridiagonal::new(vec![1.0, 1.0], vec![0.0]);
        assert_eq!(t3.top_k_by_magnitude(5).len(), 2);
    }

    #[test]
    fn gershgorin_contains_spectrum() {
        let t = sample();
        let (lo, hi) = t.gershgorin();
        assert!(lo <= 2.0 - std::f64::consts::SQRT_2);
        assert!(hi >= 2.0 + std::f64::consts::SQRT_2);
    }

    #[test]
    #[should_panic(expected = "beta must be one shorter")]
    fn shape_mismatch_panics() {
        Tridiagonal::new(vec![1.0, 2.0], vec![0.5, 0.5]);
    }
}

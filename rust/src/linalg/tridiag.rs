//! Symmetric tridiagonal matrix `T` — the Lanczos output (Figure 3): `K`
//! diagonal values `alpha` and `K-1` off-diagonal values `beta`, i.e. the
//! `3K - 2` words the Lanczos Core ships to the Jacobi cores over PLRAM
//! (§IV-C).

use crate::linalg::DenseMatrix;

/// Symmetric tridiagonal matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Tridiagonal {
    /// Main diagonal (`alpha`), length K.
    pub alpha: Vec<f64>,
    /// Off diagonal (`beta`), length K-1.
    pub beta: Vec<f64>,
}

impl Tridiagonal {
    /// Construct; panics unless `beta.len() + 1 == alpha.len()`.
    pub fn new(alpha: Vec<f64>, beta: Vec<f64>) -> Self {
        assert_eq!(beta.len() + 1, alpha.len(), "beta must be one shorter than alpha");
        Self { alpha, beta }
    }

    /// Dimension K.
    pub fn k(&self) -> usize {
        self.alpha.len()
    }

    /// Number of device words (`3K - 2`) transferred to the Jacobi cores.
    pub fn device_words(&self) -> usize {
        3 * self.k() - 2
    }

    /// Densify.
    pub fn to_dense(&self) -> DenseMatrix {
        let k = self.k();
        let mut m = DenseMatrix::zeros(k, k);
        for i in 0..k {
            m[(i, i)] = self.alpha[i];
            if i + 1 < k {
                m[(i, i + 1)] = self.beta[i];
                m[(i + 1, i)] = self.beta[i];
            }
        }
        m
    }

    /// `y = T x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let k = self.k();
        assert_eq!(x.len(), k);
        let mut y = vec![0.0; k];
        for i in 0..k {
            y[i] = self.alpha[i] * x[i];
            if i > 0 {
                y[i] += self.beta[i - 1] * x[i - 1];
            }
            if i + 1 < k {
                y[i] += self.beta[i] * x[i + 1];
            }
        }
        y
    }

    /// Characteristic-polynomial sign count (Sturm sequence): number of
    /// eigenvalues strictly less than `x`. Used by tests to verify the
    /// Jacobi eigenvalues without an external eigensolver.
    pub fn eigenvalues_below(&self, x: f64) -> usize {
        let k = self.k();
        let mut count = 0usize;
        let mut d = self.alpha[0] - x;
        if d < 0.0 {
            count += 1;
        }
        for i in 1..k {
            let b2 = self.beta[i - 1] * self.beta[i - 1];
            // Guard against division by ~0 (shift slightly, standard trick).
            let denom = if d.abs() < 1e-300 { 1e-300_f64.copysign(d) } else { d };
            d = self.alpha[i] - x - b2 / denom;
            if d < 0.0 {
                count += 1;
            }
        }
        count
    }

    /// The `j`-th smallest eigenvalue (0-based), located by bisection over
    /// the Sturm count — `O(k)` per probe, ~60 probes. Used by the
    /// adaptive Lanczos stopping rule, where the tridiagonal is tiny and
    /// a full eigendecomposition per iteration would be wasteful.
    pub fn kth_smallest_eigenvalue(&self, j: usize) -> f64 {
        assert!(j < self.k(), "eigenvalue index {j} out of range (k = {})", self.k());
        let (mut lo, mut hi) = self.gershgorin();
        // Widen so the strict `< x` count is j at lo and k at hi.
        let pad = 1e-12 + 1e-12 * lo.abs().max(hi.abs());
        lo -= pad;
        hi += pad;
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.eigenvalues_below(mid) > j {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// The `k` largest-magnitude eigenvalues, in decreasing `|lambda|`
    /// order (the Top-K convention): candidates are the `k` smallest and
    /// `k` largest algebraic eigenvalues, merged by magnitude.
    pub fn top_k_by_magnitude(&self, k: usize) -> Vec<f64> {
        let m = self.k();
        let k = k.min(m);
        // Candidate *indices* (not values — equal values from a multiple
        // eigenvalue must each keep their slot): the k smallest and k
        // largest, deduplicated where the ranges overlap.
        let mut idx: Vec<usize> = (0..k).chain(m - k..m).collect();
        idx.sort_unstable();
        idx.dedup();
        let mut cand: Vec<f64> = idx.into_iter().map(|j| self.kth_smallest_eigenvalue(j)).collect();
        cand.sort_by(|a, b| b.abs().partial_cmp(&a.abs()).unwrap());
        cand.truncate(k);
        cand
    }

    /// Gershgorin bound: all eigenvalues lie in `[lo, hi]`.
    pub fn gershgorin(&self) -> (f64, f64) {
        let k = self.k();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..k {
            let mut r = 0.0;
            if i > 0 {
                r += self.beta[i - 1].abs();
            }
            if i + 1 < k {
                r += self.beta[i].abs();
            }
            lo = lo.min(self.alpha[i] - r);
            hi = hi.max(self.alpha[i] + r);
        }
        (lo, hi)
    }
}

/// Symmetric **band** matrix with `bw` sub/super-diagonals — the block
/// Lanczos projection `T`. A block recurrence with block size `b` produces
/// `b x b` symmetric diagonal blocks `A_j` and upper-triangular
/// off-diagonal blocks `B_{j+1}`, which interleave into a band of width
/// exactly `b`; at `bw == 1` this is the classic [`Tridiagonal`].
///
/// Storage is the upper diagonals only (the matrix is symmetric by
/// construction): `diags[d][j] = T[j][j + d]` for `d in 0..=bw`.
///
/// Top-K Ritz extraction mirrors [`Tridiagonal`]: a Sturm-style inertia
/// count ([`BandTridiagonal::eigenvalues_below`], banded unpivoted
/// `L D L^T` with the same tiny-pivot guard), bisection
/// ([`BandTridiagonal::kth_smallest_eigenvalue`]) inside a padded
/// Gershgorin interval, and the magnitude merge
/// ([`BandTridiagonal::top_k_by_magnitude`]). Eigen*vectors* of the tiny
/// band go through the dense [`crate::linalg::qr_algorithm_symmetric`]
/// on [`BandTridiagonal::to_dense`] — `T` is at most a few dozen rows, so
/// a direct band bulge-chase would buy nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct BandTridiagonal {
    dim: usize,
    bw: usize,
    /// `diags[d][j] = T[j][j + d]`; `diags[0]` is the main diagonal.
    diags: Vec<Vec<f64>>,
}

impl BandTridiagonal {
    /// Zero matrix of the given dimension and bandwidth (`bw >= 1`).
    pub fn new(dim: usize, bw: usize) -> Self {
        assert!(dim >= 1, "band matrix must be non-empty");
        assert!(bw >= 1, "bandwidth must be >= 1");
        let bw = bw.min(dim.saturating_sub(1)).max(1);
        let diags = (0..=bw).map(|d| vec![0.0; dim.saturating_sub(d)]).collect();
        Self { dim, bw, diags }
    }

    /// Dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of sub/super-diagonals.
    pub fn bandwidth(&self) -> usize {
        self.bw
    }

    /// Entry `(i, j)`; zero outside the band.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
        let d = hi - lo;
        if d > self.bw {
            0.0
        } else {
            self.diags[d][lo]
        }
    }

    /// Set entries `(i, j)` and `(j, i)` (symmetric write). Panics outside
    /// the band.
    pub fn set_sym(&mut self, i: usize, j: usize, v: f64) {
        let (lo, hi) = if i <= j { (i, j) } else { (j, i) };
        let d = hi - lo;
        assert!(d <= self.bw, "({i}, {j}) outside bandwidth {}", self.bw);
        self.diags[d][lo] = v;
    }

    /// Densify (symmetric).
    pub fn to_dense(&self) -> DenseMatrix {
        let n = self.dim;
        let mut m = DenseMatrix::zeros(n, n);
        for d in 0..=self.bw {
            for j in 0..n.saturating_sub(d) {
                m[(j, j + d)] = self.diags[d][j];
                m[(j + d, j)] = self.diags[d][j];
            }
        }
        m
    }

    /// Exact conversion to [`Tridiagonal`] when the bandwidth is 1.
    pub fn to_tridiagonal(&self) -> Option<Tridiagonal> {
        if self.bw != 1 {
            return None;
        }
        Some(Tridiagonal::new(self.diags[0].clone(), self.diags[1].clone()))
    }

    /// Inertia count (Sylvester): eigenvalues strictly below `x`, via an
    /// unpivoted banded `L D L^T` of `T - xI` counting negative pivots —
    /// the band generalization of the tridiagonal Sturm recurrence, with
    /// the same `1e-300` pivot guard.
    pub fn eigenvalues_below(&self, x: f64) -> usize {
        let n = self.dim;
        let w = self.bw;
        // Working lower-band copy of (T - xI): work[r][j] = T[j+r][j].
        let mut work: Vec<Vec<f64>> = (0..=w)
            .map(|r| {
                (0..n.saturating_sub(r))
                    .map(|j| self.diags[r][j] - if r == 0 { x } else { 0.0 })
                    .collect()
            })
            .collect();
        let mut count = 0usize;
        for j in 0..n {
            let d = work[0][j];
            if d < 0.0 {
                count += 1;
            }
            let denom = if d.abs() < 1e-300 { 1e-300_f64.copysign(d) } else { d };
            // Eliminate column j from the trailing band: for i = j+r and
            // k = j+s with s >= r, A[i][k] -= A[i][j] A[k][j] / d.
            let reach = w.min(n - 1 - j);
            for r in 1..=reach {
                let lrj = work[r][j] / denom;
                for s in r..=reach {
                    work[s - r][j + r] -= lrj * work[s][j];
                }
            }
        }
        count
    }

    /// The `j`-th smallest eigenvalue (0-based) by bisection over the
    /// inertia count — the band twin of
    /// [`Tridiagonal::kth_smallest_eigenvalue`].
    pub fn kth_smallest_eigenvalue(&self, j: usize) -> f64 {
        assert!(j < self.dim, "eigenvalue index {j} out of range (dim = {})", self.dim);
        let (mut lo, mut hi) = self.gershgorin();
        let pad = 1e-12 + 1e-12 * lo.abs().max(hi.abs());
        lo -= pad;
        hi += pad;
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.eigenvalues_below(mid) > j {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// The `k` largest-magnitude eigenvalues in decreasing `|lambda|`
    /// order — the Top-K convention, same candidate merge as
    /// [`Tridiagonal::top_k_by_magnitude`].
    pub fn top_k_by_magnitude(&self, k: usize) -> Vec<f64> {
        let m = self.dim;
        let k = k.min(m);
        let mut idx: Vec<usize> = (0..k).chain(m - k..m).collect();
        idx.sort_unstable();
        idx.dedup();
        let mut cand: Vec<f64> = idx.into_iter().map(|j| self.kth_smallest_eigenvalue(j)).collect();
        cand.sort_by(|a, b| b.abs().partial_cmp(&a.abs()).unwrap());
        cand.truncate(k);
        cand
    }

    /// Gershgorin bound over the band rows.
    pub fn gershgorin(&self) -> (f64, f64) {
        let n = self.dim;
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..n {
            let mut r = 0.0;
            for d in 1..=self.bw {
                if i >= d {
                    r += self.diags[d][i - d].abs();
                }
                if i + d < n {
                    r += self.diags[d][i].abs();
                }
            }
            let a = self.diags[0][i];
            lo = lo.min(a - r);
            hi = hi.max(a + r);
        }
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Tridiagonal {
        Tridiagonal::new(vec![2.0, 2.0, 2.0], vec![-1.0, -1.0])
    }

    #[test]
    fn dense_round_trip_matvec() {
        let t = sample();
        let d = t.to_dense();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(t.matvec(&x), d.matvec(&x));
    }

    #[test]
    fn device_words_formula() {
        assert_eq!(sample().device_words(), 7);
    }

    #[test]
    fn sturm_counts_known_spectrum() {
        // Eigenvalues of tridiag(-1, 2, -1) of size 3: 2 - sqrt(2), 2, 2 + sqrt(2).
        let t = sample();
        let s2 = std::f64::consts::SQRT_2;
        assert_eq!(t.eigenvalues_below(2.0 - s2 - 1e-9), 0);
        assert_eq!(t.eigenvalues_below(2.0 - s2 + 1e-9), 1);
        assert_eq!(t.eigenvalues_below(2.0 + 1e-9), 2);
        assert_eq!(t.eigenvalues_below(2.0 + s2 + 1e-9), 3);
    }

    #[test]
    fn bisection_finds_indexed_and_top_magnitude_eigenvalues() {
        // tridiag(-1, 2, -1) size 3: spectrum {2 - sqrt2, 2, 2 + sqrt2}.
        let t = sample();
        let s2 = std::f64::consts::SQRT_2;
        assert!((t.kth_smallest_eigenvalue(0) - (2.0 - s2)).abs() < 1e-9);
        assert!((t.kth_smallest_eigenvalue(1) - 2.0).abs() < 1e-9);
        assert!((t.kth_smallest_eigenvalue(2) - (2.0 + s2)).abs() < 1e-9);
        let top2 = t.top_k_by_magnitude(2);
        assert!((top2[0] - (2.0 + s2)).abs() < 1e-9);
        assert!((top2[1] - 2.0).abs() < 1e-9);
        // Magnitude ordering picks the negative end when it dominates.
        let t2 = Tridiagonal::new(vec![-5.0, 0.1, 3.0], vec![0.0, 0.0]);
        let top = t2.top_k_by_magnitude(2);
        assert!((top[0] - -5.0).abs() < 1e-9, "{top:?}");
        assert!((top[1] - 3.0).abs() < 1e-9, "{top:?}");
        // k clamps to the dimension; a repeated eigenvalue keeps its slots.
        let t3 = Tridiagonal::new(vec![1.0, 1.0], vec![0.0]);
        assert_eq!(t3.top_k_by_magnitude(5).len(), 2);
    }

    #[test]
    fn gershgorin_contains_spectrum() {
        let t = sample();
        let (lo, hi) = t.gershgorin();
        assert!(lo <= 2.0 - std::f64::consts::SQRT_2);
        assert!(hi >= 2.0 + std::f64::consts::SQRT_2);
    }

    #[test]
    #[should_panic(expected = "beta must be one shorter")]
    fn shape_mismatch_panics() {
        Tridiagonal::new(vec![1.0, 2.0], vec![0.5, 0.5]);
    }

    #[test]
    fn band_bw1_matches_tridiagonal_exactly() {
        let t = sample();
        let mut b = BandTridiagonal::new(3, 1);
        for i in 0..3 {
            b.set_sym(i, i, t.alpha[i]);
            if i + 1 < 3 {
                b.set_sym(i, i + 1, t.beta[i]);
            }
        }
        assert_eq!(b.to_tridiagonal().unwrap(), t);
        for probe in [0.1, 0.5859, 2.0001, 3.5] {
            assert_eq!(b.eigenvalues_below(probe), t.eigenvalues_below(probe), "probe {probe}");
        }
        for j in 0..3 {
            assert!((b.kth_smallest_eigenvalue(j) - t.kth_smallest_eigenvalue(j)).abs() < 1e-9);
        }
        assert_eq!(b.gershgorin(), t.gershgorin());
        let (bt, tt) = (b.top_k_by_magnitude(2), t.top_k_by_magnitude(2));
        for (x, y) in bt.iter().zip(&tt) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    /// A deterministic band fixture with bandwidth 2.
    fn band_sample() -> BandTridiagonal {
        let mut b = BandTridiagonal::new(6, 2);
        for i in 0..6 {
            b.set_sym(i, i, 1.0 + 0.3 * i as f64);
            if i + 1 < 6 {
                b.set_sym(i, i + 1, -0.4 + 0.05 * i as f64);
            }
            if i + 2 < 6 {
                b.set_sym(i, i + 2, 0.2 - 0.03 * i as f64);
            }
        }
        b
    }

    #[test]
    fn band_inertia_matches_dense_reference() {
        let b = band_sample();
        let (vals, _) = crate::linalg::qr_algorithm_symmetric(&b.to_dense(), 1e-13, 500);
        let mut sorted = vals.clone();
        sorted.sort_by(|a, c| a.partial_cmp(c).unwrap());
        // Sturm count agrees with the dense spectrum at probes straddling
        // every eigenvalue.
        for (j, lam) in sorted.iter().enumerate() {
            assert_eq!(b.eigenvalues_below(lam - 1e-7), j, "below eig {j}");
            assert_eq!(b.eigenvalues_below(lam + 1e-7), j + 1, "above eig {j}");
        }
        // Bisection recovers each indexed eigenvalue.
        for (j, lam) in sorted.iter().enumerate() {
            assert!((b.kth_smallest_eigenvalue(j) - lam).abs() < 1e-8, "eig {j}");
        }
        // Magnitude merge matches the dense solver's |lambda| ordering.
        let top = b.top_k_by_magnitude(3);
        for (i, x) in top.iter().enumerate() {
            assert!((x - vals[i]).abs() < 1e-8, "top[{i}]: {x} vs {}", vals[i]);
        }
        // Gershgorin contains the spectrum.
        let (lo, hi) = b.gershgorin();
        assert!(lo <= sorted[0] && hi >= sorted[5]);
    }

    #[test]
    fn band_accessors_and_bounds() {
        let b = band_sample();
        assert_eq!(b.dim(), 6);
        assert_eq!(b.bandwidth(), 2);
        assert_eq!(b.get(0, 3), 0.0, "outside band reads zero");
        assert_eq!(b.get(2, 1), b.get(1, 2), "symmetric access");
        assert!(b.to_tridiagonal().is_none(), "bw 2 is not tridiagonal");
        // Repeated eigenvalue slots: top_k clamps to dim.
        assert_eq!(b.top_k_by_magnitude(10).len(), 6);
    }
}

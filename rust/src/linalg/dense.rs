//! Small dense matrix (row-major, f64) used for the K x K stage: the Jacobi
//! input/outputs, IRAM's projected problem, and verification math. K is at
//! most a few dozen in this system, so clarity beats blocking here.

use crate::linalg::vecops;

/// Row-major dense matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    /// Rows.
    pub nrows: usize,
    /// Columns.
    pub ncols: usize,
    /// Row-major data, `len == nrows * ncols`.
    pub data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_rows(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols);
        Self { nrows, ncols, data }
    }

    /// Column `j` as a vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.nrows).map(|i| self[(i, j)]).collect()
    }

    /// Matrix product `self * rhs`.
    pub fn matmul(&self, rhs: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.ncols, rhs.nrows);
        let mut out = DenseMatrix::zeros(self.nrows, rhs.ncols);
        for i in 0..self.nrows {
            for k in 0..self.ncols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..rhs.ncols {
                    out[(i, j)] += a * rhs[(k, j)];
                }
            }
        }
        out
    }

    /// `self * x` for a dense vector.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for i in 0..self.nrows {
            let row = &self.data[i * self.ncols..(i + 1) * self.ncols];
            y[i] = row.iter().zip(x).map(|(&a, &b)| a * b).sum();
        }
        y
    }

    /// Transpose.
    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.ncols, self.nrows);
        for i in 0..self.nrows {
            for j in 0..self.ncols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&v| v * v).sum::<f64>().sqrt()
    }

    /// Largest absolute off-diagonal entry (Jacobi convergence criterion).
    pub fn max_offdiag(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.nrows {
            for j in 0..self.ncols {
                if i != j {
                    m = m.max(self[(i, j)].abs());
                }
            }
        }
        m
    }

    /// Is `self` symmetric within `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        for i in 0..self.nrows {
            for j in (i + 1)..self.ncols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Max |self - other| entry; panics on shape mismatch.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Orthonormality defect `max |Q^T Q - I|` of the columns.
    pub fn orthonormality_defect(&self) -> f64 {
        let qtq = self.transpose().matmul(self);
        qtq.max_abs_diff(&DenseMatrix::identity(self.ncols))
    }
}

impl std::ops::Index<(usize, usize)> for DenseMatrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.ncols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DenseMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.ncols + j]
    }
}

/// Mean pairwise angle (degrees) between the columns of `q` — the paper's
/// orthogonality metric for Fig 11 (ideal: 90 degrees).
pub fn mean_pairwise_angle_deg(cols: &[Vec<f32>]) -> f64 {
    let k = cols.len();
    if k < 2 {
        return 90.0;
    }
    let mut sum = 0.0;
    let mut cnt = 0usize;
    for i in 0..k {
        for j in (i + 1)..k {
            let c = vecops::dot(&cols[i], &cols[j])
                / (vecops::norm2(&cols[i]) * vecops::norm2(&cols[j])).max(1e-300);
            let c = c.clamp(-1.0, 1.0);
            sum += c.acos().to_degrees();
            cnt += 1;
        }
    }
    sum / cnt as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_hand_checked() {
        let a = DenseMatrix::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = DenseMatrix::from_rows(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = DenseMatrix::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = DenseMatrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = DenseMatrix::from_rows(3, 3, (1..=9).map(|v| v as f64).collect());
        let x = vec![1.0, 0.5, -1.0];
        let bx = DenseMatrix::from_rows(3, 1, x.clone());
        let via_mm = a.matmul(&bx);
        assert_eq!(a.matvec(&x), via_mm.data);
    }

    #[test]
    fn transpose_and_symmetry() {
        let a = DenseMatrix::from_rows(2, 2, vec![1.0, 2.0, 2.0, 5.0]);
        assert!(a.is_symmetric(0.0));
        assert_eq!(a.transpose(), a);
        let b = DenseMatrix::from_rows(2, 2, vec![1.0, 2.0, 3.0, 5.0]);
        assert!(!b.is_symmetric(0.5));
    }

    #[test]
    fn offdiag_and_defect() {
        let a = DenseMatrix::from_rows(2, 2, vec![5.0, 0.25, -0.5, 7.0]);
        assert_eq!(a.max_offdiag(), 0.5);
        assert!(DenseMatrix::identity(4).orthonormality_defect() < 1e-15);
    }

    #[test]
    fn mean_angle_of_orthonormal_basis_is_90() {
        let cols = vec![vec![1.0f32, 0.0, 0.0], vec![0.0, 1.0, 0.0], vec![0.0, 0.0, 1.0]];
        assert!((mean_pairwise_angle_deg(&cols) - 90.0).abs() < 1e-9);
        let slanted = vec![vec![1.0f32, 0.0], vec![1.0, 1.0]];
        assert!((mean_pairwise_angle_deg(&slanted) - 45.0).abs() < 1e-4);
    }
}

//! Dense linear-algebra substrate: vector kernels, a small dense matrix
//! type, tridiagonal utilities, and QR — everything the Lanczos loop, the
//! IRAM baseline, and the verification paths need, with no external BLAS.

mod dense;
mod qr;
mod tridiag;
mod vecops;

pub use dense::{mean_pairwise_angle_deg, DenseMatrix};
pub use qr::{panel_qr_mgs, qr_decompose, qr_algorithm_symmetric};
pub use tridiag::{BandTridiagonal, Tridiagonal};
pub use vecops::{axpy, axpy_dot, axpy_norm2, axpy_q, dot, dot_q, norm2, normalize, scale, scale_quantize_into};

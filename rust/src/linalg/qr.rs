//! QR decomposition (Householder) and the shifted QR eigenvalue iteration
//! for small symmetric matrices.
//!
//! Two consumers: the IRAM baseline (implicit restarts need QR of the
//! shifted projected matrix, exactly what ARPACK does), and tests that
//! verify the Jacobi systolic results against an independent method — the
//! paper cites QR as the approach "more common on CPU" (§IV-C).

use crate::linalg::DenseMatrix;

/// Tall-skinny panel QR via modified Gram-Schmidt — the intra-block
/// orthonormalization step of the block Lanczos recurrence.
///
/// `panel` holds `b` columns of length `n`, column-major (column `j` is
/// `panel[j*n..(j+1)*n]`). On return the leading `rank` columns are
/// orthonormal (Q) and `r` holds the `b x b` upper-triangular factor in
/// row-major order (`r[i*b + j]` = R\[i\]\[j\]), so `A = Q R` over the
/// full-rank prefix. Dots and norms accumulate in f64 through the
/// [`crate::linalg`] vector kernels; the panel itself stays f32 (the
/// working-precision mirror of the quantized basis).
///
/// Returns the numerical rank: the index of the first column whose
/// residual norm fell below `tol` after orthogonalization against the
/// previous columns, or `b` when the panel is full rank. A deficient
/// column means the block recurrence hit an invariant subspace (the block
/// analog of `beta -> 0` breakdown); trailing columns of `panel` and the
/// corresponding rows of `r` are left unspecified in that case.
///
/// The panel is at most `b x b` coefficients of O(b^2 n) flops — noise
/// next to the O(nnz) SpMV — so a simple column-serial MGS (numerically
/// the same variant the unfused reorthogonalization uses) is the right
/// tool; no Householder accumulation is needed for b this small.
pub fn panel_qr_mgs(panel: &mut [f32], n: usize, b: usize, r: &mut [f64], tol: f64) -> usize {
    assert_eq!(panel.len(), n * b, "panel must hold b columns of length n");
    assert!(r.len() >= b * b, "R buffer must hold b x b coefficients");
    r[..b * b].fill(0.0);
    for j in 0..b {
        let (done, rest) = panel.split_at_mut(j * n);
        let col = &mut rest[..n];
        // MGS: project out each previous column in sequence, recording the
        // coefficient against the *updated* residual.
        for i in 0..j {
            let qi = &done[i * n..(i + 1) * n];
            let p = crate::linalg::dot(col, qi);
            r[i * b + j] = p;
            crate::linalg::axpy(-(p as f32), qi, col);
        }
        let nrm = crate::linalg::norm2(col);
        if nrm < tol {
            return j;
        }
        r[j * b + j] = nrm;
        crate::linalg::scale((1.0 / nrm) as f32, col);
    }
    b
}

/// Householder QR: returns `(Q, R)` with `A = Q R`, `Q` orthogonal, `R`
/// upper triangular.
pub fn qr_decompose(a: &DenseMatrix) -> (DenseMatrix, DenseMatrix) {
    let (m, n) = (a.nrows, a.ncols);
    let mut r = a.clone();
    let mut q = DenseMatrix::identity(m);
    for k in 0..n.min(m.saturating_sub(1)) {
        // Householder vector for column k below the diagonal.
        let mut x_norm2 = 0.0;
        for i in k..m {
            x_norm2 += r[(i, k)] * r[(i, k)];
        }
        let x_norm = x_norm2.sqrt();
        if x_norm == 0.0 {
            continue;
        }
        let alpha = -x_norm * r[(k, k)].signum();
        let mut v = vec![0.0; m];
        v[k] = r[(k, k)] - alpha;
        for i in (k + 1)..m {
            v[i] = r[(i, k)];
        }
        let vtv: f64 = v.iter().map(|&x| x * x).sum();
        if vtv == 0.0 {
            continue;
        }
        // R <- (I - 2 v v^T / v^T v) R
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i] * r[(i, j)];
            }
            let f = 2.0 * dot / vtv;
            for i in k..m {
                r[(i, j)] -= f * v[i];
            }
        }
        // Q <- Q (I - 2 v v^T / v^T v)
        for i in 0..m {
            let mut dot = 0.0;
            for j in k..m {
                dot += q[(i, j)] * v[j];
            }
            let f = 2.0 * dot / vtv;
            for j in k..m {
                q[(i, j)] -= f * v[j];
            }
        }
    }
    (q, r)
}

/// Symmetric eigendecomposition via Householder tridiagonalization (tred2)
/// followed by the implicit-shift QL iteration (tql2) — the EISPACK/LAPACK
/// `dsyev` lineage, robust for any symmetric matrix. Returns
/// `(eigenvalues, eigenvectors)` with eigenvalues sorted by decreasing
/// magnitude and eigenvectors as the corresponding columns.
///
/// `tol`/`max_iter` bound the QL iteration per eigenvalue (30 is the
/// classic limit; `max_iter` caps it).
pub fn qr_algorithm_symmetric(a: &DenseMatrix, tol: f64, max_iter: usize) -> (Vec<f64>, DenseMatrix) {
    assert!(a.is_symmetric(1e-9), "QR eigensolver expects a symmetric matrix");
    let n = a.nrows;
    let mut v = a.clone(); // becomes the transformation accumulator
    let mut d = vec![0.0f64; n]; // diagonal
    let mut e = vec![0.0f64; n]; // off-diagonal

    // ---- tred2: Householder reduction to tridiagonal, accumulating Q in v.
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += v[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = v[(i, l)];
            } else {
                for k in 0..=l {
                    v[(i, k)] /= scale;
                    h += v[(i, k)] * v[(i, k)];
                }
                let mut f = v[(i, l)];
                let g = if f > 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                v[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    v[(j, i)] = v[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += v[(j, k)] * v[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += v[(k, j)] * v[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * v[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = v[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        v[(j, k)] -= f * e[k] + g * v[(i, k)];
                    }
                }
            }
        } else {
            e[i] = v[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += v[(i, k)] * v[(k, j)];
                }
                for k in 0..i {
                    v[(k, j)] -= g * v[(k, i)];
                }
            }
        }
        d[i] = v[(i, i)];
        v[(i, i)] = 1.0;
        for j in 0..i {
            v[(j, i)] = 0.0;
            v[(i, j)] = 0.0;
        }
    }

    // ---- tql2: implicit-shift QL on (d, e), accumulating rotations in v.
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    let iter_cap = max_iter.clamp(30, 1000);
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find a small off-diagonal to split at.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= tol.max(f64::EPSILON) * dd || e[m].abs() < 1e-300 {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > iter_cap {
                break; // accept current accuracy
            }
            // Form the implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let r = (g * g + 1.0).sqrt();
            let sign_r = if g >= 0.0 { r } else { -r };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                let r = (f * f + g * g).sqrt();
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                let gg = d[i + 1] - p;
                let rr = (d[i] - gg) * s + 2.0 * c * b;
                p = s * rr;
                d[i + 1] = gg + p;
                g = c * rr - b;
                // Accumulate the rotation into the eigenvector matrix.
                for k in 0..n {
                    f = v[(k, i + 1)];
                    v[(k, i + 1)] = s * v[(k, i)] + c * f;
                    v[(k, i)] = c * v[(k, i)] - s * f;
                }
            }
            if e[m] == 0.0 && m > l {
                // broke out of the inner loop with r == 0
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // ---- Sort by decreasing magnitude.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| d[j].abs().partial_cmp(&d[i].abs()).unwrap());
    let eigvals: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let mut eigvecs = DenseMatrix::zeros(n, n);
    for (newj, &oldj) in idx.iter().enumerate() {
        for i in 0..n {
            eigvecs[(i, newj)] = v[(i, oldj)];
        }
    }
    (eigvals, eigvecs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_sym(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.f64_range(-1.0, 1.0);
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn panel_qr_orthonormalizes_and_factors() {
        let (n, b) = (40usize, 3usize);
        let mut rng = crate::util::rng::Pcg64::new(5);
        let orig: Vec<f32> = (0..n * b).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
        let mut panel = orig.clone();
        let mut r = vec![0.0f64; b * b];
        let rank = panel_qr_mgs(&mut panel, n, b, &mut r, 1e-12);
        assert_eq!(rank, b);
        // Q columns orthonormal.
        for j in 0..b {
            let qj = &panel[j * n..(j + 1) * n];
            assert!((crate::linalg::norm2(qj) - 1.0).abs() < 1e-6, "col {j} not unit");
            for i in 0..j {
                let d = crate::linalg::dot(qj, &panel[i * n..(i + 1) * n]).abs();
                assert!(d < 1e-6, "cols {i},{j} dot {d}");
            }
        }
        // R upper triangular with positive diagonal, and A = Q R.
        for i in 0..b {
            assert!(r[i * b + i] > 0.0);
            for j in 0..i {
                assert_eq!(r[i * b + j], 0.0, "R not upper triangular at ({i},{j})");
            }
        }
        for j in 0..b {
            for row in 0..n {
                let mut acc = 0.0f64;
                for i in 0..=j {
                    acc += panel[i * n + row] as f64 * r[i * b + j];
                }
                assert!((acc - orig[j * n + row] as f64).abs() < 1e-5, "A != QR at ({row},{j})");
            }
        }
    }

    #[test]
    fn panel_qr_reports_rank_deficiency() {
        let (n, b) = (16usize, 3usize);
        let mut panel = vec![0.0f32; n * b];
        for i in 0..n {
            let x = (i as f32 * 0.37).sin();
            panel[i] = x; // col 0
            panel[n + i] = 2.0 * x; // col 1: linearly dependent
            panel[2 * n + i] = (i as f32 * 0.11).cos(); // col 2
        }
        let mut r = vec![0.0f64; b * b];
        let rank = panel_qr_mgs(&mut panel, n, b, &mut r, 1e-6);
        assert_eq!(rank, 1, "dependent column must stop the factorization");
    }

    #[test]
    fn qr_reconstructs_a() {
        let a = rand_sym(6, 3);
        let (q, r) = qr_decompose(&a);
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-10);
        assert!(q.orthonormality_defect() < 1e-10);
        for i in 0..6 {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-10, "R not upper triangular at ({i},{j})");
            }
        }
    }

    #[test]
    fn eigen_residuals_small() {
        let a = rand_sym(8, 7);
        let (vals, vecs) = qr_algorithm_symmetric(&a, 1e-12, 500);
        for k in 0..8 {
            let v = vecs.col(k);
            let av = a.matvec(&v);
            let res: f64 = av
                .iter()
                .zip(&v)
                .map(|(&avi, &vi)| (avi - vals[k] * vi).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(res < 1e-6, "residual {res} for eig {k} = {}", vals[k]);
        }
    }

    #[test]
    fn eigenvalues_sorted_by_magnitude() {
        let a = rand_sym(8, 11);
        let (vals, _) = qr_algorithm_symmetric(&a, 1e-12, 500);
        for w in vals.windows(2) {
            assert!(w[0].abs() >= w[1].abs() - 1e-12);
        }
    }

    #[test]
    fn diagonal_matrix_eigen_identity() {
        let mut a = DenseMatrix::zeros(4, 4);
        for (i, v) in [3.0, -7.0, 0.5, 1.0].iter().enumerate() {
            a[(i, i)] = *v;
        }
        let (vals, vecs) = qr_algorithm_symmetric(&a, 1e-14, 100);
        assert!((vals[0] - -7.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
        assert!(vecs.orthonormality_defect() < 1e-8);
    }

    #[test]
    fn trace_preserved() {
        let a = rand_sym(10, 23);
        let tr: f64 = (0..10).map(|i| a[(i, i)]).sum();
        let (vals, _) = qr_algorithm_symmetric(&a, 1e-12, 800);
        let sum: f64 = vals.iter().sum();
        assert!((tr - sum).abs() < 1e-8, "trace {tr} vs eig-sum {sum}");
    }
}

//! Multi-tenant eigensolver service — the data-center deployment shape the
//! paper motivates (§I: "applications on top of Top-K eigenproblem are
//! mostly encountered in data centers").
//!
//! A leader thread owns a FIFO job queue; worker threads (one per
//! configured solver replica, mirroring the paper's multiple Jacobi cores
//! per SLR) pull jobs, run the two-phase solver, and deliver results
//! through per-job channels. Shutdown is graceful: pending jobs drain
//! unless `abort` is requested.

use crate::coordinator::{SolveOptions, Solution, Solver};
use crate::sparse::CooMatrix;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A submitted eigenproblem.
pub struct Job {
    /// Client-assigned identifier.
    pub id: u64,
    /// The matrix to decompose.
    pub matrix: CooMatrix,
    /// Per-job solve options.
    pub opts: SolveOptions,
    reply: Sender<JobResult>,
}

/// Result delivered to the submitter.
#[derive(Debug)]
pub struct JobResult {
    /// Job identifier.
    pub id: u64,
    /// Solution or an error string (solver errors must not kill workers).
    pub outcome: Result<Solution, String>,
    /// Queue wait time in seconds.
    pub queued_s: f64,
}

struct Shared {
    queue: Mutex<VecDeque<(Job, std::time::Instant)>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// Handle returned by [`EigenService::submit`]; await with `recv`.
pub struct Ticket {
    rx: Receiver<JobResult>,
}

impl Ticket {
    /// Block until the job completes.
    pub fn wait(self) -> JobResult {
        self.rx.recv().expect("service dropped without reply")
    }
    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<JobResult> {
        self.rx.try_recv().ok()
    }
}

/// The service: leader queue + solver worker replicas.
pub struct EigenService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    completed: Arc<AtomicU64>,
}

impl EigenService {
    /// Start `replicas` solver workers.
    pub fn start(replicas: usize) -> Self {
        assert!(replicas >= 1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let completed = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::with_capacity(replicas);
        for w in 0..replicas {
            let shared = Arc::clone(&shared);
            let completed = Arc::clone(&completed);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("eigen-worker-{w}"))
                    .spawn(move || loop {
                        let item = {
                            let mut q = shared.queue.lock().unwrap();
                            loop {
                                if let Some(item) = q.pop_front() {
                                    break Some(item);
                                }
                                if shared.shutdown.load(Ordering::SeqCst) {
                                    break None;
                                }
                                q = shared.available.wait(q).unwrap();
                            }
                        };
                        let Some((job, enqueued)) = item else { break };
                        let queued_s = enqueued.elapsed().as_secs_f64();
                        // A panicking solve must not take the worker down.
                        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            Solver::new(job.opts.clone()).solve(&job.matrix)
                        }));
                        let outcome = match outcome {
                            Ok(Ok(sol)) => Ok(sol),
                            Ok(Err(e)) => Err(e.to_string()),
                            Err(_) => Err("solver panicked".to_string()),
                        };
                        completed.fetch_add(1, Ordering::SeqCst);
                        let _ = job.reply.send(JobResult { id: job.id, outcome, queued_s });
                    })
                    .expect("spawn worker"),
            );
        }
        Self { shared, workers, next_id: AtomicU64::new(1), completed }
    }

    /// Enqueue a job; returns a [`Ticket`] to await the result.
    pub fn submit(&self, matrix: CooMatrix, opts: SolveOptions) -> (u64, Ticket) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        let job = Job { id, matrix, opts, reply: tx };
        self.shared.queue.lock().unwrap().push_back((job, std::time::Instant::now()));
        self.shared.available.notify_one();
        (id, Ticket { rx })
    }

    /// Jobs finished so far.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::SeqCst)
    }

    /// Current queue depth.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Drain the queue and stop workers.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for EigenService {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs;

    #[test]
    fn serves_concurrent_jobs() {
        let svc = EigenService::start(3);
        let mut tickets = Vec::new();
        for seed in 0..6u64 {
            let m = graphs::mesh2d(12, 12, 0.9, 0.02, seed);
            let (id, t) = svc.submit(m, SolveOptions { k: 4, ..Default::default() });
            tickets.push((id, t));
        }
        for (id, t) in tickets {
            let r = t.wait();
            assert_eq!(r.id, id);
            let sol = r.outcome.expect("solve failed");
            assert_eq!(sol.k(), 4);
            assert!(r.queued_s >= 0.0);
        }
        assert_eq!(svc.completed(), 6);
        svc.shutdown();
    }

    #[test]
    fn bad_job_reports_error_without_killing_worker() {
        let svc = EigenService::start(1);
        // Non-square matrix -> error, not a dead worker.
        let bad = CooMatrix::new(4, 5);
        let (_, t1) = svc.submit(bad, SolveOptions::default());
        assert!(t1.wait().outcome.is_err());
        // Worker must still serve the next job.
        let good = graphs::mesh2d(8, 8, 0.9, 0.02, 1);
        let (_, t2) = svc.submit(good, SolveOptions { k: 2, ..Default::default() });
        assert!(t2.wait().outcome.is_ok());
        svc.shutdown();
    }

    #[test]
    fn shutdown_with_empty_queue_is_clean() {
        let svc = EigenService::start(2);
        assert_eq!(svc.queue_depth(), 0);
        svc.shutdown();
    }
}

//! Multi-tenant eigensolver service — the data-center deployment shape the
//! paper motivates (§I: "applications on top of Top-K eigenproblem are
//! mostly encountered in data centers").
//!
//! A leader thread owns a job queue; worker threads (one per configured
//! solver replica, mirroring the paper's multiple Jacobi cores per SLR)
//! pull jobs under a pluggable [`QueuePolicy`], run the two-phase solver,
//! and deliver results through per-job channels. Shutdown is graceful:
//! pending jobs drain unless `abort` is requested.
//!
//! ## Matrix-resident serving
//!
//! The primary serving path is **handle-based**: clients
//! [`EigenService::register`] a matrix once (content-hash deduplicated)
//! and submit jobs that carry a [`MatrixHandle`] instead of an owned
//! `CooMatrix`. Every worker replica then solves against the *same*
//! `Arc<PreparedMatrix>` from the shared [`MatrixRegistry`] — the O(nnz)
//! prepare runs exactly once per `(handle, precision, engine, geometry)`
//! key no matter how many jobs or workers touch it, and jobs cross the
//! queue as a few words, never as matrix bytes. Each worker keeps one
//! [`LanczosWorkspace`] for its whole lifetime, so steady-state handle
//! jobs are allocation-light and clone-free end to end.
//!
//! [`EigenService::submit`] / [`EigenService::submit_batch`] remain as the
//! one-shot owned-matrix paths (ad-hoc queries that will never repeat);
//! they consume the matrix into the job and use
//! [`Solver::prepare_owned`], so even the legacy path no longer clones
//! the COO.
//!
//! ## K-aware dispatch
//!
//! [`QueuePolicy`] is [`crate::coordinator::scheduler::Policy`] — the same
//! type the offline §IV-C core-farm model uses, now wired into the live
//! loop. Under [`QueuePolicy::KBatched`], a worker keeps serving jobs
//! whose Jacobi core class ([`core_for_k`]) matches the one it
//! last ran; when its class runs dry it switches to the class with the
//! largest estimated backlog (solve-time estimates come from
//! [`FpgaTimingModel`] at submit time), amortizing the expensive
//! partial-reconfiguration over the most work. [`ServiceStats::reconfigs`]
//! counts the switches; [`select_next`] is the pure dispatch rule, shared
//! by the worker loop, the tests, and the `ablation_scheduler` bench so
//! the deployed policy and the model cannot drift.
//!
//! ## Evolving matrices
//!
//! [`EigenService::submit_update`] queues a [`CooDelta`] against a
//! registered handle. Updates are **generation-fenced**: a per-handle
//! read/write lock lets any number of solves share the handle while an
//! update waits, then applies the splice + renormalization + generation
//! bump exclusively — no solve ever reads a torn matrix, and every
//! `Solution` carries `SolveMetrics.generation`. Stale engines refresh
//! lazily and incrementally on the next solve (see
//! [`MatrixRegistry::update`]).
//!
//! ## Validation and telemetry
//!
//! Bad jobs are rejected at **submit** time (`k >= 1 && k <= n`, square
//! matrix, known handle): the ticket immediately yields an error
//! [`JobResult`] and no worker ever sees the job. The service keeps
//! queue/latency counters ([`ServiceStats`]) so a deployment can watch
//! saturation: submitted/completed/failed totals, live queue depth,
//! cumulative and maximum queue wait, cumulative solve time, and core
//! reconfigurations.
//!
//! ## Streaming queries on the resident matrix
//!
//! Two non-eigen job types run on the same datapath — the high-QPS
//! workload the paper's data-center framing motivates (thousands of
//! cheap queries against few resident matrices):
//!
//! * [`EigenService::submit_query`] — streaming **Top-K SpMV**: a dense
//!   query vector against the resident sharded matrix; every CU shard
//!   keeps a bounded partial max-heap and the fork/join merge yields the
//!   global top-k `(row, score)` list ([`ShardedSpmv::top_k`]). Scores
//!   come back in the matrix's **original value scale** (the stored
//!   stream is Frobenius-normalized; the service rescales — an
//!   order-preserving positive factor, so ranking is untouched).
//! * [`EigenService::submit_ppr`] — reduced-precision **Personalized
//!   PageRank** power iteration with dangling-mass redistribution and
//!   L1-delta stopping ([`ShardedSpmv::ppr_with_colsums`]); the O(nnz)
//!   column-sum normalizer is cached per generation in the registry
//!   ([`MatrixRegistry::column_sums`]).
//!
//! Both are **generation-fenced** like solves (read side): a query
//! racing [`EigenService::submit_update`] observes some complete
//! generation, never a torn matrix, and every answer carries the
//! generation it ran against. Results are bitwise-deterministic for any
//! replica count. Queries run on the native sharded engine (`opts.engine`
//! is forced to [`Engine::Native`] at submit); like updates, they occupy
//! no Jacobi core class, so they never charge reconfigurations.
//!
//! ### Batched queries, early-exit bounds, warm restarts
//!
//! Three memory optimizations ride the same query datapath, all exact:
//!
//! * **Batched multi-query SpMM** — [`EigenService::submit_query_batch`]
//!   carries `b` dense vectors in one queue item, and the dispatch loop
//!   additionally *coalesces* compatible queued single queries (same
//!   handle and `k`, same engine geometry; up to
//!   [`ServiceConfig::batch_cap`]) into one batch at dequeue time. The
//!   sharded engine then streams every matrix shard **once per batch**
//!   instead of once per query ([`ShardedSpmv::top_k_batch`]), cutting
//!   matrix bytes moved per answered query by ~`b`x while staying
//!   bitwise-identical to `b` independent queries.
//! * **Per-shard early-exit bounds** — the registry caches per-row L1
//!   norms beside the PPR column sums ([`MatrixRegistry::row_bounds`]),
//!   and the engine uses the per-shard maxima as conservative score upper
//!   bounds to skip shards that provably cannot alter the current top-k
//!   ([`ShardedSpmv::top_k_with_bounds`]);
//!   [`ServiceStats::shards_skipped`] counts the shards never streamed.
//!   Bounds are evaluated in f64 with an f32-rounding inflation, so a
//!   skip never changes an answer bit.
//! * **PPR warm restarts** — converged PPR score vectors are cached per
//!   `(handle, precision, source, alpha)` and survive generation bumps
//!   whose relative perturbation stays within the registry's
//!   `warm_keep_tol`; the next identical walk seeds from the previous
//!   fixed point and converges in fewer matrix sweeps
//!   ([`MatrixRegistry::store_ppr_warm`]). The damped iteration's fixed
//!   point is unique, so a warm start changes the iteration count, never
//!   the limit.

use crate::coordinator::registry::{MatrixHandle, MatrixRegistry, RegistryConfig, UpdateReport};
use crate::coordinator::scheduler::{coalesce_window, core_for_k};
use crate::coordinator::{Engine, SolveOptions, Solution, Solver};
use crate::fpga::FpgaTimingModel;
use crate::lanczos::LanczosWorkspace;
use crate::sparse::{CooDelta, CooMatrix, PprOptions, PprResult, RowPartition, ShardedSpmv, TopKEntry};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;

/// The live queue policy: the offline scheduler model's type, deployed.
pub use crate::coordinator::scheduler::Policy as QueuePolicy;

/// A submitted eigenproblem (the one-shot owned-matrix path).
pub struct Job {
    /// Client-assigned identifier.
    pub id: u64,
    /// The matrix to decompose (consumed by the worker — never cloned).
    pub matrix: CooMatrix,
    /// Per-job solve options.
    pub opts: SolveOptions,
    reply: Sender<JobResult>,
}

/// A batch of same-matrix jobs differing only in K.
struct BatchJob {
    ids: Vec<u64>,
    matrix: CooMatrix,
    opts: SolveOptions,
    ks: Vec<usize>,
    replies: Vec<Sender<JobResult>>,
}

/// A matrix-resident job: carries a registry handle, not matrix bytes.
struct HandleJob {
    id: u64,
    handle: MatrixHandle,
    k: usize,
    opts: SolveOptions,
    reply: Sender<JobResult>,
}

/// A delta-update job against a registered handle.
struct UpdateJob {
    id: u64,
    handle: MatrixHandle,
    delta: CooDelta,
    reply: Sender<UpdateResult>,
}

/// A streaming Top-K SpMV query against a registered handle.
struct QueryJob {
    id: u64,
    handle: MatrixHandle,
    x: Vec<f32>,
    k: usize,
    opts: SolveOptions,
    reply: Sender<QueryResult>,
}

/// A batch of Top-K SpMV queries sharing one matrix sweep: same handle,
/// same `k`, same engine geometry — only the dense vectors differ. Built
/// by [`EigenService::submit_query_batch`], or assembled at dequeue time
/// when the dispatch loop coalesces compatible queued [`QueryJob`]s.
struct QueryBatchJob {
    ids: Vec<u64>,
    handle: MatrixHandle,
    xs: Vec<Vec<f32>>,
    k: usize,
    opts: SolveOptions,
    replies: Vec<Sender<QueryResult>>,
}

/// A Personalized PageRank job against a registered handle.
struct PprJob {
    id: u64,
    handle: MatrixHandle,
    ppr: PprOptions,
    opts: SolveOptions,
    reply: Sender<PprJobResult>,
}

enum QueueItem {
    Single(Job),
    Batch(BatchJob),
    Handle(HandleJob),
    Update(UpdateJob),
    Query(QueryJob),
    QueryBatch(QueryBatchJob),
    Ppr(PprJob),
}

/// One queued unit plus its dispatch metadata: the Jacobi core class it
/// needs and the timing-model estimate of its solve time.
struct QueueEntry {
    item: QueueItem,
    enqueued: std::time::Instant,
    core: usize,
    est_s: f64,
}

/// Result delivered to the submitter.
#[derive(Debug)]
pub struct JobResult {
    /// Job identifier.
    pub id: u64,
    /// Solution or an error string (solver errors must not kill workers).
    pub outcome: Result<Solution, String>,
    /// Queue wait time in seconds (for batch members: the batch's wait).
    pub queued_s: f64,
    /// Solver wall time in seconds (for batch members: this member's
    /// solve; the shared prepare cost is inside the first member's time).
    pub solve_s: f64,
}

/// Result of a delta-update job.
#[derive(Debug)]
pub struct UpdateResult {
    /// Job identifier.
    pub id: u64,
    /// The registry's update report, or an error string.
    pub outcome: Result<UpdateReport, String>,
    /// Queue wait time in seconds.
    pub queued_s: f64,
    /// Wall time of the registry update (splice + renorm), seconds.
    pub update_s: f64,
}

/// Ticket for a delta-update job; await with `wait`.
pub struct UpdateTicket {
    rx: Receiver<UpdateResult>,
}

impl UpdateTicket {
    /// Block until the update completes.
    pub fn wait(self) -> UpdateResult {
        self.rx.recv().expect("service dropped without reply")
    }
    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<UpdateResult> {
        self.rx.try_recv().ok()
    }
}

/// The answer to a Top-K SpMV query.
#[derive(Clone, Debug, PartialEq)]
pub struct QueryAnswer {
    /// The global top-k `(row index, score)` pairs, best first (ties by
    /// lower row index), scores in the matrix's original value scale.
    pub entries: Vec<TopKEntry>,
    /// The matrix generation the query ran against (fenced: always a
    /// complete generation, never a blend).
    pub generation: u64,
}

/// Result of a Top-K SpMV query job.
#[derive(Debug)]
pub struct QueryResult {
    /// Job identifier.
    pub id: u64,
    /// Answer or an error string.
    pub outcome: Result<QueryAnswer, String>,
    /// Queue wait time in seconds.
    pub queued_s: f64,
    /// Query wall time in seconds (sweep + merge + rescale).
    pub query_s: f64,
}

/// Ticket for a Top-K query job; await with `wait`.
pub struct QueryTicket {
    rx: Receiver<QueryResult>,
}

impl QueryTicket {
    /// Block until the query completes.
    pub fn wait(self) -> QueryResult {
        self.rx.recv().expect("service dropped without reply")
    }
    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<QueryResult> {
        self.rx.try_recv().ok()
    }
}

/// The answer to a Personalized PageRank job.
#[derive(Clone, Debug, PartialEq)]
pub struct PprAnswer {
    /// Converged (or max-iters-truncated) PPR scores and iteration
    /// telemetry. Scores need no rescaling: the random walk normalizes
    /// columns of the stored matrix, so the Frobenius scale cancels.
    pub ppr: PprResult,
    /// The matrix generation the walk ran against.
    pub generation: u64,
}

/// Result of a Personalized PageRank job.
#[derive(Debug)]
pub struct PprJobResult {
    /// Job identifier.
    pub id: u64,
    /// Answer or an error string.
    pub outcome: Result<PprAnswer, String>,
    /// Queue wait time in seconds.
    pub queued_s: f64,
    /// PPR wall time in seconds (all iterations).
    pub query_s: f64,
}

/// Ticket for a PPR job; await with `wait`.
pub struct PprTicket {
    rx: Receiver<PprJobResult>,
}

impl PprTicket {
    /// Block until the PPR job completes.
    pub fn wait(self) -> PprJobResult {
        self.rx.recv().expect("service dropped without reply")
    }
    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<PprJobResult> {
        self.rx.try_recv().ok()
    }
}

/// Snapshot of the service's queue/latency counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceStats {
    /// Jobs submitted so far (batch members count individually; jobs
    /// rejected at submit time count as submitted, completed, and failed).
    pub submitted: u64,
    /// Jobs finished (successfully or not).
    pub completed: u64,
    /// Jobs that finished with an error outcome.
    pub failed: u64,
    /// Batch submissions (`submit_batch` calls that enqueued work).
    pub batches: u64,
    /// Queue items currently waiting (a batch counts as one item).
    pub queue_depth: usize,
    /// Cumulative queue wait across finished jobs, seconds.
    pub total_queued_s: f64,
    /// Largest single queue wait observed, seconds.
    pub max_queued_s: f64,
    /// Cumulative solver wall time across finished jobs, seconds.
    pub total_solve_s: f64,
    /// Jacobi core-class switches workers performed (§IV-C partial
    /// reconfigurations; [`QueuePolicy::KBatched`] exists to minimize
    /// these).
    pub reconfigs: u64,
    /// Delta-update jobs completed (also counted in `completed`).
    pub updates: u64,
    /// Top-K SpMV query jobs completed (also counted in `completed`).
    pub queries: u64,
    /// Batched query executions — one batch is one matrix sweep shared by
    /// every member; members are counted individually in `queries`.
    pub query_batches: u64,
    /// Query jobs answered inside a batched sweep (coalesced singles plus
    /// [`EigenService::submit_query_batch`] members; also in `queries`).
    pub batched_queries: u64,
    /// Matrix shards the early-exit bound proved irrelevant, so the query
    /// path never streamed them — bytes saved without changing a bit of
    /// any answer.
    pub shards_skipped: u64,
    /// Personalized PageRank jobs completed (also counted in `completed`).
    pub pprs: u64,
}

/// Internal atomic counters behind [`ServiceStats`]. Durations are stored
/// as integer microseconds so they can live in `AtomicU64`s.
#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    reconfigs: AtomicU64,
    updates: AtomicU64,
    queries: AtomicU64,
    query_batches: AtomicU64,
    batched_queries: AtomicU64,
    shards_skipped: AtomicU64,
    pprs: AtomicU64,
    total_queued_us: AtomicU64,
    max_queued_us: AtomicU64,
    total_solve_us: AtomicU64,
}

impl Counters {
    fn record_result(&self, ok: bool, queued_s: f64, solve_s: f64) {
        self.completed.fetch_add(1, Ordering::SeqCst);
        if !ok {
            self.failed.fetch_add(1, Ordering::SeqCst);
        }
        let qus = (queued_s * 1e6) as u64;
        self.total_queued_us.fetch_add(qus, Ordering::SeqCst);
        self.max_queued_us.fetch_max(qus, Ordering::SeqCst);
        self.total_solve_us.fetch_add((solve_s * 1e6) as u64, Ordering::SeqCst);
    }
}

struct Shared {
    queue: Mutex<VecDeque<QueueEntry>>,
    available: Condvar,
    shutdown: AtomicBool,
    /// While set, workers leave the queue untouched (deterministic trace
    /// loading: enqueue everything, then [`EigenService::resume`]).
    paused: AtomicBool,
    /// Per-handle generation fences: solves hold the read side while they
    /// run, updates take the write side — an update never interleaves
    /// with an in-flight solve on the same handle, so a solve's engine
    /// snapshot and its warm seed always belong to one generation (no
    /// torn reads). Entries are dropped on `unregister`.
    fences: Mutex<HashMap<u64, Arc<RwLock<()>>>>,
}

impl Shared {
    fn fence(&self, handle: MatrixHandle) -> Arc<RwLock<()>> {
        let mut fences = self.fences.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        // Self-cleaning: a fence re-created by a job racing `unregister`
        // would otherwise leak forever (handle ids are never reused).
        // Entries whose only strong reference is the map itself belong to
        // no running job — sweep them once the map grows past the bound.
        if fences.len() > 64 {
            fences.retain(|_, f| Arc::strong_count(f) > 1);
        }
        Arc::clone(fences.entry(handle.id()).or_default())
    }
}

/// Handle returned by the submit calls; await with `wait`.
pub struct Ticket {
    rx: Receiver<JobResult>,
}

impl Ticket {
    /// Block until the job completes.
    pub fn wait(self) -> JobResult {
        self.rx.recv().expect("service dropped without reply")
    }
    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<JobResult> {
        self.rx.try_recv().ok()
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Solver worker replicas.
    pub replicas: usize,
    /// Live dispatch policy (FIFO, or K-batched core-affinity).
    pub policy: QueuePolicy,
    /// Configuration of the shared [`MatrixRegistry`] (engine byte
    /// budget, warm-start cache, trust flags).
    pub registry: RegistryConfig,
    /// Start with dispatch paused; call [`EigenService::resume`] once the
    /// queue is loaded. Used for deterministic policy traces (benches,
    /// tests) — production services start live.
    pub paused: bool,
    /// Largest number of Top-K queries one dequeue may coalesce into a
    /// single batched matrix sweep (the picked query plus up to
    /// `batch_cap - 1` compatible queued companions). `<= 1` disables
    /// coalescing; [`EigenService::submit_query_batch`] items are sized
    /// by the caller and not re-coalesced.
    pub batch_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            replicas: 1,
            policy: QueuePolicy::Fifo,
            registry: RegistryConfig::default(),
            paused: false,
            batch_cap: 8,
        }
    }
}

/// Longest run of consecutive same-class affinity picks a worker may make
/// before it must take the queue head instead (plain FIFO for one
/// dispatch). This bounds starvation under [`QueuePolicy::KBatched`]: a
/// sustained stream of hot-class arrivals cannot hold back an older
/// other-class job forever — the oldest waiter is served at least once
/// every `AFFINITY_STREAK_CAP` dispatches per worker, at the cost of at
/// most one extra reconfiguration per cap window.
pub const AFFINITY_STREAK_CAP: usize = 32;

/// The pure dispatch rule of the live queue: given the queued entries'
/// `(core class, estimated solve seconds)` in arrival order and the
/// worker's currently-loaded core class, pick the index to run next.
///
/// * [`QueuePolicy::Fifo`] — always the head.
/// * [`QueuePolicy::KBatched`] — the oldest entry of the loaded core class
///   if any (keep the core hot), otherwise the first entry of the class
///   with the **largest estimated backlog** (amortize the upcoming
///   reconfiguration over the most work; ties go to the earliest class).
///   The worker loop additionally breaks affinity every
///   [`AFFINITY_STREAK_CAP`] consecutive same-class picks by taking the
///   queue **head** (the oldest waiter) for one dispatch, so no class is
///   starved by a continuous hot-class stream.
///
/// Dispatch is O(queue length) per pop (a snapshot Vec plus a scan) under
/// the queue mutex — negligible next to a solve, but worth revisiting
/// with incremental per-class totals if queues reach tens of thousands.
///
/// Public because it *is* the deployment behaviour: the worker loop, the
/// unit tests, and the `ablation_scheduler` bench all call this one
/// function, so the modelled policy and the deployed policy cannot drift.
pub fn select_next(queue: &[(usize, f64)], loaded_core: Option<usize>, policy: QueuePolicy) -> Option<usize> {
    if queue.is_empty() {
        return None;
    }
    match policy {
        QueuePolicy::Fifo => Some(0),
        QueuePolicy::KBatched => {
            if let Some(core) = loaded_core {
                if let Some(i) = queue.iter().position(|&(c, _)| c == core) {
                    return Some(i);
                }
            }
            let mut classes: Vec<(usize, f64, usize)> = Vec::new(); // (core, backlog, first idx)
            for (i, &(c, est)) in queue.iter().enumerate() {
                match classes.iter_mut().find(|e| e.0 == c) {
                    Some(e) => e.1 += est,
                    None => classes.push((c, est, i)),
                }
            }
            let mut best = &classes[0];
            for e in &classes[1..] {
                if e.1 > best.1 {
                    best = e;
                }
            }
            Some(best.2)
        }
    }
}

/// Can two queued Top-K queries share one batched matrix sweep? Same
/// handle, same `k`, and the same engine geometry (precision, CU count,
/// partition policy, thread cap — the fields of the registry's engine
/// key; `engine` is already forced to Native for every query at submit),
/// so one prepared engine serves every member and the batch is
/// bitwise-equivalent to running the members independently. Generation
/// needs no check: the batch takes one fence read and one engine
/// snapshot, so every member answers for the same complete generation —
/// exactly what each would have seen running alone at that moment.
fn coalescable(a: &QueryJob, b: &QueryJob) -> bool {
    a.handle == b.handle
        && a.k == b.k
        && a.opts.precision == b.opts.precision
        && a.opts.cus == b.opts.cus
        && a.opts.partition == b.opts.partition
        && a.opts.threads == b.opts.threads
}

/// Timing-model estimate of one solve (the §IV-C dispatch currency): the
/// [`FpgaTimingModel`] at the job's precision and CU count over an
/// idealized balanced partition — submit time knows `n`/`nnz` but not the
/// real shard table, and the queue only needs relative magnitudes.
fn estimate_solve_s(n: usize, nnz: usize, opts: &SolveOptions, k: usize) -> f64 {
    let cus = opts.cus.max(1);
    let model = FpgaTimingModel { cus, ..FpgaTimingModel::for_precision(opts.precision) };
    let shards: Vec<RowPartition> =
        (0..cus).map(|i| RowPartition { row_start: i, row_end: i + 1, nnz: nnz / cus }).collect();
    let steps = k.saturating_sub(1) * ((k.max(2) as f64).log2().ceil() as usize + 3);
    model.solve_time(n, &shards, k, opts.reorth, steps).total_s()
}

/// Timing-model estimate of one Top-K query: a single matrix sweep — the
/// `k = 1`, zero-Jacobi-step slice of the solve estimate. The queue only
/// needs relative magnitudes; what matters is that a query is priced far
/// below an eigensolve so [`QueuePolicy::KBatched`] backlog accounting
/// stays sane under mixed load.
fn estimate_query_s(n: usize, nnz: usize, opts: &SolveOptions) -> f64 {
    estimate_solve_s(n, nnz, opts, 1)
}

/// Timing-model estimate of one PPR job: one matrix sweep per iteration,
/// priced at the worst case (`max_iters`; early convergence only makes
/// the estimate conservative).
fn estimate_ppr_s(n: usize, nnz: usize, opts: &SolveOptions, max_iters: usize) -> f64 {
    estimate_solve_s(n, nnz, opts, 1) * max_iters.max(1) as f64
}

/// The service: leader queue + solver worker replicas + shared registry.
pub struct EigenService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    counters: Arc<Counters>,
    registry: Arc<MatrixRegistry>,
}

impl EigenService {
    /// Start `replicas` solver workers with default (FIFO) dispatch.
    pub fn start(replicas: usize) -> Self {
        Self::with_config(ServiceConfig { replicas, ..Default::default() })
    }

    /// Start a service under `cfg`.
    pub fn with_config(cfg: ServiceConfig) -> Self {
        assert!(cfg.replicas >= 1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            paused: AtomicBool::new(cfg.paused),
            fences: Mutex::new(HashMap::new()),
        });
        let counters = Arc::new(Counters::default());
        let registry = Arc::new(MatrixRegistry::new(cfg.registry.clone()));
        let mut workers = Vec::with_capacity(cfg.replicas);
        for w in 0..cfg.replicas {
            let shared = Arc::clone(&shared);
            let counters = Arc::clone(&counters);
            let registry = Arc::clone(&registry);
            let policy = cfg.policy;
            let batch_cap = cfg.batch_cap;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("eigen-worker-{w}"))
                    .spawn(move || Self::worker_loop(&shared, &counters, &registry, policy, batch_cap))
                    .expect("spawn worker"),
            );
        }
        Self { shared, workers, next_id: AtomicU64::new(1), counters, registry }
    }

    fn worker_loop(
        shared: &Shared,
        counters: &Counters,
        registry: &Arc<MatrixRegistry>,
        policy: QueuePolicy,
        batch_cap: usize,
    ) {
        // Worker-local state: the Jacobi core class this replica last ran
        // (reconfiguration tracking), the length of its current same-class
        // affinity streak (starvation bound), and its reusable scratch.
        let mut loaded_core: Option<usize> = None;
        let mut streak = 0usize;
        let mut ws = LanczosWorkspace::new();
        loop {
            let force_fifo = streak >= AFFINITY_STREAK_CAP;
            let picked = {
                let mut q = shared.queue.lock().unwrap();
                loop {
                    let shutdown = shared.shutdown.load(Ordering::SeqCst);
                    // Shutdown drains the queue even when paused.
                    if (!shared.paused.load(Ordering::SeqCst) || shutdown) && !q.is_empty() {
                        let idx = if force_fifo {
                            // Anti-starvation: serve the oldest waiter.
                            0
                        } else {
                            let view: Vec<(usize, f64)> = q.iter().map(|e| (e.core, e.est_s)).collect();
                            select_next(&view, loaded_core, policy).expect("queue non-empty")
                        };
                        let entry = q.remove(idx).expect("selected index in range");
                        // Batched-SpMM coalescing: when the pick is a Top-K
                        // query, pull every compatible queued query (same
                        // handle, k, and engine geometry; arrival order; up
                        // to `batch_cap` members total) into the same
                        // matrix sweep. Still under the queue lock, so no
                        // submitter ever observes a half-coalesced queue.
                        let mut tail = Vec::new();
                        if batch_cap > 1 {
                            if let QueueItem::Query(head) = &entry.item {
                                let keys: Vec<Option<u64>> = q
                                    .iter()
                                    .map(|e| match &e.item {
                                        QueueItem::Query(j) => Some(u64::from(coalescable(head, j))),
                                        _ => None,
                                    })
                                    .collect();
                                for &i in coalesce_window(&keys, 1, batch_cap).iter().rev() {
                                    tail.push(q.remove(i).expect("coalesce pick in range"));
                                }
                                tail.reverse();
                            }
                        }
                        break Some((entry, tail));
                    }
                    if shutdown {
                        break None;
                    }
                    q = shared.available.wait(q).unwrap();
                }
            };
            let Some((entry, tail)) = picked else { break };
            // Reconfiguration accounting runs over the *member* core
            // sequence: a batch executes its Ks in order on this worker, so
            // its internal class switches are real reconfigurations too
            // (entry.core — the max member class — is only the queue-side
            // selection label). `loaded_core` ends at the physically-last
            // member's class.
            let member_cores: Vec<usize> = match &entry.item {
                QueueItem::Single(job) => vec![core_for_k(job.opts.k)],
                QueueItem::Handle(job) => vec![core_for_k(job.k)],
                QueueItem::Batch(batch) => batch.ks.iter().map(|&k| core_for_k(k)).collect(),
                // Updates, Top-K queries (single or batched), and PPR
                // walks run on no Jacobi core: no class change, no
                // reconfiguration accounting.
                QueueItem::Update(_)
                | QueueItem::Query(_)
                | QueueItem::QueryBatch(_)
                | QueueItem::Ppr(_) => Vec::new(),
            };
            let mut first = true;
            for &core in &member_cores {
                if loaded_core == Some(core) {
                    // A forced-FIFO pick re-arms affinity even when it
                    // happens to land on the hot class again.
                    streak = if first && force_fifo { 0 } else { streak + 1 };
                } else {
                    streak = 0;
                    if loaded_core.is_some() {
                        counters.reconfigs.fetch_add(1, Ordering::SeqCst);
                    }
                    loaded_core = Some(core);
                }
                first = false;
            }
            let queued_s = entry.enqueued.elapsed().as_secs_f64();
            match entry.item {
                QueueItem::Single(job) => Self::run_single(job, queued_s, counters),
                QueueItem::Batch(batch) => Self::run_batch(batch, queued_s, counters),
                QueueItem::Handle(job) => Self::run_handle(job, queued_s, counters, registry, shared, &mut ws),
                QueueItem::Update(job) => Self::run_update(job, queued_s, counters, registry, shared),
                QueueItem::Query(job) if tail.is_empty() => {
                    Self::run_query(job, queued_s, counters, registry, shared)
                }
                QueueItem::Query(job) => {
                    // Fuse the picked query with its coalesced companions
                    // into one batched sweep. Each member keeps its own
                    // queue-wait clock — they were enqueued at different
                    // times.
                    let QueryJob { id, handle, x, k, opts, reply } = job;
                    let mut ids = vec![id];
                    let mut xs = vec![x];
                    let mut replies = vec![reply];
                    let mut queued = vec![queued_s];
                    for e in tail {
                        let QueueItem::Query(j) = e.item else {
                            unreachable!("only queries coalesce")
                        };
                        ids.push(j.id);
                        xs.push(j.x);
                        replies.push(j.reply);
                        queued.push(e.enqueued.elapsed().as_secs_f64());
                    }
                    let batch = QueryBatchJob { ids, handle, xs, k, opts, replies };
                    Self::run_query_batch(batch, &queued, counters, registry, shared);
                }
                QueueItem::QueryBatch(batch) => {
                    let queued = vec![queued_s; batch.ids.len()];
                    Self::run_query_batch(batch, &queued, counters, registry, shared);
                }
                QueueItem::Ppr(job) => Self::run_ppr(job, queued_s, counters, registry, shared),
            }
        }
    }

    fn run_single(job: Job, queued_s: f64, counters: &Counters) {
        let t0 = std::time::Instant::now();
        let Job { id, matrix, opts, reply } = job;
        // A panicking solve must not take the worker down. The job owns
        // its matrix, so the owned prepare path runs clone-free.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut solver = Solver::new(opts);
            solver.prepare_owned(matrix).and_then(|prep| solver.solve_prepared(&prep))
        }));
        let outcome = match outcome {
            Ok(Ok(sol)) => Ok(sol),
            Ok(Err(e)) => Err(e.to_string()),
            Err(_) => Err("solver panicked".to_string()),
        };
        let solve_s = t0.elapsed().as_secs_f64();
        counters.record_result(outcome.is_ok(), queued_s, solve_s);
        let _ = reply.send(JobResult { id, outcome, queued_s, solve_s });
    }

    fn run_batch(batch: BatchJob, queued_s: f64, counters: &Counters) {
        // Prepare once, then solve per K. A panicking prepare fails every
        // member; a panicking member solve fails only that member —
        // siblings keep their results. The shared prepare wall time is
        // charged to the first member's `solve_s` so the batch's total
        // solver time is conserved in the telemetry.
        let BatchJob { ids, matrix, opts, ks, replies } = batch;
        let prep_t0 = std::time::Instant::now();
        let prepared = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut solver = Solver::new(opts.clone());
            solver.prepare_owned(matrix).map(|p| (solver, p)).map_err(|e| e.to_string())
        }));
        let prep_s = prep_t0.elapsed().as_secs_f64();
        let outcomes: Vec<(Result<Solution, String>, f64)> = match prepared {
            Ok(Ok((mut solver, prep))) => ks
                .iter()
                .enumerate()
                .map(|(i, &k)| {
                    let t0 = std::time::Instant::now();
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        solver.solve_prepared_with_k(&prep, k).map_err(|e| e.to_string())
                    }))
                    .unwrap_or_else(|_| Err("solver panicked".to_string()));
                    let mut solve_s = t0.elapsed().as_secs_f64();
                    if i == 0 {
                        solve_s += prep_s;
                    }
                    (r, solve_s)
                })
                .collect(),
            Ok(Err(msg)) => ks
                .iter()
                .enumerate()
                .map(|(i, _)| (Err(msg.clone()), if i == 0 { prep_s } else { 0.0 }))
                .collect(),
            Err(_) => ks
                .iter()
                .enumerate()
                .map(|(i, _)| {
                    (Err("solver panicked".to_string()), if i == 0 { prep_s } else { 0.0 })
                })
                .collect(),
        };
        for ((id, reply), (outcome, solve_s)) in
            ids.into_iter().zip(replies).zip(outcomes)
        {
            counters.record_result(outcome.is_ok(), queued_s, solve_s);
            let _ = reply.send(JobResult { id, outcome, queued_s, solve_s });
        }
    }

    fn run_handle(
        job: HandleJob,
        queued_s: f64,
        counters: &Counters,
        registry: &Arc<MatrixRegistry>,
        shared: &Shared,
        ws: &mut LanczosWorkspace,
    ) {
        let t0 = std::time::Instant::now();
        let HandleJob { id, handle, k, opts, reply } = job;
        // Generation fence (read side): in-flight solves on a handle
        // exclude updates on the same handle, so the engine snapshot and
        // warm seed below come from one consistent generation.
        let fence = shared.fence(handle);
        let _guard = fence.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let prep = registry.prepared(handle, &opts)?;
            // Warm seed in the shape the solve path wants: the block path
            // seeds its whole initial panel from the cached Ritz front;
            // the single-vector path takes the dominant column only.
            let b = opts.block_size.max(1);
            let (v1, panel) = if b > 1 {
                (None, registry.warm_panel(handle, k, opts.precision, b))
            } else {
                (registry.warm_v1(handle, k, opts.precision), None)
            };
            let mut sol = Solver::solve_detached_seeded(&prep, k, &opts, ws, v1, panel)?;
            // A warm seed that is (nearly) an exact eigenvector can break
            // the recurrence down early, truncating the answer below the
            // requested K. Retry cold: if the truncation was genuine (an
            // exact invariant subspace), the cold solve reproduces it; if
            // it was a warm-start artifact, the cold solve recovers the
            // full K pairs. Either way the key is negatively cached —
            // re-storing the cold dominant would just recreate the same
            // truncating seed on every future repeat.
            if sol.metrics.warm_started && sol.k() < k {
                sol = Solver::solve_detached(&prep, k, &opts, ws, None)?;
                registry.disable_warm(handle, k, opts.precision);
            } else if !sol.eigenvectors.is_empty() {
                // Store the leading Ritz front (up to b columns): repeats
                // of this key at any block width find a usable seed.
                let front: Vec<&[f32]> =
                    sol.eigenvectors.iter().take(b.min(sol.k())).map(|v| v.as_slice()).collect();
                registry.store_warm_panel(handle, k, opts.precision, &front);
            }
            Ok(sol)
        }));
        let outcome: Result<Solution, String> = match outcome {
            Ok(Ok(sol)) => Ok(sol),
            Ok(Err(e)) => Err(e.to_string()),
            Err(_) => Err("solver panicked".to_string()),
        };
        let solve_s = t0.elapsed().as_secs_f64();
        counters.record_result(outcome.is_ok(), queued_s, solve_s);
        let _ = reply.send(JobResult { id, outcome, queued_s, solve_s });
    }

    fn run_update(
        job: UpdateJob,
        queued_s: f64,
        counters: &Counters,
        registry: &Arc<MatrixRegistry>,
        shared: &Shared,
    ) {
        let t0 = std::time::Instant::now();
        let UpdateJob { id, handle, delta, reply } = job;
        // Generation fence (write side): wait out in-flight solves on this
        // handle, and hold solves submitted behind us until the splice and
        // generation bump are complete.
        let fence = shared.fence(handle);
        let _guard = fence.write().unwrap_or_else(std::sync::PoisonError::into_inner);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| registry.update(handle, delta)));
        let outcome: Result<UpdateReport, String> = match outcome {
            Ok(Ok(rep)) => Ok(rep),
            Ok(Err(e)) => Err(e.to_string()),
            Err(_) => Err("update panicked".to_string()),
        };
        let update_s = t0.elapsed().as_secs_f64();
        counters.updates.fetch_add(1, Ordering::SeqCst);
        counters.record_result(outcome.is_ok(), queued_s, update_s);
        let _ = reply.send(UpdateResult { id, outcome, queued_s, update_s });
    }

    fn run_query(
        job: QueryJob,
        queued_s: f64,
        counters: &Counters,
        registry: &Arc<MatrixRegistry>,
        shared: &Shared,
    ) {
        let t0 = std::time::Instant::now();
        let QueryJob { id, handle, x, k, opts, reply } = job;
        // Generation fence (read side), exactly like solves: the engine
        // snapshot below belongs to one complete generation.
        let fence = shared.fence(handle);
        let _guard = fence.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let prep = registry.prepared(handle, &opts)?;
            let fro = prep.frobenius_norm();
            let generation = prep.generation();
            // Early-exit bounds: per-row L1 norms, cached per generation
            // beside the PPR column sums. The per-shard maxima are
            // conservative f64 score bounds, so a skipped shard provably
            // cannot alter the top-k — the answer stays bitwise-identical
            // to the unbounded sweep.
            let bounds = registry.row_bounds(handle, &prep);
            crate::with_precision!(opts.precision, V => {
                let engine = prep
                    .operator()
                    .as_any()
                    .and_then(|a| a.downcast_ref::<ShardedSpmv<V>>())
                    .ok_or_else(|| anyhow::anyhow!("query needs the native sharded engine"))?;
                let (mut entries, skipped) = match bounds.as_deref() {
                    Some(rb) => engine.top_k_with_bounds(&x, k, rb),
                    None => (engine.top_k(&x, k), 0),
                };
                counters.shards_skipped.fetch_add(skipped as u64, Ordering::SeqCst);
                // Stored values are Frobenius-normalized; return scores in
                // the original value scale. The factor is positive, so the
                // ranking (and its determinism) is untouched.
                for e in &mut entries {
                    e.score = (f64::from(e.score) * fro) as f32;
                }
                Ok(QueryAnswer { entries, generation })
            })
        }));
        let outcome: Result<QueryAnswer, String> = match outcome {
            Ok(Ok(ans)) => Ok(ans),
            Ok(Err(e)) => Err(format!("{e}")),
            Err(_) => Err("query panicked".to_string()),
        };
        let query_s = t0.elapsed().as_secs_f64();
        counters.queries.fetch_add(1, Ordering::SeqCst);
        counters.record_result(outcome.is_ok(), queued_s, query_s);
        let _ = reply.send(QueryResult { id, outcome, queued_s, query_s });
    }

    /// One batched matrix sweep answering every member of a
    /// [`QueryBatchJob`] — the SpMM path: each shard's packets stream
    /// once for the whole batch, each member keeps its own bounded heap,
    /// merge, rescale, and reply. `queued` carries each member's own
    /// queue wait (coalesced members were enqueued at different times);
    /// the shared sweep wall time is split evenly across members so the
    /// batch's total solver time is conserved in the telemetry.
    fn run_query_batch(
        batch: QueryBatchJob,
        queued: &[f64],
        counters: &Counters,
        registry: &Arc<MatrixRegistry>,
        shared: &Shared,
    ) {
        let t0 = std::time::Instant::now();
        let QueryBatchJob { ids, handle, xs, k, opts, replies } = batch;
        let b = ids.len();
        // One generation fence read and one engine snapshot for the whole
        // batch: every member answers for the same complete generation.
        let fence = shared.fence(handle);
        let _guard = fence.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let prep = registry.prepared(handle, &opts)?;
            let fro = prep.frobenius_norm();
            let generation = prep.generation();
            let bounds = registry.row_bounds(handle, &prep);
            crate::with_precision!(opts.precision, V => {
                let engine = prep
                    .operator()
                    .as_any()
                    .and_then(|a| a.downcast_ref::<ShardedSpmv<V>>())
                    .ok_or_else(|| anyhow::anyhow!("query needs the native sharded engine"))?;
                let (mut answers, skipped) = match bounds.as_deref() {
                    Some(rb) => engine.top_k_batch_with_bounds(&xs, k, rb),
                    None => (engine.top_k_batch(&xs, k), 0),
                };
                counters.shards_skipped.fetch_add(skipped as u64, Ordering::SeqCst);
                for entries in &mut answers {
                    for e in entries.iter_mut() {
                        e.score = (f64::from(e.score) * fro) as f32;
                    }
                }
                Ok(answers
                    .into_iter()
                    .map(|entries| QueryAnswer { entries, generation })
                    .collect::<Vec<_>>())
            })
        }));
        let outcomes: Vec<Result<QueryAnswer, String>> = match outcome {
            Ok(Ok(answers)) => answers.into_iter().map(Ok).collect(),
            Ok(Err(e)) => {
                let msg = format!("{e}");
                (0..b).map(|_| Err(msg.clone())).collect()
            }
            Err(_) => (0..b).map(|_| Err("query panicked".to_string())).collect(),
        };
        // The shared sweep is split evenly: per-answer wall time is what
        // a throughput dashboard wants, and the members' sum reproduces
        // the batch's wall time.
        let query_s = t0.elapsed().as_secs_f64() / b.max(1) as f64;
        counters.query_batches.fetch_add(1, Ordering::SeqCst);
        counters.batched_queries.fetch_add(b as u64, Ordering::SeqCst);
        for ((id, reply), (outcome, &queued_s)) in
            ids.into_iter().zip(replies).zip(outcomes.into_iter().zip(queued))
        {
            counters.queries.fetch_add(1, Ordering::SeqCst);
            counters.record_result(outcome.is_ok(), queued_s, query_s);
            let _ = reply.send(QueryResult { id, outcome, queued_s, query_s });
        }
    }

    fn run_ppr(
        job: PprJob,
        queued_s: f64,
        counters: &Counters,
        registry: &Arc<MatrixRegistry>,
        shared: &Shared,
    ) {
        let t0 = std::time::Instant::now();
        let PprJob { id, handle, ppr, opts, reply } = job;
        let fence = shared.fence(handle);
        let _guard = fence.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let prep = registry.prepared(handle, &opts)?;
            let generation = prep.generation();
            // Shared O(nnz) normalizer pass, once per generation.
            let colsums = registry
                .column_sums(handle, &prep)
                .ok_or_else(|| anyhow::anyhow!("ppr needs the native sharded engine"))?;
            crate::with_precision!(opts.precision, V => {
                let engine = prep
                    .operator()
                    .as_any()
                    .and_then(|a| a.downcast_ref::<ShardedSpmv<V>>())
                    .ok_or_else(|| anyhow::anyhow!("ppr needs the native sharded engine"))?;
                // Cross-generation warm restart: a converged walk for this
                // (precision, source, alpha) seeds the next one. The
                // damped iteration's fixed point is unique, so the seed
                // changes the iteration count, never the limit; the
                // registry drops seeds whose generation bump exceeded
                // `warm_keep_tol`, and the whole path is off unless the
                // registry's `warm_start` flag is set.
                let seed = registry.ppr_warm_scores(handle, opts.precision, ppr.source, ppr.alpha);
                let res = engine.ppr_with_colsums_seeded(&ppr, &colsums, seed.as_deref());
                // Only converged fixed points go back into the cache — a
                // max-iters truncation would seed the next walk with a
                // half-converged vector for no saving.
                if res.converged {
                    registry.store_ppr_warm(handle, opts.precision, ppr.source, ppr.alpha, &res.scores);
                }
                Ok(PprAnswer { ppr: res, generation })
            })
        }));
        let outcome: Result<PprAnswer, String> = match outcome {
            Ok(Ok(ans)) => Ok(ans),
            Ok(Err(e)) => Err(format!("{e}")),
            Err(_) => Err("ppr panicked".to_string()),
        };
        let query_s = t0.elapsed().as_secs_f64();
        counters.pprs.fetch_add(1, Ordering::SeqCst);
        counters.record_result(outcome.is_ok(), queued_s, query_s);
        let _ = reply.send(PprJobResult { id, outcome, queued_s, query_s });
    }

    /// An immediately-failed ticket for a job rejected at submit time: the
    /// error [`JobResult`] is already in the channel, no worker is
    /// involved, and the counters record a completed+failed job.
    fn rejected(&self, id: u64, msg: String) -> Ticket {
        let (tx, rx) = channel();
        self.counters.record_result(false, 0.0, 0.0);
        let _ = tx.send(JobResult { id, outcome: Err(msg), queued_s: 0.0, solve_s: 0.0 });
        Ticket { rx }
    }

    /// [`EigenService::rejected`], for the Top-K query path.
    fn rejected_query(&self, id: u64, msg: String) -> QueryTicket {
        let (tx, rx) = channel();
        self.counters.queries.fetch_add(1, Ordering::SeqCst);
        self.counters.record_result(false, 0.0, 0.0);
        let _ = tx.send(QueryResult { id, outcome: Err(msg), queued_s: 0.0, query_s: 0.0 });
        QueryTicket { rx }
    }

    /// An immediately-successful ticket for a `k == 0` query: the
    /// deterministic empty answer (the stack-wide `k == 0` contract —
    /// see [`crate::sparse::merge_top_k`]) without a queue trip or a
    /// matrix sweep. Counted as a completed query, not a failure.
    fn empty_query(&self, id: u64, generation: u64) -> QueryTicket {
        let (tx, rx) = channel();
        self.counters.queries.fetch_add(1, Ordering::SeqCst);
        self.counters.record_result(true, 0.0, 0.0);
        let _ = tx.send(QueryResult {
            id,
            outcome: Ok(QueryAnswer { entries: Vec::new(), generation }),
            queued_s: 0.0,
            query_s: 0.0,
        });
        QueryTicket { rx }
    }

    /// [`EigenService::rejected`], for the PPR path.
    fn rejected_ppr(&self, id: u64, msg: String) -> PprTicket {
        let (tx, rx) = channel();
        self.counters.pprs.fetch_add(1, Ordering::SeqCst);
        self.counters.record_result(false, 0.0, 0.0);
        let _ = tx.send(PprJobResult { id, outcome: Err(msg), queued_s: 0.0, query_s: 0.0 });
        PprTicket { rx }
    }

    fn enqueue(&self, item: QueueItem, core: usize, est_s: f64) {
        self.shared.queue.lock().unwrap().push_back(QueueEntry {
            item,
            enqueued: std::time::Instant::now(),
            core,
            est_s,
        });
        self.shared.available.notify_one();
    }

    /// The shared matrix registry (register matrices directly, read
    /// telemetry, seed warm starts).
    pub fn registry(&self) -> &Arc<MatrixRegistry> {
        &self.registry
    }

    /// Register a matrix with the service's registry; the returned handle
    /// can be submitted any number of times from any thread.
    pub fn register(&self, matrix: CooMatrix) -> anyhow::Result<MatrixHandle> {
        self.registry.register(matrix)
    }

    /// Drop a registered matrix's residency (source, cached engines, warm
    /// entries). Jobs already queued for the handle fail with "unknown
    /// matrix handle"; in-flight solves finish normally. Long-lived
    /// services must unregister client matrices they are done with — the
    /// registry byte budget bounds engines, not sources.
    pub fn unregister(&self, handle: MatrixHandle) -> bool {
        let dropped = self.registry.unregister(handle);
        if dropped {
            let mut fences = self.shared.fences.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            fences.remove(&handle.id());
        }
        dropped
    }

    /// Enqueue a one-shot owned-matrix job; returns a [`Ticket`] to await
    /// the result. Invalid jobs (non-square matrix, `k` out of
    /// `1..=n`) are rejected here — the ticket yields the error
    /// immediately and no worker time is spent.
    pub fn submit(&self, matrix: CooMatrix, opts: SolveOptions) -> (u64, Ticket) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        if matrix.nrows != matrix.ncols {
            return (id, self.rejected(id, format!("matrix must be square ({}x{})", matrix.nrows, matrix.ncols)));
        }
        if opts.k < 1 || opts.k > matrix.nrows {
            return (id, self.rejected(id, format!("bad k: {} not in 1..={}", opts.k, matrix.nrows)));
        }
        let (tx, rx) = channel();
        let core = core_for_k(opts.k);
        let est = estimate_solve_s(matrix.nrows, matrix.nnz(), &opts, opts.k);
        let job = Job { id, matrix, opts, reply: tx };
        self.enqueue(QueueItem::Single(job), core, est);
        (id, Ticket { rx })
    }

    /// Enqueue a job against a registered handle — the matrix-resident
    /// path: the queue carries a handle, the worker solves on the shared
    /// prepared engine, nothing is cloned. `k` comes from `opts.k` and is
    /// validated against the registered dimension at submit time.
    pub fn submit_handle(&self, handle: MatrixHandle, opts: SolveOptions) -> (u64, Ticket) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        let Some((n, nnz)) = self.registry.dims(handle) else {
            return (id, self.rejected(id, format!("unknown matrix handle {}", handle.id())));
        };
        if opts.k < 1 || opts.k > n {
            return (id, self.rejected(id, format!("bad k: {} not in 1..={n}", opts.k)));
        }
        let (tx, rx) = channel();
        let core = core_for_k(opts.k);
        let est = estimate_solve_s(n, nnz, &opts, opts.k);
        let job = HandleJob { id, handle, k: opts.k, opts, reply: tx };
        self.enqueue(QueueItem::Handle(job), core, est);
        (id, Ticket { rx })
    }

    /// Convenience: one handle job per entry of `ks` (each an independent
    /// queue item, so multiple workers fan out over the shared engine).
    pub fn submit_handle_batch(
        &self,
        handle: MatrixHandle,
        opts: SolveOptions,
        ks: &[usize],
    ) -> Vec<(u64, Ticket)> {
        ks.iter().map(|&k| self.submit_handle(handle, SolveOptions { k, ..opts.clone() })).collect()
    }

    /// Enqueue a delta update against a registered handle — the evolving-
    /// graph path. The update is **fenced** against solves on the same
    /// handle: it waits out in-flight solves and completes atomically
    /// (splice + Frobenius renorm + generation bump) before any later
    /// solve on the handle runs, so no solve ever observes a torn state.
    /// Cached engines refresh lazily and incrementally on the next solve;
    /// warm-start seeds survive when the relative perturbation is within
    /// the registry's `warm_keep_tol`.
    ///
    /// Ordering note: the fence serializes *execution*, not queue order —
    /// under [`QueuePolicy::KBatched`] a later-submitted solve may be
    /// dispatched before an earlier update. Replay pipelines that need
    /// strict delta/query interleaving should run [`QueuePolicy::Fifo`]
    /// or wait on the returned [`UpdateTicket`] before submitting
    /// dependent queries.
    pub fn submit_update(&self, handle: MatrixHandle, delta: CooDelta) -> (u64, UpdateTicket) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        let Some((n, _)) = self.registry.dims(handle) else {
            self.counters.record_result(false, 0.0, 0.0);
            let _ = tx.send(UpdateResult {
                id,
                outcome: Err(format!("unknown matrix handle {}", handle.id())),
                queued_s: 0.0,
                update_s: 0.0,
            });
            return (id, UpdateTicket { rx });
        };
        if (delta.nrows, delta.ncols) != (n, n) {
            self.counters.record_result(false, 0.0, 0.0);
            let _ = tx.send(UpdateResult {
                id,
                outcome: Err(format!("delta dimensions {}x{} do not match matrix {n}x{n}", delta.nrows, delta.ncols)),
                queued_s: 0.0,
                update_s: 0.0,
            });
            return (id, UpdateTicket { rx });
        }
        // Updates carry no Jacobi core class and a nominal cost estimate;
        // KBatched treats them as a tiny foreign-class backlog.
        let job = UpdateJob { id, handle, delta, reply: tx };
        self.enqueue(QueueItem::Update(job), 0, 1e-6);
        (id, UpdateTicket { rx })
    }

    /// Enqueue a streaming Top-K SpMV query against a registered handle:
    /// dense query vector `x` (length `n`) times the resident matrix,
    /// answering the global top-`k` `(row, score)` pairs, best first.
    /// `k > n` clamps to `n`; `k == 0` answers the deterministic empty
    /// list at submit time. At dispatch, compatible queued queries may be
    /// coalesced into one batched sweep (see [`ServiceConfig::batch_cap`])
    /// — the answer is unchanged bit for bit, only the matrix bytes
    /// streamed per answer drop. The answer is **bitwise-deterministic** —
    /// identical to the full-SpMV + stable-sort oracle — for any CU
    /// count, partition policy, or replica count, and carries the
    /// generation it ran against ([`QueryAnswer::generation`]).
    ///
    /// `opts` selects the storage format / engine geometry exactly as for
    /// solves (`opts.k` is ignored; `k` is the explicit argument).
    /// `opts.engine` is forced to [`Engine::Native`]: the heap kernel
    /// lives in the typed sharded datapath, and an opaque PJRT engine
    /// cannot stream it.
    pub fn submit_query(&self, handle: MatrixHandle, x: Vec<f32>, k: usize, opts: SolveOptions) -> (u64, QueryTicket) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        let Some((n, nnz)) = self.registry.dims(handle) else {
            return (id, self.rejected_query(id, format!("unknown matrix handle {}", handle.id())));
        };
        if x.len() != n {
            return (id, self.rejected_query(id, format!("query vector length {} does not match n={n}", x.len())));
        }
        if k == 0 {
            // k = 0 is a degenerate but well-posed request with exactly
            // one right answer — the empty list. Answer it at submit time.
            let generation = self.registry.generation(handle).unwrap_or(1);
            return (id, self.empty_query(id, generation));
        }
        let opts = SolveOptions { engine: Engine::Native, ..opts };
        let est = estimate_query_s(n, nnz, &opts);
        let (tx, rx) = channel();
        let job = QueryJob { id, handle, x, k, opts, reply: tx };
        // Like updates: no Jacobi core class.
        self.enqueue(QueueItem::Query(job), 0, est);
        (id, QueryTicket { rx })
    }

    /// Enqueue a batch of Top-K SpMV queries sharing one matrix sweep —
    /// the SpMM path: every member rides the same handle, `k`, and engine
    /// geometry, so the worker streams each matrix shard **once for the
    /// whole batch** instead of once per member
    /// ([`ShardedSpmv::top_k_batch`]), while the answers stay
    /// bitwise-identical to independent [`EigenService::submit_query`]
    /// calls. Returns one `(id, QueryTicket)` per vector, in order.
    /// Members with the wrong vector length are rejected at submit time
    /// without poisoning valid siblings; an unknown handle rejects every
    /// member; `k == 0` answers every member the deterministic empty list
    /// immediately; an empty `xs` enqueues nothing.
    pub fn submit_query_batch(
        &self,
        handle: MatrixHandle,
        xs: Vec<Vec<f32>>,
        k: usize,
        opts: SolveOptions,
    ) -> Vec<(u64, QueryTicket)> {
        if xs.is_empty() {
            return Vec::new();
        }
        self.counters.submitted.fetch_add(xs.len() as u64, Ordering::SeqCst);
        let Some((n, nnz)) = self.registry.dims(handle) else {
            return xs
                .iter()
                .map(|_| {
                    let id = self.next_id.fetch_add(1, Ordering::SeqCst);
                    (id, self.rejected_query(id, format!("unknown matrix handle {}", handle.id())))
                })
                .collect();
        };
        let generation = if k == 0 { self.registry.generation(handle).unwrap_or(1) } else { 0 };
        let opts = SolveOptions { engine: Engine::Native, ..opts };
        let mut out: Vec<(u64, Option<QueryTicket>)> = Vec::with_capacity(xs.len());
        let mut ids = Vec::new();
        let mut valid_xs = Vec::new();
        let mut replies = Vec::new();
        for x in xs {
            let id = self.next_id.fetch_add(1, Ordering::SeqCst);
            if x.len() != n {
                let msg = format!("query vector length {} does not match n={n}", x.len());
                out.push((id, Some(self.rejected_query(id, msg))));
                continue;
            }
            if k == 0 {
                out.push((id, Some(self.empty_query(id, generation))));
                continue;
            }
            let (tx, rx) = channel();
            ids.push(id);
            valid_xs.push(x);
            replies.push(tx);
            out.push((id, Some(QueryTicket { rx })));
        }
        if !ids.is_empty() {
            // Priced as one sweep shared by every member — which is the
            // point of the batch.
            let est = estimate_query_s(n, nnz, &opts);
            let job = QueryBatchJob { ids, handle, xs: valid_xs, k, opts, replies };
            self.enqueue(QueueItem::QueryBatch(job), 0, est);
        }
        out.into_iter().map(|(id, t)| (id, t.expect("every member has a ticket"))).collect()
    }

    /// Enqueue a Personalized PageRank job against a registered handle:
    /// damped power iteration `x' = alpha * P x + (1 - alpha) * e_s` over
    /// the resident matrix's stored (reduced-precision) values, with
    /// dangling-mass redistribution and L1-delta stopping
    /// ([`PprOptions`]). The converged scores, iteration count, and
    /// final delta come back in [`PprAnswer`] with the generation the
    /// walk ran against. Deterministic for any CU/replica count.
    ///
    /// Symmetric graphs work as registered; for a *directed* graph,
    /// register the transpose (the kernel walks `M z` with columns
    /// normalized, i.e. `M[i][j]` = weight of edge `j -> i`).
    pub fn submit_ppr(&self, handle: MatrixHandle, ppr: PprOptions, opts: SolveOptions) -> (u64, PprTicket) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        let Some((n, nnz)) = self.registry.dims(handle) else {
            return (id, self.rejected_ppr(id, format!("unknown matrix handle {}", handle.id())));
        };
        if ppr.source >= n {
            return (id, self.rejected_ppr(id, format!("ppr source {} out of range for n={n}", ppr.source)));
        }
        if !(ppr.alpha > 0.0 && ppr.alpha < 1.0) {
            return (id, self.rejected_ppr(id, format!("ppr alpha {} not in (0, 1)", ppr.alpha)));
        }
        if ppr.max_iters < 1 {
            return (id, self.rejected_ppr(id, "ppr needs max_iters >= 1".to_string()));
        }
        let opts = SolveOptions { engine: Engine::Native, ..opts };
        let est = estimate_ppr_s(n, nnz, &opts, ppr.max_iters);
        let (tx, rx) = channel();
        let job = PprJob { id, handle, ppr, opts, reply: tx };
        self.enqueue(QueueItem::Ppr(job), 0, est);
        (id, PprTicket { rx })
    }

    /// Enqueue one batch of same-matrix jobs, one per entry of `ks`.
    ///
    /// The batch is scheduled as a unit on one worker; the prepare phase
    /// (canonicalize + normalize + CSR + sharded-engine build) runs once
    /// and is shared by every member solve. Returns one `(id, Ticket)`
    /// pair per K, in the same order as `ks`. An empty `ks` enqueues
    /// nothing and returns an empty vector. Members with invalid K (and
    /// every member, when the matrix is not square) are rejected at
    /// submit time without poisoning valid siblings.
    pub fn submit_batch(
        &self,
        matrix: CooMatrix,
        opts: SolveOptions,
        ks: &[usize],
    ) -> Vec<(u64, Ticket)> {
        if ks.is_empty() {
            return Vec::new();
        }
        self.counters.submitted.fetch_add(ks.len() as u64, Ordering::SeqCst);
        if matrix.nrows != matrix.ncols {
            return ks
                .iter()
                .map(|_| {
                    let id = self.next_id.fetch_add(1, Ordering::SeqCst);
                    let msg = format!("matrix must be square ({}x{})", matrix.nrows, matrix.ncols);
                    (id, self.rejected(id, msg))
                })
                .collect();
        }
        let n = matrix.nrows;
        let mut out: Vec<(u64, Option<Ticket>)> = Vec::with_capacity(ks.len());
        let mut ids = Vec::new();
        let mut valid_ks = Vec::new();
        let mut replies = Vec::new();
        let mut core = 0usize;
        let mut est = 0.0f64;
        for &k in ks {
            let id = self.next_id.fetch_add(1, Ordering::SeqCst);
            if k < 1 || k > n {
                out.push((id, Some(self.rejected(id, format!("bad k: {k} not in 1..={n}")))));
                continue;
            }
            let (tx, rx) = channel();
            ids.push(id);
            valid_ks.push(k);
            replies.push(tx);
            core = core.max(core_for_k(k));
            est += estimate_solve_s(n, matrix.nnz(), &opts, k);
            out.push((id, Some(Ticket { rx })));
        }
        if !ids.is_empty() {
            self.counters.batches.fetch_add(1, Ordering::SeqCst);
            let batch = BatchJob { ids, matrix, opts, ks: valid_ks, replies };
            self.enqueue(QueueItem::Batch(batch), core, est);
        }
        out.into_iter().map(|(id, t)| (id, t.expect("every member has a ticket"))).collect()
    }

    /// Unpause dispatch after a [`ServiceConfig::paused`] start.
    pub fn resume(&self) {
        self.shared.paused.store(false, Ordering::SeqCst);
        self.shared.available.notify_all();
    }

    /// Jobs finished so far.
    pub fn completed(&self) -> u64 {
        self.counters.completed.load(Ordering::SeqCst)
    }

    /// Current queue depth (items: a batch counts as one).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Snapshot the queue/latency counters.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            submitted: self.counters.submitted.load(Ordering::SeqCst),
            completed: self.counters.completed.load(Ordering::SeqCst),
            failed: self.counters.failed.load(Ordering::SeqCst),
            batches: self.counters.batches.load(Ordering::SeqCst),
            queue_depth: self.queue_depth(),
            total_queued_s: self.counters.total_queued_us.load(Ordering::SeqCst) as f64 / 1e6,
            max_queued_s: self.counters.max_queued_us.load(Ordering::SeqCst) as f64 / 1e6,
            total_solve_s: self.counters.total_solve_us.load(Ordering::SeqCst) as f64 / 1e6,
            reconfigs: self.counters.reconfigs.load(Ordering::SeqCst),
            updates: self.counters.updates.load(Ordering::SeqCst),
            queries: self.counters.queries.load(Ordering::SeqCst),
            query_batches: self.counters.query_batches.load(Ordering::SeqCst),
            batched_queries: self.counters.batched_queries.load(Ordering::SeqCst),
            shards_skipped: self.counters.shards_skipped.load(Ordering::SeqCst),
            pprs: self.counters.pprs.load(Ordering::SeqCst),
        }
    }

    /// Drain the queue and stop workers.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for EigenService {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs;

    #[test]
    fn serves_concurrent_jobs() {
        let svc = EigenService::start(3);
        let mut tickets = Vec::new();
        for seed in 0..6u64 {
            let m = graphs::mesh2d(12, 12, 0.9, 0.02, seed);
            let (id, t) = svc.submit(m, SolveOptions { k: 4, ..Default::default() });
            tickets.push((id, t));
        }
        for (id, t) in tickets {
            let r = t.wait();
            assert_eq!(r.id, id);
            let sol = r.outcome.expect("solve failed");
            assert_eq!(sol.k(), 4);
            assert!(r.queued_s >= 0.0);
            assert!(r.solve_s >= 0.0);
        }
        assert_eq!(svc.completed(), 6);
        let stats = svc.stats();
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.batches, 0);
        assert!(stats.total_solve_s >= 0.0);
        assert!(stats.max_queued_s <= stats.total_queued_s + 1e-9);
        svc.shutdown();
    }

    #[test]
    fn bad_job_reports_error_without_killing_worker() {
        let svc = EigenService::start(1);
        // Non-square matrix -> error at submit, not a dead worker.
        let bad = CooMatrix::new(4, 5);
        let (_, t1) = svc.submit(bad, SolveOptions::default());
        assert!(t1.wait().outcome.is_err());
        // Worker must still serve the next job.
        let good = graphs::mesh2d(8, 8, 0.9, 0.02, 1);
        let (_, t2) = svc.submit(good, SolveOptions { k: 2, ..Default::default() });
        assert!(t2.wait().outcome.is_ok());
        assert_eq!(svc.stats().failed, 1);
        svc.shutdown();
    }

    #[test]
    fn bad_k_is_rejected_at_submit_time() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(6, 6, 0.9, 0.02, 4); // n = 36
        // k = 0 and k > n never reach a worker: the ticket already holds
        // the error and the queue stays empty.
        let (_, t0) = svc.submit(m.clone(), SolveOptions { k: 0, ..Default::default() });
        let r0 = t0.wait();
        assert!(r0.outcome.unwrap_err().contains("bad k"));
        let (_, t1) = svc.submit(m.clone(), SolveOptions { k: 37, ..Default::default() });
        assert!(t1.wait().outcome.is_err());
        assert_eq!(svc.queue_depth(), 0);
        let stats = svc.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.failed, 2);
        // Unknown handles are rejected the same way.
        let reg = MatrixRegistry::default();
        let foreign = reg.register(m).unwrap();
        let (_, t2) = svc.submit_handle(foreign, SolveOptions { k: 2, ..Default::default() });
        assert!(t2.wait().outcome.unwrap_err().contains("unknown matrix handle"));
        svc.shutdown();
    }

    #[test]
    fn shutdown_with_empty_queue_is_clean() {
        let svc = EigenService::start(2);
        assert_eq!(svc.queue_depth(), 0);
        svc.shutdown();
    }

    #[test]
    fn batch_matches_individual_submissions() {
        let svc = EigenService::start(2);
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 31);
        let ks = [2usize, 4, 6];
        let batch = svc.submit_batch(m.clone(), SolveOptions::default(), &ks);
        assert_eq!(batch.len(), 3);
        let mut singles = Vec::new();
        for &k in &ks {
            let (_, t) = svc.submit(m.clone(), SolveOptions { k, ..Default::default() });
            singles.push(t);
        }
        for (((_, bt), st), &k) in batch.into_iter().zip(singles).zip(&ks) {
            let b = bt.wait().outcome.expect("batch member failed");
            let s = st.wait().outcome.expect("single failed");
            assert_eq!(b.k(), s.k(), "k={k}");
            for i in 0..b.k() {
                assert!(
                    (b.eigenvalues[i] - s.eigenvalues[i]).abs() < 1e-9,
                    "k={k} pair {i}: batch {} vs single {}",
                    b.eigenvalues[i],
                    s.eigenvalues[i]
                );
            }
        }
        let stats = svc.stats();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.completed, 6);
        svc.shutdown();
    }

    #[test]
    fn batch_member_error_does_not_poison_siblings() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(6, 6, 0.9, 0.02, 2); // n = 36
        // k = 100 > n fails; the others succeed.
        let tickets = svc.submit_batch(m, SolveOptions::default(), &[4, 100, 6]);
        let results: Vec<JobResult> = tickets.into_iter().map(|(_, t)| t.wait()).collect();
        assert!(results[0].outcome.is_ok());
        assert!(results[1].outcome.is_err());
        assert!(results[2].outcome.is_ok());
        assert_eq!(svc.stats().failed, 1);
        svc.shutdown();
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(4, 4, 0.9, 0.02, 3);
        assert!(svc.submit_batch(m, SolveOptions::default(), &[]).is_empty());
        assert_eq!(svc.stats().submitted, 0);
        assert_eq!(svc.stats().batches, 0);
        svc.shutdown();
    }

    #[test]
    fn handle_jobs_share_one_prepare_and_match_owned_jobs() {
        let svc = EigenService::start(3);
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 51);
        let h = svc.register(m.clone()).unwrap();
        // Re-registering the same content dedups onto the same handle.
        assert_eq!(svc.register(m.clone()).unwrap(), h);
        let ks = [2usize, 3, 4, 5, 6, 7, 8, 6, 4, 2];
        let tickets = svc.submit_handle_batch(h, SolveOptions::default(), &ks);
        let mut owned = Vec::new();
        for &k in &ks {
            let (_, t) = svc.submit(m.clone(), SolveOptions { k, ..Default::default() });
            owned.push(t);
        }
        for (((_, ht), ot), &k) in tickets.into_iter().zip(owned).zip(&ks) {
            let hres = ht.wait().outcome.expect("handle job failed");
            let ores = ot.wait().outcome.expect("owned job failed");
            assert_eq!(hres.k(), ores.k(), "k={k}");
            assert_eq!(hres.eigenvalues, ores.eigenvalues, "k={k}");
        }
        // The acceptance bar: M handle jobs across P workers, exactly one
        // prepare; every other hit came from the shared engine.
        let rstats = svc.registry().stats();
        assert_eq!(rstats.prepares, 1, "{rstats:?}");
        assert_eq!(rstats.engine_hits, ks.len() as u64 - 1);
        assert_eq!(rstats.matrices, 1);
        assert_eq!(rstats.dedup_hits, 1);
        let stats = svc.stats();
        assert_eq!(stats.submitted, 2 * ks.len() as u64);
        assert_eq!(stats.completed, stats.submitted);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.queue_depth, 0);
        svc.shutdown();
    }

    #[test]
    fn select_next_policies() {
        // (core, est) queue in arrival order.
        let q = [(8usize, 1.0), (32, 1.0), (8, 1.0), (32, 2.0)];
        assert_eq!(select_next(&[], None, QueuePolicy::Fifo), None);
        assert_eq!(select_next(&q, None, QueuePolicy::Fifo), Some(0));
        assert_eq!(select_next(&q, Some(32), QueuePolicy::Fifo), Some(0), "FIFO ignores affinity");
        // Affinity: keep the loaded core while its class has work.
        assert_eq!(select_next(&q, Some(32), QueuePolicy::KBatched), Some(1));
        assert_eq!(select_next(&q, Some(8), QueuePolicy::KBatched), Some(0));
        // No affinity: the class with the largest estimated backlog wins
        // (core 32 has 3.0s vs core 8's 2.0s).
        assert_eq!(select_next(&q, None, QueuePolicy::KBatched), Some(1));
        assert_eq!(select_next(&q, Some(16), QueuePolicy::KBatched), Some(1));
        // Ties go to the earliest-seen class.
        let tie = [(8usize, 1.0), (32, 1.0)];
        assert_eq!(select_next(&tie, None, QueuePolicy::KBatched), Some(0));
    }

    #[test]
    fn kbatched_dispatch_reduces_reconfigurations() {
        // Deterministic trace: pause dispatch, enqueue an alternating-K
        // trace (worst case for FIFO), resume, drain. One replica so the
        // reconfiguration count is exact.
        let trace: Vec<usize> = (0..16).map(|i| if i % 2 == 0 { 4 } else { 24 }).collect();
        let mut reconfigs = Vec::new();
        for policy in [QueuePolicy::Fifo, QueuePolicy::KBatched] {
            let svc = EigenService::with_config(ServiceConfig {
                replicas: 1,
                policy,
                paused: true,
                ..Default::default()
            });
            let h = svc.register(graphs::mesh2d(8, 8, 0.9, 0.02, 6)).unwrap();
            let tickets: Vec<_> = trace
                .iter()
                .map(|&k| svc.submit_handle(h, SolveOptions { k, ..Default::default() }).1)
                .collect();
            assert_eq!(svc.queue_depth(), trace.len(), "paused service holds the whole trace");
            svc.resume();
            for t in tickets {
                assert!(t.wait().outcome.is_ok());
            }
            reconfigs.push(svc.stats().reconfigs);
            svc.shutdown();
        }
        let (fifo, kbatched) = (reconfigs[0], reconfigs[1]);
        assert_eq!(fifo, trace.len() as u64 - 1, "FIFO thrashes on alternation");
        assert_eq!(kbatched, 1, "K-batched pays one switch for two classes");
    }

    #[test]
    fn batch_internal_core_switches_are_counted() {
        let svc = EigenService::with_config(ServiceConfig { replicas: 1, paused: true, ..Default::default() });
        let m = graphs::mesh2d(8, 8, 0.9, 0.02, 8); // n = 64
        let h = svc.register(m.clone()).unwrap();
        let batch = svc.submit_batch(m, SolveOptions::default(), &[32, 4]);
        let (_, t) = svc.submit_handle(h, SolveOptions { k: 4, ..Default::default() });
        svc.resume();
        for (_, bt) in batch {
            assert!(bt.wait().outcome.is_ok());
        }
        assert!(t.wait().outcome.is_ok());
        // The 32 -> 4 switch *inside* the batch is a real reconfiguration;
        // the following k=4 handle job then runs on the already-loaded
        // class-4 core without another switch.
        assert_eq!(svc.stats().reconfigs, 1);
        svc.shutdown();
    }

    #[test]
    fn update_jobs_are_fenced_and_bump_generations_deterministically() {
        // Paused single-replica FIFO service: the trace solve/update/solve
        // executes in order, so the first solve must see generation 1 and
        // the second generation 2 — and results after the update must
        // match a fresh solve of the mutated matrix.
        let svc = EigenService::with_config(ServiceConfig { replicas: 1, paused: true, ..Default::default() });
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 97);
        let h = svc.register(m.clone()).unwrap();

        let mut canon = m.clone();
        canon.canonicalize();
        let mut delta = crate::sparse::CooDelta::new(canon.nrows, canon.ncols);
        for i in 0..canon.nnz() {
            let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
            if r <= c && c < 16 {
                delta.upsert_sym(r, c, canon.vals[i] * 1.5);
            }
        }
        assert!(!delta.is_empty());

        let (_, t1) = svc.submit_handle(h, SolveOptions { k: 4, ..Default::default() });
        let (_, tu) = svc.submit_update(h, delta.clone());
        let (_, t2) = svc.submit_handle(h, SolveOptions { k: 4, ..Default::default() });
        svc.resume();

        let before = t1.wait().outcome.expect("pre-update solve");
        assert_eq!(before.metrics.generation, 1);
        let urep = tu.wait().outcome.expect("update");
        assert_eq!(urep.generation, 2);
        assert!(urep.changed > 0);
        let after = t2.wait().outcome.expect("post-update solve");
        assert_eq!(after.metrics.generation, 2);
        assert_ne!(before.eigenvalues, after.eigenvalues, "the delta must change the spectrum");

        // Post-update answers equal a from-scratch solve of the mutated
        // matrix (the exactness acceptance, via the service path).
        let mut scratch = canon.clone();
        let mut d = delta;
        d.canonicalize();
        scratch.apply_delta(&d);
        let direct = Solver::new(SolveOptions { k: 4, ..Default::default() }).solve(&scratch).unwrap();
        assert_eq!(after.eigenvalues, direct.eigenvalues);
        assert_eq!(after.eigenvectors, direct.eigenvectors);

        let stats = svc.stats();
        assert_eq!(stats.updates, 1);
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.failed, 0);
        let rstats = svc.registry().stats();
        assert_eq!(rstats.updates, 1);
        assert_eq!(rstats.prepares, 2, "initial build + one generation refresh: {rstats:?}");
        svc.shutdown();
    }

    #[test]
    fn bad_updates_are_rejected_without_touching_workers() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(6, 6, 0.9, 0.02, 13); // n = 36
        let h = svc.register(m).unwrap();
        // Unknown handle.
        let reg = MatrixRegistry::default();
        let foreign = reg.register(graphs::mesh2d(6, 6, 0.9, 0.02, 14)).unwrap();
        let (_, t) = svc.submit_update(foreign, crate::sparse::CooDelta::new(36, 36));
        assert!(t.wait().outcome.unwrap_err().contains("unknown matrix handle"));
        // Dimension mismatch.
        let (_, t) = svc.submit_update(h, crate::sparse::CooDelta::new(4, 4));
        assert!(t.wait().outcome.unwrap_err().contains("do not match"));
        assert_eq!(svc.queue_depth(), 0);
        assert_eq!(svc.stats().failed, 2);
        // Asymmetric delta fails on the worker, not the service.
        let mut asym = crate::sparse::CooDelta::new(36, 36);
        asym.upsert(0, 1, 9.0);
        let (_, t) = svc.submit_update(h, asym);
        assert!(t.wait().outcome.unwrap_err().contains("symmetric"));
        // The worker still serves.
        let (_, ts) = svc.submit_handle(h, SolveOptions { k: 2, ..Default::default() });
        assert!(ts.wait().outcome.is_ok());
        svc.shutdown();
    }

    #[test]
    fn concurrent_updates_and_solves_never_tear() {
        // Hammer one handle with interleaved solves and small updates from
        // the submit side while 3 replicas drain: every solve must succeed
        // and report a generation consistent with some applied update
        // (1..=updates+1); every update must succeed.
        let svc = EigenService::with_config(ServiceConfig { replicas: 3, ..Default::default() });
        let m = graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 101);
        let h = svc.register(m.clone()).unwrap();
        let mut canon = m;
        canon.canonicalize();
        let rounds = 6usize;
        let mut solve_tickets = Vec::new();
        let mut update_tickets = Vec::new();
        for round in 0..rounds {
            for k in [2usize, 4] {
                solve_tickets.push(svc.submit_handle(h, SolveOptions { k, ..Default::default() }).1);
            }
            let mut d = crate::sparse::CooDelta::new(canon.nrows, canon.ncols);
            let (r, c) = (canon.rows[round] as usize, canon.cols[round] as usize);
            d.upsert_sym(r, c, 0.123 + round as f32 * 0.01);
            update_tickets.push(svc.submit_update(h, d).1);
        }
        for t in update_tickets {
            assert!(t.wait().outcome.is_ok());
        }
        let max_gen = rounds as u64 + 1;
        for t in solve_tickets {
            let r = t.wait();
            let sol = r.outcome.expect("solve under concurrent updates");
            assert!(sol.metrics.generation >= 1 && sol.metrics.generation <= max_gen);
        }
        assert_eq!(svc.stats().updates, rounds as u64);
        assert_eq!(svc.registry().generation(h), Some(max_gen));
        svc.shutdown();
    }

    #[test]
    fn warm_start_service_reuses_previous_answers() {
        let svc = EigenService::with_config(ServiceConfig {
            replicas: 1,
            registry: RegistryConfig { warm_start: true, ..Default::default() },
            ..Default::default()
        });
        let h = svc.register(graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 61)).unwrap();
        let (_, t1) = svc.submit_handle(h, SolveOptions { k: 4, ..Default::default() });
        let first = t1.wait().outcome.unwrap();
        assert!(!first.metrics.warm_started);
        let (_, t2) = svc.submit_handle(h, SolveOptions { k: 4, ..Default::default() });
        let second = t2.wait().outcome.unwrap();
        assert!(second.metrics.warm_started, "repeat query must seed from the cache");
        // Both are finite-K Ritz estimates of the same dominant pair.
        assert!((second.eigenvalues[0] - first.eigenvalues[0]).abs() < 2e-2 * first.eigenvalues[0].abs().max(1.0));
        assert_eq!(svc.registry().stats().warm_hits, 1);
        svc.shutdown();
    }

    #[test]
    fn query_jobs_match_the_serial_oracle_in_original_scale() {
        let svc = EigenService::start(2);
        let n = 1usize << 8;
        let m = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 201);
        let h = svc.register(m.clone()).unwrap();
        let x: Vec<f32> = (0..n).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 - 0.5).collect();
        // Oracle: normalized stored values, serial full SpMV + stable
        // sort, scores rescaled back to the original value scale.
        let mut canon = m.clone();
        canon.canonicalize();
        let fro = crate::sparse::frobenius_norm(&canon);
        let csr = crate::coordinator::typed_csr_scaled::<f32>(&canon, Some(1.0 / fro));
        let mut expect = crate::sparse::top_k_serial(&csr, &x, 10);
        for e in &mut expect {
            e.score = (f64::from(e.score) * fro) as f32;
        }
        // Repeats across 2 replicas: bitwise-identical answers, one engine.
        let tickets: Vec<_> =
            (0..4).map(|_| svc.submit_query(h, x.clone(), 10, SolveOptions::default()).1).collect();
        for t in tickets {
            let r = t.wait();
            let ans = r.outcome.expect("query failed");
            assert_eq!(ans.generation, 1);
            assert_eq!(ans.entries, expect);
            assert!(r.query_s >= 0.0);
        }
        // k > n clamps to n (every row ranked).
        let (_, t) = svc.submit_query(h, x.clone(), n + 99, SolveOptions::default());
        assert_eq!(t.wait().outcome.unwrap().entries.len(), n);
        let stats = svc.stats();
        assert_eq!(stats.queries, 5);
        assert_eq!(stats.failed, 0);
        assert_eq!(svc.registry().stats().prepares, 1, "queries share one engine build");
        svc.shutdown();
    }

    #[test]
    fn ppr_jobs_match_the_serial_oracle_and_share_one_colsum_pass() {
        let svc = EigenService::start(2);
        let m = graphs::mesh2d(12, 12, 0.9, 0.02, 17);
        let h = svc.register(m.clone()).unwrap();
        let popts = crate::sparse::PprOptions { source: 5, ..Default::default() };
        // Oracle: serial PPR over the same stored values (bitwise —
        // engine and oracle share one recurrence).
        let mut canon = m.clone();
        canon.canonicalize();
        let fro = crate::sparse::frobenius_norm(&canon);
        let csr = crate::coordinator::typed_csr_scaled::<f32>(&canon, Some(1.0 / fro));
        let expect = crate::sparse::ppr_serial(&csr, &popts);
        assert!(expect.converged);

        let tickets: Vec<_> =
            (0..3).map(|_| svc.submit_ppr(h, popts.clone(), SolveOptions::default()).1).collect();
        for t in tickets {
            let ans = t.wait().outcome.expect("ppr failed");
            assert_eq!(ans.generation, 1);
            assert_eq!(ans.ppr, expect);
        }
        let rstats = svc.registry().stats();
        assert_eq!(rstats.colsum_builds, 1, "{rstats:?}");
        assert_eq!(rstats.colsum_hits, 2, "{rstats:?}");
        assert_eq!(svc.stats().pprs, 3);
        svc.shutdown();
    }

    #[test]
    fn bad_queries_and_pprs_are_rejected_at_submit_time() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(6, 6, 0.9, 0.02, 23); // n = 36
        let h = svc.register(m).unwrap();
        let reg = MatrixRegistry::default();
        let foreign = reg.register(graphs::mesh2d(6, 6, 0.9, 0.02, 24)).unwrap();
        let (_, t) = svc.submit_query(foreign, vec![0.0; 36], 4, SolveOptions::default());
        assert!(t.wait().outcome.unwrap_err().contains("unknown matrix handle"));
        let (_, t) = svc.submit_query(h, vec![1.0; 35], 4, SolveOptions::default());
        assert!(t.wait().outcome.unwrap_err().contains("does not match"));
        // k = 0 is not an error: the deterministic empty answer comes
        // back at submit time without a queue trip (the stack-wide k = 0
        // contract).
        let (_, t) = svc.submit_query(h, vec![1.0; 36], 0, SolveOptions::default());
        let empty = t.wait().outcome.expect("k = 0 answers the empty list");
        assert!(empty.entries.is_empty());
        assert_eq!(empty.generation, 1);
        let popts = crate::sparse::PprOptions::default();
        let (_, t) = svc.submit_ppr(h, crate::sparse::PprOptions { source: 36, ..popts.clone() }, SolveOptions::default());
        assert!(t.wait().outcome.unwrap_err().contains("out of range"));
        let (_, t) = svc.submit_ppr(h, crate::sparse::PprOptions { alpha: 1.0, ..popts.clone() }, SolveOptions::default());
        assert!(t.wait().outcome.unwrap_err().contains("alpha"));
        let (_, t) = svc.submit_ppr(h, crate::sparse::PprOptions { max_iters: 0, ..popts }, SolveOptions::default());
        assert!(t.wait().outcome.unwrap_err().contains("max_iters"));
        assert_eq!(svc.queue_depth(), 0, "rejected jobs never reach the queue");
        let stats = svc.stats();
        assert_eq!(stats.failed, 5);
        assert_eq!(stats.queries, 3, "two rejections plus one k = 0 empty");
        assert_eq!(stats.pprs, 3);
        // The worker still serves a valid query afterwards.
        let (_, t) = svc.submit_query(h, vec![1.0; 36], 3, SolveOptions::default());
        assert!(t.wait().outcome.is_ok());
        svc.shutdown();
    }

    #[test]
    fn fenced_queries_racing_updates_answer_for_a_complete_generation() {
        // Paused FIFO single replica: solve ordering is deterministic, so
        // the query before the update must answer generation 1 and the
        // query after it generation 2 — each bitwise equal to the oracle
        // of its own generation.
        let svc = EigenService::with_config(ServiceConfig { replicas: 1, paused: true, ..Default::default() });
        let m = graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 211);
        let h = svc.register(m.clone()).unwrap();
        let n = 1usize << 7;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();

        let mut canon = m.clone();
        canon.canonicalize();
        let mut delta = crate::sparse::CooDelta::new(n, n);
        for i in 0..canon.nnz() {
            let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
            if r <= c && c < 12 {
                delta.upsert_sym(r, c, canon.vals[i] * 2.5);
            }
        }
        assert!(!delta.is_empty());

        let oracle = |coo: &CooMatrix| {
            let fro = crate::sparse::frobenius_norm(coo);
            let csr = crate::coordinator::typed_csr_scaled::<f32>(coo, Some(1.0 / fro));
            let mut top = crate::sparse::top_k_serial(&csr, &x, 8);
            for e in &mut top {
                e.score = (f64::from(e.score) * fro) as f32;
            }
            top
        };
        let expect_g1 = oracle(&canon);
        let mut mutated = canon.clone();
        let mut d = delta.clone();
        d.canonicalize();
        mutated.apply_delta(&d);
        let expect_g2 = oracle(&mutated);
        assert_ne!(expect_g1, expect_g2, "the delta must move the ranking scores");

        let (_, q1) = svc.submit_query(h, x.clone(), 8, SolveOptions::default());
        let (_, tu) = svc.submit_update(h, delta);
        let (_, q2) = svc.submit_query(h, x.clone(), 8, SolveOptions::default());
        svc.resume();

        let a1 = q1.wait().outcome.expect("pre-update query");
        assert_eq!(a1.generation, 1);
        assert_eq!(a1.entries, expect_g1);
        assert!(tu.wait().outcome.is_ok());
        let a2 = q2.wait().outcome.expect("post-update query");
        assert_eq!(a2.generation, 2);
        assert_eq!(a2.entries, expect_g2);
        svc.shutdown();
    }

    #[test]
    fn queued_queries_coalesce_into_one_sweep_and_stay_bitwise_exact() {
        // Paused single replica: the queue holds five compatible k = 6
        // queries and one incompatible k = 3 query before dispatch
        // starts, so the first dequeue must coalesce exactly the five
        // into one batched sweep and leave the odd one alone.
        let svc = EigenService::with_config(ServiceConfig {
            replicas: 1,
            paused: true,
            batch_cap: 8,
            ..Default::default()
        });
        let n = 1usize << 8;
        let m = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 221);
        let h = svc.register(m.clone()).unwrap();
        let mk = |seed: usize| -> Vec<f32> {
            (0..n).map(|i| ((i * 31 + seed * 17 + 3) % 101) as f32 / 101.0 - 0.5).collect()
        };
        let queries: Vec<Vec<f32>> = (0..5).map(mk).collect();
        let tickets: Vec<_> = queries
            .iter()
            .map(|x| svc.submit_query(h, x.clone(), 6, SolveOptions::default()).1)
            .collect();
        let (_, odd) = svc.submit_query(h, queries[0].clone(), 3, SolveOptions::default());
        assert_eq!(svc.queue_depth(), 6);
        svc.resume();
        // Oracle: a coalescing-disabled service answering one at a time.
        let lone = EigenService::with_config(ServiceConfig { replicas: 1, batch_cap: 1, ..Default::default() });
        let hl = lone.register(m).unwrap();
        for (x, t) in queries.iter().zip(tickets) {
            let got = t.wait().outcome.expect("batched query");
            let want =
                lone.submit_query(hl, x.clone(), 6, SolveOptions::default()).1.wait().outcome.unwrap();
            assert_eq!(got, want, "coalesced member must be bitwise-identical to a lone query");
        }
        assert!(odd.wait().outcome.is_ok());
        let stats = svc.stats();
        assert_eq!(stats.queries, 6);
        assert_eq!(stats.query_batches, 1, "{stats:?}");
        assert_eq!(stats.batched_queries, 5, "the k = 3 query must not ride the k = 6 sweep");
        assert_eq!(stats.failed, 0);
        let lstats = lone.stats();
        assert_eq!(lstats.query_batches, 0, "batch_cap = 1 disables coalescing");
        svc.shutdown();
        lone.shutdown();
    }

    #[test]
    fn submit_query_batch_rejects_members_without_poisoning_siblings() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(8, 8, 0.9, 0.02, 29); // n = 64
        let h = svc.register(m.clone()).unwrap();
        let x_good: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11).cos()).collect();
        let tickets = svc.submit_query_batch(
            h,
            vec![x_good.clone(), vec![0.0; 63], x_good.clone()],
            4,
            SolveOptions::default(),
        );
        assert_eq!(tickets.len(), 3);
        let results: Vec<QueryResult> = tickets.into_iter().map(|(_, t)| t.wait()).collect();
        let a0 = results[0].outcome.as_ref().expect("good member");
        assert!(results[1].outcome.as_ref().unwrap_err().contains("does not match"));
        let a2 = results[2].outcome.as_ref().expect("good member");
        // The two valid members shared one sweep and match a lone query.
        let (_, t) = svc.submit_query(h, x_good, 4, SolveOptions::default());
        let lone = t.wait().outcome.unwrap();
        assert_eq!(*a0, lone);
        assert_eq!(*a2, lone);
        let stats = svc.stats();
        assert_eq!(stats.query_batches, 1);
        assert_eq!(stats.batched_queries, 2, "the rejected member never reaches the sweep");
        assert_eq!(stats.failed, 1);
        // k = 0 batch: deterministic empties, nothing enqueued.
        for (_, t) in svc.submit_query_batch(h, vec![vec![0.0; 64]; 2], 0, SolveOptions::default()) {
            let a = t.wait().outcome.expect("k = 0 empty");
            assert!(a.entries.is_empty());
        }
        // An unknown handle rejects every member.
        let reg = MatrixRegistry::default();
        let foreign = reg.register(m).unwrap();
        let r = svc
            .submit_query_batch(foreign, vec![vec![0.0; 64]], 4, SolveOptions::default())
            .pop()
            .unwrap()
            .1
            .wait();
        assert!(r.outcome.unwrap_err().contains("unknown matrix handle"));
        // An empty batch enqueues nothing.
        assert!(svc.submit_query_batch(h, Vec::new(), 4, SolveOptions::default()).is_empty());
        svc.shutdown();
    }

    #[test]
    fn ppr_warm_restart_reuses_the_previous_fixed_point_across_generations() {
        let svc = EigenService::with_config(ServiceConfig {
            replicas: 1,
            registry: RegistryConfig { warm_start: true, ..Default::default() },
            ..Default::default()
        });
        let m = graphs::mesh2d(12, 12, 0.9, 0.02, 33);
        let h = svc.register(m.clone()).unwrap();
        let popts = crate::sparse::PprOptions { source: 7, ..Default::default() };
        let cold =
            svc.submit_ppr(h, popts.clone(), SolveOptions::default()).1.wait().outcome.unwrap();
        assert!(cold.ppr.converged);
        assert!(!cold.ppr.warm_started);
        // A small delta bumps the generation; the cached fixed point
        // survives the registry's warm_keep_tol guard and seeds the next
        // walk, which converges in fewer matrix sweeps.
        let mut canon = m;
        canon.canonicalize();
        let mut delta = crate::sparse::CooDelta::new(canon.nrows, canon.ncols);
        let (r, c) = (canon.rows[0] as usize, canon.cols[0] as usize);
        delta.upsert_sym(r, c, canon.vals[0] * 1.01);
        assert!(svc.submit_update(h, delta).1.wait().outcome.is_ok());
        let warm = svc.submit_ppr(h, popts, SolveOptions::default()).1.wait().outcome.unwrap();
        assert_eq!(warm.generation, 2);
        assert!(warm.ppr.warm_started, "the seed must survive a small generation bump");
        assert!(warm.ppr.converged);
        assert!(
            warm.ppr.iterations < cold.ppr.iterations,
            "warm restart must save sweeps: warm {} vs cold {}",
            warm.ppr.iterations,
            cold.ppr.iterations
        );
        assert_eq!(svc.registry().stats().ppr_warm_hits, 1);
        svc.shutdown();
    }
}

//! Multi-tenant eigensolver service — the data-center deployment shape the
//! paper motivates (§I: "applications on top of Top-K eigenproblem are
//! mostly encountered in data centers").
//!
//! A leader thread owns a FIFO job queue; worker threads (one per
//! configured solver replica, mirroring the paper's multiple Jacobi cores
//! per SLR) pull jobs, run the two-phase solver, and deliver results
//! through per-job channels. Shutdown is graceful: pending jobs drain
//! unless `abort` is requested.
//!
//! ## Batched submission
//!
//! [`EigenService::submit_batch`] enqueues one *batch* of jobs over the
//! same matrix with different K values. A batch is scheduled as a unit on
//! one worker, which runs the O(nnz) prepare phase **once**
//! ([`Solver::prepare`]) and shares the resulting CSR + sharded SpMV
//! engine across all member solves — the same-matrix multi-K fast path.
//! Each member still gets its own [`JobResult`] through its own
//! [`Ticket`].
//!
//! ## Telemetry
//!
//! The service keeps queue/latency counters ([`ServiceStats`]) so a
//! deployment can watch saturation: submitted/completed/failed totals,
//! live queue depth, cumulative and maximum queue wait, and cumulative
//! solve time.

use crate::coordinator::{SolveOptions, Solution, Solver};
use crate::sparse::CooMatrix;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A submitted eigenproblem.
pub struct Job {
    /// Client-assigned identifier.
    pub id: u64,
    /// The matrix to decompose.
    pub matrix: CooMatrix,
    /// Per-job solve options.
    pub opts: SolveOptions,
    reply: Sender<JobResult>,
}

/// A batch of same-matrix jobs differing only in K.
struct BatchJob {
    ids: Vec<u64>,
    matrix: CooMatrix,
    opts: SolveOptions,
    ks: Vec<usize>,
    replies: Vec<Sender<JobResult>>,
}

enum QueueItem {
    Single(Job),
    Batch(BatchJob),
}

/// Result delivered to the submitter.
#[derive(Debug)]
pub struct JobResult {
    /// Job identifier.
    pub id: u64,
    /// Solution or an error string (solver errors must not kill workers).
    pub outcome: Result<Solution, String>,
    /// Queue wait time in seconds (for batch members: the batch's wait).
    pub queued_s: f64,
    /// Solver wall time in seconds (for batch members: this member's
    /// solve; the shared prepare cost is inside the first member's time).
    pub solve_s: f64,
}

/// Snapshot of the service's queue/latency counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceStats {
    /// Jobs submitted so far (batch members count individually).
    pub submitted: u64,
    /// Jobs finished (successfully or not).
    pub completed: u64,
    /// Jobs that finished with an error outcome.
    pub failed: u64,
    /// Batch submissions (`submit_batch` calls that enqueued work).
    pub batches: u64,
    /// Queue items currently waiting (a batch counts as one item).
    pub queue_depth: usize,
    /// Cumulative queue wait across finished jobs, seconds.
    pub total_queued_s: f64,
    /// Largest single queue wait observed, seconds.
    pub max_queued_s: f64,
    /// Cumulative solver wall time across finished jobs, seconds.
    pub total_solve_s: f64,
}

/// Internal atomic counters behind [`ServiceStats`]. Durations are stored
/// as integer microseconds so they can live in `AtomicU64`s.
#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    total_queued_us: AtomicU64,
    max_queued_us: AtomicU64,
    total_solve_us: AtomicU64,
}

impl Counters {
    fn record_result(&self, ok: bool, queued_s: f64, solve_s: f64) {
        self.completed.fetch_add(1, Ordering::SeqCst);
        if !ok {
            self.failed.fetch_add(1, Ordering::SeqCst);
        }
        let qus = (queued_s * 1e6) as u64;
        self.total_queued_us.fetch_add(qus, Ordering::SeqCst);
        self.max_queued_us.fetch_max(qus, Ordering::SeqCst);
        self.total_solve_us.fetch_add((solve_s * 1e6) as u64, Ordering::SeqCst);
    }
}

struct Shared {
    queue: Mutex<VecDeque<(QueueItem, std::time::Instant)>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// Handle returned by [`EigenService::submit`]; await with `recv`.
pub struct Ticket {
    rx: Receiver<JobResult>,
}

impl Ticket {
    /// Block until the job completes.
    pub fn wait(self) -> JobResult {
        self.rx.recv().expect("service dropped without reply")
    }
    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<JobResult> {
        self.rx.try_recv().ok()
    }
}

/// The service: leader queue + solver worker replicas.
pub struct EigenService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    counters: Arc<Counters>,
}

impl EigenService {
    /// Start `replicas` solver workers.
    pub fn start(replicas: usize) -> Self {
        assert!(replicas >= 1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let counters = Arc::new(Counters::default());
        let mut workers = Vec::with_capacity(replicas);
        for w in 0..replicas {
            let shared = Arc::clone(&shared);
            let counters = Arc::clone(&counters);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("eigen-worker-{w}"))
                    .spawn(move || loop {
                        let item = {
                            let mut q = shared.queue.lock().unwrap();
                            loop {
                                if let Some(item) = q.pop_front() {
                                    break Some(item);
                                }
                                if shared.shutdown.load(Ordering::SeqCst) {
                                    break None;
                                }
                                q = shared.available.wait(q).unwrap();
                            }
                        };
                        let Some((item, enqueued)) = item else { break };
                        let queued_s = enqueued.elapsed().as_secs_f64();
                        match item {
                            QueueItem::Single(job) => {
                                Self::run_single(job, queued_s, &counters);
                            }
                            QueueItem::Batch(batch) => {
                                Self::run_batch(batch, queued_s, &counters);
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { shared, workers, next_id: AtomicU64::new(1), counters }
    }

    fn run_single(job: Job, queued_s: f64, counters: &Counters) {
        let t0 = std::time::Instant::now();
        // A panicking solve must not take the worker down.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Solver::new(job.opts.clone()).solve(&job.matrix)
        }));
        let outcome = match outcome {
            Ok(Ok(sol)) => Ok(sol),
            Ok(Err(e)) => Err(e.to_string()),
            Err(_) => Err("solver panicked".to_string()),
        };
        let solve_s = t0.elapsed().as_secs_f64();
        counters.record_result(outcome.is_ok(), queued_s, solve_s);
        let _ = job.reply.send(JobResult { id: job.id, outcome, queued_s, solve_s });
    }

    fn run_batch(batch: BatchJob, queued_s: f64, counters: &Counters) {
        // Prepare once, then solve per K. A panicking prepare fails every
        // member; a panicking member solve fails only that member —
        // siblings keep their results. The shared prepare wall time is
        // charged to the first member's `solve_s` so the batch's total
        // solver time is conserved in the telemetry.
        let BatchJob { ids, matrix, opts, ks, replies } = batch;
        let prep_t0 = std::time::Instant::now();
        let prepared = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut solver = Solver::new(opts.clone());
            solver.prepare(&matrix).map(|p| (solver, p)).map_err(|e| e.to_string())
        }));
        let prep_s = prep_t0.elapsed().as_secs_f64();
        let outcomes: Vec<(Result<Solution, String>, f64)> = match prepared {
            Ok(Ok((mut solver, prep))) => ks
                .iter()
                .enumerate()
                .map(|(i, &k)| {
                    let t0 = std::time::Instant::now();
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        solver.solve_prepared_with_k(&prep, k).map_err(|e| e.to_string())
                    }))
                    .unwrap_or_else(|_| Err("solver panicked".to_string()));
                    let mut solve_s = t0.elapsed().as_secs_f64();
                    if i == 0 {
                        solve_s += prep_s;
                    }
                    (r, solve_s)
                })
                .collect(),
            Ok(Err(msg)) => ks
                .iter()
                .enumerate()
                .map(|(i, _)| (Err(msg.clone()), if i == 0 { prep_s } else { 0.0 }))
                .collect(),
            Err(_) => ks
                .iter()
                .enumerate()
                .map(|(i, _)| {
                    (Err("solver panicked".to_string()), if i == 0 { prep_s } else { 0.0 })
                })
                .collect(),
        };
        for ((id, reply), (outcome, solve_s)) in
            ids.into_iter().zip(replies).zip(outcomes)
        {
            counters.record_result(outcome.is_ok(), queued_s, solve_s);
            let _ = reply.send(JobResult { id, outcome, queued_s, solve_s });
        }
    }

    /// Enqueue a job; returns a [`Ticket`] to await the result.
    pub fn submit(&self, matrix: CooMatrix, opts: SolveOptions) -> (u64, Ticket) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        let job = Job { id, matrix, opts, reply: tx };
        self.counters.submitted.fetch_add(1, Ordering::SeqCst);
        self.shared
            .queue
            .lock()
            .unwrap()
            .push_back((QueueItem::Single(job), std::time::Instant::now()));
        self.shared.available.notify_one();
        (id, Ticket { rx })
    }

    /// Enqueue one batch of same-matrix jobs, one per entry of `ks`.
    ///
    /// The batch is scheduled as a unit on one worker; the prepare phase
    /// (canonicalize + normalize + CSR + sharded-engine build) runs once
    /// and is shared by every member solve. Returns one `(id, Ticket)`
    /// pair per K, in the same order as `ks`. An empty `ks` enqueues
    /// nothing and returns an empty vector.
    pub fn submit_batch(
        &self,
        matrix: CooMatrix,
        opts: SolveOptions,
        ks: &[usize],
    ) -> Vec<(u64, Ticket)> {
        if ks.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(ks.len());
        let mut ids = Vec::with_capacity(ks.len());
        let mut replies = Vec::with_capacity(ks.len());
        for _ in ks {
            let id = self.next_id.fetch_add(1, Ordering::SeqCst);
            let (tx, rx) = channel();
            ids.push(id);
            replies.push(tx);
            out.push((id, Ticket { rx }));
        }
        self.counters.submitted.fetch_add(ks.len() as u64, Ordering::SeqCst);
        self.counters.batches.fetch_add(1, Ordering::SeqCst);
        let batch = BatchJob { ids, matrix, opts, ks: ks.to_vec(), replies };
        self.shared
            .queue
            .lock()
            .unwrap()
            .push_back((QueueItem::Batch(batch), std::time::Instant::now()));
        self.shared.available.notify_one();
        out
    }

    /// Jobs finished so far.
    pub fn completed(&self) -> u64 {
        self.counters.completed.load(Ordering::SeqCst)
    }

    /// Current queue depth (items: a batch counts as one).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Snapshot the queue/latency counters.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            submitted: self.counters.submitted.load(Ordering::SeqCst),
            completed: self.counters.completed.load(Ordering::SeqCst),
            failed: self.counters.failed.load(Ordering::SeqCst),
            batches: self.counters.batches.load(Ordering::SeqCst),
            queue_depth: self.queue_depth(),
            total_queued_s: self.counters.total_queued_us.load(Ordering::SeqCst) as f64 / 1e6,
            max_queued_s: self.counters.max_queued_us.load(Ordering::SeqCst) as f64 / 1e6,
            total_solve_s: self.counters.total_solve_us.load(Ordering::SeqCst) as f64 / 1e6,
        }
    }

    /// Drain the queue and stop workers.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for EigenService {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs;

    #[test]
    fn serves_concurrent_jobs() {
        let svc = EigenService::start(3);
        let mut tickets = Vec::new();
        for seed in 0..6u64 {
            let m = graphs::mesh2d(12, 12, 0.9, 0.02, seed);
            let (id, t) = svc.submit(m, SolveOptions { k: 4, ..Default::default() });
            tickets.push((id, t));
        }
        for (id, t) in tickets {
            let r = t.wait();
            assert_eq!(r.id, id);
            let sol = r.outcome.expect("solve failed");
            assert_eq!(sol.k(), 4);
            assert!(r.queued_s >= 0.0);
            assert!(r.solve_s >= 0.0);
        }
        assert_eq!(svc.completed(), 6);
        let stats = svc.stats();
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.batches, 0);
        assert!(stats.total_solve_s >= 0.0);
        assert!(stats.max_queued_s <= stats.total_queued_s + 1e-9);
        svc.shutdown();
    }

    #[test]
    fn bad_job_reports_error_without_killing_worker() {
        let svc = EigenService::start(1);
        // Non-square matrix -> error, not a dead worker.
        let bad = CooMatrix::new(4, 5);
        let (_, t1) = svc.submit(bad, SolveOptions::default());
        assert!(t1.wait().outcome.is_err());
        // Worker must still serve the next job.
        let good = graphs::mesh2d(8, 8, 0.9, 0.02, 1);
        let (_, t2) = svc.submit(good, SolveOptions { k: 2, ..Default::default() });
        assert!(t2.wait().outcome.is_ok());
        assert_eq!(svc.stats().failed, 1);
        svc.shutdown();
    }

    #[test]
    fn shutdown_with_empty_queue_is_clean() {
        let svc = EigenService::start(2);
        assert_eq!(svc.queue_depth(), 0);
        svc.shutdown();
    }

    #[test]
    fn batch_matches_individual_submissions() {
        let svc = EigenService::start(2);
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 31);
        let ks = [2usize, 4, 6];
        let batch = svc.submit_batch(m.clone(), SolveOptions::default(), &ks);
        assert_eq!(batch.len(), 3);
        let mut singles = Vec::new();
        for &k in &ks {
            let (_, t) = svc.submit(m.clone(), SolveOptions { k, ..Default::default() });
            singles.push(t);
        }
        for (((_, bt), st), &k) in batch.into_iter().zip(singles).zip(&ks) {
            let b = bt.wait().outcome.expect("batch member failed");
            let s = st.wait().outcome.expect("single failed");
            assert_eq!(b.k(), s.k(), "k={k}");
            for i in 0..b.k() {
                assert!(
                    (b.eigenvalues[i] - s.eigenvalues[i]).abs() < 1e-9,
                    "k={k} pair {i}: batch {} vs single {}",
                    b.eigenvalues[i],
                    s.eigenvalues[i]
                );
            }
        }
        let stats = svc.stats();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.completed, 6);
        svc.shutdown();
    }

    #[test]
    fn batch_member_error_does_not_poison_siblings() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(6, 6, 0.9, 0.02, 2); // n = 36
        // k = 100 > n fails; the others succeed.
        let tickets = svc.submit_batch(m, SolveOptions::default(), &[4, 100, 6]);
        let results: Vec<JobResult> = tickets.into_iter().map(|(_, t)| t.wait()).collect();
        assert!(results[0].outcome.is_ok());
        assert!(results[1].outcome.is_err());
        assert!(results[2].outcome.is_ok());
        assert_eq!(svc.stats().failed, 1);
        svc.shutdown();
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let svc = EigenService::start(1);
        let m = graphs::mesh2d(4, 4, 0.9, 0.02, 3);
        assert!(svc.submit_batch(m, SolveOptions::default(), &[]).is_empty());
        assert_eq!(svc.stats().submitted, 0);
        assert_eq!(svc.stats().batches, 0);
        svc.shutdown();
    }
}

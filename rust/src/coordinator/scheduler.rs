//! K-aware job scheduling over reconfigurable Jacobi cores (§IV-C).
//!
//! The bitstream hosts Jacobi cores compiled for specific K values and
//! "opening the doors for independent optimization on specific values of
//! K by reconfiguring individual SLRs". Reconfiguring an SLR is expensive
//! (partial-reconfiguration latency is orders of magnitude above a
//! solve's Jacobi phase), so a multi-tenant deployment should batch jobs
//! by their K-core. This module models that decision:
//!
//! * [`CoreFarm`] — a set of reconfigurable cores, each currently loaded
//!   with one K-variant and a reconfiguration cost to switch;
//! * [`schedule`] — assigns a job list under [`Policy::Fifo`] (arrival
//!   order, greedy earliest-free core) or [`Policy::KBatched`] (group by
//!   K-core first), returning the makespan and reconfiguration count.
//!
//! The `ablation_scheduler` bench quantifies the win on mixed workloads.

use crate::runtime::ArtifactRegistry;

/// One schedulable eigenproblem: its Jacobi core requirement and its
/// estimated total solve time (Lanczos dominates; the estimate typically
/// comes from [`crate::fpga::FpgaTimingModel`]).
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    /// Requested eigencomponents.
    pub k: usize,
    /// Estimated solve seconds (excluding reconfiguration).
    pub solve_s: f64,
}

/// Scheduling policy. This is also the **live** queue-policy type of
/// [`crate::coordinator::service::EigenService`] (re-exported there as
/// `QueuePolicy`): the offline model below and the deployed dispatch loop
/// share one type, so they cannot drift apart silently.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Arrival order, greedy earliest-available core.
    Fifo,
    /// Stable-sort jobs by K-core, then greedy — amortizes reconfigs.
    KBatched,
}

impl Policy {
    /// Name for reports and CLI flags.
    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::KBatched => "kbatched",
        }
    }

    /// Parse a CLI spelling (`fifo` | `kbatched`).
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "kbatched" | "k-batched" => Some(Policy::KBatched),
            _ => None,
        }
    }
}

/// The Jacobi core class a K-value runs on: the smallest compiled core that
/// fits (`ArtifactRegistry::pick_jacobi`), or the next power of two for
/// K beyond the shipped bitstream (soft-core fallback — still a distinct
/// reconfiguration class). Both the offline [`schedule`] model and the live
/// service queue group jobs by this class.
pub fn core_for_k(k: usize) -> usize {
    ArtifactRegistry::pick_jacobi(k).unwrap_or_else(|| k.max(4).next_power_of_two())
}

/// A farm of reconfigurable Jacobi cores.
#[derive(Clone, Debug)]
pub struct CoreFarm {
    /// Currently-loaded K per core (the shipped bitstream: K=32 on SLR1,
    /// two K=16 cores on SLR2).
    pub loaded_k: Vec<usize>,
    /// Partial-reconfiguration latency (seconds). U280 SLR-sized partial
    /// bitstreams take ~100 ms over PCIe ICAP.
    pub reconfig_s: f64,
}

impl Default for CoreFarm {
    fn default() -> Self {
        Self { loaded_k: vec![32, 16, 16], reconfig_s: 0.1 }
    }
}

/// Outcome of scheduling a job list.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleReport {
    /// Wall time until the last job finishes (seconds).
    pub makespan_s: f64,
    /// Reconfigurations performed.
    pub reconfigs: usize,
    /// Per-job completion times in submission order.
    pub completion_s: Vec<f64>,
}

/// Simulate the farm executing `jobs` under `policy`.
///
/// Jobs whose K exceeds every available core size are rejected with an
/// error naming the job index.
pub fn schedule(farm: &CoreFarm, jobs: &[JobSpec], policy: Policy) -> Result<ScheduleReport, String> {
    // Resolve each job to its required core variant.
    let mut order: Vec<(usize, usize)> = Vec::with_capacity(jobs.len()); // (job idx, core k)
    for (i, j) in jobs.iter().enumerate() {
        let core = ArtifactRegistry::pick_jacobi(j.k)
            .ok_or_else(|| format!("job {i}: k={} exceeds the largest core (32)", j.k))?;
        order.push((i, core));
    }
    if policy == Policy::KBatched {
        // Stable sort: groups identical cores, preserves arrival order
        // within a group (fairness inside the batch).
        order.sort_by_key(|&(_, core)| core);
    }

    let mut free_at = vec![0.0f64; farm.loaded_k.len()];
    let mut loaded = farm.loaded_k.clone();
    let mut completion = vec![0.0f64; jobs.len()];
    let mut reconfigs = 0usize;

    for &(ji, core) in &order {
        // Pick the core minimizing start + (reconfig if needed); ties go to
        // the one already loaded with the right K.
        let mut best: Option<(usize, f64, bool)> = None;
        for (c, &t_free) in free_at.iter().enumerate() {
            let needs = loaded[c] != core;
            let ready = t_free + if needs { farm.reconfig_s } else { 0.0 };
            let better = match best {
                None => true,
                Some((_, bready, bneeds)) => ready < bready || (ready == bready && bneeds && !needs),
            };
            if better {
                best = Some((c, ready, needs));
            }
        }
        let (c, ready, needs) = best.expect("farm has at least one core");
        if needs {
            reconfigs += 1;
            loaded[c] = core;
        }
        let done = ready + jobs[ji].solve_s;
        free_at[c] = done;
        completion[ji] = done;
    }
    let makespan_s = free_at.iter().fold(0.0f64, |a, &b| a.max(b));
    Ok(ScheduleReport { makespan_s, reconfigs, completion_s: completion })
}

/// The batched-SpMM coalescing rule, shared by the live dispatch loop and
/// the unit tests below (the same one-pure-function idiom as `select_next`,
/// so the deployed behavior and the modeled one cannot drift apart).
///
/// `keys[i]` is the batch-compatibility key of the `i`-th *remaining*
/// queue entry (`None` for entries that are not coalescible queries —
/// solves, updates, PPRs). Given the key of a query already dequeued at
/// the head of a batch, returns the queue indices (arrival order) of up to
/// `cap - 1` further entries with the same key — together they form one
/// SpMM batch that streams the matrix once.
///
/// Arrival order is preserved and nothing is skipped *within* the batch
/// window: an incompatible entry does not end the scan (it simply stays
/// queued, to be dispatched on its own later), so one odd query cannot
/// break up an otherwise coalescible burst. Starvation is bounded by the
/// existing policy machinery: coalescing only ever removes entries that
/// arrived no later than the scan's last match, and the head entry was
/// chosen by `select_next` in the first place.
pub fn coalesce_window(keys: &[Option<u64>], head_key: u64, cap: usize) -> Vec<usize> {
    let want = cap.saturating_sub(1);
    let mut picked = Vec::new();
    if want == 0 {
        return picked;
    }
    for (i, key) in keys.iter().enumerate() {
        if *key == Some(head_key) {
            picked.push(i);
            if picked.len() == want {
                break;
            }
        }
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_jobs(n: usize) -> Vec<JobSpec> {
        // Alternating K classes, constant solve time: worst case for FIFO.
        (0..n)
            .map(|i| JobSpec { k: if i % 2 == 0 { 8 } else { 24 }, solve_s: 0.02 })
            .collect()
    }

    #[test]
    fn kbatched_beats_fifo_when_cores_are_scarce() {
        // One core serving two K-classes: FIFO alternation reconfigures on
        // nearly every job; batching pays one reconfiguration total.
        let farm = CoreFarm { loaded_k: vec![32], reconfig_s: 0.1 };
        let jobs = mixed_jobs(24);
        let fifo = schedule(&farm, &jobs, Policy::Fifo).unwrap();
        let batched = schedule(&farm, &jobs, Policy::KBatched).unwrap();
        assert!(
            batched.makespan_s < fifo.makespan_s / 2.0,
            "batched {} vs fifo {}",
            batched.makespan_s,
            fifo.makespan_s
        );
        // Sorted order visits the K=8 class first (core loaded with 32), then
        // K=32: two switches total.
        assert!(batched.reconfigs <= 2, "reconfigs {}", batched.reconfigs);
        assert!(fifo.reconfigs >= 20, "alternation thrashes: {}", fifo.reconfigs);
    }

    #[test]
    fn kbatched_never_worse_than_fifo_on_shipped_farm() {
        // With the shipped 3-core farm the greedy FIFO picker already
        // specializes cores per K-class; batching must still not lose.
        let farm = CoreFarm::default();
        for n in [6usize, 24, 60] {
            let jobs = mixed_jobs(n);
            let fifo = schedule(&farm, &jobs, Policy::Fifo).unwrap();
            let batched = schedule(&farm, &jobs, Policy::KBatched).unwrap();
            assert!(
                batched.makespan_s <= fifo.makespan_s * 1.25 + farm.reconfig_s,
                "n={n}: batched {} vs fifo {}",
                batched.makespan_s,
                fifo.makespan_s
            );
        }
    }

    #[test]
    fn uniform_k_needs_no_extra_reconfigs() {
        let farm = CoreFarm { loaded_k: vec![16, 16], reconfig_s: 0.1 };
        let jobs: Vec<JobSpec> = (0..10).map(|_| JobSpec { k: 12, solve_s: 0.01 }).collect();
        let r = schedule(&farm, &jobs, Policy::Fifo).unwrap();
        assert_eq!(r.reconfigs, 0, "k=12 runs on the loaded K=16 cores");
        // Two cores, ten 10ms jobs: makespan = 5 jobs each = 50ms.
        assert!((r.makespan_s - 0.05).abs() < 1e-9);
    }

    #[test]
    fn oversized_k_rejected_with_job_index() {
        let farm = CoreFarm::default();
        let jobs = vec![JobSpec { k: 8, solve_s: 0.01 }, JobSpec { k: 40, solve_s: 0.01 }];
        let err = schedule(&farm, &jobs, Policy::Fifo).unwrap_err();
        assert!(err.contains("job 1"), "{err}");
    }

    #[test]
    fn completion_times_cover_every_job() {
        let farm = CoreFarm::default();
        let jobs = mixed_jobs(9);
        let r = schedule(&farm, &jobs, Policy::KBatched).unwrap();
        assert_eq!(r.completion_s.len(), 9);
        assert!(r.completion_s.iter().all(|&t| t > 0.0));
        let max = r.completion_s.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!((max - r.makespan_s).abs() < 1e-12);
    }

    #[test]
    fn core_classes_and_policy_names() {
        assert_eq!(core_for_k(8), 8);
        assert_eq!(core_for_k(12), 16);
        assert_eq!(core_for_k(32), 32);
        // Beyond the shipped bitstream: next-power-of-two soft-core class.
        assert_eq!(core_for_k(40), 64);
        assert_eq!(core_for_k(1), 4);
        assert_eq!(Policy::Fifo.name(), "fifo");
        assert_eq!(Policy::parse("kbatched"), Some(Policy::KBatched));
        assert_eq!(Policy::parse("k-batched"), Some(Policy::KBatched));
        assert_eq!(Policy::parse("lifo"), None);
    }

    #[test]
    fn coalesce_window_picks_compatible_queries_in_arrival_order() {
        // Keys: two compatible bursts (7) split by an incompatible query
        // (9) and a non-query entry (None). The odd entries never end the
        // scan and are never picked.
        let keys = vec![Some(7), Some(9), None, Some(7), Some(7)];
        assert_eq!(coalesce_window(&keys, 7, 8), vec![0, 3, 4]);
        // The cap counts the already-dequeued head: cap 3 = head + 2 more.
        assert_eq!(coalesce_window(&keys, 7, 3), vec![0, 3]);
        // cap <= 1 disables coalescing entirely.
        assert!(coalesce_window(&keys, 7, 1).is_empty());
        assert!(coalesce_window(&keys, 7, 0).is_empty());
        // No compatible entries: empty window, batch of one.
        assert!(coalesce_window(&keys, 42, 8).is_empty());
        assert!(coalesce_window(&[], 7, 8).is_empty());
    }

    #[test]
    fn reconfig_cost_drives_the_policy_gap() {
        // With zero reconfiguration cost the policies tie.
        let farm = CoreFarm { loaded_k: vec![32, 16], reconfig_s: 0.0 };
        let jobs = mixed_jobs(16);
        let fifo = schedule(&farm, &jobs, Policy::Fifo).unwrap();
        let batched = schedule(&farm, &jobs, Policy::KBatched).unwrap();
        assert!((fifo.makespan_s - batched.makespan_s).abs() < 1e-9);
    }
}

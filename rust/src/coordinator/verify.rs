//! Solution verification — the paper's Fig 11 accuracy metrics (§V-C).
//!
//! * **Orthogonality**: eigenvectors must form an orthonormal basis; we
//!   report the mean pairwise angle in degrees (ideal 90, paper reports
//!   > 89.9 with reorth-every-2).
//! * **Reconstruction error**: mean `||M v - lambda v||_2` over the K
//!   pairs (paper reports < 1e-3 on normalized matrices).
//!
//! Note the convention: the paper measures on the *Frobenius-normalized*
//! operator, so `verify` renormalizes internally before computing
//! residuals — otherwise the metric would scale with `||M||_F` and be
//! incomparable across graphs.

use crate::coordinator::Solution;
use crate::linalg::{self, mean_pairwise_angle_deg};
use crate::sparse::CooMatrix;

/// Accuracy report for one solution.
#[derive(Clone, Copy, Debug)]
pub struct VerifyReport {
    /// Mean pairwise angle between eigenvectors, degrees (ideal: 90).
    pub mean_angle_deg: f64,
    /// Worst pairwise |dot| between distinct eigenvectors.
    pub max_cross_dot: f64,
    /// Mean `||Mv - lambda v||` on the normalized operator.
    pub mean_residual: f64,
    /// Max residual across pairs.
    pub max_residual: f64,
}

/// Compute Fig 11 metrics for `sol` against the original matrix.
pub fn verify(matrix: &CooMatrix, sol: &Solution) -> VerifyReport {
    let k = sol.k();
    assert!(k >= 1, "empty solution");
    // Orthogonality.
    let mean_angle_deg = mean_pairwise_angle_deg(&sol.eigenvectors);
    let mut max_cross_dot = 0.0f64;
    for i in 0..k {
        for j in 0..i {
            max_cross_dot = max_cross_dot.max(linalg::dot(&sol.eigenvectors[i], &sol.eigenvectors[j]).abs());
        }
    }
    // Residuals on the normalized operator: lambda_norm = lambda / ||M||_F.
    let inv_fro = 1.0 / sol.frobenius_norm;
    let mut mean_residual = 0.0f64;
    let mut max_residual = 0.0f64;
    for (lambda, v) in sol.pairs() {
        let mv = matrix.spmv_ref(v);
        let lam_n = lambda * inv_fro;
        let mut r2 = 0.0f64;
        for (mvi, vi) in mv.iter().zip(v) {
            let d = *mvi as f64 * inv_fro - lam_n * *vi as f64;
            r2 += d * d;
        }
        let r = r2.sqrt();
        mean_residual += r;
        max_residual = max_residual.max(r);
    }
    mean_residual /= k as f64;
    VerifyReport { mean_angle_deg, max_cross_dot, mean_residual, max_residual }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{SolveOptions, Solver};
    use crate::graphs;
    use crate::lanczos::ReorthPolicy;

    #[test]
    fn accurate_solution_passes_paper_thresholds() {
        // A spectrum with an 8-dimensional dominant invariant subspace:
        // K-step Lanczos converges the top pairs to high accuracy, so the
        // paper's Fig 11 thresholds apply even at unit-test scale.
        let mut m = crate::sparse::CooMatrix::new(256, 256);
        for i in 0..256 {
            let d = if i < 8 { 0.9 - 0.1 * i as f32 } else { 1e-4 / (i as f32) };
            m.push(i, i, d);
        }
        // k slightly above the dominant dimension so the last Ritz pairs
        // land in the tiny tail subspace instead of straddling the gap.
        let mut s = Solver::new(SolveOptions { k: 12, reorth: ReorthPolicy::Every, ..Default::default() });
        let sol = s.solve(&m).unwrap();
        let r = verify(&m, &sol);
        assert!(r.mean_angle_deg > 89.9, "angle {}", r.mean_angle_deg);
        assert!(r.mean_residual < 1e-3, "residual {}", r.mean_residual);
        assert!(r.max_residual >= r.mean_residual);
        assert!(r.max_cross_dot < 1e-2);
    }

    #[test]
    fn graph_scale_residual_is_modest() {
        let m = graphs::mesh2d(24, 24, 0.9, 0.02, 9);
        let mut s = Solver::new(SolveOptions { k: 8, reorth: ReorthPolicy::Every, ..Default::default() });
        let sol = s.solve(&m).unwrap();
        let r = verify(&m, &sol);
        assert!(r.mean_angle_deg > 89.5, "angle {}", r.mean_angle_deg);
        assert!(r.mean_residual < 5e-2, "residual {}", r.mean_residual);
    }

    #[test]
    fn no_reorth_degrades_orthogonality_at_large_k() {
        let m = graphs::rmat(1 << 8, 10 << 8, 0.6, 0.18, 0.18, 3);
        let mut with = Solver::new(SolveOptions { k: 20, reorth: ReorthPolicy::EveryN(2), ..Default::default() });
        let mut without = Solver::new(SolveOptions { k: 20, reorth: ReorthPolicy::None, ..Default::default() });
        let rw = verify(&m, &with.solve(&m).unwrap());
        let ro = verify(&m, &without.solve(&m).unwrap());
        assert!(
            rw.max_cross_dot <= ro.max_cross_dot + 1e-12,
            "reorth should not be worse: {} vs {}",
            rw.max_cross_dot,
            ro.max_cross_dot
        );
    }
}

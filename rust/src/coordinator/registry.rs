//! The shared prepared-engine registry — matrix-resident serving.
//!
//! The paper motivates Top-K eigensolvers as data-center infrastructure
//! (§I) where the *same* enormous graph is queried over and over, and its
//! §IV-C reconfigurable-core discussion schedules jobs *around resident
//! state*. SSD- and multi-GPU-scale follow-ups (Zheng et al.,
//! arXiv:1602.01421; arXiv:2201.07498) draw the same conclusion: at scale
//! the matrix is the resident asset and solves are the cheap, concurrent
//! part. [`MatrixRegistry`] is that inversion for the service layer:
//!
//! * [`MatrixRegistry::register`] ingests a `CooMatrix` **once** —
//!   canonicalize in place (no COO clone), symmetry-check, Frobenius-
//!   normalize — and returns a small [`MatrixHandle`]. Registration
//!   deduplicates by content hash (full equality compare on a hash match),
//!   so two tenants registering the same graph share one residency.
//! * [`MatrixRegistry::prepared`] returns the `Arc<PreparedMatrix>` for a
//!   `(handle, precision, engine, geometry)` key, building it **exactly
//!   once** (concurrent callers for the same key block on a per-key latch;
//!   callers for different keys build in parallel) — the prepare-count
//!   telemetry in [`RegistryStats`] pins this.
//! * Cached engines are evicted least-recently-used against a byte budget
//!   ([`RegistryConfig::budget_bytes`]), charged at
//!   [`PreparedMatrix::resident_bytes`] (the COO-line convention the
//!   datapath telemetry already uses). Eviction only drops the registry's
//!   `Arc`; in-flight solves keep their engine alive until they finish.
//! * A warm-start cache ([`RegistryConfig::warm_start`]) remembers the
//!   dominant Ritz vector of each completed `(handle, k, precision)` query
//!   so repeated queries seed Lanczos `v1` from the previous answer
//!   instead of the uniform start — fewer effective iterations to the same
//!   invariant subspace on slowly-drifting production graphs.
//!
//! Worker replicas then run [`crate::coordinator::Solver::solve_detached`]
//! against the shared engine concurrently, each with its own
//! [`crate::lanczos::LanczosWorkspace`] — zero per-job COO clones, zero
//! redundant prepare work.

use crate::coordinator::{native_operator_from_canonical, select_engine, Engine, PreparedMatrix, SolveOptions};
use crate::fixed::Precision;
use crate::runtime::{PjrtSpmv, Runtime};
use crate::sparse::{CooMatrix, PartitionPolicy};
use crate::util::pool::ThreadPool;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Poison-resistant lock: a panic inside a registry section (e.g. an
/// engine build hitting a pathological matrix) must cost that one request,
/// not brick every later job on the registry or on one engine key. All
/// guarded state stays valid across an unwind mid-section: maps are
/// updated with single insert/remove calls and a half-built engine slot is
/// simply `None`, which the next caller rebuilds.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Opaque handle to a registered matrix. Cheap to copy, hash, and send —
/// this is what service jobs carry instead of an owned `CooMatrix`.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct MatrixHandle(u64);

impl MatrixHandle {
    /// The numeric id (stable for the registry's lifetime; for logs).
    pub fn id(&self) -> u64 {
        self.0
    }
}

/// Registry configuration.
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Byte budget for cached prepared engines (LRU-evicted beyond it);
    /// `0` = unlimited. An engine larger than the whole budget is still
    /// served and cached — only *other* engines can be evicted for it.
    pub budget_bytes: usize,
    /// Seed repeated `(handle, k, precision)` queries with the previous
    /// dominant Ritz vector. Off by default: a warm start is no longer
    /// bit-identical to the cold solve (so deterministic replay paths
    /// should leave it off), and a seed lying too close to an exact
    /// eigenvector can truncate the Krylov subspace — the service's
    /// handle path retries such solves cold so callers still get K pairs.
    pub warm_start: bool,
    /// Skip the O(nnz) symmetry check at registration (trusted sources).
    pub skip_symmetry_check: bool,
    /// Register matrices as-is without Frobenius normalization (inputs
    /// already normalized; mirrors [`SolveOptions::skip_normalize`]).
    pub skip_normalize: bool,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self { budget_bytes: 0, warm_start: false, skip_symmetry_check: false, skip_normalize: false }
    }
}

/// Snapshot of the registry's telemetry counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RegistryStats {
    /// Registered (distinct) matrices currently resident.
    pub matrices: usize,
    /// Prepared engines currently cached.
    pub engines: usize,
    /// Estimated bytes of all cached engines.
    pub resident_bytes: usize,
    /// Engine builds performed ([`crate::coordinator::Solver::prepare`]-
    /// equivalent work). The acceptance bar: M jobs against one registered
    /// handle and one engine key leave this at exactly 1.
    pub prepares: u64,
    /// `prepared` calls served from the cache (no build).
    pub engine_hits: u64,
    /// Registrations that deduplicated onto an existing handle.
    pub dedup_hits: u64,
    /// Engines evicted by the byte-budget LRU.
    pub evictions: u64,
    /// Warm-start cache entries currently held.
    pub warm_entries: usize,
    /// Warm-start seeds served.
    pub warm_hits: u64,
}

struct Source {
    coo: Arc<CooMatrix>,
    fro: f64,
    /// Content hash computed at registration — kept so `unregister` can
    /// maintain `by_hash` without an O(nnz) re-hash under the lock.
    hash: u64,
}

/// Engine identity: one prepared engine per handle x storage format x
/// engine kind x shard geometry.
#[derive(Clone, PartialEq, Eq, Hash)]
struct EngineKey {
    handle: u64,
    precision: Precision,
    engine: Engine,
    cus: usize,
    partition: PartitionPolicy,
    threads: usize,
}

impl EngineKey {
    fn for_opts(handle: MatrixHandle, opts: &SolveOptions) -> Self {
        Self {
            handle: handle.0,
            precision: opts.precision,
            engine: Self::effective_engine(opts.engine, opts.precision),
            cus: opts.cus,
            partition: opts.partition,
            threads: opts.effective_threads(),
        }
    }

    /// Collapse PJRT requests that are statically known to fall back onto
    /// the native key, so `Engine::Pjrt` and `Engine::Native` requests for
    /// the same matrix share one cached engine (and one CU pool) instead
    /// of byte-identical twins: fixed-point formats always fall back (the
    /// artifacts are f32), and a build without the `pjrt` feature always
    /// falls back (stub runtime). A feature-enabled f32 request that fails
    /// at runtime (missing artifact, no fitting shape) still caches its
    /// native fallback under the Pjrt key — accepted duplication for that
    /// rare case.
    fn effective_engine(engine: Engine, precision: Precision) -> Engine {
        match engine {
            Engine::Pjrt if precision != Precision::Float32 => Engine::Native,
            Engine::Pjrt if !cfg!(feature = "pjrt") => Engine::Native,
            e => e,
        }
    }
}

struct EngineSlot {
    /// Build-once latch: concurrent `prepared` calls for one key serialize
    /// here (not on the registry lock), so different keys build in
    /// parallel while the same key is never built twice.
    cell: Arc<Mutex<Option<Arc<PreparedMatrix>>>>,
    last_used: u64,
    /// 0 while the build is in flight (pending slots are never evicted).
    bytes: usize,
}

type WarmKey = (u64, usize, Precision);

/// Bound on warm-start entries (each is an n-length f32 vector).
const WARM_CAP: usize = 256;

/// One warm-start cache slot: a usable seed, or a negative entry for keys
/// where warm-starting proved counterproductive (the seed collapsed the
/// Krylov subspace) — those queries run cold permanently instead of
/// paying a truncated warm solve plus a cold retry on every repeat.
enum WarmEntry {
    Seed(Vec<f32>),
    Disabled,
}

struct Inner {
    sources: HashMap<u64, Source>,
    by_hash: HashMap<u64, Vec<u64>>,
    engines: HashMap<EngineKey, EngineSlot>,
    warm: HashMap<WarmKey, WarmEntry>,
    warm_order: VecDeque<WarmKey>,
    tick: u64,
}

/// Handle ids are process-globally unique (not per-registry), so a handle
/// from one registry can never silently alias a different matrix in
/// another — a lookup with a foreign handle fails instead of answering
/// the wrong question.
static NEXT_HANDLE_ID: AtomicU64 = AtomicU64::new(1);

/// The shared prepared-engine registry (see module docs).
pub struct MatrixRegistry {
    cfg: RegistryConfig,
    inner: Mutex<Inner>,
    /// Lazy PJRT runtime for `Engine::Pjrt` keys (mirrors `Solver`).
    runtime: Mutex<Option<Arc<Runtime>>>,
    prepares: AtomicU64,
    engine_hits: AtomicU64,
    dedup_hits: AtomicU64,
    evictions: AtomicU64,
    warm_hits: AtomicU64,
}

impl Default for MatrixRegistry {
    fn default() -> Self {
        Self::new(RegistryConfig::default())
    }
}

impl MatrixRegistry {
    /// Empty registry under `cfg`.
    pub fn new(cfg: RegistryConfig) -> Self {
        Self {
            cfg,
            inner: Mutex::new(Inner {
                sources: HashMap::new(),
                by_hash: HashMap::new(),
                engines: HashMap::new(),
                warm: HashMap::new(),
                warm_order: VecDeque::new(),
                tick: 0,
            }),
            runtime: Mutex::new(None),
            prepares: AtomicU64::new(0),
            engine_hits: AtomicU64::new(0),
            dedup_hits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
        }
    }

    /// The active configuration.
    pub fn config(&self) -> &RegistryConfig {
        &self.cfg
    }

    /// Ingest a matrix: canonicalize **in place** (the registry owns the
    /// buffers — no COO clone anywhere on this path), check symmetry,
    /// Frobenius-normalize, and deduplicate against already-registered
    /// content. Returns the handle service jobs carry from here on.
    pub fn register(&self, mut m: CooMatrix) -> Result<MatrixHandle> {
        anyhow::ensure!(m.nrows > 0, "matrix must be non-empty");
        let fro =
            crate::coordinator::canonicalize_ingest(&mut m, self.cfg.skip_symmetry_check, self.cfg.skip_normalize)?;
        let hash = m.content_hash();
        let mut inner = lock(&self.inner);
        if let Some(ids) = inner.by_hash.get(&hash) {
            for &id in ids {
                let s = &inner.sources[&id];
                // Equal normalized content AND equal norm: a scaled copy of
                // a registered graph normalizes to the same entries but a
                // different Frobenius norm, and must get its own handle so
                // its eigenvalues rescale correctly.
                if s.fro.to_bits() == fro.to_bits() && *s.coo == m {
                    self.dedup_hits.fetch_add(1, Ordering::SeqCst);
                    return Ok(MatrixHandle(id));
                }
            }
        }
        let id = NEXT_HANDLE_ID.fetch_add(1, Ordering::Relaxed);
        inner.sources.insert(id, Source { coo: Arc::new(m), fro, hash });
        inner.by_hash.entry(hash).or_default().push(id);
        Ok(MatrixHandle(id))
    }

    /// Dimensions `(n, nnz)` of a registered matrix (submit-time
    /// validation wants `n` without touching the engine cache).
    pub fn dims(&self, h: MatrixHandle) -> Option<(usize, usize)> {
        let inner = lock(&self.inner);
        inner.sources.get(&h.0).map(|s| (s.coo.nrows, s.coo.nnz()))
    }

    /// Drop a matrix's residency: its source COO, every cached engine built
    /// from it, and its warm-start entries. In-flight solves holding an
    /// `Arc<PreparedMatrix>` finish normally; later jobs on the handle fail
    /// with "unknown matrix handle". Returns `false` if the handle was not
    /// registered. The byte budget only polices *engines* — long-lived
    /// services that register client matrices must unregister (or dedup
    /// onto a fixed catalog) to bound the O(nnz) source memory.
    pub fn unregister(&self, h: MatrixHandle) -> bool {
        let mut inner = lock(&self.inner);
        let Some(src) = inner.sources.remove(&h.0) else { return false };
        let hash = src.hash;
        if let Some(ids) = inner.by_hash.get_mut(&hash) {
            ids.retain(|&id| id != h.0);
            if ids.is_empty() {
                inner.by_hash.remove(&hash);
            }
        }
        inner.engines.retain(|k, _| k.handle != h.0);
        inner.warm.retain(|k, _| k.0 != h.0);
        inner.warm_order.retain(|k| k.0 != h.0);
        true
    }

    /// The shared prepared engine for `(handle, opts)`: built exactly once
    /// per key, cached under the byte-budget LRU, shared zero-copy with
    /// every caller. Errors on an unknown handle.
    pub fn prepared(&self, h: MatrixHandle, opts: &SolveOptions) -> Result<Arc<PreparedMatrix>> {
        let key = EngineKey::for_opts(h, opts);
        let (coo, fro, cell) = {
            let mut inner = lock(&self.inner);
            let src = inner.sources.get(&h.0).ok_or_else(|| anyhow::anyhow!("unknown matrix handle {}", h.0))?;
            let coo = Arc::clone(&src.coo);
            let fro = src.fro;
            inner.tick += 1;
            let tick = inner.tick;
            let slot = inner.engines.entry(key.clone()).or_insert_with(|| EngineSlot {
                cell: Arc::new(Mutex::new(None)),
                last_used: tick,
                bytes: 0,
            });
            slot.last_used = tick;
            (coo, fro, Arc::clone(&slot.cell))
        };

        let mut built = lock(&cell);
        if let Some(prep) = built.as_ref() {
            self.engine_hits.fetch_add(1, Ordering::SeqCst);
            return Ok(Arc::clone(prep));
        }
        let prep = Arc::new(self.build_engine(&coo, fro, opts));
        self.prepares.fetch_add(1, Ordering::SeqCst);
        *built = Some(Arc::clone(&prep));
        drop(built);

        // Record the engine's footprint and enforce the byte budget.
        let mut inner = lock(&self.inner);
        if let Some(slot) = inner.engines.get_mut(&key) {
            slot.bytes = prep.resident_bytes();
        }
        self.evict_over_budget(&mut inner, &key);
        Ok(prep)
    }

    /// Engine construction from the registry's canonical, normalized COO.
    /// Runs outside the registry lock (only the per-key latch is held), so
    /// concurrent builds of *different* engines overlap.
    fn build_engine(&self, coo: &CooMatrix, fro: f64, opts: &SolveOptions) -> PreparedMatrix {
        let mut sw = Stopwatch::start();
        let precision = opts.precision;
        // Each cached engine owns its CU pool, so solves on different
        // resident matrices never contend on one pool (solves on the same
        // engine serialize their fork/joins, matching one device). The
        // cost is `effective_threads` resident OS threads per cached
        // engine — bounded by `budget_bytes` eviction and `unregister`,
        // both of which drop the pool with the engine.
        let native = || {
            let pool = Arc::new(ThreadPool::new(opts.effective_threads()));
            native_operator_from_canonical(coo, precision, opts.cus, opts.partition, &pool)
        };
        let (op, engine_used) = select_engine(opts.engine, precision, || self.try_pjrt(coo), native);
        PreparedMatrix {
            op,
            fro,
            n: coo.nrows,
            nnz: coo.nnz(),
            precision,
            engine_used,
            prepare_s: sw.lap_s(),
        }
    }

    fn try_pjrt(&self, coo: &CooMatrix) -> Result<Arc<dyn crate::lanczos::Operator>> {
        // Only runtime *creation* serializes; the guard is released before
        // the O(nnz) PjrtSpmv build so different-key engine builds stay
        // parallel, as the per-key latch design promises.
        let rt = {
            let mut guard = lock(&self.runtime);
            if guard.is_none() {
                *guard = Some(Arc::new(Runtime::cpu()?));
            }
            Arc::clone(guard.as_ref().unwrap())
        };
        let op = PjrtSpmv::new(rt, coo)?;
        Ok(Arc::new(op))
    }

    /// Evict least-recently-used **built** engines (never the one just
    /// used, never pending builds) until the cache fits the budget.
    fn evict_over_budget(&self, inner: &mut Inner, keep: &EngineKey) {
        if self.cfg.budget_bytes == 0 {
            return;
        }
        loop {
            let total: usize = inner.engines.values().map(|s| s.bytes).sum();
            if total <= self.cfg.budget_bytes {
                return;
            }
            let victim = inner
                .engines
                .iter()
                .filter(|(k, s)| *k != keep && s.bytes > 0)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    inner.engines.remove(&k);
                    self.evictions.fetch_add(1, Ordering::SeqCst);
                }
                None => return, // only the kept/pending engines remain
            }
        }
    }

    /// Warm-start seed for a repeated `(handle, k, precision)` query:
    /// the previous dominant Ritz vector, if the cache is enabled, has
    /// seen this query complete, and the key is not negatively cached.
    pub fn warm_v1(&self, h: MatrixHandle, k: usize, precision: Precision) -> Option<Vec<f32>> {
        if !self.cfg.warm_start {
            return None;
        }
        let inner = lock(&self.inner);
        match inner.warm.get(&(h.0, k, precision)) {
            Some(WarmEntry::Seed(v)) => {
                self.warm_hits.fetch_add(1, Ordering::SeqCst);
                Some(v.clone())
            }
            Some(WarmEntry::Disabled) | None => None,
        }
    }

    /// Record the dominant Ritz vector of a completed query for future
    /// warm starts. No-op unless [`RegistryConfig::warm_start`] is set, or
    /// when the key has been [`MatrixRegistry::disable_warm`]-ed.
    pub fn store_warm(&self, h: MatrixHandle, k: usize, precision: Precision, dominant: &[f32]) {
        if !self.cfg.warm_start || dominant.is_empty() {
            return;
        }
        let mut inner = lock(&self.inner);
        let key = (h.0, k, precision);
        if matches!(inner.warm.get(&key), Some(WarmEntry::Disabled)) {
            return;
        }
        if inner.warm.insert(key, WarmEntry::Seed(dominant.to_vec())).is_none() {
            inner.warm_order.push_back(key);
            while inner.warm.len() > WARM_CAP {
                if let Some(old) = inner.warm_order.pop_front() {
                    inner.warm.remove(&old);
                } else {
                    break;
                }
            }
        }
    }

    /// Negatively cache a `(handle, k, precision)` query: its warm seed
    /// collapsed the Krylov subspace (truncated solve), so future repeats
    /// run cold instead of repeating a wasted warm solve plus retry.
    pub fn disable_warm(&self, h: MatrixHandle, k: usize, precision: Precision) {
        if !self.cfg.warm_start {
            return;
        }
        let mut inner = lock(&self.inner);
        let key = (h.0, k, precision);
        if inner.warm.insert(key, WarmEntry::Disabled).is_none() {
            inner.warm_order.push_back(key);
        }
    }

    /// Telemetry snapshot.
    pub fn stats(&self) -> RegistryStats {
        let inner = lock(&self.inner);
        RegistryStats {
            matrices: inner.sources.len(),
            engines: inner.engines.values().filter(|s| s.bytes > 0).count(),
            resident_bytes: inner.engines.values().map(|s| s.bytes).sum(),
            prepares: self.prepares.load(Ordering::SeqCst),
            engine_hits: self.engine_hits.load(Ordering::SeqCst),
            dedup_hits: self.dedup_hits.load(Ordering::SeqCst),
            evictions: self.evictions.load(Ordering::SeqCst),
            warm_entries: inner.warm.len(),
            warm_hits: self.warm_hits.load(Ordering::SeqCst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Solver;
    use crate::graphs;
    use crate::lanczos::LanczosWorkspace;

    fn opts_k(k: usize) -> SolveOptions {
        SolveOptions { k, ..Default::default() }
    }

    #[test]
    fn register_dedups_identical_content_but_not_scaled_copies() {
        let reg = MatrixRegistry::default();
        let m = graphs::mesh2d(10, 10, 0.9, 0.02, 1);
        let h1 = reg.register(m.clone()).unwrap();
        let h2 = reg.register(m.clone()).unwrap();
        assert_eq!(h1, h2, "identical content shares one residency");
        assert_eq!(reg.stats().dedup_hits, 1);
        assert_eq!(reg.stats().matrices, 1);
        // A scaled copy normalizes to the same entries but a different
        // Frobenius norm: it must NOT alias the original.
        let mut scaled = m.clone();
        for v in &mut scaled.vals {
            *v *= 2.0;
        }
        let h3 = reg.register(scaled).unwrap();
        assert_ne!(h1, h3);
        assert_eq!(reg.stats().matrices, 2);
        // Different graph, different handle.
        let h4 = reg.register(graphs::mesh2d(10, 10, 0.9, 0.02, 2)).unwrap();
        assert_ne!(h1, h4);
    }

    #[test]
    fn register_validates_input() {
        let reg = MatrixRegistry::default();
        assert!(reg.register(CooMatrix::new(4, 5)).is_err(), "non-square");
        assert!(reg.register(CooMatrix::new(0, 0)).is_err(), "empty");
        let mut asym = CooMatrix::new(4, 4);
        asym.push(0, 0, 1.0);
        asym.push(0, 1, 0.5);
        assert!(reg.register(asym.clone()).is_err(), "asymmetric");
        let trusting = MatrixRegistry::new(RegistryConfig { skip_symmetry_check: true, ..Default::default() });
        assert!(trusting.register(asym).is_ok());
    }

    #[test]
    fn prepared_builds_once_per_key() {
        let reg = MatrixRegistry::default();
        let h = reg.register(graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 5)).unwrap();
        let (n, nnz) = reg.dims(h).unwrap();
        assert_eq!(n, 1 << 7);
        assert!(nnz > 0);
        let a = reg.prepared(h, &opts_k(4)).unwrap();
        let b = reg.prepared(h, &opts_k(8)).unwrap(); // same key: k is not part of engine identity
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.stats().prepares, 1);
        assert_eq!(reg.stats().engine_hits, 1);
        // A different storage format is a different engine.
        let c = reg.prepared(h, &SolveOptions { precision: Precision::FixedQ1_15, ..opts_k(4) }).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(reg.stats().prepares, 2);
        assert_eq!(reg.stats().engines, 2);
        // Unknown handle errors (ids are globally unique, so a foreign or
        // stale handle can never alias another registry's matrix).
        assert!(reg.prepared(MatrixHandle(u64::MAX), &opts_k(4)).is_err());
    }

    #[test]
    fn registry_solves_match_direct_solver() {
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 23);
        let reg = MatrixRegistry::default();
        let h = reg.register(m.clone()).unwrap();
        let opts = opts_k(6);
        let prep = reg.prepared(h, &opts).unwrap();
        let mut ws = LanczosWorkspace::new();
        let via_registry = Solver::solve_detached(&prep, 6, &opts, &mut ws, None).unwrap();
        let direct = Solver::new(opts).solve(&m).unwrap();
        assert_eq!(via_registry.eigenvalues, direct.eigenvalues);
        assert_eq!(via_registry.eigenvectors, direct.eigenvectors);
    }

    #[test]
    fn unregister_drops_sources_engines_and_warm_entries() {
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let m = graphs::mesh2d(10, 10, 0.9, 0.02, 5);
        let h = reg.register(m.clone()).unwrap();
        let prep = reg.prepared(h, &opts_k(4)).unwrap();
        reg.store_warm(h, 4, Precision::Float32, &[0.1; 100]);
        assert_eq!(reg.stats().matrices, 1);
        assert_eq!(reg.stats().engines, 1);
        assert_eq!(reg.stats().warm_entries, 1);

        assert!(reg.unregister(h));
        assert!(!reg.unregister(h), "second unregister is a no-op");
        let stats = reg.stats();
        assert_eq!(stats.matrices, 0);
        assert_eq!(stats.engines, 0);
        assert_eq!(stats.resident_bytes, 0);
        assert_eq!(stats.warm_entries, 0);
        // Held engines stay usable; the handle itself is dead...
        assert!(prep.n() > 0);
        assert!(reg.prepared(h, &opts_k(4)).is_err());
        assert!(reg.dims(h).is_none());
        // ...and re-registering the same content mints a fresh handle
        // (no dedup against removed state).
        let h2 = reg.register(m).unwrap();
        assert_ne!(h, h2);
        assert!(reg.prepared(h2, &opts_k(4)).is_ok());
    }

    #[test]
    fn byte_budget_evicts_least_recently_used() {
        // Budget sized for roughly one engine: the second prepared engine
        // evicts the first; re-preparing the first rebuilds it.
        let reg = MatrixRegistry::new(RegistryConfig { budget_bytes: 1, ..Default::default() });
        let h1 = reg.register(graphs::mesh2d(12, 12, 0.9, 0.02, 3)).unwrap();
        let h2 = reg.register(graphs::mesh2d(12, 12, 0.9, 0.02, 4)).unwrap();
        let a1 = reg.prepared(h1, &opts_k(4)).unwrap();
        let _a2 = reg.prepared(h2, &opts_k(4)).unwrap();
        let stats = reg.stats();
        assert_eq!(stats.prepares, 2);
        assert!(stats.evictions >= 1, "budget of 1 byte must evict");
        // The evicted engine is still usable by holders of its Arc...
        assert!(a1.n() > 0);
        // ...and a new request simply rebuilds it.
        let a1_again = reg.prepared(h1, &opts_k(4)).unwrap();
        assert!(!Arc::ptr_eq(&a1, &a1_again));
        assert_eq!(reg.stats().prepares, 3);
    }

    #[test]
    fn warm_start_cache_round_trips_when_enabled() {
        let cold = MatrixRegistry::default();
        let h = cold.register(graphs::mesh2d(8, 8, 0.9, 0.02, 7)).unwrap();
        cold.store_warm(h, 4, Precision::Float32, &[1.0; 64]);
        assert!(cold.warm_v1(h, 4, Precision::Float32).is_none(), "disabled by default");

        let warm = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let h = warm.register(graphs::mesh2d(8, 8, 0.9, 0.02, 7)).unwrap();
        assert!(warm.warm_v1(h, 4, Precision::Float32).is_none(), "cold query has no seed");
        warm.store_warm(h, 4, Precision::Float32, &[0.5; 64]);
        assert_eq!(warm.warm_v1(h, 4, Precision::Float32).unwrap(), vec![0.5; 64]);
        assert!(warm.warm_v1(h, 5, Precision::Float32).is_none(), "k is part of the key");
        assert!(warm.warm_v1(h, 4, Precision::FixedQ1_15).is_none(), "precision is part of the key");
        let stats = warm.stats();
        assert_eq!(stats.warm_entries, 1);
        assert_eq!(stats.warm_hits, 1);
    }

    #[test]
    fn disable_warm_negatively_caches_a_key() {
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let h = reg.register(graphs::mesh2d(8, 8, 0.9, 0.02, 9)).unwrap();
        reg.store_warm(h, 4, Precision::Float32, &[0.5; 64]);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_some());
        reg.disable_warm(h, 4, Precision::Float32);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_none());
        // Stores after disabling are ignored: the key stays cold for good.
        reg.store_warm(h, 4, Precision::Float32, &[0.5; 64]);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_none());
        // Other keys are unaffected.
        reg.store_warm(h, 5, Precision::Float32, &[0.5; 64]);
        assert!(reg.warm_v1(h, 5, Precision::Float32).is_some());
    }

    #[test]
    fn pjrt_requests_share_the_native_engine_when_fallback_is_static() {
        // Without the `pjrt` feature (and always for fixed-point formats),
        // an Engine::Pjrt request is statically known to fall back to
        // native; the cache key collapses onto the native key so the two
        // request flavors share one engine instead of byte-identical
        // twins.
        if cfg!(feature = "pjrt") {
            return; // runtime fallback is not statically known there
        }
        let reg = MatrixRegistry::default();
        let h = reg.register(graphs::mesh2d(8, 8, 0.9, 0.02, 11)).unwrap();
        let a = reg.prepared(h, &SolveOptions { engine: Engine::Pjrt, ..opts_k(4) }).unwrap();
        let b = reg.prepared(h, &opts_k(4)).unwrap(); // Engine::Native
        assert!(Arc::ptr_eq(&a, &b), "fallback and native requests must share one engine");
        assert_eq!(reg.stats().prepares, 1);
        assert_eq!(a.engine(), "native");
    }

    #[test]
    fn warm_started_solve_converges_on_repeat_query() {
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let m = graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 41);
        let h = reg.register(m).unwrap();
        let opts = opts_k(4);
        let prep = reg.prepared(h, &opts).unwrap();
        let mut ws = LanczosWorkspace::new();
        let first = Solver::solve_detached(&prep, 4, &opts, &mut ws, None).unwrap();
        assert!(!first.metrics.warm_started);
        reg.store_warm(h, 4, opts.precision, &first.eigenvectors[0]);
        let v1 = reg.warm_v1(h, 4, opts.precision);
        assert!(v1.is_some());
        let second = Solver::solve_detached(&prep, 4, &opts, &mut ws, v1).unwrap();
        assert!(second.metrics.warm_started);
        // Same dominant eigenvalue, warm or cold (both are finite-K Ritz
        // estimates, so compare at estimate accuracy, not bitwise).
        assert!(
            (second.eigenvalues[0] - first.eigenvalues[0]).abs() < 2e-2 * first.eigenvalues[0].abs().max(1.0),
            "{} vs {}",
            second.eigenvalues[0],
            first.eigenvalues[0]
        );
    }
}

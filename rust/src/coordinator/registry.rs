//! The shared prepared-engine registry — matrix-resident serving.
//!
//! The paper motivates Top-K eigensolvers as data-center infrastructure
//! (§I) where the *same* enormous graph is queried over and over, and its
//! §IV-C reconfigurable-core discussion schedules jobs *around resident
//! state*. SSD- and multi-GPU-scale follow-ups (Zheng et al.,
//! arXiv:1602.01421; arXiv:2201.07498) draw the same conclusion: at scale
//! the matrix is the resident asset and solves are the cheap, concurrent
//! part. [`MatrixRegistry`] is that inversion for the service layer:
//!
//! * [`MatrixRegistry::register`] ingests a `CooMatrix` **once** —
//!   canonicalize in place (no COO clone), symmetry-check, Frobenius-
//!   normalize — and returns a small [`MatrixHandle`]. Registration
//!   deduplicates by content hash (full equality compare on a hash match),
//!   so two tenants registering the same graph share one residency.
//! * [`MatrixRegistry::prepared`] returns the `Arc<PreparedMatrix>` for a
//!   `(handle, precision, engine, geometry)` key, building it **exactly
//!   once** (concurrent callers for the same key block on a per-key latch;
//!   callers for different keys build in parallel) — the prepare-count
//!   telemetry in [`RegistryStats`] pins this.
//! * Cached engines are evicted least-recently-used against a byte budget
//!   ([`RegistryConfig::budget_bytes`]), charged at
//!   [`PreparedMatrix::resident_bytes`] (the COO-line convention the
//!   datapath telemetry already uses). Eviction only drops the registry's
//!   `Arc`; in-flight solves keep their engine alive until they finish.
//! * A warm-start cache ([`RegistryConfig::warm_start`]) remembers the
//!   dominant Ritz vector of each completed `(handle, k, precision)` query
//!   so repeated queries seed Lanczos `v1` from the previous answer
//!   instead of the uniform start — fewer effective iterations to the same
//!   invariant subspace on slowly-drifting production graphs.
//!
//! Worker replicas then run [`crate::coordinator::Solver::solve_detached`]
//! against the shared engine concurrently, each with its own
//! [`crate::lanczos::LanczosWorkspace`] — zero per-job COO clones, zero
//! redundant prepare work.
//!
//! ## The update lifecycle (evolving graphs)
//!
//! Registered matrices are **updatable**: [`MatrixRegistry::update`] takes
//! a [`CooDelta`] (edge insertions, deletions, value changes in the
//! original value scale), splices it into the canonical source in place
//! (`O(nnz + d)`, no re-sort), recomputes the Frobenius norm, and bumps
//! the handle's **generation**. Cached engines are *not* evicted: they are
//! invalidated by generation and lazily refreshed on the next
//! [`MatrixRegistry::prepared`] — reusing the engine's CU pool and
//! classifying every CU shard as dirty or carried-over when the dirty-row
//! fraction is small
//! ([`ShardedSpmv::rebuild_shards`]), falling back to a full rebuild when
//! the delta touches too much (`RegistryConfig::dirty_full_fraction`) or
//! the engine is opaque (PJRT). The source is kept in **original scale**
//! and normalization is applied at engine-build time (bitwise identical
//! to the in-place path — see
//! [`crate::coordinator::native_operator_scaled`]), so an incrementally
//! refreshed engine is exactly equal to a from-scratch
//! `register` + `prepared` of the mutated matrix.
//!
//! The warm-start cache is **retained across generations** under a
//! relative-perturbation guard: `||delta||_F / ||M||_F <=`
//! [`RegistryConfig::warm_keep_tol`] keeps the previous dominant Ritz
//! vectors as seeds (a small delta barely moves the invariant subspace);
//! larger deltas drop the handle's warm entries and re-solves run cold.

use crate::coordinator::{
    native_operator_scaled, scaled_coo_copy, select_engine, typed_csr_scaled, Engine, PreparedMatrix, SolveOptions,
};
use crate::fixed::Precision;
use crate::runtime::{PjrtSpmv, Runtime};
use crate::sparse::{
    frobenius_norm, CooDelta, CooMatrix, CsrMatrix, OocManifest, OocMatrix, PartitionPolicy, ShardedSpmv,
};
use crate::util::pool::ThreadPool;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Poison-resistant lock: a panic inside a registry section (e.g. an
/// engine build hitting a pathological matrix) must cost that one request,
/// not brick every later job on the registry or on one engine key. All
/// guarded state stays valid across an unwind mid-section: maps are
/// updated with single insert/remove calls and a half-built engine slot is
/// simply `None`, which the next caller rebuilds.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// FNV-1a over a directory path — the dedup pre-filter key for
/// out-of-core sources (full path equality is still compared on a hash
/// match, mirroring the content-hash flow for resident matrices).
fn path_hash(p: &std::path::Path) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in p.to_string_lossy().as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Opaque handle to a registered matrix. Cheap to copy, hash, and send —
/// this is what service jobs carry instead of an owned `CooMatrix`.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct MatrixHandle(u64);

impl MatrixHandle {
    /// The numeric id (stable for the registry's lifetime; for logs).
    pub fn id(&self) -> u64 {
        self.0
    }
}

/// Registry configuration.
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Byte budget for cached prepared engines (LRU-evicted beyond it);
    /// `0` = unlimited. An engine larger than the whole budget is still
    /// served and cached — only *other* engines can be evicted for it.
    pub budget_bytes: usize,
    /// Seed repeated `(handle, k, precision)` queries with the previous
    /// dominant Ritz vector. Off by default: a warm start is no longer
    /// bit-identical to the cold solve (so deterministic replay paths
    /// should leave it off), and a seed lying too close to an exact
    /// eigenvector can truncate the Krylov subspace — the service's
    /// handle path retries such solves cold so callers still get K pairs.
    pub warm_start: bool,
    /// Skip the O(nnz) symmetry check at registration (trusted sources).
    pub skip_symmetry_check: bool,
    /// Register matrices as-is without Frobenius normalization (inputs
    /// already normalized; mirrors [`SolveOptions::skip_normalize`]).
    pub skip_normalize: bool,
    /// Warm-start retention guard across updates: a delta with relative
    /// perturbation `||delta||_F / ||M||_F` at or below this keeps the
    /// handle's cached dominant Ritz vectors as seeds for the next
    /// generation's solves; a larger delta drops them (the invariant
    /// subspace may have moved too far for the seed to help).
    pub warm_keep_tol: f64,
    /// Incremental re-prep cutoff: when a pending update's dirty-row
    /// fraction exceeds this, stale engines are rebuilt from scratch
    /// instead of incrementally (most shards would be dirty anyway).
    pub dirty_full_fraction: f64,
    /// Cap on the chunk-buffer bytes one out-of-core engine may pin
    /// (`serve --ooc-budget-mb` at the CLI; `0` = unlimited). Out-of-core
    /// matrices are charged at O(n) + buffer bytes, not O(nnz), so the
    /// ordinary [`RegistryConfig::budget_bytes`] LRU barely sees them —
    /// this knob is the explicit promise that streaming a huge graph will
    /// not quietly pin more RAM than the operator budgeted. Directories
    /// whose double buffer would exceed it are rejected at prepare time
    /// (re-export with a smaller chunk target to shrink the buffers).
    pub ooc_buffer_budget_bytes: usize,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            budget_bytes: 0,
            warm_start: false,
            skip_symmetry_check: false,
            skip_normalize: false,
            warm_keep_tol: 0.05,
            dirty_full_fraction: 0.25,
            ooc_buffer_budget_bytes: 0,
        }
    }
}

/// Snapshot of the registry's telemetry counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RegistryStats {
    /// Registered (distinct) matrices currently resident.
    pub matrices: usize,
    /// Prepared engines currently cached.
    pub engines: usize,
    /// Estimated bytes of all cached engines.
    pub resident_bytes: usize,
    /// Engine builds performed ([`crate::coordinator::Solver::prepare`]-
    /// equivalent work). The acceptance bar: M jobs against one registered
    /// handle and one engine key leave this at exactly 1.
    pub prepares: u64,
    /// `prepared` calls served from the cache (no build).
    pub engine_hits: u64,
    /// Registrations that deduplicated onto an existing handle.
    pub dedup_hits: u64,
    /// Engines evicted by the byte-budget LRU.
    pub evictions: u64,
    /// Warm-start cache entries currently held.
    pub warm_entries: usize,
    /// Warm-start seeds served.
    pub warm_hits: u64,
    /// Delta updates applied across all handles.
    pub updates: u64,
    /// Stale engines refreshed incrementally (dirty shards only).
    pub incremental_rebuilds: u64,
    /// Stale engines rebuilt from scratch (dirty fraction too high,
    /// missing history, or an opaque engine).
    pub full_rebuilds: u64,
    /// CU shards re-derived across all incremental refreshes.
    pub shards_rebuilt: u64,
    /// CU shards carried over untouched across all incremental refreshes.
    pub shards_reused: u64,
    /// Updates whose perturbation was small enough to keep the handle's
    /// warm-start seeds across the generation bump.
    pub warm_kept: u64,
    /// Updates that dropped the handle's warm-start seeds.
    pub warm_dropped: u64,
    /// PPR column-sum tables computed (one O(nnz) pass each). The
    /// acceptance bar mirrors `prepares`: M PPR jobs against one resident
    /// matrix leave this at exactly 1 per generation.
    pub colsum_builds: u64,
    /// PPR column-sum requests served from the cache.
    pub colsum_hits: u64,
    /// Early-exit row-bound tables computed (one O(nnz) pass each,
    /// mirroring `colsum_builds`: once per (handle, precision, generation)).
    pub rowbound_builds: u64,
    /// Row-bound requests served from the cache.
    pub rowbound_hits: u64,
    /// PPR warm-score cache entries currently held.
    pub ppr_warm_entries: usize,
    /// PPR power iterations seeded from a previous generation's scores.
    pub ppr_warm_hits: u64,
}

/// What one [`MatrixRegistry::update`] did: the new generation, the size
/// of the dirty set, op counts, and the warm-retention decision.
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateReport {
    /// The handle's generation after this update.
    pub generation: u64,
    /// Stored non-zeros after the splice.
    pub nnz: usize,
    /// Rows the delta touched (the dirty set driving incremental re-prep).
    pub dirty_rows: usize,
    /// Entries inserted.
    pub inserted: usize,
    /// Entries whose value changed.
    pub changed: usize,
    /// Entries deleted.
    pub deleted: usize,
    /// `||delta||_F / ||M_old||_F` — the relative perturbation compared
    /// against [`RegistryConfig::warm_keep_tol`].
    pub rel_delta: f64,
    /// Whether the handle's warm-start seeds survived this update.
    pub warm_kept: bool,
}

/// One applied delta: the generation it produced and the rows it touched
/// (the per-engine refresh unions records newer than the engine's build).
struct UpdateRecord {
    generation: u64,
    dirty_rows: Vec<u32>,
}

/// Update history kept per source; engines lagging further behind than
/// this take the full-rebuild path.
const MAX_UPDATE_HISTORY: usize = 32;

/// Where a registered matrix's entries actually live.
enum SourceData {
    /// Canonical COO in **original** scale — normalization is applied at
    /// engine-build time so delta values (also original-scale) compose
    /// exactly and the Frobenius norm can be recomputed after each update.
    Resident(Arc<CooMatrix>),
    /// An out-of-core packet directory
    /// ([`crate::sparse::PacketFileWriter`] output): the entries never
    /// enter RAM — engines stream the chunk files through double-buffered
    /// prefetch, and residency is charged at the buffer pool, not O(nnz).
    /// The stored values are already normalized and quantized (raw bits),
    /// so these sources are immutable: [`MatrixRegistry::update`] rejects
    /// them and dedup keys on the canonical directory path.
    Ooc { dir: PathBuf, manifest: OocManifest },
}

struct Source {
    data: SourceData,
    fro: f64,
    /// Content hash computed at registration (and refreshed per update) —
    /// kept so `unregister` can maintain `by_hash` without an O(nnz)
    /// re-hash under the lock.
    hash: u64,
    /// Bumped by every applied update; engines and solves carry it.
    generation: u64,
    /// Recent updates, oldest first, capped at [`MAX_UPDATE_HISTORY`].
    updates: VecDeque<UpdateRecord>,
}

impl Source {
    /// Normalization scale for engine builds (`None` = skip_normalize;
    /// `fro` is pinned to 1.0 for zero matrices, making the scale a
    /// bitwise no-op there, matching the in-place normalizer).
    fn scale(&self, skip_normalize: bool) -> Option<f64> {
        if skip_normalize {
            None
        } else {
            Some(1.0 / self.fro)
        }
    }

    /// Union of dirty rows from all updates after `from_gen`, or `None`
    /// when the history no longer reaches back that far.
    fn dirty_rows_since(&self, from_gen: u64) -> Option<Vec<u32>> {
        if from_gen == self.generation {
            return Some(Vec::new());
        }
        let need_oldest = from_gen + 1;
        if self.updates.front().map(|u| u.generation) > Some(need_oldest) || self.updates.is_empty() {
            return None;
        }
        let mut union: Vec<u32> = Vec::new();
        for u in self.updates.iter().filter(|u| u.generation > from_gen) {
            union.extend_from_slice(&u.dirty_rows);
        }
        union.sort_unstable();
        union.dedup();
        Some(union)
    }
}

/// Engine identity: one prepared engine per handle x storage format x
/// engine kind x shard geometry.
#[derive(Clone, PartialEq, Eq, Hash)]
struct EngineKey {
    handle: u64,
    precision: Precision,
    engine: Engine,
    cus: usize,
    partition: PartitionPolicy,
    threads: usize,
}

impl EngineKey {
    fn for_opts(handle: MatrixHandle, opts: &SolveOptions) -> Self {
        Self {
            handle: handle.0,
            precision: opts.precision,
            engine: Self::effective_engine(opts.engine, opts.precision),
            cus: opts.cus,
            partition: opts.partition,
            threads: opts.effective_threads(),
        }
    }

    /// Collapse PJRT requests that are statically known to fall back onto
    /// the native key, so `Engine::Pjrt` and `Engine::Native` requests for
    /// the same matrix share one cached engine (and one CU pool) instead
    /// of byte-identical twins: fixed-point formats always fall back (the
    /// artifacts are f32), and a build without the `pjrt` feature always
    /// falls back (stub runtime). A feature-enabled f32 request that fails
    /// at runtime (missing artifact, no fitting shape) still caches its
    /// native fallback under the Pjrt key — accepted duplication for that
    /// rare case.
    fn effective_engine(engine: Engine, precision: Precision) -> Engine {
        match engine {
            Engine::Pjrt if precision != Precision::Float32 => Engine::Native,
            Engine::Pjrt if !cfg!(feature = "pjrt") => Engine::Native,
            e => e,
        }
    }
}

/// A built engine plus the source generation it reflects: a mismatch with
/// the source's current generation marks the engine stale, to be
/// refreshed (incrementally where possible) by the next `prepared` call.
struct BuiltEngine {
    generation: u64,
    prep: Arc<PreparedMatrix>,
}

/// Consistent source snapshot an engine build runs against: the canonical
/// original-scale COO, its Frobenius norm, the generation it represents,
/// and the normalization scale to apply at the value stream. Taken under
/// the registry lock in one shot, so a build never mixes generations.
struct BuildCtx {
    coo: Arc<CooMatrix>,
    fro: f64,
    generation: u64,
    scale: Option<f64>,
}

/// What `prepared` snapshotted under the registry lock: a resident build
/// context, or the out-of-core directory whose chunk files the engine will
/// stream (nothing O(nnz) is cloned on either path).
enum SnapshotCtx {
    Resident(BuildCtx),
    Ooc { dir: PathBuf, manifest: OocManifest, generation: u64 },
}

struct EngineSlot {
    /// Build-once latch: concurrent `prepared` calls for one key serialize
    /// here (not on the registry lock), so different keys build in
    /// parallel while the same key is never built twice per generation.
    cell: Arc<Mutex<Option<BuiltEngine>>>,
    last_used: u64,
    /// 0 while the build is in flight (pending slots are never evicted).
    bytes: usize,
}

type WarmKey = (u64, usize, Precision);

/// Bound on warm-start entries (each is a panel of up to `block_size`
/// n-length f32 vectors; single-vector queries store one column).
const WARM_CAP: usize = 256;

/// PPR warm-score identity: the iteration's fixed point depends on the
/// stored value stream (handle + precision), the personalization vertex,
/// and the damping factor (bit-keyed — `f64` isn't `Hash`). `tol` and
/// `max_iters` only decide when to stop, so they share a seed.
type PprWarmKey = (u64, Precision, usize, u64);

/// Bound on PPR warm-score entries (each is an n-length f32 vector).
const PPR_WARM_CAP: usize = 256;

/// One warm-start cache slot: a usable seed panel (the converged Ritz
/// front of a previous solve — one column for single-vector warm starts,
/// up to `b` columns for block-Lanczos panel seeds), or a negative entry
/// for keys where warm-starting proved counterproductive (the seed
/// collapsed the Krylov subspace) — those queries run cold permanently
/// instead of paying a truncated warm solve plus a cold retry on every
/// repeat.
enum WarmEntry {
    Seed(Vec<Vec<f32>>),
    Disabled,
}

struct Inner {
    sources: HashMap<u64, Source>,
    by_hash: HashMap<u64, Vec<u64>>,
    engines: HashMap<EngineKey, EngineSlot>,
    warm: HashMap<WarmKey, WarmEntry>,
    warm_order: VecDeque<WarmKey>,
    /// PPR normalizer tables per `(handle, precision)`, tagged with the
    /// generation they reflect (stale entries are overwritten on next
    /// use). Column sums depend only on the stored value stream, so the
    /// key needs no engine geometry.
    colsums: HashMap<(u64, Precision), (u64, Arc<Vec<f64>>)>,
    /// Early-exit row-bound tables (per-row L1 norms of the stored
    /// values) per `(handle, precision)`, generation-tagged exactly like
    /// `colsums` — shard geometry is irrelevant, the engine derives its
    /// per-shard maxima per sweep.
    rowbounds: HashMap<(u64, Precision), (u64, Arc<Vec<f64>>)>,
    /// Previous converged PPR scores per (handle, precision, source,
    /// alpha): warm seeds for re-solves after a small delta. Deliberately
    /// *not* generation-tagged — crossing generations is the point; the
    /// `warm_keep_tol` guard in `update` drops entries the delta moved
    /// too far.
    ppr_warm: HashMap<PprWarmKey, Vec<f32>>,
    ppr_warm_order: VecDeque<PprWarmKey>,
    tick: u64,
}

/// Handle ids are process-globally unique (not per-registry), so a handle
/// from one registry can never silently alias a different matrix in
/// another — a lookup with a foreign handle fails instead of answering
/// the wrong question.
static NEXT_HANDLE_ID: AtomicU64 = AtomicU64::new(1);

/// The shared prepared-engine registry (see module docs).
pub struct MatrixRegistry {
    cfg: RegistryConfig,
    inner: Mutex<Inner>,
    /// Lazy PJRT runtime for `Engine::Pjrt` keys (mirrors `Solver`).
    runtime: Mutex<Option<Arc<Runtime>>>,
    prepares: AtomicU64,
    engine_hits: AtomicU64,
    dedup_hits: AtomicU64,
    evictions: AtomicU64,
    warm_hits: AtomicU64,
    updates: AtomicU64,
    incremental_rebuilds: AtomicU64,
    full_rebuilds: AtomicU64,
    shards_rebuilt: AtomicU64,
    shards_reused: AtomicU64,
    warm_kept: AtomicU64,
    warm_dropped: AtomicU64,
    colsum_builds: AtomicU64,
    colsum_hits: AtomicU64,
    rowbound_builds: AtomicU64,
    rowbound_hits: AtomicU64,
    ppr_warm_hits: AtomicU64,
}

impl Default for MatrixRegistry {
    fn default() -> Self {
        Self::new(RegistryConfig::default())
    }
}

impl MatrixRegistry {
    /// Empty registry under `cfg`.
    pub fn new(cfg: RegistryConfig) -> Self {
        Self {
            cfg,
            inner: Mutex::new(Inner {
                sources: HashMap::new(),
                by_hash: HashMap::new(),
                engines: HashMap::new(),
                warm: HashMap::new(),
                warm_order: VecDeque::new(),
                colsums: HashMap::new(),
                rowbounds: HashMap::new(),
                ppr_warm: HashMap::new(),
                ppr_warm_order: VecDeque::new(),
                tick: 0,
            }),
            runtime: Mutex::new(None),
            prepares: AtomicU64::new(0),
            engine_hits: AtomicU64::new(0),
            dedup_hits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
            updates: AtomicU64::new(0),
            incremental_rebuilds: AtomicU64::new(0),
            full_rebuilds: AtomicU64::new(0),
            shards_rebuilt: AtomicU64::new(0),
            shards_reused: AtomicU64::new(0),
            warm_kept: AtomicU64::new(0),
            warm_dropped: AtomicU64::new(0),
            colsum_builds: AtomicU64::new(0),
            colsum_hits: AtomicU64::new(0),
            rowbound_builds: AtomicU64::new(0),
            rowbound_hits: AtomicU64::new(0),
            ppr_warm_hits: AtomicU64::new(0),
        }
    }

    /// The active configuration.
    pub fn config(&self) -> &RegistryConfig {
        &self.cfg
    }

    /// Ingest a matrix: canonicalize **in place** (the registry owns the
    /// buffers — no COO clone anywhere on this path), check symmetry,
    /// compute the Frobenius norm, and deduplicate against already-
    /// registered content. Returns the handle service jobs carry from
    /// here on.
    ///
    /// The source is stored canonical in **original scale**; normalization
    /// is deferred to engine-build time (bitwise identical values — see
    /// [`crate::coordinator::native_operator_scaled`]) so that
    /// [`MatrixRegistry::update`] deltas, which arrive in original scale,
    /// compose exactly across generations.
    pub fn register(&self, mut m: CooMatrix) -> Result<MatrixHandle> {
        anyhow::ensure!(m.nrows > 0, "matrix must be non-empty");
        anyhow::ensure!(m.nrows == m.ncols, "matrix must be square");
        m.canonicalize();
        if !self.cfg.skip_symmetry_check {
            anyhow::ensure!(
                m.is_symmetric(1e-4),
                "operator must be symmetric (set skip_symmetry_check for trusted input)"
            );
        }
        let fro = Self::effective_fro(&m, self.cfg.skip_normalize);
        let hash = m.content_hash();
        let mut inner = lock(&self.inner);
        if let Some(ids) = inner.by_hash.get(&hash) {
            for &id in ids {
                // Original-scale content comparison: a scaled copy of a
                // registered graph has different stored values, so it
                // naturally gets its own handle (its eigenvalues rescale
                // by a different norm).
                if let SourceData::Resident(coo) = &inner.sources[&id].data {
                    if **coo == m {
                        self.dedup_hits.fetch_add(1, Ordering::SeqCst);
                        return Ok(MatrixHandle(id));
                    }
                }
            }
        }
        let id = NEXT_HANDLE_ID.fetch_add(1, Ordering::Relaxed);
        inner.sources.insert(
            id,
            Source {
                data: SourceData::Resident(Arc::new(m)),
                fro,
                hash,
                generation: 1,
                updates: VecDeque::new(),
            },
        );
        inner.by_hash.entry(hash).or_default().push(id);
        Ok(MatrixHandle(id))
    }

    /// Register an **out-of-core** packet directory
    /// ([`crate::coordinator::PreparedMatrix::export_ooc`] /
    /// `topk-eigen generate --ooc` output) without loading the matrix:
    /// only the manifest is read. Jobs on the returned handle stream the
    /// chunk files through double-buffered prefetch, and the engine cache
    /// charges the handle at its chunk-buffer bytes — a graph bigger than
    /// RAM does not count as bigger than RAM against
    /// [`RegistryConfig::budget_bytes`], and crucially never evicts small
    /// resident engines that do fit.
    ///
    /// Registrations of the same directory (canonical path) deduplicate
    /// onto one handle. The stored format is fixed at export time: a
    /// `prepared` call with a different [`SolveOptions::precision`] fails
    /// instead of silently re-quantizing.
    pub fn register_ooc(&self, dir: impl Into<PathBuf>) -> Result<MatrixHandle> {
        let dir = dir.into();
        let manifest = OocManifest::load(&dir)?;
        // Canonical path so `./graph`, `graph/` and symlinks to it share
        // one residency (falls back to the given path if it vanished).
        let dir = std::fs::canonicalize(&dir).unwrap_or(dir);
        let hash = path_hash(&dir);
        let mut inner = lock(&self.inner);
        if let Some(ids) = inner.by_hash.get(&hash) {
            for &id in ids {
                if let SourceData::Ooc { dir: existing, .. } = &inner.sources[&id].data {
                    if *existing == dir {
                        self.dedup_hits.fetch_add(1, Ordering::SeqCst);
                        return Ok(MatrixHandle(id));
                    }
                }
            }
        }
        let id = NEXT_HANDLE_ID.fetch_add(1, Ordering::Relaxed);
        let fro = manifest.fro;
        inner.sources.insert(
            id,
            Source {
                data: SourceData::Ooc { dir, manifest },
                fro,
                hash,
                generation: 1,
                updates: VecDeque::new(),
            },
        );
        inner.by_hash.entry(hash).or_default().push(id);
        Ok(MatrixHandle(id))
    }

    /// Apply a delta (edge insertions, deletions, value changes — original
    /// value scale) to a registered matrix **in place**: splice into the
    /// canonical source (`O(nnz + d)`, no re-sort), recompute the
    /// Frobenius norm, bump the handle's generation, and record the dirty
    /// rows. Cached engines stay resident and are refreshed lazily — the
    /// next [`MatrixRegistry::prepared`] on each key rebuilds only the CU
    /// shards the accumulated deltas touched (or everything, past the
    /// [`RegistryConfig::dirty_full_fraction`] cutoff). In-flight solves
    /// keep their engine snapshot; nothing they read is mutated.
    ///
    /// Warm-start seeds survive the generation bump when the relative
    /// perturbation `||delta||_F / ||M||_F` is at most
    /// [`RegistryConfig::warm_keep_tol`]; otherwise the handle's seeds are
    /// dropped and the next queries run cold.
    pub fn update(&self, h: MatrixHandle, mut delta: CooDelta) -> Result<UpdateReport> {
        delta.canonicalize();
        let mut inner = lock(&self.inner);
        let src = inner.sources.get_mut(&h.0).ok_or_else(|| anyhow::anyhow!("unknown matrix handle {}", h.0))?;
        let SourceData::Resident(src_coo) = &mut src.data else {
            anyhow::bail!(
                "matrix handle {} is out-of-core: packet files store pre-quantized bits and cannot be \
                 spliced in place — regenerate the directory and register it again",
                h.0
            );
        };
        anyhow::ensure!(
            (src_coo.nrows, src_coo.ncols) == (delta.nrows, delta.ncols),
            "delta dimensions {}x{} do not match matrix {}x{}",
            delta.nrows,
            delta.ncols,
            src_coo.nrows,
            src_coo.ncols
        );
        if !self.cfg.skip_symmetry_check {
            anyhow::ensure!(
                delta.is_symmetric(),
                "delta must be symmetric (edit both triangles, or set skip_symmetry_check)"
            );
        }
        if delta.is_empty() {
            return Ok(UpdateReport {
                generation: src.generation,
                nnz: src_coo.nnz(),
                dirty_rows: 0,
                inserted: 0,
                changed: 0,
                deleted: 0,
                rel_delta: 0.0,
                warm_kept: true,
            });
        }
        // Reference norm for the warm-retention ratio: the *actual* matrix
        // norm, even when normalization is skipped (src.fro is pinned to
        // 1.0 there and would turn the documented relative guard into an
        // absolute one).
        let old_fro = if self.cfg.skip_normalize { frobenius_norm(src_coo) } else { src.fro };
        // Copy-on-write: in the steady state the registry's Arc is the
        // only strong reference and the splice mutates in place; a
        // concurrent engine build holding the Arc forces one clone and
        // keeps reading its consistent snapshot.
        //
        // Scaling note: the splice, re-norm, and re-hash are O(nnz) and run
        // under the registry lock, stalling other handles' `prepared`
        // snapshots for the duration. Updates are the rare, heavyweight
        // operation by contract (the service fences them anyway); if
        // update throughput across many tenants ever matters, the next
        // step is per-source locking so only the updated handle pays.
        let coo = Arc::make_mut(src_coo);
        let report = coo.apply_delta(&delta);
        src.fro = Self::effective_fro(coo, self.cfg.skip_normalize);
        let new_hash = coo.content_hash();
        let new_nnz = coo.nnz();
        src.generation += 1;
        let generation = src.generation;
        src.updates.push_back(UpdateRecord { generation, dirty_rows: report.dirty_rows.clone() });
        while src.updates.len() > MAX_UPDATE_HISTORY {
            src.updates.pop_front();
        }
        let old_hash = src.hash;
        src.hash = new_hash;
        // Warm retention: small relative perturbation keeps the seeds.
        let rel_delta = if old_fro > 0.0 { report.delta_fro() / old_fro } else { f64::INFINITY };
        let warm_kept = rel_delta <= self.cfg.warm_keep_tol;
        if !warm_kept {
            inner.warm.retain(|k, _| k.0 != h.0);
            inner.warm_order.retain(|k| k.0 != h.0);
            // PPR warm scores ride the same guard: a large delta may have
            // moved the PPR fixed point too far for the old scores to be a
            // useful (iteration-saving) seed.
            inner.ppr_warm.retain(|k, _| k.0 != h.0);
            inner.ppr_warm_order.retain(|k| k.0 != h.0);
            self.warm_dropped.fetch_add(1, Ordering::SeqCst);
        } else {
            self.warm_kept.fetch_add(1, Ordering::SeqCst);
        }
        // Keep the dedup index consistent with the mutated content.
        if old_hash != new_hash {
            if let Some(ids) = inner.by_hash.get_mut(&old_hash) {
                ids.retain(|&id| id != h.0);
                if ids.is_empty() {
                    inner.by_hash.remove(&old_hash);
                }
            }
            inner.by_hash.entry(new_hash).or_default().push(h.0);
        }
        self.updates.fetch_add(1, Ordering::SeqCst);
        Ok(UpdateReport {
            generation,
            nnz: new_nnz,
            dirty_rows: report.dirty_rows.len(),
            inserted: report.inserted,
            changed: report.changed,
            deleted: report.deleted,
            rel_delta,
            warm_kept,
        })
    }

    /// Current generation of a registered matrix (bumped per update).
    pub fn generation(&self, h: MatrixHandle) -> Option<u64> {
        lock(&self.inner).sources.get(&h.0).map(|s| s.generation)
    }

    /// Frobenius norm for eigenvalue rescaling: 1.0 when normalization is
    /// skipped or the matrix is zero (matching the in-place normalizer's
    /// convention, so both prepare paths rescale identically).
    fn effective_fro(m: &CooMatrix, skip_normalize: bool) -> f64 {
        if skip_normalize {
            return 1.0;
        }
        let f = frobenius_norm(m);
        if f == 0.0 {
            1.0
        } else {
            f
        }
    }

    /// Dimensions `(n, nnz)` of a registered matrix (submit-time
    /// validation wants `n` without touching the engine cache).
    pub fn dims(&self, h: MatrixHandle) -> Option<(usize, usize)> {
        let inner = lock(&self.inner);
        inner.sources.get(&h.0).map(|s| match &s.data {
            SourceData::Resident(coo) => (coo.nrows, coo.nnz()),
            SourceData::Ooc { manifest, .. } => (manifest.nrows, manifest.nnz),
        })
    }

    /// Drop a matrix's residency: its source COO, every cached engine built
    /// from it, and its warm-start entries. In-flight solves holding an
    /// `Arc<PreparedMatrix>` finish normally; later jobs on the handle fail
    /// with "unknown matrix handle". Returns `false` if the handle was not
    /// registered. The byte budget only polices *engines* — long-lived
    /// services that register client matrices must unregister (or dedup
    /// onto a fixed catalog) to bound the O(nnz) source memory.
    pub fn unregister(&self, h: MatrixHandle) -> bool {
        let mut inner = lock(&self.inner);
        let Some(src) = inner.sources.remove(&h.0) else { return false };
        let hash = src.hash;
        if let Some(ids) = inner.by_hash.get_mut(&hash) {
            ids.retain(|&id| id != h.0);
            if ids.is_empty() {
                inner.by_hash.remove(&hash);
            }
        }
        inner.engines.retain(|k, _| k.handle != h.0);
        inner.warm.retain(|k, _| k.0 != h.0);
        inner.warm_order.retain(|k| k.0 != h.0);
        inner.colsums.retain(|k, _| k.0 != h.0);
        inner.rowbounds.retain(|k, _| k.0 != h.0);
        inner.ppr_warm.retain(|k, _| k.0 != h.0);
        inner.ppr_warm_order.retain(|k| k.0 != h.0);
        true
    }

    /// The shared prepared engine for `(handle, opts)`: built exactly once
    /// per key **and generation**, cached under the byte-budget LRU,
    /// shared zero-copy with every caller. A cached engine whose
    /// generation lags the source (a delta landed since it was built) is
    /// refreshed under the same per-key latch — incrementally when the
    /// accumulated dirty-row fraction is small (untouched CU shards and
    /// the worker pool carry over), from scratch otherwise. Errors on an
    /// unknown handle.
    pub fn prepared(&self, h: MatrixHandle, opts: &SolveOptions) -> Result<Arc<PreparedMatrix>> {
        let key = EngineKey::for_opts(h, opts);
        let (ctx, cell) = {
            let mut inner = lock(&self.inner);
            let src = inner.sources.get(&h.0).ok_or_else(|| anyhow::anyhow!("unknown matrix handle {}", h.0))?;
            let ctx = match &src.data {
                SourceData::Resident(coo) => SnapshotCtx::Resident(BuildCtx {
                    coo: Arc::clone(coo),
                    fro: src.fro,
                    generation: src.generation,
                    scale: src.scale(self.cfg.skip_normalize),
                }),
                SourceData::Ooc { dir, manifest } => SnapshotCtx::Ooc {
                    dir: dir.clone(),
                    manifest: manifest.clone(),
                    generation: src.generation,
                },
            };
            inner.tick += 1;
            let tick = inner.tick;
            let slot = inner.engines.entry(key.clone()).or_insert_with(|| EngineSlot {
                cell: Arc::new(Mutex::new(None)),
                last_used: tick,
                bytes: 0,
            });
            slot.last_used = tick;
            (ctx, Arc::clone(&slot.cell))
        };

        let generation = match &ctx {
            SnapshotCtx::Resident(c) => c.generation,
            SnapshotCtx::Ooc { generation, .. } => *generation,
        };
        let mut built = lock(&cell);
        let prep = match (built.as_ref(), &ctx) {
            (Some(b), _) if b.generation == generation => {
                self.engine_hits.fetch_add(1, Ordering::SeqCst);
                return Ok(Arc::clone(&b.prep));
            }
            (Some(stale), SnapshotCtx::Resident(bctx)) => {
                // A delta landed since this engine was built: refresh it,
                // reusing untouched shard structure when the dirty set is
                // small and the engine is a native sharded one.
                let dirty = {
                    let inner = lock(&self.inner);
                    inner.sources.get(&h.0).and_then(|s| s.dirty_rows_since(stale.generation))
                };
                let prep = self.refresh_engine(&stale.prep, bctx, dirty, opts);
                self.prepares.fetch_add(1, Ordering::SeqCst);
                prep
            }
            (None, SnapshotCtx::Resident(bctx)) => {
                let prep = Arc::new(self.build_engine(bctx, opts));
                self.prepares.fetch_add(1, Ordering::SeqCst);
                prep
            }
            // OOC sources are immutable (update() rejects them), so an
            // existing build can never be stale — but rebuilding is the
            // correct degenerate behaviour if that ever changes.
            (_, SnapshotCtx::Ooc { dir, manifest, .. }) => {
                let prep = Arc::new(self.build_ooc_engine(dir, manifest, generation, opts)?);
                self.prepares.fetch_add(1, Ordering::SeqCst);
                prep
            }
        };
        *built = Some(BuiltEngine { generation, prep: Arc::clone(&prep) });
        drop(built);

        // Record the engine's footprint and enforce the byte budget.
        let mut inner = lock(&self.inner);
        if let Some(slot) = inner.engines.get_mut(&key) {
            slot.bytes = prep.resident_bytes();
        }
        self.evict_over_budget(&mut inner, &key);
        Ok(prep)
    }

    /// Engine construction from the registry's canonical original-scale
    /// COO, normalizing at the value stream (`scale`). Runs outside the
    /// registry lock (only the per-key latch is held), so concurrent
    /// builds of *different* engines overlap.
    fn build_engine(&self, ctx: &BuildCtx, opts: &SolveOptions) -> PreparedMatrix {
        let mut sw = Stopwatch::start();
        let precision = opts.precision;
        let coo = ctx.coo.as_ref();
        // Each cached engine owns its CU pool, so solves on different
        // resident matrices never contend on one pool (solves on the same
        // engine serialize their fork/joins, matching one device). The
        // cost is `effective_threads` resident OS threads per cached
        // engine — bounded by `budget_bytes` eviction and `unregister`,
        // both of which drop the pool with the engine.
        let native = || {
            let pool = Arc::new(ThreadPool::new(opts.effective_threads()));
            native_operator_scaled(coo, ctx.scale, precision, opts.cus, opts.partition, &pool)
        };
        let (op, engine_used) = select_engine(opts.engine, precision, || self.try_pjrt(coo, ctx.scale), native);
        PreparedMatrix {
            op,
            fro: ctx.fro,
            n: coo.nrows,
            nnz: coo.nnz(),
            precision,
            engine_used,
            prepare_s: sw.lap_s(),
            generation: ctx.generation,
        }
    }

    /// Build the out-of-core engine for a packet directory: open the chunk
    /// tables, validate the stored precision against the engine key, gate
    /// the buffer pool on [`RegistryConfig::ooc_buffer_budget_bytes`], and
    /// bind the double-buffered streaming `ShardedSpmv`. Shard count and
    /// partition policy come from the manifest (they were baked in at
    /// export time), so differing `cus`/`partition` in the options only
    /// name the cache key.
    fn build_ooc_engine(
        &self,
        dir: &std::path::Path,
        manifest: &OocManifest,
        generation: u64,
        opts: &SolveOptions,
    ) -> Result<PreparedMatrix> {
        anyhow::ensure!(
            manifest.precision == opts.precision,
            "precision mismatch: packet files at {} store {}, job requested {} (the stored bits are \
             final — re-export the directory to change formats)",
            dir.display(),
            manifest.precision.name(),
            opts.precision.name()
        );
        let mut sw = Stopwatch::start();
        let budget = self.cfg.ooc_buffer_budget_bytes;
        let op: Arc<dyn crate::lanczos::Operator> = crate::with_precision!(manifest.precision, V => {
            let matrix: Arc<OocMatrix<V>> = OocMatrix::open(dir)?;
            anyhow::ensure!(
                budget == 0 || matrix.buffer_bytes() <= budget,
                "out-of-core buffers for {} need {} bytes, over the {} byte budget (--ooc-budget-mb); \
                 re-export the directory with a smaller chunk target to shrink the buffers",
                dir.display(),
                matrix.buffer_bytes(),
                budget
            );
            let pool = Arc::new(ThreadPool::new(opts.effective_threads()));
            Arc::new(ShardedSpmv::new_ooc(matrix, pool)) as Arc<dyn crate::lanczos::Operator>
        });
        Ok(PreparedMatrix {
            op,
            fro: manifest.fro,
            n: manifest.nrows,
            nnz: manifest.nnz,
            precision: manifest.precision,
            engine_used: "native-ooc",
            prepare_s: sw.lap_s(),
            generation,
        })
    }

    /// Refresh a stale engine to the snapshot generation: incremental when
    /// the dirty history is available, the fraction is under the cutoff,
    /// and the old engine is a native sharded one; full rebuild otherwise.
    fn refresh_engine(
        &self,
        old: &Arc<PreparedMatrix>,
        ctx: &BuildCtx,
        dirty: Option<Vec<u32>>,
        opts: &SolveOptions,
    ) -> Arc<PreparedMatrix> {
        if let Some(dirty) = dirty {
            let frac = dirty.len() as f64 / ctx.coo.nrows.max(1) as f64;
            if frac <= self.cfg.dirty_full_fraction {
                if let Some(prep) = self.rebuild_incremental(old, ctx, &dirty) {
                    self.incremental_rebuilds.fetch_add(1, Ordering::SeqCst);
                    return Arc::new(prep);
                }
            }
        }
        self.full_rebuilds.fetch_add(1, Ordering::SeqCst);
        Arc::new(self.build_engine(ctx, opts))
    }

    /// The incremental path: downcast the cached engine back to its
    /// concrete `ShardedSpmv<V>`, restream the (re-normalized) typed value
    /// array from the updated source — unavoidable, the new Frobenius
    /// scale touches every word — and let
    /// [`ShardedSpmv::rebuild_shards`] rebind the CU shard table, reusing
    /// its worker pool and counting dirty vs carried-over shards. Returns
    /// `None` for opaque engines (PJRT), which take the full-rebuild path.
    fn rebuild_incremental(&self, old: &Arc<PreparedMatrix>, ctx: &BuildCtx, dirty: &[u32]) -> Option<PreparedMatrix> {
        let mut sw = Stopwatch::start();
        let precision = old.precision();
        let coo = ctx.coo.as_ref();
        crate::with_precision!(precision, V => {
            let sharded = old.operator().as_any()?.downcast_ref::<ShardedSpmv<V>>()?;
            let csr: CsrMatrix<V> = typed_csr_scaled::<V>(coo, ctx.scale);
            let (engine, shard_stats) = sharded.rebuild_shards(Arc::new(csr), dirty);
            self.shards_rebuilt.fetch_add(shard_stats.rebuilt as u64, Ordering::SeqCst);
            self.shards_reused.fetch_add(shard_stats.reused as u64, Ordering::SeqCst);
            Some(PreparedMatrix {
                op: Arc::new(engine),
                fro: ctx.fro,
                n: coo.nrows,
                nnz: coo.nnz(),
                precision,
                engine_used: "native",
                prepare_s: sw.lap_s(),
                generation: ctx.generation,
            })
        })
    }

    fn try_pjrt(&self, coo: &CooMatrix, scale: Option<f64>) -> Result<Arc<dyn crate::lanczos::Operator>> {
        // Only runtime *creation* serializes; the guard is released before
        // the O(nnz) PjrtSpmv build so different-key engine builds stay
        // parallel, as the per-key latch design promises.
        let rt = {
            let mut guard = lock(&self.runtime);
            if guard.is_none() {
                *guard = Some(Arc::new(Runtime::cpu()?));
            }
            Arc::clone(guard.as_ref().unwrap())
        };
        // The PJRT path consumes whole matrices: materialize the
        // normalized copy (the registry's source stays original-scale).
        // No scale (skip_normalize) needs no copy at all.
        let op = match scale {
            Some(inv) => PjrtSpmv::new(rt, &scaled_coo_copy(coo, inv))?,
            None => PjrtSpmv::new(rt, coo)?,
        };
        Ok(Arc::new(op))
    }

    /// Evict least-recently-used **built** engines (never the one just
    /// used, never pending builds) until the cache fits the budget.
    fn evict_over_budget(&self, inner: &mut Inner, keep: &EngineKey) {
        if self.cfg.budget_bytes == 0 {
            return;
        }
        loop {
            let total: usize = inner.engines.values().map(|s| s.bytes).sum();
            if total <= self.cfg.budget_bytes {
                return;
            }
            let victim = inner
                .engines
                .iter()
                .filter(|(k, s)| *k != keep && s.bytes > 0)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    inner.engines.remove(&k);
                    self.evictions.fetch_add(1, Ordering::SeqCst);
                }
                None => return, // only the kept/pending engines remain
            }
        }
    }

    /// The PPR normalizer table for a prepared engine: per-column sums of
    /// the **stored** (quantized, Frobenius-scaled) values in f64, cached
    /// per `(handle, precision)` and tagged with the generation — a stream
    /// of PPR jobs on one resident matrix pays the O(nnz) pass once per
    /// generation, not once per job ([`RegistryStats::colsum_builds`] /
    /// [`RegistryStats::colsum_hits`] pin this). Column sums depend only
    /// on the stored value stream, so CU count, partition policy, and
    /// thread count share one table. Returns `None` for opaque engines
    /// (PJRT), which cannot expose their value stream.
    pub fn column_sums(&self, h: MatrixHandle, prep: &PreparedMatrix) -> Option<Arc<Vec<f64>>> {
        let key = (h.0, prep.precision());
        let generation = prep.generation();
        {
            let inner = lock(&self.inner);
            if let Some((built_gen, sums)) = inner.colsums.get(&key) {
                if *built_gen == generation {
                    self.colsum_hits.fetch_add(1, Ordering::SeqCst);
                    return Some(Arc::clone(sums));
                }
            }
        }
        // Compute outside the registry lock (O(nnz)); concurrent callers
        // may race to build the same table, in which case the last insert
        // wins — every caller still returns sums matching its own prep's
        // generation, never a blend.
        let sums = crate::with_precision!(prep.precision(), V => {
            let sharded = prep.operator().as_any()?.downcast_ref::<ShardedSpmv<V>>()?;
            Some(Arc::new(sharded.column_sums()))
        })?;
        self.colsum_builds.fetch_add(1, Ordering::SeqCst);
        let mut inner = lock(&self.inner);
        // A job racing `unregister` still gets its table, but must not
        // resurrect a cache entry for a dead handle (ids are never reused,
        // so the entry would leak forever).
        if inner.sources.contains_key(&h.0) {
            inner.colsums.insert(key, (generation, Arc::clone(&sums)));
        }
        Some(sums)
    }

    /// The early-exit bound table for a prepared engine: per-row L1 norms
    /// of the **stored** (quantized, Frobenius-scaled) values in f64,
    /// cached per `(handle, precision)` and tagged with the generation —
    /// exactly [`MatrixRegistry::column_sums`]'s lifecycle, for the table
    /// [`ShardedSpmv::top_k_with_bounds`] prunes cold CU shards with
    /// ([`RegistryStats::rowbound_builds`] /
    /// [`RegistryStats::rowbound_hits`] pin the once-per-generation bar).
    /// Geometry-free like colsums: every CU count and partition policy
    /// shares one table. Returns `None` for opaque engines (PJRT).
    pub fn row_bounds(&self, h: MatrixHandle, prep: &PreparedMatrix) -> Option<Arc<Vec<f64>>> {
        let key = (h.0, prep.precision());
        let generation = prep.generation();
        {
            let inner = lock(&self.inner);
            if let Some((built_gen, bounds)) = inner.rowbounds.get(&key) {
                if *built_gen == generation {
                    self.rowbound_hits.fetch_add(1, Ordering::SeqCst);
                    return Some(Arc::clone(bounds));
                }
            }
        }
        // Compute outside the registry lock (O(nnz)); a racing build for
        // the same table is benign — last insert wins, every caller gets
        // bounds matching its own prep's generation.
        let bounds = crate::with_precision!(prep.precision(), V => {
            let sharded = prep.operator().as_any()?.downcast_ref::<ShardedSpmv<V>>()?;
            Some(Arc::new(sharded.row_l1_norms()))
        })?;
        self.rowbound_builds.fetch_add(1, Ordering::SeqCst);
        let mut inner = lock(&self.inner);
        // Same no-resurrect rule as colsums: a job racing `unregister`
        // keeps its table but must not re-cache under a dead handle.
        if inner.sources.contains_key(&h.0) {
            inner.rowbounds.insert(key, (generation, Arc::clone(&bounds)));
        }
        Some(bounds)
    }

    /// Warm seed for a PPR job: the previous **converged** scores recorded
    /// for the same `(handle, precision, source, alpha)`, if the warm-start
    /// cache is enabled and the entry survived every update since (the
    /// [`RegistryConfig::warm_keep_tol`] guard in
    /// [`MatrixRegistry::update`]). The damped iteration's fixed point is
    /// unique, so a surviving seed changes iteration count, never the
    /// answer's limit — `ppr_warm_hits` plus the result's iteration
    /// telemetry show warm re-solves streaming the matrix fewer times.
    pub fn ppr_warm_scores(&self, h: MatrixHandle, precision: Precision, source: usize, alpha: f64) -> Option<Vec<f32>> {
        if !self.cfg.warm_start {
            return None;
        }
        let inner = lock(&self.inner);
        let seed = inner.ppr_warm.get(&(h.0, precision, source, alpha.to_bits()))?;
        self.ppr_warm_hits.fetch_add(1, Ordering::SeqCst);
        Some(seed.clone())
    }

    /// Record a completed PPR's scores for future warm restarts. Only
    /// **converged** results are worth seeding from (a capped run may be
    /// far from the fixed point); callers enforce that. No-op unless
    /// [`RegistryConfig::warm_start`] is set.
    pub fn store_ppr_warm(&self, h: MatrixHandle, precision: Precision, source: usize, alpha: f64, scores: &[f32]) {
        if !self.cfg.warm_start || scores.is_empty() {
            return;
        }
        let mut inner = lock(&self.inner);
        // No-resurrect: never cache under an unregistered handle.
        if !inner.sources.contains_key(&h.0) {
            return;
        }
        let key = (h.0, precision, source, alpha.to_bits());
        if inner.ppr_warm.insert(key, scores.to_vec()).is_none() {
            inner.ppr_warm_order.push_back(key);
            while inner.ppr_warm.len() > PPR_WARM_CAP {
                if let Some(old) = inner.ppr_warm_order.pop_front() {
                    inner.ppr_warm.remove(&old);
                } else {
                    break;
                }
            }
        }
    }

    /// Warm-start seed for a repeated `(handle, k, precision)` query:
    /// the previous dominant Ritz vector, if the cache is enabled, has
    /// seen this query complete, and the key is not negatively cached.
    pub fn warm_v1(&self, h: MatrixHandle, k: usize, precision: Precision) -> Option<Vec<f32>> {
        self.warm_panel(h, k, precision, 1).and_then(|p| p.into_iter().next())
    }

    /// Warm-start *panel* for a repeated `(handle, k, precision)` query:
    /// up to `b` leading Ritz vectors of the previous completed solve, in
    /// decreasing-magnitude order — the block-Lanczos seed block. `b = 1`
    /// degenerates to [`MatrixRegistry::warm_v1`].
    pub fn warm_panel(&self, h: MatrixHandle, k: usize, precision: Precision, b: usize) -> Option<Vec<Vec<f32>>> {
        if !self.cfg.warm_start || b == 0 {
            return None;
        }
        let inner = lock(&self.inner);
        match inner.warm.get(&(h.0, k, precision)) {
            Some(WarmEntry::Seed(panel)) => {
                self.warm_hits.fetch_add(1, Ordering::SeqCst);
                Some(panel.iter().take(b).cloned().collect())
            }
            Some(WarmEntry::Disabled) | None => None,
        }
    }

    /// Record the dominant Ritz vector of a completed query for future
    /// warm starts. No-op unless [`RegistryConfig::warm_start`] is set, or
    /// when the key has been [`MatrixRegistry::disable_warm`]-ed.
    pub fn store_warm(&self, h: MatrixHandle, k: usize, precision: Precision, dominant: &[f32]) {
        if dominant.is_empty() {
            return;
        }
        self.store_warm_panel(h, k, precision, std::slice::from_ref(&dominant));
    }

    /// Record the leading Ritz vectors of a completed query (decreasing
    /// magnitude) for future warm starts: column 0 seeds single-vector
    /// solves, the whole front seeds block panels. No-op unless
    /// [`RegistryConfig::warm_start`] is set, or when the key has been
    /// [`MatrixRegistry::disable_warm`]-ed.
    pub fn store_warm_panel(&self, h: MatrixHandle, k: usize, precision: Precision, ritz: &[&[f32]]) {
        if !self.cfg.warm_start || ritz.is_empty() || ritz.iter().any(|c| c.is_empty()) {
            return;
        }
        let mut inner = lock(&self.inner);
        let key = (h.0, k, precision);
        if matches!(inner.warm.get(&key), Some(WarmEntry::Disabled)) {
            return;
        }
        let panel: Vec<Vec<f32>> = ritz.iter().map(|c| c.to_vec()).collect();
        if inner.warm.insert(key, WarmEntry::Seed(panel)).is_none() {
            inner.warm_order.push_back(key);
            while inner.warm.len() > WARM_CAP {
                if let Some(old) = inner.warm_order.pop_front() {
                    inner.warm.remove(&old);
                } else {
                    break;
                }
            }
        }
    }

    /// Negatively cache a `(handle, k, precision)` query: its warm seed
    /// collapsed the Krylov subspace (truncated solve), so future repeats
    /// run cold instead of repeating a wasted warm solve plus retry.
    pub fn disable_warm(&self, h: MatrixHandle, k: usize, precision: Precision) {
        if !self.cfg.warm_start {
            return;
        }
        let mut inner = lock(&self.inner);
        let key = (h.0, k, precision);
        if inner.warm.insert(key, WarmEntry::Disabled).is_none() {
            inner.warm_order.push_back(key);
        }
    }

    /// Telemetry snapshot.
    pub fn stats(&self) -> RegistryStats {
        let inner = lock(&self.inner);
        RegistryStats {
            matrices: inner.sources.len(),
            engines: inner.engines.values().filter(|s| s.bytes > 0).count(),
            resident_bytes: inner.engines.values().map(|s| s.bytes).sum(),
            prepares: self.prepares.load(Ordering::SeqCst),
            engine_hits: self.engine_hits.load(Ordering::SeqCst),
            dedup_hits: self.dedup_hits.load(Ordering::SeqCst),
            evictions: self.evictions.load(Ordering::SeqCst),
            warm_entries: inner.warm.len(),
            warm_hits: self.warm_hits.load(Ordering::SeqCst),
            updates: self.updates.load(Ordering::SeqCst),
            incremental_rebuilds: self.incremental_rebuilds.load(Ordering::SeqCst),
            full_rebuilds: self.full_rebuilds.load(Ordering::SeqCst),
            shards_rebuilt: self.shards_rebuilt.load(Ordering::SeqCst),
            shards_reused: self.shards_reused.load(Ordering::SeqCst),
            warm_kept: self.warm_kept.load(Ordering::SeqCst),
            warm_dropped: self.warm_dropped.load(Ordering::SeqCst),
            colsum_builds: self.colsum_builds.load(Ordering::SeqCst),
            colsum_hits: self.colsum_hits.load(Ordering::SeqCst),
            rowbound_builds: self.rowbound_builds.load(Ordering::SeqCst),
            rowbound_hits: self.rowbound_hits.load(Ordering::SeqCst),
            ppr_warm_entries: inner.ppr_warm.len(),
            ppr_warm_hits: self.ppr_warm_hits.load(Ordering::SeqCst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Solver;
    use crate::graphs;
    use crate::lanczos::LanczosWorkspace;

    fn opts_k(k: usize) -> SolveOptions {
        SolveOptions { k, ..Default::default() }
    }

    #[test]
    fn register_dedups_identical_content_but_not_scaled_copies() {
        let reg = MatrixRegistry::default();
        let m = graphs::mesh2d(10, 10, 0.9, 0.02, 1);
        let h1 = reg.register(m.clone()).unwrap();
        let h2 = reg.register(m.clone()).unwrap();
        assert_eq!(h1, h2, "identical content shares one residency");
        assert_eq!(reg.stats().dedup_hits, 1);
        assert_eq!(reg.stats().matrices, 1);
        // A scaled copy has different original-scale values (and would
        // rescale eigenvalues by a different norm): it must NOT alias the
        // original.
        let mut scaled = m.clone();
        for v in &mut scaled.vals {
            *v *= 2.0;
        }
        let h3 = reg.register(scaled).unwrap();
        assert_ne!(h1, h3);
        assert_eq!(reg.stats().matrices, 2);
        // Different graph, different handle.
        let h4 = reg.register(graphs::mesh2d(10, 10, 0.9, 0.02, 2)).unwrap();
        assert_ne!(h1, h4);
    }

    #[test]
    fn register_validates_input() {
        let reg = MatrixRegistry::default();
        assert!(reg.register(CooMatrix::new(4, 5)).is_err(), "non-square");
        assert!(reg.register(CooMatrix::new(0, 0)).is_err(), "empty");
        let mut asym = CooMatrix::new(4, 4);
        asym.push(0, 0, 1.0);
        asym.push(0, 1, 0.5);
        assert!(reg.register(asym.clone()).is_err(), "asymmetric");
        let trusting = MatrixRegistry::new(RegistryConfig { skip_symmetry_check: true, ..Default::default() });
        assert!(trusting.register(asym).is_ok());
    }

    #[test]
    fn prepared_builds_once_per_key() {
        let reg = MatrixRegistry::default();
        let h = reg.register(graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 5)).unwrap();
        let (n, nnz) = reg.dims(h).unwrap();
        assert_eq!(n, 1 << 7);
        assert!(nnz > 0);
        let a = reg.prepared(h, &opts_k(4)).unwrap();
        let b = reg.prepared(h, &opts_k(8)).unwrap(); // same key: k is not part of engine identity
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.stats().prepares, 1);
        assert_eq!(reg.stats().engine_hits, 1);
        // A different storage format is a different engine.
        let c = reg.prepared(h, &SolveOptions { precision: Precision::FixedQ1_15, ..opts_k(4) }).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(reg.stats().prepares, 2);
        assert_eq!(reg.stats().engines, 2);
        // Unknown handle errors (ids are globally unique, so a foreign or
        // stale handle can never alias another registry's matrix).
        assert!(reg.prepared(MatrixHandle(u64::MAX), &opts_k(4)).is_err());
    }

    #[test]
    fn registry_solves_match_direct_solver() {
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 23);
        let reg = MatrixRegistry::default();
        let h = reg.register(m.clone()).unwrap();
        let opts = opts_k(6);
        let prep = reg.prepared(h, &opts).unwrap();
        let mut ws = LanczosWorkspace::new();
        let via_registry = Solver::solve_detached(&prep, 6, &opts, &mut ws, None).unwrap();
        let direct = Solver::new(opts).solve(&m).unwrap();
        assert_eq!(via_registry.eigenvalues, direct.eigenvalues);
        assert_eq!(via_registry.eigenvectors, direct.eigenvectors);
    }

    #[test]
    fn unregister_drops_sources_engines_and_warm_entries() {
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let m = graphs::mesh2d(10, 10, 0.9, 0.02, 5);
        let h = reg.register(m.clone()).unwrap();
        let prep = reg.prepared(h, &opts_k(4)).unwrap();
        reg.store_warm(h, 4, Precision::Float32, &[0.1; 100]);
        assert_eq!(reg.stats().matrices, 1);
        assert_eq!(reg.stats().engines, 1);
        assert_eq!(reg.stats().warm_entries, 1);

        assert!(reg.unregister(h));
        assert!(!reg.unregister(h), "second unregister is a no-op");
        let stats = reg.stats();
        assert_eq!(stats.matrices, 0);
        assert_eq!(stats.engines, 0);
        assert_eq!(stats.resident_bytes, 0);
        assert_eq!(stats.warm_entries, 0);
        // Held engines stay usable; the handle itself is dead...
        assert!(prep.n() > 0);
        assert!(reg.prepared(h, &opts_k(4)).is_err());
        assert!(reg.dims(h).is_none());
        // ...and re-registering the same content mints a fresh handle
        // (no dedup against removed state).
        let h2 = reg.register(m).unwrap();
        assert_ne!(h, h2);
        assert!(reg.prepared(h2, &opts_k(4)).is_ok());
    }

    #[test]
    fn byte_budget_evicts_least_recently_used() {
        // Budget sized for roughly one engine: the second prepared engine
        // evicts the first; re-preparing the first rebuilds it.
        let reg = MatrixRegistry::new(RegistryConfig { budget_bytes: 1, ..Default::default() });
        let h1 = reg.register(graphs::mesh2d(12, 12, 0.9, 0.02, 3)).unwrap();
        let h2 = reg.register(graphs::mesh2d(12, 12, 0.9, 0.02, 4)).unwrap();
        let a1 = reg.prepared(h1, &opts_k(4)).unwrap();
        let _a2 = reg.prepared(h2, &opts_k(4)).unwrap();
        let stats = reg.stats();
        assert_eq!(stats.prepares, 2);
        assert!(stats.evictions >= 1, "budget of 1 byte must evict");
        // The evicted engine is still usable by holders of its Arc...
        assert!(a1.n() > 0);
        // ...and a new request simply rebuilds it.
        let a1_again = reg.prepared(h1, &opts_k(4)).unwrap();
        assert!(!Arc::ptr_eq(&a1, &a1_again));
        assert_eq!(reg.stats().prepares, 3);
    }

    #[test]
    fn warm_start_cache_round_trips_when_enabled() {
        let cold = MatrixRegistry::default();
        let h = cold.register(graphs::mesh2d(8, 8, 0.9, 0.02, 7)).unwrap();
        cold.store_warm(h, 4, Precision::Float32, &[1.0; 64]);
        assert!(cold.warm_v1(h, 4, Precision::Float32).is_none(), "disabled by default");

        let warm = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let h = warm.register(graphs::mesh2d(8, 8, 0.9, 0.02, 7)).unwrap();
        assert!(warm.warm_v1(h, 4, Precision::Float32).is_none(), "cold query has no seed");
        warm.store_warm(h, 4, Precision::Float32, &[0.5; 64]);
        assert_eq!(warm.warm_v1(h, 4, Precision::Float32).unwrap(), vec![0.5; 64]);
        assert!(warm.warm_v1(h, 5, Precision::Float32).is_none(), "k is part of the key");
        assert!(warm.warm_v1(h, 4, Precision::FixedQ1_15).is_none(), "precision is part of the key");
        let stats = warm.stats();
        assert_eq!(stats.warm_entries, 1);
        assert_eq!(stats.warm_hits, 1);
    }

    #[test]
    fn warm_panel_round_trips_and_degenerates_to_v1() {
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let h = reg.register(graphs::mesh2d(8, 8, 0.9, 0.02, 11)).unwrap();
        let cols: Vec<Vec<f32>> = (0..3).map(|c| vec![c as f32 + 0.25; 64]).collect();
        let refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        reg.store_warm_panel(h, 8, Precision::Float32, &refs);
        // Full panel, truncated panel, and the v1 view all come from one
        // entry; warm_v1 returns the leading (dominant) column.
        assert_eq!(reg.warm_panel(h, 8, Precision::Float32, 4).unwrap(), cols);
        assert_eq!(reg.warm_panel(h, 8, Precision::Float32, 2).unwrap(), cols[..2].to_vec());
        assert_eq!(reg.warm_v1(h, 8, Precision::Float32).unwrap(), cols[0]);
        assert_eq!(reg.stats().warm_entries, 1);
        // A single-vector store overwrites the same key with a 1-column
        // panel; block requests still get a (smaller) usable seed.
        reg.store_warm(h, 8, Precision::Float32, &cols[1]);
        assert_eq!(reg.warm_panel(h, 8, Precision::Float32, 4).unwrap(), vec![cols[1].clone()]);
        // Disabled keys refuse panels like they refuse v1 seeds.
        reg.disable_warm(h, 8, Precision::Float32);
        reg.store_warm_panel(h, 8, Precision::Float32, &refs);
        assert!(reg.warm_panel(h, 8, Precision::Float32, 4).is_none());
    }

    #[test]
    fn disable_warm_negatively_caches_a_key() {
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let h = reg.register(graphs::mesh2d(8, 8, 0.9, 0.02, 9)).unwrap();
        reg.store_warm(h, 4, Precision::Float32, &[0.5; 64]);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_some());
        reg.disable_warm(h, 4, Precision::Float32);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_none());
        // Stores after disabling are ignored: the key stays cold for good.
        reg.store_warm(h, 4, Precision::Float32, &[0.5; 64]);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_none());
        // Other keys are unaffected.
        reg.store_warm(h, 5, Precision::Float32, &[0.5; 64]);
        assert!(reg.warm_v1(h, 5, Precision::Float32).is_some());
    }

    #[test]
    fn column_sums_cache_builds_once_per_generation_and_precision() {
        let reg = MatrixRegistry::default();
        let m = graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 91);
        let h = reg.register(m.clone()).unwrap();
        let prep = reg.prepared(h, &opts_k(2)).unwrap();
        let a = reg.column_sums(h, &prep).unwrap();
        let b = reg.column_sums(h, &prep).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "repeat requests share one table");
        assert_eq!(a.len(), 1 << 7);
        assert_eq!(reg.stats().colsum_builds, 1);
        assert_eq!(reg.stats().colsum_hits, 1);
        // Another precision stores different values: its own table.
        let prep_q = reg.prepared(h, &SolveOptions { precision: Precision::FixedQ1_15, ..opts_k(2) }).unwrap();
        let q = reg.column_sums(h, &prep_q).unwrap();
        assert!(!Arc::ptr_eq(&a, &q));
        assert_eq!(reg.stats().colsum_builds, 2);
        // A generation bump invalidates: the refreshed engine rebuilds
        // once, and the new table reflects the new values and scale.
        reg.update(h, perturb_delta(&m, 0.02, 1.5)).unwrap();
        let prep2 = reg.prepared(h, &opts_k(2)).unwrap();
        let c = reg.column_sums(h, &prep2).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_ne!(a.as_ref(), c.as_ref());
        assert_eq!(reg.stats().colsum_builds, 3);
        // Unregister purges the handle's tables; an in-flight job holding
        // the prep still computes its table but does not resurrect the
        // cache entry for the dead handle.
        assert!(reg.unregister(h));
        let orphan = reg.column_sums(h, &prep2).unwrap();
        assert_eq!(orphan.as_ref(), c.as_ref());
        assert_eq!(reg.stats().colsum_builds, 4, "dead handle: recompute, no cache");
        let _ = reg.column_sums(h, &prep2).unwrap();
        assert_eq!(reg.stats().colsum_builds, 5, "still not cached");
    }

    #[test]
    fn update_generation_bumps_invalidate_colsum_and_rowbound_caches() {
        // PR 6 only pinned the unregister path; this pins the other half
        // of the lifecycle: an update() generation bump must invalidate
        // the cached colsum AND row-bound tables, and each rebuilds
        // exactly once for the new generation.
        let reg = MatrixRegistry::default();
        let m = graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 93);
        let h = reg.register(m.clone()).unwrap();
        let prep = reg.prepared(h, &opts_k(2)).unwrap();
        let cs1 = reg.column_sums(h, &prep).unwrap();
        let rb1 = reg.row_bounds(h, &prep).unwrap();
        assert_eq!(rb1.len(), 1 << 7);
        let rb1_again = reg.row_bounds(h, &prep).unwrap();
        assert!(Arc::ptr_eq(&rb1, &rb1_again), "repeat requests share one table");
        let stats = reg.stats();
        assert_eq!((stats.rowbound_builds, stats.rowbound_hits), (1, 1));
        assert_eq!((stats.colsum_builds, stats.colsum_hits), (1, 0));

        // A value-changing delta: new generation, new stored values.
        reg.update(h, perturb_delta(&m, 0.02, 1.5)).unwrap();
        let prep2 = reg.prepared(h, &opts_k(2)).unwrap();
        assert_eq!(prep2.generation(), 2);
        let cs2 = reg.column_sums(h, &prep2).unwrap();
        let rb2 = reg.row_bounds(h, &prep2).unwrap();
        assert!(!Arc::ptr_eq(&cs1, &cs2), "stale colsum table must not be served");
        assert!(!Arc::ptr_eq(&rb1, &rb2), "stale row-bound table must not be served");
        assert_ne!(rb1.as_ref(), rb2.as_ref(), "the 1.5x perturbation changes row norms");
        let stats = reg.stats();
        assert_eq!(stats.colsum_builds, 2, "{stats:?}");
        assert_eq!(stats.rowbound_builds, 2, "{stats:?}");
        // The new tables are cached for the new generation.
        let _ = reg.column_sums(h, &prep2).unwrap();
        let _ = reg.row_bounds(h, &prep2).unwrap();
        let stats = reg.stats();
        assert_eq!((stats.colsum_builds, stats.rowbound_builds), (2, 2));
        assert_eq!((stats.colsum_hits, stats.rowbound_hits), (1, 2));
        // Unregister still purges (the path PR 6 pinned for colsums).
        assert!(reg.unregister(h));
        let orphan = reg.row_bounds(h, &prep2).unwrap();
        assert_eq!(orphan.as_ref(), rb2.as_ref());
        assert_eq!(reg.stats().rowbound_builds, 3, "dead handle: recompute, no cache");
    }

    #[test]
    fn ppr_warm_scores_survive_small_deltas_and_follow_the_guard() {
        let reg = MatrixRegistry::new(RegistryConfig {
            warm_start: true,
            warm_keep_tol: 0.05,
            ..Default::default()
        });
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 95);
        let h = reg.register(m.clone()).unwrap();
        let p = Precision::Float32;
        assert!(reg.ppr_warm_scores(h, p, 3, 0.85).is_none(), "cold cache");
        reg.store_ppr_warm(h, p, 3, 0.85, &[0.25; 256]);
        assert_eq!(reg.ppr_warm_scores(h, p, 3, 0.85).unwrap(), vec![0.25; 256]);
        assert!(reg.ppr_warm_scores(h, p, 4, 0.85).is_none(), "source is part of the key");
        assert!(reg.ppr_warm_scores(h, p, 3, 0.9).is_none(), "alpha is part of the key");
        let stats = reg.stats();
        assert_eq!((stats.ppr_warm_entries, stats.ppr_warm_hits), (1, 1));

        // Small delta: the seed crosses the generation bump.
        let rep = reg.update(h, perturb_delta(&m, 0.01, 1.0001)).unwrap();
        assert!(rep.warm_kept);
        assert!(reg.ppr_warm_scores(h, p, 3, 0.85).is_some(), "seed survives a small delta");
        // Violent delta: the guard drops it.
        let rep = reg.update(h, perturb_delta(&m, 1.0, 10.0)).unwrap();
        assert!(!rep.warm_kept);
        assert!(reg.ppr_warm_scores(h, p, 3, 0.85).is_none(), "seed dropped past warm_keep_tol");
        assert_eq!(reg.stats().ppr_warm_entries, 0);

        // Disabled by default, and unregister purges.
        let off = MatrixRegistry::default();
        let h2 = off.register(graphs::mesh2d(8, 8, 0.9, 0.02, 13)).unwrap();
        off.store_ppr_warm(h2, p, 0, 0.85, &[0.1; 64]);
        assert!(off.ppr_warm_scores(h2, p, 0, 0.85).is_none(), "off by default");
        reg.store_ppr_warm(h, p, 1, 0.85, &[0.5; 256]);
        assert!(reg.unregister(h));
        assert_eq!(reg.stats().ppr_warm_entries, 0);
        reg.store_ppr_warm(h, p, 1, 0.85, &[0.5; 256]);
        assert!(reg.ppr_warm_scores(h, p, 1, 0.85).is_none(), "dead handles are never re-cached");
    }

    #[test]
    fn pjrt_requests_share_the_native_engine_when_fallback_is_static() {
        // Without the `pjrt` feature (and always for fixed-point formats),
        // an Engine::Pjrt request is statically known to fall back to
        // native; the cache key collapses onto the native key so the two
        // request flavors share one engine instead of byte-identical
        // twins.
        if cfg!(feature = "pjrt") {
            return; // runtime fallback is not statically known there
        }
        let reg = MatrixRegistry::default();
        let h = reg.register(graphs::mesh2d(8, 8, 0.9, 0.02, 11)).unwrap();
        let a = reg.prepared(h, &SolveOptions { engine: Engine::Pjrt, ..opts_k(4) }).unwrap();
        let b = reg.prepared(h, &opts_k(4)).unwrap(); // Engine::Native
        assert!(Arc::ptr_eq(&a, &b), "fallback and native requests must share one engine");
        assert_eq!(reg.stats().prepares, 1);
        assert_eq!(a.engine(), "native");
    }

    /// Build a symmetric value-perturbation delta touching ~`frac` of the
    /// upper-triangle entries of a canonical symmetric matrix.
    fn perturb_delta(m: &CooMatrix, frac: f64, factor: f32) -> CooDelta {
        let mut canon = m.clone();
        canon.canonicalize();
        let stride = ((1.0 / frac.max(1e-9)) as usize).max(1);
        let mut d = CooDelta::new(canon.nrows, canon.ncols);
        let mut picked = 0usize;
        for i in 0..canon.nnz() {
            let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
            if r <= c {
                picked += 1;
                if picked % stride == 0 {
                    d.upsert_sym(r, c, canon.vals[i] * factor);
                }
            }
        }
        d
    }

    /// Symmetric value-perturbation delta confined to the row/col block
    /// `[0, band)` — dirty rows stay inside one CU shard, so incremental
    /// re-prep telemetry has untouched shards to report.
    fn banded_delta(m: &CooMatrix, band: usize, factor: f32) -> CooDelta {
        let mut canon = m.clone();
        canon.canonicalize();
        let mut d = CooDelta::new(canon.nrows, canon.ncols);
        for i in 0..canon.nnz() {
            let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
            if r <= c && c < band {
                d.upsert_sym(r, c, canon.vals[i] * factor);
            }
        }
        d
    }

    #[test]
    fn update_bumps_generation_and_refreshes_engines_incrementally() {
        let reg = MatrixRegistry::default();
        let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 71);
        let h = reg.register(m.clone()).unwrap();
        assert_eq!(reg.generation(h), Some(1));
        let opts = opts_k(4);
        let prep1 = reg.prepared(h, &opts).unwrap();
        assert_eq!(prep1.generation(), 1);

        // Small symmetric value perturbation confined to one row band.
        let delta = banded_delta(&m, 24, 1.1);
        assert!(!delta.is_empty());
        let report = reg.update(h, delta).unwrap();
        assert_eq!(report.generation, 2);
        assert!(report.changed > 0 && report.dirty_rows > 0);
        assert_eq!(reg.generation(h), Some(2));
        assert_eq!(reg.stats().updates, 1);

        // The cached engine refreshes lazily, incrementally, on next use.
        let prep2 = reg.prepared(h, &opts).unwrap();
        assert!(!Arc::ptr_eq(&prep1, &prep2));
        assert_eq!(prep2.generation(), 2);
        let stats = reg.stats();
        assert_eq!(stats.incremental_rebuilds, 1, "{stats:?}");
        assert_eq!(stats.full_rebuilds, 0, "{stats:?}");
        assert!(stats.shards_reused > 0, "untouched CU shards must carry over: {stats:?}");
        assert_eq!(stats.prepares, 2);
        // Subsequent calls at the same generation are cache hits.
        let prep3 = reg.prepared(h, &opts).unwrap();
        assert!(Arc::ptr_eq(&prep2, &prep3));
        assert_eq!(reg.stats().engine_hits, 1);
        // The old engine snapshot stays usable for in-flight solves.
        assert_eq!(prep1.generation(), 1);
        assert!(prep1.n() > 0);
    }

    #[test]
    fn incremental_refresh_is_exactly_a_fresh_register_and_prepare() {
        // The acceptance bar, at unit scale: after a delta, solving on the
        // incrementally refreshed engine equals (bitwise) solving on a
        // from-scratch register+prepare of the mutated matrix.
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 81);
        for precision in [Precision::Float32, Precision::FixedQ1_31] {
            let opts = SolveOptions { precision, ..opts_k(5) };
            let reg = MatrixRegistry::default();
            let h = reg.register(m.clone()).unwrap();
            let _ = reg.prepared(h, &opts).unwrap();
            let delta = perturb_delta(&m, 0.02, 1.25);
            reg.update(h, delta.clone()).unwrap();
            let inc = reg.prepared(h, &opts).unwrap();
            assert_eq!(reg.stats().incremental_rebuilds, 1);

            // From scratch: mutate a canonical copy, register, prepare.
            let mut scratch = m.clone();
            scratch.canonicalize();
            let mut d = delta.clone();
            d.canonicalize();
            scratch.apply_delta(&d);
            let reg2 = MatrixRegistry::default();
            let h2 = reg2.register(scratch).unwrap();
            let fresh = reg2.prepared(h2, &opts).unwrap();
            assert_eq!(reg2.stats().full_rebuilds, 0);

            assert_eq!(inc.frobenius_norm().to_bits(), fresh.frobenius_norm().to_bits(), "{precision:?}");
            assert_eq!(inc.nnz(), fresh.nnz());
            let mut ws = LanczosWorkspace::new();
            let a = Solver::solve_detached(&inc, 5, &opts, &mut ws, None).unwrap();
            let b = Solver::solve_detached(&fresh, 5, &opts, &mut ws, None).unwrap();
            assert_eq!(a.eigenvalues, b.eigenvalues, "{precision:?}");
            assert_eq!(a.eigenvectors, b.eigenvectors, "{precision:?}");
        }
    }

    #[test]
    fn large_or_historyless_deltas_fall_back_to_full_rebuild() {
        let reg = MatrixRegistry::new(RegistryConfig { dirty_full_fraction: 0.001, ..Default::default() });
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 83);
        let h = reg.register(m.clone()).unwrap();
        let _ = reg.prepared(h, &opts_k(4)).unwrap();
        // Perturb far more rows than the (tiny) incremental cutoff allows.
        reg.update(h, perturb_delta(&m, 0.5, 1.1)).unwrap();
        let _ = reg.prepared(h, &opts_k(4)).unwrap();
        let stats = reg.stats();
        assert_eq!(stats.full_rebuilds, 1, "{stats:?}");
        assert_eq!(stats.incremental_rebuilds, 0, "{stats:?}");
    }

    #[test]
    fn update_validates_input() {
        let reg = MatrixRegistry::default();
        let m = graphs::mesh2d(8, 8, 0.9, 0.02, 31);
        let h = reg.register(m).unwrap();
        // Unknown handle.
        assert!(reg.update(MatrixHandle(u64::MAX), CooDelta::new(64, 64)).is_err());
        // Dimension mismatch.
        assert!(reg.update(h, CooDelta::new(3, 3)).is_err());
        // Asymmetric delta rejected (symmetry checking on by default).
        let mut asym = CooDelta::new(64, 64);
        asym.upsert(0, 1, 5.0);
        assert!(reg.update(h, asym).is_err());
        // Empty delta: no-op, generation unchanged.
        let rep = reg.update(h, CooDelta::new(64, 64)).unwrap();
        assert_eq!(rep.generation, 1);
        assert_eq!(rep.dirty_rows, 0);
        assert_eq!(reg.generation(h), Some(1));
        assert_eq!(reg.stats().updates, 0);
    }

    #[test]
    fn warm_seeds_survive_small_deltas_and_drop_on_large_ones() {
        let reg = MatrixRegistry::new(RegistryConfig {
            warm_start: true,
            warm_keep_tol: 0.05,
            ..Default::default()
        });
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 87);
        let h = reg.register(m.clone()).unwrap();
        reg.store_warm(h, 4, Precision::Float32, &[0.1; 256]);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_some());

        // Tiny perturbation: seeds retained across the generation bump.
        let rep = reg.update(h, perturb_delta(&m, 0.01, 1.0001)).unwrap();
        assert!(rep.rel_delta <= 0.05, "rel_delta {}", rep.rel_delta);
        assert!(rep.warm_kept);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_some(), "warm seed kept across generations");
        assert_eq!(reg.stats().warm_kept, 1);

        // Violent perturbation: seeds dropped.
        let rep = reg.update(h, perturb_delta(&m, 1.0, 10.0)).unwrap();
        assert!(rep.rel_delta > 0.05, "rel_delta {}", rep.rel_delta);
        assert!(!rep.warm_kept);
        assert!(reg.warm_v1(h, 4, Precision::Float32).is_none(), "warm seed dropped");
        assert_eq!(reg.stats().warm_dropped, 1);
    }

    #[test]
    fn ooc_handles_register_prepare_and_refuse_updates() {
        let reg = MatrixRegistry::default();
        // Export a resident prepare into packet files, then register the
        // directory — the registry never sees the COO.
        let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 97);
        let opts = SolveOptions { cus: 3, ..opts_k(4) };
        let mut solver = Solver::new(opts.clone());
        let prep_res = solver.prepare(&m).unwrap();
        let dir = crate::sparse::ooc::scratch_dir("reg-ooc");
        prep_res.export_ooc(&dir, Some(4096)).unwrap();

        let h = reg.register_ooc(&dir).unwrap();
        assert_eq!(reg.register_ooc(&dir).unwrap(), h, "same directory dedups onto one handle");
        assert_eq!(reg.stats().dedup_hits, 1);
        assert_eq!(reg.dims(h), Some((1 << 9, prep_res.nnz())));

        let prep = reg.prepared(h, &opts).unwrap();
        assert_eq!(prep.engine(), "native-ooc");
        assert!(prep.is_ooc());
        let again = reg.prepared(h, &opts).unwrap();
        assert!(Arc::ptr_eq(&prep, &again), "the streamed engine is cached like any other");
        assert_eq!(reg.stats().engine_hits, 1);

        // Solves on the streamed engine are bitwise the resident solve.
        let mut ws = LanczosWorkspace::new();
        let a = Solver::solve_detached(&prep_res, 4, &opts, &mut ws, None).unwrap();
        let b = Solver::solve_detached(&prep, 4, &opts, &mut ws, None).unwrap();
        assert_eq!(a.eigenvalues, b.eigenvalues);
        assert_eq!(a.eigenvectors, b.eigenvectors);
        assert!(b.metrics.io_bytes_read > 0);

        // The stored bits are final: another precision is rejected, not
        // silently re-quantized...
        let err = reg.prepared(h, &SolveOptions { precision: Precision::FixedQ1_15, ..opts_k(4) }).unwrap_err();
        assert!(err.to_string().contains("precision mismatch"), "{err}");
        // ...and deltas cannot be spliced into packet files.
        let err = reg.update(h, CooDelta::new(1 << 9, 1 << 9)).unwrap_err();
        assert!(err.to_string().contains("out-of-core"), "{err}");

        // Unregister drops the handle; the directory itself is untouched.
        assert!(reg.unregister(h));
        assert!(reg.prepared(h, &opts).is_err());
        assert!(dir.join(crate::sparse::MANIFEST_NAME).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn register_ooc_rejects_a_directory_without_a_manifest() {
        let reg = MatrixRegistry::default();
        let dir = crate::sparse::ooc::scratch_dir("reg-missing");
        let err = reg.register_ooc(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("manifest"), "{err:#}");
    }

    #[test]
    fn ooc_engine_does_not_evict_smaller_resident_engines_that_fit() {
        // The eviction-accounting bar: an out-of-core handle is charged at
        // its chunk-buffer bytes, NOT the O(nnz) size of the file it
        // streams — so caching its engine must not push a small resident
        // engine (which fits the budget) out of the LRU.
        let small = graphs::mesh2d(12, 12, 0.9, 0.02, 3);
        let big = graphs::mesh2d(128, 128, 0.9, 0.02, 5);
        let small_opts = opts_k(4);
        let big_opts = SolveOptions { cus: 2, ..opts_k(4) };

        // Measure the real footprints first (engine byte accounting is
        // deterministic, so throwaway prepares predict the registry's).
        let small_bytes = Solver::new(small_opts.clone()).prepare(&small).unwrap().resident_bytes();
        let big_prep = Solver::new(big_opts.clone()).prepare(&big).unwrap();
        let dir = crate::sparse::ooc::scratch_dir("reg-evict");
        big_prep.export_ooc(&dir, Some(4096)).unwrap();
        let ooc_buffer = crate::sparse::OocMatrix::<f32>::open(&dir).unwrap().buffer_bytes();
        // The scale relation the whole feature rests on: the streaming
        // buffers plus the small engine fit where the big matrix resident
        // would not.
        assert!(
            small_bytes + ooc_buffer < big_prep.resident_bytes(),
            "buffers {ooc_buffer} + small {small_bytes} must undercut resident {}",
            big_prep.resident_bytes()
        );

        let reg = MatrixRegistry::new(RegistryConfig {
            budget_bytes: small_bytes + ooc_buffer,
            ..Default::default()
        });
        let hs = reg.register(small).unwrap();
        let small_engine = reg.prepared(hs, &small_opts).unwrap();
        let hb = reg.register_ooc(&dir).unwrap();
        let _big_engine = reg.prepared(hb, &big_opts).unwrap();
        let stats = reg.stats();
        assert_eq!(stats.evictions, 0, "OOC charged at O(buffer) must not evict: {stats:?}");
        assert_eq!(stats.engines, 2);
        assert_eq!(stats.resident_bytes, small_bytes + ooc_buffer);
        // The small engine is still the cached one, untouched.
        let small_again = reg.prepared(hs, &small_opts).unwrap();
        assert!(Arc::ptr_eq(&small_engine, &small_again));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ooc_buffer_budget_gates_prepare() {
        let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 101);
        let opts = SolveOptions { cus: 2, ..opts_k(4) };
        let prep = Solver::new(opts.clone()).prepare(&m).unwrap();
        let dir = crate::sparse::ooc::scratch_dir("reg-budget");
        prep.export_ooc(&dir, Some(4096)).unwrap();
        let reg = MatrixRegistry::new(RegistryConfig {
            ooc_buffer_budget_bytes: 1, // nothing fits
            ..Default::default()
        });
        let h = reg.register_ooc(&dir).unwrap();
        let err = reg.prepared(h, &opts).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
        // Raising the budget (a fresh registry — config is construction-
        // time) admits the same directory.
        let reg2 = MatrixRegistry::new(RegistryConfig {
            ooc_buffer_budget_bytes: 64 << 20,
            ..Default::default()
        });
        let h2 = reg2.register_ooc(&dir).unwrap();
        assert!(reg2.prepared(h2, &opts).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_started_solve_converges_on_repeat_query() {
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let m = graphs::rmat(1 << 7, 8 << 7, 0.57, 0.19, 0.19, 41);
        let h = reg.register(m).unwrap();
        let opts = opts_k(4);
        let prep = reg.prepared(h, &opts).unwrap();
        let mut ws = LanczosWorkspace::new();
        let first = Solver::solve_detached(&prep, 4, &opts, &mut ws, None).unwrap();
        assert!(!first.metrics.warm_started);
        reg.store_warm(h, 4, opts.precision, &first.eigenvectors[0]);
        let v1 = reg.warm_v1(h, 4, opts.precision);
        assert!(v1.is_some());
        let second = Solver::solve_detached(&prep, 4, &opts, &mut ws, v1).unwrap();
        assert!(second.metrics.warm_started);
        // Same dominant eigenvalue, warm or cold (both are finite-K Ritz
        // estimates, so compare at estimate accuracy, not bitwise).
        assert!(
            (second.eigenvalues[0] - first.eigenvalues[0]).abs() < 2e-2 * first.eigenvalues[0].abs().max(1.0),
            "{} vs {}",
            second.eigenvalues[0],
            first.eigenvalues[0]
        );
    }
}

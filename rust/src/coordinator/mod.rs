//! L3 coordinator — the end-to-end Top-K eigensolver pipeline.
//!
//! [`Solver`] wires the phases the way the hardware does (Figure 6):
//!
//! 1. **Prepare**: canonicalize + symmetrize check + Frobenius-normalize
//!    (entries into `(-1,1)`, §III-A), build CSR **in the storage format
//!    the solve requested** (typed engine selection: [`Precision`]
//!    dispatched over the monomorphized `ShardedSpmv<V>` kernels),
//!    partition rows across the CU pool.
//! 2. **Lanczos** (SLR0 twin): K iterations with the sharded SpMV engine —
//!    native typed CSR stripes on the thread pool, or the PJRT artifact
//!    path ([`crate::runtime::PjrtSpmv`], f32 only) when enabled and a
//!    compiled shape fits. Basis vectors are stored quantized
//!    ([`crate::lanczos::lanczos_typed`]); dots and norms accumulate in
//!    float (§IV).
//! 3. **Jacobi** (SLR1/2 twin): systolic-array diagonalization of the
//!    `K x K` tridiagonal output.
//! 4. **Lift + rescale**: eigenvectors through the (typed) Lanczos basis,
//!    eigenvalues rescaled by the Frobenius norm.
//!
//! The prepare phase is split out as [`Solver::prepare`] →
//! [`PreparedMatrix`] so that several solves over the *same* matrix (the
//! batched service's multi-K fast path) share one canonicalization, one
//! typed CSR conversion and one sharded engine instead of redoing the
//! O(nnz) setup per job.
//!
//! [`service`] adds a multi-tenant job queue on top (the data-center usage
//! the paper motivates), and [`verify`] computes the paper's Fig 11
//! accuracy metrics for any solution.

pub mod registry;
pub mod scheduler;
pub mod service;
pub mod verify;

pub use registry::{MatrixHandle, MatrixRegistry, RegistryConfig, RegistryStats, UpdateReport};

use crate::fixed::{packet_capacity, Precision};
use crate::jacobi::{jacobi_eigen, JacobiMode, SystolicStats};
use crate::lanczos::{block_lanczos_typed_ws, BlockLanczosResult};
use crate::lanczos::{lanczos_typed_ws, lift_eigenvector_typed, LanczosOptions, LanczosResult};
use crate::lanczos::{LanczosWorkspace, Operator, ReorthPolicy};
use crate::linalg::qr_algorithm_symmetric;
use crate::runtime::{PjrtSpmv, Runtime};
use crate::sparse::{
    normalize_frobenius, CooMatrix, CsrMatrix, OocManifest, OocMatrix, PacketFileWriter, PartitionPolicy,
    ShardedSpmv,
};
use crate::util::pool::ThreadPool;
use crate::util::timer::Stopwatch;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Which SpMV engine drives the Lanczos loop.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Native sharded CSR kernels on the CU thread pool, in the storage
    /// format selected by [`SolveOptions::precision`].
    Native,
    /// PJRT-compiled Pallas/XLA artifact (falls back to native when no
    /// compiled shape fits, artifacts are missing, the crate was built
    /// without the `pjrt` feature, or a fixed-point storage format was
    /// requested — the artifacts are f32).
    Pjrt,
}

/// Solve configuration.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Number of eigenpairs.
    pub k: usize,
    /// Reorthogonalization cadence (paper default: every 2 iterations).
    pub reorth: ReorthPolicy,
    /// Storage format of the datapath: matrix value arrays and Lanczos
    /// basis vectors are *stored* in this format (paper device: Q1.31
    /// fixed point; Q1.15 halves value bytes and packs 6 entries per
    /// 512-bit line instead of 5).
    pub precision: Precision,
    /// Jacobi engine for phase 2.
    pub jacobi: JacobiMode,
    /// SpMV compute units — row shards of the matrix (paper: 5).
    pub cus: usize,
    /// Worker threads in the CU pool. `0` (the default) means one worker
    /// per CU; smaller values multiplex shards onto fewer threads (useful
    /// when many solver replicas share a host), larger values are allowed
    /// but idle beyond `cus`.
    pub threads: usize,
    /// Row partition policy across CUs.
    pub partition: PartitionPolicy,
    /// SpMV engine.
    pub engine: Engine,
    /// Skip Frobenius normalization (input already normalized).
    pub skip_normalize: bool,
    /// Skip the O(nnz) structural symmetry check in the prepare phase.
    /// The Lanczos recurrence silently produces wrong spectra on
    /// asymmetric operators, so the check is on by default and rejects
    /// asymmetric input with an error; trusted callers that already
    /// guarantee symmetry (e.g. generators, a registry re-preparing a
    /// checked matrix) can opt out to save the pass.
    pub skip_symmetry_check: bool,
    /// Use the fused single-sweep Lanczos datapath (default). `false`
    /// (`--no-fuse` at the CLI) selects the serial-pass reference
    /// implementation — same spectra, more full-length vector passes.
    pub fuse: bool,
    /// Adaptive Lanczos stopping: `Some(tol)` lets the iteration run past
    /// K (up to `2K + 8` iterations) and stop as soon as the top-K Ritz
    /// values stabilize to relative tolerance `tol`. This is what turns a
    /// warm start into an SpMV saving — a seed close to the invariant
    /// subspace converges in fewer iterations. `None` (the default) is
    /// the paper's fixed K-iteration schedule, bit-identical to previous
    /// behaviour.
    pub adaptive_tol: Option<f64>,
    /// Block-Lanczos width `b`: Krylov columns advanced per matrix pass.
    /// `1` (the default) is the paper's single-vector recurrence,
    /// bit-identical to previous behaviour. `b > 1` switches phase 1 to
    /// the block engine: each iteration streams the matrix **once** while
    /// applying it to all `b` columns (SpMV + Paige block axpy + block
    /// dots + reorthogonalization projections, fused per shard stripe),
    /// so HBM bytes per converged Ritz pair drop by up to `b` on the
    /// bandwidth-bound datapath, and clustered eigenvalues converge in
    /// fewer matrix passes. Phase 2 diagonalizes the resulting band
    /// matrix with the dense QR reference (outside the systolic array's
    /// tridiagonal contract).
    pub block_size: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            k: 8,
            reorth: ReorthPolicy::EveryN(2),
            precision: Precision::Float32,
            jacobi: JacobiMode::Systolic,
            cus: 5,
            threads: 0,
            partition: PartitionPolicy::BalancedNnz,
            engine: Engine::Native,
            skip_normalize: false,
            skip_symmetry_check: false,
            fuse: true,
            adaptive_tol: None,
            block_size: 1,
        }
    }
}

impl SolveOptions {
    /// Effective CU-pool worker count: `threads`, or one per CU when 0.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            self.cus.max(1)
        } else {
            self.threads
        }
    }
}

/// Timing + diagnostics of one solve.
#[derive(Clone, Debug, Default)]
pub struct SolveMetrics {
    /// Prepare phase seconds (normalize + CSR + partition). For solves
    /// sharing a [`PreparedMatrix`], every solution reports the same
    /// shared preparation cost.
    pub prepare_s: f64,
    /// Lanczos phase seconds.
    pub lanczos_s: f64,
    /// Jacobi phase seconds.
    pub jacobi_s: f64,
    /// Lift/rescale seconds.
    pub lift_s: f64,
    /// Logical SpMV count: effective basis size (`matrix_passes *
    /// block_size` on the block path).
    pub spmv_count: usize,
    /// Full streams of the matrix value array phase 1 performed. On the
    /// single-vector path this equals `spmv_count`; on the block path one
    /// fused pass applies the operator to all `block_size` columns, so
    /// `matrix_passes = spmv_count / block_size`. HBM traffic
    /// (`packets_streamed` / `bytes_streamed`) is charged per matrix
    /// pass, not per logical SpMV.
    pub matrix_passes: usize,
    /// Block-Lanczos width this solve ran with (1 = single-vector path).
    pub block_size: usize,
    /// Systolic statistics from phase 2.
    pub systolic: SystolicStats,
    /// Engine actually used ("native" / "pjrt").
    pub engine_used: &'static str,
    /// Lanczos breakdown iteration, if the subspace closed early.
    pub breakdown_at: Option<usize>,
    /// Storage format of the datapath ("f32" / "q1.31" / "q2.30" /
    /// "q1.15").
    pub precision: &'static str,
    /// Bytes of the matrix value array in the storage format (half the
    /// f32 figure at Q1.15).
    pub value_bytes: usize,
    /// COO entries per 512-bit HBM line in the storage format (§IV-B1:
    /// 5 at f32, 6 at Q1.15).
    pub packet_capacity: usize,
    /// 512-bit matrix-stream lines moved across all matrix passes of this
    /// solve (one pass serves every block column on the fused block path).
    pub packets_streamed: usize,
    /// Matrix-stream bytes moved across all matrix passes (whole 64-byte
    /// lines).
    pub bytes_streamed: usize,
    /// Bytes of the stored Lanczos basis (`k * n` words of the storage
    /// format).
    pub basis_bytes: usize,
    /// Fused Lanczos fork/join sweeps executed (`Operator::apply_fused`
    /// calls — one per iteration on the fused datapath, 0 with
    /// `--no-fuse`).
    pub fused_sweeps: usize,
    /// Full-length vector passes the Lanczos iteration phase performed
    /// (3 per full iteration when fused; every serial axpy/dot/norm pass —
    /// two per reorthogonalized basis row — when unfused).
    pub vector_passes: usize,
    /// Whether this solve was seeded with a warm-start vector (the
    /// registry's cached dominant Ritz vector for a repeated `(handle, k)`
    /// query) instead of the paper's uniform `v1`.
    pub warm_started: bool,
    /// Generation of the prepared matrix this solve ran against: bumped by
    /// every [`MatrixRegistry::update`] on the handle, 0 for matrices
    /// prepared outside the registry. Lets clients correlate answers with
    /// the delta stream they submitted.
    pub generation: u64,
    /// Packet-file bytes this solve read from storage (out-of-core
    /// engines only; 0 when the matrix is RAM-resident). Delta of the
    /// engine's monotone IO counter around the solve, so concurrent solves
    /// sharing one OOC engine each report the traffic observed during
    /// their own window.
    pub io_bytes_read: u64,
    /// Times the fused sweep had to block on a chunk whose prefetch had
    /// not completed (out-of-core engines only). Stalls well below the
    /// chunk count mean the double buffer kept the compute units fed.
    pub prefetch_stalls: u64,
}

impl SolveMetrics {
    /// Total seconds.
    pub fn total_s(&self) -> f64 {
        self.prepare_s + self.lanczos_s + self.jacobi_s + self.lift_s
    }
}

/// A Top-K eigensolution of the *original* (pre-normalization) matrix.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Eigenvalues, decreasing magnitude, rescaled to the input matrix.
    pub eigenvalues: Vec<f64>,
    /// Unit eigenvectors, one per eigenvalue (length n).
    pub eigenvectors: Vec<Vec<f32>>,
    /// Frobenius norm used for rescaling.
    pub frobenius_norm: f64,
    /// Run diagnostics.
    pub metrics: SolveMetrics,
}

impl Solution {
    /// Iterator over `(lambda, eigenvector)` pairs.
    pub fn pairs(&self) -> impl Iterator<Item = (f64, &Vec<f32>)> {
        self.eigenvalues.iter().copied().zip(self.eigenvectors.iter())
    }
    /// Number of pairs returned (may be < requested K after breakdown).
    pub fn k(&self) -> usize {
        self.eigenvalues.len()
    }
}

/// A matrix prepared once for repeated solves: canonicalized, normalized,
/// converted to CSR in the requested storage format, and bound to an SpMV
/// engine. Built by [`Solver::prepare`] / [`Solver::prepare_owned`];
/// consumed by [`Solver::solve_prepared`] /
/// [`Solver::solve_prepared_with_k`] / [`Solver::solve_detached`].
///
/// `PreparedMatrix` is `Send + Sync` and the engine is held as
/// `Arc<dyn Operator>`, so an `Arc<PreparedMatrix>` can be shared across
/// worker threads and solved against **concurrently** — each solve brings
/// its own [`LanczosWorkspace`]; the engine's CU pool serializes the
/// per-iteration fork/joins of concurrent solves without affecting their
/// results (shard merges are position-, not timing-, ordered). This is the
/// matrix-resident serving model: the matrix is the resident asset
/// ([`MatrixRegistry`]), solves are the cheap concurrent part.
pub struct PreparedMatrix {
    op: Arc<dyn Operator>,
    fro: f64,
    n: usize,
    nnz: usize,
    precision: Precision,
    engine_used: &'static str,
    prepare_s: f64,
    /// Source generation this engine reflects (see
    /// [`MatrixRegistry::update`]); 0 outside the registry.
    generation: u64,
}

impl PreparedMatrix {
    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.n
    }
    /// Source generation this engine was built from (0 outside the
    /// registry's update lifecycle).
    pub fn generation(&self) -> u64 {
        self.generation
    }
    /// Stored non-zeros after canonicalization.
    pub fn nnz(&self) -> usize {
        self.nnz
    }
    /// Frobenius norm divided out during preparation (1.0 if skipped).
    pub fn frobenius_norm(&self) -> f64 {
        self.fro
    }
    /// Engine bound to this matrix ("native" / "pjrt").
    pub fn engine(&self) -> &'static str {
        self.engine_used
    }
    /// Storage format the engine streams.
    pub fn precision(&self) -> Precision {
        self.precision
    }
    /// Stored bits per matrix value in the bound engine.
    pub fn value_bits(&self) -> u32 {
        self.op.value_bits()
    }
    /// Bytes of the engine's matrix value array.
    pub fn value_bytes(&self) -> usize {
        self.nnz * (self.op.value_bits() as usize / 8)
    }
    /// COO entries per 512-bit line in the bound engine's format.
    pub fn packet_capacity(&self) -> usize {
        packet_capacity(self.op.value_bits())
    }
    /// 512-bit lines one SpMV streams through the bound engine.
    pub fn packets_per_apply(&self) -> usize {
        self.op.packets_per_apply()
    }
    /// Matrix-stream bytes one SpMV moves through the bound engine.
    pub fn bytes_per_apply(&self) -> usize {
        self.op.bytes_per_apply()
    }
    /// Preparation wall time in seconds.
    pub fn prepare_s(&self) -> f64 {
        self.prepare_s
    }
    /// RAM actually held by the bound engine — what the registry's
    /// byte-budgeted LRU charges per cached engine. Resident engines
    /// report their CSR arrays (O(nnz)); out-of-core engines report only
    /// the double-buffered chunk pool + chunk tables (O(buffer)), which is
    /// the whole point of streaming from packet files: a huge matrix on
    /// disk must not evict small resident matrices that do fit in RAM.
    pub fn resident_bytes(&self) -> usize {
        self.op.resident_bytes()
    }
    /// Whether the bound engine streams the matrix from packet files
    /// instead of holding it resident.
    pub fn is_ooc(&self) -> bool {
        self.op.as_any().is_some_and(|any| {
            crate::with_precision!(self.precision, V => {
                any.downcast_ref::<ShardedSpmv<V>>().is_some_and(|s| s.is_ooc())
            })
        })
    }
    /// Serialize this prepared matrix's **exact** engine-resident values
    /// into an out-of-core packet directory: per-shard chunk files of
    /// 512-bit-aligned packet lines plus a manifest, written raw-bits so a
    /// subsequent [`Solver::prepare_ooc`] on the directory yields an
    /// engine that is bitwise identical to this one (same quantized
    /// values, same row partition, same fused-sweep results). Requires the
    /// native sharded engine with a resident matrix (PJRT and already-OOC
    /// engines have no CSR to export).
    ///
    /// `chunk_target_bytes` bounds each chunk's payload (`None` = the
    /// [`DEFAULT_CHUNK_BYTES`](crate::sparse::DEFAULT_CHUNK_BYTES) 1 MiB
    /// target); the double buffer holds two chunks per shard in flight.
    pub fn export_ooc(&self, dir: impl AsRef<Path>, chunk_target_bytes: Option<usize>) -> Result<OocManifest> {
        let dir = dir.as_ref();
        let any = self
            .op
            .as_any()
            .with_context(|| format!("export_ooc: the {} engine is opaque (no resident CSR)", self.engine_used))?;
        crate::with_precision!(self.precision, V => {
            let sharded = any
                .downcast_ref::<ShardedSpmv<V>>()
                .context("export_ooc: engine is not the native sharded SpMV")?;
            let matrix = sharded
                .matrix()
                .context("export_ooc: engine is already out-of-core; copy the packet directory instead")?;
            let mut writer = PacketFileWriter::new(dir);
            if let Some(bytes) = chunk_target_bytes {
                writer = writer.chunk_target_bytes(bytes);
            }
            writer.write_csr::<V>(matrix, self.fro, sharded.cus(), sharded.policy())
        })
    }
    /// The shared engine (for telemetry and tests; solves go through
    /// [`Solver::solve_detached`]).
    pub fn operator(&self) -> &Arc<dyn Operator> {
        &self.op
    }
}

/// The coordinator.
pub struct Solver {
    opts: SolveOptions,
    pool: Arc<ThreadPool>,
    runtime: Option<Arc<Runtime>>,
    /// Lanczos iteration scratch, reused across every solve this solver
    /// runs (including all members of a batched `submit_batch` job) — the
    /// steady-state zero-allocation path.
    ws: LanczosWorkspace,
}

impl Solver {
    /// Build a solver; spawns the CU worker pool (one worker per CU unless
    /// [`SolveOptions::threads`] overrides it). The PJRT runtime is created
    /// lazily on the first `Engine::Pjrt` solve.
    pub fn new(opts: SolveOptions) -> Self {
        let pool = Arc::new(ThreadPool::new(opts.effective_threads()));
        Self { opts, pool, runtime: None, ws: LanczosWorkspace::new() }
    }

    /// Access (and lazily create) the PJRT runtime.
    pub fn runtime(&mut self) -> Result<Arc<Runtime>> {
        if self.runtime.is_none() {
            self.runtime = Some(Arc::new(Runtime::cpu()?));
        }
        Ok(Arc::clone(self.runtime.as_ref().unwrap()))
    }

    /// The active options.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Run the prepare phase once: canonicalize, normalize, build the CSR
    /// in the requested storage format, and bind the SpMV engine (typed
    /// sharded native pool, or PJRT when requested, available, and the
    /// format is f32). The result can back any number of
    /// [`Solver::solve_prepared_with_k`] calls against the same matrix.
    ///
    /// Borrowing convenience wrapper: clones the input once. Callers that
    /// own their matrix (the service's job queue, the registry) should use
    /// [`Solver::prepare_owned`], which canonicalizes in place and never
    /// copies the COO arrays.
    pub fn prepare(&mut self, matrix: &CooMatrix) -> Result<PreparedMatrix> {
        self.prepare_owned(matrix.clone())
    }

    /// The owned/in-place prepare path: consumes the matrix, canonicalizes
    /// it in place, checks symmetry (unless
    /// [`SolveOptions::skip_symmetry_check`]), normalizes, and binds the
    /// engine — zero COO clones end to end.
    pub fn prepare_owned(&mut self, mut m: CooMatrix) -> Result<PreparedMatrix> {
        let mut sw = Stopwatch::start();
        let fro = canonicalize_ingest(&mut m, self.opts.skip_symmetry_check, self.opts.skip_normalize)?;
        let n = m.nrows;
        let nnz = m.nnz();
        let precision = self.opts.precision;
        // Acquire the (lazy) PJRT runtime up front when it could be needed,
        // so the engine-selection helper borrows `self` only immutably.
        let runtime = if self.opts.engine == Engine::Pjrt && precision == Precision::Float32 {
            Some(self.runtime())
        } else {
            None
        };
        let (op, engine_used) = select_engine(
            self.opts.engine,
            precision,
            || match runtime {
                Some(Ok(rt)) => Ok(Arc::new(PjrtSpmv::new(rt, &m)?) as Arc<dyn Operator>),
                Some(Err(e)) => Err(e),
                None => unreachable!("PJRT attempted without a runtime request"),
            },
            || self.native_operator(&m),
        );
        Ok(PreparedMatrix { op, fro, n, nnz, precision, engine_used, prepare_s: sw.lap_s(), generation: 0 })
    }

    /// Bind an **out-of-core** engine to a packet-file directory written by
    /// [`PreparedMatrix::export_ooc`] or the streaming R-MAT generator: the
    /// matrix stays on disk and each CU stripe streams its shard through
    /// double-buffered chunk prefetch during every fused sweep. Resident
    /// memory is O(n) solve vectors plus the chunk buffers — graphs larger
    /// than RAM ride the same Lanczos datapath, bitwise-identical to the
    /// resident engine built from the same values.
    ///
    /// The directory's storage format must match
    /// [`SolveOptions::precision`]; packet files carry raw quantized bits,
    /// so re-interpreting them in another format would silently change the
    /// spectrum. Shard count and partition policy come from the manifest
    /// (they were baked in at export time), not from the options.
    pub fn prepare_ooc(&mut self, dir: impl AsRef<Path>) -> Result<PreparedMatrix> {
        let dir = dir.as_ref();
        let mut sw = Stopwatch::start();
        let man = OocManifest::load(dir)?;
        anyhow::ensure!(
            man.precision == self.opts.precision,
            "precision mismatch: packet files at {} store {}, solve requested {} \
             (re-export the directory, or request --precision {})",
            dir.display(),
            man.precision.name(),
            self.opts.precision.name(),
            man.precision.name()
        );
        let op: Arc<dyn Operator> = crate::with_precision!(man.precision, V => {
            let matrix: Arc<OocMatrix<V>> = OocMatrix::open(dir)?;
            Arc::new(ShardedSpmv::new_ooc(matrix, Arc::clone(&self.pool))) as Arc<dyn Operator>
        });
        Ok(PreparedMatrix {
            op,
            fro: man.fro,
            n: man.nrows,
            nnz: man.nnz,
            precision: man.precision,
            engine_used: "native-ooc",
            prepare_s: sw.lap_s(),
            generation: 0,
        })
    }

    /// Solve the Top-K eigenproblem for a symmetric sparse matrix.
    ///
    /// The input is canonicalized and Frobenius-normalized internally;
    /// returned eigenvalues are rescaled back to the input's scale.
    pub fn solve(&mut self, matrix: &CooMatrix) -> Result<Solution> {
        // Reject bad shapes/K before the O(nnz) prepare work.
        anyhow::ensure!(matrix.nrows == matrix.ncols, "matrix must be square");
        anyhow::ensure!(self.opts.k >= 1 && self.opts.k <= matrix.nrows, "bad k");
        let prep = self.prepare(matrix)?;
        self.solve_prepared(&prep)
    }

    /// Solve against an already-prepared matrix with the configured K.
    pub fn solve_prepared(&mut self, prep: &PreparedMatrix) -> Result<Solution> {
        self.solve_prepared_with_k(prep, self.opts.k)
    }

    /// Solve against an already-prepared matrix for a caller-chosen K
    /// (the multi-K fast path: Lanczos, Jacobi and lift re-run; the O(nnz)
    /// preparation and the engine binding are shared).
    pub fn solve_prepared_with_k(&mut self, prep: &PreparedMatrix, k: usize) -> Result<Solution> {
        Solver::solve_detached(prep, k, &self.opts, &mut self.ws, None)
    }

    /// Solve against a shared prepared matrix without a `Solver` instance:
    /// the worker-replica entry point of matrix-resident serving. Any
    /// number of threads may call this concurrently on one
    /// `Arc<PreparedMatrix>` — each caller brings its own
    /// [`LanczosWorkspace`] (the only mutable per-solve state) and results
    /// are bitwise identical to running the same solves serially.
    ///
    /// `v1` optionally seeds the Lanczos start vector (the registry's
    /// warm-start cache passes the previous dominant Ritz vector for
    /// repeated `(handle, k)` queries); `None` is the paper's deterministic
    /// uniform start.
    ///
    /// The whole phase pipeline runs inside one [`crate::with_precision!`]
    /// dispatch so the Lanczos basis stays in storage format from the
    /// recurrence through eigenvector lift.
    pub fn solve_detached(
        prep: &PreparedMatrix,
        k: usize,
        opts: &SolveOptions,
        ws: &mut LanczosWorkspace,
        v1: Option<Vec<f32>>,
    ) -> Result<Solution> {
        Solver::solve_detached_seeded(prep, k, opts, ws, v1, None)
    }

    /// As [`Solver::solve_detached`], with an optional warm-start *panel*:
    /// up to `block_size` cached Ritz vectors seed the initial block of
    /// the block-Lanczos path (the registry stores the converged Ritz
    /// front of a previous solve on the same `(handle, k)`). On the
    /// single-vector path the panel's first column stands in for `v1`
    /// when no explicit `v1` was given, so callers can pass whichever
    /// seed shape they have.
    pub fn solve_detached_seeded(
        prep: &PreparedMatrix,
        k: usize,
        opts: &SolveOptions,
        ws: &mut LanczosWorkspace,
        v1: Option<Vec<f32>>,
        panel: Option<Vec<Vec<f32>>>,
    ) -> Result<Solution> {
        anyhow::ensure!(k >= 1 && k <= prep.n, "bad k");
        if let Some(v) = &v1 {
            anyhow::ensure!(v.len() == prep.n, "warm-start v1 length mismatch");
        }
        if let Some(p) = &panel {
            for col in p {
                anyhow::ensure!(col.len() == prep.n, "warm-start panel column length mismatch");
            }
        }
        let b = opts.block_size.max(1);
        let mut sw = Stopwatch::start();
        let mut metrics = SolveMetrics {
            prepare_s: prep.prepare_s,
            engine_used: prep.engine_used,
            precision: prep.precision.name(),
            value_bytes: prep.value_bytes(),
            packet_capacity: prep.packet_capacity(),
            warm_started: v1.is_some() || panel.as_ref().is_some_and(|p| !p.is_empty()),
            generation: prep.generation,
            block_size: b,
            ..Default::default()
        };

        // Out-of-core telemetry baseline: the engine counters are monotone
        // across solves, so the delta around this solve is what *it* read.
        let io_before = prep.op.io_bytes_read();
        let stalls_before = prep.op.prefetch_stalls();

        // Adaptive stopping budget: up to 2K + 8 iterations (a warm seed
        // typically stops well short of it; a cold one may use it all).
        let max_iters = if opts.adaptive_tol.is_some() { (2 * k + 8).min(prep.n) } else { 0 };
        let (eigenvalues, eigenvectors) = if b > 1 {
            // The block engine rounds the basis up to whole panels of b
            // columns; the fixed schedule must still fit the operator.
            anyhow::ensure!(
                k.div_ceil(b) * b <= prep.n,
                "block_size {b} too large: ceil(k/b)*b exceeds n={}",
                prep.n
            );
            let lopts = LanczosOptions {
                k,
                reorth: opts.reorth,
                precision: prep.precision,
                fused: opts.fuse,
                v1,
                max_iters,
                ritz_tol: opts.adaptive_tol.unwrap_or(1e-6),
                block_size: b,
                panel,
            };
            crate::with_precision!(prep.precision, V => {
                // ---- Phase 1: block Lanczos (one matrix stream/iter) -----
                let bres: BlockLanczosResult<V> = block_lanczos_typed_ws(prep.op.as_ref(), &lopts, ws);
                metrics.lanczos_s = sw.lap_s();
                metrics.spmv_count = bres.spmv_count;
                metrics.matrix_passes = bres.matrix_passes;
                metrics.breakdown_at = bres.breakdown_at;
                metrics.basis_bytes = bres.basis_value_bytes();
                metrics.fused_sweeps = bres.fused_sweeps;
                metrics.vector_passes = bres.vector_passes;
                // HBM traffic charges once per *matrix pass*: the fused
                // block sweep streams the value array a single time while
                // applying the operator to all b columns.
                metrics.packets_streamed = bres.matrix_passes * prep.packets_per_apply();
                metrics.bytes_streamed = bres.matrix_passes * prep.bytes_per_apply();

                // ---- Phase 2: band diagonalization -----------------------
                // The block recurrence produces a symmetric *band* matrix
                // (bandwidth b), outside the systolic array's tridiagonal
                // contract — diagonalize the dense embedding with the QR
                // reference instead. Systolic stats stay zero here.
                let (band_vals, band_vecs) = qr_algorithm_symmetric(&bres.band.to_dense(), 1e-12, 500);
                metrics.jacobi_s = sw.lap_s();

                // ---- Lift + rescale --------------------------------------
                // QR output is sorted by decreasing magnitude, same Top-K
                // convention as the Jacobi path. Breakdown below K still
                // truncates.
                let k_eff = bres.k().min(k);
                let mut eigenvalues = Vec::with_capacity(k_eff);
                let mut eigenvectors = Vec::with_capacity(k_eff);
                for j in 0..k_eff {
                    eigenvalues.push(band_vals[j] * prep.fro);
                    eigenvectors.push(lift_eigenvector_typed(&bres.basis, &band_vecs.col(j)));
                }
                metrics.lift_s = sw.lap_s();
                (eigenvalues, eigenvectors)
            })
        } else {
            let v1 = v1.or_else(|| panel.and_then(|p| p.into_iter().next()));
            let lopts = LanczosOptions {
                k,
                reorth: opts.reorth,
                precision: prep.precision,
                fused: opts.fuse,
                v1,
                max_iters,
                ritz_tol: opts.adaptive_tol.unwrap_or(1e-6),
                block_size: 1,
                panel: None,
            };
            crate::with_precision!(prep.precision, V => {
                // ---- Phase 1: Lanczos (typed basis storage, reused scratch) --
                let lres: LanczosResult<V> = lanczos_typed_ws(prep.op.as_ref(), &lopts, ws);
                metrics.lanczos_s = sw.lap_s();
                metrics.spmv_count = lres.spmv_count;
                metrics.matrix_passes = lres.matrix_passes;
                metrics.breakdown_at = lres.breakdown_at;
                metrics.basis_bytes = lres.basis_value_bytes();
                metrics.fused_sweeps = lres.fused_sweeps;
                metrics.vector_passes = lres.vector_passes;
                metrics.packets_streamed = lres.matrix_passes * prep.packets_per_apply();
                metrics.bytes_streamed = lres.matrix_passes * prep.bytes_per_apply();

                // ---- Phase 2: Jacobi -----------------------------------------
                let eig = jacobi_eigen(&lres.tridiag, opts.jacobi, 1e-10);
                metrics.jacobi_s = sw.lap_s();
                metrics.systolic = eig.stats;

                // ---- Lift + rescale ------------------------------------------
                // Adaptive runs may build a basis larger than K; the Top-K
                // answer is the K largest-magnitude pairs of the (sorted)
                // Jacobi output. Breakdown below K still truncates.
                let k_eff = lres.k().min(k);
                let mut eigenvalues = Vec::with_capacity(k_eff);
                let mut eigenvectors = Vec::with_capacity(k_eff);
                for j in 0..k_eff {
                    eigenvalues.push(eig.eigenvalues[j] * prep.fro);
                    eigenvectors.push(lift_eigenvector_typed(&lres.basis, &eig.eigenvectors.col(j)));
                }
                metrics.lift_s = sw.lap_s();
                (eigenvalues, eigenvectors)
            })
        };

        metrics.io_bytes_read = prep.op.io_bytes_read().saturating_sub(io_before);
        metrics.prefetch_stalls = prep.op.prefetch_stalls().saturating_sub(stalls_before);
        Ok(Solution { eigenvalues, eigenvectors, frobenius_norm: prep.fro, metrics })
    }

    fn native_operator(&self, m: &CooMatrix) -> Arc<dyn Operator> {
        native_operator_from_canonical(m, self.opts.precision, self.opts.cus, self.opts.partition, &self.pool)
    }
}

/// The shared ingest pipeline of both prepare paths ([`Solver`] and the
/// [`MatrixRegistry`]): validate squareness, canonicalize **in place**,
/// check structural symmetry (tolerance 1e-4) unless skipped, and
/// Frobenius-normalize unless skipped. Returns the norm divided out (1.0
/// when normalization is skipped). One implementation so the registry's
/// handle solves and direct `Solver` solves cannot diverge on validation
/// or normalization semantics.
pub(crate) fn canonicalize_ingest(m: &mut CooMatrix, skip_symmetry_check: bool, skip_normalize: bool) -> Result<f64> {
    anyhow::ensure!(m.nrows == m.ncols, "matrix must be square");
    m.canonicalize();
    if !skip_symmetry_check {
        anyhow::ensure!(
            m.is_symmetric(1e-4),
            "operator must be symmetric (set skip_symmetry_check for trusted input, \
             or --skip-symmetry-check at the CLI)"
        );
    }
    Ok(if skip_normalize { 1.0 } else { normalize_frobenius(m) })
}

/// Resolve the SpMV engine for a prepare: PJRT when requested, available,
/// and the storage format is f32; the typed native sharded engine
/// otherwise, with the fallback warnings. One implementation shared by
/// [`Solver::prepare_owned`] and the [`MatrixRegistry`] engine builder so
/// the two prepare paths cannot drift apart.
pub(crate) fn select_engine(
    engine: Engine,
    precision: Precision,
    try_pjrt: impl FnOnce() -> Result<Arc<dyn Operator>>,
    native: impl FnOnce() -> Arc<dyn Operator>,
) -> (Arc<dyn Operator>, &'static str) {
    match engine {
        Engine::Pjrt if precision != Precision::Float32 => {
            log::warn!("PJRT artifacts are f32-only; using the native {} datapath", precision.name());
            (native(), "native")
        }
        Engine::Pjrt => match try_pjrt() {
            Ok(op) => (op, "pjrt"),
            Err(e) => {
                log::warn!("PJRT engine unavailable ({e}); falling back to native");
                (native(), "native")
            }
        },
        Engine::Native => (native(), "native"),
    }
}

/// Build the native sharded engine from an **already canonical** COO (the
/// prepare paths canonicalize in place first, so no extra COO copy is made
/// here). Shared by [`Solver`] and the [`MatrixRegistry`], which bind the
/// same engine construction to different pools.
pub(crate) fn native_operator_from_canonical(
    m: &CooMatrix,
    precision: Precision,
    cus: usize,
    partition: PartitionPolicy,
    pool: &Arc<ThreadPool>,
) -> Arc<dyn Operator> {
    native_operator_scaled(m, None, precision, cus, partition, pool)
}

/// As [`native_operator_from_canonical`], but with the Frobenius
/// normalization **deferred to build time**: `scale = Some(1/||M||_F)`
/// multiplies every value during the CSR conversion (f64 arithmetic,
/// clamped into the open interval — see [`crate::sparse::scale_value`]).
/// This is the registry's path: it keeps the canonical source in original
/// scale so delta updates compose exactly, and normalizes each engine as
/// it is built. The values produced are bitwise identical to normalizing
/// the COO in place first ([`Solver`]'s path), so the two prepare flavors
/// cannot drift.
pub(crate) fn native_operator_scaled(
    m: &CooMatrix,
    scale: Option<f64>,
    precision: Precision,
    cus: usize,
    partition: PartitionPolicy,
    pool: &Arc<ThreadPool>,
) -> Arc<dyn Operator> {
    crate::with_precision!(precision, V => {
        let typed: CsrMatrix<V> = typed_csr_scaled::<V>(m, scale);
        Arc::new(ShardedSpmv::new(Arc::new(typed), cus, partition, Arc::clone(pool))) as Arc<dyn Operator>
    })
}

/// One-pass typed CSR construction from a canonical COO, applying the
/// optional normalization scale at the value stream: `W::from_f32` of the
/// (clamped f64-scaled) f32 value — the exact composition the in-place
/// normalize + `to_precision` pipeline performs, fused into one pass.
pub(crate) fn typed_csr_scaled<V: crate::fixed::Dataword>(m: &CooMatrix, scale: Option<f64>) -> CsrMatrix<V> {
    let mut indptr = vec![0usize; m.nrows + 1];
    for &r in &m.rows {
        indptr[r as usize + 1] += 1;
    }
    for i in 0..m.nrows {
        indptr[i + 1] += indptr[i];
    }
    let vals: Vec<V> = match scale {
        Some(inv) => m.vals.iter().map(|&v| V::from_f32(crate::sparse::scale_value(v, inv))).collect(),
        None => m.vals.iter().map(|&v| V::from_f32(v)).collect(),
    };
    CsrMatrix { nrows: m.nrows, ncols: m.ncols, indptr, indices: m.cols.clone(), vals }
}

/// A normalized f32 copy of a canonical original-scale COO — the PJRT
/// engine path consumes whole normalized matrices rather than a deferred
/// scale. (Callers with no scale to apply pass the original directly —
/// no copy.)
pub(crate) fn scaled_coo_copy(m: &CooMatrix, inv: f64) -> CooMatrix {
    let mut out = m.clone();
    for v in &mut out.vals {
        *v = crate::sparse::scale_value(*v, inv);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs;

    #[test]
    fn solves_planted_partition_dominant_structure() {
        let (adj, _) = graphs::planted_partition(300, 3, 0.12, 0.004, 7);
        let w = graphs::adjacency_to_laplacian(&adj, graphs::LaplacianKind::NormalizedAdjacency);
        let mut solver = Solver::new(SolveOptions { k: 8, reorth: ReorthPolicy::Every, ..Default::default() });
        let sol = solver.solve(&w).unwrap();
        assert_eq!(sol.k(), 8);
        // Normalized adjacency: top eigenvalue is 1 (before rescale the
        // operator was normalized; rescale restores it).
        assert!((sol.eigenvalues[0] - 1.0).abs() < 0.05, "{:?}", sol.eigenvalues);
        // Community structure: at least one more eigenvalue near 1. (The
        // paper's deterministic uniform start is nearly orthogonal to the
        // community-difference eigenvectors on equal-size blocks, so not
        // every community direction is guaranteed in K Krylov steps —
        // the spectral-clustering example uses a random v1 for exactly
        // this reason.)
        assert!(sol.eigenvalues[1] > 0.5, "{:?}", sol.eigenvalues);
    }

    #[test]
    fn eigen_residuals_small_on_rmat() {
        let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 13);
        let mut solver = Solver::new(SolveOptions { k: 8, reorth: ReorthPolicy::Every, ..Default::default() });
        let sol = solver.solve(&m).unwrap();
        let report = verify::verify(&m, &sol);
        // Single-pass Lanczos with K iterations yields approximate Ritz
        // pairs; on a 512-vertex RMAT the normalized residual sits in the
        // few-percent range (it shrinks with graph size — the Fig 11 bench
        // measures the paper-scale behaviour).
        assert!(report.mean_residual < 5e-2, "residual {}", report.mean_residual);
        assert!(report.mean_angle_deg > 89.0, "angle {}", report.mean_angle_deg);
    }

    #[test]
    fn metrics_are_populated() {
        let m = graphs::mesh2d(20, 20, 0.9, 0.01, 3);
        let mut solver = Solver::new(SolveOptions { k: 6, ..Default::default() });
        let sol = solver.solve(&m).unwrap();
        assert_eq!(sol.metrics.spmv_count, 6);
        assert_eq!(sol.metrics.matrix_passes, 6, "single-vector path: one matrix pass per SpMV");
        assert_eq!(sol.metrics.block_size, 1);
        assert_eq!(sol.metrics.engine_used, "native");
        assert!(sol.metrics.total_s() > 0.0);
        assert!(sol.metrics.systolic.steps > 0);
        // Datapath telemetry: f32 baseline figures.
        assert_eq!(sol.metrics.precision, "f32");
        assert_eq!(sol.metrics.packet_capacity, 5);
        assert!(sol.metrics.value_bytes > 0);
        assert!(sol.metrics.packets_streamed > 0);
        assert_eq!(sol.metrics.bytes_streamed, sol.metrics.packets_streamed * 64);
        assert!(sol.metrics.basis_bytes > 0);
    }

    #[test]
    fn rescaling_matches_unnormalized_spectrum() {
        // Diagonal matrix with a big value: normalization must not change
        // the reported eigenvalue. (k > 1 so the Krylov space can rotate
        // from the uniform start onto the dominant axis.)
        let mut m = CooMatrix::new(64, 64);
        for i in 0..64 {
            m.push(i, i, if i == 0 { 42.0 } else { 1.0 });
        }
        let mut solver = Solver::new(SolveOptions { k: 8, ..Default::default() });
        let sol = solver.solve(&m).unwrap();
        assert!((sol.eigenvalues[0] - 42.0).abs() < 1e-3, "{:?}", sol.eigenvalues);
    }

    #[test]
    fn prepared_matrix_shares_setup_across_ks() {
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 21);
        let mut solver = Solver::new(SolveOptions { k: 8, ..Default::default() });
        let prep = solver.prepare(&m).unwrap();
        assert_eq!(prep.engine(), "native");
        assert!(prep.n() == 1 << 8 && prep.nnz() > 0);
        assert!(prep.prepare_s() >= 0.0);
        // Multi-K over one prepared matrix must match fresh single solves.
        for k in [2usize, 4, 8] {
            let fast = solver.solve_prepared_with_k(&prep, k).unwrap();
            let mut fresh = Solver::new(SolveOptions { k, ..Default::default() });
            let slow = fresh.solve(&m).unwrap();
            assert_eq!(fast.k(), slow.k(), "k={k}");
            for i in 0..fast.k() {
                assert!(
                    (fast.eigenvalues[i] - slow.eigenvalues[i]).abs() < 1e-9,
                    "k={k} pair {i}: {} vs {}",
                    fast.eigenvalues[i],
                    slow.eigenvalues[i]
                );
            }
            // Shared prepare time is reported on every member solution.
            assert_eq!(fast.metrics.prepare_s, prep.prepare_s());
        }
    }

    #[test]
    fn asymmetric_input_is_rejected_in_release_semantics() {
        // A genuinely asymmetric operator must be an error (not a
        // debug-only assert): Lanczos silently produces wrong spectra on
        // it.
        let mut m = CooMatrix::new(8, 8);
        for i in 0..8 {
            m.push(i, i, 1.0);
        }
        m.push(0, 3, 0.5); // no (3, 0) mirror
        let mut solver = Solver::new(SolveOptions { k: 2, ..Default::default() });
        let err = solver.prepare(&m).unwrap_err();
        assert!(err.to_string().contains("symmetric"), "{err}");
        assert!(solver.solve(&m).is_err());
        // Trusted callers can opt out and take responsibility.
        let mut trusting = Solver::new(SolveOptions { k: 2, skip_symmetry_check: true, ..Default::default() });
        assert!(trusting.prepare(&m).is_ok());
    }

    #[test]
    fn prepare_owned_matches_borrowing_prepare() {
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 11);
        let mut a = Solver::new(SolveOptions { k: 5, ..Default::default() });
        let mut b = Solver::new(SolveOptions { k: 5, ..Default::default() });
        let prep_ref = a.prepare(&m).unwrap();
        let prep_owned = b.prepare_owned(m.clone()).unwrap();
        assert_eq!(prep_ref.n(), prep_owned.n());
        assert_eq!(prep_ref.nnz(), prep_owned.nnz());
        assert_eq!(prep_ref.frobenius_norm(), prep_owned.frobenius_norm());
        let sa = a.solve_prepared(&prep_ref).unwrap();
        let sb = b.solve_prepared(&prep_owned).unwrap();
        assert_eq!(sa.eigenvalues, sb.eigenvalues);
        assert!(prep_owned.resident_bytes() > 0);
    }

    #[test]
    fn prepared_matrix_is_shareable_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PreparedMatrix>();
        // Detached concurrent solves on one Arc<PreparedMatrix> match the
        // Solver-owned path bitwise (the full stress test lives in
        // tests/service_registry.rs).
        let m = graphs::mesh2d(16, 16, 0.9, 0.02, 9);
        let opts = SolveOptions { k: 4, ..Default::default() };
        let mut solver = Solver::new(opts.clone());
        let prep = std::sync::Arc::new(solver.prepare(&m).unwrap());
        let serial = solver.solve_prepared_with_k(&prep, 4).unwrap();
        let concurrent = std::thread::scope(|s| {
            let h = s.spawn(|| {
                let mut ws = LanczosWorkspace::new();
                Solver::solve_detached(&prep, 4, &opts, &mut ws, None).unwrap()
            });
            h.join().unwrap()
        });
        assert_eq!(serial.eigenvalues, concurrent.eigenvalues);
        assert_eq!(serial.eigenvectors, concurrent.eigenvectors);
        assert!(!concurrent.metrics.warm_started);
    }

    #[test]
    fn solve_prepared_rejects_bad_k() {
        let m = graphs::mesh2d(8, 8, 0.9, 0.02, 1);
        let mut solver = Solver::new(SolveOptions::default());
        let prep = solver.prepare(&m).unwrap();
        assert!(solver.solve_prepared_with_k(&prep, 0).is_err());
        assert!(solver.solve_prepared_with_k(&prep, 65).is_err());
        assert!(solver.solve_prepared_with_k(&prep, 64).is_ok());
    }

    #[test]
    fn threads_knob_multiplexes_without_changing_results() {
        let m = graphs::rmat(1 << 8, 6 << 8, 0.6, 0.18, 0.18, 5);
        let mut wide = Solver::new(SolveOptions { k: 6, cus: 5, threads: 0, ..Default::default() });
        let mut narrow = Solver::new(SolveOptions { k: 6, cus: 5, threads: 2, ..Default::default() });
        let a = wide.solve(&m).unwrap();
        let b = narrow.solve(&m).unwrap();
        assert_eq!(a.eigenvalues, b.eigenvalues);
        assert_eq!(SolveOptions { cus: 5, threads: 0, ..Default::default() }.effective_threads(), 5);
        assert_eq!(SolveOptions { cus: 5, threads: 2, ..Default::default() }.effective_threads(), 2);
    }

    #[test]
    fn q115_datapath_shrinks_storage_and_stays_accurate() {
        // The acceptance-bar configuration: Q1.15 storage must *measurably*
        // shrink the datapath — half the value bytes, 6 entries per line —
        // while the solve stays usable at unit-test scale.
        let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 29);
        let mut f = Solver::new(SolveOptions { k: 6, reorth: ReorthPolicy::Every, ..Default::default() });
        let mut q = Solver::new(SolveOptions {
            k: 6,
            reorth: ReorthPolicy::Every,
            precision: Precision::FixedQ1_15,
            ..Default::default()
        });
        let sf = f.solve(&m).unwrap();
        let sq = q.solve(&m).unwrap();
        assert_eq!(sq.metrics.precision, "q1.15");
        assert_eq!(sq.metrics.packet_capacity, 6);
        assert_eq!(sf.metrics.packet_capacity, 5);
        assert_eq!(sq.metrics.value_bytes * 2, sf.metrics.value_bytes, "16-bit words halve the array");
        assert!(sq.metrics.packets_streamed < sf.metrics.packets_streamed);
        assert!(sq.metrics.bytes_streamed < sf.metrics.bytes_streamed);
        assert_eq!(sq.metrics.basis_bytes * 2, sf.metrics.basis_bytes);
        // Eigenvalues track the f32 solve within quantization-scale error.
        for i in 0..sq.k().min(sf.k()) {
            assert!(
                (sq.eigenvalues[i] - sf.eigenvalues[i]).abs() < 3e-2 * sf.eigenvalues[0].abs().max(1.0),
                "pair {i}: {} vs {}",
                sq.eigenvalues[i],
                sf.eigenvalues[i]
            );
        }
    }

    #[test]
    fn q131_prepared_solves_match_fresh_solves() {
        // The multi-K fast path must hold in typed storage too.
        let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 33);
        let opts = SolveOptions { precision: Precision::FixedQ1_31, ..Default::default() };
        let mut solver = Solver::new(opts.clone());
        let prep = solver.prepare(&m).unwrap();
        assert_eq!(prep.precision(), Precision::FixedQ1_31);
        assert_eq!(prep.value_bits(), 32);
        assert_eq!(prep.packet_capacity(), 5);
        for k in [2usize, 5] {
            let fast = solver.solve_prepared_with_k(&prep, k).unwrap();
            let mut fresh = Solver::new(SolveOptions { k, ..opts.clone() });
            let slow = fresh.solve(&m).unwrap();
            for i in 0..fast.k() {
                assert!(
                    (fast.eigenvalues[i] - slow.eigenvalues[i]).abs() < 1e-9,
                    "k={k} pair {i}"
                );
            }
        }
    }

    #[test]
    fn block_solve_matches_single_vector_spectrum_with_fewer_passes() {
        // Diagonal fixture with a well-separated geometric spectrum: both
        // paths resolve the top-K accurately (deterministic comparison
        // against the known eigenvalues), while the metrics expose the
        // block path's stream-once-per-iteration accounting. The heavier
        // sharded/precision sweep lives in tests/block_lanczos.rs.
        let mut m = CooMatrix::new(64, 64);
        let mut exact = [0.0f64; 8];
        let mut cur = 0.9f32;
        for i in 0..64 {
            m.push(i, i, cur);
            if i < 8 {
                exact[i] = f64::from(cur);
            }
            cur *= 0.8;
        }
        let opts = |block_size| SolveOptions {
            k: 8,
            reorth: ReorthPolicy::Every,
            adaptive_tol: Some(1e-9),
            block_size,
            ..Default::default()
        };
        let single = Solver::new(opts(1)).solve(&m).unwrap();
        let block = Solver::new(opts(4)).solve(&m).unwrap();
        assert_eq!(block.metrics.block_size, 4);
        assert_eq!(single.metrics.block_size, 1);
        // One fused stream per block iteration: b logical SpMVs per pass,
        // HBM traffic charged per pass.
        assert_eq!(block.metrics.spmv_count, block.metrics.matrix_passes * 4);
        assert_eq!(block.metrics.bytes_streamed / block.metrics.matrix_passes, single.metrics.bytes_streamed / single.metrics.matrix_passes);
        // Adaptive single-vector runs at least K = 8 passes; the block
        // budget caps at ceil((2K+8)/b) = 6 — strictly fewer streams.
        assert!(
            block.metrics.matrix_passes < single.metrics.matrix_passes,
            "b=4 must stream the matrix fewer times ({} vs {})",
            block.metrics.matrix_passes,
            single.metrics.matrix_passes
        );
        // Band phase 2 bypasses the systolic array.
        assert_eq!(block.metrics.systolic.steps, 0);
        assert!(single.metrics.systolic.steps > 0);
        assert_eq!(block.k(), 8);
        for (i, want) in exact.iter().enumerate() {
            assert!(
                (single.eigenvalues[i] - want).abs() < 3e-3 * exact[0],
                "single pair {i}: {} vs {want}",
                single.eigenvalues[i]
            );
            assert!(
                (block.eigenvalues[i] - want).abs() < 3e-3 * exact[0],
                "block pair {i}: {} vs {want}",
                block.eigenvalues[i]
            );
        }
    }

    #[test]
    fn block_solve_rejects_oversized_block_schedule() {
        let m = graphs::mesh2d(4, 4, 0.9, 0.02, 1);
        // n = 16, k = 15, b = 8 → ceil(15/8)*8 = 16 fits; k = 16 doesn't
        // round (16), still fits; b = 7 → ceil(15/7)*7 = 21 > 16 errors.
        let mut ok = Solver::new(SolveOptions { k: 15, block_size: 8, ..Default::default() });
        assert!(ok.solve(&m).is_ok());
        let mut bad = Solver::new(SolveOptions { k: 15, block_size: 7, ..Default::default() });
        let err = bad.solve(&m).unwrap_err();
        assert!(err.to_string().contains("block_size"), "{err}");
    }

    #[test]
    fn export_ooc_then_prepare_ooc_matches_resident_bitwise() {
        let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 41);
        let opts = SolveOptions { k: 6, cus: 3, ..Default::default() };
        let mut solver = Solver::new(opts.clone());
        let prep = solver.prepare(&m).unwrap();
        assert!(!prep.is_ooc());
        let dir = crate::sparse::ooc::scratch_dir("coord");
        let man = prep.export_ooc(&dir, Some(4096)).unwrap();
        assert_eq!(man.nnz, prep.nnz());
        assert_eq!(man.fro, prep.frobenius_norm());
        let ooc = solver.prepare_ooc(&dir).unwrap();
        assert!(ooc.is_ooc());
        assert_eq!(ooc.engine(), "native-ooc");
        assert_eq!(ooc.n(), prep.n());
        assert_eq!(ooc.nnz(), prep.nnz());
        assert_eq!(ooc.frobenius_norm(), prep.frobenius_norm());
        let a = solver.solve_prepared(&prep).unwrap();
        let b = solver.solve_prepared(&ooc).unwrap();
        assert_eq!(a.eigenvalues, b.eigenvalues, "OOC solve must be bitwise resident");
        assert_eq!(a.eigenvectors, b.eigenvectors);
        // Telemetry: the resident solve never touches storage; the OOC
        // solve charges every packet line it streamed.
        assert_eq!(a.metrics.io_bytes_read, 0);
        assert_eq!(a.metrics.prefetch_stalls, 0);
        assert!(b.metrics.io_bytes_read > 0, "OOC solve reads packet files");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prepare_ooc_rejects_precision_mismatch() {
        let m = graphs::mesh2d(16, 16, 0.9, 0.02, 3);
        let mut f = Solver::new(SolveOptions { k: 4, ..Default::default() });
        let prep = f.prepare(&m).unwrap();
        let dir = crate::sparse::ooc::scratch_dir("coord-prec");
        prep.export_ooc(&dir, None).unwrap();
        let mut q =
            Solver::new(SolveOptions { k: 4, precision: Precision::FixedQ1_15, ..Default::default() });
        let err = q.prepare_ooc(&dir).unwrap_err();
        assert!(err.to_string().contains("precision mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pjrt_with_fixed_precision_falls_back_to_typed_native() {
        let m = graphs::mesh2d(12, 12, 0.9, 0.02, 5);
        let mut solver = Solver::new(SolveOptions {
            k: 4,
            engine: Engine::Pjrt,
            precision: Precision::FixedQ1_15,
            ..Default::default()
        });
        let sol = solver.solve(&m).unwrap();
        assert_eq!(sol.metrics.engine_used, "native");
        assert_eq!(sol.metrics.precision, "q1.15");
        assert_eq!(sol.metrics.packet_capacity, 6);
    }
}

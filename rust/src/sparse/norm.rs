//! Frobenius normalization (§III-A).
//!
//! The paper normalizes the input matrix in Frobenius norm so that all
//! values — and therefore all eigenvalues and eigenvector entries — fall in
//! `(-1, 1)`. Eigencomponents are invariant to constant scaling (the
//! eigenvalues simply scale by `1/||M||_F`), and the bounded range is what
//! licenses Q1.31 fixed-point arithmetic on the device path.

use crate::sparse::CooMatrix;

/// `||M||_F = sqrt(sum of squared entries)`, accumulated in f64 to avoid
/// cancellation on large nnz.
pub fn frobenius_norm(m: &CooMatrix) -> f64 {
    m.vals.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Scale `M` by `1 / ||M||_F` in place; returns the norm used so callers can
/// rescale eigenvalues back (`lambda_M = lambda_normalized * norm`).
///
/// A zero matrix is returned unchanged with norm 1.0.
pub fn normalize_frobenius(m: &mut CooMatrix) -> f64 {
    let norm = frobenius_norm(m);
    if norm == 0.0 {
        return 1.0;
    }
    let inv = (1.0 / norm) as f32;
    for v in &mut m.vals {
        *v *= inv;
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_of_identity() {
        let mut m = CooMatrix::new(4, 4);
        for i in 0..4 {
            m.push(i, i, 1.0);
        }
        assert!((frobenius_norm(&m) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn normalized_matrix_has_unit_norm_and_bounded_entries() {
        let mut m = CooMatrix::new(3, 3);
        m.push(0, 1, 10.0);
        m.push(1, 2, -20.0);
        m.push(2, 0, 5.0);
        let norm = normalize_frobenius(&mut m);
        assert!((frobenius_norm(&m) - 1.0).abs() < 1e-6);
        assert!(m.vals.iter().all(|v| v.abs() < 1.0), "entries must be in (-1,1)");
        assert!((norm - (100.0f64 + 400.0 + 25.0).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn eigenvalue_rescaling_is_consistent() {
        // For a diagonal matrix the eigenvalues are the entries: check that
        // normalized eigenvalue * norm reproduces the original.
        let mut m = CooMatrix::new(2, 2);
        m.push(0, 0, 3.0);
        m.push(1, 1, 4.0);
        let norm = normalize_frobenius(&mut m);
        let lam0 = m.vals[0] as f64 * norm;
        let lam1 = m.vals[1] as f64 * norm;
        assert!((lam0 - 3.0).abs() < 1e-5);
        assert!((lam1 - 4.0).abs() < 1e-5);
    }

    #[test]
    fn zero_matrix_untouched() {
        let mut m = CooMatrix::new(2, 2);
        assert_eq!(normalize_frobenius(&mut m), 1.0);
    }
}

//! Frobenius normalization (§III-A).
//!
//! The paper normalizes the input matrix in Frobenius norm so that all
//! values — and therefore all eigenvalues and eigenvector entries — fall in
//! `(-1, 1)`. Eigencomponents are invariant to constant scaling (the
//! eigenvalues simply scale by `1/||M||_F`), and the bounded range is what
//! licenses Q1.31 fixed-point arithmetic on the device path.
//!
//! The interval is **open**: a single-entry matrix (or one whose norm is
//! dominated by a single entry) has `|v| / ||M||_F` rounding to exactly
//! `1.0` in f32, which the fixed-point storage formats cannot represent
//! (`Q1.31` tops out at `1 - 2^-31`). [`scale_value`] therefore computes
//! the quotient in f64 and clamps the result to the largest f32 strictly
//! below 1.0 — every consumer of normalized matrices may rely on the
//! post-condition `all(|v| < 1.0)`.

use crate::sparse::CooMatrix;

/// Largest f32 strictly below 1.0 (`1 - 2^-24`): the boundary value of the
/// open normalization interval.
pub const ONE_BELOW: f32 = f32::from_bits(0x3F7F_FFFF);

/// `||M||_F = sqrt(sum of squared entries)`, accumulated in f64 to avoid
/// cancellation on large nnz.
pub fn frobenius_norm(m: &CooMatrix) -> f64 {
    m.vals.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// One normalized value: `v * inv` computed in f64, rounded to f32, and
/// clamped into the **open** interval `(-1, 1)` (a dominant entry divided
/// by the norm can round to exactly `±1.0` in f32, violating the
/// invariant the Q formats rely on). `inv` is `1 / ||M||_F`.
///
/// This is the single scaling kernel shared by [`normalize_frobenius`] and
/// the registry's build-time normalization, so the in-place and deferred
/// paths produce bitwise-identical values.
#[inline]
pub fn scale_value(v: f32, inv: f64) -> f32 {
    let scaled = (v as f64 * inv) as f32;
    scaled.clamp(-ONE_BELOW, ONE_BELOW)
}

/// Scale `M` by `1 / ||M||_F` in place; returns the norm used so callers can
/// rescale eigenvalues back (`lambda_M = lambda_normalized * norm`).
///
/// Post-condition: every stored value satisfies `|v| < 1.0` exactly (see
/// [`scale_value`]). A zero matrix is returned unchanged with norm 1.0.
pub fn normalize_frobenius(m: &mut CooMatrix) -> f64 {
    let norm = frobenius_norm(m);
    if norm == 0.0 {
        return 1.0;
    }
    let inv = 1.0 / norm;
    for v in &mut m.vals {
        *v = scale_value(*v, inv);
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Dataword, Precision};

    #[test]
    fn norm_of_identity() {
        let mut m = CooMatrix::new(4, 4);
        for i in 0..4 {
            m.push(i, i, 1.0);
        }
        assert!((frobenius_norm(&m) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn normalized_matrix_has_unit_norm_and_bounded_entries() {
        let mut m = CooMatrix::new(3, 3);
        m.push(0, 1, 10.0);
        m.push(1, 2, -20.0);
        m.push(2, 0, 5.0);
        let norm = normalize_frobenius(&mut m);
        assert!((frobenius_norm(&m) - 1.0).abs() < 1e-6);
        assert!(m.vals.iter().all(|v| v.abs() < 1.0), "entries must be in (-1,1)");
        assert!((norm - (100.0f64 + 400.0 + 25.0).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn eigenvalue_rescaling_is_consistent() {
        // For a diagonal matrix the eigenvalues are the entries: check that
        // normalized eigenvalue * norm reproduces the original.
        let mut m = CooMatrix::new(2, 2);
        m.push(0, 0, 3.0);
        m.push(1, 1, 4.0);
        let norm = normalize_frobenius(&mut m);
        let lam0 = m.vals[0] as f64 * norm;
        let lam1 = m.vals[1] as f64 * norm;
        assert!((lam0 - 3.0).abs() < 1e-5);
        assert!((lam1 - 4.0).abs() < 1e-5);
    }

    #[test]
    fn zero_matrix_untouched() {
        let mut m = CooMatrix::new(2, 2);
        assert_eq!(normalize_frobenius(&mut m), 1.0);
    }

    #[test]
    fn one_below_is_the_open_boundary() {
        assert!(ONE_BELOW < 1.0);
        // The next representable f32 above ONE_BELOW is exactly 1.0.
        assert_eq!(f32::from_bits(ONE_BELOW.to_bits() + 1), 1.0);
    }

    /// Regression for the boundary bug: a 1x1 matrix normalizes its single
    /// entry to |v|/|v| which used to round to exactly 1.0 in f32,
    /// violating the open-interval invariant.
    #[test]
    fn single_entry_matrix_stays_strictly_inside_the_open_interval() {
        for &val in &[42.0f32, -42.0, 1.0, 1e-20, 3.4e38] {
            let mut m = CooMatrix::new(1, 1);
            m.push(0, 0, val);
            let norm = normalize_frobenius(&mut m);
            assert!(m.vals[0].abs() < 1.0, "val={val}: normalized {} must be < 1", m.vals[0]);
            assert_eq!(m.vals[0].abs(), ONE_BELOW, "val={val}");
            // Rescaling still recovers the original to f32 accuracy.
            assert!(((m.vals[0] as f64 * norm - val as f64) / val as f64).abs() < 1e-6, "val={val}");
            // Every storage format can hold the value without hitting its
            // saturation boundary semantics (round-trip stays < 1).
            for p in Precision::ALL {
                let q = crate::with_precision!(p, V => V::from_f32(m.vals[0]).to_f32());
                assert!(q.abs() < 1.0, "{}: {q}", p.name());
            }
        }
    }

    /// A power-law-style matrix dominated by one huge entry: the dominant
    /// value normalizes to just under 1.0, never to 1.0, in all formats.
    #[test]
    fn dominated_matrix_keeps_all_precisions_strictly_bounded() {
        let n = 64;
        let mut m = CooMatrix::new(n, n);
        // One entry carries (almost) the whole norm; the tail is tiny.
        m.push(0, 0, 1e12);
        for i in 1..n {
            m.push(i, i, 1e-6);
        }
        normalize_frobenius(&mut m);
        assert!(m.vals.iter().all(|v| v.abs() < 1.0), "all normalized entries in (-1,1)");
        assert_eq!(m.vals[0], ONE_BELOW, "dominant entry clamps to the open boundary");
        for p in Precision::ALL {
            for &v in &m.vals {
                let q = crate::with_precision!(p, V => V::from_f32(v).to_f32());
                assert!(q.abs() < 1.0, "{}: {v} -> {q}", p.name());
            }
        }
    }

    #[test]
    fn scale_value_matches_in_place_normalization_bitwise() {
        let mut m = CooMatrix::new(3, 3);
        m.push(0, 1, 0.125);
        m.push(1, 0, 0.125);
        m.push(2, 2, -7.75);
        let orig = m.vals.clone();
        let norm = normalize_frobenius(&mut m);
        let inv = 1.0 / norm;
        for (o, n) in orig.iter().zip(&m.vals) {
            assert_eq!(scale_value(*o, inv).to_bits(), n.to_bits());
        }
    }
}

//! Compressed Sparse Row (CSR) matrix, generic over the stored scalar.
//!
//! CSR is the host-side workhorse: the CPU baselines (IRAM, cyclic Jacobi
//! verification) and the L3 native SpMV path use it because row-sliced CSR
//! stripes shard cleanly across "CU" worker threads with zero write
//! contention — each worker owns a disjoint output range, mirroring how the
//! paper's Merge Unit concatenates per-CU partial vectors (§IV-B1).
//!
//! The value array stores a [`Dataword`] (`f32` by default), so the typed
//! mixed-precision engines read 16-bit words from memory where the f32
//! baseline reads 32 — the SpMV gather still multiplies and accumulates in
//! f32, the paper's float-where-it-matters rule (§IV).

use crate::fixed::Dataword;
use crate::sparse::{CooDelta, CooMatrix, DeltaApply};

/// CSR sparse matrix with values stored in format `V` (default `f32`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CsrMatrix<V: Dataword = f32> {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row pointer array, length `nrows + 1`.
    pub indptr: Vec<usize>,
    /// Column index per non-zero, grouped by row.
    pub indices: Vec<u32>,
    /// Value per non-zero, stored in format `V`.
    pub vals: Vec<V>,
}

impl<V: Dataword> CsrMatrix<V> {
    /// Build from a canonical (row-major sorted, deduplicated) COO matrix.
    pub fn from_canonical_coo(coo: &CooMatrix<V>) -> Self {
        let mut indptr = vec![0usize; coo.nrows + 1];
        for &r in &coo.rows {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..coo.nrows {
            indptr[i + 1] += indptr[i];
        }
        Self {
            nrows: coo.nrows,
            ncols: coo.ncols,
            indptr,
            indices: coo.cols.clone(),
            vals: coo.vals.clone(),
        }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Bytes occupied by the value array alone (`nnz * V::bytes()`): the
    /// quantity the 16-bit datapath halves relative to f32.
    pub fn value_bytes(&self) -> usize {
        self.nnz() * V::bytes()
    }

    /// Re-store the value array in format `W` (quantizing through f32),
    /// keeping the index structure identical.
    pub fn to_precision<W: Dataword>(&self) -> CsrMatrix<W> {
        CsrMatrix {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            vals: self.vals.iter().map(|v| W::from_f32(v.to_f32())).collect(),
        }
    }

    /// Column indices and values of row `r`.
    pub fn row(&self, r: usize) -> (&[u32], &[V]) {
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[a..b], &self.vals[a..b])
    }

    /// `y = M x` over the full matrix.
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0f32; self.nrows];
        self.spmv_into(x, &mut y, 0, self.nrows);
        y
    }

    /// `y[r0..r1] = (M x)[r0..r1]`: the row-stripe kernel each CU worker
    /// runs. `y` must have length `nrows` (full-buffer convenience wrapper
    /// of [`CsrMatrix::spmv_into_stripe`]).
    pub fn spmv_into(&self, x: &[f32], y: &mut [f32], r0: usize, r1: usize) {
        assert!(y.len() == self.nrows);
        self.spmv_into_stripe(x, &mut y[r0..r1], r0, r1);
    }

    /// `y_stripe = (M x)[r0..r1]` where `y_stripe.len() == r1 - r0`: the
    /// chunk-local form that parallel CU workers use so concurrent stripes
    /// never hold overlapping `&mut` views of one output buffer. Values
    /// dequantize to f32 at the multiplier input; the accumulator is f32
    /// for every storage format.
    ///
    /// The inner gather loop uses unchecked indexing: `indptr` monotonicity
    /// and `indices < ncols` are structural invariants established at
    /// construction ([`CsrMatrix::validate`] checks them; `from_canonical_coo`
    /// guarantees them) — bounds checks here cost ~10% on the SpMV hot
    /// path (EXPERIMENTS.md §Perf).
    pub fn spmv_into_stripe(&self, x: &[f32], y_stripe: &mut [f32], r0: usize, r1: usize) {
        assert!(r0 <= r1 && r1 <= self.nrows && y_stripe.len() == r1 - r0 && x.len() >= self.ncols);
        debug_assert!(self.validate().is_ok());
        for r in r0..r1 {
            // SAFETY: r < nrows and indptr has nrows+1 entries.
            let (lo, hi) = unsafe {
                (*self.indptr.get_unchecked(r), *self.indptr.get_unchecked(r + 1))
            };
            let mut acc = 0.0f32;
            for k in lo..hi {
                // SAFETY: indptr is monotone with last = nnz, so k < nnz;
                // indices[k] < ncols <= x.len() by construction.
                unsafe {
                    acc += self.vals.get_unchecked(k).to_f32()
                        * x.get_unchecked(*self.indices.get_unchecked(k) as usize);
                }
            }
            y_stripe[r - r0] = acc;
        }
    }

    /// Splice a canonical [`CooDelta`] into this CSR matrix in place:
    /// one two-pointer merge over the row-major entry stream rebuilds
    /// `indptr`/`indices`/`vals` with insertions, value changes, and
    /// deletions applied — `O(nnz + d)`, untouched rows are straight
    /// copies, no COO round-trip. Returns the same [`DeltaApply`] report
    /// as [`CooMatrix::apply_delta`] (the two appliers share one splice
    /// kernel, so a COO and a CSR of the same matrix stay byte-equivalent
    /// under the same delta).
    pub fn apply_delta(&mut self, delta: &CooDelta) -> DeltaApply {
        assert_eq!((self.nrows, self.ncols), (delta.nrows, delta.ncols), "delta dimension mismatch");
        assert!(delta.is_canonical(), "canonicalize the delta before applying");
        let cap = self.nnz() + delta.len();
        let mut counts = vec![0usize; self.nrows];
        let (mut indices, mut vals) = (Vec::with_capacity(cap), Vec::with_capacity(cap));
        let old = (0..self.nrows).flat_map(|r| {
            let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
            self.indices[lo..hi].iter().zip(&self.vals[lo..hi]).map(move |(&c, &v)| (r as u32, c, v))
        });
        let report = crate::sparse::delta::splice(old, &delta.entries, |r, c, v| {
            counts[r as usize] += 1;
            indices.push(c);
            vals.push(v);
        });
        let mut indptr = vec![0usize; self.nrows + 1];
        for r in 0..self.nrows {
            indptr[r + 1] = indptr[r] + counts[r];
        }
        self.indptr = indptr;
        self.indices = indices;
        self.vals = vals;
        debug_assert!(self.validate().is_ok());
        report
    }

    /// Convert back to COO (canonical order).
    pub fn to_coo(&self) -> CooMatrix<V> {
        let mut rows = Vec::with_capacity(self.nnz());
        for r in 0..self.nrows {
            for _ in self.indptr[r]..self.indptr[r + 1] {
                rows.push(r as u32);
            }
        }
        CooMatrix::from_triplets(self.nrows, self.ncols, rows, self.indices.clone(), self.vals.clone())
    }

    /// Transpose (O(nnz)).
    pub fn transpose(&self) -> CsrMatrix<V> {
        let mut indptr = vec![0usize; self.ncols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            indptr[i + 1] += indptr[i];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut vals = vec![V::default(); self.nnz()];
        for r in 0..self.nrows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[k] as usize;
                let dst = cursor[c];
                cursor[c] += 1;
                indices[dst] = r as u32;
                vals[dst] = self.vals[k];
            }
        }
        CsrMatrix { nrows: self.ncols, ncols: self.nrows, indptr, indices, vals }
    }

    /// Maximum row length (useful for padding decisions on the device path
    /// and for scaling quantization-error bounds in the property tests).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.nrows).map(|r| self.indptr[r + 1] - self.indptr[r]).max().unwrap_or(0)
    }

    /// Structural + numeric internal consistency; used by property tests and
    /// after deserialization.
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.nrows + 1 {
            return Err(format!("indptr len {} != nrows+1 {}", self.indptr.len(), self.nrows + 1));
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.nnz() {
            return Err("indptr endpoints invalid".into());
        }
        if self.indices.len() != self.vals.len() {
            return Err("indices/vals length mismatch".into());
        }
        for r in 0..self.nrows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr not monotone at row {r}"));
            }
        }
        if let Some(&c) = self.indices.iter().find(|&&c| c as usize >= self.ncols) {
            return Err(format!("column index {c} out of bounds ({})", self.ncols));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q1_15, Q1_31};

    fn sample() -> CsrMatrix {
        CooMatrix::from_triplets(
            3,
            3,
            vec![0, 0, 1, 1, 2, 2],
            vec![0, 1, 1, 2, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        )
        .to_csr()
    }

    #[test]
    fn spmv_matches_coo() {
        let m = sample();
        assert_eq!(m.spmv(&[1.0, 1.0, 1.0]), vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn stripes_compose_to_full_spmv() {
        let m = sample();
        let x = [2.0f32, -1.0, 0.5];
        let full = m.spmv(&x);
        let mut y = vec![0.0f32; 3];
        m.spmv_into(&x, &mut y, 0, 1);
        m.spmv_into(&x, &mut y, 1, 3);
        assert_eq!(full, y);
    }

    #[test]
    fn transpose_twice_is_identity() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_swaps_action() {
        let m = sample();
        let mt = m.transpose();
        // (M^T x)_j = sum_i M_ij x_i
        let x = [1.0f32, 2.0, 3.0];
        let y = mt.spmv(&x);
        assert_eq!(y, vec![1.0 * 1.0 + 5.0 * 3.0, 2.0 * 1.0 + 3.0 * 2.0, 4.0 * 2.0 + 6.0 * 3.0]);
    }

    #[test]
    fn row_accessor() {
        let m = sample();
        let (cols, vals) = m.row(1);
        assert_eq!(cols, &[1, 2]);
        assert_eq!(vals, &[3.0, 4.0]);
    }

    #[test]
    fn coo_round_trip() {
        let m = sample();
        assert_eq!(m.to_coo().to_csr(), m);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut m = sample();
        assert!(m.validate().is_ok());
        m.indices[0] = 99;
        assert!(m.validate().is_err());
    }

    #[test]
    fn max_row_nnz() {
        let m = sample();
        assert_eq!(m.max_row_nnz(), 2);
    }

    #[test]
    fn apply_delta_matches_coo_applier() {
        use crate::sparse::CooDelta;
        let mut coo = CooMatrix::from_triplets(
            3,
            3,
            vec![0, 0, 1, 1, 2, 2],
            vec![0, 1, 1, 2, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        );
        coo.canonicalize();
        let mut csr = CsrMatrix::from_canonical_coo(&coo);
        let mut d = CooDelta::new(3, 3);
        d.upsert(0, 2, 9.0);
        d.upsert(1, 1, -3.0);
        d.delete(2, 0);
        d.delete(1, 0);
        d.canonicalize();
        let rep_coo = coo.apply_delta(&d);
        let rep_csr = csr.apply_delta(&d);
        // One splice kernel behind both appliers: identical reports and
        // byte-equivalent matrices.
        assert_eq!(rep_coo, rep_csr);
        assert_eq!(csr, CsrMatrix::from_canonical_coo(&coo));
        assert!(csr.validate().is_ok());
        // SpMV agrees with the mutated matrix.
        let x = [1.0f32, -1.0, 0.5];
        assert_eq!(csr.spmv(&x), coo.spmv_ref(&x));
    }

    #[test]
    fn apply_delta_on_typed_storage_quantizes_upserts() {
        use crate::sparse::CooDelta;
        let mut coo: CooMatrix = CooMatrix::new(4, 4);
        for i in 0..4 {
            coo.push(i, i, 0.25);
        }
        let mut q: CsrMatrix<Q1_15> = coo.to_csr().to_precision::<Q1_15>();
        let mut d = CooDelta::new(4, 4);
        d.upsert(1, 1, 0.123_456); // not representable exactly at Q1.15
        d.canonicalize();
        q.apply_delta(&d);
        let got = q.row(1).1[0].to_f32();
        assert!(((got - 0.123_456).abs() as f64) <= <Q1_15 as Dataword>::ulp());
    }

    #[test]
    fn typed_csr_halves_value_bytes_and_tracks_spmv() {
        // Post-normalization regime: values in (-1, 1).
        let mut coo: CooMatrix = CooMatrix::new(6, 6);
        for i in 0..6 {
            coo.push(i, i, 0.3 - (i as f32) * 0.05);
            coo.push(i, (i + 2) % 6, -0.125);
        }
        let f = coo.to_csr();
        let q15: CsrMatrix<Q1_15> = f.to_precision::<Q1_15>();
        let q31: CsrMatrix<Q1_31> = f.to_precision::<Q1_31>();
        assert_eq!(q15.value_bytes(), f.value_bytes() / 2, "16-bit words halve the array");
        assert_eq!(q31.value_bytes(), f.value_bytes());
        let x: Vec<f32> = (0..6).map(|i| ((i * 7 % 5) as f32) * 0.2 - 0.4).collect();
        let y_ref = f.spmv(&x);
        for (a, b) in q31.spmv(&x).iter().zip(&y_ref) {
            assert!(((a - b).abs() as f64) <= 4.0 * <Q1_31 as Dataword>::ulp(), "{a} vs {b}");
        }
        for (a, b) in q15.spmv(&x).iter().zip(&y_ref) {
            assert!(((a - b).abs() as f64) <= 4.0 * <Q1_15 as Dataword>::ulp(), "{a} vs {b}");
        }
        // Round-trips and stripes still work in typed storage.
        assert_eq!(q15.to_coo().to_csr(), q15);
        assert_eq!(q15.transpose().transpose(), q15);
    }
}

//! Non-eigen query kernels on the resident-matrix datapath: streaming
//! **Top-K SpMV** (approximate embedding similarity, arxiv 2103.04808) and
//! reduced-precision **Personalized PageRank** (arxiv 2009.10443).
//!
//! Both reuse the exact substrate the eigensolver streams: the typed CSR
//! value arrays ([`crate::fixed::Dataword`] storage formats), the per-CU
//! row stripes, and the fork/join merge of
//! [`ShardedSpmv`](crate::sparse::ShardedSpmv). This module holds the
//! engine-independent pieces — the deterministic bounded heap, the
//! shard-merge, the PPR power iteration core, and the brute-force serial
//! oracles the property tests pin every result against.
//!
//! ## Determinism contract
//!
//! Top-K results are **bitwise equal** to "full SpMV + stable sort by
//! `(score desc, index asc)` + truncate to K" for any CU shard count or
//! partition policy: per-row scores come from the same stripe kernel the
//! serial SpMV runs (identical accumulation order), and ranking uses the
//! IEEE total order ([`f32::total_cmp`]) with ascending row index as the
//! tie-break, so the selected set and its order are a pure function of the
//! score vector. `tests/query_oracle.rs` property-checks this across all
//! four storage formats.
//!
//! PPR is likewise bitwise reproducible for a fixed engine: the SpMV per
//! iteration is the sharded engine's (bitwise serial-equal), and every
//! other pass (dangling-mass fold, damping, L1 delta) is a serial sweep in
//! a fixed order.
//!
//! ## PPR accuracy vs the f64 oracle
//!
//! [`ppr_with`] iterates in f32 over values *stored* in the engine's
//! format, so its distance from a dense f64 power iteration on the
//! original matrix is bounded by the storage quantization. The documented
//! per-precision L1 tolerances (pinned by `tests/query_oracle.rs` on
//! star/cycle/R-MAT/dangling graphs at unit-test scale) are:
//!
//! | format | L1(x - x_oracle) |
//! |--------|------------------|
//! | f32    | 1e-4             |
//! | q1.31  | 1e-3             |
//! | q2.30  | 1e-3             |
//! | q1.15  | 8e-2             |
//!
//! (Q1.15's bound is loose because Frobenius normalization shrinks stored
//! values toward the 2^-15 quantization step on larger graphs; against an
//! oracle run on the *dequantized* stored values every format lands within
//! 5e-4.)

use crate::fixed::Dataword;
use crate::sparse::CsrMatrix;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One Top-K hit: a row index and its SpMV score.
///
/// The derived/total order ranks **better-first**: higher score wins, and
/// equal scores (IEEE total order, so `-0.0 < 0.0`) go to the *lower* row
/// index — the tie-break that makes heap selection equal a stable sort of
/// the full score vector.
#[derive(Copy, Clone, Debug)]
pub struct TopKEntry {
    /// Row index of the hit.
    pub index: u32,
    /// SpMV score of that row (engine scale; the service rescales by the
    /// Frobenius norm so clients see original-matrix scores).
    pub score: f32,
}

impl PartialEq for TopKEntry {
    fn eq(&self, other: &Self) -> bool {
        self.index == other.index && self.score.total_cmp(&other.score).is_eq()
    }
}
impl Eq for TopKEntry {}

impl Ord for TopKEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Greater = better: higher score, then lower index.
        self.score.total_cmp(&other.score).then_with(|| other.index.cmp(&self.index))
    }
}
impl PartialOrd for TopKEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// A bounded partial max-heap: the per-CU selection structure of the Top-K
/// SpMV sweep. Each CU shard pushes every row score it produces; the heap
/// keeps only the `k` best under [`TopKEntry`]'s total order (internally a
/// min-heap whose root is the current worst, so a non-improving row costs
/// one comparison and no allocation).
pub struct TopKHeap {
    k: usize,
    heap: BinaryHeap<Reverse<TopKEntry>>,
}

impl TopKHeap {
    /// An empty heap bounded to `k` entries (`k = 0` keeps nothing).
    pub fn new(k: usize) -> Self {
        Self { k, heap: BinaryHeap::with_capacity(k.min(1 << 20)) }
    }

    /// Offer one `(index, score)`; kept only while among the `k` best.
    #[inline]
    pub fn push(&mut self, index: u32, score: f32) {
        if self.k == 0 {
            return;
        }
        let e = TopKEntry { index, score };
        if self.heap.len() < self.k {
            self.heap.push(Reverse(e));
        } else if self.heap.peek().is_some_and(|worst| e > worst.0) {
            self.heap.pop();
            self.heap.push(Reverse(e));
        }
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no entry is held.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drain into a best-first sorted vector.
    pub fn into_sorted(self) -> Vec<TopKEntry> {
        let mut v: Vec<TopKEntry> = self.heap.into_iter().map(|r| r.0).collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }
}

/// The fork/join Merge Unit of the Top-K sweep: fold per-shard best-first
/// lists (disjoint row ranges, shard order) into the global best-first
/// top-`k`. Because [`TopKEntry`]'s order is total and shard row ranges are
/// disjoint, the result is independent of shard boundaries — identical to
/// selecting from the concatenated score vector directly.
///
/// `k == 0` returns the empty vector deterministically — the whole
/// selection stack ([`TopKHeap::new`]`(0)`, this merge,
/// [`top_k_serial`], [`ShardedSpmv::top_k`](crate::sparse::ShardedSpmv::top_k))
/// shares that contract, so callers never need to pre-validate `k`.
pub fn merge_top_k(parts: Vec<Vec<TopKEntry>>, k: usize) -> Vec<TopKEntry> {
    let mut all: Vec<TopKEntry> = parts.into_iter().flatten().collect();
    all.sort_unstable_by(|a, b| b.cmp(a));
    all.truncate(k);
    all
}

/// Brute-force Top-K oracle: full SpMV, rank every row by
/// `(score desc, index asc)`, take the first `k` (clamped to `nrows`;
/// `k == 0` is deterministically empty). The property tests pin
/// [`ShardedSpmv::top_k`]
/// (crate::sparse::ShardedSpmv::top_k) bitwise against this.
pub fn top_k_serial<V: Dataword>(m: &CsrMatrix<V>, x: &[f32], k: usize) -> Vec<TopKEntry> {
    let y = m.spmv(x);
    let mut all: Vec<TopKEntry> =
        y.iter().enumerate().map(|(i, &score)| TopKEntry { index: i as u32, score }).collect();
    all.sort_by(|a, b| b.cmp(a)); // stable, though the order is total anyway
    all.truncate(k.min(m.nrows));
    all
}

/// Per-row L1 norms of the **dequantized stored** values in f64:
/// `row_l1[r] = sum_j |M_rj|`. These are the conservative score bounds the
/// early-exit Top-K sweep prunes CU shards with — for any query `x`,
/// `|(M x)_r| <= row_l1[r] * max_j |x_j|` holds in exact arithmetic, and
/// [`ShardedSpmv::top_k_with_bounds`](crate::sparse::ShardedSpmv::top_k_with_bounds)
/// inflates the product by the worst-case f32 accumulation error before
/// comparing, so the bound also dominates the *computed* f32 score. Like
/// [`column_sums`], the table depends only on the stored value stream
/// (precision), not on any shard geometry — the registry caches it per
/// `(handle, precision, generation)` beside the colsums.
pub fn row_l1_norms<V: Dataword>(m: &CsrMatrix<V>) -> Vec<f64> {
    let mut norms = vec![0.0f64; m.nrows];
    for r in 0..m.nrows {
        let (lo, hi) = (m.indptr[r], m.indptr[r + 1]);
        let mut acc = 0.0f64;
        for k in lo..hi {
            acc += (m.vals[k].to_f32() as f64).abs();
        }
        norms[r] = acc;
    }
    norms
}

/// Personalized PageRank configuration.
///
/// The iteration solves `x = alpha * P x + (1 - alpha) * e_s` by damped
/// power iteration, where `P` is the column-normalized resident matrix
/// (`P_ij = M_ij / colsum_j`), `e_s` the one-hot personalization on
/// [`PprOptions::source`], and zero-out-weight (dangling) columns
/// redistribute their mass uniformly. Stops when the L1 change of `x`
/// falls to [`PprOptions::tol`] or after [`PprOptions::max_iters`].
#[derive(Clone, Debug)]
pub struct PprOptions {
    /// Personalization vertex (the `e_s` one-hot).
    pub source: usize,
    /// Damping factor in `(0, 1)` (teleport probability `1 - alpha`).
    pub alpha: f64,
    /// L1 stopping tolerance on the per-iteration change.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
}

impl Default for PprOptions {
    fn default() -> Self {
        // tol sits above the f32 L1-delta floor: the iteration vector is
        // f32, so the per-iteration delta of a unit-scale graph stalls
        // around a few ulps per component (~3e-6 L1 on a hub-heavy star)
        // and a tighter default would spin to max_iters without ever
        // reporting convergence.
        Self { source: 0, alpha: 0.85, tol: 5e-6, max_iters: 200 }
    }
}

/// A converged (or capped) PPR vector plus iteration telemetry.
#[derive(Clone, Debug, PartialEq)]
pub struct PprResult {
    /// The PPR scores (length n, sums to ~1 for non-negative matrices).
    pub scores: Vec<f32>,
    /// Power iterations performed.
    pub iterations: usize,
    /// L1 change of the final iteration.
    pub l1_delta: f64,
    /// Whether `l1_delta <= tol` before the cap.
    pub converged: bool,
    /// Dangling vertices (zero column weight) whose mass was
    /// redistributed each iteration.
    pub dangling: usize,
    /// Whether the iteration started from a caller-supplied seed
    /// ([`ppr_with_seed`]) instead of the cold one-hot start.
    pub warm_started: bool,
}

/// Column weight sums of a typed CSR: `colsum[j] = sum_i M_ij` over the
/// **dequantized stored** values, accumulated in f64 row-major (one fixed
/// order, so the sums are independent of any sharding). These are the
/// out-weight normalizers of the PPR transition matrix — the convention is
/// `M_ij` = weight of the edge `j -> i`, so a symmetric adjacency works
/// as-is and a directed graph should be registered **transposed**.
pub fn column_sums<V: Dataword>(m: &CsrMatrix<V>) -> Vec<f64> {
    let mut sums = vec![0.0f64; m.ncols];
    for k in 0..m.nnz() {
        sums[m.indices[k] as usize] += m.vals[k].to_f32() as f64;
    }
    sums
}

/// The PPR power-iteration core, parameterized over the SpMV so the
/// sharded engine and the serial oracle share one implementation (and
/// therefore one dangling/damping/stopping semantics):
///
/// per iteration, with `z_j = x_j / colsum_j` (0 on dangling columns):
/// `x'_i = alpha * ((M z)_i + dangling_mass / n) + (1 - alpha) * e_s_i`.
///
/// `apply` must compute `y = M z` for the matrix `colsums` was taken from.
/// The vector stays f32 (the datapath's word) while all scalar folds
/// (dangling mass, damping coefficients, L1 delta) run in f64. Because the
/// normalization `z = x ./ colsum` divides stored values by their own
/// column totals, the result is invariant to the registry's Frobenius
/// scaling up to quantization — scores come back in probability scale with
/// no rescale step.
///
/// Panics if `source >= n`, `alpha` outside `(0, 1)`, or `max_iters == 0`
/// (the service validates these at submit time).
pub fn ppr_with(n: usize, colsums: &[f64], opts: &PprOptions, apply: impl FnMut(&[f32], &mut [f32])) -> PprResult {
    ppr_with_seed(n, colsums, opts, None, apply)
}

/// [`ppr_with`] with an optional warm start: when `seed` is `Some`, the
/// iteration begins from those scores instead of the cold one-hot on
/// `opts.source`. The damped iteration `x <- alpha * P_hat x + (1-alpha) e_s`
/// is an L1 contraction with contraction factor `alpha`, so its fixed point
/// is unique — a warm start changes only *how many* iterations the L1-delta
/// stop takes to reach `tol`, not which vector it converges toward. The
/// service seeds from the previous generation's converged scores after a
/// small `CooDelta` (the same `||delta||_F` guard the eigen warm-seed
/// cache uses), so warm re-solves cost measurably fewer matrix passes.
///
/// A cold call (`seed = None`) is bitwise identical to [`ppr_with`].
/// Panics additionally if `seed.len() != n`.
pub fn ppr_with_seed(
    n: usize,
    colsums: &[f64],
    opts: &PprOptions,
    seed: Option<&[f32]>,
    mut apply: impl FnMut(&[f32], &mut [f32]),
) -> PprResult {
    assert_eq!(colsums.len(), n, "column-sum table must cover every vertex");
    assert!(opts.source < n, "ppr source {} out of range (n = {n})", opts.source);
    assert!(opts.alpha > 0.0 && opts.alpha < 1.0, "alpha must be in (0, 1), got {}", opts.alpha);
    assert!(opts.max_iters >= 1, "max_iters must be >= 1");
    let dangling: Vec<bool> = colsums.iter().map(|&s| s == 0.0).collect();
    let n_dangling = dangling.iter().filter(|&&d| d).count();
    let warm_started = seed.is_some();
    let mut x = match seed {
        Some(s) => {
            assert_eq!(s.len(), n, "warm seed must cover every vertex");
            s.to_vec()
        }
        None => {
            let mut x = vec![0.0f32; n];
            x[opts.source] = 1.0;
            x
        }
    };
    let mut z = vec![0.0f32; n];
    let mut y = vec![0.0f32; n];
    let teleport = 1.0 - opts.alpha;
    let (mut iterations, mut l1_delta, mut converged) = (0usize, f64::INFINITY, false);
    for _ in 0..opts.max_iters {
        iterations += 1;
        // Normalize by column weight; fold dangling mass (serial, fixed
        // order — deterministic for any engine geometry).
        let mut dangling_mass = 0.0f64;
        for j in 0..n {
            if dangling[j] {
                dangling_mass += x[j] as f64;
                z[j] = 0.0;
            } else {
                z[j] = (x[j] as f64 / colsums[j]) as f32;
            }
        }
        apply(&z, &mut y);
        let spread = opts.alpha * dangling_mass / n as f64;
        l1_delta = 0.0;
        for i in 0..n {
            let xi = (opts.alpha * y[i] as f64 + spread + if i == opts.source { teleport } else { 0.0 }) as f32;
            l1_delta += (xi as f64 - x[i] as f64).abs();
            x[i] = xi;
        }
        if l1_delta <= opts.tol {
            converged = true;
            break;
        }
    }
    PprResult { scores: x, iterations, l1_delta, converged, dangling: n_dangling, warm_started }
}

/// Serial PPR oracle over a typed CSR — [`ppr_with`] driven by the plain
/// serial SpMV. [`ShardedSpmv::ppr`](crate::sparse::ShardedSpmv::ppr) is
/// bitwise equal to this for any CU count (the sharded apply is bitwise
/// serial-equal and every other pass is shared code).
pub fn ppr_serial<V: Dataword>(m: &CsrMatrix<V>, opts: &PprOptions) -> PprResult {
    assert_eq!(m.nrows, m.ncols, "PPR needs a square matrix");
    let colsums = column_sums(m);
    ppr_with(m.nrows, &colsums, opts, |z, y| {
        y.copy_from_slice(&m.spmv(z));
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    #[test]
    fn heap_keeps_k_best_with_index_tiebreak() {
        let mut h = TopKHeap::new(3);
        for (i, s) in [(0u32, 1.0f32), (1, 5.0), (2, 5.0), (3, 0.5), (4, 5.0), (5, 2.0)] {
            h.push(i, s);
        }
        assert_eq!(h.len(), 3);
        let best = h.into_sorted();
        // Three fives tie; lower indices win and order ascending.
        assert_eq!(best, vec![
            TopKEntry { index: 1, score: 5.0 },
            TopKEntry { index: 2, score: 5.0 },
            TopKEntry { index: 4, score: 5.0 },
        ]);
    }

    #[test]
    fn heap_k_zero_and_underfill() {
        let mut h = TopKHeap::new(0);
        h.push(7, 3.0);
        assert!(h.is_empty());
        assert!(h.into_sorted().is_empty());
        let mut h = TopKHeap::new(10);
        h.push(1, -1.0);
        h.push(0, -2.0);
        let v = h.into_sorted();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].index, 1);
    }

    #[test]
    fn entry_order_is_total_and_better_first() {
        let a = TopKEntry { index: 3, score: 2.0 };
        let b = TopKEntry { index: 1, score: 2.0 };
        let c = TopKEntry { index: 0, score: -0.0 };
        let d = TopKEntry { index: 0, score: 0.0 };
        assert!(b > a, "equal scores: lower index ranks higher");
        assert!(d > c, "IEEE total order: +0.0 outranks -0.0");
        assert_ne!(c, d);
    }

    #[test]
    fn merge_equals_global_selection() {
        let scores: Vec<f32> = (0..40).map(|i| ((i * 17) % 13) as f32 * 0.5).collect();
        let global = {
            let mut h = TopKHeap::new(5);
            for (i, &s) in scores.iter().enumerate() {
                h.push(i as u32, s);
            }
            h.into_sorted()
        };
        // Shard into uneven stripes, select per shard, merge.
        let mut parts = Vec::new();
        for (lo, hi) in [(0usize, 7usize), (7, 25), (25, 40)] {
            let mut h = TopKHeap::new(5);
            for i in lo..hi {
                h.push(i as u32, scores[i]);
            }
            parts.push(h.into_sorted());
        }
        assert_eq!(merge_top_k(parts, 5), global);
    }

    #[test]
    fn serial_oracle_matches_hand_computation() {
        let m: CsrMatrix =
            CooMatrix::from_triplets(3, 3, vec![0, 1, 2], vec![0, 1, 2], vec![1.0f32, 3.0, 2.0]).to_csr();
        let got = top_k_serial(&m, &[1.0, 1.0, 1.0], 2);
        assert_eq!(got, vec![TopKEntry { index: 1, score: 3.0 }, TopKEntry { index: 2, score: 2.0 }]);
        // k beyond n clamps.
        assert_eq!(top_k_serial(&m, &[1.0, 1.0, 1.0], 99).len(), 3);
    }

    #[test]
    fn ppr_on_two_cycle_matches_closed_form() {
        // Two vertices joined by one undirected unit edge: P swaps mass, so
        // x = (1-a) e_0 + a P x has the closed form
        // x_0 = 1/(1+a), x_1 = a/(1+a).
        let mut coo: CooMatrix = CooMatrix::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        let m = coo.to_csr();
        // tol below the f32 delta floor: the iteration runs to the cap,
        // oscillating a few ulps around the fixed point — `converged`
        // stays false but the scores are as close as f32 gets.
        let opts = PprOptions { alpha: 0.85, tol: 1e-12, max_iters: 500, source: 0 };
        let r = ppr_serial(&m, &opts);
        assert_eq!(r.dangling, 0);
        assert!(r.l1_delta < 1e-5, "delta must reach the f32 floor, got {}", r.l1_delta);
        let expect0 = 1.0 / (1.0 + 0.85);
        let expect1 = 0.85 / (1.0 + 0.85);
        assert!((r.scores[0] as f64 - expect0).abs() < 1e-6, "{:?}", r.scores);
        assert!((r.scores[1] as f64 - expect1).abs() < 1e-6, "{:?}", r.scores);
    }

    #[test]
    fn ppr_redistributes_dangling_mass_and_conserves_total() {
        // Personalize on the isolated (dangling) vertex 2: its mass must
        // teleport uniformly instead of vanishing, so the connected pair
        // {0, 1} ends up with positive scores and sum(x) stays 1. (Spread
        // only redistributes mass *held by* dangling vertices — an
        // isolated vertex that never receives any stays at exactly 0.)
        let mut coo: CooMatrix = CooMatrix::new(3, 3);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        let m = coo.to_csr();
        let r = ppr_serial(&m, &PprOptions { source: 2, tol: 1e-6, max_iters: 500, ..Default::default() });
        assert!(r.converged);
        assert_eq!(r.dangling, 1);
        let total: f64 = r.scores.iter().map(|&s| s as f64).sum();
        assert!((total - 1.0).abs() < 1e-5, "mass must be conserved, got {total}");
        assert!(r.scores.iter().all(|&s| s > 0.0), "spread mass reaches every vertex: {:?}", r.scores);
        assert!(r.scores[2] > r.scores[0], "the personalization vertex keeps the teleport share");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn ppr_rejects_bad_source() {
        let m: CsrMatrix = CooMatrix::new(2, 2).to_csr();
        ppr_serial(&m, &PprOptions { source: 2, ..Default::default() });
    }

    #[test]
    fn k_zero_is_deterministically_empty_across_the_stack() {
        // The whole selection stack shares the k == 0 -> empty contract;
        // no layer may panic or demand pre-validation.
        assert!(merge_top_k(vec![vec![TopKEntry { index: 0, score: 1.0 }]], 0).is_empty());
        assert!(merge_top_k(Vec::new(), 0).is_empty());
        let m: CsrMatrix =
            CooMatrix::from_triplets(3, 3, vec![0, 1, 2], vec![0, 1, 2], vec![1.0f32, 3.0, 2.0]).to_csr();
        assert!(top_k_serial(&m, &[1.0, 1.0, 1.0], 0).is_empty());
    }

    #[test]
    fn row_l1_norms_sum_absolute_stored_values() {
        let mut coo: CooMatrix = CooMatrix::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(0, 2, -3.0);
        coo.push(2, 1, 0.5);
        let m = coo.to_csr();
        let norms = row_l1_norms(&m);
        assert_eq!(norms, vec![5.0, 0.0, 0.5]);
        // The bound it exists for: |(M x)_r| <= row_l1[r] * max|x_j|.
        let x = [0.25f32, -1.0, 0.75];
        let y = m.spmv(&x);
        let xmax = x.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()));
        for r in 0..3 {
            assert!((y[r] as f64).abs() <= norms[r] * xmax + 1e-12);
        }
    }

    #[test]
    fn warm_seeded_ppr_reaches_the_same_fixed_point_in_fewer_iterations() {
        // 5-cycle with one chord: enough structure that convergence takes
        // a handful of iterations. Seeding from the converged answer must
        // re-converge immediately; seeding from a nearby vector converges
        // to the same scores (unique fixed point) in fewer iterations.
        let mut coo: CooMatrix = CooMatrix::new(5, 5);
        for i in 0..5 {
            coo.push((i + 1) % 5, i, 1.0);
            coo.push(i, (i + 1) % 5, 1.0);
        }
        coo.push(0, 2, 1.0);
        coo.push(2, 0, 1.0);
        let m = coo.to_csr();
        let opts = PprOptions { source: 1, tol: 1e-5, max_iters: 300, ..Default::default() };
        let colsums = column_sums(&m);
        let cold = ppr_with(m.nrows, &colsums, &opts, |z, y| y.copy_from_slice(&m.spmv(z)));
        assert!(cold.converged && !cold.warm_started);
        let warm = ppr_with_seed(m.nrows, &colsums, &opts, Some(&cold.scores), |z, y| {
            y.copy_from_slice(&m.spmv(z))
        });
        assert!(warm.converged && warm.warm_started);
        assert!(
            warm.iterations < cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
        for i in 0..5 {
            assert!((warm.scores[i] as f64 - cold.scores[i] as f64).abs() < 1e-4);
        }
        // Cold call through the seeded entry point stays bitwise-equal.
        let cold2 = ppr_with_seed(m.nrows, &colsums, &opts, None, |z, y| y.copy_from_slice(&m.spmv(z)));
        assert_eq!(cold2, cold);
    }
}

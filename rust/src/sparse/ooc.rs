//! Out-of-core packet files: the matrix lives on storage, not in RAM.
//!
//! The paper's premise is that the matrix is *streamed* — HBM channels feed
//! each CU 512-bit packet lines while only the O(n) Lanczos vectors stay in
//! fast memory. This module extends that economy past RAM (after the
//! SSD-eigensolver design of arXiv 1602.01421): a [`PacketFileWriter`]
//! serializes any `CsrMatrix<V>` into one chunk file per CU shard, and an
//! [`OocShardSource`] replays a shard through a **double-buffered
//! prefetcher** — the fused sweep consumes one chunk buffer while a
//! dedicated I/O pool fills the other, so warm iterations overlap storage
//! reads with SpMV and stay allocation-flat (all chunk buffers are
//! preallocated at [`OocMatrix::open`]).
//!
//! ## On-disk format (version 1)
//!
//! Per shard `shard-NNN.pkt`:
//!
//! ```text
//! header   64 B   magic "TKPK", version u32, precision tag u32, shard u32,
//!                 nrows/ncols/row_start/row_end/nnz/chunk_count u64 (LE)
//! table    40 B/chunk  row_start, row_end, nnz, payload_bytes, fnv1a64
//! payload  64 B-aligned packet lines, each holding up to
//!          packet_capacity(V::BITS) entries of (row u32, col u32,
//!          raw value bits) — §IV-B1's line layout, zero-padded
//! ```
//!
//! Values are serialized as **raw storage bits** ([`Dataword::to_bits`]):
//! an f32 round-trip would silently perturb Q1.31/Q2.30 words (24-bit
//! mantissa vs 31 fraction bits), and the whole point of the format is that
//! an out-of-core solve is bitwise-identical to the resident path.
//!
//! Chunk boundaries fall on multiples of the 512-row kernel window
//! ([`crate::sparse::sharded`]'s `TOPK_ROW_CHUNK`) relative to the shard's
//! first row, so the windowed kernels (`top_k*`, `apply_fused_block`) see
//! exactly the window sequence the resident engine produces; chunks tile
//! the shard's whole row range (a chunk may carry zero entries) so
//! window-level vector work runs even where the matrix is locally empty.
//!
//! A human-readable `manifest.tkm` records precision, dimensions, the
//! Frobenius norm (as raw f64 bits), and the shard partition; every parse
//! or validation failure is a line-numbered `anyhow` error.

use std::io::{Read as _, Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::fixed::{packet_capacity, Dataword, Precision};
use crate::sparse::sharded::TOPK_ROW_CHUNK;
use crate::sparse::{partition_rows_balanced, CooMatrix, CsrMatrix, PartitionPolicy, RowPartition};
use crate::util::pool::ThreadPool;

/// Bytes per 512-bit packet line.
const LINE_BYTES: usize = (crate::fixed::LINE_BITS / 8) as usize;
/// File magic: "TKPK" (Top-K PacKet).
const MAGIC: [u8; 4] = *b"TKPK";
/// On-disk format version this build reads and writes.
const FORMAT_VERSION: u32 = 1;
/// Fixed per-shard header size.
const HEADER_BYTES: usize = 64;
/// Chunk-table entry size (5 LE u64 words).
const TABLE_ENTRY_BYTES: usize = 40;
/// Default chunk payload target: ~1 MiB keeps seeks rare while two buffers
/// per shard stay far below any realistic matrix size.
pub const DEFAULT_CHUNK_BYTES: usize = 1 << 20;
/// Manifest file name inside an OOC directory.
pub const MANIFEST_NAME: &str = "manifest.tkm";

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte slice, continuing from `h` (seed with [`FNV_OFFSET`]).
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

fn precision_tag(p: Precision) -> u32 {
    match p {
        Precision::Float32 => 0,
        Precision::FixedQ1_31 => 1,
        Precision::FixedQ2_30 => 2,
        Precision::FixedQ1_15 => 3,
    }
}

fn tag_precision(tag: u32) -> Option<Precision> {
    Precision::ALL.iter().copied().find(|&p| precision_tag(p) == tag)
}

fn get_u32(b: &[u8], o: usize) -> u32 {
    u32::from_le_bytes(b[o..o + 4].try_into().unwrap())
}

fn get_u64(b: &[u8], o: usize) -> u64 {
    u64::from_le_bytes(b[o..o + 8].try_into().unwrap())
}

/// Path of shard `s`'s chunk file inside `dir`.
pub fn shard_path(dir: &Path, s: usize) -> PathBuf {
    dir.join(format!("shard-{s:03}.pkt"))
}

/// Unique scratch directory under the system temp dir (tests and benches;
/// caller removes it when done).
#[doc(hidden)]
pub fn scratch_dir(tag: &str) -> PathBuf {
    static SEQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("topk-ooc-{tag}-{}-{n}", std::process::id()))
}

// ---------------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------------

/// Everything the engine needs to know about an OOC directory without
/// touching a chunk file: precision, dimensions, Frobenius norm, and the
/// CU shard partition (identical to what `partition_rows_balanced` would
/// produce on the resident matrix, so shard geometry matches bit-for-bit).
#[derive(Clone, Debug, PartialEq)]
pub struct OocManifest {
    /// Storage format of the persisted values.
    pub precision: Precision,
    /// Matrix rows.
    pub nrows: usize,
    /// Matrix columns.
    pub ncols: usize,
    /// Stored non-zeros across all shards.
    pub nnz: usize,
    /// Frobenius norm of the *original* matrix (values on disk are already
    /// normalized); eigenvalues rescale by this, so it is stored as exact
    /// f64 bits.
    pub fro: f64,
    /// Maximum row length (sizes the early-exit inflate bound).
    pub max_row_nnz: usize,
    /// Partition policy the shard table was built with.
    pub policy: PartitionPolicy,
    /// One row partition per shard file.
    pub parts: Vec<RowPartition>,
}

impl OocManifest {
    fn policy_name(policy: PartitionPolicy) -> &'static str {
        match policy {
            PartitionPolicy::EqualRows => "equal",
            PartitionPolicy::BalancedNnz => "balanced",
        }
    }

    fn save(&self, dir: &Path) -> Result<()> {
        let mut text = String::new();
        text.push_str("format = tkpk\n");
        text.push_str(&format!("version = {FORMAT_VERSION}\n"));
        text.push_str(&format!("precision = {}\n", self.precision.name()));
        text.push_str(&format!("nrows = {}\n", self.nrows));
        text.push_str(&format!("ncols = {}\n", self.ncols));
        text.push_str(&format!("nnz = {}\n", self.nnz));
        text.push_str(&format!("fro_bits = {}\n", self.fro.to_bits()));
        text.push_str(&format!("max_row_nnz = {}\n", self.max_row_nnz));
        text.push_str(&format!("policy = {}\n", Self::policy_name(self.policy)));
        text.push_str(&format!("shards = {}\n", self.parts.len()));
        for (s, p) in self.parts.iter().enumerate() {
            text.push_str(&format!("shard = {s} {} {} {}\n", p.row_start, p.row_end, p.nnz));
        }
        let path = dir.join(MANIFEST_NAME);
        std::fs::write(&path, text).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Parse `dir/manifest.tkm`. Every malformed line is reported as
    /// `manifest.tkm:<line>: <what>` so a damaged directory is debuggable.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading OOC manifest {}", path.display()))?;
        let mut fields: std::collections::HashMap<&str, (usize, &str)> =
            std::collections::HashMap::new();
        let mut shard_lines: Vec<(usize, &str)> = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("{MANIFEST_NAME}:{lineno}: expected `key = value`"))?;
            let (key, value) = (key.trim(), value.trim());
            if key == "shard" {
                shard_lines.push((lineno, value));
            } else {
                fields.insert(key, (lineno, value));
            }
        }
        fn take<'t, T: std::str::FromStr>(
            fields: &std::collections::HashMap<&str, (usize, &'t str)>,
            key: &str,
        ) -> Result<T> {
            let (lineno, value) =
                fields.get(key).with_context(|| format!("{MANIFEST_NAME}: missing `{key}`"))?;
            value.parse::<T>().ok().with_context(|| {
                format!("{MANIFEST_NAME}:{lineno}: invalid `{key}` value `{value}`")
            })
        }
        let format: String = take(&fields, "format")?;
        ensure!(format == "tkpk", "{MANIFEST_NAME}: unknown format `{format}`");
        let version: u32 = take(&fields, "version")?;
        ensure!(
            version == FORMAT_VERSION,
            "{MANIFEST_NAME}: unsupported version {version} (this build reads {FORMAT_VERSION})"
        );
        let (prec_line, prec_name) = *fields
            .get("precision")
            .with_context(|| format!("{MANIFEST_NAME}: missing `precision`"))?;
        let precision = Precision::ALL
            .iter()
            .copied()
            .find(|p| p.name() == prec_name)
            .with_context(|| {
                format!("{MANIFEST_NAME}:{prec_line}: unknown precision `{prec_name}`")
            })?;
        let (pol_line, pol_name) =
            *fields.get("policy").with_context(|| format!("{MANIFEST_NAME}: missing `policy`"))?;
        let policy = match pol_name {
            "equal" => PartitionPolicy::EqualRows,
            "balanced" => PartitionPolicy::BalancedNnz,
            other => bail!("{MANIFEST_NAME}:{pol_line}: unknown policy `{other}`"),
        };
        let nrows: usize = take(&fields, "nrows")?;
        let ncols: usize = take(&fields, "ncols")?;
        let nnz: usize = take(&fields, "nnz")?;
        let fro = f64::from_bits(take::<u64>(&fields, "fro_bits")?);
        let max_row_nnz: usize = take(&fields, "max_row_nnz")?;
        let shards: usize = take(&fields, "shards")?;
        ensure!(
            shard_lines.len() == shards,
            "{MANIFEST_NAME}: `shards = {shards}` but {} shard lines",
            shard_lines.len()
        );
        let mut parts = Vec::with_capacity(shards);
        for (expect, &(lineno, value)) in shard_lines.iter().enumerate() {
            let nums: Vec<usize> = value.split_whitespace().map(|t| t.parse().ok()).collect::<
                Option<Vec<usize>>,
            >()
            .with_context(|| {
                format!("{MANIFEST_NAME}:{lineno}: expected `shard = <idx> <row_start> <row_end> <nnz>`")
            })?;
            ensure!(
                nums.len() == 4 && nums[0] == expect,
                "{MANIFEST_NAME}:{lineno}: expected shard index {expect}, got `{value}`"
            );
            ensure!(
                nums[1] <= nums[2] && nums[2] <= nrows,
                "{MANIFEST_NAME}:{lineno}: shard rows {}..{} out of bounds (nrows {nrows})",
                nums[1],
                nums[2]
            );
            parts.push(RowPartition { row_start: nums[1], row_end: nums[2], nnz: nums[3] });
        }
        let total: usize = parts.iter().map(|p| p.nnz).sum();
        ensure!(total == nnz, "{MANIFEST_NAME}: shard nnz sum {total} != nnz {nnz}");
        Ok(Self { precision, nrows, ncols, nnz, fro, max_row_nnz, policy, parts })
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Serializes a matrix into an OOC packet directory: one chunk file per CU
/// shard plus `manifest.tkm`. The shard table comes from the same
/// `partition_rows_balanced` the resident engine uses, so an OOC solve sees
/// the exact CU geometry of its in-memory twin.
pub struct PacketFileWriter {
    dir: PathBuf,
    chunk_target_bytes: usize,
}

impl PacketFileWriter {
    /// Writer targeting `dir` (created if missing) with the default
    /// [`DEFAULT_CHUNK_BYTES`] chunk payload target.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), chunk_target_bytes: DEFAULT_CHUNK_BYTES }
    }

    /// Override the chunk payload target (tests use tiny chunks to exercise
    /// many prefetch hand-offs). Chunk boundaries still fall on 512-row
    /// window multiples, so a single dense window may exceed the target.
    pub fn chunk_target_bytes(mut self, bytes: usize) -> Self {
        self.chunk_target_bytes = bytes.max(LINE_BYTES);
        self
    }

    /// Serialize a canonical COO matrix (convenience wrapper over
    /// [`PacketFileWriter::write_csr`]).
    pub fn write_coo<V: Dataword>(
        &self,
        coo: &CooMatrix<V>,
        fro: f64,
        cus: usize,
        policy: PartitionPolicy,
    ) -> Result<OocManifest> {
        self.write_csr(&coo.to_csr(), fro, cus, policy)
    }

    /// Serialize a CSR matrix into `cus` shard files. `fro` is the original
    /// Frobenius norm (the values in `m` are expected to already be
    /// normalized/quantized exactly as the resident engine stores them —
    /// the writer moves raw bits, never re-rounds).
    pub fn write_csr<V: Dataword>(
        &self,
        m: &CsrMatrix<V>,
        fro: f64,
        cus: usize,
        policy: PartitionPolicy,
    ) -> Result<OocManifest> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating OOC dir {}", self.dir.display()))?;
        let parts = partition_rows_balanced(m, cus, policy);
        for (s, p) in parts.iter().enumerate() {
            self.write_shard(m, s, p)
                .with_context(|| format!("writing {}", shard_path(&self.dir, s).display()))?;
        }
        let manifest = OocManifest {
            precision: V::precision(),
            nrows: m.nrows,
            ncols: m.ncols,
            nnz: m.nnz(),
            fro,
            max_row_nnz: m.max_row_nnz(),
            policy,
            parts,
        };
        manifest.save(&self.dir)?;
        Ok(manifest)
    }

    /// Serialize shard-by-shard from a producer callback — the streaming
    /// entry point for graphs larger than RAM. `make_shard(s, row_start,
    /// row_end)` returns a full-height CSR holding ONLY rows
    /// `[row_start, row_end)` (all other rows empty), so peak residency is
    /// one shard's entries, never the whole matrix. The caller fixes the
    /// row partition up front: a streaming producer has no global CSR to
    /// nnz-balance over, so [`PartitionPolicy::EqualRows`] geometry is the
    /// norm here.
    pub fn write_shards<V: Dataword>(
        &self,
        nrows: usize,
        ncols: usize,
        fro: f64,
        policy: PartitionPolicy,
        rows: &[(usize, usize)],
        mut make_shard: impl FnMut(usize, usize, usize) -> Result<CsrMatrix<V>>,
    ) -> Result<OocManifest> {
        ensure!(!rows.is_empty(), "write_shards needs at least one shard");
        ensure!(
            rows[0].0 == 0
                && rows[rows.len() - 1].1 == nrows
                && rows.windows(2).all(|w| w[0].1 == w[1].0),
            "shard row ranges must tile 0..{nrows} contiguously"
        );
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating OOC dir {}", self.dir.display()))?;
        let mut parts = Vec::with_capacity(rows.len());
        let (mut nnz, mut max_row_nnz) = (0usize, 0usize);
        for (s, &(row_start, row_end)) in rows.iter().enumerate() {
            let m = make_shard(s, row_start, row_end)?;
            ensure!(
                m.nrows == nrows && m.ncols == ncols,
                "shard {s}: producer returned {}x{}, expected {nrows}x{ncols}",
                m.nrows,
                m.ncols
            );
            let p = RowPartition { row_start, row_end, nnz: m.indptr[row_end] - m.indptr[row_start] };
            ensure!(
                m.nnz() == p.nnz,
                "shard {s}: {} entries fall outside rows {row_start}..{row_end}",
                m.nnz() - p.nnz
            );
            max_row_nnz = max_row_nnz.max(m.max_row_nnz());
            self.write_shard(&m, s, &p)
                .with_context(|| format!("writing {}", shard_path(&self.dir, s).display()))?;
            nnz += p.nnz;
            parts.push(p);
        }
        let manifest =
            OocManifest { precision: V::precision(), nrows, ncols, nnz, fro, max_row_nnz, policy, parts };
        manifest.save(&self.dir)?;
        Ok(manifest)
    }

    /// Plan chunk boundaries for one shard: whole 512-row windows, closing
    /// a chunk once its payload reaches the target; chunks tile the entire
    /// shard row range (the tail chunk may carry zero entries).
    fn plan_chunks<V: Dataword>(
        &self,
        m: &CsrMatrix<V>,
        p: &RowPartition,
    ) -> Vec<(usize, usize, usize)> {
        let cap = packet_capacity(V::BITS);
        let mut chunks = Vec::new();
        let (mut c0, mut cn) = (p.row_start, 0usize);
        let mut w0 = p.row_start;
        while w0 < p.row_end {
            let w1 = (w0 + TOPK_ROW_CHUNK).min(p.row_end);
            cn += m.indptr[w1] - m.indptr[w0];
            if cn.div_ceil(cap) * LINE_BYTES >= self.chunk_target_bytes || w1 == p.row_end {
                chunks.push((c0, w1, cn));
                (c0, cn) = (w1, 0);
            }
            w0 = w1;
        }
        chunks
    }

    fn write_shard<V: Dataword>(&self, m: &CsrMatrix<V>, s: usize, p: &RowPartition) -> Result<()> {
        let cap = packet_capacity(V::BITS);
        let vb = V::bytes();
        let chunks = self.plan_chunks(m, p);
        let file = std::fs::File::create(shard_path(&self.dir, s))?;
        let mut w = std::io::BufWriter::new(file);
        let mut header = [0u8; HEADER_BYTES];
        header[0..4].copy_from_slice(&MAGIC);
        header[4..8].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
        header[8..12].copy_from_slice(&precision_tag(V::precision()).to_le_bytes());
        header[12..16].copy_from_slice(&(s as u32).to_le_bytes());
        for (i, v) in [m.nrows, m.ncols, p.row_start, p.row_end, p.nnz, chunks.len()]
            .into_iter()
            .enumerate()
        {
            header[16 + i * 8..24 + i * 8].copy_from_slice(&(v as u64).to_le_bytes());
        }
        w.write_all(&header)?;
        // Reserve the chunk table; payload checksums are back-patched after
        // the single streaming pass over the entries.
        w.write_all(&vec![0u8; chunks.len() * TABLE_ENTRY_BYTES])?;
        let mut metas = Vec::with_capacity(chunks.len());
        for &(r0, r1, cn) in &chunks {
            let mut hash = FNV_OFFSET;
            let mut payload = 0u64;
            let mut line = [0u8; LINE_BYTES];
            let mut slot = 0usize;
            for r in r0..r1 {
                for k in m.indptr[r]..m.indptr[r + 1] {
                    let o = slot * (8 + vb);
                    line[o..o + 4].copy_from_slice(&(r as u32).to_le_bytes());
                    line[o + 4..o + 8].copy_from_slice(&m.indices[k].to_le_bytes());
                    let bits = m.vals[k].to_bits();
                    line[o + 8..o + 8 + vb].copy_from_slice(&bits.to_le_bytes()[..vb]);
                    slot += 1;
                    if slot == cap {
                        hash = fnv1a(hash, &line);
                        w.write_all(&line)?;
                        payload += LINE_BYTES as u64;
                        line = [0u8; LINE_BYTES];
                        slot = 0;
                    }
                }
            }
            if slot > 0 {
                hash = fnv1a(hash, &line);
                w.write_all(&line)?;
                payload += LINE_BYTES as u64;
            }
            debug_assert_eq!(payload as usize, cn.div_ceil(cap) * LINE_BYTES);
            metas.push((r0 as u64, r1 as u64, cn as u64, payload, hash));
        }
        w.seek(SeekFrom::Start(HEADER_BYTES as u64))?;
        for (r0, r1, cn, payload, hash) in metas {
            for v in [r0, r1, cn, payload, hash] {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        w.flush()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Reader: OocMatrix + double-buffered OocShardSource
// ---------------------------------------------------------------------------

/// One chunk's location inside a shard file.
#[derive(Clone, Debug)]
struct ChunkMeta {
    row_start: usize,
    row_end: usize,
    nnz: usize,
    payload_bytes: usize,
    checksum: u64,
    /// Absolute file offset of the first payload byte.
    file_offset: u64,
    /// Global packet-line index of the chunk's first line (error messages).
    first_line: usize,
}

#[derive(Debug)]
struct ShardMeta {
    path: PathBuf,
    chunks: Vec<ChunkMeta>,
}

/// A decoded chunk: the raw packet lines plus column-index/value arrays
/// unpacked for the SpMV gather. Buffers are pooled by the owning
/// [`OocMatrix`] — warm sweeps allocate nothing.
pub struct ChunkBuf<V: Dataword> {
    /// Pool identity for the `race-check` lease tracker: handing one
    /// buffer to two consumers, or recycling it twice, panics under the
    /// feature. Always 0 (and unused) in default builds.
    lease_id: u64,
    raw: Vec<u8>,
    /// Absolute row index per entry (ascending; row-major CSR order).
    pub(crate) rows: Vec<u32>,
    /// Column index per entry.
    pub(crate) cols: Vec<u32>,
    /// Value per entry (raw bits restored, no re-quantization).
    pub(crate) vals: Vec<V>,
    /// First row this chunk covers (inclusive).
    pub(crate) row_start: usize,
    /// Last row this chunk covers (exclusive).
    pub(crate) row_end: usize,
}

impl<V: Dataword> ChunkBuf<V> {
    fn with_capacity(max_payload: usize, max_nnz: usize) -> Self {
        Self {
            lease_id: crate::util::race::new_lease_id(),
            raw: Vec::with_capacity(max_payload),
            rows: Vec::with_capacity(max_nnz),
            cols: Vec::with_capacity(max_nnz),
            vals: Vec::with_capacity(max_nnz),
            row_start: 0,
            row_end: 0,
        }
    }

    fn capacity_bytes(&self) -> usize {
        self.raw.capacity()
            + self.rows.capacity() * 4
            + self.cols.capacity() * 4
            + self.vals.capacity() * V::bytes()
    }

    /// Decoded entries in this chunk.
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    /// True when the chunk covers rows but carries no entries.
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Row range `[row_start, row_end)` this chunk covers.
    pub fn row_range(&self) -> (usize, usize) {
        (self.row_start, self.row_end)
    }

    /// Absolute row index per entry.
    pub fn rows(&self) -> &[u32] {
        &self.rows
    }

    /// Column index per entry.
    pub fn cols(&self) -> &[u32] {
        &self.cols
    }

    /// Stored value per entry.
    pub fn vals(&self) -> &[V] {
        &self.vals
    }
}

enum SlotState<V: Dataword> {
    Pending,
    Ready(ChunkBuf<V>),
    Failed(String),
    Taken,
}

struct PrefetchSlot<V: Dataword> {
    state: Mutex<SlotState<V>>,
    cv: Condvar,
}

/// A file-backed matrix: shard/chunk metadata, a dedicated I/O thread pool,
/// and a preallocated pool of chunk buffers (two per shard — one being
/// consumed, one being prefetched). Resident footprint is O(chunk table) +
/// O(buffers), never O(nnz).
pub struct OocMatrix<V: Dataword> {
    dir: PathBuf,
    nrows: usize,
    ncols: usize,
    nnz: usize,
    fro: f64,
    max_row_nnz: usize,
    policy: PartitionPolicy,
    parts: Vec<RowPartition>,
    shards: Vec<ShardMeta>,
    /// Dedicated I/O workers. Never the CU compute pool: `ThreadPool`
    /// scopes assert against re-entry, and compute workers must be able to
    /// enqueue prefetches without waiting on themselves.
    io: ThreadPool,
    buffers: Mutex<Vec<ChunkBuf<V>>>,
    buffer_bytes: usize,
    io_bytes: AtomicU64,
    chunks_read: AtomicU64,
    stalls: AtomicU64,
}

impl<V: Dataword> OocMatrix<V> {
    /// Open an OOC directory for streaming. Validates the manifest, every
    /// shard header, chunk-table geometry (alignment, tiling, nnz sums) and
    /// file lengths — a truncated file is rejected here with the packet
    /// line where data stops. Chunk *contents* are checksum-verified on
    /// every read (see [`OocMatrix::verify`] for an eager full pass).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Arc<Self>> {
        let dir = dir.into();
        let man = OocManifest::load(&dir)?;
        ensure!(
            man.precision == V::precision(),
            "{}: precision mismatch: file stores {}, engine requested {}",
            dir.join(MANIFEST_NAME).display(),
            man.precision.name(),
            V::precision().name()
        );
        let mut shards = Vec::with_capacity(man.parts.len());
        for (s, p) in man.parts.iter().enumerate() {
            shards.push(Self::open_shard(&dir, s, p, &man)?);
        }
        let max_nnz =
            shards.iter().flat_map(|s| s.chunks.iter()).map(|c| c.nnz).max().unwrap_or(0);
        let max_payload =
            shards.iter().flat_map(|s| s.chunks.iter()).map(|c| c.payload_bytes).max().unwrap_or(0);
        // Two buffers per shard: one consumed by the sweep, one filled by
        // the prefetcher. Preallocated once; warm sweeps allocate nothing.
        let nbufs = 2 * man.parts.len().max(1);
        let buffers: Vec<ChunkBuf<V>> =
            (0..nbufs).map(|_| ChunkBuf::with_capacity(max_payload, max_nnz)).collect();
        let buffer_bytes = buffers.iter().map(|b| b.capacity_bytes()).sum::<usize>()
            + shards.iter().map(|s| s.chunks.len() * TABLE_ENTRY_BYTES).sum::<usize>();
        let io = ThreadPool::new(man.parts.len().clamp(1, 4));
        Ok(Arc::new(Self {
            dir,
            nrows: man.nrows,
            ncols: man.ncols,
            nnz: man.nnz,
            fro: man.fro,
            max_row_nnz: man.max_row_nnz,
            policy: man.policy,
            parts: man.parts,
            shards,
            io,
            buffers: Mutex::new(buffers),
            buffer_bytes,
            io_bytes: AtomicU64::new(0),
            chunks_read: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
        }))
    }

    fn open_shard(dir: &Path, s: usize, p: &RowPartition, man: &OocManifest) -> Result<ShardMeta> {
        let path = shard_path(dir, s);
        let mut file = std::fs::File::open(&path)
            .with_context(|| format!("opening OOC shard {}", path.display()))?;
        let actual_len = file.metadata()?.len();
        let name = path.display().to_string();
        ensure!(
            actual_len >= HEADER_BYTES as u64,
            "{name}: truncated header ({actual_len} of {HEADER_BYTES} bytes)"
        );
        let mut header = [0u8; HEADER_BYTES];
        file.read_exact(&mut header)?;
        ensure!(header[0..4] == MAGIC, "{name}: bad magic {:02x?}", &header[0..4]);
        let version = get_u32(&header, 4);
        ensure!(
            version == FORMAT_VERSION,
            "{name}: unsupported version {version} (this build reads {FORMAT_VERSION})"
        );
        let tag = get_u32(&header, 8);
        let stored = tag_precision(tag)
            .with_context(|| format!("{name}: unknown precision tag {tag}"))?;
        ensure!(
            stored == V::precision(),
            "{name}: precision mismatch: file stores {}, engine requested {}",
            stored.name(),
            V::precision().name()
        );
        ensure!(get_u32(&header, 12) as usize == s, "{name}: shard index mismatch");
        let (nrows, ncols) = (get_u64(&header, 16) as usize, get_u64(&header, 24) as usize);
        let (r0, r1) = (get_u64(&header, 32) as usize, get_u64(&header, 40) as usize);
        let (snnz, nchunks) = (get_u64(&header, 48) as usize, get_u64(&header, 56) as usize);
        ensure!(
            (nrows, ncols) == (man.nrows, man.ncols)
                && (r0, r1, snnz) == (p.row_start, p.row_end, p.nnz),
            "{name}: header disagrees with manifest (rows {r0}..{r1} nnz {snnz} \
             vs {}..{} nnz {})",
            p.row_start,
            p.row_end,
            p.nnz
        );
        let table_bytes = nchunks * TABLE_ENTRY_BYTES;
        ensure!(
            actual_len >= (HEADER_BYTES + table_bytes) as u64,
            "{name}: truncated chunk table ({actual_len} bytes, need {})",
            HEADER_BYTES + table_bytes
        );
        let mut table = vec![0u8; table_bytes];
        file.read_exact(&mut table)?;
        let cap = packet_capacity(V::BITS);
        let mut chunks = Vec::with_capacity(nchunks);
        let mut offset = (HEADER_BYTES + table_bytes) as u64;
        let mut first_line = 0usize;
        let (mut cursor_row, mut total_nnz) = (p.row_start, 0usize);
        for c in 0..nchunks {
            let e = &table[c * TABLE_ENTRY_BYTES..(c + 1) * TABLE_ENTRY_BYTES];
            let meta = ChunkMeta {
                row_start: get_u64(e, 0) as usize,
                row_end: get_u64(e, 8) as usize,
                nnz: get_u64(e, 16) as usize,
                payload_bytes: get_u64(e, 24) as usize,
                checksum: get_u64(e, 32),
                file_offset: offset,
                first_line,
            };
            ensure!(
                meta.row_start == cursor_row && meta.row_end > meta.row_start
                    && meta.row_end <= p.row_end,
                "{name}: chunk {c} rows {}..{} do not tile the shard (expected start {cursor_row})",
                meta.row_start,
                meta.row_end
            );
            ensure!(
                (meta.row_start - p.row_start) % TOPK_ROW_CHUNK == 0,
                "{name}: chunk {c} starts at row {} — not aligned to the {TOPK_ROW_CHUNK}-row \
                 kernel window",
                meta.row_start
            );
            ensure!(
                meta.payload_bytes == meta.nnz.div_ceil(cap) * LINE_BYTES,
                "{name}: chunk {c} payload {} bytes inconsistent with nnz {} at {} \
                 entries/line",
                meta.payload_bytes,
                meta.nnz,
                cap
            );
            cursor_row = meta.row_end;
            total_nnz += meta.nnz;
            offset += meta.payload_bytes as u64;
            first_line += meta.payload_bytes / LINE_BYTES;
            chunks.push(meta);
        }
        ensure!(
            nchunks == 0 || cursor_row == p.row_end,
            "{name}: chunks end at row {cursor_row}, shard ends at {}",
            p.row_end
        );
        ensure!(
            total_nnz == p.nnz,
            "{name}: chunk nnz sum {total_nnz} != shard nnz {}",
            p.nnz
        );
        ensure!(
            actual_len == offset,
            "{name}: truncated at packet line {} (expected {} payload lines / {} bytes, \
             file holds {} bytes)",
            (actual_len.saturating_sub((HEADER_BYTES + table_bytes) as u64) / LINE_BYTES as u64),
            first_line,
            offset,
            actual_len
        );
        Ok(ShardMeta { path, chunks })
    }

    /// Matrix rows.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Matrix columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Frobenius norm recorded at write time (eigenvalue rescale factor).
    pub fn fro(&self) -> f64 {
        self.fro
    }

    /// Maximum row length recorded at write time.
    pub fn max_row_nnz(&self) -> usize {
        self.max_row_nnz
    }

    /// Partition policy the shard files were written with.
    pub fn policy(&self) -> PartitionPolicy {
        self.policy
    }

    /// CU shard partition (identical to the resident engine's).
    pub fn parts(&self) -> &[RowPartition] {
        &self.parts
    }

    /// Directory this matrix streams from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Total chunks across all shards.
    pub fn chunk_count(&self) -> usize {
        self.shards.iter().map(|s| s.chunks.len()).sum()
    }

    /// Chunks in one shard (how many [`OocShardSource::next_chunk`] calls
    /// a full replay of that shard takes).
    pub fn shard_chunks(&self, shard: usize) -> usize {
        self.shards[shard].chunks.len()
    }

    /// Resident bytes this matrix pins: the preallocated chunk buffers plus
    /// chunk tables — O(buffer), never O(nnz). What the registry charges.
    pub fn buffer_bytes(&self) -> usize {
        self.buffer_bytes
    }

    /// Payload bytes read from storage so far (whole 64-byte lines).
    pub fn io_bytes_read(&self) -> u64 {
        self.io_bytes.load(Ordering::Relaxed)
    }

    /// Chunks read from storage so far.
    pub fn chunks_read(&self) -> u64 {
        self.chunks_read.load(Ordering::Relaxed)
    }

    /// Times a sweep blocked waiting for a prefetch that was still in
    /// flight. Strictly fewer stalls than chunks read ⇒ I/O overlapped
    /// compute.
    pub fn prefetch_stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Read + checksum + decode one chunk into a pooled buffer. Runs on the
    /// I/O pool for prefetches and inline for [`OocMatrix::verify`]. The
    /// buffer goes back to the pool even when the read fails (a corrupt or
    /// truncated chunk must not shrink the pool).
    fn read_chunk(&self, shard: usize, chunk: usize) -> Result<ChunkBuf<V>> {
        let mut buf = self
            .buffers
            .lock()
            .expect("ooc buffer pool poisoned")
            .pop()
            // The pool is sized for steady state (2 per shard); a caller
            // holding guards across sweeps just grows it transiently.
            .unwrap_or_else(|| ChunkBuf::with_capacity(0, 0));
        // Track the handout: under `race-check` a second lease of this
        // buffer before its release panics (double handout).
        crate::util::race::lease(buf.lease_id);
        match self.read_chunk_into(shard, chunk, &mut buf) {
            Ok(()) => Ok(buf),
            Err(e) => {
                self.recycle(buf);
                Err(e)
            }
        }
    }

    fn read_chunk_into(&self, shard: usize, chunk: usize, buf: &mut ChunkBuf<V>) -> Result<()> {
        let smeta = &self.shards[shard];
        let meta = &smeta.chunks[chunk];
        let name = smeta.path.display();
        let mut file = std::fs::File::open(&smeta.path)
            .with_context(|| format!("opening OOC shard {name}"))?;
        file.seek(SeekFrom::Start(meta.file_offset))?;
        buf.raw.clear();
        buf.raw.resize(meta.payload_bytes, 0);
        file.read_exact(&mut buf.raw).with_context(|| {
            format!(
                "{name}: short read in chunk {chunk} (packet lines {}..{})",
                meta.first_line,
                meta.first_line + meta.payload_bytes / LINE_BYTES
            )
        })?;
        let computed = fnv1a(FNV_OFFSET, &buf.raw);
        ensure!(
            computed == meta.checksum,
            "{name}: checksum mismatch in chunk {chunk} (rows {}..{}, packet lines {}..{}): \
             stored {:#018x}, computed {computed:#018x}",
            meta.row_start,
            meta.row_end,
            meta.first_line,
            meta.first_line + meta.payload_bytes / LINE_BYTES,
            meta.checksum
        );
        let cap = packet_capacity(V::BITS);
        let vb = V::bytes();
        buf.rows.clear();
        buf.cols.clear();
        buf.vals.clear();
        let mut remaining = meta.nnz;
        for line in buf.raw.chunks_exact(LINE_BYTES) {
            let take = cap.min(remaining);
            for i in 0..take {
                let o = i * (8 + vb);
                buf.rows.push(get_u32(line, o));
                buf.cols.push(get_u32(line, o + 4));
                let bits = if vb == 2 {
                    u16::from_le_bytes([line[o + 8], line[o + 9]]) as u32
                } else {
                    get_u32(line, o + 8)
                };
                buf.vals.push(V::from_bits(bits));
            }
            remaining -= take;
            if remaining == 0 {
                break;
            }
        }
        buf.row_start = meta.row_start;
        buf.row_end = meta.row_end;
        self.io_bytes.fetch_add(meta.payload_bytes as u64, Ordering::Relaxed);
        self.chunks_read.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn recycle(&self, buf: ChunkBuf<V>) {
        // Track the return: under `race-check` recycling a buffer that is
        // not out panics (double recycle).
        crate::util::race::release(buf.lease_id);
        self.buffers.lock().expect("ooc buffer pool poisoned").push(buf);
    }

    /// Eagerly read every chunk of every shard, verifying checksums —
    /// the `Result`-returning integrity pass (sweeps themselves panic on a
    /// corrupt chunk, since kernels cannot return errors mid-fork).
    pub fn verify(&self) -> Result<()> {
        for s in 0..self.shards.len() {
            for c in 0..self.shards[s].chunks.len() {
                let buf = self.read_chunk(s, c)?;
                self.recycle(buf);
            }
        }
        Ok(())
    }

    /// Stream every entry in global CSR order (shard-major, row-major) —
    /// the exact accumulation order `query::column_sums`/`row_l1_norms` use
    /// on the resident matrix, so f64 reductions match bitwise.
    pub fn for_each_entry(self: &Arc<Self>, mut f: impl FnMut(u32, u32, V)) {
        for s in 0..self.parts.len() {
            let mut src = OocShardSource::new(self.clone(), s);
            while let Some(chunk) = src.next_chunk() {
                for e in 0..chunk.len() {
                    f(chunk.rows[e], chunk.cols[e], chunk.vals[e]);
                }
            }
        }
    }
}

/// Guard over a decoded chunk; returns the buffer to the matrix's pool on
/// drop so warm sweeps never allocate.
pub struct ChunkGuard<V: Dataword> {
    matrix: Arc<OocMatrix<V>>,
    buf: Option<ChunkBuf<V>>,
}

impl<V: Dataword> std::ops::Deref for ChunkGuard<V> {
    type Target = ChunkBuf<V>;
    fn deref(&self) -> &ChunkBuf<V> {
        self.buf.as_ref().expect("chunk buffer present until drop")
    }
}

impl<V: Dataword> Drop for ChunkGuard<V> {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.matrix.recycle(buf);
        }
    }
}

/// Double-buffered replay of one shard's chunk sequence: the next chunk is
/// always being read+decoded on the I/O pool while the caller consumes the
/// current one. One sweep = one source per shard.
pub struct OocShardSource<V: Dataword> {
    matrix: Arc<OocMatrix<V>>,
    shard: usize,
    next: usize,
    inflight: Option<Arc<PrefetchSlot<V>>>,
}

impl<V: Dataword> OocShardSource<V> {
    /// Start streaming `shard`, immediately issuing the first prefetch.
    pub fn new(matrix: Arc<OocMatrix<V>>, shard: usize) -> Self {
        let inflight =
            (!matrix.shards[shard].chunks.is_empty()).then(|| Self::issue(&matrix, shard, 0));
        Self { matrix, shard, next: 0, inflight }
    }

    fn issue(matrix: &Arc<OocMatrix<V>>, shard: usize, chunk: usize) -> Arc<PrefetchSlot<V>> {
        let slot =
            Arc::new(PrefetchSlot { state: Mutex::new(SlotState::Pending), cv: Condvar::new() });
        let (m, s) = (matrix.clone(), slot.clone());
        matrix.io.execute(move || {
            let outcome = match m.read_chunk(shard, chunk) {
                Ok(buf) => SlotState::Ready(buf),
                Err(e) => SlotState::Failed(format!("{e:#}")),
            };
            *s.state.lock().expect("prefetch slot poisoned") = outcome;
            s.cv.notify_all();
        });
        slot
    }

    /// Hand out the next chunk, blocking only if the prefetch has not
    /// landed yet (counted in [`OocMatrix::prefetch_stalls`]). Issues the
    /// following chunk's read *before* blocking, so the second buffer fills
    /// while this one is consumed.
    ///
    /// Panics if the underlying read fails (corrupt chunk mid-sweep);
    /// integrity-checking callers use [`OocMatrix::verify`] instead.
    pub fn next_chunk(&mut self) -> Option<ChunkGuard<V>> {
        let total = self.matrix.shards[self.shard].chunks.len();
        if self.next >= total {
            return None;
        }
        let slot = self.inflight.take().expect("prefetch issued for current chunk");
        if self.next + 1 < total {
            self.inflight = Some(Self::issue(&self.matrix, self.shard, self.next + 1));
        }
        self.next += 1;
        let mut st = slot.state.lock().expect("prefetch slot poisoned");
        if matches!(*st, SlotState::Pending) {
            self.matrix.stalls.fetch_add(1, Ordering::Relaxed);
            while matches!(*st, SlotState::Pending) {
                st = slot.cv.wait(st).expect("prefetch slot poisoned");
            }
        }
        match std::mem::replace(&mut *st, SlotState::Taken) {
            SlotState::Ready(buf) => {
                drop(st);
                Some(ChunkGuard { matrix: self.matrix.clone(), buf: Some(buf) })
            }
            SlotState::Failed(msg) => panic!("out-of-core chunk read failed: {msg}"),
            SlotState::Pending | SlotState::Taken => unreachable!("slot settled above"),
        }
    }
}

impl<V: Dataword> Drop for OocShardSource<V> {
    /// Reclaim an abandoned prefetch: a source dropped mid-stream (partial
    /// sweep, early exit, panic unwind) still has a read in flight whose
    /// buffer would otherwise never return to the pool — each such drop
    /// used to shrink the preallocated pool permanently. Waits for the
    /// I/O job to settle (it holds the buffer until then) and recycles.
    fn drop(&mut self) {
        if let Some(slot) = self.inflight.take() {
            let mut st = slot.state.lock().unwrap_or_else(|e| e.into_inner());
            while matches!(*st, SlotState::Pending) {
                st = slot.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            if let SlotState::Ready(buf) = std::mem::replace(&mut *st, SlotState::Taken) {
                self.matrix.recycle(buf);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q1_15, Q1_31};
    use crate::graphs;

    fn cleanup(dir: &Path) {
        let _ = std::fs::remove_dir_all(dir);
    }

    fn write_sample<V: Dataword>(
        dir: &Path,
        cus: usize,
        chunk_target: usize,
    ) -> (CsrMatrix<V>, OocManifest) {
        let m: CsrMatrix<V> = graphs::erdos_renyi(200, 1400, 7).to_csr().to_precision::<V>();
        let man = PacketFileWriter::new(dir)
            .chunk_target_bytes(chunk_target)
            .write_csr(&m, 2.5, cus, PartitionPolicy::BalancedNnz)
            .expect("write");
        (m, man)
    }

    fn csr_triplets<V: Dataword>(m: &CsrMatrix<V>) -> Vec<(u32, u32, u32)> {
        let mut out = Vec::with_capacity(m.nnz());
        for r in 0..m.nrows {
            for k in m.indptr[r]..m.indptr[r + 1] {
                out.push((r as u32, m.indices[k], m.vals[k].to_bits()));
            }
        }
        out
    }

    fn roundtrip_bitwise<V: Dataword>() {
        let dir = scratch_dir("roundtrip");
        let (m, man) = write_sample::<V>(&dir, 3, 256);
        assert_eq!(man.parts, partition_rows_balanced(&m, 3, PartitionPolicy::BalancedNnz));
        let ooc = OocMatrix::<V>::open(&dir).expect("open");
        assert_eq!((ooc.nrows(), ooc.ncols(), ooc.nnz()), (m.nrows, m.ncols, m.nnz()));
        assert_eq!(ooc.fro(), 2.5);
        assert_eq!(ooc.max_row_nnz(), m.max_row_nnz());
        ooc.verify().expect("verify");
        let mut got = Vec::new();
        ooc.for_each_entry(|r, c, v| got.push((r, c, v.to_bits())));
        assert_eq!(got, csr_triplets(&m), "{}: stream order / raw bits differ", V::NAME);
        // Telemetry: every chunk read at least once, all payload counted.
        assert!(ooc.chunks_read() >= ooc.chunk_count() as u64);
        assert!(ooc.io_bytes_read() > 0);
        assert!(ooc.prefetch_stalls() <= ooc.chunks_read());
        cleanup(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "large random fixture; file I/O is covered by the small tiling tests")]
    fn roundtrip_is_bitwise_for_all_precisions() {
        roundtrip_bitwise::<f32>();
        roundtrip_bitwise::<Q1_31>();
        roundtrip_bitwise::<crate::fixed::Q2_30>();
        roundtrip_bitwise::<Q1_15>();
    }

    #[test]
    #[cfg_attr(miri, ignore = "large random fixture; pool accounting is covered by the midstream-drop test")]
    fn buffers_return_to_pool_and_stay_bounded() {
        let dir = scratch_dir("pool");
        let (_m, man) = write_sample::<f32>(&dir, 3, 128);
        let ooc = OocMatrix::<f32>::open(&dir).expect("open");
        let before = ooc.buffers.lock().unwrap().len();
        assert_eq!(before, 2 * man.parts.len());
        for _ in 0..3 {
            ooc.for_each_entry(|_, _, _| {});
        }
        assert_eq!(ooc.buffers.lock().unwrap().len(), before, "buffers leaked or grew");
        assert!(ooc.buffer_bytes() > 0);
        cleanup(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "large random fixture; no unsafe on the rejection path")]
    fn wrong_precision_is_rejected() {
        let dir = scratch_dir("precision");
        let (_m, _man) = write_sample::<Q1_31>(&dir, 2, 512);
        let err = match OocMatrix::<Q1_15>::open(&dir) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("wrong-precision open must fail"),
        };
        assert!(err.contains("precision mismatch"), "{err}");
        assert!(err.contains("q1.31") && err.contains("q1.15"), "{err}");
        cleanup(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "large random fixture; no unsafe on the error path")]
    fn corrupted_chunk_names_chunk_and_lines() {
        let dir = scratch_dir("corrupt");
        let (_m, _man) = write_sample::<f32>(&dir, 1, 256);
        // Flip one payload byte in the last chunk of shard 0.
        let path = shard_path(&dir, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 17;
        bytes[last] ^= 0xA5;
        std::fs::write(&path, bytes).unwrap();
        let ooc = OocMatrix::<f32>::open(&dir).expect("open succeeds; payload unread");
        let err = format!("{:#}", ooc.verify().expect_err("corrupt payload must fail verify"));
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("chunk") && err.contains("packet lines"), "{err}");
        cleanup(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "large random fixture; no unsafe on the error path")]
    fn truncated_file_is_rejected_with_line_number() {
        let dir = scratch_dir("truncate");
        let (_m, _man) = write_sample::<f32>(&dir, 1, 256);
        let path = shard_path(&dir, 0);
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - LINE_BYTES as u64).unwrap();
        let err = match OocMatrix::<f32>::open(&dir) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("truncated file must be rejected at open"),
        };
        assert!(err.contains("truncated at packet line"), "{err}");
        cleanup(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "large random fixture; no unsafe on the parse path")]
    fn manifest_errors_are_line_numbered() {
        let dir = scratch_dir("manifest");
        let (_m, _man) = write_sample::<f32>(&dir, 2, 512);
        let path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&path).unwrap();
        // `nrows = ...` is the manifest's 4th line.
        let bad = text
            .lines()
            .map(|l| if l.starts_with("nrows") { "nrows = banana".to_string() } else { l.into() })
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(&path, bad).unwrap();
        let err = format!("{:#}", OocManifest::load(&dir).expect_err("bad manifest"));
        assert!(err.contains("manifest.tkm:4"), "{err}");
        assert!(err.contains("nrows"), "{err}");
        cleanup(&dir);
    }

    #[test]
    fn chunks_tile_shards_including_empty_windows() {
        // Entries only in the first rows of a 1100-row matrix: with a tiny
        // chunk target the trailing 512-row windows become a zero-entry
        // chunk that still covers its rows (the windowed kernels need every
        // row range present even where the matrix is locally empty).
        let dir = scratch_dir("tiling");
        let mut coo: CooMatrix = CooMatrix::new(1100, 1100);
        for i in 0..10 {
            coo.push(i, (i + 1) % 10, 0.25 + i as f32 * 0.01);
            coo.push((i + 1) % 10, i, 0.25 + i as f32 * 0.01);
        }
        coo.canonicalize();
        let m = coo.to_csr();
        PacketFileWriter::new(&dir)
            .chunk_target_bytes(64)
            .write_csr(&m, 1.0, 1, PartitionPolicy::EqualRows)
            .expect("write");
        let ooc = OocMatrix::<f32>::open(&dir).expect("open");
        let shard = &ooc.shards[0];
        assert!(shard.chunks.len() >= 2, "expected multiple chunks, got {}", shard.chunks.len());
        assert_eq!(shard.chunks.first().unwrap().row_start, 0);
        assert_eq!(shard.chunks.last().unwrap().row_end, 1100);
        for w in shard.chunks.windows(2) {
            assert_eq!(w[0].row_end, w[1].row_start, "chunks must tile");
        }
        assert!(shard.chunks.iter().any(|c| c.nnz == 0), "zero-entry tail chunk expected");
        let mut seen = 0usize;
        ooc.for_each_entry(|_, _, _| seen += 1);
        assert_eq!(seen, m.nnz());
        ooc.verify().expect("verify");
        cleanup(&dir);
    }

    #[test]
    fn empty_tail_shard_streams_nothing() {
        // More CUs than occupied rows: tail shards are empty ranges.
        let dir = scratch_dir("empty-shard");
        let mut coo: CooMatrix = CooMatrix::new(6, 6);
        for i in 0..6 {
            coo.push(i, i, 0.5);
        }
        coo.canonicalize();
        let m = coo.to_csr();
        PacketFileWriter::new(&dir)
            .chunk_target_bytes(64)
            .write_csr(&m, 1.0, 8, PartitionPolicy::EqualRows)
            .expect("write");
        let ooc = OocMatrix::<f32>::open(&dir).expect("open");
        assert_eq!(ooc.parts().len(), 8);
        let mut seen = 0usize;
        ooc.for_each_entry(|r, c, v| {
            assert_eq!(r, c);
            assert_eq!(v, 0.5);
            seen += 1;
        });
        assert_eq!(seen, 6);
        cleanup(&dir);
    }

    #[test]
    fn dropped_midstream_source_recycles_inflight_prefetch() {
        // Regression: a source dropped mid-stream still has a prefetch in
        // flight; before `OocShardSource`'s `Drop` that read's buffer never
        // returned to the pool, so every abandoned partial sweep shrank the
        // preallocated pool permanently. Needs a >512-row fixture: chunk
        // boundaries align to 512-row windows, so the 200-row sample above
        // is a single chunk per shard and never has a second read in
        // flight.
        let dir = scratch_dir("midstream-drop");
        let mut coo: CooMatrix = CooMatrix::new(1600, 1600);
        for r in [0usize, 1, 600, 601, 1200, 1201] {
            let c = (r + 7) % 1600;
            coo.push(r, c, 0.5 + r as f32 * 1e-3);
            coo.push(c, r, 0.5 + r as f32 * 1e-3);
        }
        coo.canonicalize();
        let m = coo.to_csr();
        PacketFileWriter::new(&dir)
            .chunk_target_bytes(64)
            .write_csr(&m, 1.0, 1, PartitionPolicy::EqualRows)
            .expect("write");
        let ooc = OocMatrix::<f32>::open(&dir).expect("open");
        let chunks = ooc.shards[0].chunks.len();
        assert!(chunks >= 2, "fixture must span multiple chunks, got {chunks}");
        let before = ooc.buffers.lock().unwrap().len();
        // Abandon the stream at every possible depth, including before the
        // first chunk is taken (the constructor has already issued a read).
        for consumed in 0..chunks {
            let mut src = OocShardSource::new(ooc.clone(), 0);
            for _ in 0..consumed {
                let _ = src.next_chunk().expect("chunk within bounds");
            }
            drop(src);
            let now = ooc.buffers.lock().unwrap().len();
            assert_eq!(now, before, "pool shrank after dropping at depth {consumed}");
        }
        // The matrix still streams completely after all the partial sweeps.
        let mut seen = 0usize;
        ooc.for_each_entry(|_, _, _| seen += 1);
        assert_eq!(seen, m.nnz());
        assert_eq!(ooc.buffers.lock().unwrap().len(), before);
        cleanup(&dir);
    }
}

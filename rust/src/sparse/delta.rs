//! Sparse matrix deltas — the update currency of evolving-graph serving.
//!
//! The spectral workloads the paper targets run on graphs that *change*
//! between queries (the multi-GPU follow-up, arXiv:2201.07498, and the
//! SSD-scale FlashEigen, arXiv:1602.01421, both re-solve mutating
//! matrices). A [`CooDelta`] is a batch of edge **insertions**, **value
//! changes**, and **deletions** against a registered matrix; applying it
//! to a canonical [`crate::sparse::CooMatrix`] or
//! [`crate::sparse::CsrMatrix`] is a two-pointer splice — `O(nnz + d)`
//! with no re-sort of the untouched entries — returning a [`DeltaApply`]
//! report (dirty rows, op counts, `||delta||_F`) that drives the
//! registry's incremental shard re-prep and warm-start retention.

use crate::fixed::Dataword;

/// One delta operation at a coordinate.
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum DeltaOp {
    /// Set the entry to this value, inserting it if absent.
    Upsert(f32),
    /// Remove the entry (a no-op if absent).
    Delete,
}

/// A batch of coordinate-level edits against an `nrows x ncols` matrix.
///
/// Entries are applied **last-writer-wins** per coordinate after
/// [`CooDelta::canonicalize`] (which the appliers call implicitly through
/// the sorted invariant — build deltas with the push helpers and
/// canonicalize once, or rely on the registry to do it). Values are in the
/// matrix's **original** (pre-normalization) scale.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CooDelta {
    /// Number of rows of the target matrix (must match at apply time).
    pub nrows: usize,
    /// Number of columns of the target matrix.
    pub ncols: usize,
    /// `(row, col, op)` edits. Crate-private so every write goes through
    /// the push helpers: direct pushes would bypass both the bounds check
    /// and the sortedness tracker, letting a delta that claims to be
    /// canonical corrupt a canonical matrix on splice.
    pub(crate) entries: Vec<(u32, u32, DeltaOp)>,
    sorted: bool,
}

impl CooDelta {
    /// Empty delta against an `nrows x ncols` matrix.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, entries: Vec::new(), sorted: true }
    }

    /// Number of edits.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// The queued `(row, col, op)` edits, in push order until
    /// [`CooDelta::canonicalize`], sorted and unique after.
    pub fn entries(&self) -> &[(u32, u32, DeltaOp)] {
        &self.entries
    }

    /// True when the delta carries no edits.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Queue `M[r, c] = v` (insert or value change).
    pub fn upsert(&mut self, r: usize, c: usize, v: f32) {
        self.push(r, c, DeltaOp::Upsert(v));
    }

    /// Queue removal of `M[r, c]`.
    pub fn delete(&mut self, r: usize, c: usize) {
        self.push(r, c, DeltaOp::Delete);
    }

    /// Queue `M[r, c] = M[c, r] = v` — the symmetric-operator convenience
    /// (the Lanczos phase requires symmetric matrices, so most callers
    /// edit both triangles together).
    pub fn upsert_sym(&mut self, r: usize, c: usize, v: f32) {
        self.upsert(r, c, v);
        if r != c {
            self.upsert(c, r, v);
        }
    }

    /// Queue symmetric removal of `M[r, c]` and `M[c, r]`.
    pub fn delete_sym(&mut self, r: usize, c: usize) {
        self.delete(r, c);
        if r != c {
            self.delete(c, r);
        }
    }

    fn push(&mut self, r: usize, c: usize, op: DeltaOp) {
        assert!(r < self.nrows && c < self.ncols, "delta coordinate ({r},{c}) out of bounds");
        if self.sorted {
            if let Some(&(lr, lc, _)) = self.entries.last() {
                self.sorted = (lr, lc) < (r as u32, c as u32);
            }
        }
        self.entries.push((r as u32, c as u32, op));
    }

    /// Sort by `(row, col)` and keep the **last** op per coordinate
    /// (last-writer-wins). Appliers require canonical deltas; this is
    /// idempotent and `O(d log d)`.
    pub fn canonicalize(&mut self) {
        if self.sorted {
            return;
        }
        // Stable sort preserves queue order among equal coordinates, so
        // "last pushed" stays last.
        self.entries.sort_by_key(|&(r, c, _)| (r, c));
        let mut out: Vec<(u32, u32, DeltaOp)> = Vec::with_capacity(self.entries.len());
        for &e in &self.entries {
            match out.last_mut() {
                Some(last) if (last.0, last.1) == (e.0, e.1) => *last = e,
                _ => out.push(e),
            }
        }
        self.entries = out;
        self.sorted = true;
    }

    /// True once entries are sorted and unique per coordinate.
    pub fn is_canonical(&self) -> bool {
        self.sorted
    }

    /// Check that every off-diagonal edit has its mirror with an equal op
    /// (value equality is exact): the cheap `O(d log d)` stand-in for the
    /// registry's full symmetry check on updates. Requires canonical form.
    pub fn is_symmetric(&self) -> bool {
        debug_assert!(self.sorted, "canonicalize before is_symmetric");
        self.entries.iter().all(|&(r, c, op)| {
            r == c
                || self
                    .entries
                    .binary_search_by_key(&(c, r), |&(er, ec, _)| (er, ec))
                    .map(|i| self.entries[i].2 == op)
                    .unwrap_or(false)
        })
    }
}

/// Report of one delta application: what changed, where, and by how much.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeltaApply {
    /// Rows holding at least one effective edit (sorted, deduplicated) —
    /// the dirty set driving incremental shard re-prep.
    pub dirty_rows: Vec<u32>,
    /// Entries inserted (upsert on an absent coordinate).
    pub inserted: usize,
    /// Entries whose value changed (upsert on a present coordinate with a
    /// different value).
    pub changed: usize,
    /// Entries removed.
    pub deleted: usize,
    /// Edits with no effect (upsert of the identical value, delete of an
    /// absent coordinate).
    pub noops: usize,
    /// `sum((new - old)^2)` over every effective edit, in the original
    /// value scale: `sqrt` of this over `||M||_F` is the relative
    /// perturbation the warm-start retention guard compares against.
    pub delta_fro_sq: f64,
}

impl DeltaApply {
    /// `||delta||_F` — Frobenius norm of the change.
    pub fn delta_fro(&self) -> f64 {
        self.delta_fro_sq.sqrt()
    }

    /// Effective edits (everything but no-ops).
    pub fn effective(&self) -> usize {
        self.inserted + self.changed + self.deleted
    }

    fn mark_dirty(&mut self, r: u32) {
        if self.dirty_rows.last() != Some(&r) {
            self.dirty_rows.push(r);
        }
    }

    /// Record one edit outcome. `old`/`new` are `None` when absent.
    pub(crate) fn record(&mut self, r: u32, old: Option<f32>, new: Option<f32>) -> bool {
        match (old, new) {
            (None, Some(v)) => {
                self.inserted += 1;
                self.delta_fro_sq += (v as f64) * (v as f64);
            }
            (Some(o), Some(v)) => {
                if o.to_bits() == v.to_bits() {
                    self.noops += 1;
                    return false;
                }
                self.changed += 1;
                let d = v as f64 - o as f64;
                self.delta_fro_sq += d * d;
            }
            (Some(o), None) => {
                self.deleted += 1;
                self.delta_fro_sq += (o as f64) * (o as f64);
            }
            (None, None) => {
                self.noops += 1;
                return false;
            }
        }
        self.mark_dirty(r);
        true
    }
}

/// Splice a canonical delta into canonical parallel triplet arrays: the
/// shared two-pointer merge behind `CooMatrix::apply_delta` and
/// `CsrMatrix::apply_delta`. `rows` may be an implicit iterator source for
/// CSR, so the caller passes closures yielding the old entries in order
/// and receives the merged stream back in order.
pub(crate) fn splice<V: Dataword>(
    old: impl Iterator<Item = (u32, u32, V)>,
    delta: &[(u32, u32, DeltaOp)],
    mut emit: impl FnMut(u32, u32, V),
) -> DeltaApply {
    debug_assert!(
        delta.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)),
        "delta entries must be sorted and unique (canonicalize first; direct `entries` edits bypass the tracker)"
    );
    let mut report = DeltaApply::default();
    let mut old = old.peekable();
    let mut j = 0usize;
    loop {
        let next_old = old.peek().map(|&(r, c, _)| (r, c));
        let next_delta = delta.get(j).map(|&(r, c, _)| (r, c));
        match (next_old, next_delta) {
            (None, None) => break,
            (Some(_), None) => {
                let (r, c, v) = old.next().unwrap();
                emit(r, c, v);
            }
            (Some(oc), Some(dc)) if oc < dc => {
                let (r, c, v) = old.next().unwrap();
                emit(r, c, v);
            }
            (Some(oc), Some(dc)) if oc == dc => {
                let (r, c, v) = old.next().unwrap();
                match delta[j].2 {
                    DeltaOp::Upsert(nv) => {
                        if report.record(r, Some(v.to_f32()), Some(nv)) {
                            emit(r, c, V::from_f32(nv));
                        } else {
                            // No-op upsert: keep the stored word verbatim —
                            // re-encoding through f32 could perturb a
                            // wider-than-f32 fixed-point word (Q1.31).
                            emit(r, c, v);
                        }
                    }
                    DeltaOp::Delete => {
                        report.record(r, Some(v.to_f32()), None);
                    }
                }
                j += 1;
            }
            // Delta coordinate absent from the matrix.
            _ => {
                let (r, c, op) = delta[j];
                match op {
                    DeltaOp::Upsert(nv) => {
                        if report.record(r, None, Some(nv)) {
                            emit(r, c, V::from_f32(nv));
                        }
                    }
                    DeltaOp::Delete => {
                        report.record(r, None, None);
                    }
                }
                j += 1;
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    #[test]
    fn canonicalize_is_last_writer_wins() {
        let mut d = CooDelta::new(4, 4);
        d.upsert(2, 1, 5.0);
        d.upsert(0, 0, 1.0);
        d.delete(2, 1);
        d.upsert(2, 1, 7.0);
        assert!(!d.is_canonical());
        d.canonicalize();
        assert!(d.is_canonical());
        assert_eq!(d.entries, vec![(0, 0, DeltaOp::Upsert(1.0)), (2, 1, DeltaOp::Upsert(7.0))]);
        // Idempotent.
        d.canonicalize();
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn sorted_pushes_skip_the_sort() {
        let mut d = CooDelta::new(4, 4);
        d.upsert(0, 1, 1.0);
        d.upsert(1, 0, 2.0);
        d.upsert(1, 2, 3.0);
        assert!(d.is_canonical());
    }

    #[test]
    fn symmetric_helpers_mirror_edits() {
        let mut d = CooDelta::new(5, 5);
        d.upsert_sym(1, 3, 2.5);
        d.upsert_sym(2, 2, -1.0); // diagonal: no mirror
        d.delete_sym(0, 4);
        d.canonicalize();
        assert!(d.is_symmetric());
        assert_eq!(d.len(), 5);
        let mut asym = CooDelta::new(5, 5);
        asym.upsert(0, 1, 1.0);
        asym.canonicalize();
        assert!(!asym.is_symmetric());
        // A mirror with a different value is asymmetric too.
        let mut off = CooDelta::new(5, 5);
        off.upsert(0, 1, 1.0);
        off.upsert(1, 0, 1.5);
        off.canonicalize();
        assert!(!off.is_symmetric());
    }

    #[test]
    fn delta_apply_report_accumulates_frobenius_change() {
        let mut m: CooMatrix = CooMatrix::new(3, 3);
        m.push(0, 0, 3.0);
        m.push(1, 1, 4.0);
        let mut d = CooDelta::new(3, 3);
        d.upsert(0, 0, 5.0); // change: (5-3)^2 = 4
        d.delete(1, 1); // delete: 4^2 = 16
        d.upsert(2, 2, 1.0); // insert: 1
        d.canonicalize();
        let rep = m.apply_delta(&d);
        assert_eq!(rep.changed, 1);
        assert_eq!(rep.deleted, 1);
        assert_eq!(rep.inserted, 1);
        assert!((rep.delta_fro_sq - 21.0).abs() < 1e-12);
        assert!((rep.delta_fro() - 21.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(rep.effective(), 3);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_edit_panics() {
        let mut d = CooDelta::new(2, 2);
        d.upsert(2, 0, 1.0);
    }
}

//! Sparse-matrix substrate: COO and CSR storage (generic over the
//! [`crate::fixed::Dataword`] value scalar), MatrixMarket IO, Frobenius
//! normalization, nnz-balanced partitioning, the 512-bit COO packet stream
//! that models the paper's HBM read path (§IV-B) with per-format
//! entries-per-line capacity, and the pool-parallel [`ShardedSpmv`] engine
//! that executes one CU worker per row stripe over whichever storage format
//! the solve requested. The query kernels (streaming Top-K SpMV with
//! per-CU bounded heaps — [`TopKHeap`], [`ShardedSpmv::top_k`] — and the
//! [`ppr_serial`]/[`ShardedSpmv::ppr`] Personalized PageRank power
//! iteration) run non-eigen jobs over the same stripes and storage
//! formats. The [`ooc`] module extends the packet model past RAM: matrices
//! serialized into per-shard chunk files ([`PacketFileWriter`]) stream back
//! through double-buffered prefetch ([`OocMatrix`]) as the engine's
//! [`MatrixBacking::Ooc`] backing, bitwise-identical to the resident path.

mod coo;
mod csr;
pub(crate) mod delta;
mod mmio;
mod norm;
pub mod ooc;
mod packet;
mod partition;
mod query;
mod sharded;

pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use delta::{CooDelta, DeltaApply, DeltaOp};
pub use mmio::{read_matrix_market, read_matrix_market_with, write_matrix_market, DuplicatePolicy, MmioError};
pub use norm::{frobenius_norm, normalize_frobenius, scale_value, ONE_BELOW};
pub use ooc::{
    ChunkBuf, ChunkGuard, OocManifest, OocMatrix, OocShardSource, PacketFileWriter, DEFAULT_CHUNK_BYTES,
    MANIFEST_NAME,
};
pub use packet::{CooPacket, PacketStream, PACKET_BITS, PACKET_MAX_NNZ, PACKET_NNZ};
pub use partition::{imbalance, partition_rows_balanced, PartitionPolicy, RowPartition};
pub use query::{
    column_sums, merge_top_k, ppr_serial, ppr_with, ppr_with_seed, row_l1_norms, top_k_serial, PprOptions,
    PprResult, TopKEntry, TopKHeap,
};
pub use sharded::{MatrixBacking, ShardRebuild, ShardedSpmv};

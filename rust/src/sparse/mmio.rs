//! MatrixMarket (`.mtx`) reader/writer — the interchange format of the
//! SuiteSparse collection the paper evaluates on (Table II). Supports the
//! `matrix coordinate real|integer|pattern general|symmetric` subset, which
//! covers every graph in the paper's suite.
//!
//! Duplicate coordinates are handled by an explicit [`DuplicatePolicy`]:
//! the default reader **accumulates** them (sums values, the assembled-
//! matrix convention scipy and SuiteSparse use), so the returned COO is
//! always canonical — sorted, one entry per coordinate. Keeping
//! duplicates verbatim (the old behaviour) silently inflated `nnz`,
//! double-counted the Frobenius norm, and defeated `is_symmetric` and the
//! registry's content-hash dedup downstream.

use crate::sparse::CooMatrix;
use std::collections::HashSet;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// What to do with repeated `(row, col)` coordinates in a coordinate file
/// (including a symmetric file that lists both triangles of one edge —
/// the mirror expansion makes those duplicates too).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DuplicatePolicy {
    /// Sum duplicate values (pattern entries sum their implicit 1.0s) and
    /// return a canonical matrix. The default.
    Accumulate,
    /// Fail with a parse error naming the first duplicated line — strict
    /// validation for pipelines that treat duplicates as data corruption.
    Reject,
}

/// Errors from MatrixMarket parsing.
#[derive(Debug, thiserror::Error)]
pub enum MmioError {
    /// IO failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// Structural / syntactic problem, with line number.
    #[error("parse error at line {line}: {msg}")]
    Parse {
        /// 1-based line number.
        line: usize,
        /// Description.
        msg: String,
    },
}

fn perr<T>(line: usize, msg: impl Into<String>) -> Result<T, MmioError> {
    Err(MmioError::Parse { line, msg: msg.into() })
}

/// Read a MatrixMarket coordinate file into COO with the default
/// [`DuplicatePolicy::Accumulate`]: duplicates are summed and the result
/// is canonical. `symmetric` files are expanded to full storage (both
/// triangles).
pub fn read_matrix_market(path: impl AsRef<Path>) -> Result<CooMatrix, MmioError> {
    read_matrix_market_with(path, DuplicatePolicy::Accumulate)
}

/// Read a MatrixMarket coordinate file into COO under an explicit
/// [`DuplicatePolicy`]. See [`read_matrix_market`].
pub fn read_matrix_market_with(path: impl AsRef<Path>, dup: DuplicatePolicy) -> Result<CooMatrix, MmioError> {
    let f = std::fs::File::open(path)?;
    let mut lines = BufReader::new(f).lines();
    // Header
    let header = match lines.next() {
        Some(h) => h?,
        None => return perr(1, "empty file"),
    };
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 5 || !h[0].starts_with("%%MatrixMarket") {
        return perr(1, "missing %%MatrixMarket header");
    }
    if h[1] != "matrix" || h[2] != "coordinate" {
        return perr(1, format!("unsupported object/format: {} {}", h[1], h[2]));
    }
    let field = h[3]; // real | integer | pattern
    if !matches!(field, "real" | "integer" | "pattern") {
        return perr(1, format!("unsupported field: {field}"));
    }
    let symmetry = h[4]; // general | symmetric
    if !matches!(symmetry, "general" | "symmetric") {
        return perr(1, format!("unsupported symmetry: {symmetry}"));
    }

    // Size line (skipping comments)
    let mut lineno = 1usize;
    let size_line = loop {
        let l = match lines.next() {
            Some(l) => l?,
            None => return perr(lineno, "missing size line"),
        };
        lineno += 1;
        if !l.trim_start().starts_with('%') && !l.trim().is_empty() {
            break l;
        }
    };
    let dims: Vec<&str> = size_line.split_whitespace().collect();
    if dims.len() != 3 {
        return perr(lineno, "size line must be `rows cols nnz`");
    }
    let nrows: usize = dims[0].parse().map_err(|_| MmioError::Parse { line: lineno, msg: "bad rows".into() })?;
    let ncols: usize = dims[1].parse().map_err(|_| MmioError::Parse { line: lineno, msg: "bad cols".into() })?;
    let nnz: usize = dims[2].parse().map_err(|_| MmioError::Parse { line: lineno, msg: "bad nnz".into() })?;

    let mut coo = CooMatrix::new(nrows, ncols);
    coo.rows.reserve(nnz);
    coo.cols.reserve(nnz);
    coo.vals.reserve(nnz);
    let mut seen = 0usize;
    // Reject mode tracks every stored coordinate (file entries plus their
    // symmetric mirrors), so a file listing both triangles of one edge is
    // caught as the duplicate it becomes after expansion.
    let mut occupied: HashSet<(u32, u32)> = HashSet::new();
    for l in lines {
        let l = l?;
        lineno += 1;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = match it.next().map(str::parse) {
            Some(Ok(v)) => v,
            _ => return perr(lineno, "bad row index"),
        };
        let c: usize = match it.next().map(str::parse) {
            Some(Ok(v)) => v,
            _ => return perr(lineno, "bad col index"),
        };
        // 1-based indices per the MM spec.
        if r == 0 || c == 0 || r > nrows || c > ncols {
            return perr(lineno, format!("index ({r},{c}) out of bounds {nrows}x{ncols}"));
        }
        let v: f32 = if field == "pattern" {
            1.0
        } else {
            match it.next().map(str::parse::<f64>) {
                Some(Ok(v)) => v as f32,
                _ => return perr(lineno, "bad value"),
            }
        };
        if dup == DuplicatePolicy::Reject {
            let mut coords = vec![((r - 1) as u32, (c - 1) as u32)];
            if symmetry == "symmetric" && r != c {
                coords.push(((c - 1) as u32, (r - 1) as u32));
            }
            for rc in coords {
                if !occupied.insert(rc) {
                    return perr(lineno, format!("duplicate entry ({r},{c})"));
                }
            }
        }
        coo.push(r - 1, c - 1, v);
        if symmetry == "symmetric" && r != c {
            coo.push(c - 1, r - 1, v);
        }
        seen += 1;
    }
    if seen != nnz {
        return perr(lineno, format!("expected {nnz} entries, found {seen}"));
    }
    // Accumulate duplicates and return canonical storage: sorted, one
    // entry per coordinate, duplicate values summed.
    coo.canonicalize();
    Ok(coo)
}

/// Write COO as `matrix coordinate real general`.
pub fn write_matrix_market(path: impl AsRef<Path>, m: &CooMatrix) -> Result<(), MmioError> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% generated by topk-eigen")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for i in 0..m.nnz() {
        writeln!(w, "{} {} {}", m.rows[i] + 1, m.cols[i] + 1, m.vals[i])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("topk-eigen-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn write_read_round_trip() {
        let mut m = CooMatrix::new(3, 3);
        m.push(0, 0, 1.5);
        m.push(1, 2, -2.25);
        m.push(2, 1, 4.0);
        let p = tmpfile("rt.mtx");
        write_matrix_market(&p, &m).unwrap();
        let m2 = read_matrix_market(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn reads_symmetric_expansion() {
        let p = tmpfile("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 2\n1 1 5.0\n3 1 2.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 3); // diagonal once, off-diagonal mirrored
        assert!(m.is_symmetric(0.0));
    }

    #[test]
    fn reads_pattern_as_ones() {
        let p = tmpfile("pat.mtx");
        std::fs::write(&p, "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n").unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.vals, vec![1.0, 1.0]);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let p = tmpfile("com.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n% a comment\n\n2 2 1\n% another\n2 2 3.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.vals[0], 3.0);
    }

    #[test]
    fn duplicate_general_entries_accumulate() {
        let p = tmpfile("dupgen.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n3 3 3\n1 2 1.5\n1 2 2.5\n3 3 1.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        // nnz is the *stored* count, not the file's inflated entry count.
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.rows, vec![0, 2]);
        assert_eq!(m.cols, vec![1, 2]);
        assert_eq!(m.vals, vec![4.0, 1.0]);
        // Strict mode refuses the same file, naming the duplicated line.
        assert!(matches!(
            read_matrix_market_with(&p, DuplicatePolicy::Reject),
            Err(MmioError::Parse { line: 4, .. })
        ));
    }

    #[test]
    fn symmetric_file_listing_both_triangles_stays_symmetric() {
        // Non-conforming but seen in the wild: a `symmetric` file carrying
        // both (2,1) and (1,2) of the same edge. Mirror expansion makes
        // four entries; accumulation folds them to one per triangle with
        // the summed value — and the result is still symmetric, so the
        // downstream symmetry check and content-hash dedup behave.
        let p = tmpfile("dupsym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 3\n1 1 5.0\n2 1 2.0\n1 2 2.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 3); // (0,0), (0,1), (1,0)
        assert!(m.is_symmetric(0.0));
        let off: Vec<f32> =
            (0..m.nnz()).filter(|&i| m.rows[i] != m.cols[i]).map(|i| m.vals[i]).collect();
        assert_eq!(off, vec![4.0, 4.0], "both triangles of the duplicated edge sum");
        assert!(matches!(
            read_matrix_market_with(&p, DuplicatePolicy::Reject),
            Err(MmioError::Parse { line: 5, .. })
        ));
    }

    #[test]
    fn pattern_duplicates_sum_their_implicit_ones() {
        let p = tmpfile("duppat.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern general\n2 2 3\n1 2 \n1 2\n2 1\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.vals, vec![2.0, 1.0], "duplicate pattern entry counts twice");
        assert!(read_matrix_market_with(&p, DuplicatePolicy::Reject).is_err());
    }

    #[test]
    fn clean_files_pass_reject_mode_and_stay_canonical() {
        let p = tmpfile("clean.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 2\n1 1 5.0\n3 1 2.0\n",
        )
        .unwrap();
        let strict = read_matrix_market_with(&p, DuplicatePolicy::Reject).unwrap();
        let lax = read_matrix_market(&p).unwrap();
        assert_eq!(strict, lax);
        // Canonical order: sorted by (row, col).
        let coords: Vec<(u32, u32)> = strict.rows.iter().zip(&strict.cols).map(|(&r, &c)| (r, c)).collect();
        let mut sorted = coords.clone();
        sorted.sort_unstable();
        assert_eq!(coords, sorted);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let p = tmpfile("oob.mtx");
        std::fs::write(&p, "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n").unwrap();
        assert!(matches!(read_matrix_market(&p), Err(MmioError::Parse { line: 3, .. })));
    }

    #[test]
    fn rejects_wrong_count() {
        let p = tmpfile("cnt.mtx");
        std::fs::write(&p, "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
    }

    #[test]
    fn rejects_bad_header() {
        let p = tmpfile("hdr.mtx");
        std::fs::write(&p, "not a matrix\n1 1 0\n").unwrap();
        assert!(matches!(read_matrix_market(&p), Err(MmioError::Parse { line: 1, .. })));
    }
}

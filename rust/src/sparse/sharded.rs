//! The pool-parallel sharded SpMV engine (§IV-B), generic over the stored
//! value scalar.
//!
//! The paper's Lanczos Core streams the COO matrix through **5 HBM-fed
//! SpMV Compute Units** in parallel and concatenates their partial output
//! vectors in a Merge Unit (Figure 6 A–C). [`ShardedSpmv`] is the
//! structural twin of that design at the L3 layer:
//!
//! * each [`RowPartition`] stripe = one CU's slice of the matrix;
//! * each [`ThreadPool`] worker = one CU datapath (default pool size 5);
//! * the scoped fork/join = the Merge Unit (output rows are disjoint, so
//!   the "merge" is free — workers write non-overlapping `y` ranges).
//!
//! The engine is generic over [`Dataword`]: a Q1.15 instance stores the
//! value array in 16-bit words (half the f32 bytes) and its per-CU packet
//! accounting uses 6 entries per 512-bit line instead of 5 (§IV-B1) —
//! [`ShardedSpmv::bytes_streamed`] exposes the resulting HBM traffic so
//! precision/bandwidth trade-offs are measurable, not notional.
//!
//! Both partition policies are supported: [`PartitionPolicy::EqualRows`]
//! reproduces the paper's scheme exactly, [`PartitionPolicy::BalancedNnz`]
//! equalizes per-CU work on power-law graphs (the `ablation_cu_packets`
//! bench quantifies the difference).
//!
//! Determinism: each output row is accumulated by exactly one worker in
//! the same element order as the serial kernel, so sharded results are
//! **bitwise identical** to [`CsrMatrix::spmv`] of the same storage format
//! for any shard count or policy — `tests/sharded_spmv.rs` and
//! `tests/typed_storage.rs` property-check this.
//!
//! Sharing: the engine is `Send + Sync` and holds its matrix behind an
//! `Arc`, so one `ShardedSpmv` (inside an
//! `Arc<crate::coordinator::PreparedMatrix>`) can serve **concurrent**
//! solves from multiple service workers. Concurrent `apply`/`apply_fused`
//! calls serialize their fork/joins on the engine's pool (one scope at a
//! time — see [`ThreadPool::scope_chunks`]), and because shard merges are
//! position-ordered, not completion-ordered, results stay bitwise
//! identical to running the same calls serially — the property
//! matrix-resident serving rests on (`tests/service_registry.rs` stresses
//! the full stack).

use crate::fixed::{packet_capacity, Dataword};
use crate::lanczos::{FusedBlockIteration, FusedIteration, Operator};
use crate::linalg;
use crate::sparse::ooc::{OocMatrix, OocShardSource};
use crate::sparse::query::{self, merge_top_k, PprOptions, PprResult, TopKEntry, TopKHeap};
use crate::sparse::{partition_rows_balanced, CsrMatrix, PartitionPolicy, RowPartition};
use crate::util::pool::ThreadPool;
use crate::util::ptr::SendPtr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Rows a CU worker scores per stripe-kernel call inside the Top-K sweep:
/// large enough to amortize the call, small enough that the scratch stays
/// cache-resident (the bounded heap, not the score vector, is the per-CU
/// state the paper's design keeps on chip). Out-of-core chunk boundaries
/// (`sparse::ooc`) align to this window so both backings see the same
/// kernel window sequence.
pub(crate) const TOPK_ROW_CHUNK: usize = 512;

/// Where a CU shard's packets come from.
///
/// `Resident` is the classic engine: the whole CSR matrix pinned in RAM.
/// `Ooc` keeps the matrix in an on-disk packet directory and streams each
/// stripe through [`OocShardSource`]'s double-buffered prefetch — O(buffer)
/// resident bytes instead of O(nnz), same bitwise results (the OOC kernels
/// replay the exact per-row f32 accumulation order of
/// [`CsrMatrix::spmv_into_stripe`]).
pub enum MatrixBacking<V: Dataword = f32> {
    /// Whole matrix in RAM behind an `Arc` (shared across engines).
    Resident(Arc<CsrMatrix<V>>),
    /// Matrix on storage; chunks stream through pooled, prefetched buffers.
    Ooc(Arc<OocMatrix<V>>),
}

impl<V: Dataword> MatrixBacking<V> {
    /// Matrix rows.
    pub fn nrows(&self) -> usize {
        match self {
            MatrixBacking::Resident(m) => m.nrows,
            MatrixBacking::Ooc(o) => o.nrows(),
        }
    }

    /// Matrix columns.
    pub fn ncols(&self) -> usize {
        match self {
            MatrixBacking::Resident(m) => m.ncols,
            MatrixBacking::Ooc(o) => o.ncols(),
        }
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        match self {
            MatrixBacking::Resident(m) => m.nnz(),
            MatrixBacking::Ooc(o) => o.nnz(),
        }
    }

    /// Longest row (sizes the early-exit inflate bound).
    pub fn max_row_nnz(&self) -> usize {
        match self {
            MatrixBacking::Resident(m) => m.max_row_nnz(),
            MatrixBacking::Ooc(o) => o.max_row_nnz(),
        }
    }

    /// True when the matrix streams from storage.
    pub fn is_ooc(&self) -> bool {
        matches!(self, MatrixBacking::Ooc(_))
    }
}

/// Multi-CU SpMV: row stripes dispatched to a thread pool, one worker per
/// CU shard. Output regions are disjoint so no synchronization is needed
/// beyond the final join — exactly the paper's partition + merge scheme.
pub struct ShardedSpmv<V: Dataword = f32> {
    backing: MatrixBacking<V>,
    parts: Vec<RowPartition>,
    policy: PartitionPolicy,
    pool: Arc<ThreadPool>,
    applies: AtomicUsize,
    shards_skipped: AtomicUsize,
}

impl<V: Dataword> ShardedSpmv<V> {
    /// Shard `matrix` into `cus` stripes under `policy` and run them on
    /// `pool` (pool should have >= `cus` workers for full overlap; with
    /// fewer workers, stripes are multiplexed onto the available ones).
    pub fn new(matrix: Arc<CsrMatrix<V>>, cus: usize, policy: PartitionPolicy, pool: Arc<ThreadPool>) -> Self {
        let parts = partition_rows_balanced(&matrix, cus, policy);
        Self {
            backing: MatrixBacking::Resident(matrix),
            parts,
            policy,
            pool,
            applies: AtomicUsize::new(0),
            shards_skipped: AtomicUsize::new(0),
        }
    }

    /// Convenience constructor that spawns a dedicated pool with one worker
    /// per CU — the paper's configuration when `cus == 5`. Prefer
    /// [`ShardedSpmv::new`] when several engines can share one pool (the
    /// coordinator and the batched service do).
    pub fn with_own_pool(matrix: Arc<CsrMatrix<V>>, cus: usize, policy: PartitionPolicy) -> Self {
        let pool = Arc::new(ThreadPool::new(cus.max(1)));
        Self::new(matrix, cus, policy, pool)
    }

    /// Engine over an out-of-core matrix: shard table and policy come from
    /// the packet directory's manifest (written by the same
    /// `partition_rows_balanced` a resident prepare would run, so CU
    /// geometry — and therefore every merge order — matches the resident
    /// twin exactly). Each sweep streams chunk files through the matrix's
    /// double-buffered prefetcher; only O(buffer) matrix bytes stay in RAM.
    pub fn new_ooc(matrix: Arc<OocMatrix<V>>, pool: Arc<ThreadPool>) -> Self {
        let parts = matrix.parts().to_vec();
        let policy = matrix.policy();
        Self {
            backing: MatrixBacking::Ooc(matrix),
            parts,
            policy,
            pool,
            applies: AtomicUsize::new(0),
            shards_skipped: AtomicUsize::new(0),
        }
    }

    /// [`ShardedSpmv::new_ooc`] with a dedicated one-worker-per-shard pool.
    pub fn with_own_pool_ooc(matrix: Arc<OocMatrix<V>>) -> Self {
        let pool = Arc::new(ThreadPool::new(matrix.parts().len().max(1)));
        Self::new_ooc(matrix, pool)
    }

    /// The shard table (exposed for the FPGA model and tests).
    pub fn partitions(&self) -> &[RowPartition] {
        &self.parts
    }

    /// The partition policy the shards were built with.
    pub fn policy(&self) -> PartitionPolicy {
        self.policy
    }

    /// Number of CU shards.
    pub fn cus(&self) -> usize {
        self.parts.len()
    }

    /// Heaviest-shard/ideal nnz ratio (1.0 = perfect balance); see
    /// [`crate::sparse::imbalance`].
    pub fn imbalance(&self) -> f64 {
        crate::sparse::imbalance(&self.parts)
    }

    /// Number of `apply` calls so far (telemetry for the service layer).
    pub fn applies(&self) -> usize {
        self.applies.load(Ordering::Relaxed)
    }

    /// Cumulative CU shards pruned by the early-exit Top-K bound checks
    /// ([`ShardedSpmv::top_k_with_bounds`] /
    /// [`ShardedSpmv::top_k_batch_with_bounds`]) since this engine was
    /// built — matrix stripes whose packets were provably not worth
    /// streaming.
    pub fn shards_skipped(&self) -> usize {
        self.shards_skipped.load(Ordering::Relaxed)
    }

    /// Short name of the storage format this engine streams.
    pub fn format_name(&self) -> &'static str {
        V::NAME
    }

    /// COO entries per 512-bit HBM line in this engine's format (§IV-B1).
    pub fn packet_entries_per_line(&self) -> usize {
        packet_capacity(V::BITS)
    }

    /// Bytes of the matrix value array in this storage format (on disk for
    /// the out-of-core backing).
    pub fn value_bytes(&self) -> usize {
        match &self.backing {
            MatrixBacking::Resident(m) => m.value_bytes(),
            MatrixBacking::Ooc(o) => o.nnz() * V::bytes(),
        }
    }

    /// Cumulative HBM matrix-stream bytes across all `apply` calls so far
    /// (whole 64-byte lines, summed per CU shard — the paper's accounting).
    pub fn bytes_streamed(&self) -> usize {
        self.applies() * self.bytes_per_apply()
    }

    /// Where this engine's packets come from.
    pub fn backing(&self) -> &MatrixBacking<V> {
        &self.backing
    }

    /// The resident CSR matrix, when there is one (`None` for an
    /// out-of-core engine — its entries only ever exist chunk by chunk).
    pub fn matrix(&self) -> Option<&Arc<CsrMatrix<V>>> {
        match &self.backing {
            MatrixBacking::Resident(m) => Some(m),
            MatrixBacking::Ooc(_) => None,
        }
    }

    /// The out-of-core matrix, when the engine streams from storage.
    pub fn ooc_matrix(&self) -> Option<&Arc<OocMatrix<V>>> {
        match &self.backing {
            MatrixBacking::Resident(_) => None,
            MatrixBacking::Ooc(o) => Some(o),
        }
    }

    /// True when sweeps stream chunk files instead of resident CSR rows.
    pub fn is_ooc(&self) -> bool {
        self.backing.is_ooc()
    }

    /// One CU stripe of `y = M x` from the out-of-core backing: zero-fill,
    /// then accumulate streamed entries in row-major order. Per output row
    /// this performs the exact f32 operation sequence of
    /// [`CsrMatrix::spmv_into_stripe`] (left-to-right products into a +0.0
    /// start, untouched rows keep +0.0), which is what makes OOC solves
    /// bitwise-identical to resident ones.
    fn ooc_spmv_stripe(ooc: &Arc<OocMatrix<V>>, shard: usize, x: &[f32], y_stripe: &mut [f32], r0: usize) {
        y_stripe.fill(0.0);
        let mut src = OocShardSource::new(Arc::clone(ooc), shard);
        while let Some(chunk) = src.next_chunk() {
            let (rows, cols, vals) = (chunk.rows(), chunk.cols(), chunk.vals());
            for e in 0..vals.len() {
                y_stripe[rows[e] as usize - r0] += vals[e].to_f32() * x[cols[e] as usize];
            }
        }
    }

    /// Streaming Top-K SpMV query: score every row of the resident matrix
    /// against the dense vector `x` and return the `k` best
    /// `(index, score)` hits, best first.
    ///
    /// Each CU worker streams its own row stripe through the same typed
    /// stripe kernel the eigensolver uses, feeding scores into a
    /// **bounded partial max-heap** ([`TopKHeap`], `k` entries) instead of
    /// materializing the full output vector; the fork/join merge folds the
    /// per-shard heaps in shard order ([`merge_top_k`]). One matrix stream
    /// per query — the sweep counts as one `apply` in the byte/packet
    /// telemetry.
    ///
    /// Determinism: per-row scores are bitwise identical to the serial
    /// SpMV's and ranking is the total order of [`TopKEntry`], so the
    /// result is **bitwise equal** to the brute-force oracle
    /// [`top_k_serial`](crate::sparse::top_k_serial) for any shard count
    /// or partition policy.
    /// `k` larger than the row count clamps to it.
    ///
    /// Implemented as the batch-1 case of [`ShardedSpmv::top_k_batch`] —
    /// one kernel serves both backings and every batch size, and the
    /// per-query stripe sweep is the same call sequence either way.
    pub fn top_k(&self, x: &[f32], k: usize) -> Vec<TopKEntry> {
        let (mut res, _) = self.top_k_batch_core(&[x], k, None);
        res.pop().unwrap_or_default()
    }

    /// Batched multi-query Top-K SpMM: answer `b = xs.len()` dense queries
    /// against the resident matrix while streaming its packets **once for
    /// the whole batch** instead of once per query (arxiv 2103.04808's
    /// amortization — the same economics block Lanczos buys the
    /// eigensolver).
    ///
    /// Each CU worker walks its row stripe in [`TOPK_ROW_CHUNK`]-row
    /// chunks; inside a chunk the inner loop is column-blocked over the
    /// batch — the chunk's CSR rows are re-scored for every query while
    /// their index/value lines are cache-hot, feeding a per-(shard, query)
    /// bounded heap. Per-query merges are the same totally-ordered
    /// [`merge_top_k`], so element `q` of the result is **bitwise equal**
    /// to an independent [`ShardedSpmv::top_k`]`(&xs[q], k)` call for any
    /// shard count or policy — the per-query stripe-kernel call sequence
    /// is identical; only the matrix traffic is shared.
    ///
    /// Telemetry: the sweep counts **one** `apply` regardless of `b`, so
    /// [`ShardedSpmv::bytes_streamed`] per answered query drops by ~`b`×.
    /// An empty batch or `k == 0` returns deterministically empty results
    /// without streaming anything.
    pub fn top_k_batch(&self, xs: &[Vec<f32>], k: usize) -> Vec<Vec<TopKEntry>> {
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        self.top_k_batch_core(&refs, k, None).0
    }

    /// [`ShardedSpmv::top_k_batch`] with early-exit shard pruning: given
    /// the per-row L1 table from [`ShardedSpmv::row_l1_norms`] (the
    /// registry caches it per `(handle, precision, generation)`), shards
    /// are swept hottest-bound-first in waves, and once every query's
    /// running top-`k` is full, a shard whose conservative score bound
    /// cannot beat **any** query's current k-th score — and therefore no
    /// later shard's either, the order is descending — is never streamed.
    /// Returns the per-query results plus the number of shards skipped
    /// (also accumulated on [`ShardedSpmv::shards_skipped`]).
    ///
    /// Exactness: a shard `s` is pruned only when, for every query `q`,
    /// `shard_l1[s] * max_j|x_q[j]| * inflate < kth_q` strictly, where the
    /// bound is evaluated in f64 and `inflate = (1 + 2^-24)^(max_row_nnz + 2)`
    /// dominates the worst-case relative error of the f32 stripe
    /// accumulation. Every *computed* score in a pruned shard is therefore
    /// strictly below the running (hence the final) k-th score, so the
    /// merged output is **bitwise equal** to the no-skip path — pruning
    /// changes bytes moved, never bits returned.
    pub fn top_k_batch_with_bounds(
        &self,
        xs: &[Vec<f32>],
        k: usize,
        row_l1: &[f64],
    ) -> (Vec<Vec<TopKEntry>>, usize) {
        let refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        self.top_k_batch_core(&refs, k, Some(row_l1))
    }

    /// Single-query early-exit Top-K: [`ShardedSpmv::top_k_batch_with_bounds`]
    /// at batch size 1. Bitwise equal to [`ShardedSpmv::top_k`], returning
    /// additionally how many CU shards the bound check pruned.
    pub fn top_k_with_bounds(&self, x: &[f32], k: usize, row_l1: &[f64]) -> (Vec<TopKEntry>, usize) {
        let (mut res, skipped) = self.top_k_batch_core(&[x], k, Some(row_l1));
        (res.pop().unwrap_or_default(), skipped)
    }

    /// One CU worker's share of a batched sweep: walk the stripe in
    /// 512-row windows, score every query per window while the window's
    /// matrix lines are cache-hot (resident CSR rows or a streamed OOC
    /// chunk), keep per-query bounded heaps. Per query and per window this
    /// produces the exact score bits of the serial stripe kernel — the
    /// bitwise anchor of both the batch path and the out-of-core path
    /// (OOC chunk boundaries are aligned to the same 512-row windows, so
    /// the window sequence is identical across backings).
    fn sweep_shard(&self, shard: usize, xs: &[&[f32]], k: usize) -> Vec<Vec<TopKEntry>> {
        let p = self.parts[shard];
        let mut heaps: Vec<TopKHeap> = xs.iter().map(|_| TopKHeap::new(k)).collect();
        let mut buf = [0.0f32; TOPK_ROW_CHUNK];
        match &self.backing {
            MatrixBacking::Resident(m) => {
                let mut r0 = p.row_start;
                while r0 < p.row_end {
                    let r1 = (r0 + TOPK_ROW_CHUNK).min(p.row_end);
                    for (heap, x) in heaps.iter_mut().zip(xs) {
                        let chunk = &mut buf[..r1 - r0];
                        m.spmv_into_stripe(x, chunk, r0, r1);
                        for (off, &score) in chunk.iter().enumerate() {
                            heap.push((r0 + off) as u32, score);
                        }
                    }
                    r0 = r1;
                }
            }
            MatrixBacking::Ooc(ooc) => {
                let mut src = OocShardSource::new(Arc::clone(ooc), shard);
                while let Some(chunk) = src.next_chunk() {
                    let (c0, c1) = chunk.row_range();
                    let (rows, cols, vals) = (chunk.rows(), chunk.cols(), chunk.vals());
                    let (mut e0, mut r0) = (0usize, c0);
                    while r0 < c1 {
                        let r1 = (r0 + TOPK_ROW_CHUNK).min(c1);
                        let e1 = e0 + rows[e0..].partition_point(|&r| (r as usize) < r1);
                        for (heap, x) in heaps.iter_mut().zip(xs) {
                            let scores = &mut buf[..r1 - r0];
                            scores.fill(0.0);
                            for e in e0..e1 {
                                scores[rows[e] as usize - r0] += vals[e].to_f32() * x[cols[e] as usize];
                            }
                            for (off, &score) in scores.iter().enumerate() {
                                heap.push((r0 + off) as u32, score);
                            }
                        }
                        (e0, r0) = (e1, r1);
                    }
                }
            }
        }
        heaps.into_iter().map(TopKHeap::into_sorted).collect()
    }

    fn top_k_batch_core(&self, xs: &[&[f32]], k: usize, row_l1: Option<&[f64]>) -> (Vec<Vec<TopKEntry>>, usize) {
        let (nrows, ncols) = (self.backing.nrows(), self.backing.ncols());
        for x in xs {
            assert!(x.len() >= ncols, "query vector shorter than ncols");
        }
        let b = xs.len();
        let k = k.min(nrows);
        if b == 0 || k == 0 {
            // Nothing to select: deterministic empties, no matrix stream.
            return (vec![Vec::new(); b], 0);
        }
        self.applies.fetch_add(1, Ordering::Relaxed);
        let parts = &self.parts;

        // No bound table: one scope over every shard, exactly `top_k`'s
        // dispatch shape, batched.
        let Some(rl1) = row_l1 else {
            let mut slots: Vec<Vec<Vec<TopKEntry>>> = vec![Vec::new(); parts.len()];
            let s_ptr = SendPtr(slots.as_mut_ptr());
            self.pool.scope_chunks(parts.len(), |i| {
                let out = self.sweep_shard(i, xs, k);
                // SAFETY: as in `apply` — the scoped join outlives every
                // use and slot `i` is written by exactly this task.
                unsafe { s_ptr.set(i, out) };
            });
            let mut results = Vec::with_capacity(b);
            for q in 0..b {
                let per_shard: Vec<Vec<TopKEntry>> =
                    slots.iter_mut().map(|s| std::mem::take(&mut s[q])).collect();
                results.push(merge_top_k(per_shard, k));
            }
            return (results, 0);
        };
        assert_eq!(rl1.len(), nrows, "row-bound table must cover every row");

        // Conservative per-shard score bound: the shard's max row L1 times
        // the query's max |x_j|, inflated past the worst-case relative
        // error of the f32 stripe accumulation so the bound dominates
        // computed scores, not just exact ones.
        let mut shard_l1 = vec![0.0f64; parts.len()];
        for (s, p) in parts.iter().enumerate() {
            let mut hi = 0.0f64;
            for r in p.row_start..p.row_end {
                hi = hi.max(rl1[r]);
            }
            shard_l1[s] = hi;
        }
        let xmax: Vec<f64> =
            xs.iter().map(|x| x[..ncols].iter().fold(0.0f64, |acc, &v| acc.max((v as f64).abs()))).collect();
        let inflate = (1.0 + (-24.0f64).exp2())
            .powi((self.backing.max_row_nnz().min(i32::MAX as usize - 2) as i32) + 2);
        // Hottest bound first; ties to the lower shard (deterministic).
        // Every query's bound shares the shard factor, so this one order
        // is descending for the whole batch and the prune check can stop
        // at the first unprunable shard.
        let mut order: Vec<usize> = (0..parts.len()).collect();
        order.sort_by(|&a, &c| shard_l1[c].total_cmp(&shard_l1[a]).then(a.cmp(&c)));
        // Waves smaller than the full shard set buy prune points between
        // joins even when the pool could cover every shard at once.
        let wave = self.pool.size().min(parts.len().div_ceil(2)).max(1);

        let mut merged: Vec<Vec<TopKEntry>> = vec![Vec::new(); b];
        let mut skipped = 0usize;
        let mut next = 0usize;
        while next < order.len() {
            let s = order[next];
            let prunable = merged.iter().zip(&xmax).all(|(mq, &xm)| {
                mq.len() == k && shard_l1[s] * xm * inflate < f64::from(mq[k - 1].score)
            });
            if prunable {
                skipped = order.len() - next;
                break;
            }
            let end = (next + wave).min(order.len());
            let live = &order[next..end];
            let mut slots: Vec<Vec<Vec<TopKEntry>>> = vec![Vec::new(); live.len()];
            let s_ptr = SendPtr(slots.as_mut_ptr());
            self.pool.scope_chunks(live.len(), |j| {
                let out = self.sweep_shard(live[j], xs, k);
                // SAFETY: as in `apply` — the scoped join outlives every
                // use and slot `j` is written by exactly this task.
                unsafe { s_ptr.set(j, out) };
            });
            for q in 0..b {
                // Folding the running top-k with the new shards is exact:
                // the order is total with unique row indices, so truncation
                // keeps the same k best as one flat merge over all shards.
                let mut fold: Vec<Vec<TopKEntry>> = Vec::with_capacity(live.len() + 1);
                fold.push(std::mem::take(&mut merged[q]));
                for slot in slots.iter_mut() {
                    fold.push(std::mem::take(&mut slot[q]));
                }
                merged[q] = merge_top_k(fold, k);
            }
            next = end;
        }
        self.shards_skipped.fetch_add(skipped, Ordering::Relaxed);
        (merged, skipped)
    }

    /// The early-exit bound table: per-row L1 norms of the stored values
    /// in f64, serial and shard-independent (see
    /// [`row_l1_norms`](crate::sparse::row_l1_norms)). Exposed so the
    /// registry can cache it per `(handle, precision, generation)` beside
    /// the PPR colsums.
    pub fn row_l1_norms(&self) -> Vec<f64> {
        match &self.backing {
            MatrixBacking::Resident(m) => query::row_l1_norms(m.as_ref()),
            MatrixBacking::Ooc(o) => {
                // One streaming pass in global CSR order: each row's |v|
                // terms fold left-to-right exactly as the resident kernel's,
                // so the f64 table matches it bitwise.
                let mut norms = vec![0.0f64; o.nrows()];
                o.for_each_entry(|r, _, v| norms[r as usize] += (v.to_f32() as f64).abs());
                norms
            }
        }
    }

    /// Personalized PageRank on the resident matrix: damped power
    /// iteration `x' = alpha * P x + (1 - alpha) * e_s` with
    /// dangling-mass redistribution and L1-delta stopping (see
    /// [`ppr_with`](crate::sparse::ppr_with) for the exact recurrence).
    /// `P` column-normalizes
    /// the **stored** (quantized) values, so the reduced-precision formats
    /// run the random walk over their own datapath words and the result is
    /// invariant to the registry's Frobenius scaling up to quantization.
    ///
    /// Every iteration streams the matrix once through the sharded CU
    /// sweep ([`Operator::apply`]), so the telemetry counters advance one
    /// `apply` per iteration. Bitwise equal to
    /// [`ppr_serial`](crate::sparse::ppr_serial) on
    /// the same stored matrix for any CU count.
    pub fn ppr(&self, opts: &PprOptions) -> PprResult {
        let colsums = self.column_sums();
        self.ppr_with_colsums(opts, &colsums)
    }

    /// The PPR normalizer table: per-column sums of the **stored**
    /// (quantized, scaled) values in f64, serial and shard-independent
    /// (see [`column_sums`](crate::sparse::column_sums)). Exposed so the
    /// registry can cache it per `(handle, precision, generation)`.
    pub fn column_sums(&self) -> Vec<f64> {
        match &self.backing {
            MatrixBacking::Resident(m) => query::column_sums(m.as_ref()),
            MatrixBacking::Ooc(o) => {
                // Streamed in the same flat entry order the resident kernel
                // walks (row-major over the whole matrix), so each column's
                // f64 accumulation sequence — and the table — is bitwise
                // identical.
                let mut sums = vec![0.0f64; o.ncols()];
                o.for_each_entry(|_, c, v| sums[c as usize] += v.to_f32() as f64);
                sums
            }
        }
    }

    /// [`ShardedSpmv::ppr`] with a precomputed column-sum table — the
    /// registry caches these per `(handle, precision, generation)` so a
    /// stream of PPR jobs on one resident matrix pays the O(nnz)
    /// normalizer pass once (see
    /// [`MatrixRegistry::column_sums`](crate::coordinator::MatrixRegistry::column_sums)).
    pub fn ppr_with_colsums(&self, opts: &PprOptions, colsums: &[f64]) -> PprResult {
        self.ppr_with_colsums_seeded(opts, colsums, None)
    }

    /// [`ShardedSpmv::ppr_with_colsums`] with an optional warm start: when
    /// `seed` is `Some`, the power iteration begins from those scores
    /// instead of the cold one-hot (see
    /// [`ppr_with_seed`](crate::sparse::ppr_with_seed) — the fixed point
    /// is unique, so seeding changes iteration count, never the limit).
    /// The service feeds this the previous generation's converged scores
    /// after a small `CooDelta`, so warm re-solves stream the matrix
    /// measurably fewer times; each iteration still counts one `apply`.
    pub fn ppr_with_colsums_seeded(&self, opts: &PprOptions, colsums: &[f64], seed: Option<&[f32]>) -> PprResult {
        assert_eq!(self.backing.nrows(), self.backing.ncols(), "PPR needs a square matrix");
        query::ppr_with_seed(self.backing.nrows(), colsums, opts, seed, |z, y| self.apply(z, y))
    }

    /// Rebind this engine to an updated matrix, re-deriving the CU shard
    /// table and reporting which shards the delta actually touched — the
    /// incremental re-prep step of the registry's update path.
    ///
    /// `matrix` is the post-delta CSR (same dimensions, values already in
    /// this engine's storage format); `dirty_rows` is the sorted dirty set
    /// from [`CooMatrix::apply_delta`](crate::sparse::CooMatrix::apply_delta).
    /// The new engine shares this engine's worker pool (no thread churn)
    /// and keeps its policy; partitions are recomputed with the same
    /// function a from-scratch prepare uses, so an incrementally rebuilt
    /// engine is **indistinguishable** from a freshly built one — solves
    /// against either are bitwise identical.
    ///
    /// A shard counts as *reused* when its row range, nnz, and rows are
    /// untouched by the delta (identical boundaries, no dirty row
    /// inside) — the [`ShardRebuild`] telemetry classifies CU images as
    /// dirty or carried-over, which is what the acceptance test pins. Be
    /// precise about what is and is not saved: the caller re-streams the
    /// full value array regardless (Frobenius re-normalization after an
    /// update rescales every stored word — an O(nnz) pass no structural
    /// reuse can avoid) and `matrix` arrives fully built, so "reuse" here
    /// is the engine-level carry-over (pool, policy, and the clean
    /// shards' identity for telemetry/validation), not a skipped copy of
    /// index bytes. The splice-level savings live upstream: the registry
    /// updates its canonical COO in `O(nnz + d)` without re-sorting
    /// (`CooMatrix::apply_delta`), which is what the incremental-vs-full
    /// re-prep bench measures. Consumers maintaining a raw *unnormalized*
    /// CSR under deltas get true in-place splicing from
    /// [`CsrMatrix::apply_delta`].
    pub fn rebuild_shards(&self, matrix: Arc<CsrMatrix<V>>, dirty_rows: &[u32]) -> (Self, ShardRebuild) {
        assert!(
            !self.is_ooc(),
            "rebuild_shards on an out-of-core engine: delta updates require a resident matrix \
             (re-export the packet directory instead)"
        );
        assert_eq!(matrix.nrows, self.backing.nrows(), "update must preserve dimensions");
        debug_assert!(dirty_rows.windows(2).all(|w| w[0] < w[1]), "dirty rows must be sorted and unique");
        let parts = partition_rows_balanced(&matrix, self.parts.len(), self.policy);
        let mut stats = ShardRebuild::default();
        for (new, old) in parts.iter().zip(&self.parts) {
            let same_range = new.row_start == old.row_start && new.row_end == old.row_end;
            let has_dirty = dirty_rows
                .partition_point(|&r| (r as usize) < new.row_start)
                < dirty_rows.partition_point(|&r| (r as usize) < new.row_end);
            if same_range && !has_dirty && new.nnz == old.nnz {
                stats.reused += 1;
            } else {
                stats.rebuilt += 1;
            }
        }
        let engine = Self {
            backing: MatrixBacking::Resident(matrix),
            parts,
            policy: self.policy,
            pool: Arc::clone(&self.pool),
            applies: AtomicUsize::new(0),
            shards_skipped: AtomicUsize::new(0),
        };
        (engine, stats)
    }
}

/// Per-shard telemetry of one [`ShardedSpmv::rebuild_shards`] call: how
/// many CU shards the delta dirtied vs how many carried over untouched.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardRebuild {
    /// Shards containing dirty rows or whose row boundaries moved.
    pub rebuilt: usize,
    /// Shards whose range, nnz, and rows were untouched by the delta.
    pub reused: usize,
}

impl<V: Dataword> Operator for ShardedSpmv<V> {
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
    fn n(&self) -> usize {
        self.backing.nrows()
    }
    fn nnz(&self) -> usize {
        self.backing.nnz()
    }
    fn value_bits(&self) -> u32 {
        V::BITS
    }
    fn packets_per_apply(&self) -> usize {
        // Each CU streams its own shard: partially-filled tail lines cost a
        // full transaction per shard, not one per matrix.
        let cap = packet_capacity(V::BITS);
        self.parts.iter().map(|p| p.nnz.div_ceil(cap)).sum()
    }
    fn io_bytes_read(&self) -> u64 {
        match &self.backing {
            MatrixBacking::Resident(_) => 0,
            MatrixBacking::Ooc(o) => o.io_bytes_read(),
        }
    }
    fn prefetch_stalls(&self) -> u64 {
        match &self.backing {
            MatrixBacking::Resident(_) => 0,
            MatrixBacking::Ooc(o) => o.prefetch_stalls(),
        }
    }
    fn resident_bytes(&self) -> usize {
        match &self.backing {
            MatrixBacking::Resident(m) => {
                8 * m.indptr.len() + 4 * m.indices.len() + V::bytes() * m.vals.len()
            }
            // The matrix itself stays on storage; RAM holds only the
            // preallocated chunk buffers + chunk tables.
            MatrixBacking::Ooc(o) => o.buffer_bytes(),
        }
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(y.len(), self.backing.nrows());
        self.applies.fetch_add(1, Ordering::Relaxed);
        let parts = &self.parts;
        // Disjoint writes: each task owns rows [row_start, row_end) and
        // materializes only its own stripe of the output buffer, so the
        // concurrent `&mut` views never overlap and the only
        // synchronization is the scoped join.
        let y_ptr = SendPtr(y.as_mut_ptr());
        self.pool.scope_chunks(parts.len(), |i| {
            let p = parts[i];
            // SAFETY: `scope_chunks` blocks until every worker finishes, so
            // the pointer outlives all uses; stripes tile `[0, nrows)`
            // without overlap (invariant of `partition_rows_balanced`).
            let y_stripe = unsafe { y_ptr.slice_mut(p.row_start, p.row_end - p.row_start) };
            match &self.backing {
                MatrixBacking::Resident(m) => m.spmv_into_stripe(x, y_stripe, p.row_start, p.row_end),
                MatrixBacking::Ooc(ooc) => Self::ooc_spmv_stripe(ooc, i, x, y_stripe, p.row_start),
            }
        });
    }

    fn fused_shards(&self) -> usize {
        self.parts.len().max(1)
    }

    fn parallel_for(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.pool.scope_chunks(tasks, |i| f(i));
    }

    /// The tentpole sweep: each CU worker writes its `y` stripe, then —
    /// while the stripe is still cache-hot — subtracts `beta_prev *
    /// v_prev`, reduces its partial `dot(w, v)`, and (on reorth
    /// iterations) its partial projections against every basis row, into
    /// its own `partials` slot. The join merges the per-shard partials:
    /// SpMV + axpy + dot (+ K reorth dots) in **one** fork/join over the
    /// data instead of a parade of serial full-length passes.
    fn apply_fused(&self, x: &[f32], y: &mut [f32], it: &mut FusedIteration<'_>) -> f64 {
        let n = self.backing.nrows();
        assert_eq!(y.len(), n);
        assert_eq!(x.len(), n);
        self.applies.fetch_add(1, Ordering::Relaxed);
        let parts = &self.parts;
        let shards = parts.len();
        let nproj = it.basis.map_or(0, |b| b.rows());
        let stride = 1 + nproj;
        assert!(it.partials.len() >= shards * stride, "partials scratch too small");
        assert!(it.projs.len() >= nproj, "projection buffer too small");
        let (beta_prev, v_prev, basis) = (it.beta_prev, it.v_prev, it.basis);
        if beta_prev != 0.0 {
            assert_eq!(v_prev.len(), n);
        }
        let y_ptr = SendPtr(y.as_mut_ptr());
        let p_ptr = SendPtr(it.partials.as_mut_ptr());
        self.pool.scope_chunks(shards, |i| {
            let p = parts[i];
            let (r0, r1) = (p.row_start, p.row_end);
            // SAFETY: as in `apply` — the scoped join outlives every use
            // and stripes tile `[0, nrows)` disjointly, so the stripe-local
            // `&mut` views never overlap.
            let w_stripe = unsafe { y_ptr.slice_mut(r0, r1 - r0) };
            // SAFETY: partials slot `i` (stride `1 + nproj`) is written by
            // exactly this task; the scratch outlives the join.
            let slot = unsafe { p_ptr.slice_mut(i * stride, stride) };
            // The stripe SpMV streams resident rows or prefetched OOC
            // chunks; either way the axpy/dot/reorth tail below runs on the
            // same bitwise stripe, while the next shard's chunks are
            // already being read — the I/O-behind-compute overlap.
            match &self.backing {
                MatrixBacking::Resident(m) => m.spmv_into_stripe(x, w_stripe, r0, r1),
                MatrixBacking::Ooc(ooc) => Self::ooc_spmv_stripe(ooc, i, x, w_stripe, r0),
            }
            slot[0] = if beta_prev != 0.0 {
                linalg::axpy_dot(-beta_prev, &v_prev[r0..r1], w_stripe, &x[r0..r1])
            } else {
                linalg::dot(w_stripe, &x[r0..r1])
            };
            if let Some(basis) = basis {
                basis.dots_range(w_stripe, r0, r1, &mut slot[1..]);
            }
        });
        // Merge Unit for the reductions: fold the per-shard partials in
        // shard order (deterministic for a fixed CU count).
        let mut alpha = 0.0f64;
        for s in 0..shards {
            alpha += it.partials[s * stride];
        }
        for (j, proj) in it.projs.iter_mut().take(nproj).enumerate() {
            let mut acc = 0.0f64;
            for s in 0..shards {
                acc += it.partials[s * stride + 1 + j];
            }
            *proj = acc;
        }
        alpha
    }

    /// The block tentpole sweep: each CU worker walks its row stripe in
    /// [`TOPK_ROW_CHUNK`]-row chunks (the same cache-hot discipline as the
    /// Top-K batch kernel) and, per chunk, runs SpMV + the Paige-reordered
    /// `V_{j-1} B_j^T` subtraction + partial block dots `A_j` + partial
    /// reorth projections for **all `b` panel columns** while that chunk's
    /// CSR lines are resident. One walk of the matrix per block iteration
    /// — `applies` ticks once, not `b` times — which is exactly the
    /// bytes-per-Ritz-pair economics `benches/lanczos_block.rs` pins.
    fn apply_fused_block(&self, x: &[f32], y: &mut [f32], it: &mut FusedBlockIteration<'_>) {
        let n = self.backing.nrows();
        let b = it.b;
        assert_eq!(x.len(), b * n, "x must be a column-major b x n panel");
        assert_eq!(y.len(), b * n, "y must be a column-major b x n panel");
        self.applies.fetch_add(1, Ordering::Relaxed);
        let parts = &self.parts;
        let shards = parts.len();
        let nproj = it.basis.map_or(0, |bs| bs.rows());
        let stride = b * b + nproj * b;
        assert!(it.partials.len() >= shards * stride, "partials scratch too small");
        assert!(it.a_out.len() >= b * b, "block-dot buffer too small");
        assert!(it.projs.len() >= nproj * b, "projection buffer too small");
        let (v_prev, b_prev, basis) = (it.v_prev, it.b_prev, it.basis);
        if !v_prev.is_empty() {
            assert_eq!(v_prev.len(), b * n, "v_prev must be a column-major b x n panel");
            assert!(b_prev.len() >= b * b, "B_j coefficient buffer too small");
        }
        let y_ptr = SendPtr(y.as_mut_ptr());
        let p_ptr = SendPtr(it.partials.as_mut_ptr());
        self.pool.scope_chunks(shards, |i| {
            let p = parts[i];
            // SAFETY: as in `apply_fused` — partials slot `i` (stride
            // `b*b + nproj*b`) is written by exactly this task; the scoped
            // join outlives every use.
            let slot = unsafe { p_ptr.slice_mut(i * stride, stride) };
            slot.fill(0.0);
            // One 512-row window of the fused block sweep, shared by both
            // backings: `spmv` fills column `c`'s window of `w`, then the
            // Paige-reordered triangular subtraction, block dots, and
            // reorth projections run on it cache-hot. OOC chunk boundaries
            // align to these windows, so the window sequence — and every
            // f32/f64 accumulation order — is identical either way.
            let mut fuse_window = |r0: usize, r1: usize, spmv: &mut dyn FnMut(usize, &mut [f32])| {
                for c in 0..b {
                    // SAFETY: as above — windows of column `c` within this
                    // task's row stripe; disjoint across tasks.
                    let w_chunk = unsafe { y_ptr.slice_mut(c * n + r0, r1 - r0) };
                    spmv(c, w_chunk);
                    if !v_prev.is_empty() {
                        // w_c -= sum_{i >= c} B_j[c][i] * v_prev_i over the
                        // chunk rows (B_j is upper triangular).
                        for pv in c..b {
                            let coeff = b_prev[c * b + pv] as f32;
                            if coeff != 0.0 {
                                linalg::axpy(-coeff, &v_prev[pv * n + r0..pv * n + r1], w_chunk);
                            }
                        }
                    }
                    for r in 0..b {
                        slot[r * b + c] += linalg::dot(&x[r * n + r0..r * n + r1], w_chunk);
                    }
                    if let Some(basis) = basis {
                        basis.dots_range_add(
                            w_chunk,
                            r0,
                            r1,
                            &mut slot[b * b + c * nproj..b * b + (c + 1) * nproj],
                        );
                    }
                }
            };
            match &self.backing {
                MatrixBacking::Resident(m) => {
                    let mut r0 = p.row_start;
                    while r0 < p.row_end {
                        let r1 = (r0 + TOPK_ROW_CHUNK).min(p.row_end);
                        fuse_window(r0, r1, &mut |c, w| {
                            m.spmv_into_stripe(&x[c * n..(c + 1) * n], w, r0, r1)
                        });
                        r0 = r1;
                    }
                }
                MatrixBacking::Ooc(ooc) => {
                    let mut src = OocShardSource::new(Arc::clone(ooc), i);
                    while let Some(chunk) = src.next_chunk() {
                        let (c0, c1) = chunk.row_range();
                        let (rows, cols, vals) = (chunk.rows(), chunk.cols(), chunk.vals());
                        let (mut e0, mut r0) = (0usize, c0);
                        while r0 < c1 {
                            let r1 = (r0 + TOPK_ROW_CHUNK).min(c1);
                            let e1 = e0 + rows[e0..].partition_point(|&r| (r as usize) < r1);
                            fuse_window(r0, r1, &mut |c, w| {
                                w.fill(0.0);
                                let xc = &x[c * n..(c + 1) * n];
                                for e in e0..e1 {
                                    w[rows[e] as usize - r0] += vals[e].to_f32() * xc[cols[e] as usize];
                                }
                            });
                            (e0, r0) = (e1, r1);
                        }
                    }
                }
            }
        });
        // Merge Unit: fold the per-shard partials in shard order
        // (deterministic for a fixed CU count).
        for (e, a) in it.a_out.iter_mut().take(b * b).enumerate() {
            let mut acc = 0.0f64;
            for s in 0..shards {
                acc += it.partials[s * stride + e];
            }
            *a = acc;
        }
        for (j, proj) in it.projs.iter_mut().take(nproj * b).enumerate() {
            let mut acc = 0.0f64;
            for s in 0..shards {
                acc += it.partials[s * stride + b * b + j];
            }
            *proj = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q1_15;
    use crate::graphs;
    use crate::sparse::CooMatrix;

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn sharded_matches_serial() {
        let m = Arc::new(graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 3).to_csr());
        let pool = Arc::new(ThreadPool::new(5));
        let x: Vec<f32> = (0..m.nrows).map(|i| ((i * 37) % 11) as f32 * 0.1 - 0.5).collect();
        let serial = m.spmv(&x);
        for cus in [1, 2, 5, 8] {
            for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
                let sharded = ShardedSpmv::new(Arc::clone(&m), cus, policy, Arc::clone(&pool));
                let mut y = vec![0.0f32; m.nrows];
                sharded.apply(&x, &mut y);
                assert_eq!(serial, y, "cus={cus} policy={policy:?}");
                assert_eq!(sharded.applies(), 1);
            }
        }
    }

    #[test]
    fn partitions_tile_rows() {
        let m = Arc::new(graphs::mesh2d(40, 40, 0.9, 0.01, 5).to_csr());
        let pool = Arc::new(ThreadPool::new(4));
        let s = ShardedSpmv::new(Arc::clone(&m), 5, PartitionPolicy::BalancedNnz, pool);
        let parts = s.partitions();
        assert_eq!(parts.len(), 5);
        assert_eq!(s.cus(), 5);
        assert_eq!(parts[0].row_start, 0);
        assert_eq!(parts.last().unwrap().row_end, m.nrows);
        assert!(s.imbalance() >= 1.0);
        assert_eq!(s.policy(), PartitionPolicy::BalancedNnz);
    }

    #[test]
    fn empty_tail_shards_are_harmless() {
        // 3 rows across 8 shards: shards 3..8 are empty ranges. The engine
        // must still produce the exact serial result.
        let mut coo: CooMatrix = CooMatrix::new(3, 3);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 2.0);
        coo.push(2, 2, -1.0);
        let m = Arc::new(coo.to_csr());
        let x = vec![1.0f32, -2.0, 0.5];
        let serial = m.spmv(&x);
        for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
            let s = ShardedSpmv::with_own_pool(Arc::clone(&m), 8, policy);
            assert_eq!(s.cus(), 8);
            let mut y = vec![0.0f32; 3];
            s.apply(&x, &mut y);
            assert_eq!(serial, y, "policy={policy:?}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn concurrent_applies_on_one_shared_engine_are_bitwise_serial() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ShardedSpmv>();
        assert_send_sync::<ShardedSpmv<Q1_15>>();
        let m = Arc::new(graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 7).to_csr());
        let engine = Arc::new(ShardedSpmv::with_own_pool(Arc::clone(&m), 5, PartitionPolicy::BalancedNnz));
        let serial = m.spmv(&vec![0.25f32; m.nrows]);
        let threads = 4;
        let rounds = 8;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let engine = Arc::clone(&engine);
                let serial = &serial;
                s.spawn(move || {
                    let x = vec![0.25f32; engine.n()];
                    let mut y = vec![0.0f32; engine.n()];
                    for _ in 0..rounds {
                        engine.apply(&x, &mut y);
                        assert_eq!(&y, serial, "concurrent apply must equal the serial kernel");
                    }
                });
            }
        });
        assert_eq!(engine.applies(), threads * rounds);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn fused_block_sweep_matches_serial_reference_and_streams_once() {
        use crate::lanczos::{BasisArena, BasisDots, FusedBlockIteration};
        let m = Arc::new(graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 13).to_csr());
        let n = m.nrows;
        let b = 3usize;
        let x: Vec<f32> = (0..b * n).map(|i| ((i as f32) * 0.013).sin() * 0.4).collect();
        let v_prev: Vec<f32> = (0..b * n).map(|i| ((i as f32) * 0.021).cos() * 0.3).collect();
        let b_prev = [0.5f64, -0.1, 0.2, 0.0, 0.8, -0.3, 0.0, 0.0, 0.6];
        let mut basis: BasisArena<f32> = BasisArena::with_capacity(2, n);
        for r in 0..2 {
            let row = basis.alloc_row();
            for (i, v) in row.iter_mut().enumerate() {
                *v = ((r * n + i) as f32 * 0.002).sin() * 0.2;
            }
        }
        let nproj = basis.rows();
        // Serial reference through the default (CSR) implementation.
        let mut y_ref = vec![0.0f32; b * n];
        let mut a_ref = vec![0.0f64; b * b];
        let mut projs_ref = vec![0.0f64; nproj * b];
        let mut it_ref = FusedBlockIteration {
            b,
            v_prev: &v_prev,
            b_prev: &b_prev,
            basis: Some(&basis),
            partials: &mut [],
            a_out: &mut a_ref,
            projs: &mut projs_ref,
        };
        Operator::apply_fused_block(m.as_ref(), &x, &mut y_ref, &mut it_ref);
        for cus in [1usize, 3, 5, 8] {
            for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
                let engine = ShardedSpmv::with_own_pool(Arc::clone(&m), cus, policy);
                let mut y = vec![0.0f32; b * n];
                let mut a_out = vec![0.0f64; b * b];
                let mut projs = vec![0.0f64; nproj * b];
                let mut partials = vec![0.0f64; cus * (b * b + nproj * b)];
                let mut it = FusedBlockIteration {
                    b,
                    v_prev: &v_prev,
                    b_prev: &b_prev,
                    basis: Some(&basis),
                    partials: &mut partials,
                    a_out: &mut a_out,
                    projs: &mut projs,
                };
                engine.apply_fused_block(&x, &mut y, &mut it);
                assert_eq!(engine.applies(), 1, "one matrix stream per block pass, cus={cus}");
                // Panel entries are bitwise serial (per-row accumulation
                // order is unchanged by sharding/chunking)...
                assert_eq!(y, y_ref, "cus={cus} policy={policy:?}");
                // ...while the f64 reductions only differ by summation
                // order across chunks/shards.
                for e in 0..b * b {
                    assert!((a_out[e] - a_ref[e]).abs() < 1e-9, "A[{e}] cus={cus}: {} vs {}", a_out[e], a_ref[e]);
                }
                for j in 0..nproj * b {
                    assert!((projs[j] - projs_ref[j]).abs() < 1e-9, "proj[{j}] cus={cus}");
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn rebuild_shards_reuses_untouched_cus_and_matches_fresh_engine() {
        use crate::sparse::CooDelta;
        let mut coo = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 23);
        coo.canonicalize();
        let old = ShardedSpmv::with_own_pool(Arc::new(coo.to_csr()), 5, PartitionPolicy::BalancedNnz);
        // Pure value changes confined to the first few rows: nnz per row is
        // unchanged, so partition boundaries stay put and only the shard
        // holding those rows is dirty.
        let mut d = CooDelta::new(coo.nrows, coo.ncols);
        for i in 0..coo.nnz() {
            if (coo.rows[i] as usize) < 4 {
                d.upsert(coo.rows[i] as usize, coo.cols[i] as usize, coo.vals[i] * 1.25);
            }
        }
        d.canonicalize();
        let rep = coo.apply_delta(&d);
        assert!(!rep.dirty_rows.is_empty());
        let (rebuilt, stats) = old.rebuild_shards(Arc::new(coo.to_csr()), &rep.dirty_rows);
        assert_eq!(stats.rebuilt + stats.reused, 5);
        assert_eq!(stats.rebuilt, 1, "value-only delta in rows 0..4 dirties exactly the first shard: {stats:?}");
        assert!(stats.reused >= 4);
        // The rebuilt engine is indistinguishable from a fresh one.
        let fresh = ShardedSpmv::with_own_pool(Arc::new(coo.to_csr()), 5, PartitionPolicy::BalancedNnz);
        assert_eq!(rebuilt.partitions(), fresh.partitions());
        let x: Vec<f32> = (0..coo.nrows).map(|i| ((i * 31) % 17) as f32 * 0.05 - 0.4).collect();
        let (mut ya, mut yb) = (vec![0.0f32; coo.nrows], vec![0.0f32; coo.nrows]);
        rebuilt.apply(&x, &mut ya);
        fresh.apply(&x, &mut yb);
        assert_eq!(ya, yb);
        // Structural edits that move a boundary dirty the neighbours too.
        let mut grow = CooDelta::new(coo.nrows, coo.ncols);
        for c in 0..64 {
            grow.upsert(0, c, 0.5);
        }
        grow.canonicalize();
        let rep2 = coo.apply_delta(&grow);
        let (_, stats2) = rebuilt.rebuild_shards(Arc::new(coo.to_csr()), &rep2.dirty_rows);
        assert!(stats2.rebuilt >= 1);
        assert_eq!(stats2.rebuilt + stats2.reused, 5);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn own_pool_constructor_matches_shared_pool() {
        let m = Arc::new(graphs::erdos_renyi(200, 1600, 9).to_csr());
        let x: Vec<f32> = (0..200).map(|i| (i as f32 * 0.017).sin()).collect();
        let shared_pool = Arc::new(ThreadPool::new(3));
        let a = ShardedSpmv::new(Arc::clone(&m), 5, PartitionPolicy::BalancedNnz, shared_pool);
        let b = ShardedSpmv::with_own_pool(Arc::clone(&m), 5, PartitionPolicy::BalancedNnz);
        let (mut ya, mut yb) = (vec![0.0f32; 200], vec![0.0f32; 200]);
        a.apply(&x, &mut ya);
        b.apply(&x, &mut yb);
        assert_eq!(ya, yb);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn top_k_matches_serial_oracle_and_counts_one_apply() {
        let m = Arc::new(graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 41).to_csr());
        let x: Vec<f32> = (0..m.nrows).map(|i| ((i * 29) % 13) as f32 * 0.1 - 0.6).collect();
        for cus in [1usize, 3, 5, 8] {
            let engine = ShardedSpmv::with_own_pool(Arc::clone(&m), cus, PartitionPolicy::BalancedNnz);
            for k in [1usize, 8, m.nrows, m.nrows + 7] {
                let got = engine.top_k(&x, k);
                let want = crate::sparse::top_k_serial(&m, &x, k);
                assert_eq!(got, want, "cus={cus} k={k}");
            }
            assert_eq!(engine.applies(), 4, "one matrix stream per query");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn top_k_batch_is_bitwise_equal_to_independent_queries_and_streams_once() {
        let m = Arc::new(graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 51).to_csr());
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|q| (0..m.nrows).map(|i| ((i * 29 + q * 7) % 13) as f32 * 0.1 - 0.6).collect())
            .collect();
        for cus in [1usize, 3, 5, 8] {
            let engine = ShardedSpmv::with_own_pool(Arc::clone(&m), cus, PartitionPolicy::BalancedNnz);
            let batch = engine.top_k_batch(&xs, 8);
            assert_eq!(engine.applies(), 1, "one matrix stream per batch, cus={cus}");
            assert_eq!(batch.len(), 4);
            for (q, x) in xs.iter().enumerate() {
                let single = ShardedSpmv::with_own_pool(Arc::clone(&m), cus, PartitionPolicy::BalancedNnz);
                assert_eq!(batch[q], single.top_k(x, 8), "cus={cus} q={q}");
            }
            // Degenerate batches select nothing and stream nothing.
            assert!(engine.top_k_batch(&[], 8).is_empty());
            assert_eq!(engine.top_k_batch(&xs, 0), vec![Vec::new(); 4]);
            assert_eq!(engine.applies(), 1);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn early_exit_skips_cold_shards_and_stays_bitwise_exact() {
        // Skewed norms: rows 0..64 carry ~5 orders of magnitude more
        // weight than the rest, so under EqualRows all hot rows land in
        // shard 0 and every other shard is provably prunable once the
        // running top-k is full.
        let mut coo: CooMatrix = CooMatrix::new(512, 512);
        for r in 0..512usize {
            let w = if r < 64 { 8.0f32 } else { 1e-4 };
            coo.push(r, (r * 7 + 1) % 512, w);
            coo.push(r, (r * 13 + 5) % 512, w * 0.5);
        }
        let m = Arc::new(coo.to_csr());
        // A 2-worker pool under 8 shards: waves of 2, so prune checks fire
        // between joins.
        let pool = Arc::new(ThreadPool::new(2));
        let engine = ShardedSpmv::new(Arc::clone(&m), 8, PartitionPolicy::EqualRows, pool);
        let bounds = engine.row_l1_norms();
        let x = vec![1.0f32; 512];
        let (got, skipped) = engine.top_k_with_bounds(&x, 8, &bounds);
        assert!(skipped > 0, "cold shards must be pruned");
        assert_eq!(engine.shards_skipped(), skipped);
        assert_eq!(got, engine.top_k(&x, 8), "pruning changes bytes, never bits");
        // Batched variant: prune only when every member allows it; each
        // member stays bitwise-equal to its independent query.
        let xs: Vec<Vec<f32>> = vec![x.clone(), x.iter().map(|v| v * 0.5).collect()];
        let (batch, bskip) = engine.top_k_batch_with_bounds(&xs, 8, &bounds);
        assert!(bskip > 0);
        for (q, xq) in xs.iter().enumerate() {
            assert_eq!(batch[q], engine.top_k(xq, 8), "q={q}");
        }
        // Bounds on a flat-norm matrix stay harmless: whatever gets
        // pruned (likely nothing), the result is still bitwise-exact.
        let flat = Arc::new(graphs::mesh2d(20, 20, 0.9, 0.01, 3).to_csr());
        let fe = ShardedSpmv::with_own_pool(Arc::clone(&flat), 5, PartitionPolicy::EqualRows);
        let fx = vec![0.3f32; flat.nrows];
        let fb = fe.row_l1_norms();
        let (fres, _) = fe.top_k_with_bounds(&fx, 4, &fb);
        assert_eq!(fres, fe.top_k(&fx, 4));
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn seeded_engine_ppr_matches_cold_fixed_point_in_fewer_streams() {
        let m = Arc::new(graphs::mesh2d(12, 12, 0.9, 0.02, 7).to_csr());
        let opts = crate::sparse::PprOptions { source: 3, ..Default::default() };
        let engine = ShardedSpmv::with_own_pool(Arc::clone(&m), 5, PartitionPolicy::EqualRows);
        let colsums = engine.column_sums();
        let cold = engine.ppr_with_colsums(&opts, &colsums);
        assert!(cold.converged && !cold.warm_started);
        let warm = engine.ppr_with_colsums_seeded(&opts, &colsums, Some(&cold.scores));
        assert!(warm.converged && warm.warm_started);
        assert!(warm.iterations < cold.iterations, "warm {} vs cold {}", warm.iterations, cold.iterations);
        assert_eq!(engine.applies(), cold.iterations + warm.iterations, "one stream per iteration, warm or cold");
        for i in 0..m.nrows {
            assert!((warm.scores[i] as f64 - cold.scores[i] as f64).abs() < 1e-4);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn ppr_matches_serial_oracle_for_any_cu_count() {
        let m = Arc::new(graphs::mesh2d(12, 12, 0.9, 0.02, 7).to_csr());
        let opts = crate::sparse::PprOptions { source: 3, ..Default::default() };
        let serial = crate::sparse::ppr_serial(&m, &opts);
        for cus in [1usize, 3, 5, 8] {
            let engine = ShardedSpmv::with_own_pool(Arc::clone(&m), cus, PartitionPolicy::EqualRows);
            let got = engine.ppr(&opts);
            assert_eq!(got, serial, "cus={cus}");
            assert_eq!(engine.applies(), got.iterations, "one stream per iteration");
        }
        assert!(serial.converged);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn ooc_backed_engine_is_bitwise_equal_to_resident() {
        use crate::sparse::ooc::{scratch_dir, OocMatrix, PacketFileWriter};
        let dir = scratch_dir("engine");
        // 4096 rows over 3 shards = multiple 512-row windows per shard, and
        // the small chunk target splits each shard into several chunks, so
        // the double-buffer hand-off actually runs.
        let m = Arc::new(graphs::rmat(1 << 12, 8 << 12, 0.57, 0.19, 0.19, 63).to_csr());
        PacketFileWriter::new(&dir)
            .chunk_target_bytes(4096)
            .write_csr(m.as_ref(), 1.0, 3, PartitionPolicy::BalancedNnz)
            .expect("write");
        let ooc = OocMatrix::<f32>::open(&dir).expect("open");
        let resident = ShardedSpmv::with_own_pool(Arc::clone(&m), 3, PartitionPolicy::BalancedNnz);
        let streamed = ShardedSpmv::with_own_pool_ooc(Arc::clone(&ooc));
        assert!(streamed.is_ooc() && !resident.is_ooc());
        assert!(streamed.matrix().is_none() && streamed.ooc_matrix().is_some());
        assert_eq!(streamed.partitions(), resident.partitions());
        assert_eq!(streamed.nnz(), resident.nnz());
        // apply
        let x: Vec<f32> = (0..m.nrows).map(|i| ((i * 37) % 11) as f32 * 0.1 - 0.5).collect();
        let (mut ya, mut yb) = (vec![0.0f32; m.nrows], vec![0.0f32; m.nrows]);
        resident.apply(&x, &mut ya);
        streamed.apply(&x, &mut yb);
        assert_eq!(ya, yb, "OOC apply must be bitwise resident");
        // fused sweep (with Paige axpy + dot)
        let v_prev: Vec<f32> = (0..m.nrows).map(|i| ((i as f32) * 0.03).cos() * 0.2).collect();
        let shards = resident.fused_shards();
        let (mut pa, mut pb) = (vec![0.0f64; shards], vec![0.0f64; shards]);
        let mut it_a = FusedIteration {
            beta_prev: 0.7,
            v_prev: &v_prev,
            basis: None,
            partials: &mut pa,
            projs: &mut [],
        };
        let mut it_b = FusedIteration {
            beta_prev: 0.7,
            v_prev: &v_prev,
            basis: None,
            partials: &mut pb,
            projs: &mut [],
        };
        let (mut wa, mut wb) = (ya.clone(), ya.clone());
        let aa = resident.apply_fused(&x, &mut wa, &mut it_a);
        let ab = streamed.apply_fused(&x, &mut wb, &mut it_b);
        assert_eq!(wa, wb, "fused stripe must be bitwise resident");
        assert_eq!(aa.to_bits(), ab.to_bits(), "merged alpha must be bitwise resident");
        // top-k, query tables, PPR
        assert_eq!(streamed.top_k(&x, 8), resident.top_k(&x, 8));
        assert_eq!(streamed.row_l1_norms(), resident.row_l1_norms());
        assert_eq!(streamed.column_sums(), resident.column_sums());
        let opts = crate::sparse::PprOptions { source: 5, ..Default::default() };
        assert_eq!(streamed.ppr(&opts), resident.ppr(&opts));
        // telemetry moved bytes through the prefetcher
        assert!(streamed.io_bytes_read() > 0);
        assert!(streamed.prefetch_stalls() <= ooc.chunks_read());
        // OOC residency is the chunk-buffer pool, not the matrix. (At this
        // small scale the decoded buffers can rival the CSR itself — the
        // strict `ooc < resident` bound is asserted at streaming scale in
        // tests/ooc_stream.rs.)
        assert_eq!(streamed.resident_bytes(), ooc.buffer_bytes(), "OOC must charge O(buffer) bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy random fixture; mini_fused_datapath covers this path under Miri")]
    fn typed_engine_shrinks_stream_and_stays_close() {
        let mut coo = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 17);
        crate::sparse::normalize_frobenius(&mut coo);
        let f = Arc::new(coo.to_csr());
        let q = Arc::new(f.to_precision::<Q1_15>());
        let a = ShardedSpmv::with_own_pool(Arc::clone(&f), 5, PartitionPolicy::BalancedNnz);
        let b = ShardedSpmv::with_own_pool(Arc::clone(&q), 5, PartitionPolicy::BalancedNnz);
        // Storage telemetry: half the value bytes, 6 entries per line.
        assert_eq!(b.value_bytes(), a.value_bytes() / 2);
        assert_eq!(a.packet_entries_per_line(), 5);
        assert_eq!(b.packet_entries_per_line(), 6);
        assert!(b.packets_per_apply() < a.packets_per_apply());
        assert_eq!(a.format_name(), "f32");
        assert_eq!(b.format_name(), "q1.15");
        // Bytes accumulate per apply.
        let x: Vec<f32> = (0..f.nrows).map(|i| ((i * 13) % 7) as f32 * 0.1 - 0.3).collect();
        let (mut ya, mut yb) = (vec![0.0f32; f.nrows], vec![0.0f32; f.nrows]);
        a.apply(&x, &mut ya);
        b.apply(&x, &mut yb);
        b.apply(&x, &mut yb);
        assert_eq!(a.bytes_streamed(), a.bytes_per_apply());
        assert_eq!(b.bytes_streamed(), 2 * b.bytes_per_apply());
        assert!(b.bytes_per_apply() < a.bytes_per_apply());
        // Quantized result tracks the f32 reference within a row-scaled ulp.
        let bound = f.max_row_nnz() as f64 * <Q1_15 as Dataword>::ulp() + 1e-5;
        for (p, r) in yb.iter().zip(&ya) {
            assert!(((p - r).abs() as f64) <= bound, "{p} vs {r} (bound {bound})");
        }
    }

    #[test]
    fn mini_fused_datapath_matches_serial_on_a_tiny_fixture() {
        // Small deterministic fixture sized for Miri: the same checked
        // SendPtr paths the heavy tests cover (apply stripes, fused
        // partials slots, top-k batch slots) on a 24-row ring + diagonal,
        // 3 shards, pool of 2 — every `scope_chunks` here really forks.
        use crate::lanczos::FusedIteration;
        let n = 24usize;
        let mut coo: CooMatrix = CooMatrix::new(n, n);
        for i in 0..n {
            coo.push(i, (i + 1) % n, 0.5 + i as f32 * 0.01);
            coo.push((i + 1) % n, i, 0.5 + i as f32 * 0.01);
            coo.push(i, i, -0.25);
        }
        coo.canonicalize();
        let m = Arc::new(coo.to_csr());
        let x: Vec<f32> = (0..n).map(|i| ((i * 5) % 7) as f32 * 0.2 - 0.5).collect();
        let serial = m.spmv(&x);
        let pool = Arc::new(ThreadPool::new(2));
        let engine = ShardedSpmv::new(Arc::clone(&m), 3, PartitionPolicy::EqualRows, pool);
        // apply: stripe writes through the checked slice accessor.
        let mut y = vec![0.0f32; n];
        engine.apply(&x, &mut y);
        assert_eq!(serial, y);
        // apply_fused: stripe + partials-slot writes, no reorth basis.
        let v_prev = vec![0.0f32; n];
        let mut w = vec![0.0f32; n];
        let mut partials = vec![0.0f64; 3];
        let mut projs = [0.0f64; 0];
        let mut it = FusedIteration {
            beta_prev: 0.0,
            v_prev: &v_prev,
            basis: None,
            partials: &mut partials,
            projs: &mut projs,
        };
        let alpha = engine.apply_fused(&x, &mut w, &mut it);
        assert_eq!(w, serial, "fused stripe must equal the plain apply");
        let want: f64 = linalg::dot(&serial, &x);
        assert!((alpha - want).abs() <= 1e-9 * want.abs().max(1.0), "{alpha} vs {want}");
        // top_k_batch: per-shard heap slots through the checked set().
        let got = engine.top_k_batch(&[x.clone(), x.clone()], 3);
        let oracle = crate::sparse::top_k_serial(&m, &x, 3);
        assert_eq!(got, vec![oracle.clone(), oracle]);
    }
}

//! nnz-balanced row partitioner (§IV-B1).
//!
//! The paper splits the COO matrix across 5 SpMV CUs "by assigning an equal
//! number of rows to each CU". On power-law graphs equal *rows* can be very
//! unequal *work*, so we provide both policies: `EqualRows` reproduces the
//! paper exactly; `BalancedNnz` greedily equalizes non-zeros per shard and
//! is the default for the native engine (the ablation bench compares them).

use crate::sparse::CsrMatrix;

/// One CU shard: a contiguous row range plus its nnz count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowPartition {
    /// First row (inclusive).
    pub row_start: usize,
    /// Last row (exclusive).
    pub row_end: usize,
    /// Non-zeros inside the range.
    pub nnz: usize,
}

impl RowPartition {
    /// Number of rows in the shard.
    pub fn nrows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Partitioning policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartitionPolicy {
    /// Equal row counts per shard — the paper's scheme.
    EqualRows,
    /// Contiguous ranges with (approximately) equal nnz per shard.
    BalancedNnz,
}

/// Split `m` into `shards` contiguous row ranges under `policy`.
///
/// Always returns exactly `shards` partitions (possibly empty ones at the
/// tail for tiny matrices) whose ranges tile `[0, nrows)` exactly. Generic
/// over the stored scalar: partitioning reads only the index structure.
pub fn partition_rows_balanced<V: crate::fixed::Dataword>(
    m: &CsrMatrix<V>,
    shards: usize,
    policy: PartitionPolicy,
) -> Vec<RowPartition> {
    assert!(shards >= 1);
    let nrows = m.nrows;
    let total_nnz = m.nnz();
    let mut out = Vec::with_capacity(shards);
    match policy {
        PartitionPolicy::EqualRows => {
            let base = nrows / shards;
            let extra = nrows % shards;
            let mut r0 = 0usize;
            for s in 0..shards {
                let len = base + usize::from(s < extra);
                let r1 = r0 + len;
                out.push(RowPartition { row_start: r0, row_end: r1, nnz: m.indptr[r1] - m.indptr[r0] });
                r0 = r1;
            }
        }
        PartitionPolicy::BalancedNnz => {
            // Take-or-leave against global prefix targets: shard `s` ends at
            // the row whose cumulative nnz lands closest to
            // `(s+1) * total / shards`. Including the boundary row when
            // that lands *closer* to the target (instead of the old
            // never-exceed greedy, which left every shard light and dumped
            // the accumulated leftover on the last shard) keeps every
            // boundary within half the boundary row's nnz of its target,
            // so `max shard nnz <= ideal + max_row_nnz` — the bound the
            // property test pins on power-law graphs. Boundaries are
            // monotone; a row heavier than several targets legitimately
            // yields empty shards beside it.
            let mut r0 = 0usize;
            for s in 0..shards {
                let mut r1 = r0;
                if s == shards - 1 {
                    r1 = nrows;
                } else {
                    let target = total_nnz as f64 * (s + 1) as f64 / shards as f64;
                    while r1 < nrows && (m.indptr[r1 + 1] as f64) <= target {
                        r1 += 1;
                    }
                    // Boundary row: take it iff overshooting is closer to
                    // the target than stopping short.
                    if r1 < nrows {
                        let under = m.indptr[r1] as f64;
                        let over = m.indptr[r1 + 1] as f64;
                        if over - target < target - under {
                            r1 += 1;
                        }
                    }
                }
                let nnz = m.indptr[r1] - m.indptr[r0];
                out.push(RowPartition { row_start: r0, row_end: r1, nnz });
                r0 = r1;
            }
        }
    }
    debug_assert_eq!(out.len(), shards);
    debug_assert_eq!(out.first().unwrap().row_start, 0);
    debug_assert_eq!(out.last().unwrap().row_end, nrows);
    out
}

/// Ratio of the heaviest shard's nnz to the ideal (total/shards): 1.0 is a
/// perfect balance. Used by the partition ablation.
pub fn imbalance(parts: &[RowPartition]) -> f64 {
    let total: usize = parts.iter().map(|p| p.nnz).sum();
    if total == 0 {
        return 1.0;
    }
    let ideal = total as f64 / parts.len() as f64;
    parts.iter().map(|p| p.nnz as f64).fold(0.0, f64::max) / ideal
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    /// Matrix with a skewed row distribution: row 0 holds half the nnz.
    fn skewed(n: usize) -> CsrMatrix {
        let mut m: CooMatrix = CooMatrix::new(n, n);
        for c in 0..n {
            m.push(0, c, 1.0);
        }
        for r in 1..n {
            m.push(r, r, 1.0);
        }
        m.to_csr()
    }

    #[test]
    fn tiles_are_exact_and_cover() {
        for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
            let m = skewed(100);
            let parts = partition_rows_balanced(&m, 5, policy);
            assert_eq!(parts.len(), 5);
            assert_eq!(parts[0].row_start, 0);
            assert_eq!(parts.last().unwrap().row_end, 100);
            for w in parts.windows(2) {
                assert_eq!(w[0].row_end, w[1].row_start, "ranges must tile");
            }
            let nnz: usize = parts.iter().map(|p| p.nnz).sum();
            assert_eq!(nnz, m.nnz());
        }
    }

    #[test]
    fn equal_rows_matches_paper_scheme() {
        let m = skewed(103);
        let parts = partition_rows_balanced(&m, 5, PartitionPolicy::EqualRows);
        let sizes: Vec<usize> = parts.iter().map(|p| p.nrows()).collect();
        assert_eq!(sizes, vec![21, 21, 21, 20, 20]);
    }

    #[test]
    fn balanced_nnz_beats_equal_rows_on_skew() {
        let m = skewed(1000);
        let eq = partition_rows_balanced(&m, 5, PartitionPolicy::EqualRows);
        let bal = partition_rows_balanced(&m, 5, PartitionPolicy::BalancedNnz);
        assert!(imbalance(&bal) < imbalance(&eq), "bal={} eq={}", imbalance(&bal), imbalance(&eq));
    }

    #[test]
    fn balanced_nnz_near_ideal_on_moderate_skew() {
        // Skew spread across rows (not one pathological row): the greedy
        // partitioner should land close to the ideal split.
        let n = 1000;
        let mut m: CooMatrix = CooMatrix::new(n, n);
        for r in 0..n {
            let deg = 1 + (r % 10);
            for d in 0..deg {
                m.push(r, (r + d + 1) % n, 1.0);
            }
        }
        let csr = m.to_csr();
        let bal = partition_rows_balanced(&csr, 5, PartitionPolicy::BalancedNnz);
        assert!(imbalance(&bal) < 1.15, "imbalance {}", imbalance(&bal));
    }

    #[test]
    fn more_shards_than_rows() {
        let m = skewed(3);
        let parts = partition_rows_balanced(&m, 8, PartitionPolicy::EqualRows);
        assert_eq!(parts.len(), 8);
        assert_eq!(parts.iter().map(|p| p.nrows()).sum::<usize>(), 3);
        assert_eq!(parts.last().unwrap().row_end, 3);
    }

    /// The take-or-leave bound: every boundary lands within half the
    /// boundary row's nnz of its prefix target, so no shard exceeds
    /// `ideal + max_row_nnz`. Property-checked on power-law (R-MAT)
    /// graphs, where the old never-exceed greedy left every shard light
    /// and dumped the leftover on the last shard.
    #[test]
    fn balanced_nnz_imbalance_bounded_on_power_law_graphs() {
        for seed in [3u64, 17, 40] {
            let m = crate::graphs::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, seed).to_csr();
            let max_row = m.max_row_nnz() as f64;
            for shards in [3usize, 5, 8] {
                let parts = partition_rows_balanced(&m, shards, PartitionPolicy::BalancedNnz);
                let ideal = m.nnz() as f64 / shards as f64;
                let bound = 1.0 + max_row / ideal + 1e-9;
                assert!(
                    imbalance(&parts) <= bound,
                    "seed={seed} shards={shards}: imbalance {} > bound {bound}",
                    imbalance(&parts)
                );
                // Tiling invariants hold alongside the balance bound.
                assert_eq!(parts.len(), shards);
                assert_eq!(parts[0].row_start, 0);
                assert_eq!(parts.last().unwrap().row_end, m.nrows);
                assert_eq!(parts.iter().map(|p| p.nnz).sum::<usize>(), m.nnz());
            }
        }
    }

    #[test]
    fn single_shard_is_whole_matrix() {
        let m = skewed(10);
        let parts = partition_rows_balanced(&m, 1, PartitionPolicy::BalancedNnz);
        assert_eq!(parts, vec![RowPartition { row_start: 0, row_end: 10, nnz: m.nnz() }]);
    }
}

//! Coordinate-format (COO) sparse matrix.
//!
//! COO is the paper's on-device layout: each non-zero is a `(row, col, val)`
//! triple of 32-bit words, five of which fit a 512-bit HBM packet (§IV-B1).
//! Unlike CSR, COO streaming has no indirect index chain, which is what
//! makes the fully-pipelined dataflow SpMV possible.

use crate::sparse::CsrMatrix;

/// Sparse matrix in coordinate format with `f32` values (the paper's device
/// word is 32 bits).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CooMatrix {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row index per non-zero.
    pub rows: Vec<u32>,
    /// Column index per non-zero.
    pub cols: Vec<u32>,
    /// Value per non-zero.
    pub vals: Vec<f32>,
}

impl CooMatrix {
    /// Empty `nrows x ncols` matrix.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    /// Build from parallel triplet arrays. Panics if lengths differ or any
    /// index is out of bounds.
    pub fn from_triplets(nrows: usize, ncols: usize, rows: Vec<u32>, cols: Vec<u32>, vals: Vec<f32>) -> Self {
        assert_eq!(rows.len(), cols.len());
        assert_eq!(rows.len(), vals.len());
        debug_assert!(rows.iter().all(|&r| (r as usize) < nrows), "row index out of bounds");
        debug_assert!(cols.iter().all(|&c| (c as usize) < ncols), "col index out of bounds");
        Self { nrows, ncols, rows, cols, vals }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one entry.
    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.nrows && c < self.ncols);
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    /// Fraction of cells that are non-zero (Table II "Sparsity").
    pub fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// COO memory footprint in bytes (3 x 32-bit words per nnz, Table II
    /// "Size" convention).
    pub fn size_bytes(&self) -> usize {
        self.nnz() * 12
    }

    /// Sort entries by `(row, col)` and sum duplicates. Canonical form used
    /// before CSR conversion and device packetization.
    pub fn canonicalize(&mut self) {
        let mut idx: Vec<usize> = (0..self.nnz()).collect();
        idx.sort_unstable_by_key(|&i| (self.rows[i], self.cols[i]));
        let (mut rows, mut cols, mut vals) =
            (Vec::with_capacity(self.nnz()), Vec::with_capacity(self.nnz()), Vec::with_capacity(self.nnz()));
        for &i in &idx {
            if let (Some(&lr), Some(&lc)) = (rows.last(), cols.last()) {
                if lr == self.rows[i] && lc == self.cols[i] {
                    *vals.last_mut().unwrap() += self.vals[i];
                    continue;
                }
            }
            rows.push(self.rows[i]);
            cols.push(self.cols[i]);
            vals.push(self.vals[i]);
        }
        self.rows = rows;
        self.cols = cols;
        self.vals = vals;
    }

    /// Symmetrize: `M <- (M + M^T) / 2` structurally (entries mirrored; the
    /// average keeps eigenvalues of already-symmetric inputs unchanged).
    /// The Lanczos phase requires a symmetric operator.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.nrows, self.ncols, "symmetrize needs a square matrix");
        let n = self.nnz();
        let mut rows = Vec::with_capacity(2 * n);
        let mut cols = Vec::with_capacity(2 * n);
        let mut vals = Vec::with_capacity(2 * n);
        for i in 0..n {
            let (r, c, v) = (self.rows[i], self.cols[i], self.vals[i] * 0.5);
            rows.push(r);
            cols.push(c);
            vals.push(v);
            rows.push(c);
            cols.push(r);
            vals.push(v);
        }
        self.rows = rows;
        self.cols = cols;
        self.vals = vals;
        self.canonicalize();
    }

    /// Dense `y = M x` reference (test oracle; O(nnz)).
    pub fn spmv_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0f32; self.nrows];
        for i in 0..self.nnz() {
            y[self.rows[i] as usize] += self.vals[i] * x[self.cols[i] as usize];
        }
        y
    }

    /// Convert to CSR (canonicalizes a copy first).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut c = self.clone();
        c.canonicalize();
        CsrMatrix::from_canonical_coo(&c)
    }

    /// Check structural symmetry (entry (r,c) implies (c,r) with equal
    /// value up to `tol`). O(nnz log nnz).
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let mut map = std::collections::HashMap::with_capacity(self.nnz());
        for i in 0..self.nnz() {
            *map.entry((self.rows[i], self.cols[i])).or_insert(0.0f32) += self.vals[i];
        }
        map.iter().all(|(&(r, c), &v)| {
            let vt = map.get(&(c, r)).copied().unwrap_or(0.0);
            (v - vt).abs() <= tol * v.abs().max(vt.abs()).max(1.0)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CooMatrix {
        // [[1, 2, 0],
        //  [0, 3, 4],
        //  [5, 0, 6]]
        CooMatrix::from_triplets(
            3,
            3,
            vec![0, 0, 1, 1, 2, 2],
            vec![0, 1, 1, 2, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        )
    }

    #[test]
    fn spmv_ref_matches_hand_computation() {
        let m = sample();
        let y = m.spmv_ref(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn canonicalize_sorts_and_merges() {
        let mut m = CooMatrix::from_triplets(
            2,
            2,
            vec![1, 0, 1, 0],
            vec![0, 1, 0, 1],
            vec![1.0, 2.0, 3.0, 4.0],
        );
        m.canonicalize();
        assert_eq!(m.rows, vec![0, 1]);
        assert_eq!(m.cols, vec![1, 0]);
        assert_eq!(m.vals, vec![6.0, 4.0]);
    }

    #[test]
    fn symmetrize_produces_symmetric_matrix() {
        let mut m = sample();
        assert!(!m.is_symmetric(1e-6));
        m.symmetrize();
        assert!(m.is_symmetric(1e-6));
        // Diagonal preserved exactly: (1, 3, 6).
        let d: Vec<f32> = (0..3)
            .map(|i| {
                (0..m.nnz())
                    .filter(|&k| m.rows[k] == i && m.cols[k] == i)
                    .map(|k| m.vals[k])
                    .sum()
            })
            .collect();
        assert_eq!(d, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn density_and_size() {
        let m = sample();
        assert!((m.density() - 6.0 / 9.0).abs() < 1e-12);
        assert_eq!(m.size_bytes(), 72);
    }

    #[test]
    fn to_csr_round_trips_spmv() {
        let m = sample();
        let csr = m.to_csr();
        let x = [0.5f32, -1.0, 2.0];
        assert_eq!(m.spmv_ref(&x), csr.spmv(&x));
    }

    #[test]
    fn empty_matrix_is_fine() {
        let m = CooMatrix::new(4, 4);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.spmv_ref(&[1.0; 4]), vec![0.0; 4]);
        assert_eq!(m.density(), 0.0);
    }
}

//! Coordinate-format (COO) sparse matrix, generic over the stored scalar.
//!
//! COO is the paper's on-device layout: each non-zero is a `(row, col, val)`
//! triple — two 32-bit indices plus one [`Dataword`]-wide value — packed
//! into 512-bit HBM lines (§IV-B1). Unlike CSR, COO streaming has no
//! indirect index chain, which is what makes the fully-pipelined dataflow
//! SpMV possible. The value array is generic over [`Dataword`] so the
//! mixed-precision datapath stores 16-bit words as 16 bits, not as rounded
//! f32s; arithmetic (duplicate merging, the `spmv_ref` oracle) still
//! accumulates in float, matching the design's float units (§IV).

use crate::fixed::Dataword;
use crate::sparse::{CooDelta, CsrMatrix, DeltaApply};

/// Sparse matrix in coordinate format. `V` is the stored value scalar
/// (default `f32`, the paper's host word; `Q1_31`/`Q2_30`/`Q1_15` for the
/// device datapath).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CooMatrix<V: Dataword = f32> {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row index per non-zero.
    pub rows: Vec<u32>,
    /// Column index per non-zero.
    pub cols: Vec<u32>,
    /// Value per non-zero, stored in format `V`.
    pub vals: Vec<V>,
}

impl<V: Dataword> CooMatrix<V> {
    /// Empty `nrows x ncols` matrix.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    /// Build from parallel triplet arrays. Panics if lengths differ or any
    /// index is out of bounds.
    pub fn from_triplets(nrows: usize, ncols: usize, rows: Vec<u32>, cols: Vec<u32>, vals: Vec<V>) -> Self {
        assert_eq!(rows.len(), cols.len());
        assert_eq!(rows.len(), vals.len());
        debug_assert!(rows.iter().all(|&r| (r as usize) < nrows), "row index out of bounds");
        debug_assert!(cols.iter().all(|&c| (c as usize) < ncols), "col index out of bounds");
        Self { nrows, ncols, rows, cols, vals }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one entry.
    pub fn push(&mut self, r: usize, c: usize, v: V) {
        debug_assert!(r < self.nrows && c < self.ncols);
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    /// Fraction of cells that are non-zero (Table II "Sparsity").
    pub fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// COO memory footprint in bytes: two 32-bit indices plus one
    /// `V::BITS`-wide value per nnz (Table II "Size" convention — 12 bytes
    /// per entry at f32, 10 at Q1.15).
    pub fn size_bytes(&self) -> usize {
        self.nnz() * (8 + V::bytes())
    }

    /// Bytes occupied by the value array alone — the quantity the
    /// mixed-precision storage halves at Q1.15.
    pub fn value_bytes(&self) -> usize {
        self.nnz() * V::bytes()
    }

    /// Re-store the value array in format `W` (quantizing through f32),
    /// keeping the index arrays identical. This is the storage-side
    /// conversion the coordinator applies when a solve requests a
    /// fixed-point datapath.
    pub fn to_precision<W: Dataword>(&self) -> CooMatrix<W> {
        CooMatrix {
            nrows: self.nrows,
            ncols: self.ncols,
            rows: self.rows.clone(),
            cols: self.cols.clone(),
            vals: self.vals.iter().map(|v| W::from_f32(v.to_f32())).collect(),
        }
    }

    /// Sort entries by `(row, col)` and sum duplicates (float accumulation,
    /// re-stored in `V`). Canonical form used before CSR conversion and
    /// device packetization.
    pub fn canonicalize(&mut self) {
        let mut idx: Vec<usize> = (0..self.nnz()).collect();
        idx.sort_unstable_by_key(|&i| (self.rows[i], self.cols[i]));
        let (mut rows, mut cols, mut vals): (Vec<u32>, Vec<u32>, Vec<V>) =
            (Vec::with_capacity(self.nnz()), Vec::with_capacity(self.nnz()), Vec::with_capacity(self.nnz()));
        for &i in &idx {
            if let (Some(&lr), Some(&lc)) = (rows.last(), cols.last()) {
                if lr == self.rows[i] && lc == self.cols[i] {
                    let last = vals.last_mut().unwrap();
                    *last = V::from_f32(last.to_f32() + self.vals[i].to_f32());
                    continue;
                }
            }
            rows.push(self.rows[i]);
            cols.push(self.cols[i]);
            vals.push(self.vals[i]);
        }
        self.rows = rows;
        self.cols = cols;
        self.vals = vals;
    }

    /// Symmetrize: `M <- (M + M^T) / 2` structurally (entries mirrored; the
    /// average keeps eigenvalues of already-symmetric inputs unchanged).
    /// The Lanczos phase requires a symmetric operator.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.nrows, self.ncols, "symmetrize needs a square matrix");
        let n = self.nnz();
        let mut rows = Vec::with_capacity(2 * n);
        let mut cols = Vec::with_capacity(2 * n);
        let mut vals = Vec::with_capacity(2 * n);
        for i in 0..n {
            let (r, c) = (self.rows[i], self.cols[i]);
            let v = V::from_f32(self.vals[i].to_f32() * 0.5);
            rows.push(r);
            cols.push(c);
            vals.push(v);
            rows.push(c);
            cols.push(r);
            vals.push(v);
        }
        self.rows = rows;
        self.cols = cols;
        self.vals = vals;
        self.canonicalize();
    }

    /// Content hash of the matrix: dimensions plus every `(row, col, val)`
    /// triplet in stored order (FNV-1a over the raw words; values hash
    /// their f32 bit pattern so `-0.0 != 0.0` but equal matrices in equal
    /// storage formats always collide). The registry uses this for
    /// register-time deduplication — hash first, full `==` compare on a
    /// hash match — so entry *order* matters: canonicalize before hashing
    /// to get order-independent identity.
    pub fn content_hash(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut mix = |word: u64| {
            for b in word.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        mix(self.nrows as u64);
        mix(self.ncols as u64);
        for i in 0..self.nnz() {
            mix(self.rows[i] as u64);
            mix(self.cols[i] as u64);
            mix(self.vals[i].to_f32().to_bits() as u64);
        }
        h
    }

    /// Splice a canonical [`CooDelta`] into this **canonical** matrix:
    /// insertions, value changes, and deletions applied in one two-pointer
    /// merge over the sorted triplets — `O(nnz + d)`, no re-sort, entries
    /// stay canonical. Returns the [`DeltaApply`] report (dirty rows, op
    /// counts, `||delta||_F`) the registry's incremental re-prep and
    /// warm-start guard consume.
    ///
    /// Panics if dimensions differ or the delta is not canonical; callers
    /// are responsible for [`CooDelta::canonicalize`] (the registry does
    /// this on ingest).
    pub fn apply_delta(&mut self, delta: &CooDelta) -> DeltaApply {
        assert_eq!((self.nrows, self.ncols), (delta.nrows, delta.ncols), "delta dimension mismatch");
        assert!(delta.is_canonical(), "canonicalize the delta before applying");
        debug_assert!(
            (1..self.nnz()).all(|i| (self.rows[i - 1], self.cols[i - 1]) < (self.rows[i], self.cols[i])),
            "apply_delta requires a canonical matrix"
        );
        let cap = self.nnz() + delta.len();
        let (mut rows, mut cols, mut vals) =
            (Vec::with_capacity(cap), Vec::with_capacity(cap), Vec::with_capacity(cap));
        let old = self.rows.iter().zip(&self.cols).zip(&self.vals).map(|((&r, &c), &v)| (r, c, v));
        let report = crate::sparse::delta::splice(old, &delta.entries, |r, c, v| {
            rows.push(r);
            cols.push(c);
            vals.push(v);
        });
        self.rows = rows;
        self.cols = cols;
        self.vals = vals;
        report
    }

    /// Dense `y = M x` reference (test oracle; O(nnz), f32 accumulation).
    pub fn spmv_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0f32; self.nrows];
        for i in 0..self.nnz() {
            y[self.rows[i] as usize] += self.vals[i].to_f32() * x[self.cols[i] as usize];
        }
        y
    }

    /// Convert to CSR (canonicalizes a copy first).
    pub fn to_csr(&self) -> CsrMatrix<V> {
        let mut c = self.clone();
        c.canonicalize();
        CsrMatrix::from_canonical_coo(&c)
    }

    /// Check structural symmetry (entry (r,c) implies (c,r) with equal
    /// value up to `tol`). O(nnz log nnz).
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let mut map = std::collections::HashMap::with_capacity(self.nnz());
        for i in 0..self.nnz() {
            *map.entry((self.rows[i], self.cols[i])).or_insert(0.0f32) += self.vals[i].to_f32();
        }
        map.iter().all(|(&(r, c), &v)| {
            let vt = map.get(&(c, r)).copied().unwrap_or(0.0);
            (v - vt).abs() <= tol * v.abs().max(vt.abs()).max(1.0)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q1_15;

    fn sample() -> CooMatrix {
        // [[1, 2, 0],
        //  [0, 3, 4],
        //  [5, 0, 6]]
        CooMatrix::from_triplets(
            3,
            3,
            vec![0, 0, 1, 1, 2, 2],
            vec![0, 1, 1, 2, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        )
    }

    #[test]
    fn spmv_ref_matches_hand_computation() {
        let m = sample();
        let y = m.spmv_ref(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn canonicalize_sorts_and_merges() {
        let mut m: CooMatrix = CooMatrix::from_triplets(
            2,
            2,
            vec![1, 0, 1, 0],
            vec![0, 1, 0, 1],
            vec![1.0, 2.0, 3.0, 4.0],
        );
        m.canonicalize();
        assert_eq!(m.rows, vec![0, 1]);
        assert_eq!(m.cols, vec![1, 0]);
        assert_eq!(m.vals, vec![6.0, 4.0]);
    }

    #[test]
    fn symmetrize_produces_symmetric_matrix() {
        let mut m = sample();
        assert!(!m.is_symmetric(1e-6));
        m.symmetrize();
        assert!(m.is_symmetric(1e-6));
        // Diagonal preserved exactly: (1, 3, 6).
        let d: Vec<f32> = (0..3)
            .map(|i| {
                (0..m.nnz())
                    .filter(|&k| m.rows[k] == i && m.cols[k] == i)
                    .map(|k| m.vals[k])
                    .sum()
            })
            .collect();
        assert_eq!(d, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn density_and_size() {
        let m = sample();
        assert!((m.density() - 6.0 / 9.0).abs() < 1e-12);
        assert_eq!(m.size_bytes(), 72);
        assert_eq!(m.value_bytes(), 24);
    }

    #[test]
    fn to_csr_round_trips_spmv() {
        let m = sample();
        let csr = m.to_csr();
        let x = [0.5f32, -1.0, 2.0];
        assert_eq!(m.spmv_ref(&x), csr.spmv(&x));
    }

    #[test]
    fn empty_matrix_is_fine() {
        let m: CooMatrix = CooMatrix::new(4, 4);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.spmv_ref(&[1.0; 4]), vec![0.0; 4]);
        assert_eq!(m.density(), 0.0);
    }

    #[test]
    fn content_hash_tracks_content_not_identity() {
        let a = sample();
        let b = sample();
        assert_eq!(a.content_hash(), b.content_hash(), "equal matrices hash equal");
        let mut c = sample();
        c.vals[0] = 1.5;
        assert_ne!(a.content_hash(), c.content_hash(), "value change must change the hash");
        let mut d = sample();
        d.rows[0] = 1;
        assert_ne!(a.content_hash(), d.content_hash(), "structure change must change the hash");
        // Entry order matters pre-canonicalization; canonical forms agree.
        let mut e = CooMatrix::from_triplets(
            3,
            3,
            vec![0, 0, 1, 1, 2, 2],
            vec![1, 0, 2, 1, 2, 0],
            vec![2.0, 1.0, 4.0, 3.0, 6.0, 5.0],
        );
        assert_ne!(a.content_hash(), e.content_hash());
        let mut a2 = sample();
        a2.canonicalize();
        e.canonicalize();
        assert_eq!(a2.content_hash(), e.content_hash(), "canonical identity is order-free");
    }

    #[test]
    fn apply_delta_splices_inserts_changes_and_deletes() {
        use crate::sparse::CooDelta;
        let mut m = sample();
        m.canonicalize();
        let mut d = CooDelta::new(3, 3);
        d.upsert(0, 2, 9.0); // insert
        d.upsert(1, 1, -3.0); // value change
        d.delete(2, 0); // delete
        d.delete(1, 0); // absent: no-op
        d.canonicalize();
        let rep = m.apply_delta(&d);
        assert_eq!((rep.inserted, rep.changed, rep.deleted, rep.noops), (1, 1, 1, 1));
        assert_eq!(rep.dirty_rows, vec![0, 1, 2]);
        // The spliced matrix equals rebuilding the mutated matrix from
        // scratch and canonicalizing.
        let expect = CooMatrix::from_triplets(
            3,
            3,
            vec![0, 0, 0, 1, 1, 2],
            vec![0, 1, 2, 1, 2, 2],
            vec![1.0, 2.0, 9.0, -3.0, 4.0, 6.0],
        );
        assert_eq!(m, expect);
        // Result is still canonical: a second delta applies cleanly.
        let mut d2 = CooDelta::new(3, 3);
        d2.upsert(0, 2, 9.0); // identical value: no-op, not dirty
        d2.canonicalize();
        let rep2 = m.apply_delta(&d2);
        assert_eq!(rep2.effective(), 0);
        assert!(rep2.dirty_rows.is_empty());
        assert_eq!(rep2.noops, 1);
    }

    #[test]
    fn apply_delta_matches_scratch_rebuild_on_random_edits() {
        use crate::sparse::{CooDelta, DeltaOp};
        let mut m = crate::graphs::rmat(1 << 7, 6 << 7, 0.57, 0.19, 0.19, 9);
        m.canonicalize();
        let mut d = CooDelta::new(m.nrows, m.ncols);
        // Deterministic mixed edits: change every 7th entry, delete every
        // 11th, insert a few fresh coordinates.
        for i in (0..m.nnz()).step_by(7) {
            d.upsert(m.rows[i] as usize, m.cols[i] as usize, m.vals[i] * 1.5 + 0.01);
        }
        for i in (0..m.nnz()).step_by(11) {
            d.delete(m.rows[i] as usize, m.cols[i] as usize);
        }
        for r in 0..8 {
            d.upsert(r, (r * 13 + 1) % m.ncols, 0.25);
        }
        d.canonicalize();
        let mut spliced = m.clone();
        let rep = spliced.apply_delta(&d);
        assert!(rep.effective() > 0);
        // Oracle: apply the ops through a map and rebuild from scratch.
        let mut map: std::collections::BTreeMap<(u32, u32), f32> =
            (0..m.nnz()).map(|i| ((m.rows[i], m.cols[i]), m.vals[i])).collect();
        for &(r, c, op) in &d.entries {
            match op {
                DeltaOp::Upsert(v) => {
                    map.insert((r, c), v);
                }
                DeltaOp::Delete => {
                    map.remove(&(r, c));
                }
            }
        }
        let mut oracle = CooMatrix::new(m.nrows, m.ncols);
        for (&(r, c), &v) in &map {
            oracle.push(r as usize, c as usize, v);
        }
        assert_eq!(spliced, oracle);
    }

    #[test]
    fn typed_storage_shrinks_value_array() {
        // Values bounded in (-1, 1) — the post-normalization regime.
        let mut m: CooMatrix = CooMatrix::new(8, 8);
        for i in 0..8 {
            m.push(i, (i + 3) % 8, (i as f32 / 10.0) - 0.35);
        }
        let q: CooMatrix<Q1_15> = m.to_precision::<Q1_15>();
        assert_eq!(q.nnz(), m.nnz());
        assert_eq!(q.value_bytes(), m.value_bytes() / 2, "Q1.15 must halve value bytes");
        assert_eq!(q.size_bytes(), m.nnz() * 10);
        // Quantization stays within one step; indices are untouched.
        assert_eq!(q.rows, m.rows);
        assert_eq!(q.cols, m.cols);
        for (qv, fv) in q.vals.iter().zip(&m.vals) {
            assert!(((qv.to_f32() - fv).abs() as f64) <= <Q1_15 as Dataword>::ulp());
        }
    }

    #[test]
    fn typed_spmv_ref_tracks_f32_within_ulp() {
        let mut m: CooMatrix = CooMatrix::new(16, 16);
        for i in 0..16 {
            m.push(i, i, 0.5 - (i as f32) / 40.0);
            m.push(i, (i + 1) % 16, 0.125);
        }
        let x: Vec<f32> = (0..16).map(|i| ((i as f32) * 0.37).sin() * 0.9).collect();
        let y_ref = m.spmv_ref(&x);
        let q = m.to_precision::<Q1_15>();
        let y_q = q.spmv_ref(&x);
        for (a, b) in y_q.iter().zip(&y_ref) {
            // Two entries per row, |x| < 1: error bounded by 2 * ulp/2.
            assert!(((a - b).abs() as f64) <= 2.0 * <Q1_15 as Dataword>::ulp(), "{a} vs {b}");
        }
    }
}

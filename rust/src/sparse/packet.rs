//! 512-bit COO packet stream — the paper's HBM read unit (§IV-B1) — generic
//! over the stored value scalar.
//!
//! Each HBM transaction delivers a 512-bit line. A COO entry is two 32-bit
//! indices plus one [`Dataword`]-wide value, so capacity depends on the
//! storage format ([`packet_capacity`]): **5 entries** per line at f32
//! (480/512 bits used) and **6 entries** at 16-bit Q1.15 (480/512 bits) —
//! smaller datawords move more non-zeros per transaction, which is the
//! §IV-B1 bandwidth argument for the mixed-precision datapath. The Matrix
//! Fetch Unit consumes one packet per clock cycle in maximum-length AXI
//! bursts; the [`PacketStream`] iterator reproduces that granularity so
//! both the native SpMV engine and the FPGA timing model can account
//! per-packet work exactly as the hardware would.

use crate::fixed::{packet_capacity, Dataword};
use crate::sparse::CooMatrix;

/// Bits per HBM transaction line.
pub const PACKET_BITS: usize = crate::fixed::LINE_BITS as usize;
/// COO entries per packet at the 32-bit baseline word:
/// `floor(512 / (32 + 32 + 32))`.
pub const PACKET_NNZ: usize = packet_capacity(32);
/// Upper bound on entries per line across all supported datawords (6 at
/// 16-bit values); sizes the fixed packet arrays.
pub const PACKET_MAX_NNZ: usize = packet_capacity(16);

/// One 512-bit line: up to [`CooPacket::capacity`] `(row, col, val)`
/// entries; `len < capacity` only for the final packet of a shard.
#[derive(Clone, Copy, Debug)]
pub struct CooPacket<V: Dataword = f32> {
    /// Row indices (valid up to `len`).
    pub rows: [u32; PACKET_MAX_NNZ],
    /// Column indices.
    pub cols: [u32; PACKET_MAX_NNZ],
    /// Values, stored in format `V`.
    pub vals: [V; PACKET_MAX_NNZ],
    /// Number of valid entries in this packet.
    pub len: usize,
}

impl<V: Dataword> CooPacket<V> {
    /// Entries a full packet of this format carries (§IV-B1): 5 at 32-bit
    /// values, 6 at 16-bit.
    pub const fn capacity() -> usize {
        packet_capacity(V::BITS)
    }

    /// Iterator over the valid entries, values dequantized to f32 (the
    /// multiplier input format).
    pub fn entries(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.len).map(move |i| (self.rows[i], self.cols[i], self.vals[i].to_f32()))
    }

    /// Iterator over the valid entries in raw storage format.
    pub fn entries_raw(&self) -> impl Iterator<Item = (u32, u32, V)> + '_ {
        (0..self.len).map(move |i| (self.rows[i], self.cols[i], self.vals[i]))
    }
}

/// Streaming packet view over a COO range (typically one CU's shard).
pub struct PacketStream<'a, V: Dataword = f32> {
    coo: &'a CooMatrix<V>,
    start: usize,
    pos: usize,
    end: usize,
    width: usize,
}

impl<'a, V: Dataword> PacketStream<'a, V> {
    /// Stream the whole matrix at the format's full packet width
    /// ([`CooPacket::capacity`]: 5 entries/line at f32, 6 at Q1.15).
    pub fn new(coo: &'a CooMatrix<V>) -> Self {
        Self::over_range(coo, 0, coo.nnz(), CooPacket::<V>::capacity())
    }

    /// Stream `[start, end)` with a configurable packet width up to
    /// [`PACKET_MAX_NNZ`] (synthetic widths beyond a format's real capacity
    /// belong to the timing model's `packet_nnz` knob, not the stream).
    pub fn over_range(coo: &'a CooMatrix<V>, start: usize, end: usize, width: usize) -> Self {
        assert!(width >= 1 && width <= PACKET_MAX_NNZ, "unreasonable packet width {width}");
        assert!(start <= end && end <= coo.nnz());
        Self { coo, start, pos: start, end, width }
    }

    /// Total packets this stream yields over its whole `[start, end)` range
    /// — a property of the range, stable across iteration (the OOC writer
    /// sizes chunk files from it, so it must not drift with the cursor).
    pub fn packet_count(&self) -> usize {
        let n = self.end - self.start;
        n.div_ceil(self.width)
    }

    /// Bytes the stream moves over HBM, counting whole 64-byte lines (the
    /// paper's accounting: a partially-filled line still costs a full
    /// transaction).
    pub fn line_bytes(&self) -> usize {
        self.packet_count() * (PACKET_BITS / 8)
    }
}

impl<'a, V: Dataword> Iterator for PacketStream<'a, V> {
    type Item = CooPacket<V>;

    fn next(&mut self) -> Option<CooPacket<V>> {
        if self.pos >= self.end {
            return None;
        }
        let take = self.width.min(self.end - self.pos);
        let mut p = CooPacket {
            rows: [0; PACKET_MAX_NNZ],
            cols: [0; PACKET_MAX_NNZ],
            vals: [V::default(); PACKET_MAX_NNZ],
            len: take,
        };
        for i in 0..p.len {
            p.rows[i] = self.coo.rows[self.pos + i];
            p.cols[i] = self.coo.cols[self.pos + i];
            p.vals[i] = self.coo.vals[self.pos + i];
        }
        self.pos += take;
        Some(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{Q1_15, Q1_31};

    fn coo(n: usize) -> CooMatrix {
        let mut m: CooMatrix = CooMatrix::new(n, n);
        for i in 0..n {
            m.push(i, (i + 1) % n, i as f32);
        }
        m
    }

    #[test]
    fn packet_count_and_tail() {
        let m = coo(13);
        let s = PacketStream::new(&m);
        assert_eq!(s.packet_count(), 3);
        let ps: Vec<_> = PacketStream::new(&m).collect();
        assert_eq!(ps.len(), 3);
        assert_eq!(ps[0].len, 5);
        assert_eq!(ps[1].len, 5);
        assert_eq!(ps[2].len, 3);
    }

    #[test]
    fn entries_round_trip() {
        let m = coo(12);
        let flat: Vec<(u32, u32, f32)> =
            PacketStream::new(&m).flat_map(|p| p.entries().collect::<Vec<_>>()).collect();
        assert_eq!(flat.len(), 12);
        for (i, &(r, c, v)) in flat.iter().enumerate() {
            assert_eq!(r as usize, i);
            assert_eq!(c as usize, (i + 1) % 12);
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn spmv_via_packets_matches_reference() {
        let m = coo(23);
        let x: Vec<f32> = (0..23).map(|i| (i as f32).sin()).collect();
        let mut y = vec![0.0f32; 23];
        for p in PacketStream::new(&m) {
            for (r, c, v) in p.entries() {
                y[r as usize] += v * x[c as usize];
            }
        }
        assert_eq!(y, m.spmv_ref(&x));
    }

    #[test]
    fn custom_width_and_range() {
        let m = coo(10);
        let s = PacketStream::over_range(&m, 2, 9, 3);
        assert_eq!(s.packet_count(), 3);
        let lens: Vec<usize> = PacketStream::over_range(&m, 2, 9, 3).map(|p| p.len).collect();
        assert_eq!(lens, vec![3, 3, 1]);
    }

    #[test]
    fn empty_range_yields_nothing() {
        let m = coo(10);
        let mut s = PacketStream::over_range(&m, 5, 5, 4);
        assert_eq!(s.packet_count(), 0);
        assert_eq!(s.line_bytes(), 0);
        assert!(s.next().is_none());
        // Degenerate empty range at the very end of the entry array.
        let mut tail = PacketStream::over_range(&m, 10, 10, 5);
        assert_eq!(tail.packet_count(), 0);
        assert!(tail.next().is_none());
    }

    #[test]
    fn packet_count_is_stable_across_iteration() {
        // `packet_count`/`line_bytes` describe the whole range; partially
        // draining the iterator must not change them (the OOC writer calls
        // them after interleaved reads).
        let m = coo(17);
        let mut s = PacketStream::over_range(&m, 1, 17, 5);
        let (total, bytes) = (s.packet_count(), s.line_bytes());
        assert_eq!(total, 4); // 16 entries at width 5: 5, 5, 5, 1
        assert_eq!(bytes, 4 * 64);
        assert_eq!(s.next().unwrap().len, 5);
        assert_eq!(s.next().unwrap().len, 5);
        assert_eq!(s.packet_count(), total, "count drifted after partial iteration");
        assert_eq!(s.line_bytes(), bytes);
        assert_eq!(s.by_ref().count(), 2);
        assert_eq!(s.packet_count(), total, "count drifted after exhaustion");
    }

    #[test]
    fn count_and_bytes_consistent_across_all_precisions() {
        // Satellite pin: for every storage format, packet_count matches the
        // packets actually yielded and line_bytes is count * 64 — including
        // a range that ends mid-packet (width does not divide the span).
        use crate::fixed::{Precision, Q2_30};
        fn check<V: Dataword>(m: &CooMatrix<V>) {
            let cap = CooPacket::<V>::capacity();
            assert_eq!(cap, V::precision().packet_capacity());
            for &(start, end) in &[(0usize, 19usize), (2, 17), (3, 3), (0, cap), (1, 1 + cap)] {
                let s = PacketStream::over_range(m, start, end, cap);
                let yielded: Vec<_> = PacketStream::over_range(m, start, end, cap).collect();
                assert_eq!(s.packet_count(), yielded.len(), "{} [{start},{end})", V::NAME);
                assert_eq!(s.line_bytes(), yielded.len() * (PACKET_BITS / 8));
                assert_eq!(yielded.iter().map(|p| p.len).sum::<usize>(), end - start);
                // Every packet but the last is full; a mid-packet tail is short.
                for p in yielded.iter().rev().skip(1) {
                    assert_eq!(p.len, cap);
                }
            }
        }
        let m = coo(19);
        check(&m);
        check(&m.to_precision::<Q1_31>());
        check(&m.to_precision::<Q2_30>());
        check(&m.to_precision::<Q1_15>());
        assert_eq!(Precision::ALL.len(), 4);
    }

    #[test]
    fn five_entries_fit_512_bits_at_f32() {
        assert!(PACKET_NNZ * 3 * 32 <= PACKET_BITS);
        assert_eq!(PACKET_NNZ, 5);
        assert_eq!(CooPacket::<f32>::capacity(), 5);
        assert_eq!(CooPacket::<Q1_31>::capacity(), 5);
    }

    #[test]
    fn six_entries_fit_512_bits_at_q115() {
        // §IV-B1: 32 + 32 + 16 = 80 bits per entry; 6 entries use 480 of
        // 512 bits — one more non-zero per HBM transaction than f32.
        assert_eq!(CooPacket::<Q1_15>::capacity(), 6);
        assert!(CooPacket::<Q1_15>::capacity() * (32 + 32 + 16) <= PACKET_BITS);
        assert_eq!(PACKET_MAX_NNZ, 6);
    }

    #[test]
    fn typed_stream_needs_fewer_packets() {
        // 30 nnz: 6 full f32 packets vs 5 full Q1.15 packets.
        let m = coo(30);
        let q: CooMatrix<Q1_15> = m.to_precision::<Q1_15>();
        assert_eq!(PacketStream::new(&m).packet_count(), 6);
        assert_eq!(PacketStream::new(&q).packet_count(), 5);
        assert_eq!(PacketStream::new(&m).line_bytes(), 6 * 64);
        assert_eq!(PacketStream::new(&q).line_bytes(), 5 * 64);
    }

    #[test]
    fn typed_final_short_packet_and_roundtrip() {
        // 20 nnz at capacity 6: packets of len 6,6,6,2 — the short tail
        // must carry exactly the leftover entries, dequantized within ulp.
        let mut m: CooMatrix = CooMatrix::new(20, 20);
        for i in 0..20 {
            m.push(i, (i + 1) % 20, (i as f32) / 32.0 - 0.3);
        }
        let q = m.to_precision::<Q1_15>();
        let lens: Vec<usize> = PacketStream::new(&q).map(|p| p.len).collect();
        assert_eq!(lens, vec![6, 6, 6, 2]);
        let flat: Vec<(u32, u32, f32)> =
            PacketStream::new(&q).flat_map(|p| p.entries().collect::<Vec<_>>()).collect();
        assert_eq!(flat.len(), 20);
        for (i, &(r, c, v)) in flat.iter().enumerate() {
            assert_eq!(r as usize, i);
            assert_eq!(c as usize, (i + 1) % 20);
            let want = (i as f32) / 32.0 - 0.3;
            assert!(((v - want).abs() as f64) <= <Q1_15 as Dataword>::ulp(), "{v} vs {want}");
        }
        // Raw entries expose the storage scalar itself.
        let first = PacketStream::new(&q).next().unwrap();
        let raw: Vec<(u32, u32, Q1_15)> = first.entries_raw().collect();
        assert_eq!(raw.len(), 6);
        assert_eq!(raw[0].2.to_f32(), flat[0].2);
    }
}

//! 512-bit COO packet stream — the paper's HBM read unit (§IV-B1).
//!
//! Each HBM transaction delivers a 512-bit line. A COO entry is three
//! 32-bit words (row, col, val), so **5 entries** fit one line (480 of 512
//! bits used). The Matrix Fetch Unit consumes one packet per clock cycle in
//! maximum-length AXI bursts. The [`PacketStream`] iterator reproduces that
//! granularity so both the native SpMV engine and the FPGA timing model can
//! account per-packet work exactly as the hardware would.

use crate::sparse::CooMatrix;

/// Bits per HBM transaction line.
pub const PACKET_BITS: usize = 512;
/// COO entries per packet: floor(512 / (3 * 32)).
pub const PACKET_NNZ: usize = 5;

/// One 512-bit line: up to 5 (row, col, val) entries; `len < 5` only for the
/// final packet of a shard.
#[derive(Clone, Copy, Debug)]
pub struct CooPacket {
    /// Row indices (valid up to `len`).
    pub rows: [u32; PACKET_NNZ],
    /// Column indices.
    pub cols: [u32; PACKET_NNZ],
    /// Values.
    pub vals: [f32; PACKET_NNZ],
    /// Number of valid entries in this packet.
    pub len: usize,
}

impl CooPacket {
    /// Iterator over the valid entries.
    pub fn entries(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.len).map(move |i| (self.rows[i], self.cols[i], self.vals[i]))
    }
}

/// Streaming packet view over a COO range (typically one CU's shard).
pub struct PacketStream<'a> {
    coo: &'a CooMatrix,
    pos: usize,
    end: usize,
    width: usize,
}

impl<'a> PacketStream<'a> {
    /// Stream the whole matrix with the standard 5-entry packets.
    pub fn new(coo: &'a CooMatrix) -> Self {
        Self::over_range(coo, 0, coo.nnz(), PACKET_NNZ)
    }

    /// Stream `[start, end)` with a configurable packet width (the CU-count
    /// / packet-width ablation uses widths 1..=15).
    pub fn over_range(coo: &'a CooMatrix, start: usize, end: usize, width: usize) -> Self {
        assert!(width >= 1 && width <= PACKET_NNZ * 3, "unreasonable packet width {width}");
        assert!(start <= end && end <= coo.nnz());
        Self { coo, pos: start, end, width }
    }

    /// Total packets this stream will yield.
    pub fn packet_count(&self) -> usize {
        let n = self.end - self.pos;
        n.div_ceil(self.width)
    }
}

impl<'a> Iterator for PacketStream<'a> {
    type Item = CooPacket;

    fn next(&mut self) -> Option<CooPacket> {
        if self.pos >= self.end {
            return None;
        }
        let take = self.width.min(self.end - self.pos);
        let mut p = CooPacket {
            rows: [0; PACKET_NNZ],
            cols: [0; PACKET_NNZ],
            vals: [0.0; PACKET_NNZ],
            len: take,
        };
        for i in 0..take {
            p.rows[i] = self.coo.rows[self.pos + i];
            p.cols[i] = self.coo.cols[self.pos + i];
            p.vals[i] = self.coo.vals[self.pos + i];
        }
        self.pos += take;
        Some(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coo(n: usize) -> CooMatrix {
        let mut m = CooMatrix::new(n, n);
        for i in 0..n {
            m.push(i, (i + 1) % n, i as f32);
        }
        m
    }

    #[test]
    fn packet_count_and_tail() {
        let m = coo(13);
        let s = PacketStream::new(&m);
        assert_eq!(s.packet_count(), 3);
        let ps: Vec<_> = PacketStream::new(&m).collect();
        assert_eq!(ps.len(), 3);
        assert_eq!(ps[0].len, 5);
        assert_eq!(ps[1].len, 5);
        assert_eq!(ps[2].len, 3);
    }

    #[test]
    fn entries_round_trip() {
        let m = coo(12);
        let flat: Vec<(u32, u32, f32)> =
            PacketStream::new(&m).flat_map(|p| p.entries().collect::<Vec<_>>()).collect();
        assert_eq!(flat.len(), 12);
        for (i, &(r, c, v)) in flat.iter().enumerate() {
            assert_eq!(r as usize, i);
            assert_eq!(c as usize, (i + 1) % 12);
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn spmv_via_packets_matches_reference() {
        let m = coo(23);
        let x: Vec<f32> = (0..23).map(|i| (i as f32).sin()).collect();
        let mut y = vec![0.0f32; 23];
        for p in PacketStream::new(&m) {
            for (r, c, v) in p.entries() {
                y[r as usize] += v * x[c as usize];
            }
        }
        assert_eq!(y, m.spmv_ref(&x));
    }

    #[test]
    fn custom_width_and_range() {
        let m = coo(10);
        let s = PacketStream::over_range(&m, 2, 9, 3);
        assert_eq!(s.packet_count(), 3);
        let lens: Vec<usize> = PacketStream::over_range(&m, 2, 9, 3).map(|p| p.len).collect();
        assert_eq!(lens, vec![3, 3, 1]);
    }

    #[test]
    fn five_entries_fit_512_bits() {
        assert!(PACKET_NNZ * 3 * 32 <= PACKET_BITS);
        assert_eq!(PACKET_NNZ, 5);
    }
}

//! Non-Hermitian extension — the paper's stated future work (§VI: "we
//! will extend our hardware design to support non-Hermitian matrices
//! through the Implicitly Restarted Arnoldi Method").
//!
//! The Lanczos three-term recurrence needs symmetry; for directed graphs
//! (web link matrices, citation networks) the Krylov reduction must keep
//! the full upper-Hessenberg projection. This module provides:
//!
//! * [`arnoldi_factorize`] — an m-step Arnoldi factorization
//!   `M V_m = V_m H_m + r e_m^T` with twice-MGS orthogonalization (the
//!   same kernel structure as the Lanczos core: the SpMV stream is
//!   unchanged, only the host-side projection widens, which is why the
//!   paper considers it a natural hardware extension);
//! * [`hessenberg_eigenvalues`] — eigenvalues of the small Hessenberg
//!   matrix via Francis-style shifted QR with 2x2-block deflation, so
//!   complex-conjugate pairs (rotational modes of directed cycles) are
//!   reported with their true magnitudes;
//! * [`arnoldi_topk`] — restarted driver returning the Top-K Ritz values
//!   by magnitude plus the dominant real Ritz vector when one exists
//!   (Perron-Frobenius guarantees it for non-negative matrices — the
//!   common spectral-analytics case).

use crate::lanczos::Operator;
use crate::linalg::{self, qr_decompose, DenseMatrix};

/// A (possibly complex) eigenvalue reported as `(re, im)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ritz {
    /// Real part.
    pub re: f64,
    /// Imaginary part (0 for real eigenvalues).
    pub im: f64,
}

impl Ritz {
    /// Magnitude `|lambda|`.
    pub fn magnitude(&self) -> f64 {
        self.re.hypot(self.im)
    }
    /// Is this (numerically) real?
    pub fn is_real(&self, tol: f64) -> bool {
        self.im.abs() <= tol * self.magnitude().max(1e-300)
    }
}

/// Result of an m-step Arnoldi factorization.
pub struct ArnoldiFactorization {
    /// Orthonormal Krylov basis rows `v_0..v_{m-1}` (length n each).
    pub basis: Vec<Vec<f32>>,
    /// Upper-Hessenberg projection `H_m` (m x m).
    pub hessenberg: DenseMatrix,
    /// Residual norm `beta_m = ||r||`.
    pub residual_norm: f64,
    /// SpMV applications performed.
    pub spmv_count: usize,
}

/// Build `M V = V H + r e_m^T` with `V` orthonormal (twice-MGS).
pub fn arnoldi_factorize<O: Operator + ?Sized>(op: &O, m: usize, v1: &[f32]) -> ArnoldiFactorization {
    let n = op.n();
    assert!(m >= 1 && m <= n, "need 1 <= m <= n");
    assert_eq!(v1.len(), n);
    let mut v = v1.to_vec();
    assert!(linalg::normalize(&mut v) > 0.0, "start vector must be non-zero");

    let mut basis: Vec<Vec<f32>> = vec![v];
    let mut h = DenseMatrix::zeros(m, m);
    let mut w = vec![0.0f32; n];
    let mut spmv_count = 0;
    let mut residual_norm = 0.0;

    for j in 0..m {
        op.apply(&basis[j], &mut w);
        spmv_count += 1;
        // Twice-MGS: first pass records H entries, second mops up the
        // rounding leakage (coefficients fold into the same H entries).
        for pass in 0..2 {
            for (i, b) in basis.iter().enumerate() {
                let c = linalg::dot(&w, b);
                linalg::axpy(-(c as f32), b, &mut w);
                if pass == 0 {
                    h[(i, j)] = c;
                } else {
                    h[(i, j)] += c;
                }
            }
        }
        let beta = linalg::norm2(&w);
        if j + 1 < m {
            h[(j + 1, j)] = beta;
        } else {
            residual_norm = beta;
            break;
        }
        if beta < 1e-12 {
            // Invariant subspace: truncate (H stays valid with zero
            // subdiagonal; remaining columns are zero).
            residual_norm = 0.0;
            let mut ht = DenseMatrix::zeros(j + 1, j + 1);
            for r in 0..=j {
                for c in 0..=j {
                    ht[(r, c)] = h[(r, c)];
                }
            }
            return ArnoldiFactorization { basis, hessenberg: ht, residual_norm, spmv_count };
        }
        let inv = (1.0 / beta) as f32;
        basis.push(w.iter().map(|&x| x * inv).collect());
    }
    ArnoldiFactorization { basis, hessenberg: h, residual_norm, spmv_count }
}

/// Eigenvalues of a small (upper-Hessenberg or general) real matrix via
/// shifted QR with trailing 1x1/2x2 deflation. Complex pairs come from
/// the 2x2 blocks' quadratic formula. Sorted by decreasing magnitude.
pub fn hessenberg_eigenvalues(h: &DenseMatrix, max_iter: usize) -> Vec<Ritz> {
    let n = h.nrows;
    assert_eq!(n, h.ncols);
    let mut a = h.clone();
    let mut out: Vec<Ritz> = Vec::with_capacity(n);
    let mut active = n;
    let mut iters = 0usize;
    let tol = 1e-12;

    while active > 0 && iters < max_iter {
        if active == 1 {
            out.push(Ritz { re: a[(0, 0)], im: 0.0 });
            active = 0;
            break;
        }
        // Deflate a trailing 1x1 block?
        if a[(active - 1, active - 2)].abs()
            <= tol * (a[(active - 1, active - 1)].abs() + a[(active - 2, active - 2)].abs() + 1e-300)
        {
            out.push(Ritz { re: a[(active - 1, active - 1)], im: 0.0 });
            active -= 1;
            continue;
        }
        // Deflate a trailing 2x2 block?
        let can_split_2x2 = active == 2
            || a[(active - 2, active - 3)].abs()
                <= tol * (a[(active - 2, active - 2)].abs() + a[(active - 3, active - 3)].abs() + 1e-300);
        if can_split_2x2 {
            let (p, q) = (active - 2, active - 1);
            let (x, y, z, w) = (a[(p, p)], a[(p, q)], a[(q, p)], a[(q, q)]);
            let tr = x + w;
            let det = x * w - y * z;
            let disc = tr * tr / 4.0 - det;
            if disc >= 0.0 {
                let s = disc.sqrt();
                out.push(Ritz { re: tr / 2.0 + s, im: 0.0 });
                out.push(Ritz { re: tr / 2.0 - s, im: 0.0 });
            } else {
                let s = (-disc).sqrt();
                out.push(Ritz { re: tr / 2.0, im: s });
                out.push(Ritz { re: tr / 2.0, im: -s });
            }
            active -= 2;
            continue;
        }
        // One shifted QR step on the leading active block (Wilkinson-ish
        // real shift from the trailing 2x2's real eigenvalue when it has
        // one; otherwise an exceptional averaged shift to break symmetry).
        let (x, y, z, w) = (
            a[(active - 2, active - 2)],
            a[(active - 2, active - 1)],
            a[(active - 1, active - 2)],
            a[(active - 1, active - 1)],
        );
        let tr = x + w;
        let det = x * w - y * z;
        let disc = tr * tr / 4.0 - det;
        let mu = if disc >= 0.0 {
            let s = disc.sqrt();
            // Root closer to the last diagonal entry.
            if (tr / 2.0 + s - w).abs() < (tr / 2.0 - s - w).abs() {
                tr / 2.0 + s
            } else {
                tr / 2.0 - s
            }
        } else {
            // Complex pair: use the real part plus an exceptional nudge
            // every few iterations to avoid cycling.
            tr / 2.0 + if iters % 7 == 6 { 0.75 * y.abs().max(z.abs()) } else { 0.0 }
        };
        let mut block = DenseMatrix::zeros(active, active);
        for r in 0..active {
            for c in 0..active {
                block[(r, c)] = a[(r, c)];
            }
            block[(r, r)] -= mu;
        }
        let (q, r) = qr_decompose(&block);
        let rq = r.matmul(&q);
        for rr in 0..active {
            for cc in 0..active {
                a[(rr, cc)] = rq[(rr, cc)];
            }
            a[(rr, rr)] += mu;
        }
        iters += 1;
    }
    // Anything left unconverged: report diagonal entries (best estimate).
    for i in (0..active).rev() {
        out.push(Ritz { re: a[(i, i)], im: 0.0 });
    }
    out.sort_by(|a, b| b.magnitude().partial_cmp(&a.magnitude()).unwrap());
    out
}

/// Options for the restarted non-Hermitian driver.
#[derive(Clone, Debug)]
pub struct ArnoldiOptions {
    /// Wanted eigenvalues (largest magnitude).
    pub k: usize,
    /// Krylov dimension per cycle (default `max(2k+4, 20)`).
    pub m: Option<usize>,
    /// Restart cycles.
    pub restarts: usize,
    /// RNG seed for the start vector.
    pub seed: u64,
}

impl Default for ArnoldiOptions {
    fn default() -> Self {
        Self { k: 6, m: None, restarts: 6, seed: 11 }
    }
}

/// Result of [`arnoldi_topk`].
#[derive(Clone, Debug)]
pub struct ArnoldiResult {
    /// Top-K Ritz values by magnitude (complex pairs included).
    pub ritz: Vec<Ritz>,
    /// Dominant Ritz vector when the dominant Ritz value is real.
    pub dominant_vector: Option<Vec<f32>>,
    /// SpMV applications across all cycles.
    pub spmv_count: usize,
}

/// Restarted Arnoldi: explicit restart with the (power-iterated) dominant
/// direction, which converges the large-magnitude end of the spectrum —
/// the Top-K regime this system targets.
pub fn arnoldi_topk<O: Operator + ?Sized>(op: &O, opts: &ArnoldiOptions) -> ArnoldiResult {
    let n = op.n();
    let k = opts.k;
    assert!(k >= 1 && k <= n);
    let m = opts.m.unwrap_or((2 * k + 4).max(20)).min(n);
    let mut rng = crate::util::rng::Pcg64::new(opts.seed);
    let mut v1: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut spmv_count = 0usize;
    let mut last: Option<ArnoldiFactorization> = None;

    for _ in 0..opts.restarts {
        let fact = arnoldi_factorize(op, m, &v1);
        spmv_count += fact.spmv_count;
        // Explicit restart: power-filter the start vector toward the
        // dominant invariant subspace using the Krylov basis itself —
        // restart from V * (leading left-null combination) ~ apply M once
        // more to the best Ritz direction. Cheap and robust.
        let ritz = hessenberg_eigenvalues(&fact.hessenberg, 500);
        let dominant_real = ritz.first().map(|r| r.is_real(1e-8)).unwrap_or(false);
        if fact.residual_norm < 1e-10 {
            last = Some(fact);
            break;
        }
        // New start: M applied to the current best dominant estimate.
        let seed_vec = if dominant_real {
            dominant_vector_estimate(op, &fact, &mut spmv_count)
        } else {
            // Complex dominant pair: restart from a fresh random mix to
            // keep both real and imaginary directions represented.
            let mut s = vec![0.0f32; n];
            for b in fact.basis.iter().take(2.min(fact.basis.len())) {
                let c = rng.normal() as f32;
                linalg::axpy(c, b, &mut s);
            }
            s
        };
        v1 = seed_vec;
        last = Some(fact);
    }

    let fact = last.expect("at least one cycle runs");
    let mut ritz = hessenberg_eigenvalues(&fact.hessenberg, 2000);
    ritz.truncate(k);
    let dominant_vector = if ritz.first().map(|r| r.is_real(1e-8)).unwrap_or(false) {
        let mut sc = spmv_count;
        let v = dominant_vector_estimate(op, &fact, &mut sc);
        spmv_count = sc;
        Some(v)
    } else {
        None
    };
    ArnoldiResult { ritz, dominant_vector, spmv_count }
}

/// Dominant Ritz vector via a few power refinements of the best basis
/// direction (valid when the dominant eigenvalue is real and separated).
fn dominant_vector_estimate<O: Operator + ?Sized>(
    op: &O,
    fact: &ArnoldiFactorization,
    spmv_count: &mut usize,
) -> Vec<f32> {
    let n = op.n();
    // Start from the Krylov direction that best aligns with dominance:
    // the sum of basis rows weighted by H's power action ~ just refine the
    // last basis vector through a few power steps.
    let mut v = fact.basis[0].clone();
    let mut w = vec![0.0f32; n];
    for _ in 0..12 {
        op.apply(&v, &mut w);
        *spmv_count += 1;
        std::mem::swap(&mut v, &mut w);
        if linalg::normalize(&mut v) == 0.0 {
            break;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;

    /// Directed cycle 0 -> 1 -> ... -> n-1 -> 0: eigenvalues are the n-th
    /// roots of unity (all magnitude 1, mostly complex).
    fn directed_cycle(n: usize) -> crate::sparse::CsrMatrix {
        let mut m = CooMatrix::new(n, n);
        for i in 0..n {
            m.push(i, (i + 1) % n, 1.0);
        }
        m.to_csr()
    }

    /// Column-stochastic "Google" matrix with damping d: dominant
    /// eigenvalue exactly 1 with a non-negative eigenvector (PageRank).
    fn google_matrix(n: usize, seed: u64) -> crate::sparse::CsrMatrix {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let d = 0.85f32;
        let mut m = CooMatrix::new(n, n);
        for j in 0..n {
            let deg = 2 + rng.range(0, 4);
            let targets = rng.sample_indices(n, deg);
            for &t in &targets {
                m.push(t, j, d / deg as f32);
            }
            // Teleport mass (dense rank-1 part approximated sparsely: add
            // to a fixed hub so the matrix stays sparse but irreducible).
            m.push(j % 7, j, (1.0 - d) * 0.5);
            m.push((j + 3) % n, j, (1.0 - d) * 0.5);
        }
        m.canonicalize();
        m.to_csr()
    }

    #[test]
    fn hessenberg_qr_on_known_spectrum() {
        // Companion-style matrix with eigenvalues 3, -2, 1 (real).
        let a = DenseMatrix::from_rows(
            3,
            3,
            vec![
                2.0, 1.0, 1.0, //
                1.0, 2.0, 0.0, //
                0.0, 1.0, -2.0,
            ],
        );
        let eigs = hessenberg_eigenvalues(&a, 500);
        // Trace preserved.
        let tr: f64 = eigs.iter().map(|r| r.re).sum();
        assert!((tr - 2.0).abs() < 1e-8, "trace {tr}");
    }

    #[test]
    fn directed_cycle_eigenvalues_have_unit_magnitude() {
        let m = directed_cycle(8);
        // A random start: the uniform vector is itself an eigenvector of
        // the cycle (M 1 = 1) and would break down immediately.
        let mut rng = crate::util::rng::Pcg64::new(2);
        let v1: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        let fact = arnoldi_factorize(&m, 8, &v1);
        assert!(fact.residual_norm < 1e-6, "cycle Krylov closes after n steps");
        let eigs = hessenberg_eigenvalues(&fact.hessenberg, 1000);
        assert_eq!(eigs.len(), 8);
        for e in &eigs {
            assert!((e.magnitude() - 1.0).abs() < 1e-6, "|lambda| = {} for {e:?}", e.magnitude());
        }
        // Complex pairs must be present (roots of unity).
        assert!(eigs.iter().any(|e| !e.is_real(1e-9)), "cycle must have complex eigenvalues");
    }

    #[test]
    fn arnoldi_basis_is_orthonormal() {
        let m = google_matrix(200, 3);
        let mut rng = crate::util::rng::Pcg64::new(5);
        let v1: Vec<f32> = (0..200).map(|_| rng.normal() as f32).collect();
        let fact = arnoldi_factorize(&m, 12, &v1);
        for i in 0..fact.basis.len() {
            assert!((linalg::norm2(&fact.basis[i]) - 1.0).abs() < 1e-5);
            for j in 0..i {
                let d = linalg::dot(&fact.basis[i], &fact.basis[j]).abs();
                assert!(d < 1e-5, "rows {i},{j} dot {d}");
            }
        }
        // Factorization identity on a probe: M v_0 == V H e_0 + r (column 0).
        let n = 200;
        let mut mv = vec![0.0f32; n];
        m.apply(&fact.basis[0], &mut mv);
        let mut vh = vec![0.0f64; n];
        for i in 0..fact.basis.len() {
            let hij = fact.hessenberg[(i, 0)];
            for (x, b) in vh.iter_mut().zip(&fact.basis[i]) {
                *x += hij * *b as f64;
            }
        }
        let err: f64 = mv
            .iter()
            .zip(&vh)
            .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-4, "factorization identity violated: {err}");
    }

    #[test]
    fn pagerank_dominant_eigenvalue_is_one() {
        let m = google_matrix(300, 9);
        let r = arnoldi_topk(&m, &ArnoldiOptions { k: 4, restarts: 8, ..Default::default() });
        assert!((r.ritz[0].magnitude() - 1.0).abs() < 1e-3, "dominant {:?}", r.ritz[0]);
        assert!(r.ritz[0].is_real(1e-6));
        // The dominant vector is the PageRank: non-negative (up to sign).
        let v = r.dominant_vector.expect("real dominant -> vector");
        let pos = v.iter().filter(|&&x| x > 0.0).count();
        let neg = v.iter().filter(|&&x| x < 0.0).count();
        assert!(pos == 0 || neg == 0, "Perron vector must be one-signed ({pos} pos / {neg} neg)");
        // Residual check: ||Mv - v|| small.
        let mut mv = vec![0.0f32; 300];
        m.apply(&v, &mut mv);
        let res: f64 = mv
            .iter()
            .zip(&v)
            .map(|(&a, &b)| (a as f64 - b as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(res < 1e-3, "PageRank residual {res}");
    }

    #[test]
    fn symmetric_input_matches_lanczos_path() {
        // On a symmetric matrix Arnoldi must agree with Lanczos+Jacobi.
        let mut adj = crate::graphs::scale_free_ba(400, 5, 7);
        crate::sparse::normalize_frobenius(&mut adj);
        let csr = adj.to_csr();
        let ar = arnoldi_topk(&csr, &ArnoldiOptions { k: 3, restarts: 6, ..Default::default() });
        let lz = crate::lanczos::lanczos(
            &csr,
            &crate::lanczos::LanczosOptions {
                k: 16,
                reorth: crate::lanczos::ReorthPolicy::Every,
                ..Default::default()
            },
        );
        let je = crate::jacobi::jacobi_eigen(&lz.tridiag, crate::jacobi::JacobiMode::Cyclic, 1e-12);
        assert!(
            (ar.ritz[0].re - je.eigenvalues[0]).abs() < 2e-3 * je.eigenvalues[0].abs(),
            "arnoldi {:?} vs lanczos {}",
            ar.ritz[0],
            je.eigenvalues[0]
        );
    }
}

//! Fixed-point arithmetic (§III-A, §IV).
//!
//! After Frobenius normalization every matrix value, eigenvalue, and
//! eigenvector entry lies in `(-1, 1)`, so the paper replaces float
//! datapaths with fixed-point where full float precision is not needed.
//! We provide the three formats the DSP-friendly design space covers:
//!
//! * [`Q1_31`] — 1 sign bit, 31 fractional bits (i32): the Lanczos vector
//!   format; quantization step `2^-31`.
//! * [`Q2_30`] — 2 integer bits, 30 fractional (i32): headroom format for
//!   intermediate sums that can transiently exceed 1 in magnitude.
//! * [`Q1_15`] — 16-bit variant for the precision ablation.
//!
//! All types saturate instead of wrapping (what the DSP48 accumulators do)
//! and use round-to-nearest on quantization.

/// Behaviour shared by the Q formats.
pub trait Fixed: Copy + Clone + PartialEq + std::fmt::Debug {
    /// Raw integer type's bit width.
    const BITS: u32;
    /// Number of fractional bits.
    const FRAC: u32;
    /// Quantize from f64 (round-to-nearest, saturating).
    fn from_f64(x: f64) -> Self;
    /// Dequantize to f64.
    fn to_f64(self) -> f64;
    /// Saturating add.
    fn add(self, rhs: Self) -> Self;
    /// Saturating subtract.
    fn sub(self, rhs: Self) -> Self;
    /// Fixed-point multiply (full-width intermediate, rounded).
    fn mul(self, rhs: Self) -> Self;
    /// Quantization step (1 ulp).
    fn ulp() -> f64 {
        (2.0f64).powi(-(Self::FRAC as i32))
    }
    /// Round-trip an f64 through this format (the quantization operator the
    /// mixed-precision Lanczos path applies).
    fn quantize(x: f64) -> f64 {
        Self::from_f64(x).to_f64()
    }
}

macro_rules! qformat {
    ($(#[$doc:meta])* $name:ident, $raw:ty, $wide:ty, $bits:expr, $frac:expr) => {
        $(#[$doc])*
        #[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug, Default)]
        pub struct $name(pub $raw);

        impl Fixed for $name {
            const BITS: u32 = $bits;
            const FRAC: u32 = $frac;

            #[inline]
            fn from_f64(x: f64) -> Self {
                let scaled = x * (1u64 << $frac) as f64;
                // round-to-nearest-even like the RTL rounding stage
                let r = scaled.round_ties_even();
                let max = <$raw>::MAX as f64;
                let min = <$raw>::MIN as f64;
                $name(if r >= max {
                    <$raw>::MAX
                } else if r <= min {
                    <$raw>::MIN
                } else {
                    r as $raw
                })
            }

            #[inline]
            fn to_f64(self) -> f64 {
                self.0 as f64 / (1u64 << $frac) as f64
            }

            #[inline]
            fn add(self, rhs: Self) -> Self {
                $name(self.0.saturating_add(rhs.0))
            }

            #[inline]
            fn sub(self, rhs: Self) -> Self {
                $name(self.0.saturating_sub(rhs.0))
            }

            #[inline]
            fn mul(self, rhs: Self) -> Self {
                let wide = (self.0 as $wide) * (rhs.0 as $wide);
                // Round: add half-ulp before shifting back.
                let rounded = (wide + (1 as $wide << ($frac - 1))) >> $frac;
                let max = <$raw>::MAX as $wide;
                let min = <$raw>::MIN as $wide;
                $name(if rounded > max {
                    <$raw>::MAX
                } else if rounded < min {
                    <$raw>::MIN
                } else {
                    rounded as $raw
                })
            }
        }
    };
}

qformat!(
    /// Q1.31: sign + 31 fractional bits; values in `[-1, 1 - 2^-31]`.
    Q1_31, i32, i64, 32, 31
);
qformat!(
    /// Q2.30: one integer bit of headroom; values in `[-2, 2 - 2^-30]`.
    Q2_30, i32, i64, 32, 30
);
qformat!(
    /// Q1.15: 16-bit variant for the precision ablation; step `2^-15`.
    Q1_15, i16, i32, 16, 15
);

/// Precision mode for the mixed-precision Lanczos datapath.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Precision {
    /// IEEE f32 everywhere (the CPU baseline datapath).
    Float32,
    /// Quantize Lanczos vectors to Q1.31 after each update (the paper's
    /// device datapath; dots/norms still accumulate in float, matching the
    /// design's float units "where required to guarantee precise results").
    FixedQ1_31,
    /// Q2.30 variant (headroom, one fewer fractional bit).
    FixedQ2_30,
    /// Q1.15 variant (16-bit, for the ablation's accuracy cliff).
    FixedQ1_15,
}

impl Precision {
    /// Quantize one value under this mode.
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            Precision::Float32 => x,
            Precision::FixedQ1_31 => Q1_31::quantize(x as f64) as f32,
            Precision::FixedQ2_30 => Q2_30::quantize(x as f64) as f32,
            Precision::FixedQ1_15 => Q1_15::quantize(x as f64) as f32,
        }
    }

    /// Quantize a vector in place.
    pub fn quantize_slice(self, xs: &mut [f32]) {
        if self == Precision::Float32 {
            return;
        }
        for x in xs {
            *x = self.quantize(*x);
        }
    }

    /// Name for reports.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Float32 => "f32",
            Precision::FixedQ1_31 => "q1.31",
            Precision::FixedQ2_30 => "q2.30",
            Precision::FixedQ1_15 => "q1.15",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q131_round_trip_error_is_sub_ulp() {
        for &x in &[0.0, 0.5, -0.25, 0.999_999, -0.999_999, 1e-9] {
            let err = (Q1_31::quantize(x) - x).abs();
            assert!(err <= Q1_31::ulp() / 2.0 + 1e-18, "x={x} err={err}");
        }
    }

    #[test]
    fn q131_saturates_at_one() {
        assert_eq!(Q1_31::from_f64(1.5).0, i32::MAX);
        assert_eq!(Q1_31::from_f64(-1.5).0, i32::MIN);
        assert!((Q1_31::from_f64(-1.0).to_f64() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn q230_has_headroom() {
        assert!((Q2_30::quantize(1.5) - 1.5).abs() < Q2_30::ulp());
        assert_eq!(Q2_30::from_f64(2.5).0, i32::MAX);
    }

    #[test]
    fn mul_matches_float_product() {
        let a = Q1_31::from_f64(0.5);
        let b = Q1_31::from_f64(-0.25);
        assert!((a.mul(b).to_f64() - -0.125).abs() <= Q1_31::ulp());
        // Q1.15 coarser.
        let c = Q1_15::from_f64(0.3);
        let d = Q1_15::from_f64(0.7);
        assert!((c.mul(d).to_f64() - 0.21).abs() <= 2.0 * Q1_15::ulp());
    }

    #[test]
    fn add_saturates_not_wraps() {
        let a = Q1_31::from_f64(0.9);
        let b = Q1_31::from_f64(0.9);
        let s = a.add(b).to_f64();
        assert!((s - (1.0 - Q1_31::ulp())).abs() < 1e-9, "saturated sum was {s}");
        // Q2.30 can represent 1.8.
        let s2 = Q2_30::from_f64(0.9).add(Q2_30::from_f64(0.9)).to_f64();
        assert!((s2 - 1.8).abs() < 2.0 * Q2_30::ulp());
    }

    #[test]
    fn ulp_ordering_across_formats() {
        assert!(Q1_31::ulp() < Q2_30::ulp());
        assert!(Q2_30::ulp() < Q1_15::ulp());
        assert_eq!(Q1_15::ulp(), 2.0f64.powi(-15));
    }

    #[test]
    fn precision_mode_quantizes_slices() {
        let mut xs = vec![0.123456789f32, -0.987654321, 0.5];
        let orig = xs.clone();
        Precision::FixedQ1_15.quantize_slice(&mut xs);
        assert!(xs.iter().zip(&orig).any(|(a, b)| a != b), "q1.15 must perturb");
        for (a, b) in xs.iter().zip(&orig) {
            assert!((a - b).abs() <= Q1_15::ulp() as f32);
        }
        let mut ys = orig.clone();
        Precision::Float32.quantize_slice(&mut ys);
        assert_eq!(ys, orig);
    }

    #[test]
    fn quantization_error_shrinks_with_frac_bits() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        let (mut e15, mut e31) = (0.0f64, 0.0f64);
        for _ in 0..1000 {
            let x = rng.f64_range(-1.0, 1.0);
            e15 += (Q1_15::quantize(x) - x).abs();
            e31 += (Q1_31::quantize(x) - x).abs();
        }
        assert!(e31 < e15 / 1000.0, "e31={e31} e15={e15}");
    }
}

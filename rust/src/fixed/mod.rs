//! Fixed-point arithmetic and the typed storage datapath (§III-A, §IV).
//!
//! After Frobenius normalization every matrix value, eigenvalue, and
//! eigenvector entry lies in `(-1, 1)`, so the paper replaces float
//! datapaths with fixed-point where full float precision is not needed.
//! We provide the three formats the DSP-friendly design space covers:
//!
//! * [`Q1_31`] — 1 sign bit, 31 fractional bits (i32): the Lanczos vector
//!   format; quantization step `2^-31`.
//! * [`Q2_30`] — 2 integer bits, 30 fractional (i32): headroom format for
//!   intermediate sums that can transiently exceed 1 in magnitude.
//! * [`Q1_15`] — 16-bit variant for the precision ablation.
//!
//! All types saturate instead of wrapping (what the DSP48 accumulators do)
//! and use round-to-nearest on quantization.
//!
//! ## Storage types, not a rounding pass
//!
//! [`Dataword`] is the storage-scalar abstraction the typed datapath is
//! generic over: `CooMatrix<V>` / `CsrMatrix<V>` value arrays,
//! `CooPacket<V>` / `PacketStream<V>` HBM lines, `ShardedSpmv<V>` engines,
//! and Lanczos basis vectors all store `V` directly. A 16-bit word halves
//! the value-array bytes and raises the entries-per-512-bit-line count
//! ([`packet_capacity`]: 6 at Q1.15 vs 5 at f32, §IV-B1), which is where
//! the paper's bandwidth headroom comes from. Arithmetic still accumulates
//! in float (dots, norms, SpMV partial sums) — the design's float units
//! "where required to guarantee precise results" (§IV).
//!
//! [`Precision`] stays the *runtime* selector: the coordinator dispatches
//! it onto the monomorphized kernels with [`with_precision!`].

/// Behaviour shared by the Q formats.
pub trait Fixed: Copy + Clone + PartialEq + std::fmt::Debug {
    /// Raw integer type's bit width.
    const BITS: u32;
    /// Number of fractional bits.
    const FRAC: u32;
    /// Quantize from f64 (round-to-nearest, saturating).
    fn from_f64(x: f64) -> Self;
    /// Dequantize to f64.
    fn to_f64(self) -> f64;
    /// Saturating add.
    fn add(self, rhs: Self) -> Self;
    /// Saturating subtract.
    fn sub(self, rhs: Self) -> Self;
    /// Fixed-point multiply (full-width intermediate, rounded).
    fn mul(self, rhs: Self) -> Self;
    /// Quantization step (1 ulp).
    fn ulp() -> f64 {
        (2.0f64).powi(-(Self::FRAC as i32))
    }
    /// Round-trip an f64 through this format (the quantization operator the
    /// mixed-precision Lanczos path applies).
    fn quantize(x: f64) -> f64 {
        Self::from_f64(x).to_f64()
    }
}

/// A scalar that can live in the storage datapath: matrix value arrays,
/// 512-bit HBM packets, and Lanczos basis vectors are generic over it.
///
/// Implemented by `f32` (the CPU-baseline word) and the three fixed-point
/// formats. Conversions go through f32 because every compute kernel
/// accumulates in float (§IV); a `Dataword` only decides how many bits a
/// *stored* value occupies and how it rounds.
pub trait Dataword: Copy + Clone + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static {
    /// Stored width in bits (32 for f32/Q1.31/Q2.30, 16 for Q1.15).
    const BITS: u32;
    /// Short format name for reports ("f32", "q1.31", ...).
    const NAME: &'static str;
    /// True for the saturating fixed-point formats.
    const IS_FIXED: bool;
    /// Quantize an f32 into this storage format (round-to-nearest,
    /// saturating for the fixed formats; identity for f32).
    fn from_f32(x: f32) -> Self;
    /// Dequantize back to f32 (identity for f32).
    fn to_f32(self) -> f32;
    /// Quantization step: `2^-FRAC` for fixed formats, `f32::EPSILON` for
    /// f32 (used to scale error bounds in the property tests).
    fn ulp() -> f64;
    /// Saturating add in the storage format (plain IEEE add for f32) —
    /// what the DSP48 accumulators do on overflow.
    fn sat_add(self, rhs: Self) -> Self;
    /// Saturating multiply in the storage format (plain IEEE mul for f32).
    fn sat_mul(self, rhs: Self) -> Self;
    /// Bytes per stored value.
    fn bytes() -> usize {
        (Self::BITS / 8) as usize
    }
    /// Raw storage bits, zero-extended to 32. The lossless serialization
    /// hook for the out-of-core packet files: `from_f32(to_f32(v))` is NOT
    /// an identity for the 31/30-fraction-bit formats (an f32 mantissa has
    /// only 24 bits), so persisted values must round-trip through the raw
    /// representation instead.
    fn to_bits(self) -> u32;
    /// Inverse of [`Dataword::to_bits`]; only the low `BITS` bits are used.
    fn from_bits(bits: u32) -> Self;
    /// The runtime [`Precision`] tag naming this format.
    fn precision() -> Precision;
}

impl Dataword for f32 {
    const BITS: u32 = 32;
    const NAME: &'static str = "f32";
    const IS_FIXED: bool = false;
    #[inline]
    fn from_f32(x: f32) -> Self {
        x
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    fn ulp() -> f64 {
        f32::EPSILON as f64
    }
    #[inline]
    fn sat_add(self, rhs: Self) -> Self {
        self + rhs
    }
    #[inline]
    fn sat_mul(self, rhs: Self) -> Self {
        self * rhs
    }
    #[inline]
    fn to_bits(self) -> u32 {
        u32::from_le_bytes(self.to_le_bytes())
    }
    #[inline]
    fn from_bits(bits: u32) -> Self {
        f32::from_le_bytes(bits.to_le_bytes())
    }
    fn precision() -> Precision {
        Precision::Float32
    }
}

macro_rules! qformat {
    ($(#[$doc:meta])* $name:ident, $raw:ty, $wide:ty, $bits:expr, $frac:expr) => {
        $(#[$doc])*
        #[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug, Default)]
        pub struct $name(pub $raw);

        impl Fixed for $name {
            const BITS: u32 = $bits;
            const FRAC: u32 = $frac;

            #[inline]
            fn from_f64(x: f64) -> Self {
                let scaled = x * (1u64 << $frac) as f64;
                // round-to-nearest-even like the RTL rounding stage
                let r = scaled.round_ties_even();
                let max = <$raw>::MAX as f64;
                let min = <$raw>::MIN as f64;
                $name(if r >= max {
                    <$raw>::MAX
                } else if r <= min {
                    <$raw>::MIN
                } else {
                    r as $raw
                })
            }

            #[inline]
            fn to_f64(self) -> f64 {
                self.0 as f64 / (1u64 << $frac) as f64
            }

            #[inline]
            fn add(self, rhs: Self) -> Self {
                $name(self.0.saturating_add(rhs.0))
            }

            #[inline]
            fn sub(self, rhs: Self) -> Self {
                $name(self.0.saturating_sub(rhs.0))
            }

            #[inline]
            fn mul(self, rhs: Self) -> Self {
                let wide = (self.0 as $wide) * (rhs.0 as $wide);
                // Round: add half-ulp before shifting back.
                let rounded = (wide + (1 as $wide << ($frac - 1))) >> $frac;
                let max = <$raw>::MAX as $wide;
                let min = <$raw>::MIN as $wide;
                $name(if rounded > max {
                    <$raw>::MAX
                } else if rounded < min {
                    <$raw>::MIN
                } else {
                    rounded as $raw
                })
            }
        }
    };
}

qformat!(
    /// Q1.31: sign + 31 fractional bits; values in `[-1, 1 - 2^-31]`.
    Q1_31, i32, i64, 32, 31
);
qformat!(
    /// Q2.30: one integer bit of headroom; values in `[-2, 2 - 2^-30]`.
    Q2_30, i32, i64, 32, 30
);
qformat!(
    /// Q1.15: 16-bit variant for the precision ablation; step `2^-15`.
    Q1_15, i16, i32, 16, 15
);

macro_rules! dataword_fixed {
    ($name:ident, $label:expr, $prec:expr, $un:ty) => {
        impl Dataword for $name {
            const BITS: u32 = <$name as Fixed>::BITS;
            const NAME: &'static str = $label;
            const IS_FIXED: bool = true;
            #[inline]
            fn from_f32(x: f32) -> Self {
                <$name as Fixed>::from_f64(x as f64)
            }
            #[inline]
            fn to_f32(self) -> f32 {
                <$name as Fixed>::to_f64(self) as f32
            }
            fn ulp() -> f64 {
                <$name as Fixed>::ulp()
            }
            #[inline]
            fn sat_add(self, rhs: Self) -> Self {
                <$name as Fixed>::add(self, rhs)
            }
            #[inline]
            fn sat_mul(self, rhs: Self) -> Self {
                <$name as Fixed>::mul(self, rhs)
            }
            #[inline]
            fn to_bits(self) -> u32 {
                // Through the unsigned twin of the raw type: `i16 as u32`
                // would sign-extend and leak format width into the bits.
                self.0 as $un as u32
            }
            #[inline]
            fn from_bits(bits: u32) -> Self {
                $name(bits as $un as _)
            }
            fn precision() -> Precision {
                $prec
            }
        }
    };
}

dataword_fixed!(Q1_31, "q1.31", Precision::FixedQ1_31, u32);
dataword_fixed!(Q2_30, "q2.30", Precision::FixedQ2_30, u32);
dataword_fixed!(Q1_15, "q1.15", Precision::FixedQ1_15, u16);

/// Bits per HBM transaction line (§IV-B1): one 512-bit AXI beat.
pub const LINE_BITS: u32 = 512;

/// COO entries per 512-bit line when values are stored in `value_bits`-wide
/// words: `floor(512 / (32 + 32 + value_bits))` — row and column indices
/// stay 32-bit. 5 entries at f32 (480/512 bits used), 6 at Q1.15 (§IV-B1).
pub const fn packet_capacity(value_bits: u32) -> usize {
    (LINE_BITS / (32 + 32 + value_bits)) as usize
}

/// Precision mode for the mixed-precision datapath: the runtime-dispatch
/// selector over the monomorphized [`Dataword`] kernels (see
/// [`with_precision!`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// IEEE f32 everywhere (the CPU baseline datapath).
    Float32,
    /// Store matrix values and Lanczos vectors as Q1.31 (the paper's
    /// device datapath; dots/norms still accumulate in float, matching the
    /// design's float units "where required to guarantee precise results").
    FixedQ1_31,
    /// Q2.30 variant (headroom, one fewer fractional bit).
    FixedQ2_30,
    /// Q1.15 variant (16-bit: half the value bytes, 6 entries per line).
    FixedQ1_15,
}

impl Precision {
    /// All four formats, in decreasing-precision order (ablation sweeps).
    pub const ALL: [Precision; 4] =
        [Precision::Float32, Precision::FixedQ1_31, Precision::FixedQ2_30, Precision::FixedQ1_15];

    /// Quantize one value under this mode.
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            Precision::Float32 => x,
            Precision::FixedQ1_31 => Q1_31::quantize(x as f64) as f32,
            Precision::FixedQ2_30 => Q2_30::quantize(x as f64) as f32,
            Precision::FixedQ1_15 => Q1_15::quantize(x as f64) as f32,
        }
    }

    /// Quantize a vector in place.
    pub fn quantize_slice(self, xs: &mut [f32]) {
        if self == Precision::Float32 {
            return;
        }
        for x in xs {
            *x = self.quantize(*x);
        }
    }

    /// Name for reports.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Float32 => "f32",
            Precision::FixedQ1_31 => "q1.31",
            Precision::FixedQ2_30 => "q2.30",
            Precision::FixedQ1_15 => "q1.15",
        }
    }

    /// Stored bits per value in this format.
    pub fn bits(self) -> u32 {
        match self {
            Precision::Float32 => <f32 as Dataword>::BITS,
            Precision::FixedQ1_31 => <Q1_31 as Dataword>::BITS,
            Precision::FixedQ2_30 => <Q2_30 as Dataword>::BITS,
            Precision::FixedQ1_15 => <Q1_15 as Dataword>::BITS,
        }
    }

    /// COO entries per 512-bit HBM line in this format (§IV-B1).
    pub fn packet_capacity(self) -> usize {
        packet_capacity(self.bits())
    }

    /// Bytes a value array of `nnz` entries occupies in this format.
    pub fn value_bytes(self, nnz: usize) -> usize {
        nnz * (self.bits() as usize / 8)
    }
}

/// Dispatch a runtime [`Precision`] onto code generic over a
/// [`Dataword`] storage type: inside `$body`, `$V` names the concrete
/// scalar type (`f32`, [`Q1_31`], [`Q2_30`], or [`Q1_15`]).
///
/// ```
/// use topk_eigen::fixed::{Dataword, Precision};
/// let p = Precision::FixedQ1_15;
/// let bytes = topk_eigen::with_precision!(p, V => V::bytes());
/// assert_eq!(bytes, 2);
/// ```
#[macro_export]
macro_rules! with_precision {
    ($p:expr, $V:ident => $body:expr) => {{
        match $p {
            $crate::fixed::Precision::Float32 => {
                type $V = f32;
                $body
            }
            $crate::fixed::Precision::FixedQ1_31 => {
                type $V = $crate::fixed::Q1_31;
                $body
            }
            $crate::fixed::Precision::FixedQ2_30 => {
                type $V = $crate::fixed::Q2_30;
                $body
            }
            $crate::fixed::Precision::FixedQ1_15 => {
                type $V = $crate::fixed::Q1_15;
                $body
            }
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q131_round_trip_error_is_sub_ulp() {
        for &x in &[0.0, 0.5, -0.25, 0.999_999, -0.999_999, 1e-9] {
            let err = (Q1_31::quantize(x) - x).abs();
            assert!(err <= <Q1_31 as Fixed>::ulp() / 2.0 + 1e-18, "x={x} err={err}");
        }
    }

    #[test]
    fn q131_saturates_at_one() {
        assert_eq!(Q1_31::from_f64(1.5).0, i32::MAX);
        assert_eq!(Q1_31::from_f64(-1.5).0, i32::MIN);
        assert!((Q1_31::from_f64(-1.0).to_f64() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn q230_has_headroom() {
        assert!((Q2_30::quantize(1.5) - 1.5).abs() < <Q2_30 as Fixed>::ulp());
        assert_eq!(Q2_30::from_f64(2.5).0, i32::MAX);
    }

    #[test]
    fn mul_matches_float_product() {
        let a = Q1_31::from_f64(0.5);
        let b = Q1_31::from_f64(-0.25);
        assert!((a.mul(b).to_f64() - -0.125).abs() <= <Q1_31 as Fixed>::ulp());
        // Q1.15 coarser.
        let c = Q1_15::from_f64(0.3);
        let d = Q1_15::from_f64(0.7);
        assert!((c.mul(d).to_f64() - 0.21).abs() <= 2.0 * <Q1_15 as Fixed>::ulp());
    }

    #[test]
    fn add_saturates_not_wraps() {
        let a = Q1_31::from_f64(0.9);
        let b = Q1_31::from_f64(0.9);
        let s = a.add(b).to_f64();
        assert!((s - (1.0 - <Q1_31 as Fixed>::ulp())).abs() < 1e-9, "saturated sum was {s}");
        // Q2.30 can represent 1.8.
        let s2 = Q2_30::from_f64(0.9).add(Q2_30::from_f64(0.9)).to_f64();
        assert!((s2 - 1.8).abs() < 2.0 * <Q2_30 as Fixed>::ulp());
    }

    #[test]
    fn ulp_ordering_across_formats() {
        assert!(<Q1_31 as Fixed>::ulp() < <Q2_30 as Fixed>::ulp());
        assert!(<Q2_30 as Fixed>::ulp() < <Q1_15 as Fixed>::ulp());
        assert_eq!(<Q1_15 as Fixed>::ulp(), 2.0f64.powi(-15));
    }

    #[test]
    fn precision_mode_quantizes_slices() {
        let mut xs = vec![0.123456789f32, -0.987654321, 0.5];
        let orig = xs.clone();
        Precision::FixedQ1_15.quantize_slice(&mut xs);
        assert!(xs.iter().zip(&orig).any(|(a, b)| a != b), "q1.15 must perturb");
        for (a, b) in xs.iter().zip(&orig) {
            assert!((a - b).abs() <= <Q1_15 as Fixed>::ulp() as f32);
        }
        let mut ys = orig.clone();
        Precision::Float32.quantize_slice(&mut ys);
        assert_eq!(ys, orig);
    }

    #[test]
    fn quantization_error_shrinks_with_frac_bits() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        let (mut e15, mut e31) = (0.0f64, 0.0f64);
        for _ in 0..1000 {
            let x = rng.f64_range(-1.0, 1.0);
            e15 += (Q1_15::quantize(x) - x).abs();
            e31 += (Q1_31::quantize(x) - x).abs();
        }
        assert!(e31 < e15 / 1000.0, "e31={e31} e15={e15}");
    }

    /// Generic round-trip check usable for any storage scalar.
    fn roundtrip_within_ulp<V: Dataword>() {
        for &x in &[0.0f32, 0.5, -0.25, 0.874_301, -0.999_9, 3.1e-5] {
            let rt = V::from_f32(x).to_f32();
            assert!(((rt - x).abs() as f64) <= V::ulp(), "{}: x={x} rt={rt}", V::NAME);
        }
    }

    #[test]
    fn dataword_round_trips_all_formats() {
        roundtrip_within_ulp::<f32>();
        roundtrip_within_ulp::<Q1_31>();
        roundtrip_within_ulp::<Q2_30>();
        roundtrip_within_ulp::<Q1_15>();
    }

    #[test]
    fn dataword_f32_is_identity() {
        for &x in &[0.1f32, -0.7, 1e-20, 123.456] {
            assert_eq!(<f32 as Dataword>::from_f32(x).to_bits(), x.to_bits());
        }
        assert!(!<f32 as Dataword>::IS_FIXED);
        assert!(<Q1_15 as Dataword>::IS_FIXED);
    }

    #[test]
    fn dataword_matches_fixed_quantization() {
        // The typed storage path and the legacy rounding pass must agree.
        for &x in &[0.123_456_789f32, -0.987_654_32, 0.000_244_14] {
            assert_eq!(<Q1_31 as Dataword>::from_f32(x).to_f32(), Precision::FixedQ1_31.quantize(x));
            assert_eq!(<Q1_15 as Dataword>::from_f32(x).to_f32(), Precision::FixedQ1_15.quantize(x));
        }
    }

    /// Generic bit-serialization check usable for any storage scalar.
    fn bits_round_trip_exact<V: Dataword>() {
        for &x in &[0.0f32, 0.5, -0.25, 0.874_301, -0.999_9, 3.1e-5] {
            let v = V::from_f32(x);
            assert_eq!(V::from_bits(v.to_bits()), v, "{}: x={x}", V::NAME);
        }
    }

    #[test]
    fn dataword_bits_round_trip_all_formats() {
        bits_round_trip_exact::<f32>();
        bits_round_trip_exact::<Q1_31>();
        bits_round_trip_exact::<Q2_30>();
        bits_round_trip_exact::<Q1_15>();
        // Negative raw values must not sign-extend into the u32 container
        // and must come back exact — incl. the 16-bit format.
        let q = Q1_15(-12345);
        assert_eq!(q.to_bits(), 0x0000_CFC7);
        assert_eq!(<Q1_15 as Dataword>::from_bits(q.to_bits()), q);
        // f32 bits match the inherent IEEE representation.
        assert_eq!(Dataword::to_bits(-0.5f32), (-0.5f32).to_bits());
    }

    #[test]
    fn dataword_bits_survive_where_f32_roundtrip_is_lossy() {
        // A raw Q1.31 value with all 31 fraction bits set is not
        // representable in an f32 (24-bit mantissa): the f32 round-trip the
        // in-memory quantization path uses must perturb it, while the raw
        // bit path the packet files use must not. This is the whole reason
        // the OOC format serializes `to_bits`, not `to_f32`.
        for raw in [0x7FFF_FFF1u32, 0x8000_0003] {
            let q = <Q1_31 as Dataword>::from_bits(raw);
            assert_eq!(q.to_bits(), raw);
            assert_ne!(<Q1_31 as Dataword>::from_f32(q.to_f32()), q, "f32 trip must be lossy");
            assert_eq!(<Q1_31 as Dataword>::from_bits(q.to_bits()), q, "bit trip must be exact");
        }
    }

    #[test]
    fn dataword_sat_ops_saturate() {
        let big = <Q1_15 as Dataword>::from_f32(0.9);
        let sum = big.sat_add(big).to_f32() as f64;
        assert!((sum - (1.0 - <Q1_15 as Fixed>::ulp())).abs() < 1e-4, "sum={sum}");
        let prod = big.sat_mul(big).to_f32() as f64;
        assert!((prod - 0.81).abs() <= 2.0 * <Q1_15 as Fixed>::ulp(), "prod={prod}");
        // f32 sat ops are plain IEEE ops.
        assert_eq!(2.0f32.sat_add(3.0), 5.0);
        assert_eq!(2.0f32.sat_mul(3.0), 6.0);
    }

    #[test]
    fn packet_capacity_per_format() {
        // §IV-B1: 5 COO entries per 512-bit line at 32-bit values; a 16-bit
        // dataword fits 6 (80 bits per entry, 480/512 used).
        assert_eq!(packet_capacity(32), 5);
        assert_eq!(packet_capacity(16), 6);
        assert_eq!(Precision::Float32.packet_capacity(), 5);
        assert_eq!(Precision::FixedQ1_31.packet_capacity(), 5);
        assert_eq!(Precision::FixedQ2_30.packet_capacity(), 5);
        assert_eq!(Precision::FixedQ1_15.packet_capacity(), 6);
    }

    #[test]
    fn value_bytes_halve_at_q115() {
        assert_eq!(Precision::Float32.value_bytes(1000), 4000);
        assert_eq!(Precision::FixedQ1_15.value_bytes(1000), 2000);
        assert_eq!(<Q1_15 as Dataword>::bytes(), 2);
        assert_eq!(<f32 as Dataword>::bytes(), 4);
    }

    #[test]
    fn with_precision_dispatches_every_format() {
        for p in Precision::ALL {
            let (name, bits) = crate::with_precision!(p, V => (V::NAME, V::BITS));
            assert_eq!(name, p.name());
            assert_eq!(bits, p.bits());
            assert_eq!(crate::with_precision!(p, V => V::precision()), p);
        }
    }
}

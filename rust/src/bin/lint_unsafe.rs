//! `lint_unsafe` — hermetic static audit of the crate's unsafe surface.
//!
//! Walks every `.rs` file under `rust/src` (no dependencies, no network, no
//! proc macros — a comment/string-aware line scanner) and enforces the
//! repo's unsafe-code policy:
//!
//! 1. Every `unsafe` **block** and `unsafe impl` is immediately preceded by
//!    a `// SAFETY:` comment (trailing same-line comments count; attribute
//!    lines between the comment and the item are skipped). `unsafe fn`
//!    declarations are exempt — their *bodies* are covered by
//!    `#![deny(unsafe_op_in_unsafe_fn)]` in `lib.rs`, which forces every
//!    interior dereference into its own commented block.
//! 2. Every `SendPtr(` construction and every `unsafe impl` is accounted
//!    for in the checked-in allowlist `scripts/unsafe_inventory.toml`,
//!    which pairs each site count with a one-line disjointness argument.
//!    Stale allowlist rows (counting sites that no longer exist) fail too.
//! 3. `static mut` and `transmute` are forbidden outright.
//! 4. `unsafe` may only appear in the audited modules named by the
//!    allowlist; a new module growing unsafe code must be added there (and
//!    to the ARCHITECTURE.md inventory table) deliberately.
//!
//! The binary's own file is skipped: it embeds deliberately-violating
//! fixtures for `--self-test`, and `#![forbid(unsafe_code)]` below makes
//! the compiler — not this scanner — the guarantee that it stays clean.
//!
//! Usage: `cargo run --bin lint_unsafe` (blocking CI step) or
//! `cargo run --bin lint_unsafe -- --self-test` to run the embedded
//! fixture checks (a fixture with an uncommented unsafe block MUST fail).

#![forbid(unsafe_code)]

use std::fs;
use std::path::{Path, PathBuf};

/// A single policy violation, printed as `rust/src/<file>:<line>: <msg>`.
struct Violation {
    file: String,
    line: usize,
    msg: String,
}

/// One source file split into parallel per-line views: `code` has comments,
/// string literals, and char literals blanked (so token scans never match
/// inside prose), `comments` holds comment text only (so `SAFETY:` markers
/// are found without string-literal false positives).
struct Stripped {
    code: Vec<String>,
    comments: Vec<String>,
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

/// Comment/string/char-literal stripper. Handles nested block comments,
/// raw strings (`r"…"`, `r#"…"#`, `br#"…"#`), escapes, and the
/// char-literal-vs-lifetime ambiguity (`'a'` starts a literal, `'a` in
/// `<'a>` does not).
fn strip(source: &str) -> Stripped {
    let chars: Vec<char> = source.chars().collect();
    let mut code = Vec::new();
    let mut comments = Vec::new();
    let mut cur_code = String::new();
    let mut cur_com = String::new();
    let mut state = State::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            // Line comments end here; every other state spans the newline.
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            code.push(std::mem::take(&mut cur_code));
            comments.push(std::mem::take(&mut cur_com));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    cur_com.push_str("//");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    cur_code.push(' ');
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    // Possible raw-string opener: r"…", r#"…"#, br#"…"#.
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    if c == 'b' && j == i + 1 {
                        // Plain identifier starting with 'b' (or b"…",
                        // handled by the '"' arm next round).
                        cur_code.push(c);
                        i += 1;
                        continue;
                    }
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        state = State::RawStr(hashes);
                        cur_code.push(' ');
                        i = j + 1;
                    } else {
                        cur_code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal iff it closes within two chars or starts
                    // with an escape; otherwise it is a lifetime tick.
                    if next == Some('\\') || chars.get(i + 2) == Some(&'\'') {
                        state = State::CharLit;
                        cur_code.push(' ');
                        i += 1;
                    } else {
                        cur_code.push(c);
                        i += 1;
                    }
                } else {
                    cur_code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur_com.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    cur_com.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '"' {
                        state = State::Code;
                    }
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let closes = (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'));
                    if closes {
                        state = State::Code;
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                i += 1;
            }
            State::CharLit => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '\'' {
                        state = State::Code;
                    }
                    i += 1;
                }
            }
        }
    }
    code.push(cur_code);
    comments.push(cur_com);
    Stripped { code, comments }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of standalone-word occurrences of `word` in `line`.
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (pos, _) in line.match_indices(word) {
        let before_ok = !line[..pos].chars().next_back().is_some_and(is_ident);
        let after_ok = !line[pos + word.len()..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            out.push(pos);
        }
    }
    out
}

/// The first code token at or after byte `col` of line `line_idx`: an
/// identifier word, or a single punctuation char (so `unsafe {` yields
/// `{` and `unsafe impl<T>` yields `impl`).
fn next_token(code: &[String], line_idx: usize, col: usize) -> Option<String> {
    let mut li = line_idx;
    let mut start = col;
    while li < code.len() {
        let rest = &code[li][start.min(code[li].len())..];
        let trimmed = rest.trim_start();
        if let Some(c) = trimmed.chars().next() {
            if is_ident(c) {
                return Some(trimmed.chars().take_while(|&c| is_ident(c)).collect());
            }
            return Some(c.to_string());
        }
        li += 1;
        start = 0;
    }
    None
}

/// Whether the `unsafe` occurrence on `line_idx` is covered by a
/// `// SAFETY:` comment: trailing on the same line, or in the contiguous
/// comment block immediately above (attribute lines in between are
/// skipped, anything else breaks the chain).
fn has_safety_comment(s: &Stripped, line_idx: usize) -> bool {
    if s.comments[line_idx].contains("SAFETY:") {
        return true;
    }
    let mut j = line_idx;
    while j > 0 {
        j -= 1;
        let code_empty = s.code[j].trim().is_empty();
        let com = &s.comments[j];
        if !com.is_empty() && code_empty {
            if com.contains("SAFETY:") {
                return true;
            }
        } else if s.code[j].trim_start().starts_with("#[") || s.code[j].trim_start().starts_with("#!") {
            continue;
        } else {
            return false;
        }
    }
    false
}

/// Unsafe-surface census of one file.
#[derive(Default)]
struct Counts {
    unsafe_impl: usize,
    sendptr: usize,
    unsafe_blocks: usize,
    /// Line of the first counted site per kind — anchors inventory-mismatch
    /// messages to real code.
    first_impl_line: usize,
    first_sendptr_line: usize,
}

/// Scan one stripped file against the policy. `audited` decides whether
/// `unsafe` is allowed here at all; inventory reconciliation happens later
/// with the full census in hand.
fn check_file(rel: &str, s: &Stripped, audited: bool, out: &mut Vec<Violation>) -> Counts {
    let mut counts = Counts::default();
    for (li, line) in s.code.iter().enumerate() {
        let ln = li + 1;
        for pos in word_positions(line, "unsafe") {
            if !audited {
                out.push(Violation {
                    file: rel.to_string(),
                    line: ln,
                    msg: "`unsafe` outside the audited modules listed in scripts/unsafe_inventory.toml".into(),
                });
                continue;
            }
            match next_token(&s.code, li, pos + "unsafe".len()).as_deref() {
                Some("fn") => {} // declaration: body policed by deny(unsafe_op_in_unsafe_fn)
                Some("impl") => {
                    counts.unsafe_impl += 1;
                    if counts.first_impl_line == 0 {
                        counts.first_impl_line = ln;
                    }
                    if !has_safety_comment(s, li) {
                        out.push(Violation {
                            file: rel.to_string(),
                            line: ln,
                            msg: "`unsafe impl` without an immediately preceding `// SAFETY:` comment".into(),
                        });
                    }
                }
                Some("{") => {
                    counts.unsafe_blocks += 1;
                    if !has_safety_comment(s, li) {
                        out.push(Violation {
                            file: rel.to_string(),
                            line: ln,
                            msg: "`unsafe` block without an immediately preceding `// SAFETY:` comment".into(),
                        });
                    }
                }
                tok => {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: ln,
                        msg: format!("unrecognized `unsafe` form (next token {tok:?})"),
                    });
                }
            }
        }
        for pos in line.match_indices("SendPtr(").map(|(p, _)| p) {
            if !line[..pos].chars().next_back().is_some_and(is_ident) {
                counts.sendptr += 1;
                if counts.first_sendptr_line == 0 {
                    counts.first_sendptr_line = ln;
                }
                if !audited {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: ln,
                        msg: "`SendPtr(` construction outside the audited modules".into(),
                    });
                }
            }
        }
        for pos in word_positions(line, "static") {
            if next_token(&s.code, li, pos + "static".len()).as_deref() == Some("mut") {
                out.push(Violation {
                    file: rel.to_string(),
                    line: ln,
                    msg: "`static mut` is forbidden; use an atomic, `Mutex`, or `OnceLock`".into(),
                });
            }
        }
        for _ in word_positions(line, "transmute") {
            out.push(Violation {
                file: rel.to_string(),
                line: ln,
                msg: "`transmute` is forbidden; use safe conversions or `from_bits`/`to_bits`".into(),
            });
        }
    }
    counts
}

// ---------------------------------------------------------------------------
// Allowlist: a hand-rolled parser for the TOML subset the inventory uses
// ([section], [[array-of-tables]], `key = "str" | int | [ "str", … ]`).
// ---------------------------------------------------------------------------

/// One allowlisted site count from `scripts/unsafe_inventory.toml`.
struct Site {
    file: String,
    kind: String,
    count: usize,
    why: String,
}

/// The parsed allowlist: audited module paths (relative to `rust/src`) and
/// per-file site counts.
struct Inventory {
    modules: Vec<String>,
    sites: Vec<Site>,
}

fn unquote(v: &str, ln: usize) -> Result<String, String> {
    let t = v.trim();
    if t.len() >= 2 && t.starts_with('"') && t.ends_with('"') {
        Ok(t[1..t.len() - 1].to_string())
    } else {
        Err(format!("line {ln}: expected a quoted string, got `{t}`"))
    }
}

fn parse_inventory(text: &str) -> Result<Inventory, String> {
    let mut inv = Inventory { modules: Vec::new(), sites: Vec::new() };
    let mut section = String::new();
    let mut in_modules_array = false;
    for (li, raw) in text.lines().enumerate() {
        let ln = li + 1;
        // Strip comments (the inventory's strings never contain '#').
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if in_modules_array {
            if line.starts_with(']') {
                in_modules_array = false;
            } else {
                inv.modules.push(unquote(line.trim_end_matches(','), ln)?);
            }
            continue;
        }
        if line == "[[site]]" {
            inv.sites.push(Site { file: String::new(), kind: String::new(), count: 0, why: String::new() });
            section = "site".into();
            continue;
        }
        if line.starts_with('[') {
            section = line.trim_matches(['[', ']']).to_string();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| format!("line {ln}: expected `key = value`"))?;
        let (key, value) = (key.trim(), value.trim());
        match (section.as_str(), key) {
            ("audit", "modules") => {
                if value == "[" {
                    in_modules_array = true;
                } else {
                    let inner = value.trim_start_matches('[').trim_end_matches(']');
                    for item in inner.split(',').filter(|s| !s.trim().is_empty()) {
                        inv.modules.push(unquote(item, ln)?);
                    }
                }
            }
            ("site", _) => {
                let site =
                    inv.sites.last_mut().ok_or_else(|| format!("line {ln}: `{key}` before any [[site]]"))?;
                match key {
                    "file" => site.file = unquote(value, ln)?,
                    "kind" => site.kind = unquote(value, ln)?,
                    "why" => site.why = unquote(value, ln)?,
                    "count" => site.count = value.parse().map_err(|e| format!("line {ln}: bad count: {e}"))?,
                    other => return Err(format!("line {ln}: unexpected `{other}` in [[site]]")),
                }
            }
            _ => return Err(format!("line {ln}: unexpected `{key}` in section `[{section}]`")),
        }
    }
    // The allowlist must be self-consistent before it can gate anything.
    let mut seen = Vec::new();
    for s in &inv.sites {
        if !matches!(s.kind.as_str(), "unsafe_impl" | "sendptr") {
            return Err(format!("site {}: unknown kind `{}`", s.file, s.kind));
        }
        if s.why.trim().is_empty() {
            return Err(format!("site {} ({}): missing the one-line `why` disjointness argument", s.file, s.kind));
        }
        if !inv.modules.contains(&s.file) {
            return Err(format!("site {} is not in the audited modules list", s.file));
        }
        let key = (s.file.clone(), s.kind.clone());
        if seen.contains(&key) {
            return Err(format!("duplicate site entry for {} ({})", s.file, s.kind));
        }
        seen.push(key);
    }
    Ok(inv)
}

// ---------------------------------------------------------------------------
// Repo walk + reconciliation.
// ---------------------------------------------------------------------------

/// Recursively collect `.rs` files under `dir`, sorted for deterministic
/// output, skipping `bin/` (this binary embeds violating fixtures and is
/// kept honest by `#![forbid(unsafe_code)]` instead).
fn collect_sources(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "bin") {
                continue;
            }
            collect_sources(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the full lint over `src_root` with `inventory`; returns violations.
fn run_lint(src_root: &Path, inventory: &Inventory) -> Result<Vec<Violation>, String> {
    let mut files = Vec::new();
    collect_sources(src_root, &mut files)?;
    if files.is_empty() {
        return Err(format!("no .rs files under {}", src_root.display()));
    }
    let mut violations = Vec::new();
    let mut census: Vec<(String, Counts)> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|e| e.to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        let source = fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let stripped = strip(&source);
        let audited = inventory.modules.contains(&rel);
        let counts = check_file(&rel, &stripped, audited, &mut violations);
        census.push((rel, counts));
    }
    reconcile(inventory, &census, &mut violations);
    Ok(violations)
}

/// Compare the census against the allowlist, both directions: undeclared
/// sites fail, and stale allowlist rows fail.
fn reconcile(inventory: &Inventory, census: &[(String, Counts)], out: &mut Vec<Violation>) {
    let expected = |file: &str, kind: &str| -> usize {
        inventory.sites.iter().find(|s| s.file == file && s.kind == kind).map_or(0, |s| s.count)
    };
    for (rel, counts) in census {
        let want_impl = expected(rel, "unsafe_impl");
        if counts.unsafe_impl != want_impl {
            out.push(Violation {
                file: rel.clone(),
                line: counts.first_impl_line.max(1),
                msg: format!(
                    "{} `unsafe impl` site(s) but the allowlist allows {want_impl}; update unsafe_inventory.toml",
                    counts.unsafe_impl
                ),
            });
        }
        let want_sp = expected(rel, "sendptr");
        if counts.sendptr != want_sp {
            out.push(Violation {
                file: rel.clone(),
                line: counts.first_sendptr_line.max(1),
                msg: format!(
                    "{} `SendPtr(` construction(s) but the allowlist allows {want_sp}; update unsafe_inventory.toml",
                    counts.sendptr
                ),
            });
        }
    }
    for site in &inventory.sites {
        if !census.iter().any(|(rel, _)| rel == &site.file) {
            out.push(Violation {
                file: site.file.clone(),
                line: 1,
                msg: format!("allowlisted ({}) in the inventory but the file does not exist", site.kind),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Self-test fixtures: the scanner must fail the bad ones and pass the good.
// ---------------------------------------------------------------------------

struct Fixture {
    name: &'static str,
    source: &'static str,
    audited: bool,
    /// Substring every expected violation message must contain; empty means
    /// the fixture must come back clean.
    expect: &'static str,
}

const FIXTURES: &[Fixture] = &[
    Fixture {
        name: "uncommented unsafe block fails",
        source: "fn f(p: *mut u8) {\n    let _ = unsafe { *p };\n}\n",
        audited: true,
        expect: "without an immediately preceding `// SAFETY:`",
    },
    Fixture {
        name: "commented unsafe block passes",
        source: "fn f(p: *mut u8) {\n    // SAFETY: caller guarantees p is valid.\n    let _ = unsafe { *p };\n}\n",
        audited: true,
        expect: "",
    },
    Fixture {
        name: "trailing same-line SAFETY comment passes",
        source: "fn f(p: *mut u8) {\n    let _ = unsafe { *p }; // SAFETY: p valid by contract.\n}\n",
        audited: true,
        expect: "",
    },
    Fixture {
        name: "attribute between comment and item is skipped",
        source: "// SAFETY: no interior mutability.\n#[allow(dead_code)]\nunsafe impl Send for X {}\n",
        audited: true,
        expect: "",
    },
    Fixture {
        name: "uncommented unsafe impl fails",
        source: "struct X;\nunsafe impl Send for X {}\n",
        audited: true,
        expect: "`unsafe impl` without an immediately preceding",
    },
    Fixture {
        name: "unsafe fn declaration alone is exempt",
        source: "/// # Safety\n/// Caller checks bounds.\npub unsafe fn get(i: usize) -> usize { i }\n",
        audited: true,
        expect: "",
    },
    Fixture {
        name: "static mut fails",
        source: "static mut COUNTER: u64 = 0;\n",
        audited: true,
        expect: "`static mut` is forbidden",
    },
    Fixture {
        name: "transmute fails",
        source: "fn f(x: u32) -> f32 {\n    // SAFETY: same size.\n    unsafe { std::mem::transmute(x) }\n}\n",
        audited: true,
        expect: "`transmute` is forbidden",
    },
    Fixture {
        name: "unsafe outside audited modules fails",
        source: "fn f(p: *mut u8) {\n    // SAFETY: commented, but the module is not audited.\n    let _ = unsafe { *p };\n}\n",
        audited: false,
        expect: "outside the audited modules",
    },
    Fixture {
        name: "unsafe in comments and strings is ignored",
        source: "// this comment says unsafe { } and static mut\nfn f() -> &'static str {\n    \"unsafe { transmute } SendPtr(\"\n}\n",
        audited: false,
        expect: "",
    },
];

/// Run the embedded fixtures; returns failure descriptions (empty = pass).
fn self_test() -> Vec<String> {
    let mut failures = Vec::new();
    for fx in FIXTURES {
        let stripped = strip(fx.source);
        let mut violations = Vec::new();
        check_file("fixture.rs", &stripped, fx.audited, &mut violations);
        if fx.expect.is_empty() {
            if !violations.is_empty() {
                failures.push(format!("{}: expected clean, got `{}`", fx.name, violations[0].msg));
            }
        } else if !violations.iter().any(|v| v.msg.contains(fx.expect)) {
            let got: Vec<&str> = violations.iter().map(|v| v.msg.as_str()).collect();
            failures.push(format!("{}: expected a violation containing `{}`, got {:?}", fx.name, fx.expect, got));
        }
    }
    // Inventory reconciliation fixture: one declared SendPtr, two real.
    let inv = Inventory {
        modules: vec!["m.rs".into()],
        sites: vec![Site { file: "m.rs".into(), kind: "sendptr".into(), count: 1, why: "test".into() }],
    };
    let src = "fn f(a: &mut [u8], b: &mut [u8]) {\n    let _p = SendPtr(a.as_mut_ptr());\n    let _q = SendPtr(b.as_mut_ptr());\n}\n";
    let mut violations = Vec::new();
    let counts = check_file("m.rs", &strip(src), true, &mut violations);
    reconcile(&inv, &[("m.rs".into(), counts)], &mut violations);
    if !violations.iter().any(|v| v.msg.contains("allows 1")) {
        failures.push("inventory mismatch fixture: expected a count-mismatch violation".into());
    }
    failures
}

// ---------------------------------------------------------------------------
// Entry point.
// ---------------------------------------------------------------------------

/// `rust/src`, resolved from the cargo manifest when run via `cargo run`,
/// with fallbacks for direct invocation from the repo root or `rust/`.
fn find_src_root() -> Result<PathBuf, String> {
    if let Ok(dir) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = Path::new(&dir).join("src");
        if p.is_dir() {
            return Ok(p);
        }
    }
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() {
            return Ok(p);
        }
    }
    Err("cannot locate rust/src (run via `cargo run --bin lint_unsafe`)".into())
}

/// `scripts/unsafe_inventory.toml`, which lives beside `rust/` at the repo
/// root.
fn find_inventory(src_root: &Path) -> Result<PathBuf, String> {
    let candidates = [
        src_root.join("../../scripts/unsafe_inventory.toml"),
        PathBuf::from("scripts/unsafe_inventory.toml"),
    ];
    candidates
        .iter()
        .find(|p| p.is_file())
        .cloned()
        .ok_or_else(|| "cannot locate scripts/unsafe_inventory.toml".into())
}

/// Locate the tree and the allowlist, then lint (the non-self-test path).
fn lint_repo() -> Result<Vec<Violation>, String> {
    let src_root = find_src_root()?;
    let inv_path = find_inventory(&src_root)?;
    let inv_text = fs::read_to_string(&inv_path).map_err(|e| format!("read {}: {e}", inv_path.display()))?;
    let inventory = parse_inventory(&inv_text).map_err(|e| format!("{}: {e}", inv_path.display()))?;
    run_lint(&src_root, &inventory)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-test") {
        let failures = self_test();
        if failures.is_empty() {
            println!("lint_unsafe self-test: {} fixtures passed", FIXTURES.len() + 1);
            return;
        }
        for f in &failures {
            eprintln!("lint_unsafe self-test FAILED: {f}");
        }
        std::process::exit(1);
    }
    match lint_repo() {
        Err(e) => {
            eprintln!("lint_unsafe: {e}");
            std::process::exit(2);
        }
        Ok(violations) if violations.is_empty() => {
            println!("lint_unsafe: rust/src clean (every unsafe site commented, inventoried, and audited)");
        }
        Ok(violations) => {
            for v in &violations {
                eprintln!("rust/src/{}:{}: {}", v.file, v.line, v.msg);
            }
            eprintln!("lint_unsafe: {} violation(s)", violations.len());
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripper_blanks_comments_strings_and_char_literals() {
        let s = strip("let x = \"unsafe { }\"; // unsafe impl\nlet c = 'u'; /* static\nmut */ let l: &'a str = r#\"transmute\"#;\n");
        assert!(!s.code[0].contains("unsafe"));
        assert!(s.comments[0].contains("unsafe impl"));
        assert!(!s.code[1].contains('u') || s.code[1].contains("let"));
        assert!(!s.code.concat().contains("transmute"));
        assert!(!s.code.concat().contains("mut */"));
        // The lifetime tick survives as code (it is not a char literal).
        assert!(s.code[2].contains("&'a str") || s.code[1].contains("&'a str"));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let s = strip("/* outer /* inner */ still comment */ fn f() {}\n");
        assert!(s.code[0].contains("fn f()"));
        assert!(!s.code[0].contains("still"));
    }

    #[test]
    fn self_test_fixtures_pass() {
        let failures = self_test();
        assert!(failures.is_empty(), "{failures:?}");
    }

    #[test]
    fn inventory_parser_roundtrips_the_real_format() {
        let text = "# comment\n[audit]\nmodules = [\n  \"a.rs\", # trailing\n  \"b.rs\",\n]\n\n[[site]]\nfile = \"a.rs\"\nkind = \"sendptr\"\ncount = 3\nwhy = \"disjoint stripes\"\n";
        let inv = parse_inventory(text).unwrap();
        assert_eq!(inv.modules, ["a.rs", "b.rs"]);
        assert_eq!(inv.sites.len(), 1);
        assert_eq!(inv.sites[0].count, 3);
    }

    #[test]
    fn inventory_parser_rejects_missing_why_and_unknown_kind() {
        let base = "[audit]\nmodules = [\"a.rs\"]\n[[site]]\nfile = \"a.rs\"\nkind = \"sendptr\"\ncount = 1\nwhy = \"\"\n";
        assert!(parse_inventory(base).unwrap_err().contains("why"));
        let bad_kind = "[audit]\nmodules = [\"a.rs\"]\n[[site]]\nfile = \"a.rs\"\nkind = \"bogus\"\ncount = 1\nwhy = \"x\"\n";
        assert!(parse_inventory(bad_kind).unwrap_err().contains("unknown kind"));
    }

    #[test]
    fn stale_allowlist_rows_are_violations() {
        let inv = Inventory {
            modules: vec!["gone.rs".into()],
            sites: vec![Site { file: "gone.rs".into(), kind: "sendptr".into(), count: 2, why: "x".into() }],
        };
        let mut violations = Vec::new();
        reconcile(&inv, &[], &mut violations);
        assert_eq!(violations.len(), 1);
        assert!(violations[0].msg.contains("does not exist"));
    }
}

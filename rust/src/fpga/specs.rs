//! Platform constants: Xilinx Alveo U280 as configured in the paper.

/// Alveo U280 + paper design operating point (§IV, §V, Table I).
#[derive(Clone, Copy, Debug)]
pub struct U280;

impl U280 {
    /// Synthesized clock (§V-A): 225 MHz.
    pub const CLOCK_HZ: f64 = 225e6;
    /// Measured per-channel HBM bandwidth (§IV-B1): 14.37 GB/s.
    pub const HBM_CHANNEL_GBPS: f64 = 14.37;
    /// AXI master ports available through the hardened switch (§IV-B1).
    pub const HBM_AXI_CHANNELS: usize = 32;
    /// SpMV compute units in the shipped design (§IV-B1).
    pub const SPMV_CUS: usize = 5;
    /// Dense-vector replicas per CU (§IV-B2).
    pub const VECTOR_REPLICAS: usize = 5;
    /// COO entries per 512-bit packet (§IV-B1).
    pub const PACKET_NNZ: usize = 5;
    /// Output values per 512-bit write-back packet (§IV-B1): "up to 15".
    pub const WRITEBACK_VALS: usize = 15;
    /// HBM bank capacity usable per dense-vector replica (§IV-B2): 250 MB.
    pub const HBM_BANK_BYTES: usize = 250 * 1024 * 1024;
    /// Max rows supported by the vector subsystem (§IV-B2): 62.4M.
    pub const MAX_ROWS: usize = 62_400_000;
    /// f32 lanes of one 512-bit word.
    pub const F32_LANES: usize = 16;

    /// Aggregate matrix-read bandwidth with all CUs active (§V-A).
    pub fn aggregate_read_gbps() -> f64 {
        Self::SPMV_CUS as f64 * Self::HBM_CHANNEL_GBPS
    }

    /// Total SLR count on the U280.
    pub const SLRS: usize = 3;

    // ---- Table I "Available" row (xcu280-fsvh2892-2L-e) ----
    /// Device LUTs.
    pub const LUTS: usize = 1_097_419;
    /// Device flip-flops.
    pub const FFS: usize = 2_180_971;
    /// Device BRAM tiles.
    pub const BRAMS: usize = 1_812;
    /// Device URAM tiles.
    pub const URAMS: usize = 960;
    /// Device DSP48 slices.
    pub const DSPS: usize = 9_020;

    /// Paper's measured board power during execution (§V-B), watts.
    pub const FPGA_POWER_W: f64 = 38.0;
    /// Paper's FPGA host-server power (§V-B), watts.
    pub const HOST_POWER_W: f64 = 40.0;
    /// Paper's CPU-baseline power (2x Xeon 6248 under load, §V-B), watts.
    pub const CPU_POWER_W: f64 = 300.0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_bandwidth_matches_paper() {
        // §V-A: "14.37 GB/s, for a total of 71.87 GB/s using 5 CU".
        let agg = U280::aggregate_read_gbps();
        assert!((agg - 71.85).abs() < 0.2, "aggregate {agg}");
    }

    #[test]
    fn packet_feeds_match_channel_bandwidth() {
        // One packet (64 B) per cycle at 225 MHz = 14.4 GB/s — the model is
        // self-consistent: packet rate saturates exactly one HBM channel.
        let bytes_per_s = 64.0 * U280::CLOCK_HZ;
        assert!((bytes_per_s / 1e9 - U280::HBM_CHANNEL_GBPS).abs() < 0.1);
    }

    #[test]
    fn replica_channels_fit_axi_switch() {
        // 5 CUs x (1 matrix + 5 replica) channels = 30 <= 32.
        let used = U280::SPMV_CUS * (1 + U280::VECTOR_REPLICAS);
        assert!(used <= U280::HBM_AXI_CHANNELS, "{used} channels");
    }

    #[test]
    fn max_rows_fit_replica_bank() {
        // 62.4M f32 rows = 249.6 MB < 250 MB bank.
        assert!(U280::MAX_ROWS * 4 <= U280::HBM_BANK_BYTES);
    }
}

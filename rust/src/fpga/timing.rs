//! Cycle-level execution-time model of the two-phase design.
//!
//! **Lanczos phase** (SLR0, §IV-A/B): per iteration,
//! * SpMV — each CU streams its COO shard at one 512-bit packet (5 nnz)
//!   per cycle; the phase ends when the *slowest* shard finishes (the
//!   paper's Merge Unit joins all CUs), so imbalance shows up faithfully;
//! * write-back — 15 values per 512-bit packet per CU, overlapped with the
//!   stream but bounded below by `n / (15 * CUs)` cycles;
//! * vector replication — the merged result is broadcast to all 25 replica
//!   banks, 16 f32 lanes per cycle per CU channel group;
//! * scalar chain (norm, axpy, dot; Algorithm 1 lines 5-9) — 16-lane
//!   pipelined units, ~3 passes over `n`;
//! * reorthogonalization — `2 i` extra n-length passes on iterations where
//!   the policy fires.
//!
//! **Jacobi phase** (SLR1/2, §IV-C): `sweeps x (K-1)` parallel steps of
//! constant latency (the systolic property), plus the `3K-2`-word PLRAM
//! transfer. Step latency = Taylor-trig + 2x2 rotate + neighbour exchange,
//! a pipeline of ~[`JACOBI_STEP_CYCLES`] cycles.
//!
//! The model is validated two ways (tests below): the SpMV phase reproduces
//! the paper's bandwidth bound (71.87 GB/s aggregate), and the end-to-end
//! time per non-zero is constant across graph sizes — the flat FPGA line
//! of Fig 10a.

use crate::fpga::specs::U280;
use crate::lanczos::ReorthPolicy;
use crate::sparse::RowPartition;

/// Latency of one systolic parallel step, cycles. Taylor-series arctan
/// (3 mults) + sin/cos (6 mults) + 2x2 rotations (8 mults, unrolled) +
/// neighbour propagation, fully pipelined: the conservative depth used for
/// all Jacobi estimates.
pub const JACOBI_STEP_CYCLES: usize = 32;

/// Cycles to move the `3K-2` tridiagonal words over PLRAM (§IV-C), one
/// word per cycle plus a fixed handshake.
pub const PLRAM_HANDSHAKE_CYCLES: usize = 16;

/// Per-phase breakdown of one solve (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    /// SpMV streaming across all K iterations.
    pub spmv_s: f64,
    /// Write-back + replica broadcast across all K iterations.
    pub memory_s: f64,
    /// Dense vector ops (lines 5-9) across all K iterations.
    pub vector_s: f64,
    /// Reorthogonalization across all K iterations.
    pub reorth_s: f64,
    /// Jacobi systolic phase.
    pub jacobi_s: f64,
}

impl PhaseTimes {
    /// Total seconds.
    pub fn total_s(&self) -> f64 {
        self.spmv_s + self.memory_s + self.vector_s + self.reorth_s + self.jacobi_s
    }
    /// Lanczos-only seconds.
    pub fn lanczos_s(&self) -> f64 {
        self.spmv_s + self.memory_s + self.vector_s + self.reorth_s
    }
}

/// The timing model, parameterized on the deployed design point.
#[derive(Clone, Copy, Debug)]
pub struct FpgaTimingModel {
    /// Number of SpMV CUs (5 in the shipped bitstream).
    pub cus: usize,
    /// COO entries per packet (5 = 512-bit lines).
    pub packet_nnz: usize,
    /// Clock frequency in Hz.
    pub clock_hz: f64,
}

impl Default for FpgaTimingModel {
    fn default() -> Self {
        Self { cus: U280::SPMV_CUS, packet_nnz: U280::PACKET_NNZ, clock_hz: U280::CLOCK_HZ }
    }
}

impl FpgaTimingModel {
    /// Model the design point streaming matrix values in storage format
    /// `p`: smaller datawords raise the entries-per-line count (§IV-B1),
    /// e.g. 6 at Q1.15 vs 5 at f32, which shortens the SpMV phase by the
    /// same ratio at fixed HBM bandwidth.
    pub fn for_precision(p: crate::fixed::Precision) -> Self {
        Self { packet_nnz: p.packet_capacity(), ..Default::default() }
    }

    /// Cycles for one SpMV iteration given the per-CU shard sizes: the
    /// slowest CU (most packets) gates the merge.
    pub fn spmv_cycles(&self, shards: &[RowPartition]) -> usize {
        assert!(!shards.is_empty());
        shards
            .iter()
            .map(|p| p.nnz.div_ceil(self.packet_nnz))
            .max()
            .unwrap()
    }

    /// Cycles for write-back + replica broadcast of an n-vector.
    pub fn memory_cycles(&self, n: usize) -> usize {
        let writeback = n.div_ceil(U280::WRITEBACK_VALS * self.cus);
        // Broadcast: each CU's channel group rebroadcasts the merged vector
        // to its replicas; 16 f32 lanes/cycle, replicas filled in parallel
        // across channels, serially per-replica within a channel group.
        let broadcast = n.div_ceil(U280::F32_LANES) * U280::VECTOR_REPLICAS / self.cus.max(1);
        writeback + broadcast
    }

    /// Cycles for the scalar/vector chain of one iteration (norm +
    /// normalize + dot + 2x axpy ≈ 3 pipelined passes over n, 16 lanes).
    pub fn vector_cycles(&self, n: usize) -> usize {
        3 * n.div_ceil(U280::F32_LANES)
    }

    /// Cycles for reorthogonalization at iteration `i` (1-based), if due:
    /// `i` dot products + `i` axpys, each an n-pass at 16 lanes.
    pub fn reorth_cycles(&self, n: usize, i: usize, policy: ReorthPolicy) -> usize {
        let due = match policy {
            ReorthPolicy::None => false,
            ReorthPolicy::Every => true,
            ReorthPolicy::EveryN(p) => p != 0 && i % p == 0,
        };
        if due {
            2 * i * n.div_ceil(U280::F32_LANES)
        } else {
            0
        }
    }

    /// Jacobi phase cycles given the measured systolic step count.
    pub fn jacobi_cycles(&self, k: usize, steps: usize) -> usize {
        PLRAM_HANDSHAKE_CYCLES + (3 * k).saturating_sub(2) + steps * JACOBI_STEP_CYCLES
    }

    /// Full solve estimate.
    ///
    /// * `n`, `shards` — matrix dimensions and the CU partition;
    /// * `k` — eigencomponents;
    /// * `policy` — reorthogonalization cadence;
    /// * `jacobi_steps` — parallel steps the systolic run needed (from
    ///   [`crate::jacobi::SystolicStats`], or `(k-1) * sweeps` estimate).
    pub fn solve_time(
        &self,
        n: usize,
        shards: &[RowPartition],
        k: usize,
        policy: ReorthPolicy,
        jacobi_steps: usize,
    ) -> PhaseTimes {
        let spmv = self.spmv_cycles(shards) * k;
        let mem = self.memory_cycles(n) * k;
        let vec = self.vector_cycles(n) * k;
        let reorth: usize = (1..=k).map(|i| self.reorth_cycles(n, i, policy)).sum();
        let jac = self.jacobi_cycles(k, jacobi_steps);
        let s = |c: usize| c as f64 / self.clock_hz;
        PhaseTimes {
            spmv_s: s(spmv),
            memory_s: s(mem),
            vector_s: s(vec),
            reorth_s: s(reorth),
            jacobi_s: s(jac),
        }
    }

    /// Effective matrix-read bandwidth during SpMV (GB/s) for a balanced
    /// partition — the model's sanity anchor against §V-A. Counts full
    /// 512-bit lines (the paper's convention): each packet moves 64 bytes
    /// even though only 60 carry COO words.
    pub fn effective_read_gbps(&self, shards: &[RowPartition]) -> f64 {
        let packets: usize = shards.iter().map(|p| p.nnz.div_ceil(self.packet_nnz)).sum();
        let bytes = packets as f64 * 64.0;
        let secs = self.spmv_cycles(shards) as f64 / self.clock_hz;
        bytes / secs / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{partition_rows_balanced, PartitionPolicy};

    fn shards_for(nnz: usize, cus: usize) -> Vec<RowPartition> {
        // Perfectly balanced synthetic shards.
        (0..cus)
            .map(|i| RowPartition { row_start: i, row_end: i + 1, nnz: nnz / cus })
            .collect()
    }

    #[test]
    fn balanced_spmv_hits_paper_aggregate_bandwidth() {
        let m = FpgaTimingModel::default();
        let shards = shards_for(50_000_000, 5);
        let gbps = m.effective_read_gbps(&shards);
        // §V-A: 71.87 GB/s aggregate.
        assert!((gbps - 71.87).abs() / 71.87 < 0.02, "gbps = {gbps}");
    }

    #[test]
    fn slowest_shard_gates_iteration() {
        let m = FpgaTimingModel::default();
        let mut shards = shards_for(1_000_000, 5);
        shards[0].nnz = 600_000; // skewed CU
        let cycles = m.spmv_cycles(&shards);
        assert_eq!(cycles, 120_000);
    }

    #[test]
    fn time_per_nnz_is_flat_across_sizes() {
        // Fig 10a: FPGA time / nnz must be ~constant as graphs grow.
        let m = FpgaTimingModel::default();
        let mut per_nnz = Vec::new();
        for scale in [1usize, 4, 16, 64] {
            let nnz = 1_000_000 * scale;
            let n = 100_000 * scale;
            let t = m.solve_time(n, &shards_for(nnz, 5), 16, ReorthPolicy::EveryN(2), 100);
            per_nnz.push(t.total_s() / nnz as f64);
        }
        let (min, max) = per_nnz.iter().fold((f64::MAX, 0.0f64), |(lo, hi), &v| (lo.min(v), hi.max(v)));
        assert!(max / min < 1.3, "per-nnz spread {per_nnz:?}");
    }

    #[test]
    fn reorth_cost_matches_cadence() {
        let m = FpgaTimingModel::default();
        let n = 1_000_000;
        let every: usize = (1..=16).map(|i| m.reorth_cycles(n, i, ReorthPolicy::Every)).sum();
        let every2: usize = (1..=16).map(|i| m.reorth_cycles(n, i, ReorthPolicy::EveryN(2))).sum();
        let none: usize = (1..=16).map(|i| m.reorth_cycles(n, i, ReorthPolicy::None)).sum();
        assert_eq!(none, 0);
        // Every-2 does the even iterations only: sum(2,4,..,16)=72 vs sum(1..16)=136.
        assert!((every2 as f64 / every as f64 - 72.0 / 136.0).abs() < 0.01);
    }

    #[test]
    fn jacobi_phase_is_tiny_relative_to_lanczos() {
        // §V-A: Lanczos dominates (>99%) on paper-scale graphs (millions
        // of rows / tens of millions of nnz).
        let m = FpgaTimingModel::default();
        let t = m.solve_time(2_000_000, &shards_for(20_000_000, 5), 16, ReorthPolicy::EveryN(2), 150);
        assert!(t.jacobi_s < 0.001 * t.lanczos_s(), "{t:?}");
    }

    #[test]
    fn balanced_partition_of_real_graph_keeps_bandwidth() {
        let coo = crate::graphs::rmat(1 << 12, 40 << 12, 0.57, 0.19, 0.19, 3);
        let csr = coo.to_csr();
        let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::BalancedNnz);
        let m = FpgaTimingModel::default();
        // Within 20% of the ideal aggregate despite power-law skew.
        assert!(m.effective_read_gbps(&shards) > 0.8 * 71.87);
    }

    #[test]
    fn q115_storage_shortens_the_spmv_phase() {
        use crate::fixed::Precision;
        let f = FpgaTimingModel::for_precision(Precision::Float32);
        let q = FpgaTimingModel::for_precision(Precision::FixedQ1_15);
        assert_eq!(f.packet_nnz, 5);
        assert_eq!(q.packet_nnz, 6);
        let shards = shards_for(30_000_000, 5);
        let cf = f.spmv_cycles(&shards);
        let cq = q.spmv_cycles(&shards);
        // 6 entries per line: exactly 5/6 of the f32 cycle count on a
        // capacity-divisible shard size.
        assert_eq!(cq * 6, cf * 5, "cf={cf} cq={cq}");
    }

    #[test]
    fn more_cus_scale_spmv_down() {
        let m1 = FpgaTimingModel { cus: 1, ..Default::default() };
        let m5 = FpgaTimingModel::default();
        let s1 = shards_for(10_000_000, 1);
        let s5 = shards_for(10_000_000, 5);
        let c1 = m1.spmv_cycles(&s1);
        let c5 = m5.spmv_cycles(&s5);
        assert_eq!(c1, 5 * c5);
    }
}

//! Analytic + event-level model of the paper's Alveo U280 hardware design
//! (§IV, §V). This is the hardware-substitution layer (see DESIGN.md):
//! the physical FPGA is unavailable, so the performance, power, and
//! resource claims are reproduced from the design's own first principles —
//! the paper states SpMV is HBM-bandwidth-bound and the systolic Jacobi
//! runs constant-time steps, which makes both phases analytically
//! modelable to within a few percent.
//!
//! * [`specs`] — U280 platform constants (channels, bandwidth, clock) and
//!   the paper's measured operating points.
//! * [`timing`] — cycle-level execution-time model for the two phases.
//! * [`resources`] — Table I resource-utilization model.
//! * [`power`] — §V-B power/efficiency model.

pub mod hetero;
pub mod power;
pub mod resources;
pub mod specs;
pub mod timing;

pub use hetero::{compare_deployments, GpuModel, HeteroEstimate};
pub use power::{PowerModel, PowerReport};
pub use resources::{jacobi_core_resources, lanczos_core_resources, ResourceUsage, SlrBudget};
pub use specs::U280;
pub use timing::{FpgaTimingModel, PhaseTimes};

//! Resource-utilization model (Table I).
//!
//! Per-unit costs are calibrated so the shipped design point (5 SpMV CUs on
//! SLR0; Jacobi cores for K=32 on SLR1 and K=16+8+4 on SLR2) reproduces the
//! paper's utilization rows; the model then extrapolates to other CU
//! counts / K values for the ablation benches. Percentages are of one SLR
//! (the U280 splits its resources roughly evenly across 3 SLRs), matching
//! the table's convention.

use crate::fpga::specs::U280;

/// Absolute resource usage of a core/design.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceUsage {
    /// Lookup tables.
    pub lut: usize,
    /// Flip-flops.
    pub ff: usize,
    /// BRAM tiles.
    pub bram: usize,
    /// URAM tiles.
    pub uram: usize,
    /// DSP slices.
    pub dsp: usize,
}

impl ResourceUsage {
    /// Component-wise sum.
    pub fn plus(self, o: ResourceUsage) -> ResourceUsage {
        ResourceUsage {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            uram: self.uram + o.uram,
            dsp: self.dsp + o.dsp,
        }
    }
}

/// One SLR's budget (1/3 of the device, the U280's actual layout).
#[derive(Clone, Copy, Debug)]
pub struct SlrBudget;

impl SlrBudget {
    /// LUTs per SLR.
    pub const LUT: usize = U280::LUTS / 3;
    /// FFs per SLR.
    pub const FF: usize = U280::FFS / 3;
    /// BRAMs per SLR.
    pub const BRAM: usize = U280::BRAMS / 3;
    /// URAMs per SLR.
    pub const URAM: usize = U280::URAMS / 3;
    /// DSPs per SLR.
    pub const DSP: usize = U280::DSPS / 3;

    /// Utilization percentages `(lut, ff, bram, uram, dsp)` of `u` against
    /// one SLR.
    pub fn utilization_pct(u: ResourceUsage) -> (f64, f64, f64, f64, f64) {
        (
            100.0 * u.lut as f64 / Self::LUT as f64,
            100.0 * u.ff as f64 / Self::FF as f64,
            100.0 * u.bram as f64 / Self::BRAM as f64,
            100.0 * u.uram as f64 / Self::URAM as f64,
            100.0 * u.dsp as f64 / Self::DSP as f64,
        )
    }

    /// Does `u` fit one SLR?
    pub fn fits(u: ResourceUsage) -> bool {
        u.lut <= Self::LUT && u.ff <= Self::FF && u.bram <= Self::BRAM && u.uram <= Self::URAM && u.dsp <= Self::DSP
    }
}

// ---- Calibrated per-unit costs ------------------------------------------
// Lanczos (SLR0, Table I row 1: 42% LUT, 13% FF, 15% BRAM, 0% URAM, 16% DSP
// with 5 CUs): per-CU dataflow pipeline + shared merge/vector unit.
const SPMV_CU_LUT: usize = 26_000;
const SPMV_CU_FF: usize = 16_000;
const SPMV_CU_BRAM: usize = 16; // stream FIFOs between the 4 stages
const SPMV_CU_DSP: usize = 64; // 5 MACs + index arithmetic, unrolled x5
const MERGE_VEC_LUT: usize = 23_000; // merge unit + scalar chain
const MERGE_VEC_FF: usize = 14_500;
const MERGE_VEC_BRAM: usize = 10;
const MERGE_VEC_DSP: usize = 160; // dot/axpy/norm 16-lane pipelines

// Jacobi (SLR1 row: 40% LUT 42% FF 68% DSP hosting the K=32 core; SLR2 row:
// 15/17/34% hosting two K=16 cores — the DSP column being exactly half of
// SLR1 pins that composition): K^2/4 PEs x 8 DSP rotations; the K/2
// diagonal PEs time-multiplex their rotation multipliers for the Taylor
// trig (the polynomial needs 10 mults once per step vs 8 sustained), so
// trig adds LUT/FF but no standing DSPs. Per-PE LUT/FF include a wiring
// term growing with K: each PE's neighbour exchange muxes span a row of
// the array, so routing cost per PE grows linearly in K (this is the
// effect that caps the systolic design at K~32, §IV-C).
const PE_DSP: usize = 8; // 2x2 rotate: 8 mults fully unrolled
const PE_LUT_BASE: usize = 151;
const PE_LUT_WIRE_PER_K: usize = 12;
const PE_FF_BASE: usize = 534;
const PE_FF_WIRE_PER_K: usize = 19;
const TRIG_LUT: usize = 500;
const TRIG_FF: usize = 800;
const JACOBI_CTRL_LUT: usize = 1_500; // sequencer + PLRAM interface
const JACOBI_CTRL_FF: usize = 2_000;

/// Resources of the Lanczos core with `cus` SpMV compute units.
pub fn lanczos_core_resources(cus: usize) -> ResourceUsage {
    ResourceUsage {
        lut: SPMV_CU_LUT * cus + MERGE_VEC_LUT,
        ff: SPMV_CU_FF * cus + MERGE_VEC_FF,
        bram: SPMV_CU_BRAM * cus + MERGE_VEC_BRAM,
        uram: 0, // the HBM redesign eliminated URAM (§IV-B2)
        dsp: SPMV_CU_DSP * cus + MERGE_VEC_DSP,
    }
}

/// Resources of one Jacobi systolic core sized for `k` eigencomponents.
pub fn jacobi_core_resources(k: usize) -> ResourceUsage {
    assert!(k >= 2, "jacobi core needs k >= 2");
    let pes = (k / 2) * (k / 2);
    let diag = k / 2;
    ResourceUsage {
        lut: (PE_LUT_BASE + PE_LUT_WIRE_PER_K * k) * pes + TRIG_LUT * diag + JACOBI_CTRL_LUT,
        ff: (PE_FF_BASE + PE_FF_WIRE_PER_K * k) * pes + TRIG_FF * diag + JACOBI_CTRL_FF,
        bram: 0,
        uram: 0,
        dsp: PE_DSP * pes,
    }
}

/// The paper's shipped configuration: SLR1 hosts the K=32 core (§IV-C:
/// "multiple Jacobi cores optimized for specific K").
pub fn shipped_slr1() -> ResourceUsage {
    jacobi_core_resources(32)
}

/// SLR2: two K=16 cores (Table I's SLR2 DSP count is exactly half of
/// SLR1's, which identifies the replica set).
pub fn shipped_slr2() -> ResourceUsage {
    jacobi_core_resources(16).plus(jacobi_core_resources(16))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pct(u: ResourceUsage) -> (f64, f64, f64, f64, f64) {
        SlrBudget::utilization_pct(u)
    }

    #[test]
    fn lanczos_slr0_matches_table1() {
        // Table I: LUT 42%, FF 13%, BRAM 15%, URAM 0%, DSP 16%.
        let (lut, ff, bram, uram, dsp) = pct(lanczos_core_resources(5));
        assert!((lut - 42.0).abs() < 2.0, "lut {lut}");
        assert!((ff - 13.0).abs() < 2.0, "ff {ff}");
        assert!((bram - 15.0).abs() < 2.0, "bram {bram}");
        assert_eq!(uram, 0.0);
        assert!((dsp - 16.0).abs() < 2.0, "dsp {dsp}");
    }

    #[test]
    fn jacobi_slr1_matches_table1() {
        // Table I SLR1: LUT 40%, FF 42%, DSP 68%, zero BRAM/URAM.
        let (lut, ff, bram, uram, dsp) = pct(shipped_slr1());
        assert!((lut - 40.0).abs() < 3.0, "lut {lut}");
        assert!((ff - 42.0).abs() < 3.0, "ff {ff}");
        assert_eq!(bram, 0.0);
        assert_eq!(uram, 0.0);
        assert!((dsp - 68.0).abs() < 3.0, "dsp {dsp}");
    }

    #[test]
    fn jacobi_slr2_matches_table1() {
        // Table I SLR2: LUT 15%, FF 17%, DSP 34%.
        let (lut, ff, _, _, dsp) = pct(shipped_slr2());
        assert!((lut - 15.0).abs() < 3.0, "lut {lut}");
        assert!((ff - 17.0).abs() < 3.0, "ff {ff}");
        assert!((dsp - 34.0).abs() < 6.0, "dsp {dsp}");
    }

    #[test]
    fn jacobi_scales_quadratically_with_k() {
        // §V: "Resource utilization of the Jacobi algorithm scales
        // quadratically with the number of eigenvalues K".
        let d8 = jacobi_core_resources(8).dsp as f64;
        let d16 = jacobi_core_resources(16).dsp as f64;
        let d32 = jacobi_core_resources(32).dsp as f64;
        assert!((d16 / d8 - 4.0).abs() < 0.6, "8->16 ratio {}", d16 / d8);
        assert!((d32 / d16 - 4.0).abs() < 0.3, "16->32 ratio {}", d32 / d16);
    }

    #[test]
    fn k32_fits_one_slr_k64_does_not() {
        // §IV-C: "the systolic formulation cannot scale beyond very small
        // matrices (K ~ 32)".
        assert!(SlrBudget::fits(jacobi_core_resources(32)));
        assert!(!SlrBudget::fits(jacobi_core_resources(64)));
    }

    #[test]
    fn lanczos_scales_linearly_with_cus() {
        let r1 = lanczos_core_resources(1);
        let r5 = lanczos_core_resources(5);
        let marginal = (r5.lut - r1.lut) as f64 / 4.0;
        assert!((marginal - SPMV_CU_LUT as f64).abs() < 1.0);
    }
}

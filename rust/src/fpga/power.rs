//! Power and efficiency model (§V-B).
//!
//! The paper's methodology: an external meter reads ~38 W at the FPGA card
//! during execution (plus ~40 W for its host server) versus ~300 W for the
//! dual-Xeon CPU baseline; Performance/Watt = 1 / (time x power), compared
//! as a ratio. We reproduce exactly that arithmetic, seeded with the
//! paper's measured wattages, applied to whatever execution times the
//! timing model / measured baseline produce.

use crate::fpga::specs::U280;

/// Power operating points (watts).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// FPGA card power under load.
    pub fpga_w: f64,
    /// FPGA host-server power.
    pub host_w: f64,
    /// CPU baseline power under load.
    pub cpu_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self { fpga_w: U280::FPGA_POWER_W, host_w: U280::HOST_POWER_W, cpu_w: U280::CPU_POWER_W }
    }
}

/// Efficiency comparison for one workload.
#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    /// FPGA execution time (s).
    pub fpga_time_s: f64,
    /// CPU execution time (s).
    pub cpu_time_s: f64,
    /// Energy consumed by the FPGA card (J).
    pub fpga_energy_j: f64,
    /// Energy consumed by the CPU (J).
    pub cpu_energy_j: f64,
    /// Perf/Watt gain, card only (the paper's 49x headline).
    pub perf_per_watt_gain: f64,
    /// Perf/Watt gain including the FPGA host (the paper's 24x).
    pub perf_per_watt_gain_with_host: f64,
}

impl PowerModel {
    /// Build the §V-B comparison from measured/modelled times.
    pub fn compare(&self, fpga_time_s: f64, cpu_time_s: f64) -> PowerReport {
        assert!(fpga_time_s > 0.0 && cpu_time_s > 0.0);
        let speedup = cpu_time_s / fpga_time_s;
        PowerReport {
            fpga_time_s,
            cpu_time_s,
            fpga_energy_j: fpga_time_s * self.fpga_w,
            cpu_energy_j: cpu_time_s * self.cpu_w,
            perf_per_watt_gain: speedup * self.cpu_w / self.fpga_w,
            perf_per_watt_gain_with_host: speedup * self.cpu_w / (self.fpga_w + self.host_w),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_headline_ratios() {
        // At the paper's geomean speedup (6.22x), the power ratios become
        // 49x (card) and 24x (card + host) — §V-B.
        let r = PowerModel::default().compare(1.0, 6.22);
        assert!((r.perf_per_watt_gain - 49.1).abs() < 1.0, "{}", r.perf_per_watt_gain);
        assert!((r.perf_per_watt_gain_with_host - 23.9).abs() < 1.0, "{}", r.perf_per_watt_gain_with_host);
    }

    #[test]
    fn energy_accounting() {
        let r = PowerModel::default().compare(2.0, 10.0);
        assert_eq!(r.fpga_energy_j, 76.0);
        assert_eq!(r.cpu_energy_j, 3000.0);
    }

    #[test]
    fn equal_times_still_favour_fpga_power() {
        let r = PowerModel::default().compare(1.0, 1.0);
        assert!((r.perf_per_watt_gain - 300.0 / 38.0).abs() < 1e-9);
    }
}

//! Heterogeneous design point — the paper's second future-work item (§VI:
//! "investigate heterogeneous implementations that combine the abundant
//! memory bandwidth of GPUs for high-performance SpMV with our systolic
//! array FPGA design for the Jacobi eigenvalue").
//!
//! Models three deployments of the two-phase solver:
//! * FPGA-only (the paper's shipped system): HBM2 @ 5x14.37 GB/s SpMV +
//!   systolic Jacobi;
//! * GPU+FPGA: V100-class SpMV (900 GB/s HBM2 at a realistic SpMV
//!   efficiency) + PCIe transfer of the 3K-2 tridiagonal words + FPGA
//!   systolic Jacobi;
//! * GPU-only: GPU SpMV + GPU Jacobi, where small-K dense eigensolves
//!   under-fill the SMs (§II: "GPUs cannot fill all their Stream
//!   Processors, as the input size is much smaller than what is required")
//!   — modeled as a fixed kernel-launch + low-occupancy cost per sweep.

use crate::fpga::timing::{FpgaTimingModel, JACOBI_STEP_CYCLES, PLRAM_HANDSHAKE_CYCLES};
use crate::lanczos::ReorthPolicy;
use crate::sparse::RowPartition;

/// GPU platform constants (V100-class, as the paper's era suggests).
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// Peak HBM2 bandwidth (GB/s).
    pub hbm_gbps: f64,
    /// Achievable SpMV efficiency vs peak (COO/CSR gather-bound).
    pub spmv_efficiency: f64,
    /// Kernel launch + sync latency per operation (s).
    pub launch_s: f64,
    /// Effective throughput for a K x K dense Jacobi sweep (fraction of
    /// SMs a K<=32 problem can fill).
    pub small_k_occupancy: f64,
    /// Peak FP32 throughput (GFLOP/s).
    pub fp32_gflops: f64,
    /// PCIe gen3 x16 effective bandwidth for device-device staging (GB/s).
    pub pcie_gbps: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        Self {
            hbm_gbps: 900.0,
            spmv_efficiency: 0.55, // gather-bound COO SpMV on V100
            launch_s: 8e-6,
            small_k_occupancy: 0.02, // K<=32 fills ~2% of 80 SMs
            fp32_gflops: 14_000.0,
            pcie_gbps: 12.0,
        }
    }
}

/// Per-deployment time estimate (seconds).
#[derive(Clone, Copy, Debug)]
pub struct HeteroEstimate {
    /// Lanczos phase (SpMV + vector ops), seconds.
    pub lanczos_s: f64,
    /// Inter-device transfer, seconds.
    pub transfer_s: f64,
    /// Jacobi phase, seconds.
    pub jacobi_s: f64,
}

impl HeteroEstimate {
    /// Total seconds.
    pub fn total_s(&self) -> f64 {
        self.lanczos_s + self.transfer_s + self.jacobi_s
    }
}

/// GPU SpMV time for one iteration: bandwidth-bound COO streaming plus
/// the dense-vector gather (counted once through HBM) and launch latency.
fn gpu_spmv_s(g: &GpuModel, nnz: usize, n: usize) -> f64 {
    let bytes = nnz as f64 * 12.0 + n as f64 * 8.0; // COO + x/y traffic
    bytes / (g.hbm_gbps * g.spmv_efficiency * 1e9) + g.launch_s
}

/// GPU Jacobi sweep time: the K^3-ish flops at tiny occupancy + launch.
fn gpu_jacobi_sweep_s(g: &GpuModel, k: usize) -> f64 {
    let flops = (k * k * k) as f64 * 8.0; // rotations as small matmuls
    flops / (g.fp32_gflops * g.small_k_occupancy * 1e9) + g.launch_s
}

/// Estimate all three deployments for one solve.
///
/// `jacobi_steps` is the measured systolic step count; GPU sweeps are
/// `jacobi_steps / (k-1)` (same schedule, different executor).
pub fn compare_deployments(
    fpga: &FpgaTimingModel,
    gpu: &GpuModel,
    n: usize,
    shards: &[RowPartition],
    k: usize,
    policy: ReorthPolicy,
    jacobi_steps: usize,
) -> (HeteroEstimate, HeteroEstimate, HeteroEstimate) {
    let nnz: usize = shards.iter().map(|p| p.nnz).sum();
    let sweeps = jacobi_steps.div_ceil((k - 1).max(1));

    // --- FPGA-only (the paper's system).
    let f = fpga.solve_time(n, shards, k, policy, jacobi_steps);
    let fpga_only = HeteroEstimate {
        lanczos_s: f.lanczos_s(),
        transfer_s: PLRAM_HANDSHAKE_CYCLES as f64 / fpga.clock_hz,
        jacobi_s: f.jacobi_s,
    };

    // --- GPU + FPGA: GPU Lanczos, tridiagonal over PCIe, FPGA Jacobi.
    let reorth_passes: usize = (1..=k)
        .map(|i| match policy {
            ReorthPolicy::None => 0,
            ReorthPolicy::Every => 2 * i,
            ReorthPolicy::EveryN(p) => {
                if p != 0 && i % p == 0 {
                    2 * i
                } else {
                    0
                }
            }
        })
        .sum();
    let gpu_vec_s = (3 * k + reorth_passes) as f64 * (n as f64 * 8.0 / (gpu.hbm_gbps * 1e9) + gpu.launch_s);
    let gpu_lanczos = k as f64 * gpu_spmv_s(gpu, nnz, n) + gpu_vec_s;
    let hybrid = HeteroEstimate {
        lanczos_s: gpu_lanczos,
        transfer_s: (3 * k) as f64 * 4.0 / (gpu.pcie_gbps * 1e9) + 15e-6, // words + PCIe latency
        jacobi_s: (jacobi_steps * JACOBI_STEP_CYCLES) as f64 / fpga.clock_hz,
    };

    // --- GPU-only.
    let gpu_only = HeteroEstimate {
        lanczos_s: gpu_lanczos,
        transfer_s: 0.0,
        jacobi_s: sweeps as f64 * gpu_jacobi_sweep_s(gpu, k),
    };

    (fpga_only, hybrid, gpu_only)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shards(nnz: usize) -> Vec<RowPartition> {
        (0..5).map(|i| RowPartition { row_start: i, row_end: i + 1, nnz: nnz / 5 }).collect()
    }

    #[test]
    fn gpu_spmv_beats_fpga_spmv_on_bandwidth() {
        // 900 GB/s * 0.55 = 495 GB/s effective vs 71.87 GB/s: the paper's
        // motivation for the hybrid.
        let fpga = FpgaTimingModel::default();
        let gpu = GpuModel::default();
        let (f, h, _) = compare_deployments(
            &fpga,
            &gpu,
            2_000_000,
            &shards(30_000_000),
            16,
            ReorthPolicy::EveryN(2),
            150,
        );
        assert!(h.lanczos_s < f.lanczos_s / 3.0, "hybrid {h:?} vs fpga {f:?}");
    }

    #[test]
    fn fpga_jacobi_beats_gpu_jacobi_at_small_k() {
        // §II: small-K dense work cannot fill a GPU.
        let fpga = FpgaTimingModel::default();
        let gpu = GpuModel::default();
        let (_, h, g) =
            compare_deployments(&fpga, &gpu, 100_000, &shards(1_000_000), 16, ReorthPolicy::EveryN(2), 150);
        assert!(h.jacobi_s < g.jacobi_s, "hybrid jacobi {} vs gpu jacobi {}", h.jacobi_s, g.jacobi_s);
    }

    #[test]
    fn hybrid_wins_end_to_end_on_large_graphs() {
        // The future-work hypothesis: GPU SpMV + FPGA Jacobi dominates both
        // pure deployments once SpMV dominates (large nnz).
        let fpga = FpgaTimingModel::default();
        let gpu = GpuModel::default();
        let (f, h, g) = compare_deployments(
            &fpga,
            &gpu,
            10_000_000,
            &shards(57_000_000),
            24,
            ReorthPolicy::EveryN(2),
            250,
        );
        assert!(h.total_s() < f.total_s(), "hybrid {} vs fpga {}", h.total_s(), f.total_s());
        assert!(h.total_s() <= g.total_s(), "hybrid {} vs gpu {}", h.total_s(), g.total_s());
    }

    #[test]
    fn pcie_transfer_is_negligible() {
        // 3K-2 words over PCIe must not erase the hybrid's advantage.
        let fpga = FpgaTimingModel::default();
        let gpu = GpuModel::default();
        let (_, h, _) =
            compare_deployments(&fpga, &gpu, 1_000_000, &shards(10_000_000), 32, ReorthPolicy::None, 300);
        assert!(h.transfer_s < 0.01 * h.total_s(), "{h:?}");
    }
}
